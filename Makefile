# Convenience targets; the Rust side needs only artifacts/manifest.txt
# (checked in). `make artifacts` regenerates the manifest and the real
# HLO programs through JAX when a Python environment is available.

.PHONY: all test bench bench-smoke artifacts doc fmt lint

all:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench e1_table1
	cargo bench --bench e2_ars
	cargo bench --bench e3_table2
	cargo bench --bench e4_table3
	cargo bench --bench e5_batching
	cargo bench --bench e6_memory

# Quick perf gate: compiles every bench, then runs the E6 memory bench
# with a short frame budget and records artifacts/BENCH_e6_memory.json
# (the bench asserts >= 30% allocation reduction and bit-identical output).
bench-smoke:
	cargo bench --no-run
	cargo bench --bench e6_memory -- --frames 64 --record

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

doc:
	cargo doc --no-deps

fmt:
	cargo fmt

# Mirrors the CI `lint` job.
lint:
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings
