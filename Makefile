# Convenience targets; the Rust side needs only artifacts/manifest.txt
# (checked in). `make artifacts` regenerates the manifest and the real
# HLO programs through JAX when a Python environment is available.

.PHONY: all test bench bench-smoke artifacts doc fmt lint check unsafe-audit

all:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench e1_table1
	cargo bench --bench e2_ars
	cargo bench --bench e3_table2
	cargo bench --bench e4_table3
	cargo bench --bench e5_batching
	cargo bench --bench e6_memory
	cargo bench --bench e7_concurrency
	cargo bench --bench e8_query
	cargo bench --bench e9_serving
	cargo bench --bench e10_faults
	cargo bench --bench e11_wire
	cargo bench --bench e12_device_lane

# Quick perf gate: compiles every bench, runs the E6 memory bench with a
# short frame budget (records artifacts/BENCH_e6_memory.json; asserts
# >= 30% allocation reduction and bit-identical output), then the E7
# concurrency bench (64 pipelines on a 4-worker hub; asserts O(workers)
# threads and sink output bit-identical to a serialized run), then the
# E8 stream-endpoint bench (topic-linked split of the E1 chain; asserts
# bit-identical sink output and bounded threads), then the E9 serving
# bench (QoS isolation: a leaky-tenant flood plus a SingleShot storm
# must not move a blocking victim's p99 latency), then the E10 fault
# bench (a chaos co-tenant panics twice and is restarted under backoff;
# asserts bit-exact victim output and < 20% p99 movement), and finally
# the E11 wire bench (the same split over a loopback TCP transport;
# records artifacts/BENCH_e11_wire.json; asserts sink output
# bit-identical across the wire), and the E12 device-lane bench (64
# live pipelines with a multi-ms NPU filter on a 4-worker hub; records
# artifacts/BENCH_e12_device_lane.json; asserts the async device lane
# reaches >= 4x the blocking throughput with O(workers) threads and
# bit-identical sink output).
bench-smoke:
	cargo bench --no-run
	cargo bench --bench e6_memory -- --frames 64 --record
	cargo bench --bench e7_concurrency -- --frames 8
	cargo bench --bench e8_query -- --frames 24
	cargo bench --bench e9_serving -- --frames 48
	cargo bench --bench e10_faults -- --frames 48
	cargo bench --bench e11_wire -- --frames 24 --record
	cargo bench --bench e12_device_lane -- --frames 12 --record

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

doc:
	cargo doc --no-deps

fmt:
	cargo fmt

# Mirrors the CI `lint` job.
lint:
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings -D clippy::mutex_atomic -D clippy::mutex_integer

# nnscheck: explore the concurrency micro-models under the controlled
# scheduler (tests/check.rs; seeded random walks + bounded-preemption
# DFS), prove the executor's lost-wakeup guard is load-bearing by
# mutation (`mutate-wake-pending` compiles it out and the suite must
# then produce a replayable counterexample), and run the lock-order
# suite (tests/lockdep.rs) in a debug build where lockdep is live.
# Replay a failing interleaving: NNSCHECK_SEED=0x<seed> make check
check:
	cargo test --features check --test check
	cargo test --features check,mutate-wake-pending --test check
	cargo test --test lockdep

# `deny(unsafe_code)` is crate-wide (see rust/src/lib.rs); only
# tensor/buffer.rs and metrics/process.rs carry the audited opt-out.
# Fail if the opt-out attribute shows up anywhere else.
unsafe-audit:
	@bad=$$(grep -rln "allow(unsafe_code)" rust/src \
		| grep -v "^rust/src/tensor/buffer.rs$$" \
		| grep -v "^rust/src/metrics/process.rs$$"); \
	if [ -n "$$bad" ]; then \
		echo "unsafe-audit: unexpected allow(unsafe_code) in:"; \
		echo "$$bad"; exit 1; \
	fi; \
	echo "unsafe-audit: opt-outs confined to tensor/buffer.rs and metrics/process.rs"
