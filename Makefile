# Convenience targets; the Rust side needs only artifacts/manifest.txt
# (checked in). `make artifacts` regenerates the manifest and the real
# HLO programs through JAX when a Python environment is available.

.PHONY: all test bench artifacts doc fmt

all:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench e1_table1
	cargo bench --bench e2_ars
	cargo bench --bench e3_table2
	cargo bench --bench e4_table3
	cargo bench --bench e5_batching

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

doc:
	cargo doc --no-deps

fmt:
	cargo fmt
