//! The MTCNN face-detection cascade (E3, Fig 4) — fused, split into two
//! hub pipelines joined by `tensor_query` stream topics, and split into
//! **two OS processes** joined by the TCP transport.
//!
//! The most topologically complex pipeline of the paper: a 5-scale image
//! pyramid of fully-convolutional P-Nets running in parallel branches,
//! merged with NMS, refined by R-Net and O-Net stages with image-patch
//! extraction and bounding-box regression between them.
//!
//! The split runs demonstrate the among-device composition of the
//! follow-up paper (arXiv:2201.06026): the camera + P-Net stage runs as
//! one pipeline publishing `mtcnn/frames` and `mtcnn/boxes`, and the
//! R/O-Net refinement runs as a *second* pipeline subscribing both —
//! first in-process on a shared worker pool, then as a child process
//! publishing over `transport=tcp` while this process consumes. Sink
//! output is bit-identical to the fused single-pipeline run in both
//! compositions.
//!
//! ```bash
//! cargo run --release --example mtcnn_cascade [frames] [device-class: a|b|c]
//! ```

use nnstreamer::apps::e3_mtcnn::{self, MtcnnConfig};
use nnstreamer::devices::DeviceClass;
use nnstreamer::net::{register_tcp, NetRegistry, TcpConfig};

/// Set in the child process: the discovery-registry address to publish
/// the front half's topics through.
const FRONT_ENV: &str = "MTCNN_FRONT_REGISTRY";

fn parse_cfg() -> Result<MtcnnConfig, Box<dyn std::error::Error>> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let class = std::env::args()
        .nth(2)
        .map(|v| DeviceClass::parse(&v))
        .transpose()?
        .unwrap_or(DeviceClass::Pc);
    Ok(MtcnnConfig {
        num_frames: frames,
        class,
        fps: 10_000.0, // batch: as fast as the cascade can go
        live: false,
        ..Default::default()
    })
}

/// Child-process body: the camera + P-Net half, publishing both topics
/// over TCP to whoever the registry resolves.
fn run_front_process(registry: &str) -> Result<(), Box<dyn std::error::Error>> {
    let cfg = parse_cfg()?;
    let transport = register_tcp(TcpConfig::new(registry));
    let report = e3_mtcnn::run_split_front(&cfg, "mtcnn-net", "tcp")?;
    // don't exit until the final EOS frames actually hit the sockets
    transport.quiesce(std::time::Duration::from_secs(10));
    for t in report.topics.iter().filter(|t| t.name.starts_with("tcp-pub:")) {
        assert_eq!(
            t.pushed,
            t.delivered + t.dropped + t.in_flight,
            "publisher-side conservation violated on {}",
            t.name
        );
        eprintln!(
            "  [front pid {}] {}: {} pushed = {} delivered + {} dropped + {} in flight",
            std::process::id(),
            t.name,
            t.pushed,
            t.delivered,
            t.dropped,
            t.in_flight
        );
    }
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if let Ok(registry) = std::env::var(FRONT_ENV) {
        return run_front_process(&registry);
    }
    let cfg = parse_cfg()?;
    let frames = cfg.num_frames;
    let class = cfg.class;

    println!(
        "running MTCNN on device class {} ({} Full-HD frames)...",
        class.name(),
        frames
    );
    let nns = e3_mtcnn::run_nns(&cfg)?;

    println!("running the two-pipeline split (front: P-Net | back: R/O-Net)...");
    let fused_sink = e3_mtcnn::run_collect(&cfg)?;
    let t0 = std::time::Instant::now();
    let split = e3_mtcnn::run_split(&cfg, "mtcnn", 4)?;
    let split_wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        split.sink, fused_sink,
        "split sink output must be bit-identical to the fused run"
    );
    let split_fps = split.sink.len() as f64 / split_wall;

    println!("running the two-PROCESS split over transport=tcp...");
    // this process consumes: host the discovery registry, register the
    // TCP transport, and hand the child the registry address
    let registry = NetRegistry::serve("127.0.0.1:0")?;
    let addr = registry.addr().to_string();
    register_tcp(TcpConfig::new(&addr));
    let class_token = match class {
        DeviceClass::MidEmbedded => "a",
        DeviceClass::HighEmbedded => "b",
        DeviceClass::Pc => "c",
    };
    let mut child = std::process::Command::new(std::env::current_exe()?)
        .arg(frames.to_string())
        .arg(class_token)
        .env(FRONT_ENV, &addr)
        .spawn()?;
    let (net_report, net_sink) = e3_mtcnn::run_split_back(&cfg, "mtcnn-net", "tcp")?;
    let status = child.wait()?;
    assert!(status.success(), "front process failed: {status}");
    assert_eq!(
        net_sink, fused_sink,
        "two-process sink output must be bit-identical to the fused run"
    );
    for t in net_report
        .topics
        .iter()
        .filter(|t| t.name.starts_with("tcp-sub:"))
    {
        assert_eq!(
            t.pushed,
            t.delivered + t.dropped + t.in_flight,
            "subscriber-side conservation violated on {}",
            t.name
        );
    }

    println!("running serial Control (the ROS team's implementation)...");
    let ctl = e3_mtcnn::run_control(&cfg)?;

    println!("\n== Table II shape on this machine ({}) ==", class.name());
    println!("                      Control    NNStreamer   NNS split (2 pipelines)");
    println!(
        "  throughput (fps)   {:8.2}    {:8.2}     {:8.2}",
        ctl.throughput_fps, nns.throughput_fps, split_fps
    );
    println!(
        "  P-Net latency (ms) {:8.1}    {:8.1}",
        ctl.pnet_latency_ms, nns.pnet_latency_ms
    );
    println!(
        "  R-Net latency (ms) {:8.1}    {:8.1}",
        ctl.rnet_latency_ms, nns.rnet_latency_ms
    );
    println!(
        "  O-Net latency (ms) {:8.1}    {:8.1}",
        ctl.onet_latency_ms, nns.onet_latency_ms
    );
    println!(
        "\n  NNStreamer throughput gain: {:+.1}%",
        (nns.throughput_fps / ctl.throughput_fps - 1.0) * 100.0
    );
    if let Some(t) = split.front.topic("mtcnn/frames") {
        println!(
            "  topic mtcnn/frames: {} published / {} delivered / {} dropped",
            t.published, t.delivered, t.dropped
        );
    }
    if let Some(t) = net_report
        .topics
        .iter()
        .find(|t| t.name == "tcp-sub:mtcnn-net/frames")
    {
        println!(
            "  wire topic mtcnn-net/frames: {} pushed / {} delivered / {} in flight",
            t.pushed, t.delivered, t.in_flight
        );
    }
    println!(
        "  split sink bit-identical to fused: OK ({} frames, in-process and over TCP)",
        split.sink.len()
    );
    Ok(())
}
