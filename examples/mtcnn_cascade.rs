//! The MTCNN face-detection cascade (E3, Fig 4).
//!
//! The most topologically complex pipeline of the paper: a 5-scale image
//! pyramid of fully-convolutional P-Nets running in parallel branches,
//! merged with NMS, refined by R-Net and O-Net stages with image-patch
//! extraction and bounding-box regression between them.
//!
//! ```bash
//! cargo run --release --example mtcnn_cascade [frames] [device-class: a|b|c]
//! ```

use nnstreamer::apps::e3_mtcnn::{self, MtcnnConfig};
use nnstreamer::devices::DeviceClass;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let class = std::env::args()
        .nth(2)
        .map(|v| DeviceClass::parse(&v))
        .transpose()?
        .unwrap_or(DeviceClass::Pc);

    let cfg = MtcnnConfig {
        num_frames: frames,
        class,
        fps: 10_000.0, // batch: as fast as the cascade can go
        live: false,
        ..Default::default()
    };

    println!(
        "running MTCNN on device class {} ({} Full-HD frames)...",
        class.name(),
        frames
    );
    let nns = e3_mtcnn::run_nns(&cfg)?;
    println!("running serial Control (the ROS team's implementation)...");
    let ctl = e3_mtcnn::run_control(&cfg)?;

    println!("\n== Table II shape on this machine ({}) ==", class.name());
    println!("                      Control    NNStreamer");
    println!(
        "  throughput (fps)   {:8.2}    {:8.2}",
        ctl.throughput_fps, nns.throughput_fps
    );
    println!(
        "  P-Net latency (ms) {:8.1}    {:8.1}",
        ctl.pnet_latency_ms, nns.pnet_latency_ms
    );
    println!(
        "  R-Net latency (ms) {:8.1}    {:8.1}",
        ctl.rnet_latency_ms, nns.rnet_latency_ms
    );
    println!(
        "  O-Net latency (ms) {:8.1}    {:8.1}",
        ctl.onet_latency_ms, nns.onet_latency_ms
    );
    println!(
        "\n  NNStreamer throughput gain: {:+.1}%",
        (nns.throughput_fps / ctl.throughput_fps - 1.0) * 100.0
    );
    Ok(())
}
