//! The MTCNN face-detection cascade (E3, Fig 4) — fused, and split into
//! two hub pipelines joined by `tensor_query` stream topics.
//!
//! The most topologically complex pipeline of the paper: a 5-scale image
//! pyramid of fully-convolutional P-Nets running in parallel branches,
//! merged with NMS, refined by R-Net and O-Net stages with image-patch
//! extraction and bounding-box regression between them.
//!
//! The split run demonstrates the among-device composition of the
//! follow-up paper (arXiv:2201.06026): the camera + P-Net stage runs as
//! one pipeline publishing `mtcnn/frames` and `mtcnn/boxes`, and the
//! R/O-Net refinement runs as a *second* pipeline subscribing both —
//! sink output is bit-identical to the fused single-pipeline run, on the
//! same bounded worker pool.
//!
//! ```bash
//! cargo run --release --example mtcnn_cascade [frames] [device-class: a|b|c]
//! ```

use nnstreamer::apps::e3_mtcnn::{self, MtcnnConfig};
use nnstreamer::devices::DeviceClass;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let class = std::env::args()
        .nth(2)
        .map(|v| DeviceClass::parse(&v))
        .transpose()?
        .unwrap_or(DeviceClass::Pc);

    let cfg = MtcnnConfig {
        num_frames: frames,
        class,
        fps: 10_000.0, // batch: as fast as the cascade can go
        live: false,
        ..Default::default()
    };

    println!(
        "running MTCNN on device class {} ({} Full-HD frames)...",
        class.name(),
        frames
    );
    let nns = e3_mtcnn::run_nns(&cfg)?;

    println!("running the two-pipeline split (front: P-Net | back: R/O-Net)...");
    let fused_sink = e3_mtcnn::run_collect(&cfg)?;
    let t0 = std::time::Instant::now();
    let split = e3_mtcnn::run_split(&cfg, "mtcnn", 4)?;
    let split_wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        split.sink, fused_sink,
        "split sink output must be bit-identical to the fused run"
    );
    let split_fps = split.sink.len() as f64 / split_wall;

    println!("running serial Control (the ROS team's implementation)...");
    let ctl = e3_mtcnn::run_control(&cfg)?;

    println!("\n== Table II shape on this machine ({}) ==", class.name());
    println!("                      Control    NNStreamer   NNS split (2 pipelines)");
    println!(
        "  throughput (fps)   {:8.2}    {:8.2}     {:8.2}",
        ctl.throughput_fps, nns.throughput_fps, split_fps
    );
    println!(
        "  P-Net latency (ms) {:8.1}    {:8.1}",
        ctl.pnet_latency_ms, nns.pnet_latency_ms
    );
    println!(
        "  R-Net latency (ms) {:8.1}    {:8.1}",
        ctl.rnet_latency_ms, nns.rnet_latency_ms
    );
    println!(
        "  O-Net latency (ms) {:8.1}    {:8.1}",
        ctl.onet_latency_ms, nns.onet_latency_ms
    );
    println!(
        "\n  NNStreamer throughput gain: {:+.1}%",
        (nns.throughput_fps / ctl.throughput_fps - 1.0) * 100.0
    );
    if let Some(t) = split.front.topic("mtcnn/frames") {
        println!(
            "  topic mtcnn/frames: {} published / {} delivered / {} dropped",
            t.published, t.delivered, t.dropped
        );
    }
    println!("  split sink bit-identical to fused: OK ({} frames)", split.sink.len());
    Ok(())
}
