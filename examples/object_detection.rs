//! Object detection serving (the E4 workload as an application).
//!
//! Builds the SSDLite-style detection pipeline with the typed
//! `PipelineBuilder`, serves a batch of frames, and prints detections
//! plus latency/throughput — including a comparison between the two NNFW
//! builds the pipeline can choose from (the paper's P6 argument:
//! framework flexibility is a performance feature).
//!
//! ```bash
//! cargo run --release --example object_detection [frames]
//! ```

use nnstreamer::elements::converter::TensorConverterProps;
use nnstreamer::elements::decoder::{decode_boxes, DecoderMode, TensorDecoderProps};
use nnstreamer::elements::filter::{Framework, TensorFilterProps};
use nnstreamer::elements::sinks::{TensorSink, TensorSinkProps};
use nnstreamer::elements::sources::VideoTestSrcProps;
use nnstreamer::elements::transform::{ArithOp, TensorTransformProps};
use nnstreamer::elements::videofilters::{VideoConvertProps, VideoScaleProps};
use nnstreamer::pipeline::PipelineBuilder;
use nnstreamer::tensor::{DType, VideoFormat};
use nnstreamer::video::Pattern;

fn serve(variant: &str, frames: u64) -> Result<(f64, f64), Box<dyn std::error::Error>> {
    let mut b = PipelineBuilder::new();
    b.chain(VideoTestSrcProps {
        pattern: Pattern::Ball,
        width: 320,
        height: 240,
        framerate: 10_000.0,
        num_buffers: Some(frames),
        ..Default::default()
    })?
    .chain(VideoConvertProps {
        format: VideoFormat::Rgb,
    })?
    .chain(VideoScaleProps {
        width: 96,
        height: 96,
    })?
    .chain(TensorConverterProps)?
    .chain(TensorTransformProps::typecast(DType::F32))?
    .chain(TensorTransformProps::arithmetic(vec![(ArithOp::Div, 255.0)]))?
    .chain(TensorFilterProps {
        framework: Framework::Xla,
        model: format!("ssd_{variant}"),
        ..Default::default()
    })?
    .chain(TensorDecoderProps {
        mode: DecoderMode::BoundingBoxes,
        head: "ssd".into(),
        threshold: 0.4,
        ..Default::default()
    })?
    .chain_named("dets", TensorSinkProps::default())?;
    let mut pipeline = b.build();
    let report = pipeline.run()?;
    let fps = report.fps("dets");
    let lat_ms: f64 = report
        .elements
        .iter()
        .filter(|e| e.buffers_in() > 0)
        .map(|e| e.latency().mean.as_secs_f64() * 1e3)
        .sum();

    if variant == "opt" {
        if let Some(el) = pipeline.finished_element("dets") {
            if let Some(sink) = el.as_any().and_then(|a| a.downcast_mut::<TensorSink>()) {
                println!("sample detections (ssd_{variant}):");
                for b in sink.buffers.iter().take(3) {
                    let boxes = decode_boxes(b.chunk())?;
                    println!("  frame pts={:>9}ns: {} boxes", b.pts_ns, boxes.len());
                    for bx in boxes.iter().take(3) {
                        println!(
                            "    class={:2} score={:.2} at ({:.2},{:.2}) {:.2}x{:.2}",
                            bx.class, bx.score, bx.x, bx.y, bx.w, bx.h
                        );
                    }
                }
            }
        }
    }
    Ok((fps, lat_ms))
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);

    println!("== serving {frames} frames with each NNFW build ==\n");
    let (fps_opt, lat_opt) = serve("opt", frames)?;
    let (fps_ref, lat_ref) = serve("ref", frames)?;

    println!("\n== NNFW flexibility (the paper's E4 headline) ==");
    println!("  build      throughput   chain-latency");
    println!("  ssd_opt    {fps_opt:8.1} fps   {lat_opt:8.2} ms");
    println!("  ssd_ref    {fps_ref:8.1} fps   {lat_ref:8.2} ms");
    println!(
        "  speedup from choosing the right NNFW build: {:.2}x",
        fps_opt / fps_ref.max(1e-9)
    );
    Ok(())
}
