//! Object detection serving (the E4 workload as an application).
//!
//! Loads the SSDLite-style detector, serves a batch of frames, and prints
//! detections plus latency/throughput — including a comparison between the
//! two NNFW builds the pipeline can choose from (the paper's P6 argument:
//! framework flexibility is a performance feature).
//!
//! ```bash
//! cargo run --release --example object_detection [frames]
//! ```

use nnstreamer::elements::decoder::decode_boxes;
use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::Pipeline;

fn serve(variant: &str, frames: u64) -> Result<(f64, f64), Box<dyn std::error::Error>> {
    let desc = format!(
        "videotestsrc pattern=ball num-buffers={frames} ! \
         video/x-raw,format=RGB,width=320,height=240,framerate=10000 ! \
         videoconvert format=RGB ! videoscale width=96 height=96 ! \
         tensor_converter ! tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=ssd_{variant} ! \
         tensor_decoder mode=bounding_boxes option1=ssd option2=0.4 ! \
         tensor_sink name=dets"
    );
    let mut pipeline = Pipeline::parse(&desc)?;
    let report = pipeline.run()?;
    let fps = report.fps("dets");
    let lat_ms: f64 = report
        .elements
        .iter()
        .filter(|e| e.buffers_in() > 0)
        .map(|e| e.latency().mean.as_secs_f64() * 1e3)
        .sum();

    if variant == "opt" {
        if let Some(el) = pipeline.finished_element("dets") {
            if let Some(sink) = el.as_any().and_then(|a| a.downcast_mut::<TensorSink>()) {
                println!("sample detections (ssd_{variant}):");
                for b in sink.buffers.iter().take(3) {
                    let boxes =
                        decode_boxes(b.chunk())?;
                    println!("  frame pts={:>9}ns: {} boxes", b.pts_ns, boxes.len());
                    for bx in boxes.iter().take(3) {
                        println!(
                            "    class={:2} score={:.2} at ({:.2},{:.2}) {:.2}x{:.2}",
                            bx.class, bx.score, bx.x, bx.y, bx.w, bx.h
                        );
                    }
                }
            }
        }
    }
    Ok((fps, lat_ms))
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let frames: u64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);

    println!("== serving {frames} frames with each NNFW build ==\n");
    let (fps_opt, lat_opt) = serve("opt", frames)?;
    let (fps_ref, lat_ref) = serve("ref", frames)?;

    println!("\n== NNFW flexibility (the paper's E4 headline) ==");
    println!("  build      throughput   chain-latency");
    println!("  ssd_opt    {fps_opt:8.1} fps   {lat_opt:8.2} ms");
    println!("  ssd_ref    {fps_ref:8.1} fps   {lat_ref:8.2} ms");
    println!(
        "  speedup from choosing the right NNFW build: {:.2}x",
        fps_opt / fps_ref.max(1e-9)
    );
    Ok(())
}
