//! The ARS multi-modal activity-recognition device (E2, Fig 3).
//!
//! Builds the full multi-sensor pipeline — accelerometer + pressure fused
//! into a long-window classifier, a fast per-window classifier, and a
//! rate-decimated microphone path — and compares it with the conventional
//! serial implementation the paper replaced.
//!
//! ```bash
//! cargo run --release --example ars_activity [windows]
//! ```

use nnstreamer::apps::e2_ars::{self, ArsConfig};
use nnstreamer::baselines::control;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let windows: u64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);

    let cfg = ArsConfig {
        num_windows: windows,
        live: false,
        ..Default::default()
    };
    println!("== the whole ARS application is this pipeline description ==");
    println!("{}\n", e2_ars::launch_description(&cfg));

    println!("running NNStreamer pipeline ({windows} sensor windows)...");
    let nns = e2_ars::run_nns(&cfg)?;
    println!("running conventional serial implementation...");
    let ctl =
        control::run_ars_control(windows, None)?;

    println!("\n== batch processing rates (windows/s), Fig 3 stages ==");
    println!("  stage          Control    NNStreamer   improvement");
    for (name, c, n) in [
        ("(a) activity", ctl.rate_a, nns.rate_a),
        ("(b) fused    ", ctl.rate_b, nns.rate_b),
        ("(c) audio    ", ctl.rate_c, nns.rate_c),
    ] {
        println!(
            "  {name}   {c:9.1}   {n:10.1}   {:+9.1}%",
            (n / c - 1.0) * 100.0
        );
    }
    println!(
        "\n  pipeline description: {} lines (the paper: 'a dozen lines')",
        nns.description_lines
    );
    Ok(())
}
