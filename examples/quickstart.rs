//! Quickstart: the Fig 1-style pipeline, end to end — built with the
//! typed `PipelineBuilder` (no launch strings, no stringly properties).
//!
//! Serves a live 30 fps camera stream (synthetic) through scaling,
//! conversion, normalization, an AOT-compiled Inception-style classifier
//! on the simulated NPU, and a label decoder — then prints per-stage
//! statistics, throughput and end-to-end latency. A live subscription on
//! the `tensor_sink` streams labels while the pipeline plays.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use nnstreamer::elements::converter::TensorConverterProps;
use nnstreamer::elements::decoder::{DecoderMode, TensorDecoderProps};
use nnstreamer::elements::filter::{Framework, TensorFilterProps};
use nnstreamer::elements::sinks::{TensorSink, TensorSinkProps};
use nnstreamer::elements::sources::VideoTestSrcProps;
use nnstreamer::elements::transform::{ArithOp, TensorTransformProps};
use nnstreamer::elements::videofilters::VideoScaleProps;
use nnstreamer::nnfw::Accelerator;
use nnstreamer::pipeline::PipelineBuilder;
use nnstreamer::tensor::DType;
use nnstreamer::video::Pattern;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut b = PipelineBuilder::new();
    b.chain(VideoTestSrcProps {
        pattern: Pattern::Ball,
        width: 640,
        height: 480,
        framerate: 30.0,
        num_buffers: Some(90),
        is_live: true,
        ..Default::default()
    })?
    .chain(VideoScaleProps {
        width: 64,
        height: 64,
    })?
    .chain(TensorConverterProps)?
    .chain(TensorTransformProps::typecast(DType::F32))?
    .chain(TensorTransformProps::arithmetic(vec![(ArithOp::Div, 255.0)]))?
    .chain(TensorFilterProps {
        framework: Framework::Xla,
        model: "i3_opt".into(),
        accelerator: Accelerator::Npu,
        ..Default::default()
    })?
    .chain(TensorDecoderProps {
        mode: DecoderMode::ImageLabeling,
        ..Default::default()
    })?
    .chain_named("labels", TensorSinkProps::default())?;
    let mut pipeline = b.build();

    // play + live subscription: labels stream to the app as they decode
    let running = pipeline.play()?;
    let mut live_seen = 0u64;
    running.subscribe("labels", move |buf| {
        live_seen += 1;
        if live_seen <= 3 {
            if let Ok(v) = buf.chunk().to_f32_vec() {
                println!(
                    "live label: pts={:6.2}s class={:3} p={:.3}",
                    buf.pts_ns as f64 / 1e9,
                    v[0],
                    v[1]
                );
            }
        }
    })?;
    let (report, elements) = running.wait()?;

    println!("\n== per-element statistics ==");
    for e in &report.elements {
        println!(
            "  {:22} in={:4} out={:4} busy_cpu={:9.3}ms busy_npu={:9.3}ms mean_lat={:7.3}ms",
            e.name,
            e.buffers_in(),
            e.buffers_out(),
            e.busy_cpu().as_secs_f64() * 1e3,
            e.busy_npu().as_secs_f64() * 1e3,
            e.latency().mean.as_secs_f64() * 1e3,
        );
    }
    println!(
        "\nwall={:.2}s  throughput={:.1} fps  app-cpu={:.1}%  peak-rss={:.1} MiB",
        report.wall.as_secs_f64(),
        report.fps("labels"),
        report.element_cpu_percent(),
        report.peak_rss_mib
    );

    // inspect a few classified labels from the pull-based collection
    if let Some((_, mut el)) = elements.into_iter().find(|(n, _)| n == "labels") {
        if let Some(sink) = el.as_any().and_then(|a| a.downcast_mut::<TensorSink>()) {
            println!("\nfirst labels (class, confidence):");
            for b in sink.buffers.iter().take(5) {
                let v = b.chunk().to_f32_vec()?;
                println!(
                    "  pts={:6.2}s  class={:3}  p={:.3}",
                    b.pts_ns as f64 / 1e9,
                    v[0],
                    v[1]
                );
            }
        }
    }
    Ok(())
}
