//! Quickstart: the Fig 1-style pipeline, end to end.
//!
//! Serves a live 30 fps camera stream (synthetic) through scaling,
//! conversion, normalization, an AOT-compiled Inception-style classifier
//! on the simulated NPU, and a label decoder — then prints per-stage
//! statistics, throughput and end-to-end latency.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::Pipeline;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let desc = "videotestsrc pattern=ball is-live=true framerate=30 num-buffers=90 ! \
                video/x-raw,format=RGB,width=640,height=480,framerate=30 ! \
                videoscale width=64 height=64 ! \
                tensor_converter ! \
                tensor_transform mode=typecast option=float32 ! \
                tensor_transform mode=arithmetic option=div:255 ! \
                tensor_filter framework=xla model=i3_opt accelerator=npu ! \
                tensor_decoder mode=image_labeling ! \
                tensor_sink name=labels";
    println!("pipeline:\n  {}\n", desc.replace(" ! ", " !\n  "));

    let mut pipeline = Pipeline::parse(desc)?;
    let report = pipeline.run()?;

    println!("== per-element statistics ==");
    for e in &report.elements {
        println!(
            "  {:22} in={:4} out={:4} busy_cpu={:9.3}ms busy_npu={:9.3}ms mean_lat={:7.3}ms",
            e.name,
            e.buffers_in(),
            e.buffers_out(),
            e.busy_cpu().as_secs_f64() * 1e3,
            e.busy_npu().as_secs_f64() * 1e3,
            e.latency().mean.as_secs_f64() * 1e3,
        );
    }
    println!(
        "\nwall={:.2}s  throughput={:.1} fps  app-cpu={:.1}%  peak-rss={:.1} MiB",
        report.wall.as_secs_f64(),
        report.fps("labels"),
        report.element_cpu_percent(),
        report.peak_rss_mib
    );

    // inspect a few classified labels
    if let Some(el) = pipeline.finished_element("labels") {
        if let Some(sink) = el.as_any().and_then(|a| a.downcast_mut::<TensorSink>()) {
            println!("\nfirst labels (class, confidence):");
            for b in sink.buffers.iter().take(5) {
                let v = b.chunk().to_f32_vec()?;
                println!(
                    "  pts={:6.2}s  class={:3}  p={:.3}",
                    b.pts_ns as f64 / 1e9,
                    v[0],
                    v[1]
                );
            }
        }
    }
    Ok(())
}
