//! Deterministic chaos harness for the fault-tolerant serving layer.
//!
//! Every test here injects faults through the seeded [`FaultPlan`]
//! machinery — panics, typed errors, stalls, and silent drops at exact
//! step indices — and asserts the error-flow contract end to end:
//! consumers always learn *why* a stream ended (clean EOS vs. typed
//! fault), `join` never reports clean success for a faulted run, the
//! supervisor restarts within its backoff budget, the watchdog kills
//! wedged pipelines, and the scheduler comes back reusable (no parked
//! tasks, no leaked threads) after an arbitrary fault sweep.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use nnstreamer::apps::e4::{self, E4Config};
use nnstreamer::pipeline::fault::splitmix64;
use nnstreamer::pipeline::{
    Executor, FaultKind, FaultPlan, Pipeline, PipelineHub, Priority, RestartPolicy, StreamEnd,
};
use nnstreamer::Error;

/// Thread count of this process, from /proc/self/status (Linux).
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn quick_e4() -> E4Config {
    E4Config {
        src_w: 160,
        src_h: 120,
        num_frames: 6,
    }
}

/// Satellite (a): a mid-stream fault must never masquerade as a clean
/// EOS. The app-side receiver drains the frames that made it through,
/// then gets the typed fault as the close reason, and `wait()` on the
/// running handle reports the panic — not success.
#[test]
fn appsink_reports_fault_not_clean_eos() {
    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=8 ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter name=conv ! appsink name=out",
    )
    .unwrap();
    p.set_fault_plan(FaultPlan::new().at("conv", 3, FaultKind::Panic));
    let rx = p.appsink("out").unwrap();
    let running = p.play().unwrap();

    let mut got = 0u64;
    let end = loop {
        match rx.recv() {
            Ok(_) => got += 1,
            Err(end) => break end,
        }
    };
    assert!(
        got < 8,
        "the fault fired mid-stream, yet all {got} frames arrived"
    );
    match &end {
        StreamEnd::Fault(f) => {
            assert_eq!(f.element, "conv");
            assert!(f.panicked, "panic injection must be flagged as a panic");
        }
        other => panic!("partial output ended with {other:?}, expected a typed fault"),
    }
    match running.wait() {
        Err(Error::Panicked { element, .. }) => assert_eq!(element, "conv"),
        Err(other) => panic!("expected Error::Panicked from join, got: {other}"),
        Ok(_) => panic!("join reported clean success for a faulted run"),
    }
}

/// Satellite (d): property sweep — inject a panic and a typed error into
/// *every* element position of the e4 chain at seeded step indices.
/// Each faulted run must join with a typed error (never clean success),
/// and afterwards the shared scheduler must still run a clean pipeline
/// to completion with the process thread count back at baseline (no
/// parked tasks pinning workers, no leaked threads).
#[test]
fn e4_chain_fault_at_every_position_yields_typed_error() {
    let cfg = quick_e4();
    let names: Vec<String> = e4::build_pipeline(&cfg, "opt")
        .unwrap()
        .graph
        .nodes
        .iter()
        .map(|n| n.name.clone())
        .collect();
    assert!(
        names.len() >= 8,
        "e4 chain should expose the full element set, got {names:?}"
    );

    // Warm the global pool and the model cache so the thread baseline
    // is stable before the sweep.
    e4::build_pipeline(&cfg, "opt").unwrap().run().unwrap();
    let baseline = process_threads();

    let mut seed = 0x9e37_79b9_7f4a_7c15u64;
    for (pos, name) in names.iter().enumerate() {
        for kind in [FaultKind::Panic, FaultKind::Error] {
            // Seeded, reproducible step index; every element sees at
            // least num_frames scheduling steps, so the fault always
            // has a chance to fire.
            let step = splitmix64(&mut seed) % (cfg.num_frames - 1);
            let mut p = e4::build_pipeline(&cfg, "opt").unwrap();
            p.set_fault_plan(FaultPlan::new().at(name.clone(), step, kind));
            let err = p.run().err().unwrap_or_else(|| {
                panic!("position {pos} ({name}) step {step} {kind:?}: faulted run joined cleanly")
            });
            match err {
                Error::Panicked { .. } | Error::Element { .. } | Error::Fault(_) => {}
                other => panic!("position {pos} ({name}): untyped join error: {other}"),
            }
        }
    }

    // The scheduler is not wedged: a clean run still completes...
    let report = e4::build_pipeline(&cfg, "opt").unwrap().run().unwrap();
    assert_eq!(
        report.element("out").unwrap().buffers_in(),
        cfg.num_frames,
        "clean run after the sweep must deliver every frame"
    );
    // ...and the sweep leaked no thread per faulted run (the small
    // slack absorbs hubs other tests in this binary spin up
    // concurrently, never the ~16 threads a per-run leak would add).
    if let (Some(before), Some(after)) = (baseline, process_threads()) {
        let added = after.saturating_sub(before);
        assert!(
            added <= 8,
            "thread count grew across the fault sweep: {before} -> {after}"
        );
    }
}

/// A dropped buffer is flow degradation, not a fault: the run completes
/// cleanly, just with fewer frames at the sink.
#[test]
fn injected_drop_shrinks_output_without_faulting() {
    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=6 ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter name=conv ! fakesink name=out",
    )
    .unwrap();
    p.set_fault_plan(FaultPlan::new().at("conv", 1, FaultKind::Drop));
    let report = p.run().unwrap();
    assert_eq!(
        report.element("out").unwrap().buffers_in(),
        5,
        "exactly the dropped frame is missing"
    );
}

/// Fault propagation crosses pipeline boundaries: a subscriber in
/// another pipeline (or plain app code) sees the frames that made it
/// through, then a typed fault close-reason — not a silent EOS.
#[test]
fn topic_subscriber_sees_fault_from_publishing_pipeline() {
    let hub = PipelineHub::with_workers(2);
    let sub = hub.subscribe("chaos/feed");
    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=32 ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter name=conv ! tensor_query_serversink topic=chaos/feed",
    )
    .unwrap();
    p.set_fault_plan(FaultPlan::new().at("conv", 2, FaultKind::Panic));
    hub.launch("svc", p).unwrap();

    let mut got = 0u64;
    while sub.recv().is_ok() {
        got += 1;
    }
    assert!(got <= 2, "at most the pre-fault frames arrived, got {got}");
    match sub.close_reason() {
        Some(StreamEnd::Fault(f)) => {
            assert_eq!(f.element, "conv");
            assert!(f.panicked);
        }
        other => panic!("expected a cross-pipeline fault close-reason, got {other:?}"),
    }
    let join = hub.join_all().pop().expect("one launched pipeline");
    assert!(join.report.is_err(), "publisher pipeline joined cleanly");
}

/// Tentpole: a supervised pipeline that faults twice restarts under its
/// deterministic backoff schedule and completes on the third attempt;
/// the report carries the restart and fault counters.
#[test]
fn supervised_pipeline_restarts_within_backoff_budget() {
    let hub = PipelineHub::with_workers(2);
    let attempts = Arc::new(AtomicUsize::new(0));
    let seen = attempts.clone();
    let t0 = Instant::now();
    hub.launch_supervised(
        "svc",
        move || {
            let mut p = Pipeline::parse(
                "videotestsrc num-buffers=16 ! \
                 video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
                 tensor_converter name=conv ! fakesink name=out",
            )?;
            if seen.fetch_add(1, Ordering::SeqCst) < 2 {
                p.set_fault_plan(FaultPlan::new().at("conv", 4, FaultKind::Panic));
            }
            Ok(p)
        },
        RestartPolicy::OnFault {
            max_restarts: 3,
            backoff: Duration::from_millis(5),
        },
    )
    .unwrap();

    let join = hub.join_supervised("svc").unwrap();
    let report = join.report.expect("third attempt completes cleanly");
    assert_eq!(report.restarts, 2);
    assert_eq!(report.faults, 2);
    assert_eq!(report.element("out").unwrap().buffers_in(), 16);
    assert_eq!(attempts.load(Ordering::SeqCst), 3);
    // Exponential backoff: restart 1 waited 5ms, restart 2 waited 10ms.
    assert!(
        t0.elapsed() >= Duration::from_millis(15),
        "restarts ran ahead of the deterministic backoff schedule"
    );
}

/// Exhausting the restart budget quarantines the pipeline with a typed
/// terminal error instead of restarting forever.
#[test]
fn restart_budget_exhaustion_quarantines() {
    let hub = PipelineHub::with_workers(2);
    hub.launch_supervised(
        "doomed",
        || {
            let mut p = Pipeline::parse("videotestsrc num-buffers=8 ! fakesink")?;
            p.set_fault_plan(FaultPlan::new().at("videotestsrc0", 0, FaultKind::Error));
            Ok(p)
        },
        RestartPolicy::OnFault {
            max_restarts: 1,
            backoff: Duration::from_millis(1),
        },
    )
    .unwrap();
    match hub.join_supervised("doomed").unwrap().report {
        Err(Error::Quarantined {
            pipeline, restarts, ..
        }) => {
            assert_eq!(pipeline, "doomed");
            assert_eq!(restarts, 1);
        }
        Err(other) => panic!("expected Error::Quarantined, got: {other}"),
        Ok(_) => panic!("always-faulting pipeline joined cleanly"),
    }
}

/// Tentpole: the stall watchdog kills a pipeline that is runnable but
/// making no progress, reporting `Error::Stalled` — even on a single
/// shared worker where the stall would otherwise also starve neighbors.
#[test]
fn watchdog_kills_stalled_pipeline_on_single_worker() {
    let hub = PipelineHub::with_workers(1);
    hub.set_watchdog(Duration::from_millis(40));
    let mut p = Pipeline::parse("videotestsrc num-buffers=32 ! fakesink").unwrap();
    p.set_fault_plan(FaultPlan::new().at("videotestsrc0", 1, FaultKind::StallMs(400)));
    hub.launch("wedge", p).unwrap();
    let join = hub.join_all().pop().expect("one launched pipeline");
    match join.report {
        Err(Error::Stalled { pipeline, .. }) => assert_eq!(pipeline, "wedge"),
        Err(other) => panic!("expected Error::Stalled, got: {other}"),
        Ok(_) => panic!("stalled pipeline joined cleanly"),
    }
}

/// Device-lane chaos: an upstream fault lands while the filter is
/// parked on an in-flight NPU job (async dispatch, multi-ms service
/// window). The join error stays typed, the orphaned completion is not
/// leaked — the NPU's in-flight gauge drains back to zero once the
/// service window elapses — and the teardown leaks no threads.
#[test]
fn fault_while_parked_on_device_job() {
    use nnstreamer::devices::NpuSim;

    let npu = NpuSim::global();
    // Long enough that the filter is certainly parked on the device
    // when the upstream panic fires. i3_opt is not used by any other
    // test in this binary, so the override races nothing.
    npu.set_service_override("i3_opt", Duration::from_millis(60));

    let hub = PipelineHub::with_workers(2);
    let baseline = process_threads();
    let mut p = Pipeline::parse(
        "videotestsrc pattern=gradient num-buffers=8 ! \
         video/x-raw,format=RGB,width=64,height=64,framerate=600 ! \
         tensor_converter name=conv ! tensor_transform mode=normalize ! \
         tensor_filter framework=xla model=i3_opt accelerator=npu ! fakesink",
    )
    .unwrap();
    // Frames 0..3 pass the converter and pile into the slow filter;
    // the panic at step 4 fires while a device job is in flight.
    p.set_fault_plan(FaultPlan::new().at("conv", 4, FaultKind::Panic));
    hub.launch("devlane", p).unwrap();

    let join = hub.join_all().pop().expect("one launched pipeline");
    match join.report {
        Err(Error::Panicked { element, .. }) => assert_eq!(element, "conv"),
        Err(other) => panic!("expected Error::Panicked, got: {other}"),
        Ok(_) => panic!("faulted run joined cleanly"),
    }

    // The abandoned job still completes inside the NPU service thread;
    // nothing may leak the in-flight slot. Poll past the service window.
    let deadline = Instant::now() + Duration::from_secs(2);
    while npu.stats.in_flight() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        npu.stats.in_flight(),
        0,
        "device job leaked after fault-while-parked teardown"
    );
    npu.clear_service_overrides();

    if let (Some(before), Some(after)) = (baseline, process_threads()) {
        assert!(
            after.saturating_sub(before) <= 4,
            "threads grew across device-lane fault: {before} -> {after}"
        );
    }
}

/// Satellite (c): the single-worker floor of the worker-count envelope
/// (`NNS_WORKERS=1`) runs a full chain end-to-end, and fault
/// propagation behaves identically with no spare worker to lean on.
#[test]
fn single_worker_envelope_runs_and_propagates_faults() {
    let exec = Executor::new(1);
    assert_eq!(exec.worker_count(), 1);

    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=8 ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter ! fakesink name=out",
    )
    .unwrap();
    let report = p.run_on(&exec, Priority::Normal).unwrap();
    assert_eq!(report.element("out").unwrap().buffers_in(), 8);
    assert_eq!(report.sched.workers, 1);

    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=8 ! tensor_converter name=conv ! fakesink",
    )
    .unwrap();
    p.set_fault_plan(FaultPlan::new().at("conv", 1, FaultKind::Panic));
    match p.run_on(&exec, Priority::Normal) {
        Err(Error::Panicked { element, .. }) => assert_eq!(element, "conv"),
        Err(other) => panic!("expected Error::Panicked, got: {other}"),
        Ok(_) => panic!("faulted run joined cleanly on one worker"),
    }

    // The worker survived the panic: a clean run still completes.
    let mut p = Pipeline::parse("videotestsrc num-buffers=4 ! fakesink name=out").unwrap();
    let report = p.run_on(&exec, Priority::Normal).unwrap();
    assert_eq!(report.element("out").unwrap().buffers_in(), 4);
    exec.shutdown();
}
