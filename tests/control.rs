//! Live-control integration tests: the paper's Pipeline-API surface —
//! `appsrc` push, `tensor_sink` callback subscription, and runtime
//! control (valves, selectors, `set_property`) on a playing pipeline.
//!
//! Determinism: control messages are applied by an element's own thread
//! strictly before the next item it processes, so a control message sent
//! before a buffer enters the pipeline is guaranteed to be in effect when
//! that buffer reaches the element. The tests synchronize on observable
//! effects (sink callbacks, drop counters) between steps.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use nnstreamer::elements::filter::{Framework, TensorFilterProps};
use nnstreamer::elements::flow::{InputSelectorProps, OutputSelectorProps, ValveProps};
use nnstreamer::elements::sinks::TensorSinkProps;
use nnstreamer::elements::sources::AppSrcProps;
use nnstreamer::elements::tensor_if::TensorIfProps;
use nnstreamer::elements::transform::{ArithOp, TensorTransformProps};
use nnstreamer::pipeline::{Executor, PipelineBuilder, Priority, Running};
use nnstreamer::tensor::{Buffer, Caps, DType};

/// Spin until `cond` holds (5 s timeout).
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Collects callback payloads as (sink_tag, f32 payload).
type Log = Arc<Mutex<Vec<(usize, Vec<f32>)>>>;

fn subscribe_into(running: &Running, name: &str, tag: usize, log: &Log) {
    let log = log.clone();
    running
        .subscribe(name, move |buf: &Buffer| {
            let vals = buf.chunk().to_f32_vec().expect("f32 payload");
            log.lock().unwrap().push((tag, vals));
        })
        .unwrap();
}

fn dropped(running: &Running, name: &str) -> u64 {
    running.element_stats(name).expect("element exists").dropped()
}

/// The acceptance-criteria pipeline: appsrc push -> tensor_filter ->
/// tensor_sink callback, with a valve and an output-selector steered
/// mid-stream.
#[test]
fn appsrc_filter_valve_selector_end_to_end() {
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [4], 0.0),
        },
    )
    .unwrap()
    .chain_named(
        "f",
        TensorFilterProps {
            framework: Framework::Passthrough,
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named("v", ValveProps::default())
    .unwrap()
    .chain_named("os", OutputSelectorProps::default())
    .unwrap()
    .chain_named("out0", TensorSinkProps::default())
    .unwrap();
    b.from("os")
        .unwrap()
        .chain_named("out1", TensorSinkProps::default())
        .unwrap();

    let mut pipeline = b.build();
    let push = pipeline.appsrc("in").unwrap();
    let running = pipeline.play().unwrap();

    let log: Log = Arc::new(Mutex::new(Vec::new()));
    subscribe_into(&running, "out0", 0, &log);
    subscribe_into(&running, "out1", 1, &log);

    let frame = |v: f32| Buffer::from_f32(0, &[v, v + 1.0, v + 2.0, v + 3.0]);

    // 1. default state: valve open, selector pad 0
    push.push(frame(1.0)).unwrap();
    wait_until("frame 1 at out0", || log.lock().unwrap().len() == 1);

    // 2. switch the selector to pad 1 before the next frame enters
    running.select_output("os", 1).unwrap();
    push.push(frame(2.0)).unwrap();
    wait_until("frame 2 at out1", || log.lock().unwrap().len() == 2);

    // 3. close the valve: the next frame is dropped (observable only
    //    through the valve's drop counter)
    running.set_valve("v", false).unwrap();
    push.push(frame(3.0)).unwrap();
    wait_until("valve drop", || dropped(&running, "v") == 1);

    // 4. reopen: traffic resumes on the still-selected pad 1
    running.set_valve("v", true).unwrap();
    push.push(frame(4.0)).unwrap();
    wait_until("frame 4 at out1", || log.lock().unwrap().len() == 3);

    push.end();
    running.wait().unwrap();

    let got = log.lock().unwrap();
    assert_eq!(
        *got,
        vec![
            (0, vec![1.0, 2.0, 3.0, 4.0]),
            (1, vec![2.0, 3.0, 4.0, 5.0]),
            (1, vec![4.0, 5.0, 6.0, 7.0]),
        ],
        "buffers must arrive bit-identically on the steered pads"
    );
}

/// Valve open/close mid-stream drops and passes frames deterministically.
#[test]
fn valve_toggling_is_deterministic() {
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [1], 0.0),
        },
    )
    .unwrap()
    .chain_named("v", ValveProps::default())
    .unwrap()
    .chain_named("out", TensorSinkProps::default())
    .unwrap();

    let mut pipeline = b.build();
    let push = pipeline.appsrc("in").unwrap();
    let running = pipeline.play().unwrap();
    let log: Log = Arc::new(Mutex::new(Vec::new()));
    subscribe_into(&running, "out", 0, &log);

    let mut expect_drops = 0u64;
    let mut expect_passes = 0usize;
    for i in 0..10u32 {
        let open = i % 3 != 2; // frames 2, 5, 8 hit a closed valve
        running.set_valve("v", open).unwrap();
        push.push(Buffer::from_f32(0, &[i as f32])).unwrap();
        if open {
            expect_passes += 1;
            wait_until("pass", || log.lock().unwrap().len() == expect_passes);
        } else {
            expect_drops += 1;
            wait_until("drop", || dropped(&running, "v") == expect_drops);
        }
    }
    push.end();
    running.wait().unwrap();

    let got: Vec<f32> = log.lock().unwrap().iter().map(|(_, v)| v[0]).collect();
    assert_eq!(got, vec![0.0, 1.0, 3.0, 4.0, 6.0, 7.0, 9.0]);
}

/// The callback path sees byte-for-byte what the pull-based collection
/// records — within one run and across two runs of the same pipeline.
#[test]
fn tensor_sink_callback_bit_identical_to_pull_based_path() {
    let frames: Vec<Vec<f32>> = (0..6)
        .map(|f| (0..8).map(|i| (f * 8 + i) as f32 / 7.0).collect())
        .collect();

    let run = |subscribe: bool| -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut b = PipelineBuilder::new();
        b.chain_named(
            "in",
            AppSrcProps {
                caps: Caps::tensor(DType::F32, [8], 0.0),
            },
        )
        .unwrap()
        .chain(TensorTransformProps::arithmetic(vec![
            (ArithOp::Mul, 3.0),
            (ArithOp::Add, -1.0),
        ]))
        .unwrap()
        .chain_named("out", TensorSinkProps::default())
        .unwrap();

        let mut pipeline = b.build();
        let push = pipeline.appsrc("in").unwrap();
        let running = pipeline.play().unwrap();
        let log: Log = Arc::new(Mutex::new(Vec::new()));
        if subscribe {
            subscribe_into(&running, "out", 0, &log);
        }
        for f in &frames {
            push.push(Buffer::from_f32(0, f)).unwrap();
        }
        push.end();
        let (_, elements) = running.wait().unwrap();
        let collected = elements
            .into_iter()
            .find(|(n, _)| n == "out")
            .map(|(_, mut el)| {
                let sink = el
                    .as_any()
                    .and_then(|a| {
                        a.downcast_mut::<nnstreamer::elements::sinks::TensorSink>()
                    })
                    .unwrap();
                sink.buffers
                    .iter()
                    .map(|b| b.chunk().to_f32_vec().unwrap())
                    .collect::<Vec<_>>()
            })
            .unwrap();
        let callback = log.lock().unwrap().iter().map(|(_, v)| v.clone()).collect();
        (callback, collected)
    };

    let (cb, pull_same_run) = run(true);
    let (_, pull_other_run) = run(false);
    assert_eq!(cb, pull_same_run, "callback vs same-run collection");
    assert_eq!(cb, pull_other_run, "callback vs independent pull-based run");
    assert_eq!(cb.len(), frames.len());
}

/// `input-selector` switching on a playing pipeline.
#[test]
fn input_selector_switches_live() {
    let caps = Caps::tensor(DType::F32, [2], 0.0);
    let mut b = PipelineBuilder::new();
    b.chain_named("src_a", AppSrcProps { caps: caps.clone() })
        .unwrap()
        .chain_named("sel", InputSelectorProps::default())
        .unwrap()
        .chain_named("out", TensorSinkProps::default())
        .unwrap();
    b.chain_named("src_b", AppSrcProps { caps }).unwrap().to("sel").unwrap();

    let mut pipeline = b.build();
    let push_a = pipeline.appsrc("src_a").unwrap();
    let push_b = pipeline.appsrc("src_b").unwrap();
    let running = pipeline.play().unwrap();
    let log: Log = Arc::new(Mutex::new(Vec::new()));
    subscribe_into(&running, "out", 0, &log);

    push_a.push(Buffer::from_f32(0, &[1.0, 1.0])).unwrap();
    wait_until("frame from a", || log.lock().unwrap().len() == 1);

    running.select_input("sel", 1).unwrap();
    push_b.push(Buffer::from_f32(0, &[2.0, 2.0])).unwrap();
    wait_until("frame from b", || log.lock().unwrap().len() == 2);

    // pad 0 is now inactive: its frames are dropped
    push_a.push(Buffer::from_f32(0, &[3.0, 3.0])).unwrap();
    wait_until("drop on inactive pad", || dropped(&running, "sel") == 1);

    push_a.end();
    push_b.end();
    running.wait().unwrap();

    let got: Vec<f32> = log.lock().unwrap().iter().map(|(_, v)| v[0]).collect();
    assert_eq!(got, vec![1.0, 2.0]);
}

/// Runtime `set_property` on a named element of a playing pipeline:
/// retune a `tensor_if` threshold mid-stream.
#[test]
fn set_property_retunes_tensor_if_live() {
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [4], 0.0),
        },
    )
    .unwrap()
    .chain_named(
        "gate",
        TensorIfProps {
            threshold: 0.5,
            ..Default::default() // average > threshold passes
        },
    )
    .unwrap()
    .chain_named("out", TensorSinkProps::default())
    .unwrap();

    let mut pipeline = b.build();
    let push = pipeline.appsrc("in").unwrap();
    let running = pipeline.play().unwrap();
    let log: Log = Arc::new(Mutex::new(Vec::new()));
    subscribe_into(&running, "out", 0, &log);

    // avg 0.1 < 0.5: gated off
    push.push(Buffer::from_f32(0, &[0.1; 4])).unwrap();
    wait_until("gated frame dropped", || dropped(&running, "gate") == 1);

    // lower the threshold live; the same payload now passes
    running.set_property("gate", "threshold", "0.0").unwrap();
    push.push(Buffer::from_f32(0, &[0.1; 4])).unwrap();
    wait_until("frame passes", || log.lock().unwrap().len() == 1);

    push.end();
    running.wait().unwrap();
}

/// The valve + output-selector scenario re-run on explicitly sized
/// pooled executors: 1 worker serializes every element step, 8 workers
/// maximize interleaving — control stays deterministic with respect to
/// the data stream in both, and the steered outputs are bit-identical.
#[test]
fn valve_selector_deterministic_on_1_and_8_workers() {
    let run_with_workers = |workers: usize| -> Vec<(usize, Vec<f32>)> {
        let exec = Executor::new(workers);
        let mut b = PipelineBuilder::new();
        b.chain_named(
            "in",
            AppSrcProps {
                caps: Caps::tensor(DType::F32, [2], 0.0),
            },
        )
        .unwrap()
        .chain_named("v", ValveProps::default())
        .unwrap()
        .chain_named("os", OutputSelectorProps::default())
        .unwrap()
        .chain_named("out0", TensorSinkProps::default())
        .unwrap();
        b.from("os")
            .unwrap()
            .chain_named("out1", TensorSinkProps::default())
            .unwrap();

        let mut pipeline = b.build();
        let push = pipeline.appsrc("in").unwrap();
        let running = pipeline.play_on(&exec, Priority::Normal).unwrap();
        let log: Log = Arc::new(Mutex::new(Vec::new()));
        subscribe_into(&running, "out0", 0, &log);
        subscribe_into(&running, "out1", 1, &log);

        let mut expect_seen = 0usize;
        let mut expect_drops = 0u64;
        for i in 0..9u32 {
            // rotate: pad 0, pad 1, closed valve
            match i % 3 {
                0 => running.select_output("os", 0).unwrap(),
                1 => running.select_output("os", 1).unwrap(),
                _ => running.set_valve("v", false).unwrap(),
            }
            push.push(Buffer::from_f32(0, &[i as f32, -(i as f32)])).unwrap();
            if i % 3 == 2 {
                expect_drops += 1;
                wait_until("valve drop", || dropped(&running, "v") == expect_drops);
                running.set_valve("v", true).unwrap();
            } else {
                expect_seen += 1;
                wait_until("frame delivered", || {
                    log.lock().unwrap().len() == expect_seen
                });
            }
        }
        push.end();
        running.wait().unwrap();
        exec.shutdown();
        let got = log.lock().unwrap().clone();
        drop(push);
        got
    };

    let w1 = run_with_workers(1);
    let w8 = run_with_workers(8);
    assert_eq!(
        w1,
        vec![
            (0, vec![0.0, -0.0]),
            (1, vec![1.0, -1.0]),
            (0, vec![3.0, -3.0]),
            (1, vec![4.0, -4.0]),
            (0, vec![6.0, -6.0]),
            (1, vec![7.0, -7.0]),
        ],
        "steered output on a serialized (1-worker) pool"
    );
    assert_eq!(w1, w8, "1-worker and 8-worker runs must agree bitwise");
}

/// A full control mailbox on a starved element surfaces as the typed
/// `ControlBackpressure` error instead of blocking the application
/// thread forever (the seed's `SyncSender::send` would deadlock here).
#[test]
fn control_backpressure_is_typed_not_blocking() {
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [1], 0.0),
        },
    )
    .unwrap()
    .chain_named("v", ValveProps::default())
    .unwrap()
    .chain_named("out", TensorSinkProps::default())
    .unwrap();

    let mut pipeline = b.build();
    let push = pipeline.appsrc("in").unwrap();
    let running = pipeline.play().unwrap();

    // no data flows, so the valve's task parks on input and never
    // drains its mailbox: keep sending until the bound is hit — the
    // send must return quickly with the typed error, never block
    let mut hit = None;
    for i in 0..200 {
        match running.set_valve("v", i % 2 == 0) {
            Ok(()) => {}
            Err(e) => {
                hit = Some(e);
                break;
            }
        }
    }
    let err = hit.expect("mailbox bound must be reached within 200 sends");
    assert!(
        matches!(
            err,
            nnstreamer::Error::ControlBackpressure { ref element, .. } if element.as_str() == "v"
        ),
        "expected typed backpressure error, got: {err}"
    );
    assert!(err.to_string().contains("control backpressure"), "{err}");

    // the pipeline is still healthy: EOS drains the mailbox and joins
    push.end();
    running.wait().unwrap();
}

/// Control-surface error paths: unknown element names fail fast with a
/// suggestion; subscribing to a non-subscribable element surfaces as the
/// pipeline's failure.
#[test]
fn control_error_paths() {
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [1], 0.0),
        },
    )
    .unwrap()
    .chain_named("v", ValveProps::default())
    .unwrap()
    .chain_named(
        "sink",
        nnstreamer::elements::sinks::FakeSinkProps::default(),
    )
    .unwrap();

    let mut pipeline = b.build();
    let push = pipeline.appsrc("in").unwrap();
    let running = pipeline.play().unwrap();

    // unknown element: immediate error with a nearest-name suggestion
    let err = running.set_valve("w", false).unwrap_err().to_string();
    assert!(err.contains("no element named"), "{err}");
    assert!(err.contains("did you mean \"v\"?"), "{err}");

    // fakesink does not support subscription: the error surfaces from
    // the sink's thread when the pipeline is joined
    running.subscribe("sink", |_buf| {}).unwrap();
    push.push(Buffer::from_f32(0, &[1.0])).unwrap();
    push.end();
    let err = running.wait().unwrap_err().to_string();
    assert!(err.contains("does not support buffer subscription"), "{err}");
}
