//! End-to-end tests of the TCP tensor-query transport: the wire really
//! crosses an OS-process boundary here.
//!
//! Publisher halves run as **child processes** by re-invoking this test
//! binary with `--exact <child_test>` and an environment variable
//! carrying the discovery-registry address; the child test functions
//! no-op when that variable is absent, so a plain `cargo test` run
//! treats them as trivially passing.

use std::time::{Duration, Instant};

use nnstreamer::net::{register_tcp_as, NetRegistry, TcpConfig, TcpTransport};
use nnstreamer::pipeline::stream::{PortRecv, PortSend, PublisherPort, SubscriberPort};
use nnstreamer::pipeline::{Pipeline, PipelineBuilder, PipelineHub, Qos, StreamEnd, Transport};
use nnstreamer::tensor::{Buffer, Caps, DType};

const EOS_ENV: &str = "NNS_TEST_EOS_REGISTRY";
const KILL_ENV: &str = "NNS_TEST_KILL_REGISTRY";
const GEN1_ENV: &str = "NNS_TEST_GEN1_REGISTRY";
const GEN2_ENV: &str = "NNS_TEST_GEN2_REGISTRY";

fn frame_caps() -> Caps {
    Caps::tensor(DType::F32, [3], 0.0)
}

/// Deterministic frame `i`: both sides of a wire test regenerate it to
/// check bit-identity.
fn frame(i: u64) -> Buffer {
    Buffer::from_f32(i, &[i as f32, (i * 2) as f32, 0.5])
}

fn payload(b: &Buffer) -> Vec<u8> {
    b.chunk().as_bytes_unaccounted().to_vec()
}

fn wait_for(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let end = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < end, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Blocking-send one buffer through a publisher port, parking politely
/// on `Full`/`NoSubscribers`.
fn send(port: &mut dyn PublisherPort, mut buf: Buffer) {
    let end = Instant::now() + Duration::from_secs(30);
    loop {
        match port.try_send(buf) {
            PortSend::Sent => return,
            PortSend::Full(b) | PortSend::NoSubscribers(b) => buf = b,
            PortSend::Closed(_) => panic!("stream closed under the publisher"),
        }
        assert!(Instant::now() < end, "publisher wedged");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Pop exactly `n` items (EOS before that is a failure).
fn drain_n(port: &mut dyn SubscriberPort, n: usize) -> Vec<Buffer> {
    let end = Instant::now() + Duration::from_secs(30);
    let mut out = Vec::new();
    while out.len() < n {
        match port.try_recv() {
            PortRecv::Item(b) => out.push(b),
            PortRecv::Empty => {
                assert!(Instant::now() < end, "timed out draining {n} frames");
                std::thread::sleep(Duration::from_millis(2));
            }
            PortRecv::End => panic!("stream ended after {} of {n} frames", out.len()),
        }
    }
    out
}

/// Pop until the stream ends; returns the items and the close reason.
fn drain_until_end(port: &mut dyn SubscriberPort) -> (Vec<Buffer>, Option<StreamEnd>) {
    let end = Instant::now() + Duration::from_secs(30);
    let mut out = Vec::new();
    loop {
        match port.try_recv() {
            PortRecv::Item(b) => out.push(b),
            PortRecv::Empty => {
                assert!(Instant::now() < end, "timed out waiting for stream end");
                std::thread::sleep(Duration::from_millis(2));
            }
            PortRecv::End => return (out, port.close_reason()),
        }
    }
}

/// Re-invoke this test binary as a publisher child process.
fn spawn_child(child_test: &str, env_key: &str, registry: &str) -> std::process::Child {
    std::process::Command::new(std::env::current_exe().expect("test binary path"))
        .args([child_test, "--exact", "--nocapture"])
        .env(env_key, registry)
        .spawn()
        .expect("spawn child test process")
}

/// Serve-side delivered count of `topic` on `t` (frames handed to the
/// wire writer) — the child's cue that its frames left the queue.
fn served_delivered(t: &TcpTransport, topic: &str) -> u64 {
    let name = format!("tcp-pub:{topic}");
    t.snapshot()
        .iter()
        .find(|s| s.name == name)
        .map(|s| s.delivered)
        .unwrap_or(0)
}

// -- EOS crosses the wire bit-identically -----------------------------------

#[test]
fn child_eos_publisher() {
    let Ok(registry) = std::env::var(EOS_ENV) else {
        return;
    };
    let t = TcpTransport::new(TcpConfig::new(registry));
    let mut port = t.advertise("net/eos", Qos::Blocking).unwrap();
    port.advertise(&frame_caps());
    wait_for("a subscriber", Duration::from_secs(10), || {
        port.subscriber_count() >= 1
    });
    for i in 0..5 {
        send(port.as_mut(), frame(i));
    }
    port.finish();
    assert!(
        t.quiesce(Duration::from_secs(10)),
        "final EOS frame flushed before exit"
    );
}

#[test]
fn eos_across_wire() {
    let registry = NetRegistry::serve("127.0.0.1:0").unwrap();
    let addr = registry.addr().to_string();
    let t = TcpTransport::new(TcpConfig::new(&addr));
    let mut sub = t.attach("net/eos", 8, Qos::Blocking).unwrap();
    let mut child = spawn_child("child_eos_publisher", EOS_ENV, &addr);

    let (got, reason) = drain_until_end(sub.as_mut());
    assert!(child.wait().unwrap().success(), "publisher process failed");

    assert_eq!(got.len(), 5, "every frame crossed the wire before EOS");
    for (i, b) in got.iter().enumerate() {
        let want = frame(i as u64);
        assert_eq!(b.pts_ns, want.pts_ns, "pts preserved");
        assert_eq!(payload(b), payload(&want), "payload bit-identical");
    }
    assert_eq!(
        sub.topic_caps().map(|c| c.to_string()),
        Some(frame_caps().to_string()),
        "caps announced across the wire"
    );
    assert!(
        matches!(reason, Some(StreamEnd::Eos)),
        "clean EOS, got {reason:?}"
    );
}

// -- a publisher process dying mid-stream is a typed fault ------------------

#[test]
fn child_abrupt_publisher() {
    let Ok(registry) = std::env::var(KILL_ENV) else {
        return;
    };
    let t = TcpTransport::new(TcpConfig::new(registry));
    let mut port = t.advertise("net/kill", Qos::Blocking).unwrap();
    port.advertise(&frame_caps());
    wait_for("a subscriber", Duration::from_secs(10), || {
        port.subscriber_count() >= 1
    });
    for i in 0..3 {
        send(port.as_mut(), frame(i));
    }
    wait_for("frames on the wire", Duration::from_secs(10), || {
        served_delivered(&t, "net/kill") >= 3
    });
    // flush margin, then die without finish(): no EOS ever sent
    std::thread::sleep(Duration::from_millis(300));
    std::process::exit(0);
}

#[test]
fn killed_publisher_surfaces_as_fault() {
    let registry = NetRegistry::serve("127.0.0.1:0").unwrap();
    let addr = registry.addr().to_string();
    let mut cfg = TcpConfig::new(&addr);
    cfg.reconnect_attempts = 2;
    cfg.reconnect_backoff = Duration::from_millis(50);
    let t = TcpTransport::new(cfg);
    let mut sub = t.attach("net/kill", 8, Qos::Blocking).unwrap();
    let mut child = spawn_child("child_abrupt_publisher", KILL_ENV, &addr);

    let (got, reason) = drain_until_end(sub.as_mut());
    let _ = child.wait();

    assert_eq!(got.len(), 3, "frames sent before the crash were delivered");
    match reason {
        Some(StreamEnd::Fault(f)) => {
            assert_eq!(f.element, "tcp:net/kill");
            assert!(
                f.message.contains("reconnect"),
                "fault names exhausted reconnects: {}",
                f.message
            );
        }
        other => panic!("abrupt publisher death must be a fault, got {other:?}"),
    }
}

// -- reconnect bridges a publisher restart ----------------------------------

#[test]
fn child_gen1_publisher() {
    let Ok(registry) = std::env::var(GEN1_ENV) else {
        return;
    };
    let t = TcpTransport::new(TcpConfig::new(registry));
    let mut port = t.advertise("net/reconnect", Qos::Blocking).unwrap();
    port.advertise(&frame_caps());
    wait_for("a subscriber", Duration::from_secs(10), || {
        port.subscriber_count() >= 1
    });
    for i in 0..3 {
        send(port.as_mut(), frame(i));
    }
    wait_for("frames on the wire", Duration::from_secs(10), || {
        served_delivered(&t, "net/reconnect") >= 3
    });
    std::thread::sleep(Duration::from_millis(300));
    std::process::exit(0); // die mid-stream; gen2 takes over
}

#[test]
fn child_gen2_publisher() {
    let Ok(registry) = std::env::var(GEN2_ENV) else {
        return;
    };
    let t = TcpTransport::new(TcpConfig::new(registry));
    let mut port = t.advertise("net/reconnect", Qos::Blocking).unwrap();
    port.advertise(&frame_caps());
    wait_for("a subscriber", Duration::from_secs(10), || {
        port.subscriber_count() >= 1
    });
    for i in 3..6 {
        send(port.as_mut(), frame(i));
    }
    port.finish();
    assert!(t.quiesce(Duration::from_secs(10)), "EOS flushed before exit");
}

#[test]
fn reconnect_resumes_from_a_restarted_publisher() {
    let registry = NetRegistry::serve("127.0.0.1:0").unwrap();
    let addr = registry.addr().to_string();
    let mut cfg = TcpConfig::new(&addr);
    // generous budget: must outlive the gen2 process startup
    cfg.reconnect_attempts = 400;
    cfg.reconnect_backoff = Duration::from_millis(25);
    let t = TcpTransport::new(cfg);
    let mut sub = t.attach("net/reconnect", 8, Qos::Blocking).unwrap();

    let mut gen1 = spawn_child("child_gen1_publisher", GEN1_ENV, &addr);
    let first = drain_n(sub.as_mut(), 3);
    let _ = gen1.wait();

    // a restarted publisher registers a new port under the same topic;
    // the subscription re-resolves and resumes
    let mut gen2 = spawn_child("child_gen2_publisher", GEN2_ENV, &addr);
    let (rest, reason) = drain_until_end(sub.as_mut());
    assert!(gen2.wait().unwrap().success(), "gen2 publisher failed");

    let pts: Vec<u64> = first.iter().chain(rest.iter()).map(|b| b.pts_ns).collect();
    assert_eq!(pts, vec![0, 1, 2, 3, 4, 5], "both generations, in order");
    assert!(
        matches!(reason, Some(StreamEnd::Eos)),
        "gen2's clean EOS ends the stream, got {reason:?}"
    );
}

// -- credit flow control bounds subscriber memory ---------------------------

#[test]
fn credit_backpressure_bounds_subscriber_memory() {
    const CAP: usize = 4;
    const TOTAL: u64 = 30;
    let registry = NetRegistry::serve("127.0.0.1:0").unwrap();
    let t = TcpTransport::new(TcpConfig::new(registry.addr().to_string()));
    // loopback: serve and subscribe on the same transport instance —
    // the frames still cross real sockets
    let mut publ = t.advertise("net/credit", Qos::Blocking).unwrap();
    publ.advertise(&frame_caps());
    let mut sub = t.attach("net/credit", CAP, Qos::Blocking).unwrap();
    wait_for("the subscriber connection", Duration::from_secs(10), || {
        publ.subscriber_count() >= 1
    });

    // without pops the window closes: at most CAP frames on the remote
    // queue + 1 held by the writer + CAP on the serve queue may be
    // accepted before the publisher observes Full
    let mut pending: Option<Buffer> = None;
    let mut sent = 0u64;
    let mut saw_full_at = None;
    let mut got = Vec::new();
    let end = Instant::now() + Duration::from_secs(30);
    while sent < TOTAL || pending.is_some() {
        assert!(Instant::now() < end, "saturation loop wedged");
        let buf = pending.take().unwrap_or_else(|| frame(sent));
        match publ.try_send(buf) {
            PortSend::Sent => sent += 1,
            PortSend::Full(b) => {
                if saw_full_at.is_none() {
                    saw_full_at = Some(sent);
                }
                let in_flight = t
                    .snapshot()
                    .iter()
                    .find(|s| s.name == "tcp-sub:net/credit")
                    .map(|s| s.in_flight)
                    .unwrap_or(0);
                assert!(
                    in_flight <= CAP as u64,
                    "subscriber held {in_flight} frames, window is {CAP}"
                );
                pending = Some(b);
                // popping one frame returns one credit and reopens the window
                if let PortRecv::Item(item) = sub.try_recv() {
                    got.push(item);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            PortSend::NoSubscribers(b) => {
                pending = Some(b);
                std::thread::sleep(Duration::from_millis(1));
            }
            PortSend::Closed(_) => panic!("stream closed under the publisher"),
        }
    }
    let full_at = saw_full_at.expect("a closed credit window parked the publisher");
    assert!(
        full_at <= (2 * CAP + 1) as u64,
        "publisher ran {full_at} frames ahead of an unread subscriber"
    );
    publ.finish();
    let (rest, reason) = drain_until_end(sub.as_mut());
    got.extend(rest);
    assert_eq!(got.len() as u64, TOTAL, "blocking QoS delivered everything");
    for (i, b) in got.iter().enumerate() {
        assert_eq!(b.pts_ns, i as u64, "in order, exactly once");
    }
    assert!(matches!(reason, Some(StreamEnd::Eos)));

    // conservation identity on both sides of the wire
    for s in t.snapshot() {
        assert_eq!(
            s.pushed,
            s.delivered + s.dropped + s.in_flight,
            "conservation violated on {}",
            s.name
        );
        assert_eq!(s.delivered, TOTAL, "{} delivered everything", s.name);
    }
}

// -- the conservation identity is reportable from PipelineReport ------------

#[test]
fn conservation_identity_in_pipeline_reports() {
    let registry = NetRegistry::serve("127.0.0.1:0").unwrap();
    // a named transport instance keeps this test isolated from siblings
    register_tcp_as("tcp-report", TcpConfig::new(registry.addr().to_string()));

    let hub = PipelineHub::with_workers(2);
    let mut back = PipelineBuilder::new();
    back.chain_named(
        "in",
        nnstreamer::elements::query::QueryServerSrcProps {
            topic: "net/report".into(),
            transport: "tcp-report".into(),
            caps: Caps::tensor(DType::U8, [3, 16, 16, 1], 240.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named("out", nnstreamer::elements::sinks::TensorSinkProps::default())
    .unwrap();
    hub.launch("back", back.build()).unwrap();

    let front = Pipeline::parse(
        "videotestsrc num-buffers=6 pattern=gradient ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter ! \
         tensor_query_serversink topic=net/report transport=tcp-report wait-subscribers=1",
    )
    .unwrap();
    hub.launch("front", front).unwrap();

    for j in hub.join_all() {
        let report = j.report.expect("pipeline succeeded");
        if j.name == "back" {
            assert_eq!(report.element("out").unwrap().buffers_in(), 6);
            for side in ["tcp-pub:net/report", "tcp-sub:net/report"] {
                let s = report
                    .topic(side)
                    .unwrap_or_else(|| panic!("{side} missing from PipelineReport::topics"));
                assert_eq!(
                    s.pushed,
                    s.delivered + s.dropped + s.in_flight,
                    "conservation violated on {side}"
                );
                assert_eq!(s.delivered, 6, "{side} carried every frame");
                assert_eq!(s.dropped, 0, "{side} dropped nothing");
                assert!(s.eos, "{side} observed end-of-stream");
            }
        }
    }
}
