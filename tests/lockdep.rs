//! Lock-order analysis suite (debug builds; lockdep compiles to nothing
//! in release, so this binary is empty there).
//!
//! Two halves, per the nnscheck design:
//!
//! * a **deliberate AB/BA fixture** must be flagged, with both lock
//!   construction sites in the report — run against an isolated graph
//!   so the planted inversion cannot pollute the process-global one;
//! * a **representative clean workload** (a real pipeline run plus
//!   topic pub/sub) must leave the process-global order graph acyclic
//!   and non-trivial (edges were actually recorded — the analysis
//!   observed the run, it did not just vacuously find nothing).

#![cfg(debug_assertions)]

use std::time::Duration;

use nnstreamer::pipeline::{Pipeline, Qos, StreamRegistry};
use nnstreamer::sync::lockdep::{self, SiteId};
use nnstreamer::sync::{Condvar, Mutex};
use nnstreamer::tensor::Buffer;

/// The classic inversion: class A before class B on one path, B before
/// A on another. Lock-order analysis needs no unlucky interleaving —
/// both paths can run on one thread, sequentially, and the closing
/// edge still reports (that is the point: latent deadlocks are found
/// without ever deadlocking).
#[test]
fn ab_ba_inversion_is_flagged_with_both_sites() {
    if !lockdep::enabled() {
        eprintln!("NNS_LOCKDEP=0: skipping");
        return;
    }
    let lock_a = Mutex::new(0u32);
    let lock_b = Mutex::new(0u32);
    let site_a = SiteId::of(lock_a.site());
    let site_b = SiteId::of(lock_b.site());
    assert_ne!(site_a, site_b, "distinct construction lines, distinct classes");

    let ((), cycles, _waits) = lockdep::with_isolated_graph(|| {
        {
            let _ga = lock_a.lock().unwrap();
            let _gb = lock_b.lock().unwrap();
        }
        {
            let _gb = lock_b.lock().unwrap();
            let _ga = lock_a.lock().unwrap();
        }
    });

    assert_eq!(cycles.len(), 1, "exactly the planted inversion: {cycles:?}");
    let cycle = &cycles[0];
    let endpoints = [cycle.from, cycle.to];
    assert!(
        endpoints.contains(&site_a) && endpoints.contains(&site_b),
        "report must carry both sites, got {} -> {}",
        cycle.from,
        cycle.to
    );
}

/// Waiting on a condvar while still holding an unrelated lock is the
/// shape of every convoy bug; lockdep records it as a diagnostic (not
/// a failure — bounded-timeout forms are legitimate).
#[test]
fn wait_while_holding_is_recorded() {
    if !lockdep::enabled() {
        eprintln!("NNS_LOCKDEP=0: skipping");
        return;
    }
    let outer = Mutex::new(0u32);
    let inner = Mutex::new(0u32);
    let cv = Condvar::new();
    let outer_site = SiteId::of(outer.site());
    let inner_site = SiteId::of(inner.site());

    let ((), cycles, waits) = lockdep::with_isolated_graph(|| {
        let _go = outer.lock().unwrap();
        let gi = inner.lock().unwrap();
        // Nobody notifies: the 1ms timeout returns promptly.
        let _ = cv.wait_timeout(gi, Duration::from_millis(1)).unwrap();
    });

    assert!(cycles.is_empty(), "plain nesting is not an inversion");
    assert_eq!(waits.len(), 1, "one wait-while-holding: {waits:?}");
    assert_eq!(waits[0].waited_at, inner_site);
    assert_eq!(waits[0].held, vec![outer_site]);
}

/// Drive the real streaming core — an executor-run pipeline and a
/// topic with backpressure — and assert the global lock-order graph it
/// leaves behind is acyclic. This is the suite-level promise DESIGN.md
/// states: the production lock classes form a partial order.
#[test]
fn streaming_core_lock_order_graph_is_acyclic() {
    if !lockdep::enabled() {
        eprintln!("NNS_LOCKDEP=0: skipping");
        return;
    }

    // A real pipeline run: run queue, sched cells, timers, inboxes.
    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=8 ! videoconvert format=RGB ! \
         tensor_converter ! tensor_transform mode=normalize ! \
         tensor_sink name=out",
    )
    .expect("parse");
    p.run().expect("pipeline run");

    // Topic pub/sub with a small bound so the publisher parks at least
    // conceptually through the same lock classes the serving path uses.
    let reg = StreamRegistry::new();
    let sub = reg.subscribe_with("lockdep-order", 2, Qos::Blocking);
    let consumer = std::thread::spawn(move || {
        let mut got = 0u32;
        while sub.recv().is_ok() {
            got += 1;
        }
        got
    });
    {
        let mut publisher = reg.publish("lockdep-order");
        for i in 0..16u64 {
            publisher.push(Buffer::from_f32(i, &[i as f32])).unwrap();
        }
        publisher.end();
    }
    assert_eq!(consumer.join().unwrap(), 16);

    assert!(
        lockdep::global_edge_count() > 0,
        "the workload must have recorded lock-order edges"
    );
    let cycles = lockdep::global_cycles();
    assert!(
        cycles.is_empty(),
        "lock-order inversion in the streaming core: {cycles:?}"
    );
    assert!(lockdep::global_is_acyclic());
}
