//! Multi-tenant serving hardening: a stress/isolation harness over the
//! [`PipelineHub`] — thousands of short-lived SingleShot tenants riding
//! the global executor while streaming pipelines run on a small
//! dedicated hub, with bounded threads, per-tenant report isolation,
//! typed admission denials, a consistent mid-stream topic snapshot, and
//! a clean `request_stop_all` under full load.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use nnstreamer::error::Error;
use nnstreamer::pipeline::{Pipeline, PipelineHub, Qos, TenantQuota};
use nnstreamer::runtime::SingleShot;

const WORKERS: usize = 4;
const TENANTS: usize = 1000;
const INVOKE_THREADS: usize = 8;

/// Thread count of this process (`/proc/self/status`); None off Linux.
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

fn streaming_desc(frames: u64) -> String {
    format!(
        "videotestsrc num-buffers={frames} pattern=gradient ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter ! fakesink name=out"
    )
}

/// Satellite 1: 1000 short-lived SingleShot tenants (each opening,
/// invoking and dropping its own serving pipeline) run concurrently with
/// 4 streaming pipelines on a 4-worker hub. Threads stay O(workers +
/// invoking threads), never O(tenants); each tenant's report is its own.
#[test]
fn serving_fleet_keeps_threads_bounded_and_reports_isolated() {
    // Warm the global executor and the model registry so the thread
    // baseline excludes one-time pool spawn and model compile.
    {
        let s = SingleShot::open("ars_a_opt").expect("artifacts present");
        s.invoke(&[&vec![0.1f32; 128 * 3]]).unwrap();
    }
    let baseline = process_threads();

    let hub = PipelineHub::with_workers(WORKERS);
    // 4 streaming pipelines, one per tenant, with distinct frame counts
    // so cross-tenant report mixing would be visible.
    let frames_of = |i: usize| (16 + 4 * i) as u64;
    for i in 0..4 {
        let p = Pipeline::parse(&streaming_desc(frames_of(i))).unwrap();
        hub.launch_as(format!("tenant-{i}"), format!("stream-{i}"), p)
            .unwrap();
    }

    // 1000 short-lived SingleShot tenants across a few app threads.
    let mut invokers = Vec::new();
    for t in 0..INVOKE_THREADS {
        invokers.push(std::thread::spawn(move || {
            let input: Vec<f32> =
                (0..128 * 3).map(|i| ((i + t) % 17) as f32 / 17.0).collect();
            let mut first: Option<Vec<Vec<f32>>> = None;
            for _ in 0..(TENANTS / INVOKE_THREADS) {
                let s = SingleShot::open("ars_a_opt").unwrap();
                let out = s.invoke(&[&input]).unwrap();
                assert_eq!(out[0].len(), 8);
                // tenant isolation: identical input, identical output,
                // whatever else the process is running
                match &first {
                    None => first = Some(out),
                    Some(f) => assert_eq!(f, &out, "tenant output diverged"),
                }
            }
        }));
    }

    // Bounded-thread criterion while everything is in flight: the hub's
    // workers plus our own invoker threads, never a thread per tenant.
    if let (Some(before), Some(during)) = (baseline, process_threads()) {
        let added = during.saturating_sub(before);
        assert!(
            added <= WORKERS + INVOKE_THREADS + 2,
            "expected O(workers) threads, got +{added} \
             (before={before}, during={during})"
        );
        assert!(
            during < TENANTS / 4,
            "thread count must stay far below one-per-tenant ({during})"
        );
    }
    for h in invokers {
        h.join().unwrap();
    }

    // Per-tenant report isolation: every join carries its tenant tag and
    // exactly its own pipeline's counters.
    let mut joined = hub.join_all();
    assert_eq!(joined.len(), 4);
    joined.sort_by(|a, b| a.name.cmp(&b.name));
    for (i, j) in joined.iter().enumerate() {
        assert_eq!(j.name, format!("stream-{i}"));
        assert_eq!(j.tenant.as_deref(), Some(format!("tenant-{i}").as_str()));
        let report = j.report.as_ref().expect("streaming pipeline succeeded");
        assert_eq!(
            report.element("out").unwrap().buffers_in(),
            frames_of(i),
            "tenant {i} report must count its own frames only"
        );
        // every pipeline report carries latency percentiles
        assert_eq!(report.latency.count, frames_of(i));
        assert!(report.latency.p50 <= report.latency.p90);
        assert!(report.latency.p90 <= report.latency.p99);
    }
}

/// Satellite 1 (stop path): `request_stop_all` while unbounded live
/// pipelines are mid-flight and app threads keep invoking must join
/// every pipeline — no hang, no error.
#[test]
fn request_stop_all_under_full_load_joins_every_pipeline() {
    let hub = Arc::new(PipelineHub::with_workers(WORKERS));
    for i in 0..4 {
        // no num-buffers: runs until stopped
        let p = Pipeline::parse(
            "videotestsrc pattern=ball ! \
             video/x-raw,format=RGB,width=16,height=16,framerate=2400 ! \
             tensor_converter ! fakesink name=out",
        )
        .unwrap();
        hub.launch(format!("live-{i}"), p).unwrap();
    }
    // one topic consumer that the stop must also release
    let sub = hub.subscribe("serving/never-published");
    let stop = Arc::new(AtomicBool::new(false));
    let invoker = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let input = vec![0.3f32; 128 * 3];
            let mut n = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let s = SingleShot::open("ars_a_opt").unwrap();
                s.invoke(&[&input]).unwrap();
                n += 1;
            }
            n
        })
    };
    // let the fleet actually saturate the pool
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(hub.running_count(), 4, "live pipelines still running");

    hub.request_stop_all();
    let joined = hub.join_all();
    assert_eq!(joined.len(), 4);
    for j in joined {
        let report = j.report.expect("stopped pipeline joins cleanly");
        assert!(
            report.element("out").unwrap().buffers_in() > 0,
            "{}: pipeline was mid-stream when stopped",
            j.name
        );
    }
    // the hub closed the subscriber it issued: recv terminates
    assert!(sub.recv().is_err(), "stop_all closes issued subscribers");
    stop.store(true, Ordering::Relaxed);
    assert!(invoker.join().unwrap() > 0);
}

/// Tentpole 2: every quota dimension denies with a typed error —
/// immediately, never a hang — and releases when usage drops.
#[test]
fn admission_control_denies_typed_on_every_dimension() {
    let hub = PipelineHub::with_workers(1);
    hub.set_quota(
        "metered",
        TenantQuota {
            max_live_pipelines: 2,
            max_queued_invokes: 3,
            max_topic_buffers: 16,
        },
    );

    // live pipelines: appsrc-fed pipelines stay live until stopped
    let mk = || Pipeline::parse("appsrc name=in ! appsink name=out").unwrap();
    hub.launch_as("metered", "p0", mk()).unwrap();
    hub.launch_as("metered", "p1", mk()).unwrap();
    match hub.launch_as("metered", "p2", mk()) {
        Err(Error::AdmissionDenied {
            tenant,
            resource,
            limit,
        }) => {
            assert_eq!(tenant, "metered");
            assert_eq!(resource, "live pipelines");
            assert_eq!(limit, 2);
        }
        Err(other) => panic!("expected typed denial, got {other}"),
        Ok(_) => panic!("expected typed denial, launch was admitted"),
    }

    // queued invokes: RAII tickets bound concurrency, denial is typed
    let tickets: Vec<_> = (0..3)
        .map(|_| hub.try_admit_invoke("metered").unwrap())
        .collect();
    assert!(matches!(
        hub.try_admit_invoke("metered"),
        Err(Error::AdmissionDenied {
            resource: "queued invokes",
            limit: 3,
            ..
        })
    ));
    drop(tickets);
    hub.try_admit_invoke("metered").unwrap();

    // topic buffers: summed live capacity is budgeted
    let _s = hub
        .subscribe_as("metered", "serving/adm-a", 12, Qos::Leaky)
        .unwrap();
    assert!(matches!(
        hub.subscribe_as("metered", "serving/adm-b", 8, Qos::Blocking),
        Err(Error::AdmissionDenied {
            resource: "topic buffers",
            limit: 16,
            ..
        })
    ));
    let small = hub
        .subscribe_as("metered", "serving/adm-b", 4, Qos::LatestOnly)
        .unwrap();
    drop(small);

    // unmetered tenants and plain launches are unaffected
    hub.launch_as("open", "q0", mk()).unwrap();
    hub.launch("plain", mk()).unwrap();
    hub.try_admit_invoke("open").unwrap();

    hub.request_stop_all();
    for j in hub.join_all() {
        j.report.unwrap();
    }
}

/// Satellite 4: a topic snapshot taken mid-stream is internally
/// consistent — delivered never exceeds pushed or published, and the
/// conservation identity `pushed == delivered + dropped + in_flight`
/// holds exactly at every sample because the snapshot is taken under
/// the topic lock (publishes can't interleave the read).
#[test]
fn midstream_topic_snapshot_never_shows_delivered_over_published() {
    let topic = "serving/mid";
    let hub = Arc::new(PipelineHub::with_workers(2));
    let sub = hub.subscribe_with_capacity(topic, 4);
    let p = Pipeline::parse(&format!(
        "videotestsrc num-buffers=400 pattern=gradient ! \
         video/x-raw,format=RGB,width=8,height=8,framerate=2400 ! \
         tensor_converter ! tensor_query_serversink topic={topic} qos=blocking"
    ))
    .unwrap();

    let done = Arc::new(AtomicBool::new(false));
    let sampler = {
        let hub = hub.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut samples = 0u64;
            while !done.load(Ordering::Relaxed) {
                for t in hub.streams().snapshot() {
                    if t.name != topic {
                        continue;
                    }
                    samples += 1;
                    assert!(
                        t.delivered <= t.pushed,
                        "delivered {} > pushed {}",
                        t.delivered,
                        t.pushed
                    );
                    assert!(
                        t.delivered <= t.published,
                        "delivered {} > published {}",
                        t.delivered,
                        t.published
                    );
                    assert_eq!(
                        t.pushed,
                        t.delivered + t.dropped + t.in_flight,
                        "conservation must hold at every mid-stream sample"
                    );
                    assert_eq!(t.dropped, t.drops.total());
                }
                std::thread::yield_now();
            }
            samples
        })
    };

    hub.launch("publisher", p).unwrap();
    let mut received = 0u64;
    while sub.recv().is_ok() {
        received += 1;
    }
    for j in hub.join_all() {
        j.report.expect("publisher succeeded");
    }
    done.store(true, Ordering::Relaxed);
    let samples = sampler.join().unwrap();
    assert_eq!(received, 400, "blocking qos delivers every frame");
    assert!(samples > 0, "sampler observed the topic mid-stream");

    // final state: settled and conserved
    let t = hub
        .streams()
        .snapshot()
        .into_iter()
        .find(|t| t.name == topic)
        .unwrap();
    assert_eq!(t.delivered, 400);
    assert_eq!(t.in_flight, 0);
    assert_eq!(t.pushed, t.delivered + t.dropped);
    assert_eq!(t.latency.count, 400, "topic queue-wait histogram filled");
}

/// Topic QoS end to end through hub subscriptions: a leaky subscriber
/// under flood loses frames (typed drop accounting) without gating the
/// publisher, while a blocking subscriber on the same topic gets all.
#[test]
fn leaky_subscriber_sheds_while_blocking_peer_gets_everything() {
    let topic = "serving/mixed";
    let hub = PipelineHub::with_workers(2);
    let lossless = hub.subscribe_with_capacity(topic, 8);
    let lossy = hub.subscribe_as("lossy", topic, 2, Qos::Leaky).unwrap();

    let p = Pipeline::parse(&format!(
        "videotestsrc num-buffers=64 pattern=ball ! \
         video/x-raw,format=RGB,width=8,height=8,framerate=2400 ! \
         tensor_converter ! tensor_query_serversink topic={topic} qos=blocking"
    ))
    .unwrap();
    hub.launch("src", p).unwrap();

    let mut lossless_n = 0u64;
    while lossless.recv().is_ok() {
        lossless_n += 1;
    }
    for j in hub.join_all() {
        j.report.unwrap();
    }
    assert_eq!(lossless_n, 64, "blocking subscriber got every frame");

    // the lossy peer was never drained: at most its capacity in flight,
    // the rest counted as leaky drops
    let c = lossy.counters();
    assert_eq!(c.pushed, 64);
    assert!(c.in_flight <= 2);
    assert_eq!(c.dropped.qos_leaky, c.pushed - c.delivered - c.in_flight);
    assert!(c.dropped.qos_leaky >= 62);
}
