//! nnscheck model suite (`--features check`; run via `make check`).
//!
//! Each test here is a *micro-model*: a closed concurrent protocol built
//! from the same production types the streaming core runs on (the
//! executor's [`SchedCell`] park/wake cell, the topic registry, the
//! transport's [`CreditWindow`], the executor's [`TimerWheel`]),
//! explored under the controlled scheduler in `nnstreamer::sync::check`.
//! A failing test prints a replayable counterexample (seed or decision
//! trace) — rerun with `NNSCHECK_SEED=<seed>` or feed the seed to
//! `check::replay` to step through the exact interleaving.
//!
//! The wake-gate model doubles as a **mutation test**: building with
//! `--features check,mutate-wake-pending` compiles out the lost-wakeup
//! guard in `SchedCell::on_wake`, and the suite then *requires* the
//! checker to produce a counterexample within the same budget — proof
//! that the exploration actually reaches the buggy interleaving rather
//! than passing vacuously.

use std::sync::Arc;
use std::sync::mpsc::TryRecvError;
use std::time::{Duration, Instant};

use nnstreamer::error::Fault;
use nnstreamer::net::transport::CreditWindow;
use nnstreamer::pipeline::executor::{SchedCell, SchedState, TimerWheel, WakeVerdict};
use nnstreamer::pipeline::stream::InProcTransport;
use nnstreamer::pipeline::{Qos, StreamEnd, StreamRegistry, Transport};
use nnstreamer::sync::check::{self, Config, Outcome};
use nnstreamer::sync::thread;
use nnstreamer::sync::{Condvar, Mutex};
use nnstreamer::tensor::Buffer;

// ---------------------------------------------------------------------------
// Model 1: the executor's park/wake protocol never loses a wakeup
// ---------------------------------------------------------------------------

/// The exact state machine `pipeline/executor.rs` runs per task, reduced
/// to one worker and one producer: the worker steps the "task" (drains
/// an inbox) and parks when the inbox is empty; the producer pushes
/// items and wakes the task. The hazard is the window between the
/// worker's last empty-inbox observation and its park: a wake landing
/// there sees state `Running` and must be latched (`wake_pending`) so
/// the park converts into a requeue — otherwise the item sits in the
/// inbox with the task parked forever, which the checker reports as a
/// deadlock.
struct WakeRig {
    sched: Mutex<SchedCell>,
    /// Run-queue stand-in: tokens for "the task is queued".
    queue: Mutex<u32>,
    queued: Condvar,
    inbox: Mutex<Vec<u32>>,
}

impl WakeRig {
    fn new() -> WakeRig {
        WakeRig {
            sched: Mutex::new(SchedCell::new()),
            // The task starts queued (SchedCell::default is Queued).
            queue: Mutex::new(1),
            queued: Condvar::new(),
            inbox: Mutex::new(Vec::new()),
        }
    }
}

const WAKE_ITEMS: u32 = 2;

fn wake_worker(rig: Arc<WakeRig>) {
    let mut consumed = 0;
    while consumed < WAKE_ITEMS {
        {
            let mut q = rig.queue.lock().unwrap();
            while *q == 0 {
                q = rig.queued.wait(q).unwrap();
            }
            *q -= 1;
        }
        rig.sched.lock().unwrap().set_running();
        loop {
            let item = rig.inbox.lock().unwrap().pop();
            match item {
                Some(_) => consumed += 1,
                None => {
                    let parked = rig
                        .sched
                        .lock()
                        .unwrap()
                        .try_park(SchedState::ParkedInput);
                    if !parked {
                        // A wake arrived mid-step: requeue instead.
                        *rig.queue.lock().unwrap() += 1;
                        rig.queued.notify_one();
                    }
                    break;
                }
            }
        }
    }
}

fn wake_producer(rig: Arc<WakeRig>) {
    for i in 0..WAKE_ITEMS {
        rig.inbox.lock().unwrap().push(i);
        let verdict = rig.sched.lock().unwrap().on_wake();
        if verdict == WakeVerdict::Enqueue {
            *rig.queue.lock().unwrap() += 1;
            rig.queued.notify_one();
        }
    }
}

fn wake_gate_model() {
    let rig = Arc::new(WakeRig::new());
    let w = {
        let rig = rig.clone();
        thread::spawn(move || wake_worker(rig))
    };
    let p = {
        let rig = rig.clone();
        thread::spawn(move || wake_producer(rig))
    };
    p.join().unwrap();
    w.join().unwrap();
}

/// With the guard intact, no interleaving loses a wakeup.
#[cfg(not(feature = "mutate-wake-pending"))]
#[test]
fn wake_gate_never_loses_a_wakeup() {
    let outcome = check::explore(&Config::default(), wake_gate_model);
    if let Some(cex) = outcome.counterexample() {
        panic!("park/wake protocol lost a wakeup:\n{cex}");
    }
}

/// Mutation kill: with `wake_pending` compiled out, the checker must
/// find the lost wakeup within the same budget *and* the counterexample
/// must replay — a seed or trace that does not reproduce is worthless
/// as a bug report.
#[cfg(feature = "mutate-wake-pending")]
#[test]
fn wake_gate_mutation_is_caught() {
    let outcome = check::explore(&Config::default(), wake_gate_model);
    let cex = outcome
        .counterexample()
        .expect("mutated guard must yield a counterexample within budget")
        .clone();
    let reproduced = match cex.seed {
        Some(seed) => check::replay(seed, wake_gate_model),
        None => check::replay_trace(&cex.trace, wake_gate_model),
    };
    assert!(
        reproduced.is_some(),
        "counterexample did not reproduce on replay:\n{cex}"
    );
}

// ---------------------------------------------------------------------------
// Model 2: topic conservation across QoS modes
// ---------------------------------------------------------------------------

/// `pushed == delivered + dropped + in_flight` on a topic with all
/// three subscriber QoS modes attached at once. The identity is also
/// `debug_assert!`ed inside `stream.rs` after every locked mutation, so
/// any interleaving that breaks it mid-stream panics right at the
/// faulty transition, not just at the final snapshot.
fn conservation_model() {
    let reg = StreamRegistry::new();
    let blocking = reg.subscribe_with("conserve", 2, Qos::Blocking);
    let leaky = reg.subscribe_with("conserve", 1, Qos::Leaky);
    let latest = reg.subscribe_with("conserve", 1, Qos::LatestOnly);
    let publisher = reg.publish("conserve");

    let p = thread::spawn(move || {
        let mut publisher = publisher;
        for i in 0..3u64 {
            // Blocks while the blocking subscriber's queue is full —
            // the leaky/latest-only queues shed instead.
            publisher.push(Buffer::from_f32(i, &[i as f32, 0.5])).unwrap();
        }
        publisher.end();
    });
    let c = thread::spawn(move || {
        let mut got = 0u64;
        while blocking.recv().is_ok() {
            got += 1;
        }
        assert_eq!(got, 3, "blocking QoS is lossless");
    });
    p.join().unwrap();
    c.join().unwrap();

    // The never-popped subscribers fold their counters into the topic
    // on detach; the snapshot re-checks the aggregate identity under
    // the topic lock (another in-crate debug_assert).
    drop(leaky);
    drop(latest);
    let snaps = reg.snapshot();
    let s = &snaps[0];
    assert_eq!(
        s.pushed,
        s.delivered + s.dropped + s.in_flight,
        "topic conservation violated in final snapshot: {s:?}"
    );
}

#[test]
fn topic_conservation_holds_across_qos_modes() {
    let cfg = Config {
        // The topic model has a larger per-run decision count; trim the
        // DFS tail so the suite stays inside the CI budget.
        dfs_max_runs: 300,
        ..Config::default()
    };
    let outcome = check::explore(&cfg, conservation_model);
    if let Some(cex) = outcome.counterexample() {
        panic!("topic conservation violated:\n{cex}");
    }
}

// ---------------------------------------------------------------------------
// Model 3: credit window accounting
// ---------------------------------------------------------------------------

/// The transport's flow-control wire invariant, `sent − credited ≤
/// capacity`, modeled socket-free: a writer `take()`s until the window
/// closes, a reader grants credits back and closes. In every
/// interleaving the writer can send at most `initial + granted` frames,
/// the balance never exceeds the capacity, and an over-window grant is
/// refused without disturbing the balance.
fn credit_model() {
    let win = Arc::new(CreditWindow::new(4, 2));
    let writer = {
        let win = win.clone();
        thread::spawn(move || {
            let mut sent = 0u64;
            while win.take() {
                sent += 1;
                assert!(win.balance() <= 4, "balance above capacity");
            }
            sent
        })
    };
    let reader = {
        let win = win.clone();
        thread::spawn(move || {
            for _ in 0..3 {
                assert!(win.grant(1), "in-window grant refused");
                assert!(win.balance() <= 4, "balance above capacity");
            }
            // A grant that would overflow the window is a protocol
            // violation: refused, balance untouched (cap is 4, so +100
            // can never fit no matter the interleaving).
            assert!(!win.grant(100), "over-window grant accepted");
            win.close();
        })
    };
    reader.join().unwrap();
    let sent = writer.join().unwrap();
    assert!(
        sent <= 2 + 3,
        "writer sent {sent} frames on 2 initial + 3 granted credits"
    );
}

#[test]
fn credit_window_never_exceeds_capacity() {
    let outcome = check::explore(&Config::default(), credit_model);
    if let Some(cex) = outcome.counterexample() {
        panic!("credit accounting violated:\n{cex}");
    }
}

// ---------------------------------------------------------------------------
// Model 4: the timer wheel never fires early
// ---------------------------------------------------------------------------

/// Deterministic virtual-time probe of the executor's `TimerWheel` (no
/// sleeping, no scheduler needed): entries must never be returned
/// before their deadline, must all fire once due — including deadlines
/// that alias the same slot after the wheel wraps — and `soonest()`
/// must track the earliest armed deadline exactly.
#[test]
fn timer_wheel_never_fires_early() {
    let base = Instant::now();
    let mut w: TimerWheel<u32> = TimerWheel::new();

    w.arm(base + Duration::from_millis(10), 1);
    w.arm(base + Duration::from_millis(20), 2);
    // 1ms ticks × 256 slots: +266ms wraps onto the +10ms slot.
    w.arm(base + Duration::from_millis(266), 3);
    assert_eq!(w.soonest(), Some(base + Duration::from_millis(10)));

    assert!(
        w.take_due(base + Duration::from_millis(9)).is_empty(),
        "fired before any deadline"
    );
    let due = w.take_due(base + Duration::from_millis(10));
    assert_eq!(due, vec![1], "exactly the 10ms entry is due, got {due:?}");
    assert_eq!(w.soonest(), Some(base + Duration::from_millis(20)));

    // The wrapped entry shares the 10ms slot but is not due yet.
    let due = w.take_due(base + Duration::from_millis(25));
    assert_eq!(due, vec![2], "slot-aliased entry fired 241ms early");
    assert_eq!(w.len(), 1);

    assert!(w.take_due(base + Duration::from_millis(265)).is_empty());
    assert_eq!(w.take_due(base + Duration::from_millis(266)), vec![3]);
    assert!(w.is_empty());
    assert_eq!(w.soonest(), None);

    // Entries armed in the past fire on the next probe, never silently
    // linger.
    w.arm(base, 4);
    assert_eq!(w.take_due(base + Duration::from_millis(1)), vec![4]);
}

// ---------------------------------------------------------------------------
// Model 5: stop/fault/EOS precedence is race-free
// ---------------------------------------------------------------------------

/// Two publishers end a shared topic concurrently — one cleanly, one
/// with a fault. Whatever the detach order, every subscriber must
/// observe `StreamEnd::Fault` (a recorded fault is sticky and outranks
/// a clean EOS), so a fault can never be masked by a racing clean
/// finish.
fn fault_precedence_model() {
    let reg = StreamRegistry::new();
    let transport = InProcTransport::new(reg.clone());
    let sub = reg.subscribe_with("faulty", 8, Qos::Blocking);
    let clean = transport.advertise("faulty", Qos::Blocking).unwrap();
    let faulty = transport.advertise("faulty", Qos::Blocking).unwrap();

    let a = thread::spawn(move || {
        let mut clean = clean;
        let _ = clean.try_send(Buffer::from_f32(0, &[1.0]));
        clean.finish();
    });
    let b = thread::spawn(move || {
        let mut faulty = faulty;
        faulty.fail(&Fault {
            element: "model".into(),
            message: "injected".into(),
            panicked: false,
        });
    });
    a.join().unwrap();
    b.join().unwrap();

    loop {
        match sub.try_recv() {
            Ok(_) => continue,
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
        }
    }
    match sub.close_reason() {
        Some(StreamEnd::Fault(f)) => assert_eq!(f.message, "injected"),
        other => panic!("fault masked by racing clean EOS: close reason {other:?}"),
    }
}

#[test]
fn fault_outranks_clean_eos_in_every_interleaving() {
    let cfg = Config {
        dfs_max_runs: 300,
        ..Config::default()
    };
    let outcome = check::explore(&cfg, fault_precedence_model);
    if let Some(cex) = outcome.counterexample() {
        panic!("stop/fault/EOS precedence raced:\n{cex}");
    }
}

// ---------------------------------------------------------------------------
// Harness self-checks
// ---------------------------------------------------------------------------

/// A model that deadlocks by construction must be reported as such, and
/// its counterexample must replay. This is the canary for the checker
/// itself: if blocked-thread detection rots, this fails before any real
/// model silently stops finding bugs.
#[test]
fn checker_detects_a_planted_deadlock() {
    fn ab_ba_model() {
        let a = Arc::new(Mutex::new(0u32));
        let b = Arc::new(Mutex::new(0u32));
        let t = {
            let (a, b) = (a.clone(), b.clone());
            thread::spawn(move || {
                let ga = a.lock().unwrap();
                let mut gb = b.lock().unwrap();
                *gb += *ga;
            })
        };
        {
            let gb = b.lock().unwrap();
            let mut ga = a.lock().unwrap();
            *ga += *gb;
        }
        t.join().unwrap();
    }

    let outcome = check::explore(&Config::default(), ab_ba_model);
    let cex = outcome
        .counterexample()
        .expect("AB/BA deadlock not found within budget")
        .clone();
    let reproduced = match cex.seed {
        Some(seed) => check::replay(seed, ab_ba_model),
        None => check::replay_trace(&cex.trace, ab_ba_model),
    };
    assert!(reproduced.is_some(), "deadlock did not replay:\n{cex}");
}

/// A race-free model passes and reports how much it explored.
#[test]
fn checker_passes_a_clean_model() {
    let outcome = check::explore(&Config::default(), || {
        let m = Arc::new(Mutex::new(0u32));
        let t = {
            let m = m.clone();
            thread::spawn(move || *m.lock().unwrap() += 1)
        };
        t.join().unwrap();
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 2);
    });
    match outcome {
        Outcome::Pass { runs } => assert!(runs > 0),
        Outcome::Fail(cex) => panic!("clean model failed:\n{cex}"),
    }
}
