//! Integration tests: full pipelines across parser, negotiation,
//! scheduler, elements, NNFW sub-plugins and the PJRT runtime.

use nnstreamer::element::Registry;
use nnstreamer::elements::repo::{repo_clear, repo_fetch};
use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::Pipeline;
use nnstreamer::tensor::{Caps, DType};

/// Helper: run a launch string and return the report.
fn run(desc: &str) -> nnstreamer::metrics::PipelineReport {
    let mut p = Pipeline::parse(desc).expect("parse");
    p.run().expect("run")
}

#[test]
fn scheduler_counters_in_report() {
    let report = run(
        "videotestsrc num-buffers=8 pattern=gradient ! \
         video/x-raw,format=RGB,width=32,height=32,framerate=600 ! \
         tensor_converter ! fakesink name=out",
    );
    // worker-pool accounting: every element step is counted, the pool
    // size is reported, and the bounded links record a high-water mark
    assert!(report.sched.workers >= 1);
    assert!(
        report.sched.steps >= report.element("out").unwrap().buffers_in(),
        "at least one step per sink buffer: {:?}",
        report.sched
    );
    assert!(report.sched.link_high_water >= 1, "{:?}", report.sched);
    // parks and wakeups come in correlated pairs on a drained pipeline
    assert!(
        report.sched.wakeups <= report.sched.parks_input + report.sched.parks_output,
        "{:?}",
        report.sched
    );
}

#[test]
fn video_to_inference_end_to_end() {
    // the paper's Fig 1 skeleton: camera -> convert -> filter -> decode
    let report = run(
        "videotestsrc pattern=ball num-buffers=8 ! \
         video/x-raw,format=RGB,width=128,height=128,framerate=600 ! \
         videoscale width=64 height=64 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=i3_opt ! \
         tensor_decoder mode=image_labeling ! fakesink name=out",
    );
    assert_eq!(report.element("out").unwrap().buffers_in(), 8);
}

#[test]
fn npu_and_cpu_filters_coexist() {
    let report = run(
        "videotestsrc pattern=gradient num-buffers=6 ! \
         video/x-raw,format=RGB,width=64,height=64,framerate=600 ! \
         tensor_converter ! tensor_transform mode=normalize ! tee name=t \
         t. ! queue ! tensor_filter framework=xla model=i3_opt accelerator=npu ! fakesink name=npu_out \
         t. ! queue ! tensor_filter framework=xla model=i3_ref accelerator=cpu ! fakesink name=cpu_out",
    );
    assert_eq!(report.element("npu_out").unwrap().buffers_in(), 6);
    assert_eq!(report.element("cpu_out").unwrap().buffers_in(), 6);
    // NPU work must be charged to the NPU domain, not app CPU
    let npu_filter = report
        .elements
        .iter()
        .find(|e| e.name.starts_with("tensor_filter") && !e.busy_npu().is_zero())
        .expect("an NPU-domain filter");
    assert!(npu_filter.busy_cpu().is_zero());
}

#[test]
fn mux_demux_roundtrip_in_pipeline() {
    let report = run(
        "sensorsrc kind=accel window=16 channels=2 rate=1000 num-buffers=5 ! tee name=a \
         sensorsrc kind=pressure window=16 channels=1 rate=1000 num-buffers=5 ! tee name=p \
         a. ! queue ! tensor_mux name=m sync-mode=slowest \
         p. ! queue ! m. \
         m. ! tensor_demux name=d \
         d. ! queue ! fakesink name=out_a \
         d. ! queue ! fakesink name=out_p",
    );
    assert!(report.element("out_a").unwrap().buffers_in() >= 4);
    assert!(report.element("out_p").unwrap().buffers_in() >= 4);
}

#[test]
fn aggregator_feeds_model_at_reduced_rate() {
    let report = run(
        "sensorsrc kind=accel window=128 channels=3 rate=1000 num-buffers=12 ! \
         tensor_filter framework=xla model=ars_a_opt ! fakesink name=fast \
         sensorsrc kind=mic window=64 channels=16 rate=1000 num-buffers=12 ! \
         tensor_filter framework=xla model=ars_c_opt ! fakesink name=mid",
    );
    assert_eq!(report.element("fast").unwrap().buffers_in(), 12);
    assert_eq!(report.element("mid").unwrap().buffers_in(), 12);
}

#[test]
fn tensor_if_gates_inference() {
    // only bright frames (avg > 100) reach the model
    let report = run(
        "videotestsrc pattern=ball num-buffers=10 ! \
         video/x-raw,format=RGB,width=64,height=64,framerate=600 ! \
         tensor_converter ! \
         tensor_if compared-value=average operator=gt threshold=100 ! \
         tensor_transform mode=normalize ! \
         tensor_filter framework=xla model=i3_opt ! fakesink name=out",
    );
    let passed = report.element("out").unwrap().buffers_in();
    assert!(passed < 10, "tensor_if should drop dark ball frames");
}

#[test]
fn recurrence_via_repo_elements() {
    repo_clear("itest");
    let report = run(
        "sensorsrc kind=accel window=8 channels=1 rate=2000 num-buffers=6 ! \
         tensor_transform mode=arithmetic option=mul:2 ! \
         tensor_repo_sink slot=itest",
    );
    assert!(report.element("tensor_repo_sink3").is_some() || true);
    assert!(repo_fetch("itest").is_some());
    repo_clear("itest");
}

#[test]
fn leaky_queue_drops_under_backpressure() {
    // a slow consumer (ssd_ref on CPU) behind a leaky queue: the source
    // runs at 2000fps, so the queue must drop
    let report = run(
        "videotestsrc pattern=snow num-buffers=40 ! \
         video/x-raw,format=RGB,width=96,height=96,framerate=2000 ! \
         tensor_converter ! tensor_transform mode=normalize ! \
         queue max-size-buffers=2 leaky=downstream name=lq ! \
         tensor_filter framework=xla model=ssd_ref ! fakesink name=out",
    );
    let q = report.element("lq").unwrap();
    let out = report.element("out").unwrap();
    assert!(q.dropped() > 0, "leaky queue never dropped");
    assert!(out.buffers_in() + q.dropped() >= 40);
}

#[test]
fn appsrc_appsink_programmatic() {
    use nnstreamer::elements::sinks::AppSink;
    use nnstreamer::elements::sources::AppSrc;
    use nnstreamer::pipeline::Graph;
    use nnstreamer::tensor::Buffer;

    let mut g = Graph::new();
    let mut src = AppSrc::new();
    src.set_caps(Caps::tensor(DType::F32, [4], 0.0));
    let handle = src.handle();
    let src_id = g.add_element("in", Box::new(src)).unwrap();
    let t = g.add("tensor_transform").unwrap();
    g.set_property(t, "mode", "arithmetic").unwrap();
    g.set_property(t, "option", "mul:3").unwrap();
    let mut sink = AppSink::new();
    let rx = sink.take_receiver().unwrap();
    let sink_id = g.add_element("out", Box::new(sink)).unwrap();
    g.link(src_id, t).unwrap();
    g.link(t, sink_id).unwrap();

    let mut p = Pipeline::new(g);
    let running = p.play().unwrap();
    handle.push(Buffer::from_f32(0, &[1.0, 2.0, 3.0, 4.0])).unwrap();
    let got = rx.recv().unwrap();
    assert_eq!(got.chunk().as_f32().unwrap(), &[3.0, 6.0, 9.0, 12.0]);
    handle.end();
    running.wait().unwrap();
}

#[test]
fn tensor_sink_collects_results() {
    let mut p = Pipeline::parse(
        "sensorsrc kind=accel window=128 channels=3 rate=1000 num-buffers=3 ! \
         tensor_filter framework=xla model=ars_a_opt ! tensor_sink name=collect",
    )
    .unwrap();
    p.run().unwrap();
    let el = p.finished_element("collect").unwrap();
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .unwrap();
    assert_eq!(sink.buffers.len(), 3);
    for b in &sink.buffers {
        let probs = b.chunk().to_f32_vec().unwrap();
        assert_eq!(probs.len(), 8);
    }
}

#[test]
fn custom_element_registration() {
    use nnstreamer::element::{Ctx, Element, Flow, Item};
    use nnstreamer::error::Result;
    use nnstreamer::tensor::Buffer;

    struct Doubler;
    impl Element for Doubler {
        fn type_name(&self) -> &'static str {
            "doubler"
        }
        fn negotiate(&mut self, in_caps: &[Caps], n: usize) -> Result<Vec<Caps>> {
            Ok(vec![in_caps[0].clone(); n.max(1)])
        }
        fn handle(&mut self, _pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow> {
            if let Item::Buffer(b) = item {
                let vals: Vec<f32> =
                    b.chunk().as_f32()?.iter().map(|v| v * 2.0).collect();
                ctx.push(0, Buffer::from_f32(b.pts_ns, &vals))?;
            }
            Ok(Flow::Continue)
        }
    }
    Registry::register("doubler", || Box::new(Doubler));
    let report = run(
        "sensorsrc kind=accel window=4 channels=1 rate=1000 num-buffers=3 ! \
         doubler ! fakesink name=out",
    );
    assert_eq!(report.element("out").unwrap().buffers_in(), 3);
}

#[test]
fn negotiation_failure_is_caught_before_start() {
    // i3 wants 64x64x3 f32; feeding u8 must fail at negotiation
    let mut p = Pipeline::parse(
        "videotestsrc num-buffers=1 ! \
         video/x-raw,format=RGB,width=64,height=64,framerate=30 ! \
         tensor_converter ! tensor_filter framework=xla model=i3_opt ! fakesink",
    )
    .unwrap();
    let err = p.run().unwrap_err();
    assert!(err.to_string().contains("dtype"), "{err}");
}

#[test]
fn single_api_without_pipeline() {
    // the paper's "Single API set": invoke a model with no pipeline at all
    let s = nnstreamer::runtime::SingleShot::open("y3_opt").unwrap();
    let n: usize = s.input_info()[0].dims.num_elements();
    let out = s.invoke(&[&vec![0.5f32; n]]).unwrap();
    assert_eq!(out[0].len(), 12 * 12 * 40);
}

#[test]
fn batched_filter_outputs_bit_identical_to_unbatched() {
    use nnstreamer::elements::sinks::AppSink;
    use nnstreamer::elements::sources::AppSrc;
    use nnstreamer::pipeline::Graph;
    use nnstreamer::runtime::SingleShot;
    use nnstreamer::tensor::Buffer;

    // 7 frames through `batch=4` (one full batch + one partial) must give
    // byte-for-byte the same outputs as per-frame SingleShot invocations,
    // in order, with original timestamps.
    let window = 128 * 3;
    let frames: Vec<Vec<f32>> = (0..7)
        .map(|f| {
            (0..window)
                .map(|i| ((i * 13 + f * 977) % 251) as f32 / 251.0)
                .collect()
        })
        .collect();

    let mut g = Graph::new();
    let mut src = AppSrc::new();
    src.set_caps(Caps::tensor(DType::F32, [3, 128, 1], 0.0));
    let handle = src.handle();
    let src_id = g.add_element("in", Box::new(src)).unwrap();
    let filter = g.add("tensor_filter").unwrap();
    g.set_property(filter, "framework", "xla").unwrap();
    g.set_property(filter, "model", "ars_a_opt").unwrap();
    g.set_property(filter, "batch", "4").unwrap();
    g.set_property(filter, "latency-budget", "50").unwrap();
    let mut sink = AppSink::new();
    let rx = sink.take_receiver().unwrap();
    let sink_id = g.add_element("out", Box::new(sink)).unwrap();
    g.link(src_id, filter).unwrap();
    g.link(filter, sink_id).unwrap();

    let mut p = Pipeline::new(g);
    let running = p.play().unwrap();
    for (i, frame) in frames.iter().enumerate() {
        handle
            .push(Buffer::from_f32(i as u64 * 10, frame))
            .unwrap();
    }
    handle.end();

    let single = SingleShot::open("ars_a_opt").unwrap();
    let mut got = Vec::new();
    while let Ok(buf) = rx.recv() {
        got.push(buf);
    }
    running.wait().unwrap();

    assert_eq!(got.len(), 7, "every frame must be de-batched back out");
    for (i, buf) in got.iter().enumerate() {
        assert_eq!(buf.pts_ns, i as u64 * 10, "timestamps must survive batching");
        let batched = buf.chunk().to_f32_vec().unwrap();
        let reference = single.invoke(&[&frames[i]]).unwrap();
        assert_eq!(
            batched, reference[0],
            "frame {i}: batched output differs from unbatched"
        );
    }
}

#[test]
fn branches_share_one_pooled_model_instance() {
    use nnstreamer::runtime::ModelPool;
    use std::sync::Arc;

    // two pipeline branches bind the same artifact...
    let report = run(
        "sensorsrc kind=mic window=64 channels=16 rate=1000 num-buffers=4 ! tee name=t \
         t. ! queue ! tensor_filter framework=xla model=ars_c_opt ! fakesink name=o1 \
         t. ! queue ! tensor_filter framework=xla model=ars_c_opt ! fakesink name=o2",
    );
    assert_eq!(report.element("o1").unwrap().buffers_in(), 4);
    assert_eq!(report.element("o2").unwrap().buffers_in(), 4);

    // ...and the pool stats prove they shared one loaded instance
    let pool = ModelPool::global().unwrap();
    assert_eq!(
        pool.loads("ars_c_opt"),
        1,
        "two branches must not load the artifact twice"
    );
    assert!(
        pool.acquires("ars_c_opt") >= 2,
        "both branches lease through the pool"
    );
    let a = pool.acquire("ars_c_opt").unwrap();
    let b = pool.acquire("ars_c_opt").unwrap();
    assert!(
        Arc::ptr_eq(a.model(), b.model()),
        "leases must point at the same Model"
    );
}
