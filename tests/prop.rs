//! Property-based tests on framework invariants.
//!
//! The offline vendor set has no proptest, so these use a small SplitMix64
//! case generator (`cases` below) — same methodology: hundreds of random
//! cases per property, failures print the seed for reproduction.

use nnstreamer::elements::decoder::{decode_boxes, encode_boxes, DetBox};
use nnstreamer::elements::sync::{SyncPolicy, Synchronizer};
use nnstreamer::error::{Error, Fault};
use nnstreamer::net::wire::{decode, encode, write_msg, Msg};
use nnstreamer::pipeline::{PushOutcome, Qos, StreamRegistry};
use nnstreamer::tensor::{
    AudioInfo, Buffer, Caps, Chunk, DType, Dims, TensorInfo, VideoFormat, VideoInfo,
};
use nnstreamer::video::pattern::splitmix64;

/// Deterministic pseudo-random case driver.
struct Gen(u64);

impl Gen {
    fn new(seed: u64) -> Self {
        Gen(seed)
    }
    fn next(&mut self) -> u64 {
        self.0 = splitmix64(self.0);
        self.0
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo).max(1)
    }
    fn f32(&mut self) -> f32 {
        (self.next() % 10_000) as f32 / 10_000.0
    }
}

fn cases(n: u64, mut f: impl FnMut(&mut Gen)) {
    for seed in 0..n {
        let mut g = Gen::new(seed.wrapping_mul(0x9e37_79b9));
        f(&mut g);
    }
}

#[test]
fn prop_dims_equivalence_reflexive_and_padded() {
    cases(300, |g| {
        let rank = g.range(1, 7) as usize;
        let dims: Vec<usize> = (0..rank).map(|_| g.range(1, 64) as usize).collect();
        let d = Dims::new(&dims);
        // reflexive
        assert!(d.equivalent(&d));
        // appending trailing 1s preserves equivalence
        let mut padded = dims.clone();
        while padded.len() < 8 {
            padded.push(1);
        }
        let p = Dims::new(&padded);
        assert!(d.equivalent(&p), "{d} !~ {p}");
        assert_eq!(d.num_elements(), p.num_elements());
        // changing any non-1 dim breaks equivalence
        for i in 0..rank {
            if dims[i] > 1 {
                let mut other = dims.clone();
                other[i] += 1;
                assert!(!d.equivalent(&Dims::new(&other)));
            }
        }
    });
}

#[test]
fn prop_dims_parse_roundtrip() {
    cases(300, |g| {
        let rank = g.range(1, 8) as usize;
        let dims: Vec<usize> = (0..rank).map(|_| g.range(1, 4096) as usize).collect();
        let d = Dims::new(&dims);
        let parsed = Dims::parse(&d.to_string()).unwrap();
        assert_eq!(d, parsed);
    });
}

#[test]
fn prop_caps_intersection_symmetric_compat() {
    cases(200, |g| {
        let dt = [DType::U8, DType::F32, DType::I16][g.range(0, 3) as usize];
        let dims: Vec<usize> = (0..g.range(1, 4)).map(|_| g.range(1, 32) as usize).collect();
        let fps = [0.0, 15.0, 30.0][g.range(0, 3) as usize];
        let a = Caps::tensor(dt, dims.clone(), fps);
        let b = Caps::tensor(dt, dims, [0.0, 15.0, 30.0][g.range(0, 3) as usize]);
        // compatibility is symmetric
        assert_eq!(a.compatible(&b), b.compatible(&a));
        if a.compatible(&b) {
            // intersection succeeds both ways and stays compatible
            let i1 = a.intersect(&b).unwrap();
            let i2 = b.intersect(&a).unwrap();
            assert!(i1.compatible(&a) && i1.compatible(&b));
            assert!(i2.compatible(&a) && i2.compatible(&b));
        }
    });
}

#[test]
fn prop_caps_display_parse_roundtrip() {
    cases(200, |g| {
        let dt = [DType::U8, DType::F32, DType::I32, DType::F64][g.range(0, 4) as usize];
        let dims: Vec<usize> = (0..g.range(1, 5)).map(|_| g.range(1, 100) as usize).collect();
        let caps = Caps::tensor(dt, dims, g.range(0, 60) as f64);
        let parsed = Caps::parse(&caps.to_string()).unwrap();
        assert!(caps.compatible(&parsed), "{caps} vs {parsed}");
    });
}

#[test]
fn prop_boxes_encode_decode_roundtrip() {
    cases(200, |g| {
        let n = g.range(0, 20) as usize;
        let boxes: Vec<DetBox> = (0..n)
            .map(|_| DetBox {
                x: g.f32(),
                y: g.f32(),
                w: g.f32(),
                h: g.f32(),
                score: g.f32(),
                class: g.range(0, 30) as usize,
            })
            .collect();
        let decoded = decode_boxes(&encode_boxes(&boxes)).unwrap();
        assert_eq!(decoded, boxes);
    });
}

#[test]
fn prop_nms_output_is_subset_and_sorted() {
    cases(200, |g| {
        let n = g.range(0, 30) as usize;
        let boxes: Vec<DetBox> = (0..n)
            .map(|_| DetBox {
                x: g.f32(),
                y: g.f32(),
                w: 0.05 + g.f32() * 0.3,
                h: 0.05 + g.f32() * 0.3,
                score: g.f32(),
                class: 0,
            })
            .collect();
        let thr = 0.3 + g.f32() * 0.5;
        let kept = nnstreamer::apps::postproc::nms(boxes.clone(), thr);
        assert!(kept.len() <= boxes.len());
        // sorted by score descending
        assert!(kept.windows(2).all(|w| w[0].score >= w[1].score));
        // no two kept boxes overlap above the threshold
        for i in 0..kept.len() {
            for j in i + 1..kept.len() {
                assert!(
                    nnstreamer::apps::postproc::iou(&kept[i], &kept[j]) <= thr + 1e-6
                );
            }
        }
        // every kept box was an input
        for k in &kept {
            assert!(boxes.iter().any(|b| b == k));
        }
    });
}

#[test]
fn prop_sync_slowest_never_reorders() {
    cases(100, |g| {
        let pads = g.range(2, 5) as usize;
        let mut s = Synchronizer::new(SyncPolicy::Slowest, pads);
        let mut emitted_pts = Vec::new();
        let mut clocks = vec![0u64; pads];
        for _ in 0..40 {
            let pad = g.range(0, pads as u64) as usize;
            clocks[pad] += g.range(1, 50);
            s.push(pad, Buffer::from_f32(clocks[pad], &[0.0]));
            while let Some(set) = s.try_collect() {
                assert_eq!(set.len(), pads);
                let latest = set.iter().map(|b| b.pts_ns).max().unwrap();
                emitted_pts.push(latest);
            }
        }
        // bundle timestamps (latest rule) must be non-decreasing
        assert!(
            emitted_pts.windows(2).all(|w| w[0] <= w[1]),
            "{emitted_pts:?}"
        );
    });
}

#[test]
fn prop_sync_fastest_emits_for_every_fresh_frame_once_warm() {
    cases(100, |g| {
        let pads = g.range(2, 4) as usize;
        let mut s = Synchronizer::new(SyncPolicy::Fastest, pads);
        // warm up: one frame on every pad
        for p in 0..pads {
            s.push(p, Buffer::from_f32(1, &[0.0]));
        }
        let mut collected = 0;
        while s.try_collect().is_some() {
            collected += 1;
        }
        assert!(collected >= 1);
        // after warm-up, each fresh frame yields exactly one set
        for i in 0..20 {
            let pad = g.range(0, pads as u64) as usize;
            s.push(pad, Buffer::from_f32(10 + i, &[0.0]));
            let mut sets = 0;
            while s.try_collect().is_some() {
                sets += 1;
            }
            assert_eq!(sets, 1);
        }
    });
}

#[test]
fn prop_transform_arithmetic_invertible() {
    use nnstreamer::element::Registry;
    cases(60, |g| {
        let scale = 1.0 + g.range(1, 100) as f64;
        let desc_fwd = format!("add:-{0},div:{1}", g.range(0, 200), scale);
        let desc_bwd = format!("mul:{1},add:{0}", desc_fwd[4..].split(',').next().unwrap().trim_start_matches('-'), scale);
        let _ = (desc_fwd, desc_bwd, Registry::exists("tensor_transform"));
        // full inversion is covered in unit tests; here assert mul/div pair
        let vals: Vec<f32> = (0..16).map(|_| g.f32() * 100.0).collect();
        let mut t = vals.clone();
        t.iter_mut().for_each(|v| *v /= scale as f32);
        t.iter_mut().for_each(|v| *v *= scale as f32);
        for (a, b) in vals.iter().zip(&t) {
            assert!((a - b).abs() < 1e-3);
        }
    });
}

/// Reference model of one subscriber endpoint under a QoS mode.
struct SubModel {
    qos: Qos,
    cap: usize,
    queue: std::collections::VecDeque<u64>,
    dropped_handle: bool,
    pushed: u64,
    delivered: u64,
    leaky: u64,
    latest: u64,
    max_evicted: u64,
}

impl SubModel {
    fn new(qos: Qos, cap: usize) -> Self {
        SubModel {
            qos,
            cap,
            queue: std::collections::VecDeque::new(),
            dropped_handle: false,
            pushed: 0,
            delivered: 0,
            leaky: 0,
            latest: 0,
            max_evicted: 0,
        }
    }

    /// Model one topic delivery (publisher qos = Blocking, so the
    /// subscriber's own mode decides).
    fn offer(&mut self, pts: u64) {
        self.pushed += 1;
        match self.qos {
            Qos::Blocking => {
                assert!(
                    self.queue.len() < self.cap,
                    "a full blocking subscriber must have gated the publisher"
                );
                self.queue.push_back(pts);
            }
            Qos::Leaky => {
                if self.queue.len() < self.cap {
                    self.queue.push_back(pts);
                } else {
                    self.leaky += 1; // arriving frame shed
                }
            }
            Qos::LatestOnly => {
                if self.queue.len() == self.cap {
                    let ev = self.queue.pop_front().unwrap();
                    self.max_evicted = self.max_evicted.max(ev);
                    self.latest += 1; // oldest frame evicted
                }
                self.queue.push_back(pts);
            }
        }
    }
}

/// Satellite 2 — conservation under random push/pull/drop schedules
/// across all three QoS modes: every buffer a subscriber was offered is
/// delivered, typed-dropped, or still in flight; nothing is lost or
/// double-counted, per subscriber and in the topic aggregate.
#[test]
fn prop_topic_qos_conservation_all_modes() {
    cases(120, |g| {
        let reg = StreamRegistry::new();
        let topic = "prop/qos";
        let n_subs = g.range(1, 4) as usize;
        let mut subs = Vec::new();
        let mut models: Vec<SubModel> = Vec::new();
        for _ in 0..n_subs {
            let qos = [Qos::Blocking, Qos::Leaky, Qos::LatestOnly]
                [g.range(0, 3) as usize];
            let cap = g.range(1, 6) as usize;
            subs.push(Some(reg.subscribe_with(topic, cap, qos)));
            models.push(SubModel::new(qos, cap));
        }
        let publisher = reg.publish(topic);
        let mut next_pts = 1u64;
        for _ in 0..g.range(30, 120) {
            match g.range(0, 10) {
                0..=4 => match publisher.try_push(Buffer::from_f32(next_pts, &[0.5])) {
                    PushOutcome::Delivered => {
                        for m in models.iter_mut().filter(|m| !m.dropped_handle) {
                            m.offer(next_pts);
                        }
                        next_pts += 1;
                    }
                    PushOutcome::Full => {
                        assert!(
                            models.iter().any(|m| !m.dropped_handle
                                && m.qos == Qos::Blocking
                                && m.queue.len() == m.cap),
                            "Full only when a blocking subscriber is full"
                        );
                    }
                    PushOutcome::NoSubscribers => {
                        assert!(models.iter().all(|m| m.dropped_handle));
                    }
                    PushOutcome::Closed => unreachable!("publisher still open"),
                },
                5..=8 => {
                    let i = g.range(0, n_subs as u64) as usize;
                    if let Some(s) = &subs[i] {
                        let m = &mut models[i];
                        match s.try_recv() {
                            Ok(b) => {
                                let want =
                                    m.queue.pop_front().expect("model had an item");
                                assert_eq!(b.pts_ns, want, "in-order delivery");
                                m.delivered += 1;
                                if m.qos == Qos::LatestOnly {
                                    assert!(
                                        b.pts_ns > m.max_evicted,
                                        "latest-only delivered {} although {} was \
                                         already evicted as stale",
                                        b.pts_ns,
                                        m.max_evicted
                                    );
                                }
                            }
                            Err(_) => assert!(m.queue.is_empty()),
                        }
                    }
                }
                _ => {
                    // drop a subscriber handle: queued buffers become
                    // typed `closed` drops, counters fold into retired
                    let i = g.range(0, n_subs as u64) as usize;
                    subs[i] = None;
                    models[i].dropped_handle = true;
                }
            }
        }
        // per-subscriber counters match the model exactly (live handles)
        for (s, m) in subs.iter().zip(&models) {
            if let Some(s) = s {
                let c = s.counters();
                assert_eq!(c.pushed, m.pushed);
                assert_eq!(c.delivered, m.delivered);
                assert_eq!(c.dropped.qos_leaky, m.leaky);
                assert_eq!(c.dropped.qos_latest, m.latest);
                assert_eq!(c.in_flight, m.queue.len() as u64);
                // conservation per subscriber
                assert_eq!(
                    c.pushed,
                    c.delivered + c.dropped.subscriber_total() + c.in_flight
                );
            }
        }
        // topic-level conservation, including retired subscribers and
        // no-subscriber drops
        let t = reg
            .snapshot()
            .into_iter()
            .find(|t| t.name == topic)
            .unwrap();
        assert_eq!(t.pushed, t.delivered + t.dropped + t.in_flight);
        assert_eq!(t.dropped, t.drops.total());
        assert!(t.delivered <= t.pushed);
    });
}

/// Satellite 2 — latest-only freshness: a latest-only subscriber never
/// receives a buffer older than one that was already evicted for it
/// (staleness monotonicity), under arbitrary push/pull interleavings.
#[test]
fn prop_latest_only_never_delivers_stale() {
    cases(150, |g| {
        let reg = StreamRegistry::new();
        let cap = g.range(1, 5) as usize;
        let sub = reg.subscribe_with("prop/latest", cap, Qos::LatestOnly);
        let publisher = reg.publish("prop/latest");
        let mut m = SubModel::new(Qos::LatestOnly, cap);
        let mut pts = 1u64;
        for _ in 0..g.range(20, 100) {
            if g.range(0, 3) < 2 {
                assert_eq!(
                    publisher.try_push(Buffer::from_f32(pts, &[1.0])),
                    PushOutcome::Delivered,
                    "latest-only never gates the publisher"
                );
                m.offer(pts);
                pts += 1;
            } else if let Ok(b) = sub.try_recv() {
                let want = m.queue.pop_front().unwrap();
                assert_eq!(b.pts_ns, want);
                assert!(b.pts_ns > m.max_evicted);
            }
        }
        let c = sub.counters();
        assert_eq!(c.dropped.qos_latest, m.latest);
        assert_eq!(c.pushed, c.delivered + c.dropped.subscriber_total() + c.in_flight);
    });
}

#[test]
fn prop_buffer_bundle_unbundle_preserves_payloads() {
    cases(150, |g| {
        let n = g.range(1, 16) as usize;
        let parts: Vec<Buffer> = (0..n)
            .map(|i| {
                let len = g.range(1, 64) as usize;
                let vals: Vec<f32> = (0..len).map(|_| g.f32()).collect();
                Buffer::from_f32(i as u64 * 10, &vals)
            })
            .collect();
        let payloads: Vec<Vec<f32>> = parts
            .iter()
            .map(|b| b.chunk().to_f32_vec().unwrap())
            .collect();
        let bundled = Buffer::bundle(parts).unwrap();
        assert_eq!(bundled.chunks.len(), n);
        let back = bundled.unbundle();
        for (b, p) in back.iter().zip(&payloads) {
            assert_eq!(&b.chunk().to_f32_vec().unwrap(), p);
        }
    });
}

// -- wire codec (net/wire.rs): roundtrip + corruption safety ----------------

fn rand_str(g: &mut Gen, max: u64) -> String {
    (0..g.range(0, max))
        .map(|_| (b'a' + (g.next() % 26) as u8) as char)
        .collect()
}

fn rand_caps(g: &mut Gen) -> Caps {
    let dtypes = [DType::U8, DType::I16, DType::I32, DType::F32, DType::F64];
    let mut info = |g: &mut Gen| {
        let rank = g.range(1, 5) as usize;
        let dims: Vec<usize> = (0..rank).map(|_| g.range(1, 256) as usize).collect();
        TensorInfo::new(dtypes[g.range(0, 5) as usize], Dims::new(&dims))
    };
    match g.range(0, 7) {
        0 => Caps::Any,
        1 => Caps::Text,
        2 => Caps::FlatBuf,
        3 => Caps::Video(VideoInfo {
            format: [
                VideoFormat::Rgb,
                VideoFormat::Bgr,
                VideoFormat::Gray8,
                VideoFormat::Nv12,
            ][g.range(0, 4) as usize],
            width: g.range(1, 4096) as usize,
            height: g.range(1, 4096) as usize,
            fps_millis: g.range(0, 240_000),
        }),
        4 => Caps::Audio(AudioInfo {
            rate: g.range(1, 192_000) as usize,
            channels: g.range(1, 9) as usize,
            samples_per_buffer: g.range(1, 4096) as usize,
        }),
        5 => Caps::Tensor {
            info: info(g),
            fps_millis: g.range(0, 240_000),
        },
        _ => Caps::Tensors {
            infos: (0..g.range(1, 5)).map(|_| info(g)).collect(),
            fps_millis: g.range(0, 240_000),
        },
    }
}

fn rand_buffer(g: &mut Gen) -> Buffer {
    let n = g.range(1, 4) as usize;
    let chunks = (0..n)
        .map(|_| {
            let len = g.range(0, 2048) as usize;
            Chunk::from_vec((0..len).map(|_| g.next() as u8).collect())
        })
        .collect();
    let mut b = Buffer::new(g.next(), chunks);
    b.duration_ns = g.next();
    b.seq = g.next();
    b
}

fn rand_msg(g: &mut Gen) -> Msg {
    match g.range(0, 10) {
        0 => Msg::Hello {
            topic: rand_str(g, 40),
            capacity: g.range(1, 1 << 16) as u32,
            credits: g.range(0, 1 << 16) as u32,
            qos: [Qos::Blocking, Qos::Leaky, Qos::LatestOnly][g.range(0, 3) as usize],
        },
        1 => Msg::Caps(rand_caps(g)),
        2 => Msg::Buffer(rand_buffer(g)),
        3 => Msg::Eos,
        4 => Msg::Fault(Fault {
            element: rand_str(g, 30),
            message: rand_str(g, 120),
            panicked: g.range(0, 2) == 1,
        }),
        5 => Msg::Credit(g.next() as u32),
        6 => Msg::Detach,
        7 => Msg::RegPut {
            topic: rand_str(g, 40),
            addr: rand_str(g, 40),
        },
        8 => Msg::RegGet {
            topic: rand_str(g, 40),
        },
        _ => Msg::RegAddr {
            addr: (g.range(0, 2) == 1).then(|| rand_str(g, 40)),
        },
    }
}

/// Satellite 2 — every frame type roundtrips bit-identically, and the
/// streaming writer emits byte-for-byte what the buffered encoder does.
#[test]
fn prop_wire_roundtrip_bit_identical() {
    cases(300, |g| {
        let msg = rand_msg(g);
        let bytes = encode(&msg).unwrap();
        let mut streamed = Vec::new();
        write_msg(&mut streamed, &msg).unwrap();
        assert_eq!(streamed, bytes, "write_msg and encode agree");
        assert_eq!(decode(&bytes).unwrap(), msg, "decode inverts encode");
    });
}

/// Satellite 2 — a frame cut at any prefix is a typed [`Error::Frame`],
/// never a panic and never a successful decode.
#[test]
fn prop_wire_truncation_is_typed_error() {
    cases(150, |g| {
        let msg = rand_msg(g);
        let bytes = encode(&msg).unwrap();
        let cut = g.range(0, bytes.len() as u64) as usize;
        match decode(&bytes[..cut]) {
            Err(Error::Frame(_)) => {}
            Ok(m) => panic!("decoded a frame truncated at {cut}: {m:?}"),
            Err(e) => panic!("truncation at {cut} must be Error::Frame, got {e}"),
        }
    });
}

/// Satellite 2 — single-bit corruption anywhere in a frame is detected
/// as a typed error. The lone exception is the header's type byte,
/// where a flip can rename one self-consistent frame into another;
/// payload corruption is always caught because a one-byte change always
/// changes the FNV-1a digest (each absorption step is a bijection).
#[test]
fn prop_wire_corruption_is_typed_error() {
    cases(300, |g| {
        let msg = rand_msg(g);
        let mut bytes = encode(&msg).unwrap();
        let i = g.range(0, bytes.len() as u64) as usize;
        bytes[i] ^= 1u8 << (g.next() % 8);
        match decode(&bytes) {
            Err(Error::Frame(_)) => {}
            Ok(_) => assert_eq!(i, 5, "only a type-byte flip may still decode"),
            Err(e) => panic!("corruption at byte {i} must be Error::Frame, got {e}"),
        }
    });
}
