//! Property-based tests on framework invariants.
//!
//! The offline vendor set has no proptest, so these use a small SplitMix64
//! case generator (`cases` below) — same methodology: hundreds of random
//! cases per property, failures print the seed for reproduction.

use nnstreamer::elements::decoder::{decode_boxes, encode_boxes, DetBox};
use nnstreamer::elements::sync::{SyncPolicy, Synchronizer};
use nnstreamer::tensor::{Buffer, Caps, DType, Dims};
use nnstreamer::video::pattern::splitmix64;

/// Deterministic pseudo-random case driver.
struct Gen(u64);

impl Gen {
    fn new(seed: u64) -> Self {
        Gen(seed)
    }
    fn next(&mut self) -> u64 {
        self.0 = splitmix64(self.0);
        self.0
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo).max(1)
    }
    fn f32(&mut self) -> f32 {
        (self.next() % 10_000) as f32 / 10_000.0
    }
}

fn cases(n: u64, mut f: impl FnMut(&mut Gen)) {
    for seed in 0..n {
        let mut g = Gen::new(seed.wrapping_mul(0x9e37_79b9));
        f(&mut g);
    }
}

#[test]
fn prop_dims_equivalence_reflexive_and_padded() {
    cases(300, |g| {
        let rank = g.range(1, 7) as usize;
        let dims: Vec<usize> = (0..rank).map(|_| g.range(1, 64) as usize).collect();
        let d = Dims::new(&dims);
        // reflexive
        assert!(d.equivalent(&d));
        // appending trailing 1s preserves equivalence
        let mut padded = dims.clone();
        while padded.len() < 8 {
            padded.push(1);
        }
        let p = Dims::new(&padded);
        assert!(d.equivalent(&p), "{d} !~ {p}");
        assert_eq!(d.num_elements(), p.num_elements());
        // changing any non-1 dim breaks equivalence
        for i in 0..rank {
            if dims[i] > 1 {
                let mut other = dims.clone();
                other[i] += 1;
                assert!(!d.equivalent(&Dims::new(&other)));
            }
        }
    });
}

#[test]
fn prop_dims_parse_roundtrip() {
    cases(300, |g| {
        let rank = g.range(1, 8) as usize;
        let dims: Vec<usize> = (0..rank).map(|_| g.range(1, 4096) as usize).collect();
        let d = Dims::new(&dims);
        let parsed = Dims::parse(&d.to_string()).unwrap();
        assert_eq!(d, parsed);
    });
}

#[test]
fn prop_caps_intersection_symmetric_compat() {
    cases(200, |g| {
        let dt = [DType::U8, DType::F32, DType::I16][g.range(0, 3) as usize];
        let dims: Vec<usize> = (0..g.range(1, 4)).map(|_| g.range(1, 32) as usize).collect();
        let fps = [0.0, 15.0, 30.0][g.range(0, 3) as usize];
        let a = Caps::tensor(dt, dims.clone(), fps);
        let b = Caps::tensor(dt, dims, [0.0, 15.0, 30.0][g.range(0, 3) as usize]);
        // compatibility is symmetric
        assert_eq!(a.compatible(&b), b.compatible(&a));
        if a.compatible(&b) {
            // intersection succeeds both ways and stays compatible
            let i1 = a.intersect(&b).unwrap();
            let i2 = b.intersect(&a).unwrap();
            assert!(i1.compatible(&a) && i1.compatible(&b));
            assert!(i2.compatible(&a) && i2.compatible(&b));
        }
    });
}

#[test]
fn prop_caps_display_parse_roundtrip() {
    cases(200, |g| {
        let dt = [DType::U8, DType::F32, DType::I32, DType::F64][g.range(0, 4) as usize];
        let dims: Vec<usize> = (0..g.range(1, 5)).map(|_| g.range(1, 100) as usize).collect();
        let caps = Caps::tensor(dt, dims, g.range(0, 60) as f64);
        let parsed = Caps::parse(&caps.to_string()).unwrap();
        assert!(caps.compatible(&parsed), "{caps} vs {parsed}");
    });
}

#[test]
fn prop_boxes_encode_decode_roundtrip() {
    cases(200, |g| {
        let n = g.range(0, 20) as usize;
        let boxes: Vec<DetBox> = (0..n)
            .map(|_| DetBox {
                x: g.f32(),
                y: g.f32(),
                w: g.f32(),
                h: g.f32(),
                score: g.f32(),
                class: g.range(0, 30) as usize,
            })
            .collect();
        let decoded = decode_boxes(&encode_boxes(&boxes)).unwrap();
        assert_eq!(decoded, boxes);
    });
}

#[test]
fn prop_nms_output_is_subset_and_sorted() {
    cases(200, |g| {
        let n = g.range(0, 30) as usize;
        let boxes: Vec<DetBox> = (0..n)
            .map(|_| DetBox {
                x: g.f32(),
                y: g.f32(),
                w: 0.05 + g.f32() * 0.3,
                h: 0.05 + g.f32() * 0.3,
                score: g.f32(),
                class: 0,
            })
            .collect();
        let thr = 0.3 + g.f32() * 0.5;
        let kept = nnstreamer::apps::postproc::nms(boxes.clone(), thr);
        assert!(kept.len() <= boxes.len());
        // sorted by score descending
        assert!(kept.windows(2).all(|w| w[0].score >= w[1].score));
        // no two kept boxes overlap above the threshold
        for i in 0..kept.len() {
            for j in i + 1..kept.len() {
                assert!(
                    nnstreamer::apps::postproc::iou(&kept[i], &kept[j]) <= thr + 1e-6
                );
            }
        }
        // every kept box was an input
        for k in &kept {
            assert!(boxes.iter().any(|b| b == k));
        }
    });
}

#[test]
fn prop_sync_slowest_never_reorders() {
    cases(100, |g| {
        let pads = g.range(2, 5) as usize;
        let mut s = Synchronizer::new(SyncPolicy::Slowest, pads);
        let mut emitted_pts = Vec::new();
        let mut clocks = vec![0u64; pads];
        for _ in 0..40 {
            let pad = g.range(0, pads as u64) as usize;
            clocks[pad] += g.range(1, 50);
            s.push(pad, Buffer::from_f32(clocks[pad], &[0.0]));
            while let Some(set) = s.try_collect() {
                assert_eq!(set.len(), pads);
                let latest = set.iter().map(|b| b.pts_ns).max().unwrap();
                emitted_pts.push(latest);
            }
        }
        // bundle timestamps (latest rule) must be non-decreasing
        assert!(
            emitted_pts.windows(2).all(|w| w[0] <= w[1]),
            "{emitted_pts:?}"
        );
    });
}

#[test]
fn prop_sync_fastest_emits_for_every_fresh_frame_once_warm() {
    cases(100, |g| {
        let pads = g.range(2, 4) as usize;
        let mut s = Synchronizer::new(SyncPolicy::Fastest, pads);
        // warm up: one frame on every pad
        for p in 0..pads {
            s.push(p, Buffer::from_f32(1, &[0.0]));
        }
        let mut collected = 0;
        while s.try_collect().is_some() {
            collected += 1;
        }
        assert!(collected >= 1);
        // after warm-up, each fresh frame yields exactly one set
        for i in 0..20 {
            let pad = g.range(0, pads as u64) as usize;
            s.push(pad, Buffer::from_f32(10 + i, &[0.0]));
            let mut sets = 0;
            while s.try_collect().is_some() {
                sets += 1;
            }
            assert_eq!(sets, 1);
        }
    });
}

#[test]
fn prop_transform_arithmetic_invertible() {
    use nnstreamer::element::Registry;
    cases(60, |g| {
        let scale = 1.0 + g.range(1, 100) as f64;
        let desc_fwd = format!("add:-{0},div:{1}", g.range(0, 200), scale);
        let desc_bwd = format!("mul:{1},add:{0}", desc_fwd[4..].split(',').next().unwrap().trim_start_matches('-'), scale);
        let _ = (desc_fwd, desc_bwd, Registry::exists("tensor_transform"));
        // full inversion is covered in unit tests; here assert mul/div pair
        let vals: Vec<f32> = (0..16).map(|_| g.f32() * 100.0).collect();
        let mut t = vals.clone();
        t.iter_mut().for_each(|v| *v /= scale as f32);
        t.iter_mut().for_each(|v| *v *= scale as f32);
        for (a, b) in vals.iter().zip(&t) {
            assert!((a - b).abs() < 1e-3);
        }
    });
}

#[test]
fn prop_buffer_bundle_unbundle_preserves_payloads() {
    cases(150, |g| {
        let n = g.range(1, 16) as usize;
        let parts: Vec<Buffer> = (0..n)
            .map(|i| {
                let len = g.range(1, 64) as usize;
                let vals: Vec<f32> = (0..len).map(|_| g.f32()).collect();
                Buffer::from_f32(i as u64 * 10, &vals)
            })
            .collect();
        let payloads: Vec<Vec<f32>> = parts
            .iter()
            .map(|b| b.chunk().to_f32_vec().unwrap())
            .collect();
        let bundled = Buffer::bundle(parts).unwrap();
        assert_eq!(bundled.chunks.len(), n);
        let back = bundled.unbundle();
        for (b, p) in back.iter().zip(&payloads) {
            assert_eq!(&b.chunk().to_f32_vec().unwrap(), p);
        }
    });
}
