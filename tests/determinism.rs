//! Worker-count determinism: the pooled executor must produce sink
//! output **bit-identical** to any other worker count (including the
//! single-worker configuration, which is behaviorally the seed
//! thread-per-element scheduler serialized) on deterministic pipelines.
//!
//! The fixture is the deterministic E4 chain (linear, non-live, blocking
//! links, AOT model on CPU) — the same chain `tests/api_roundtrip.rs`
//! uses for parser↔builder bit-identity — run on dedicated hubs with
//! 1, 2 and 8 workers.

use nnstreamer::apps::e4;
use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::pipeline::{Pipeline, PipelineHub, Priority, Qos};

/// Collect (pts, payload bytes) from a finished tensor_sink.
fn collect(p: &mut Pipeline, name: &str) -> Vec<(u64, Vec<u8>)> {
    let el = p.finished_element(name).expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| (b.pts_ns, b.chunk().as_bytes_unaccounted().to_vec()))
        .collect()
}

fn e4_launch() -> String {
    let cfg = e4::E4Config {
        src_w: 160,
        src_h: 120,
        num_frames: 6,
    };
    e4::launch_description(&cfg, "opt").replace("fakesink name=out", "tensor_sink name=out")
}

/// Run the deterministic chain on a dedicated pool of `workers`.
fn run_with_workers(workers: usize) -> Vec<(u64, Vec<u8>)> {
    let hub = PipelineHub::with_workers(workers);
    let p = Pipeline::parse(&e4_launch()).unwrap();
    hub.launch("e4", p).unwrap();
    let mut joined = hub.join_all();
    assert_eq!(joined.len(), 1);
    let j = joined.pop().unwrap();
    j.report.expect("pipeline succeeded");
    let mut pipeline = j.pipeline;
    collect(&mut pipeline, "out")
}

#[test]
fn e4_sink_output_bit_identical_across_worker_counts() {
    let w1 = run_with_workers(1);
    assert_eq!(w1.len(), 6, "all frames reach the sink");
    for workers in [2, 8] {
        let wn = run_with_workers(workers);
        assert_eq!(
            w1, wn,
            "sink output must be bit-identical between 1 and {workers} workers"
        );
    }
}

/// The same chain through `Pipeline::run_on` (no hub): executor pinning
/// at the pipeline API level agrees with the hub path bitwise.
#[test]
fn run_on_agrees_with_hub_path() {
    let via_hub = run_with_workers(2);
    let exec = nnstreamer::pipeline::Executor::new(2);
    let mut p = Pipeline::parse(&e4_launch()).unwrap();
    p.run_on(&exec, Priority::Normal).unwrap();
    let direct = collect(&mut p, "out");
    exec.shutdown();
    assert_eq!(via_hub, direct);
}

/// The deterministic chain ending in a `qos=blocking` topic publish
/// instead of a local sink, collected through a hub subscriber.
fn run_topic_with_workers(workers: usize) -> Vec<(u64, Vec<u8>)> {
    let topic = format!("det/e4-w{workers}");
    let hub = PipelineHub::with_workers(workers);
    // subscribe before launch so nothing is published unobserved
    let sub = hub.subscribe_with_qos(&topic, Qos::Blocking);
    let desc = e4_launch().replace(
        "tensor_sink name=out",
        &format!("tensor_query_serversink topic={topic} qos=blocking"),
    );
    let mut p = Pipeline::parse(&desc).unwrap();
    // deadlines disabled (the default, asserted explicitly): blocking
    // QoS with no shedding must stay on the exact pre-QoS path
    p.set_deadline(std::time::Duration::ZERO);
    hub.launch("e4-topic", p).unwrap();
    let mut out = Vec::new();
    while let Ok(b) = sub.recv() {
        out.push((b.pts_ns, b.chunk().as_bytes_unaccounted().to_vec()));
    }
    for j in hub.join_all() {
        j.report.expect("pipeline succeeded");
    }
    out
}

/// QoS hardening must not cost determinism: the e4 bit-identity matrix
/// also holds when the chain publishes through a `qos=blocking` topic
/// (with deadlines disabled), at every worker count, against the
/// local-sink reference.
#[test]
fn e4_topic_route_bit_identical_across_worker_counts_under_blocking_qos() {
    let reference = run_with_workers(1);
    for workers in [1, 2, 8] {
        let via_topic = run_topic_with_workers(workers);
        assert_eq!(via_topic.len(), 6, "blocking qos delivers every frame");
        assert_eq!(
            via_topic, reference,
            "topic route must be bit-identical to the local sink at {workers} workers"
        );
    }
}

/// Run the e4 chain live-paced (`is-live=true`, source parks on the
/// timer wheel between frames) with an explicit filter dispatch mode.
fn run_live(workers: usize, dispatch: &str) -> Vec<(u64, Vec<u8>)> {
    let desc = e4_launch()
        .replace("is-live=false", "is-live=true")
        .replace(
            "accelerator=cpu",
            &format!("accelerator=cpu dispatch={dispatch}"),
        );
    let hub = PipelineHub::with_workers(workers);
    let p = Pipeline::parse(&desc).unwrap();
    hub.launch("e4-live", p).unwrap();
    let mut joined = hub.join_all();
    let j = joined.pop().unwrap();
    let report = j.report.expect("live pipeline succeeded");
    // Live pacing rides the timer wheel, not a sleeping worker: the
    // run must record timer parks whenever an executor waker exists
    // (both dispatch modes — pacing is a source property).
    assert!(
        report.sched.parks_timer > 0,
        "live source never parked on the timer wheel ({workers} workers, dispatch={dispatch}): {:?}",
        report.sched
    );
    // Every wheel entry comes from exactly one park and fires at most
    // once — the counters can never cross.
    assert!(
        report.sched.timer_fires <= report.sched.parks_timer,
        "{:?}",
        report.sched
    );
    let mut pipeline = j.pipeline;
    collect(&mut pipeline, "out")
}

/// Timer-wheel pacing and the async device lane must not cost
/// determinism: the live-paced e4 chain is bit-identical to the
/// non-live reference across worker counts × dispatch modes.
#[test]
fn live_paced_e4_bit_identical_across_workers_and_dispatch() {
    let reference = run_with_workers(1);
    for workers in [1, 8] {
        for dispatch in ["async", "block"] {
            let live = run_live(workers, dispatch);
            assert_eq!(live.len(), 6, "live pacing delivers every frame");
            assert_eq!(
                live, reference,
                "live-paced output diverged at {workers} workers, dispatch={dispatch}"
            );
        }
    }
}

/// Many identical deterministic pipelines racing on a small pool must
/// each still produce the single-pipeline output bitwise — concurrency
/// may interleave scheduling, never data.
#[test]
fn concurrent_pipelines_stay_bit_identical() {
    let reference = run_with_workers(1);
    let hub = PipelineHub::with_workers(4);
    for i in 0..6 {
        let p = Pipeline::parse(&e4_launch()).unwrap();
        let pri = match i % 3 {
            0 => Priority::High,
            1 => Priority::Normal,
            _ => Priority::Low,
        };
        hub.launch_with_priority(format!("e4-{i}"), p, pri).unwrap();
    }
    for j in hub.join_all() {
        j.report.expect("pipeline succeeded");
        let mut pipeline = j.pipeline;
        assert_eq!(
            collect(&mut pipeline, "out"),
            reference,
            "pipeline {} diverged under concurrency",
            j.name
        );
    }
}
