//! Cross-topic (among-device) semantics of the stream-endpoint API:
//! EOS propagation across a topic link, backpressure without thread
//! growth, bit-identity of the two-pipeline MTCNN cascade vs. the fused
//! run, stop/join ordering of chained pipelines, and the query
//! request/response paths.
//!
//! Topic names are prefixed per test: the stream registry is
//! process-global and tests run concurrently.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use nnstreamer::apps::e3_mtcnn::{self, MtcnnConfig};
use nnstreamer::elements::query::{QueryClientProps, QueryServerSrcProps};
use nnstreamer::elements::sinks::TensorSink;
use nnstreamer::elements::sources::AppSrcProps;
use nnstreamer::pipeline::{Pipeline, PipelineBuilder, PipelineHub};
use nnstreamer::tensor::{Buffer, Caps, DType};

/// Thread count of this process (`/proc/self/status`); None off Linux.
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Collect (pts, payload) from a finished tensor_sink.
fn collect(p: &mut Pipeline, name: &str) -> Vec<(u64, Vec<u8>)> {
    let el = p.finished_element(name).expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| (b.pts_ns, b.chunk().as_bytes_unaccounted().to_vec()))
        .collect()
}

fn u8_frame_caps(w: usize, h: usize, fps: f64) -> Caps {
    Caps::tensor(DType::U8, [3, w, h, 1], fps)
}

// -- EOS propagation across a topic link ------------------------------------

#[test]
fn eos_propagates_across_topic_link() {
    let hub = PipelineHub::with_workers(2);

    // subscriber first: its subscription exists once launch() returns,
    // so the publisher drops nothing
    let mut back = PipelineBuilder::new();
    back.chain_named(
        "in",
        QueryServerSrcProps {
            topic: "q/eos".into(),
            caps: u8_frame_caps(16, 16, 240.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named("out", nnstreamer::elements::sinks::TensorSinkProps::default())
    .unwrap();
    hub.launch("back", back.build()).unwrap();

    let front = Pipeline::parse(
        "videotestsrc num-buffers=5 pattern=gradient ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
         tensor_converter ! tensor_query_serversink topic=q/eos",
    )
    .unwrap();
    hub.launch("front", front).unwrap();

    // join_all returning proves EOS crossed the topic: the back pipeline
    // can only finish when its serversrc observed end-of-stream
    let mut frames = 0;
    for j in hub.join_all() {
        let report = j.report.expect("pipeline succeeded");
        if j.name == "back" {
            frames = report.element("out").unwrap().buffers_in();
            let topic = report.topic("q/eos").expect("topic counters in report");
            assert_eq!(topic.published, 5);
            assert_eq!(topic.dropped, 0);
            assert!(topic.eos, "topic reached end-of-stream");
        }
    }
    assert_eq!(frames, 5, "every frame crossed the topic before EOS");
}

// -- backpressure: slow subscriber parks the publisher, no thread growth ----

#[test]
fn slow_subscriber_backpressures_publisher_without_thread_growth() {
    let hub = PipelineHub::with_workers(2);
    let baseline = process_threads();

    // tiny subscriber queue: the publisher saturates after 3 frames
    let sub = hub.subscribe_with_capacity("q/bp", 3);
    let front = Pipeline::parse(
        "videotestsrc num-buffers=24 pattern=gradient ! \
         video/x-raw,format=RGB,width=16,height=16,framerate=2400 ! \
         tensor_converter ! tensor_query_serversink name=pub topic=q/bp",
    )
    .unwrap();
    hub.launch("front", front).unwrap();

    // drain slowly; the publisher must park (not spin, not grow threads)
    let mut got = 0u64;
    let mut during = None;
    for _ in sub.iter() {
        got += 1;
        if got == 8 {
            during = process_threads();
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(got, 24, "backpressure delivered every frame");

    let joined = hub.join_all();
    let report = joined[0].report.as_ref().expect("front succeeded");
    assert_eq!(report.element("pub").unwrap().buffers_in(), 24);
    // the serversink parked at least once on the saturated topic
    assert!(
        report.element("pub").unwrap().parks_input() > 0,
        "publisher parked while the subscriber lagged"
    );
    // +8 slack: sibling tests in this binary run concurrently and spawn
    // their own bounded pools; the strict single-process assertion lives
    // in benches/e8_query.rs. What matters here: nothing per-frame.
    if let (Some(before), Some(mid)) = (baseline, during) {
        assert!(
            mid <= before + 8,
            "a saturated topic must not grow threads (before={before}, during={mid})"
        );
    }
}

// -- two-pipeline MTCNN cascade vs. fused run -------------------------------

#[test]
fn mtcnn_split_is_bit_identical_to_fused() {
    let cfg = MtcnnConfig {
        num_frames: 3,
        src_w: 480,
        src_h: 270,
        fps: 1000.0,
        ..Default::default()
    };
    let fused = e3_mtcnn::run_collect(&cfg).unwrap();
    assert_eq!(fused.len(), 3);

    let baseline = process_threads();
    let split = e3_mtcnn::run_split(&cfg, "q/mtcnn", 4).unwrap();
    assert_eq!(
        split.sink, fused,
        "two hub pipelines joined by topics must reproduce the fused output bitwise"
    );
    // total thread count stays O(workers), not O(elements): the split
    // cascade has ~40 element tasks but only its 4-worker pool ran them
    // (+8 slack for concurrently-running sibling tests' pools; the
    // strict single-process assertion lives in benches/e8_query.rs)
    if let (Some(before), Some(after)) = (baseline, process_threads()) {
        assert!(
            after <= before + 4 + 8,
            "split run grew threads beyond its pool (before={before}, after={after})"
        );
    }
    // topic accounting: one frames buffer and one boxes buffer per frame
    let frames_topic = split.back.topic("q/mtcnn/frames").unwrap();
    assert_eq!(frames_topic.published, 3);
    assert_eq!(frames_topic.dropped, 0);
    let boxes_topic = split.back.topic("q/mtcnn/boxes").unwrap();
    assert_eq!(boxes_topic.published, 3);
    assert_eq!(boxes_topic.dropped, 0);
}

// -- stop/join ordering of chained pipelines --------------------------------

#[test]
fn stop_all_unwinds_chained_pipelines_and_app_drain_loops() {
    let hub = PipelineHub::with_workers(2);

    // chain: A --(q/chain1)--> B --(q/chain2)--> app subscriber.
    // Launch downstream-first so every subscription exists before data.
    let tap = hub.subscribe("q/chain2");
    let mut mid = PipelineBuilder::new();
    mid.chain_named(
        "in",
        QueryServerSrcProps {
            topic: "q/chain1".into(),
            caps: u8_frame_caps(8, 8, 2400.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named(
        "out",
        nnstreamer::elements::query::QueryServerSinkProps {
            topic: "q/chain2".into(),
            ..Default::default()
        },
    )
    .unwrap();
    hub.launch("mid", mid.build()).unwrap();

    // unbounded source: only request_stop_all ends this pipeline
    let front = Pipeline::parse(
        "videotestsrc pattern=gradient ! \
         video/x-raw,format=RGB,width=8,height=8,framerate=2400 ! \
         tensor_converter ! tensor_query_serversink topic=q/chain1",
    )
    .unwrap();
    hub.launch("front", front).unwrap();

    // app drain loop on the chain's end, in a thread
    let seen = Arc::new(AtomicU64::new(0));
    let seen2 = seen.clone();
    let drain = std::thread::spawn(move || {
        for _ in tap.iter() {
            seen2.fetch_add(1, Ordering::Relaxed);
        }
    });

    // let some frames flow through the whole chain first
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    while seen.load(Ordering::Relaxed) < 16 {
        assert!(
            std::time::Instant::now() < deadline,
            "chain never delivered frames"
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    hub.request_stop_all();
    // both pipelines unwind: front's source observes the stop, EOS
    // crosses q/chain1, mid finishes, EOS crosses q/chain2
    for j in hub.join_all() {
        j.report.unwrap_or_else(|e| panic!("{} failed: {e}", j.name));
    }
    // and the app drain loop terminates (stop_all closed the handle
    // even if EOS had been lost)
    drain.join().expect("drain loop terminated");
    assert!(seen.load(Ordering::Relaxed) >= 16);
}

// -- hub.publish → pipeline (app as producer) -------------------------------

#[test]
fn hub_publish_feeds_a_subscribed_pipeline() {
    let hub = PipelineHub::with_workers(2);
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        QueryServerSrcProps {
            topic: "q/apppub".into(),
            caps: Caps::tensor(DType::F32, [3], 0.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named("out", nnstreamer::elements::sinks::TensorSinkProps::default())
    .unwrap();
    hub.launch("p", b.build()).unwrap();

    let mut publisher = hub.publish("q/apppub");
    assert_eq!(publisher.subscriber_count(), 1);
    for i in 0..4 {
        let delivered = publisher
            .push(Buffer::from_f32(i, &[i as f32, 1.0, 2.0]))
            .unwrap();
        assert!(delivered, "pipeline subscriber attached: nothing drops");
    }
    publisher.end();

    let mut joined = hub.join_all();
    let j = joined.pop().unwrap();
    j.report.expect("pipeline succeeded");
    let mut pipeline = j.pipeline;
    let got = collect(&mut pipeline, "out");
    assert_eq!(got.len(), 4);
    for (i, (pts, _)) in got.iter().enumerate() {
        assert_eq!(*pts, i as u64);
    }
}

// -- wait-subscribers: publisher parks until the consumer pipeline exists ---

#[test]
fn wait_subscribers_holds_frames_for_a_late_subscriber() {
    let hub = PipelineHub::with_workers(2);
    // publisher first, with wait-subscribers=1: frames park, not drop
    let front = Pipeline::parse(
        "videotestsrc num-buffers=6 pattern=gradient ! \
         video/x-raw,format=RGB,width=8,height=8,framerate=2400 ! \
         tensor_converter ! \
         tensor_query_serversink topic=q/wait wait-subscribers=1",
    )
    .unwrap();
    hub.launch("front", front).unwrap();
    std::thread::sleep(Duration::from_millis(30));

    let mut back = PipelineBuilder::new();
    back.chain_named(
        "in",
        QueryServerSrcProps {
            topic: "q/wait".into(),
            caps: u8_frame_caps(8, 8, 2400.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named("out", nnstreamer::elements::sinks::TensorSinkProps::default())
    .unwrap();
    hub.launch("back", back.build()).unwrap();

    for j in hub.join_all() {
        let report = j.report.expect("pipeline succeeded");
        if j.name == "back" {
            assert_eq!(
                report.element("out").unwrap().buffers_in(),
                6,
                "no frame was dropped while the subscriber was missing"
            );
        }
    }
}

// -- tensor_query_client element: request/response through a service --------

#[test]
fn query_client_element_round_trips_through_a_service() {
    use nnstreamer::elements::transform::{ArithOp, TensorTransformProps};

    let hub = PipelineHub::with_workers(2);

    // service: +1 on every sample
    let mut svc = PipelineBuilder::new();
    svc.chain_named(
        "in",
        QueryServerSrcProps {
            topic: "q/svc/in".into(),
            caps: Caps::tensor(DType::F32, [4], 0.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain(TensorTransformProps::arithmetic(vec![(ArithOp::Add, 1.0)]))
    .unwrap()
    .chain_named(
        "out",
        nnstreamer::elements::query::QueryServerSinkProps {
            topic: "q/svc/out".into(),
            ..Default::default()
        },
    )
    .unwrap();
    hub.launch("service", svc.build()).unwrap();

    // client pipeline: appsrc ! tensor_query_client ! tensor_sink
    let mut cli = PipelineBuilder::new();
    cli.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [4], 0.0),
        },
    )
    .unwrap()
    .chain_named(
        "bridge",
        QueryClientProps {
            topic: "q/svc/in".into(),
            reply: "q/svc/out".into(),
            caps: Caps::tensor(DType::F32, [4], 0.0),
            ..Default::default()
        },
    )
    .unwrap()
    .chain_named("out", nnstreamer::elements::sinks::TensorSinkProps::default())
    .unwrap();
    let mut client = cli.build();
    let push = client.appsrc("in").unwrap();
    hub.launch("client", client).unwrap();

    for i in 0..3 {
        push.push(Buffer::from_f32(i, &[i as f32, 0.0, 0.0, 0.0]))
            .unwrap();
    }
    push.end();

    let mut outputs = Vec::new();
    for j in hub.join_all() {
        j.report.unwrap_or_else(|e| panic!("{} failed: {e}", j.name));
        let mut pipeline = j.pipeline;
        if j.name == "client" {
            outputs = collect(&mut pipeline, "out");
        }
    }
    assert_eq!(outputs.len(), 3, "one reply per request");
    for (i, (_, bytes)) in outputs.iter().enumerate() {
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(vals, vec![i as f32 + 1.0, 1.0, 1.0, 1.0]);
    }
}

// -- appsrc/appsink keep their behavior atop the endpoint layer -------------

#[test]
fn appsrc_appsink_roundtrip_still_works_over_endpoints() {
    let mut b = PipelineBuilder::new();
    b.chain_named(
        "in",
        AppSrcProps {
            caps: Caps::tensor(DType::F32, [2], 0.0),
        },
    )
    .unwrap()
    .chain_named(
        "out",
        nnstreamer::elements::sinks::AppSinkProps::default(),
    )
    .unwrap();
    let mut pipeline = b.build();
    let push = pipeline.appsrc("in").unwrap();
    let frames = pipeline.appsink("out").unwrap();
    let running = pipeline.play().unwrap();

    push.push(Buffer::from_f32(7, &[1.0, 2.0])).unwrap();
    let got = frames.recv().unwrap();
    assert_eq!(got.pts_ns, 7);
    assert_eq!(got.chunk().as_f32().unwrap(), &[1.0, 2.0]);

    push.end();
    running.wait().unwrap();
    // channel closed at EOS: the drain loop terminates
    assert!(frames.recv().is_err());
    // pushes after end fail instead of silently queueing
    assert!(push.push(Buffer::from_f32(8, &[3.0, 4.0])).is_err());
}
