//! Parser ↔ builder round-trip over the e1–e4 application pipelines.
//!
//! Every launch string accepted before the typed-API redesign must still
//! parse into a graph that is structurally equivalent to the
//! `PipelineBuilder` construction (same element multiset, same link
//! count, same negotiated caps), and — where the pipeline is
//! deterministic — produce bit-identical sink output.

use nnstreamer::apps::{e1, e2_ars, e3_mtcnn, e4};
use nnstreamer::elements::converter::TensorConverterProps;
use nnstreamer::elements::decoder::{DecoderMode, TensorDecoderProps};
use nnstreamer::elements::filter::{Framework, TensorFilterProps};
use nnstreamer::elements::sinks::{TensorSink, TensorSinkProps};
use nnstreamer::elements::sources::VideoTestSrcProps;
use nnstreamer::elements::transform::{ArithOp, TensorTransformProps};
use nnstreamer::elements::videofilters::{VideoConvertProps, VideoScaleProps};
use nnstreamer::pipeline::{parser, Graph, Pipeline, PipelineBuilder};
use nnstreamer::tensor::{DType, VideoFormat};
use nnstreamer::video::Pattern;

/// Structural fingerprint of a negotiated graph: element type, fan-in,
/// fan-out, and negotiated out-caps per node (sorted, so auto-generated
/// names and node order don't matter).
fn fingerprint(g: &mut Graph) -> Vec<String> {
    g.negotiate_all().expect("graph negotiates");
    let mut nodes: Vec<String> = (0..g.nodes.len())
        .map(|id| {
            let node = g.node(id);
            let caps: Vec<String> =
                node.out_caps.iter().map(|c| c.to_string()).collect();
            format!(
                "{} in={} out={} caps={}",
                node.element.type_name(),
                g.n_sink_links(id),
                g.n_src_links(id),
                caps.join("|")
            )
        })
        .collect();
    nodes.sort();
    nodes
}

fn assert_equivalent(launch: &str, mut built: Graph, label: &str) {
    let mut parsed = parser::parse(launch)
        .unwrap_or_else(|e| panic!("{label}: launch string no longer parses: {e}"));
    assert_eq!(
        parsed.links.len(),
        built.links.len(),
        "{label}: link count differs"
    );
    assert_eq!(
        fingerprint(&mut parsed),
        fingerprint(&mut built),
        "{label}: parsed and builder graphs differ"
    );
}

#[test]
fn e1_launch_strings_match_builder_graphs() {
    let cfg = e1::E1Config {
        num_frames: 4,
        live: false,
        src_w: 160,
        src_h: 120,
        ..Default::default()
    };
    for case in e1::E1Case::all() {
        if case.is_control() {
            continue;
        }
        let launch = e1::launch_description(&cfg, case);
        let built = e1::build_pipeline(&cfg, case).unwrap();
        assert_equivalent(&launch, built, case.label());
    }
}

#[test]
fn e2_launch_string_matches_builder_graph_and_counts() {
    let cfg = e2_ars::ArsConfig {
        num_windows: 24,
        live: false,
        ..Default::default()
    };
    let launch = e2_ars::launch_description(&cfg);
    let built = e2_ars::build_pipeline(&cfg).unwrap();
    assert_equivalent(&launch, built, "e2");

    // both constructions run, and the deterministic fast path (a) sees
    // every window in both
    let mut from_launch = Pipeline::parse(&launch).unwrap();
    let report_l = from_launch.run().unwrap();
    let mut from_builder = Pipeline::new(e2_ars::build_pipeline(&cfg).unwrap());
    let report_b = from_builder.run().unwrap();
    assert_eq!(report_l.element("sink_a").unwrap().buffers_in(), 24);
    assert_eq!(report_b.element("sink_a").unwrap().buffers_in(), 24);
}

#[test]
fn e3_launch_string_matches_builder_graph() {
    let cfg = e3_mtcnn::MtcnnConfig {
        num_frames: 2,
        src_w: 480,
        src_h: 270,
        ..Default::default()
    };
    // build first: registers the custom filter stages the launch string
    // references
    let built = e3_mtcnn::build_pipeline(&cfg).unwrap();
    let launch = e3_mtcnn::launch_description(&cfg);
    assert_equivalent(&launch, built, "e3");
}

#[test]
fn e4_launch_string_matches_builder_graph() {
    let cfg = e4::E4Config {
        src_w: 160,
        src_h: 120,
        num_frames: 6,
    };
    for variant in ["opt", "ref"] {
        let launch = e4::launch_description(&cfg, variant);
        let built = e4::build_pipeline(&cfg, variant).unwrap();
        assert_equivalent(&launch, built.graph, &format!("e4/{variant}"));
    }
}

/// The deterministic E4 chain (linear, non-live, blocking): the launch
/// string and the typed builder must produce byte-for-byte the same sink
/// output, frame for frame.
#[test]
fn e4_pipeline_bit_identical_between_parser_and_builder() {
    let cfg = e4::E4Config {
        src_w: 160,
        src_h: 120,
        num_frames: 6,
    };

    // the e4 launch string verbatim, with the sink swapped for a
    // collecting tensor_sink
    let launch = e4::launch_description(&cfg, "opt")
        .replace("fakesink name=out", "tensor_sink name=out");
    let mut from_launch = Pipeline::parse(&launch).unwrap();
    from_launch.run().unwrap();
    let parsed_frames = collect(&mut from_launch, "out");

    // the same chain through typed props
    let mut b = PipelineBuilder::new();
    b.chain(VideoTestSrcProps {
        pattern: Pattern::Ball,
        width: cfg.src_w,
        height: cfg.src_h,
        framerate: 1000.0,
        num_buffers: Some(cfg.num_frames),
        ..Default::default()
    })
    .unwrap()
    .chain(VideoConvertProps {
        format: VideoFormat::Rgb,
    })
    .unwrap()
    .chain(VideoScaleProps {
        width: 96,
        height: 96,
    })
    .unwrap()
    .chain(TensorConverterProps)
    .unwrap()
    .chain(TensorTransformProps::typecast(DType::F32))
    .unwrap()
    .chain(TensorTransformProps::arithmetic(vec![(ArithOp::Div, 255.0)]))
    .unwrap()
    .chain(TensorFilterProps {
        framework: Framework::Xla,
        model: "ssd_opt".into(),
        ..Default::default()
    })
    .unwrap()
    .chain(TensorDecoderProps {
        mode: DecoderMode::BoundingBoxes,
        head: "ssd".into(),
        threshold: 0.5,
        ..Default::default()
    })
    .unwrap()
    .chain_named("out", TensorSinkProps::default())
    .unwrap();
    let mut from_builder = b.build();
    from_builder.run().unwrap();
    let built_frames = collect(&mut from_builder, "out");

    assert_eq!(parsed_frames.len(), cfg.num_frames as usize);
    assert_eq!(
        parsed_frames, built_frames,
        "parser and builder pipelines must produce bit-identical frames"
    );
}

/// Collect (pts, payload bytes) from a finished tensor_sink.
fn collect(p: &mut Pipeline, name: &str) -> Vec<(u64, Vec<u8>)> {
    let el = p.finished_element(name).expect("sink present");
    let sink = el
        .as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .expect("tensor_sink");
    sink.buffers
        .iter()
        .map(|b| (b.pts_ns, b.chunk().as_bytes_unaccounted().to_vec()))
        .collect()
}
