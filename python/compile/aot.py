"""AOT compiler: lower every registry model to HLO text + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the Rust `xla` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Run via ``make artifacts`` (skips up-to-date outputs) or directly:
    cd python && python -m compile.aot --out-dir ../artifacts [--only NAME]

Manifest format (line-based; the Rust runtime has no JSON dependency):
    <name>\tin=<dtype>:<d0>x<d1>...[;<dtype>:...]\tout=...\tflops=<N>\tact=<head>[;...]
"""
import argparse
import os
import sys

import jax

# The `ref` backend computes internally in f64 (see kernels/ref.py); without
# x64 the casts collapse to f32 and the ref/opt performance gap disappears.
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: default HLO printing elides big literals as "{...}", which
    # the text parser silently turns into zeros — every baked-in weight
    # would be lost. print_large_constants keeps the payloads.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # modern jax emits source_end_line/column metadata the 0.5.1 HLO text
    # parser rejects; metadata is debug-only, drop it
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def _fmt_aval(aval) -> str:
    dims = "x".join(str(d) for d in aval.shape) or "1"
    return f"{aval.dtype}:{dims}"


def _flops(lowered) -> int:
    try:
        cost = lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return int(cost.get("flops", 0))
    except Exception:
        return 0


def compile_one(name, out_dir, force=False):
    """Lower one model; returns its manifest line."""
    fn, example_inputs = model.build(name)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(fn).lower(*example_inputs)
    in_specs = ";".join(
        _fmt_aval(jax.api_util.shaped_abstractify(x)) for x in example_inputs
    )
    out_avals = jax.eval_shape(fn, *example_inputs)
    out_specs = ";".join(_fmt_aval(a) for a in out_avals)
    line = f"{name}\tin={in_specs}\tout={out_specs}\tflops={_flops(lowered)}"
    acts = model.acts_for(name)
    if acts:
        line += "\tact=" + ";".join(acts)
    if force or not os.path.exists(path):
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {name}: {len(text)} chars -> {path}", file=sys.stderr)
    else:
        print(f"[aot] {name}: up to date", file=sys.stderr)
    return line


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="compile a single model")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = list(model.registry())
    if args.only:
        names = [n for n in names if args.only in n]
        if not names:
            ap.error(f"no model matches {args.only!r}")

    lines = [compile_one(n, args.out_dir, force=args.force) for n in names]
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[aot] wrote {manifest} ({len(lines)} models)", file=sys.stderr)


if __name__ == "__main__":
    main()
