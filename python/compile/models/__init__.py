"""L2 model zoo — see DESIGN.md "Substitutions" for how each maps to the
paper's workloads (I3/Y3 in E1, ARS nets in E2, MTCNN in E3, SSDLite in E4).
"""
