"""SSDLite-style object detector for E4 ("ssdlite_object_detection" analog).

Depthwise-separable-flavored backbone (1x1 expansions + 3x3 convs) with two
feature-map scales feeding box-regression and class-score heads, like the
MediaPipe reference model. Outputs raw (boxes, scores) tensors; decoding
(anchor application + NMS) happens in the Rust tensor_decoder, as in the
paper's pipeline (Fig 5).
"""
import jax.numpy as jnp

from .common import Backend, ParamGen, maxpool

NUM_CLASSES = 11  # 10 + background
ANCHORS_PER_CELL = 2
# feature maps: 12x12 and 6x6 -> (144 + 36) * 2 = 360 anchors
NUM_ANCHORS = (12 * 12 + 6 * 6) * ANCHORS_PER_CELL


def _head(be, p, feat, cin):
    wl, bl = p.conv(3, 3, cin, ANCHORS_PER_CELL * 4)
    wc, bc = p.conv(3, 3, cin, ANCHORS_PER_CELL * NUM_CLASSES)
    loc = be.conv2d(feat, wl, bl, act="none")
    conf = be.conv2d(feat, wc, bc, act="none")
    n = feat.shape[1] * feat.shape[2] * ANCHORS_PER_CELL
    return loc.reshape(1, n, 4), conf.reshape(1, n, NUM_CLASSES)


def build(backend: Backend):
    """fn: (1,96,96,3) -> ((1,360,4) locs, (1,360,11) scores)."""
    p = ParamGen(seed=51)
    w1, b1 = p.conv(3, 3, 3, 16)
    w2, b2 = p.conv(1, 1, 16, 32)
    w3, b3 = p.conv(3, 3, 32, 32)
    w4, b4 = p.conv(1, 1, 32, 64)
    w5, b5 = p.conv(3, 3, 64, 64)
    w6, b6 = p.conv(3, 3, 64, 96)
    ph = ParamGen(seed=52)

    def fn(x):
        h = backend.conv2d(x, w1, b1, stride=2, act="relu6")  # 48x48x16
        h = backend.conv2d(h, w2, b2, act="relu6")            # 48x48x32
        h = maxpool(h, 2)                                     # 24x24x32
        h = backend.conv2d(h, w3, b3, act="relu6")            # 24x24x32
        h = backend.conv2d(h, w4, b4, act="relu6")            # 24x24x64
        h = maxpool(h, 2)                                     # 12x12x64
        f1 = backend.conv2d(h, w5, b5, act="relu6")           # 12x12x64
        f2 = backend.conv2d(
            maxpool(f1, 2), w6, b6, act="relu6"
        )                                                     # 6x6x96
        l1, c1 = _head(backend, ph, f1, 64)
        l2, c2 = _head(backend, ph, f2, 96)
        locs = jnp.concatenate([l1, l2], axis=1)
        confs = jnp.concatenate([c1, c2], axis=1)
        return locs, confs

    return fn, [jnp.zeros((1, 96, 96, 3), jnp.float32)]
