"""Shared building blocks for the L2 model zoo.

Every model is a pure function over its input tensors with weights baked in
as constants (deterministic init from a fixed seed), so each AOT artifact is
self-contained: the Rust runtime feeds input tensors and reads output
tensors, nothing else — exactly how NNStreamer's tensor_filter treats a
model file as an opaque delegate.

Two execution backends implement the same math (see DESIGN.md):
  * ``OPT``  — Pallas L1 kernels (im2col + tiled MXU matmul, fused epilogue)
  * ``REF``  — the unoptimized delegate (f64, layout round-trips, unfused),
               standing in for E4's "pinned old NNFW" build
"""
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .. import kernels
from ..kernels import ref


@dataclasses.dataclass(frozen=True)
class Backend:
    """Dispatch table: which implementation executes each layer type."""

    name: str
    conv2d: Callable
    conv1d: Callable
    dense: Callable


OPT = Backend(
    name="opt",
    conv2d=kernels.conv2d,
    conv1d=kernels.conv1d,
    dense=kernels.matmul_bias_act,
)

REF = Backend(
    name="ref",
    conv2d=ref.conv2d_unopt,
    conv1d=ref.conv1d_unopt,
    dense=ref.matmul_bias_act_unopt,
)

BACKENDS = {"opt": OPT, "ref": REF}


class ParamGen:
    """Deterministic parameter factory (split-per-call PRNG)."""

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def conv(self, kh, kw, cin, cout):
        scale = (2.0 / (kh * kw * cin)) ** 0.5
        w = jax.random.normal(self._next(), (kh, kw, cin, cout), jnp.float32)
        b = 0.01 * jax.random.normal(self._next(), (cout,), jnp.float32)
        return w * scale, b

    def conv1(self, kt, cin, cout):
        scale = (2.0 / (kt * cin)) ** 0.5
        w = jax.random.normal(self._next(), (kt, cin, cout), jnp.float32)
        b = 0.01 * jax.random.normal(self._next(), (cout,), jnp.float32)
        return w * scale, b

    def dense(self, fin, fout):
        scale = (2.0 / fin) ** 0.5
        w = jax.random.normal(self._next(), (fin, fout), jnp.float32)
        b = 0.01 * jax.random.normal(self._next(), (fout,), jnp.float32)
        return w * scale, b


def maxpool(x, window=2, stride=None, padding="VALID"):
    """NHWC max pooling."""
    stride = stride or window
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding=padding,
    )


def avgpool_global(x):
    """(B, H, W, C) -> (B, C) global average pool."""
    return jnp.mean(x, axis=(1, 2))


def maxpool1d(x, window=2, stride=None):
    """(B, T, C) temporal max pooling."""
    stride = stride or window
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, 1),
        window_strides=(1, stride, 1),
        padding="VALID",
    )
