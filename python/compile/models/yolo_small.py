"""YOLO-v3-style single-shot detector ("Y3" in Table I), scaled down.

Darknet-ish backbone (strided conv stacks) + grid detection head emitting
(1, S, S, B*(5+C)) raw predictions; the Rust tensor_decoder turns them into
boxes. ~2.5x the FLOPs of inception_small, preserving the paper's relative
model cost (Y3 throughput ~0.4x of I3 on the same NPU).
"""
import jax.numpy as jnp

from .common import Backend, ParamGen, maxpool

GRID = 12
NUM_ANCHORS = 2
NUM_CLASSES = 15
HEAD_CH = NUM_ANCHORS * (5 + NUM_CLASSES)  # 40


def build(backend: Backend):
    """fn: (1,96,96,3) f32 -> ((1,12,12,40) f32,)."""
    p = ParamGen(seed=41)
    w1, b1 = p.conv(3, 3, 3, 16)
    w2, b2 = p.conv(3, 3, 16, 32)
    w3, b3 = p.conv(3, 3, 32, 64)
    w4, b4 = p.conv(3, 3, 64, 64)
    w5, b5 = p.conv(1, 1, 64, 128)
    w6, b6 = p.conv(3, 3, 128, 64)
    wh, bh = p.conv(1, 1, 64, HEAD_CH)

    def fn(x):
        h = backend.conv2d(x, w1, b1, stride=2, act="relu")  # 48x48x16
        h = backend.conv2d(h, w2, b2, act="relu")            # 48x48x32
        h = maxpool(h, 2)                                    # 24x24x32
        h = backend.conv2d(h, w3, b3, act="relu")            # 24x24x64
        h = maxpool(h, 2)                                    # 12x12x64
        h = backend.conv2d(h, w4, b4, act="relu")            # 12x12x64
        h = backend.conv2d(h, w5, b5, act="relu")            # 12x12x128
        h = backend.conv2d(h, w6, b6, act="relu")            # 12x12x64
        raw = backend.conv2d(h, wh, bh, act="none")          # 12x12x40
        return (raw,)

    return fn, [jnp.zeros((1, 96, 96, 3), jnp.float32)]
