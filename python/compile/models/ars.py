"""Activity Recognition Sensor (ARS, E2) model stack — Fig 3's three NNs.

The ARS device fuses IIO sensors (3-axis accelerometer + pressure) and a
microphone. Fig 3 has three NN stages running at different aggregated
rates:
  (a) per-window activity classifier over short accel windows  (fast path)
  (b) long-window fused classifier over mux'ed accel+pressure  (slow path,
      fed by a tensor_aggregator, hence the low batch rate in the paper)
  (c) audio-event classifier over mic spectrogram-ish frames   (mid path)

Temporal convs are 1-D (lowered through the same Pallas matmul hot-spot).
"""
import jax.numpy as jnp

from .common import Backend, ParamGen, maxpool1d


def build_ars_a(backend: Backend):
    """fn: (1,128,3) accel window -> ((1,8) activity probs,)."""
    p = ParamGen(seed=71)
    w1 = p.conv1(5, 3, 16)
    w2 = p.conv1(5, 16, 32)
    w3 = p.conv1(3, 32, 32)
    wd = p.dense(32, 8)

    def fn(x):
        t = backend.conv1d(x, *w1, stride=2, act="relu")   # 64x16
        t = backend.conv1d(t, *w2, stride=2, act="relu")   # 32x32
        t = maxpool1d(t, 2)                                # 16x32
        t = backend.conv1d(t, *w3, act="relu")             # 16x32
        t = jnp.mean(t, axis=1)                            # (1,32)
        return (backend.dense(t, *wd, act="softmax"),)

    return fn, [jnp.zeros((1, 128, 3), jnp.float32)]


def build_ars_b(backend: Backend):
    """fn: (1,512,8) fused long window -> ((1,8) probs,).

    Input = aggregator output: 4 accel windows x (3 accel + 1 pressure +
    4 derived) channels, mux'ed and concatenated on the time axis.
    """
    p = ParamGen(seed=72)
    w1 = p.conv1(7, 8, 32)
    w2 = p.conv1(5, 32, 64)
    w3 = p.conv1(5, 64, 64)
    w4 = p.conv1(3, 64, 96)
    wd1 = p.dense(96, 64)
    wd2 = p.dense(64, 8)

    def fn(x):
        t = backend.conv1d(x, *w1, stride=2, act="relu")   # 256x32
        t = backend.conv1d(t, *w2, stride=2, act="relu")   # 128x64
        t = maxpool1d(t, 2)                                # 64x64
        t = backend.conv1d(t, *w3, act="relu")             # 64x64
        t = maxpool1d(t, 2)                                # 32x64
        t = backend.conv1d(t, *w4, act="relu")             # 32x96
        t = jnp.mean(t, axis=1)                            # (1,96)
        t = backend.dense(t, *wd1, act="relu")
        return (backend.dense(t, *wd2, act="softmax"),)

    return fn, [jnp.zeros((1, 512, 8), jnp.float32)]


def build_ars_c(backend: Backend):
    """fn: (1,64,16) mic feature frame -> ((1,4) audio-event probs,)."""
    p = ParamGen(seed=73)
    w1 = p.conv1(5, 16, 32)
    w2 = p.conv1(3, 32, 48)
    wd = p.dense(48, 4)

    def fn(x):
        t = backend.conv1d(x, *w1, stride=2, act="relu")   # 32x32
        t = backend.conv1d(t, *w2, act="relu")             # 32x48
        t = maxpool1d(t, 2)                                # 16x48
        t = jnp.mean(t, axis=1)                            # (1,48)
        return (backend.dense(t, *wd, act="softmax"),)

    return fn, [jnp.zeros((1, 64, 16), jnp.float32)]
