"""Inception-v3-style classifier ("I3" in Table I), scaled for on-device.

Architecturally faithful op mix: stem convs, two Inception "mixed" blocks
(1x1 / 3x3 / double-3x3 / pool-proj branches, channel-concatenated), global
average pool, dense classifier. Input 64x64x3 RGB (the E1 camera stream is
scaled to this by `videoscale` in the pipeline); 100 classes.
"""
import jax.numpy as jnp

from .common import Backend, ParamGen, avgpool_global, maxpool


def _mixed_block(be: Backend, p: ParamGen, x, cin, spec):
    """Inception mixed block. spec = (c1, (c3r, c3), (c5r, c5a, c5b), cp)."""
    c1, (c3r, c3), (c5r, c5a, c5b), cp = spec

    w, b = p.conv(1, 1, cin, c1)
    b1 = be.conv2d(x, w, b, act="relu")

    w, b = p.conv(1, 1, cin, c3r)
    b3 = be.conv2d(x, w, b, act="relu")
    w, b = p.conv(3, 3, c3r, c3)
    b3 = be.conv2d(b3, w, b, act="relu")

    w, b = p.conv(1, 1, cin, c5r)
    b5 = be.conv2d(x, w, b, act="relu")
    w, b = p.conv(3, 3, c5r, c5a)
    b5 = be.conv2d(b5, w, b, act="relu")
    w, b = p.conv(3, 3, c5a, c5b)
    b5 = be.conv2d(b5, w, b, act="relu")

    bp = maxpool(x, window=3, stride=1, padding="SAME")
    w, b = p.conv(1, 1, cin, cp)
    bp = be.conv2d(bp, w, b, act="relu")

    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def build(backend: Backend):
    """Returns (fn, input_specs). fn: (1,64,64,3) f32 -> ((1,100) f32,)."""
    p = ParamGen(seed=31)
    w1, b1 = p.conv(3, 3, 3, 16)
    w2, b2 = p.conv(3, 3, 16, 32)
    # block specs sized so the whole model is ~2.5x lighter than yolo_small
    spec_a = (16, (16, 24), (8, 12, 16), 16)     # -> 72 ch
    spec_b = (24, (24, 32), (12, 16, 24), 16)    # -> 96 ch
    p_a = ParamGen(seed=32)
    p_b = ParamGen(seed=33)
    wd, bd = ParamGen(seed=34).dense(96, 100)

    def fn(x):
        h = backend.conv2d(x, w1, b1, stride=2, act="relu")   # 32x32x16
        h = backend.conv2d(h, w2, b2, act="relu")             # 32x32x32
        h = maxpool(h, 2)                                     # 16x16x32
        h = _mixed_block(backend, p_a, h, 32, spec_a)         # 16x16x72
        h = maxpool(h, 2)                                     # 8x8x72
        h = _mixed_block(backend, p_b, h, 72, spec_b)         # 8x8x96
        h = avgpool_global(h)                                 # (1, 96)
        logits = backend.dense(h, wd, bd, act="softmax")      # (1, 100)
        return (logits,)

    return fn, [jnp.zeros((1, 64, 64, 3), jnp.float32)]
