"""MTCNN face-detection cascade (E3) — paper-faithful architectures.

P-Net / R-Net / O-Net exactly as in Zhang et al. 2016 (the nets are tiny,
so no scaling is needed). P-Net is fully convolutional and is compiled once
per image-pyramid scale (AOT requires static shapes; the paper's pipeline
in Fig 4 likewise instantiates one P-Net filter per scaled stream). R-Net /
O-Net take fixed-size candidate batches (padded at runtime by the Rust
image-patch element).
"""
import jax.numpy as jnp

from .common import Backend, ParamGen, maxpool

# Pyramid over the 192x108 scaled luma of the Full-HD source (factor ~0.71).
# (H, W) per scale; fully-conv P-Net output is ((H-10)//2+1 - 2, ...) etc.
PYRAMID = [(108, 192), (76, 136), (54, 96), (38, 68), (27, 48)]
RNET_BATCH = 16
ONET_BATCH = 8


def _pnet_params():
    p = ParamGen(seed=61)
    return {
        "w1": p.conv(3, 3, 3, 10),
        "w2": p.conv(3, 3, 10, 16),
        "w3": p.conv(3, 3, 16, 32),
        "wp": p.conv(1, 1, 32, 2),
        "wb": p.conv(1, 1, 32, 4),
    }


_PNET = _pnet_params()


def build_pnet(backend: Backend, scale_idx: int):
    """fn: (1,H,W,3) -> ((1,h,w,2) face prob, (1,h,w,4) bbox reg)."""
    h, w = PYRAMID[scale_idx]
    pr = _PNET

    def fn(x):
        t = backend.conv2d(x, *pr["w1"], padding="VALID", act="prelu")
        t = maxpool(t, 2, padding="SAME")
        t = backend.conv2d(t, *pr["w2"], padding="VALID", act="prelu")
        t = backend.conv2d(t, *pr["w3"], padding="VALID", act="prelu")
        prob = backend.conv2d(t, *pr["wp"], padding="VALID", act="softmax")
        reg = backend.conv2d(t, *pr["wb"], padding="VALID", act="none")
        return prob, reg

    return fn, [jnp.zeros((1, h, w, 3), jnp.float32)]


def build_rnet(backend: Backend):
    """fn: (16,24,24,3) -> ((16,2) prob, (16,4) bbox reg)."""
    p = ParamGen(seed=62)
    w1 = p.conv(3, 3, 3, 28)
    w2 = p.conv(3, 3, 28, 48)
    w3 = p.conv(2, 2, 48, 64)
    wd = p.dense(3 * 3 * 64, 128)
    wp = p.dense(128, 2)
    wb = p.dense(128, 4)

    def fn(x):
        t = backend.conv2d(x, *w1, padding="VALID", act="prelu")  # 22x22x28
        t = maxpool(t, 3, 2, padding="SAME")                      # 11x11x28
        t = backend.conv2d(t, *w2, padding="VALID", act="prelu")  # 9x9x48
        t = maxpool(t, 3, 2, padding="VALID")                     # 4x4x48
        t = backend.conv2d(t, *w3, padding="VALID", act="prelu")  # 3x3x64
        t = t.reshape(t.shape[0], -1)
        t = backend.dense(t, *wd, act="prelu")                    # (B,128)
        prob = backend.dense(t, *wp, act="softmax")
        reg = backend.dense(t, *wb, act="none")
        return prob, reg

    return fn, [jnp.zeros((RNET_BATCH, 24, 24, 3), jnp.float32)]


def build_onet(backend: Backend):
    """fn: (8,48,48,3) -> ((8,2) prob, (8,4) bbox reg, (8,10) landmarks)."""
    p = ParamGen(seed=63)
    w1 = p.conv(3, 3, 3, 32)
    w2 = p.conv(3, 3, 32, 64)
    w3 = p.conv(3, 3, 64, 64)
    w4 = p.conv(2, 2, 64, 128)
    wd = p.dense(3 * 3 * 128, 256)
    wp = p.dense(256, 2)
    wb = p.dense(256, 4)
    wl = p.dense(256, 10)

    def fn(x):
        t = backend.conv2d(x, *w1, padding="VALID", act="prelu")  # 46x46x32
        t = maxpool(t, 3, 2, padding="SAME")                      # 23x23x32
        t = backend.conv2d(t, *w2, padding="VALID", act="prelu")  # 21x21x64
        t = maxpool(t, 3, 2, padding="VALID")                     # 10x10x64
        t = backend.conv2d(t, *w3, padding="VALID", act="prelu")  # 8x8x64
        t = maxpool(t, 2, 2, padding="VALID")                     # 4x4x64
        t = backend.conv2d(t, *w4, padding="VALID", act="prelu")  # 3x3x128
        t = t.reshape(t.shape[0], -1)
        t = backend.dense(t, *wd, act="prelu")                    # (B,256)
        prob = backend.dense(t, *wp, act="softmax")
        reg = backend.dense(t, *wb, act="none")
        lmk = backend.dense(t, *wl, act="none")
        return prob, reg, lmk

    return fn, [jnp.zeros((ONET_BATCH, 48, 48, 3), jnp.float32)]
