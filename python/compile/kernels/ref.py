"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness reference (pytest asserts allclose against them)
AND the building block of the `ref` artifact variant — the deliberately
slower "pinned old NNFW" path of E4 (see DESIGN.md substitutions): f64
internal compute, layout round-trips, and unfused bias/activation, the way
an unoptimized delegate would execute.
"""
import jax
import jax.numpy as jnp


def activation(x, act):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    if act == "prelu":
        return jnp.where(x >= 0.0, x, 0.25 * x)
    if act == "softmax":
        return jax.nn.softmax(x, axis=-1)
    raise ValueError(f"unknown activation {act!r}")


def matmul_bias_act(x, y, bias=None, act="none"):
    """Oracle for kernels.matmul.matmul_bias_act (f32, fused semantics)."""
    out = jnp.dot(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return activation(out, act)


def conv2d(x, w, bias=None, stride=1, padding="SAME", act="none"):
    """Oracle for kernels.conv.conv2d (NHWC, HWIO)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return activation(out, act)


def conv1d(x, w, bias=None, stride=1, padding="SAME", act="none"):
    out = conv2d(
        x[:, None, :, :], w[None, :, :, :], bias=bias, stride=stride,
        padding=padding, act=act,
    )
    return out[:, 0, :, :]


# ---------------------------------------------------------------------------
# "ref" execution backend: the unoptimized delegate (E4's pinned NNFW 2.1).
# f64 internal precision, NHWC->NCHW->NHWC layout round-trip per conv, and
# unfused bias/activation. Numerically equivalent (within f32 tolerance) but
# measurably slower — this gap is what Table III's (a) vs (b) measures.
# ---------------------------------------------------------------------------

def conv2d_unopt(x, w, bias=None, stride=1, padding="SAME", act="none"):
    x64 = x.astype(jnp.float64).transpose(0, 3, 1, 2)  # NCHW round-trip
    w64 = w.astype(jnp.float64).transpose(3, 2, 0, 1)  # OIHW
    out = jax.lax.conv_general_dilated(
        x64,
        w64,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    out = out.transpose(0, 2, 3, 1)
    if bias is not None:
        out = out + bias.astype(jnp.float64)
    out = activation(out, act)
    return out.astype(jnp.float32)


def matmul_bias_act_unopt(x, y, bias=None, act="none"):
    out = jnp.dot(x.astype(jnp.float64), y.astype(jnp.float64))
    if bias is not None:
        out = out + bias.astype(jnp.float64)
    return activation(out, act).astype(jnp.float32)


def conv1d_unopt(x, w, bias=None, stride=1, padding="SAME", act="none"):
    out = conv2d_unopt(
        x[:, None, :, :], w[None, :, :, :], bias=bias, stride=stride,
        padding=padding, act=act,
    )
    return out[:, 0, :, :]
