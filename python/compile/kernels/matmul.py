"""Tiled Pallas matmul with optional fused bias+activation epilogue.

Blocking strategy (TPU mental model):
  * grid = (M/bm, N/bn, K/bk); the K axis is the innermost (minor) grid
    dimension so each (i, j) output tile stays resident while the K loop
    streams x/y tiles through VMEM.
  * default tiles are MXU-aligned 128 multiples; f32 accumulation happens
    directly in the output ref (all model weights/activations are f32, so
    no separate accumulator scratch is needed — this also keeps the kernel
    runnable under interpret=True on the CPU plugin).
  * VMEM footprint per step = bm*bk + bk*bn + bm*bn floats
    (128^2 * 3 * 4B = 192 KiB << 16 MiB VMEM), leaving room for
    double-buffering by the pipeline emitter.

The epilogue (bias add + activation) is fused into the final K step so the
output tile is written exactly once — the Pallas analog of the fused
conv-bias-relu blocks the paper's NNFW delegates (TFLite/Vivante) provide.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _activation(x, act):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    if act == "prelu":
        # shared-slope PReLU (slope baked as 0.25, matching our model init)
        return jnp.where(x >= 0.0, x, 0.25 * x)
    if act == "softmax":
        return jax.nn.softmax(x, axis=-1)
    raise ValueError(f"unknown activation {act!r}")


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk, act, has_bias):
    """o[i, j] = act(sum_k x[i, k] @ y[k, j] + bias[j]).

    Without bias, refs are (x, y, o); with bias, (x, y, b, o) — pallas_call
    passes inputs in order, so the bias ref is threaded via closure re-order
    in `matmul_bias_act` below.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        o_ref[...] = _activation(acc, act)


def _matmul_bias_kernel(x_ref, y_ref, b_ref, o_ref, *, nk, act):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        o_ref[...] = _activation(acc, act)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def _pick_block(size, preferred):
    """Largest MXU-friendly block <= preferred that keeps padding waste low."""
    if size >= preferred:
        return preferred
    # round size up to the next multiple of 8 (VPU sublane) for small dims
    return max(8, -(-size // 8) * 8)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def matmul_bias_act(x, y, bias=None, act="none", bm=128, bn=128, bk=128):
    """f32 (M,K) @ (K,N) + bias(N,) with fused activation, Pallas-tiled.

    Shapes need not be multiples of the block sizes; inputs are zero-padded
    (zero rows/cols do not perturb the product) and the result is sliced
    back. Runs under interpret=True — see module docstring.

    Softmax is NOT fused: it normalizes across the full (unpadded) N axis,
    which a tiled epilogue cannot see (padded zero columns would leak into
    the denominator). It is applied after the slice-back instead.
    """
    fused_act = act if act != "softmax" else "none"
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)

    xp = _pad_to(_pad_to(x.astype(jnp.float32), bm, 0), bk, 1)
    yp = _pad_to(_pad_to(y.astype(jnp.float32), bk, 0), bn, 1)
    mp, kp = xp.shape
    _, np_ = yp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    if bias is not None:
        bp = _pad_to(bias.astype(jnp.float32).reshape(1, -1), bn, 1)
        kernel = functools.partial(_matmul_bias_kernel, nk=grid[2], act=fused_act)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
                pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, yp, bp)
    else:
        kernel = functools.partial(
            _matmul_kernel, nk=grid[2], act=fused_act, has_bias=False
        )
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, yp)
    out = out[:m, :n]
    if act == "softmax":
        out = jax.nn.softmax(out, axis=-1)
    return out


def matmul(x, y):
    """Plain tiled matmul (no epilogue)."""
    return matmul_bias_act(x, y, bias=None, act="none")
