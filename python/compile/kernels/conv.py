"""conv2d / conv1d built on the Pallas matmul kernel (im2col lowering).

The paper's NNFW delegates (TFLite, Vivante) lower convolutions onto their
matmul engines; we do the same: patch extraction (cheap, memory-bound,
stays in the XLA graph) followed by the L1 Pallas matmul with a fused
bias+activation epilogue (compute-bound hot-spot).

Layout: NHWC activations, HWIO weights — the dominant on-device layout.
"""
import jax
import jax.numpy as jnp

from .matmul import matmul_bias_act


def _im2col(x, kh, kw, stride, padding):
    """(B,H,W,C) -> (B*OH*OW, KH*KW*C) patch matrix.

    Uses conv_general_dilated_patches, which yields feature order
    (C, KH, KW) per patch; we transpose to (KH, KW, C) so weight matrices
    reshape directly from HWIO.
    """
    b, h, w, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, OH, OW, C*KH*KW) with feature order (c, kh, kw)
    _, oh, ow, f = patches.shape
    patches = patches.reshape(b, oh, ow, c, kh * kw)
    patches = patches.transpose(0, 1, 2, 4, 3)  # -> (kh*kw, c) minor order
    return patches.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def conv2d(x, w, bias=None, stride=1, padding="SAME", act="none"):
    """NHWC conv2d via im2col + Pallas matmul, fused bias+activation.

    x: (B, H, W, Cin) f32;  w: (KH, KW, Cin, Cout) HWIO;  bias: (Cout,)
    """
    kh, kw, cin, cout = w.shape
    cols, (b, oh, ow) = _im2col(x, kh, kw, stride, padding)
    wm = w.reshape(kh * kw * cin, cout)
    out = matmul_bias_act(cols, wm, bias=bias, act=act)
    return out.reshape(b, oh, ow, cout)


def conv1d(x, w, bias=None, stride=1, padding="SAME", act="none"):
    """(B, T, C) temporal conv via the conv2d path with H=1."""
    kt, cin, cout = w.shape
    out = conv2d(
        x[:, None, :, :],
        w[None, :, :, :],
        bias=bias,
        stride=stride,
        padding=padding,
        act=act,
    )
    return out[:, 0, :, :]
