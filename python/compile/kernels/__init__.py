"""Layer-1 Pallas kernels (build-time only).

The kernels here are the compute hot-spot of the NNFW "delegate" that the
Rust tensor_filter element executes through PJRT. They are written for the
TPU mental model (MXU-shaped tiles, VMEM-sized blocks expressed through
BlockSpec) and lowered with ``interpret=True`` so the CPU PJRT plugin can
execute the resulting HLO. See DESIGN.md "Hardware adaptation".
"""
from .matmul import matmul, matmul_bias_act
from .conv import conv2d, conv1d
