"""L2 model registry: every AOT artifact the Rust runtime can load.

Each entry maps an artifact name to a zero-state jax function (weights are
baked constants) plus its example inputs. `aot.py` lowers every entry to
``artifacts/<name>.hlo.txt`` and records shapes in ``artifacts/manifest.txt``.

Naming convention: ``<model>[_<part>][_sN]_<variant>`` where variant is
``opt`` (Pallas L1 kernels) or ``ref`` (unoptimized delegate — E4's pinned
old-NNFW build; also the artifact used when a filter is bound to
``accelerator=cpu`` in E1, see DESIGN.md).
"""
from .models import ars, inception_small, mtcnn, ssdlite_small
from .models.common import BACKENDS


def registry():
    """name -> (fn, example_inputs). Built lazily: constructing an entry
    materializes its weights."""
    from .models import yolo_small  # local import keeps module load cheap

    entries = {}

    def add(name, builder, *args):
        for variant, be in BACKENDS.items():
            entries[f"{name}_{variant}"] = (builder, (be, *args))

    def add_opt(name, builder, *args):
        entries[f"{name}_opt"] = (builder, (BACKENDS["opt"], *args))

    add("i3", inception_small.build)
    add("y3", yolo_small.build)
    add("ssd", ssdlite_small.build)
    for s in range(len(mtcnn.PYRAMID)):
        add_opt(f"pnet_s{s}", mtcnn.build_pnet, s)
    add_opt("rnet", mtcnn.build_rnet)
    add_opt("onet", mtcnn.build_onet)
    add_opt("ars_a", ars.build_ars_a)
    add_opt("ars_b", ars.build_ars_b)
    add_opt("ars_c", ars.build_ars_c)
    return entries


def build(name):
    """Materialize one registry entry: returns (fn, example_inputs)."""
    builder, args = registry()[name]
    return builder(*args)


def acts_for(name):
    """Final activation of each output head, for the manifest ``act=``
    field. Compiled HLO embeds the activation in the program; the Rust
    runtime's surrogate backend uses the hint to reproduce head semantics
    (e.g. that classifier outputs are probability distributions)."""
    if name.startswith(("i3", "ars")):
        return ["softmax"]
    if name.startswith("y3"):
        return ["none"]
    if name.startswith("ssd"):
        return ["none", "none"]
    if name.startswith(("pnet", "rnet")):
        return ["softmax", "none"]
    if name.startswith("onet"):
        return ["softmax", "none", "none"]
    return []
