"""AOT pipeline: HLO text emission + manifest consistency."""
import os

import jax
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke(tmp_path):
    line = aot.compile_one("ars_c_opt", str(tmp_path), force=True)
    path = tmp_path / "ars_c_opt.hlo.txt"
    text = path.read_text()
    assert text.startswith("HloModule")
    # entry computation carries the expected IO signature
    assert "f32[1,64,16]" in text
    assert "f32[1,4]" in text
    assert line.split("\t")[0] == "ars_c_opt"
    assert "in=float32:1x64x16" in line
    assert "out=float32:1x4" in line


def test_manifest_covers_registry():
    manifest = os.path.join(ARTIFACTS, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built (run `make artifacts`)")
    names = {line.split("\t")[0] for line in open(manifest) if line.strip()}
    assert names == set(model.registry())


def test_artifacts_exist_for_manifest():
    manifest = os.path.join(ARTIFACTS, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built (run `make artifacts`)")
    for line in open(manifest):
        if not line.strip():
            continue
        name = line.split("\t")[0]
        path = os.path.join(ARTIFACTS, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(64)
        assert head.startswith("HloModule"), f"{name}: not HLO text"


def test_hlo_text_keeps_large_constants(tmp_path):
    """Regression: default HLO printing elides big literals as "{...}",
    silently zeroing every baked-in weight after the text round-trip."""
    aot.compile_one("ars_c_opt", str(tmp_path), force=True)
    text = (tmp_path / "ars_c_opt.hlo.txt").read_text()
    assert "constant({...})" not in text
    # at least one multi-kilobyte constant payload must be spelled out
    assert any(
        line.count(",") > 500 for line in text.splitlines() if "constant(" in line
    ), "no large constant payload found in HLO text"


def test_manifest_flops_recorded():
    manifest = os.path.join(ARTIFACTS, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    flops = {}
    for line in open(manifest):
        fields = dict(
            f.split("=", 1) for f in line.strip().split("\t")[1:] if "=" in f
        )
        flops[line.split("\t")[0]] = int(fields.get("flops", 0))
    # cost analysis must see through the pallas while-loops
    assert flops["i3_opt"] > 1e6
    # the paper's relative cost: Y3 >> I3
    assert flops["y3_opt"] > flops["i3_opt"]
