"""L2 model zoo: shapes, determinism, variant equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402
from compile.models import ars, inception_small, mtcnn, ssdlite_small  # noqa: E402
from compile.models.common import BACKENDS  # noqa: E402


def run(name):
    fn, inputs = model.build(name)
    key = jax.random.PRNGKey(0)
    reals = [
        jax.random.uniform(key, x.shape, jnp.float32, -1.0, 1.0) for x in inputs
    ]
    return fn(*reals), reals


@pytest.mark.parametrize(
    "name,out_shapes",
    [
        ("i3_opt", [(1, 100)]),
        ("y3_opt", [(1, 12, 12, 40)]),
        ("ssd_opt", [(1, 360, 4), (1, 360, 11)]),
        ("rnet_opt", [(16, 2), (16, 4)]),
        ("onet_opt", [(8, 2), (8, 4), (8, 10)]),
        ("ars_a_opt", [(1, 8)]),
        ("ars_b_opt", [(1, 8)]),
        ("ars_c_opt", [(1, 4)]),
    ],
)
def test_output_shapes(name, out_shapes):
    outs, _ = run(name)
    assert [tuple(o.shape) for o in outs] == out_shapes


@pytest.mark.parametrize("scale", range(len(mtcnn.PYRAMID)))
def test_pnet_pyramid_shapes(scale):
    outs, _ = run(f"pnet_s{scale}_opt")
    prob, reg = outs
    assert prob.shape[-1] == 2
    assert reg.shape[-1] == 4
    assert prob.shape[:3] == reg.shape[:3]
    # fully-conv map must shrink with the pyramid
    h, w = mtcnn.PYRAMID[scale]
    assert prob.shape[1] < h and prob.shape[2] < w


def test_classifier_outputs_are_probabilities():
    for name in ["i3_opt", "ars_a_opt", "ars_b_opt", "ars_c_opt"]:
        outs, _ = run(name)
        probs = np.asarray(outs[0])
        assert np.all(probs >= 0)
        np.testing.assert_allclose(probs.sum(axis=-1), 1.0, rtol=1e-4)


def test_variants_numerically_equivalent():
    """opt (Pallas) and ref (unoptimized delegate) builds of the same model
    must agree — the E4 performance gap may not change results."""
    for stem in ["i3", "y3", "ssd"]:
        fn_o, inputs = model.build(f"{stem}_opt")
        fn_r, _ = model.build(f"{stem}_ref")
        x = jax.random.uniform(
            jax.random.PRNGKey(7), inputs[0].shape, jnp.float32, 0.0, 1.0
        )
        for a, b in zip(fn_o(x), fn_r(x)):
            np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4)


def test_weights_are_deterministic():
    fn1, inputs = inception_small.build(BACKENDS["opt"])
    fn2, _ = inception_small.build(BACKENDS["opt"])
    x = jnp.ones(inputs[0].shape, jnp.float32) * 0.3
    np.testing.assert_array_equal(fn1(x)[0], fn2(x)[0])


def test_model_cost_ordering():
    """Relative model cost must preserve the paper's structure:
    Y3 heavier than I3 (Table I throughput ordering)."""
    flops = {}
    for stem in ["i3", "y3"]:
        fn, inputs = model.build(f"{stem}_opt")
        lowered = jax.jit(fn).lower(*inputs)
        cost = lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        flops[stem] = cost.get("flops", 0)
    assert flops["y3"] > 1.5 * flops["i3"], flops


def test_registry_is_complete():
    names = set(model.registry())
    for expected in [
        "i3_opt", "i3_ref", "y3_opt", "y3_ref", "ssd_opt", "ssd_ref",
        "rnet_opt", "onet_opt", "ars_a_opt", "ars_b_opt", "ars_c_opt",
    ] + [f"pnet_s{i}_opt" for i in range(len(mtcnn.PYRAMID))]:
        assert expected in names, expected


def test_ssd_anchor_count_consistent():
    assert ssdlite_small.NUM_ANCHORS == 360


def test_ars_stage_shapes_match_pipeline_wiring():
    # the Rust ARS pipeline merges 8 channels and aggregates 4x128 windows
    _, inputs = ars.build_ars_b(BACKENDS["opt"])
    assert inputs[0].shape == (1, 512, 8)
