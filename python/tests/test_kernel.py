"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose against ref.py is the CORE
correctness signal for everything the AOT artifacts compute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import kernels  # noqa: E402
from compile.kernels import ref  # noqa: E402

ACTS = ["none", "relu", "relu6", "sigmoid", "prelu", "softmax"]


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 160),
    n=st.integers(1, 140),
    with_bias=st.booleans(),
    act_i=st.integers(0, len(ACTS) - 1),
)
def test_matmul_matches_ref(m, k, n, with_bias, act_i):
    act = ACTS[act_i]
    x = rand(m * 7 + 1, (m, k))
    y = rand(n * 13 + 2, (k, n))
    b = rand(5, (n,)) if with_bias else None
    got = kernels.matmul_bias_act(x, y, b, act=act)
    want = ref.matmul_bias_act(x, y, b, act=act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(5, 24),
    w=st.integers(5, 24),
    cin=st.integers(1, 5),
    cout=st.integers(1, 8),
    kh=st.integers(1, 3),
    stride=st.integers(1, 2),
    same=st.booleans(),
)
def test_conv2d_matches_ref(h, w, cin, cout, kh, stride, same):
    padding = "SAME" if same else "VALID"
    x = rand(h * w + 3, (1, h, w, cin))
    wgt = rand(cout + 17, (kh, kh, cin, cout))
    b = rand(23, (cout,))
    got = kernels.conv2d(x, wgt, b, stride=stride, padding=padding, act="relu")
    want = ref.conv2d(x, wgt, b, stride=stride, padding=padding, act="relu")
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(4, 64),
    cin=st.integers(1, 6),
    cout=st.integers(1, 8),
    kt=st.integers(1, 5),
    stride=st.integers(1, 2),
)
def test_conv1d_matches_ref(t, cin, cout, kt, stride):
    x = rand(t + 31, (1, t, cin))
    wgt = rand(cout + 41, (kt, cin, cout))
    got = kernels.conv1d(x, wgt, stride=stride, act="none")
    want = ref.conv1d(x, wgt, stride=stride, act="none")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_unopt_backend_matches_opt():
    """The `ref`(unoptimized delegate) and `opt`(Pallas) backends compute
    the same function — E4's NNFW-version gap must be speed, not values."""
    x = rand(1, (2, 9, 9, 3))
    w = rand(2, (3, 3, 3, 4))
    b = rand(3, (4,))
    a = kernels.conv2d(x, w, b, act="relu6")
    c = ref.conv2d_unopt(x, w, b, act="relu6")
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-5)


def test_softmax_normalizes_despite_padding():
    """Regression: fused softmax over MXU-padded tiles must not leak
    padded columns into the denominator."""
    x = rand(11, (1, 96))
    y = rand(12, (96, 100))  # 100 pads to 104
    out = kernels.matmul_bias_act(x, y, act="softmax")
    np.testing.assert_allclose(jnp.sum(out), 1.0, rtol=1e-5)


def test_matmul_rejects_bad_contraction():
    with pytest.raises(AssertionError):
        kernels.matmul(jnp.zeros((3, 4)), jnp.zeros((5, 6)))
