//! # nnstreamer-rs
//!
//! A reproduction of **NNStreamer: Efficient and Agile Development of
//! On-Device AI Systems** (Ham et al., 2021) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! NNStreamer treats neural networks as *filters* of *stream pipelines*
//! (pipe-and-filter architecture). This crate implements the streaming
//! framework (Layer 3) in Rust: tensor stream types, caps negotiation,
//! a pipeline graph whose elements run as **step-driven tasks on a
//! bounded worker pool** connected by bounded inboxes, the full set of
//! `tensor_*` elements from the paper, NNFW sub-plugins that execute
//! AOT-compiled JAX/Pallas artifacts, and the baselines ("Control"
//! serial implementations and a MediaPipe-like framework) needed to
//! regenerate every table and figure of the paper's evaluation.
//!
//! Four hot-path subsystems keep steady-state streaming cheap (see
//! DESIGN.md):
//!
//! * a shared **model-instance pool** ([`runtime::ModelPool`]) — pipeline
//!   branches referencing the same artifact lease one loaded model;
//! * **batched execution** (`tensor_filter batch=N latency-budget=M`) —
//!   ready frames are stacked into a single dispatch and de-batched with
//!   their original timestamps, amortizing per-dispatch overhead;
//! * a **chunk-recycling memory subsystem** ([`tensor::ChunkPool`] +
//!   [`tensor::Chunk::make_mut`]) — per-frame kernels and model-output
//!   scratch write into recycled buffers, and uniquely-owned chunks
//!   mutate in place (copy-on-write), so the steady-state hot path runs
//!   without fresh heap allocations;
//! * a **worker-pool executor** ([`pipeline::Executor`]) — every element
//!   is a cooperative task (ready / parked-on-input / parked-on-output /
//!   parked-external), so N pipelines of E elements run on O(workers)
//!   threads instead of N×E, and a [`pipeline::PipelineHub`] hosts
//!   whole fleets of concurrent pipelines with per-pipeline priorities
//!   over one pool (`NNS_WORKERS` sizes the global pool).
//!
//! The public API is layered like the paper's (see DESIGN.md "Public
//! API"): gst-launch strings ([`pipeline::Pipeline::parse`]), a typed
//! fluent builder ([`pipeline::PipelineBuilder`]) over per-element
//! props structs ([`element::Props`]), app I/O (`appsrc` push handles,
//! `tensor_sink` callbacks), and a live-control surface on a playing
//! pipeline ([`pipeline::Running`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use nnstreamer::pipeline::Pipeline;
//!
//! # fn main() -> nnstreamer::Result<()> {
//! let mut pipeline = Pipeline::parse(
//!     "videotestsrc num-buffers=32 ! videoconvert format=RGB ! \
//!      tensor_converter ! tensor_transform mode=normalize ! \
//!      tensor_sink name=out",
//! )?;
//! pipeline.run()?;
//! # Ok(())
//! # }
//! ```
//!
//! The same pipeline through the typed builder (properties are struct
//! fields, checked at compile/construction time):
//!
//! ```no_run
//! use nnstreamer::elements::converter::TensorConverterProps;
//! use nnstreamer::elements::sinks::TensorSinkProps;
//! use nnstreamer::elements::sources::VideoTestSrcProps;
//! use nnstreamer::elements::transform::TensorTransformProps;
//! use nnstreamer::pipeline::PipelineBuilder;
//!
//! # fn main() -> nnstreamer::Result<()> {
//! let mut b = PipelineBuilder::new();
//! b.chain(VideoTestSrcProps { num_buffers: Some(32), ..Default::default() })?
//!     .chain(TensorConverterProps)?
//!     .chain(TensorTransformProps::normalize())?
//!     .chain_named("out", TensorSinkProps::default())?;
//! let mut pipeline = b.build();
//! pipeline.run()?;
//! # Ok(())
//! # }
//! ```

// Non-safe code is confined to two audited modules — `tensor/buffer.rs`
// (alignment casts) and `metrics/process.rs` (sysconf) — each carrying
// a module-level opt-out attribute and `// SAFETY:` comments on every
// site. CI greps that the opt-out appears nowhere else (see Makefile
// `unsafe-audit`).
#![deny(unsafe_code)]

pub mod apps;
pub mod baselines;
pub mod sync;
pub mod devices;
pub mod element;
pub mod elements;
pub mod error;
pub mod metrics;
pub mod net;
pub mod nnfw;
pub mod pipeline;
pub mod runtime;
pub mod tensor;
pub mod video;

pub use error::{Error, Fault, Result};

/// The concurrency shim under its design-doc name: `nns_sync::Mutex`
/// et al. compile to `std::sync` in normal builds and to the nnscheck
/// controlled scheduler under `--features check` (see DESIGN.md
/// "Concurrency contracts").
pub use crate::sync as nns_sync;
