//! Typed element properties — the compile-time-checked construction path.
//!
//! Every built-in element declares a props struct (`QueueProps`,
//! `TensorFilterProps`, ...) implementing [`Props`]. All three ways of
//! configuring an element meet in that one struct:
//!
//! * the **launch-string parser** and [`Graph::set_property`] deserialize
//!   `key=value` text into it through [`Props::set`];
//! * the **builder API** ([`PipelineBuilder`]) consumes the struct
//!   directly, so applications get field types (enums, `usize`,
//!   `Duration`, [`Caps`]) instead of strings;
//! * **runtime control** ([`ControlMsg::SetProperty`]) re-enters through
//!   the same [`Props::set`] on a playing element.
//!
//! [`Graph::set_property`]: crate::pipeline::Graph::set_property
//! [`PipelineBuilder`]: crate::pipeline::PipelineBuilder
//! [`ControlMsg::SetProperty`]: super::ControlMsg::SetProperty
//! [`Caps`]: crate::tensor::Caps

use crate::element::Element;
use crate::error::{Error, Result};

/// Typed properties of one element kind.
///
/// `Default` carries the element's documented defaults, so builder code
/// only spells out what it overrides:
///
/// ```
/// use nnstreamer::element::Props;
/// use nnstreamer::elements::flow::QueueProps;
///
/// let q = QueueProps {
///     max_size_buffers: 2,
///     ..Default::default()
/// };
/// assert_eq!(QueueProps::FACTORY, "queue");
/// assert!(!q.leaky);
/// ```
pub trait Props: Default + Send + 'static {
    /// Factory name of the element this configures (e.g. `"queue"`).
    const FACTORY: &'static str;

    /// Property keys understood by the string front-end.
    const KEYS: &'static [&'static str];

    /// Set one property from its launch-string form.
    fn set(&mut self, key: &str, value: &str) -> Result<()>;

    /// Instantiate the element, consuming the props.
    fn into_element(self) -> Result<Box<dyn Element>>;
}

/// Construction of a concrete element from its typed props — the inverse
/// direction of [`Props::into_element`] with the element type preserved
/// (used when the caller needs the concrete type, e.g. to grab an
/// `AppSrc` push handle before the pipeline starts).
pub trait FromProps: Element + Sized {
    type Props: Props;

    /// Build the element. Fallible so props with invariants the type
    /// system cannot express (e.g. `batch <= MAX_BATCH`) can reject.
    fn from_props(props: Self::Props) -> Result<Self>;
}

/// Uniform "unknown property" error, with a nearest-key suggestion when
/// the key looks like a typo of a real one.
pub(crate) fn unknown_property(
    factory: &str,
    keys: &'static [&'static str],
    key: &str,
    value: &str,
) -> Error {
    let suggestion = crate::element::registry::did_you_mean(key, keys.iter().copied());
    Error::Property {
        key: key.into(),
        value: value.into(),
        reason: format!("unknown property of {factory}{suggestion}"),
    }
}

/// Shared boolean parsing of the launch-string front-end (`true`/`1`).
pub(crate) fn parse_bool(value: &str) -> bool {
    value == "true" || value == "1"
}
