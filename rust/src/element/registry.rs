//! Element factory registry — the plugin system.
//!
//! Like GStreamer's registry, element types are registered by name and
//! instantiated by factories; anything (including user code) can register
//! additional elements, which is how NNStreamer itself extends GStreamer.

use std::collections::HashMap;
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::element::Element;
use crate::error::{Error, Result};

type Factory = Box<dyn Fn() -> Box<dyn Element> + Send + Sync>;

static REGISTRY: Lazy<Mutex<HashMap<String, Factory>>> = Lazy::new(|| {
    let mut m: HashMap<String, Factory> = HashMap::new();
    crate::elements::register_builtins(&mut m);
    Mutex::new(m)
});

/// Handle to the global element registry.
pub struct Registry;

impl Registry {
    /// Instantiate an element by factory name.
    pub fn make(name: &str) -> Result<Box<dyn Element>> {
        let reg = REGISTRY.lock().unwrap();
        let factory = reg
            .get(name)
            .ok_or_else(|| Error::Parse(format!("no such element factory {name:?}")))?;
        Ok(factory())
    }

    /// Register a custom element factory (plug-in style).
    pub fn register<F>(name: &str, factory: F)
    where
        F: Fn() -> Box<dyn Element> + Send + Sync + 'static,
    {
        REGISTRY
            .lock()
            .unwrap()
            .insert(name.to_string(), Box::new(factory));
    }

    /// Names of all registered factories (sorted).
    pub fn names() -> Vec<String> {
        let mut v: Vec<String> = REGISTRY.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn exists(name: &str) -> bool {
        REGISTRY.lock().unwrap().contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered() {
        for name in [
            "tensor_converter",
            "tensor_filter",
            "tensor_mux",
            "tensor_demux",
            "tensor_aggregator",
            "tensor_transform",
            "queue",
            "tee",
            "videotestsrc",
            "appsink",
        ] {
            assert!(Registry::exists(name), "missing builtin {name}");
        }
    }

    #[test]
    fn unknown_element_errors() {
        assert!(Registry::make("definitely_not_an_element").is_err());
    }
}
