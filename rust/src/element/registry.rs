//! Element factory registry — the plugin system.
//!
//! Like GStreamer's registry, element types are registered by name and
//! instantiated by factories; anything (including user code) can register
//! additional elements, which is how NNStreamer itself extends GStreamer.

use std::collections::HashMap;
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::element::Element;
use crate::error::{Error, Result};

type Factory = Box<dyn Fn() -> Box<dyn Element> + Send + Sync>;

static REGISTRY: Lazy<Mutex<HashMap<String, Factory>>> = Lazy::new(|| {
    let mut m: HashMap<String, Factory> = HashMap::new();
    crate::elements::register_builtins(&mut m);
    Mutex::new(m)
});

/// Handle to the global element registry.
pub struct Registry;

impl Registry {
    /// Instantiate an element by factory name. Unknown names report the
    /// nearest registered factory (edit distance <= 2) as a suggestion.
    pub fn make(name: &str) -> Result<Box<dyn Element>> {
        let reg = REGISTRY.lock().unwrap();
        let factory = reg.get(name).ok_or_else(|| {
            let names = reg.keys().map(String::as_str);
            Error::Parse(format!(
                "no such element factory {name:?}{}",
                did_you_mean(name, names)
            ))
        })?;
        Ok(factory())
    }

    /// Register a custom element factory (plug-in style).
    pub fn register<F>(name: &str, factory: F)
    where
        F: Fn() -> Box<dyn Element> + Send + Sync + 'static,
    {
        REGISTRY
            .lock()
            .unwrap()
            .insert(name.to_string(), Box::new(factory));
    }

    /// Names of all registered factories (sorted).
    pub fn names() -> Vec<String> {
        let mut v: Vec<String> = REGISTRY.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn exists(name: &str) -> bool {
        REGISTRY.lock().unwrap().contains_key(name)
    }
}

/// A `" (did you mean ...?)"` suffix naming the closest candidate, or
/// empty when nothing is within typo distance. The single formatting
/// point shared by factory lookup, unknown-property errors, and the
/// live-control surface. Candidates are sorted internally so iteration
/// order does not affect tie-breaking.
pub(crate) fn did_you_mean<'a>(
    target: &str,
    candidates: impl IntoIterator<Item = &'a str>,
) -> String {
    let mut sorted: Vec<&str> = candidates.into_iter().collect();
    sorted.sort_unstable();
    match nearest(target, sorted) {
        Some(s) => format!(" (did you mean {s:?}?)"),
        None => String::new(),
    }
}

/// Nearest candidate by Levenshtein distance, accepting only close typos
/// (distance <= 2). Ties resolve to the earliest candidate, so pass the
/// candidates in a deterministic (sorted) order.
fn nearest<'a>(
    target: &str,
    candidates: impl IntoIterator<Item = &'a str>,
) -> Option<&'a str> {
    let mut best: Option<(usize, &str)> = None;
    for cand in candidates {
        let d = edit_distance(target, cand);
        if d <= 2 {
            match best {
                Some((bd, _)) if bd <= d => {}
                _ => best = Some((d, cand)),
            }
        }
    }
    best.map(|(_, name)| name)
}

/// Classic dynamic-programming Levenshtein distance over bytes.
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered() {
        for name in [
            "tensor_converter",
            "tensor_filter",
            "tensor_mux",
            "tensor_demux",
            "tensor_aggregator",
            "tensor_transform",
            "queue",
            "tee",
            "videotestsrc",
            "appsink",
        ] {
            assert!(Registry::exists(name), "missing builtin {name}");
        }
    }

    #[test]
    fn unknown_element_errors() {
        assert!(Registry::make("definitely_not_an_element").is_err());
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("queue", "queue"), 0);
        assert_eq!(edit_distance("qeueu", "queue"), 2);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn unknown_factory_suggests_nearest_name() {
        let err = Registry::make("qeueu").unwrap_err().to_string();
        assert!(err.contains("no such element factory"), "{err}");
        assert!(err.contains("did you mean \"queue\"?"), "{err}");

        let err = Registry::make("tensor_filtr").unwrap_err().to_string();
        assert!(err.contains("did you mean \"tensor_filter\"?"), "{err}");

        // far-away garbage gets no suggestion
        let err = Registry::make("zzzzzzzz").unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
    }
}
