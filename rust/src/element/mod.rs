//! The element model: pipe-and-filter nodes with pads, properties, caps.
//!
//! Mirrors GStreamer's model at the granularity the paper relies on:
//! elements expose *sink pads* (inputs) and *src pads* (outputs), declare
//! caps through negotiation, and process timestamped [`Buffer`]s. The
//! executor (in [`crate::pipeline::executor`]) runs each element as a
//! **step-driven task on a shared worker pool** and connects pads with
//! bounded inboxes — GStreamer's "transparent and easy-to-apply
//! parallelism" (§III requirement list) at O(workers) threads instead of
//! O(elements).

pub mod props;
pub mod registry;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Fault, Result};
use crate::metrics::stats::{Domain, ElementStats};
use crate::pipeline::executor::{Inbox, PopResult, PushResult, Waker};
use crate::pipeline::fault::{FaultInjector, FaultKind};
use crate::tensor::{Buffer, Caps};

pub use props::{FromProps, Props};
pub use registry::Registry;

/// What flows over a link.
#[derive(Debug, Clone)]
pub enum Item {
    Buffer(Buffer),
    /// End of stream on this pad.
    Eos,
}

/// A buffer observer attached to a sink element at runtime
/// ([`ControlMsg::Subscribe`]).
pub type BufferCallback = Box<dyn FnMut(&Buffer) + Send>;

/// Runtime control message for an element of a *playing* pipeline.
///
/// Delivered through a per-element control channel owned by the scheduler
/// and applied by the element's own thread, strictly **before** the next
/// buffer (or EOS) it processes — so a message sent before a buffer
/// enters the pipeline is guaranteed to be in effect when that buffer
/// reaches the element.
pub enum ControlMsg {
    /// Apply a property change, same string form as the parser. Routed
    /// into the element's typed [`Props`] via
    /// [`Element::set_property`].
    SetProperty { key: String, value: String },
    /// Attach a buffer callback (supported by `tensor_sink`).
    Subscribe(BufferCallback),
}

impl std::fmt::Debug for ControlMsg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlMsg::SetProperty { key, value } => {
                write!(f, "SetProperty({key}={value})")
            }
            ControlMsg::Subscribe(_) => write!(f, "Subscribe(..)"),
        }
    }
}

/// Element processing verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flow {
    Continue,
    /// The element is done (it will produce nothing more): the scheduler
    /// sends EOS downstream and drains remaining input.
    Eos,
    /// The element cannot make progress *right now*: the executor parks
    /// its task until an external [`Waker`] fires (obtain one via
    /// [`Ctx::waker`] and hand it to the application side). Sources
    /// return it when they have nothing to produce (`appsrc` waiting for
    /// an application push); consumers **must first hand the undelivered
    /// item back** via [`Ctx::push_back_input`] so it is replayed on the
    /// next step (`appsink` waiting for the application to drain its
    /// channel). Outputs pushed before a `Wait` keep their backpressure:
    /// the executor re-checks saturated links when the wake fires.
    Wait,
}

/// How a link delivers when the consumer is saturated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// Block the producer (GStreamer's default push semantics).
    Blocking,
    /// Drop the new buffer (a `leaky=downstream` queue).
    Leaky,
}

/// Sending half of a link, as seen from the producer's src pad.
///
/// Delivers into the consumer's [`Inbox`]. Pushes never block a pool
/// worker: a blocking-delivery push that fills the inbox to capacity
/// instead records the inbox as *saturated* so the executor parks the
/// producing task after the step — backpressure without thread
/// blocking, same steady-state semantics as the seed's `SyncSender`.
pub struct LinkSender {
    inbox: Arc<Inbox>,
    dst_pad: usize,
    delivery: Delivery,
    dst_stats: Arc<ElementStats>,
}

impl LinkSender {
    pub(crate) fn new(
        inbox: Arc<Inbox>,
        dst_pad: usize,
        delivery: Delivery,
        dst_stats: Arc<ElementStats>,
    ) -> Self {
        Self {
            inbox,
            dst_pad,
            delivery,
            dst_stats,
        }
    }

    pub(crate) fn inbox(&self) -> &Arc<Inbox> {
        &self.inbox
    }

    /// Deliver an item; returns false if the consumer is gone. Blocking
    /// links that reach capacity are appended to `saturated` for the
    /// executor's park-on-output decision.
    fn send(&self, item: Item, saturated: &mut Vec<Arc<Inbox>>) -> bool {
        match self.delivery {
            Delivery::Blocking => match self.inbox.push(self.dst_pad, item) {
                PushResult::Delivered { saturated: true } => {
                    if !saturated.iter().any(|ib| Arc::ptr_eq(ib, &self.inbox)) {
                        saturated.push(self.inbox.clone());
                    }
                    true
                }
                PushResult::Delivered { saturated: false } | PushResult::Dropped => true,
                PushResult::Closed => false,
            },
            Delivery::Leaky => match self.inbox.push_leaky(self.dst_pad, item) {
                PushResult::Delivered { .. } => true,
                PushResult::Dropped => {
                    self.dst_stats.record_drop();
                    true
                }
                PushResult::Closed => false,
            },
        }
    }

    /// Deliver EOS. End-of-stream markers bypass leaky dropping (losing
    /// one would stall the consumer's EOS accounting until producer
    /// teardown) and never park the sender — it is finishing anyway.
    fn send_eos(&self) {
        let _ = self.inbox.push(self.dst_pad, Item::Eos);
    }
}

/// Execution context handed to an element while it processes.
pub struct Ctx {
    /// One sender per src pad (index = src pad index).
    pub(crate) outputs: Vec<Option<LinkSender>>,
    pub(crate) stats: Arc<ElementStats>,
    pub(crate) stop: Arc<AtomicBool>,
    /// Pipeline epoch: pts 0 corresponds to this instant (live pacing and
    /// end-to-end latency measurement).
    pub epoch: Instant,
    /// Which compute domain this element's busy time is charged to.
    pub domain: Domain,
    /// Time spent waiting (blocked pushes, live pacing) during the current
    /// handle()/generate() call — subtracted from busy-time accounting.
    pub(crate) idle_ns: u64,
    /// The element's input inbox (None for sources and test harnesses).
    /// Owned by the ctx so elements can drain additional ready items
    /// mid-`handle` (the batching path of `tensor_filter`).
    pub(crate) input: Option<Arc<Inbox>>,
    /// Items pulled ahead by an element and returned via
    /// [`push_back_input`](Ctx::push_back_input); delivered before the
    /// inbox on the next scheduler step.
    pub(crate) pending: VecDeque<(usize, Item)>,
    /// Runtime control mailbox (live property changes, subscriptions);
    /// drained by the executor at every step entry.
    pub(crate) control: Option<Receiver<ControlMsg>>,
    /// This task's waker (for elements that park on external events).
    pub(crate) waker: Option<Waker>,
    /// Inboxes this step's blocking pushes filled to capacity; the
    /// executor parks the task on them after the step.
    pub(crate) saturated: Vec<Arc<Inbox>>,
    /// Deadline budget of this pipeline in ns (0 = disabled). A buffer
    /// whose pts lies more than this budget in the past is *late*: it
    /// is shed at the next link crossing or step gate instead of
    /// consuming further compute (see [`Ctx::past_deadline`]).
    pub(crate) deadline_ns: u64,
    /// Deterministic fault injector for this element (chaos testing);
    /// None in production. The executor consults it in the step path —
    /// see [`Ctx::check_injected_fault`] for the step-index contract.
    pub(crate) injector: Option<FaultInjector>,
    /// Deadline set by [`Ctx::park_until`] during the current step: the
    /// executor drains it after a [`Flow::Wait`] and parks the task on
    /// the timer wheel instead of the external-waker path.
    pub(crate) timer_deadline: Option<Instant>,
}

impl Ctx {
    /// Is `buf` past this pipeline's deadline budget? Always false when
    /// no deadline is configured (`deadline_ns == 0`), so
    /// correctness-mode pipelines take the exact pre-QoS path. Lateness
    /// is pts-relative: elements preserve `pts_ns` when deriving
    /// buffers, so the budget covers the whole chain from source stamp
    /// to sink without any per-hop re-stamping.
    pub(crate) fn past_deadline(&self, buf: &Buffer) -> bool {
        if self.deadline_ns == 0 {
            return false;
        }
        let now = Instant::now().duration_since(self.epoch).as_nanos() as u64;
        now > buf.pts_ns.saturating_add(self.deadline_ns)
    }

    /// Push a buffer out of src pad `pad`. Never blocks: filling a
    /// bounded downstream link to capacity parks this element's task
    /// after the current step (backpressure without holding a worker).
    /// With a deadline budget configured, a late buffer is shed here —
    /// at the link crossing — and charged to this element's `shed`
    /// counter instead of filling downstream queues with dead frames.
    pub fn push(&mut self, pad: usize, buf: Buffer) -> Result<()> {
        if self.past_deadline(&buf) {
            self.stats.record_shed();
            return Ok(());
        }
        let bytes = buf.size();
        let Some(sender) = self.outputs.get(pad).and_then(Option::as_ref) else {
            // unlinked src pad: buffer is discarded (like an unlinked tee pad)
            return Ok(());
        };
        let delivered = sender.send(Item::Buffer(buf), &mut self.saturated);
        if !delivered {
            // downstream went away: treat as stop request, not an error
            self.stop.store(true, Ordering::Relaxed);
        }
        self.stats.record_out(bytes);
        Ok(())
    }

    /// Sleep until the pipeline-relative deadline `pts_ns`, accounted as
    /// idle time (live-source pacing). **Blocks the calling worker** —
    /// executor-run elements should use
    /// [`park_until_pts`](Ctx::park_until_pts) instead, which parks the
    /// task on the timer wheel at zero worker cost.
    pub fn sleep_until_pts(&mut self, pts_ns: u64) {
        let t0 = Instant::now();
        crate::pipeline::scheduler::sleep_until(self.epoch, pts_ns);
        self.idle_ns += t0.elapsed().as_nanos() as u64;
    }

    /// Timed park primitive. Returns `true` when a deadline park was
    /// armed: the element must return [`Flow::Wait`] without producing,
    /// and its step re-runs once the executor's timer wheel fires (never
    /// early, so re-checking the deadline on re-entry yields `false`).
    /// Returns `false` when the deadline already passed — proceed now —
    /// or when the ctx runs outside the executor (no waker), in which
    /// case the wait already happened as a blocking, idle-accounted
    /// sleep, preserving the pre-timer-wheel behavior for direct drives.
    pub fn park_until(&mut self, deadline: Instant) -> bool {
        let now = Instant::now();
        if now >= deadline {
            return false;
        }
        if self.waker.is_some() {
            self.timer_deadline = Some(deadline);
            true
        } else {
            std::thread::sleep(deadline - now);
            self.idle_ns += (deadline - now).as_nanos() as u64;
            false
        }
    }

    /// [`park_until`](Ctx::park_until) against a pipeline-relative pts
    /// deadline — the live-source pacing path (`is-live=true` sources
    /// call this instead of [`sleep_until_pts`](Ctx::sleep_until_pts)).
    pub fn park_until_pts(&mut self, pts_ns: u64) -> bool {
        self.park_until(self.epoch + Duration::from_nanos(pts_ns))
    }

    /// Executor-internal: drain the deadline a step set via
    /// [`park_until`](Ctx::park_until).
    pub(crate) fn take_timer_deadline(&mut self) -> Option<Instant> {
        self.timer_deadline.take()
    }

    /// Executor-internal: replay an item at the *front* of the pending
    /// queue (exact redelivery order), for steps interrupted before
    /// consuming it.
    pub(crate) fn replay_input(&mut self, pad: usize, item: Item) {
        self.pending.push_front((pad, item));
    }

    /// Does this ctx belong to an executor task (i.e. can a parked step
    /// be woken)? Elements fall back to blocking dispatch when not.
    pub fn has_waker(&self) -> bool {
        self.waker.is_some()
    }

    /// Charge modeled device/envelope occupancy to this element's busy
    /// time. The async device lane completes jobs while the element is
    /// parked, so the worker-measured step time no longer contains the
    /// service window; draining elements charge it here to keep
    /// busy-time (Table III / E3) accounting identical to the blocking
    /// dispatch path.
    pub fn charge_busy(&self, d: Duration) {
        self.stats.record_busy(self.domain, d);
    }

    /// Device-lane observability: one async submit entered a device queue.
    pub fn record_device_submit(&self) {
        self.stats.record_device_submit();
    }

    /// Device-lane observability: one completion wakeup drained a result.
    pub fn record_device_completion(&self) {
        self.stats.record_device_completion();
    }

    /// Take and reset the idle counter (scheduler-internal).
    pub(crate) fn take_idle(&mut self) -> std::time::Duration {
        std::time::Duration::from_nanos(std::mem::take(&mut self.idle_ns))
    }

    /// Record an arrival pulled from the input channel. Items replayed
    /// from the push-back queue are *not* re-recorded, so every buffer is
    /// counted exactly once however it reaches the element. Terminal
    /// elements (no src pads) additionally record the end-to-end frame
    /// latency (arrival − pts) into the pipeline's percentile histogram.
    fn record_arrival(&self, item: &(usize, Item)) {
        if let Item::Buffer(buf) = &item.1 {
            let at = Instant::now().duration_since(self.epoch).as_nanos() as u64;
            self.stats.record_in_at(at);
            if self.outputs.is_empty() {
                self.stats.record_e2e_latency_ns(at.saturating_sub(buf.pts_ns));
            }
        }
    }

    /// Executor-internal poll of the next input item: pushed-back items
    /// first, then the inbox. Distinguishes "nothing queued yet" (park
    /// on input) from "no producer remains" (end of input). Elements
    /// receive items through [`Element::handle`] and drain extras with
    /// [`try_pull_input`](Ctx::try_pull_input).
    pub(crate) fn poll_input(&mut self) -> PopResult {
        if let Some(item) = self.pending.pop_front() {
            return PopResult::Item(item);
        }
        let Some(inbox) = self.input.as_ref() else {
            return PopResult::Exhausted;
        };
        match inbox.try_pop() {
            PopResult::Item(item) => {
                self.record_arrival(&item);
                PopResult::Item(item)
            }
            other => other,
        }
    }

    /// Non-blocking attempt to pull one more queued input item while
    /// processing (the `tensor_filter` batch-aggregation path). Returns
    /// `None` when nothing is ready or the element has no input inbox.
    ///
    /// An element that pulls an item it cannot consume — in particular
    /// [`Item::Eos`] — **must** hand it back via
    /// [`push_back_input`](Ctx::push_back_input) so the scheduler's
    /// end-of-stream accounting stays correct.
    pub fn try_pull_input(&mut self) -> Option<(usize, Item)> {
        if let Some(item) = self.pending.pop_front() {
            return Some(item);
        }
        let inbox = self.input.as_ref()?;
        match inbox.try_pop() {
            PopResult::Item(item) => {
                self.record_arrival(&item);
                Some(item)
            }
            _ => None,
        }
    }

    /// Like [`try_pull_input`](Ctx::try_pull_input), but waits up to
    /// `timeout` for an item. The wait is accounted as idle time, not
    /// element busy time. On the pooled executor this holds one worker
    /// for at most `timeout` (the `tensor_filter` latency budget), so
    /// budgets should stay in the milliseconds.
    pub fn pull_input_timeout(&mut self, timeout: Duration) -> Option<(usize, Item)> {
        if let Some(item) = self.pending.pop_front() {
            return Some(item);
        }
        let t0 = Instant::now();
        let item = match self.input.as_ref() {
            Some(inbox) => inbox.pop_timeout(timeout),
            None => None,
        };
        self.idle_ns += t0.elapsed().as_nanos() as u64;
        if let Some(it) = &item {
            self.record_arrival(it);
        }
        item
    }

    /// Return an item obtained from [`try_pull_input`](Ctx::try_pull_input)
    /// / [`pull_input_timeout`](Ctx::pull_input_timeout) that the element
    /// did not consume. It is redelivered (in pull order) before any new
    /// channel items.
    pub fn push_back_input(&mut self, pad: usize, item: Item) {
        self.pending.push_back((pad, item));
    }

    /// Non-blocking pull of the next pending control message
    /// (scheduler-internal; applied via [`Element::handle_control`]).
    pub(crate) fn try_pull_control(&mut self) -> Option<ControlMsg> {
        self.control.as_ref()?.try_recv().ok()
    }

    /// Send EOS on one src pad.
    pub fn push_eos(&mut self, pad: usize) {
        if let Some(sender) = self.outputs.get(pad).and_then(Option::as_ref) {
            sender.send_eos();
        }
    }

    /// This task's waker: hand it to application-side code that must
    /// unpark a source which returned [`Flow::Wait`] (see
    /// [`crate::pipeline::executor::SharedWaker`]). A no-op waker is
    /// returned for contexts outside the executor (tests).
    pub fn waker(&self) -> Waker {
        self.waker.clone().unwrap_or_default()
    }

    pub(crate) fn set_waker(&mut self, waker: Waker) {
        self.waker = Some(waker);
    }

    /// Executor-internal: reset per-step state before an element runs.
    pub(crate) fn begin_step(&mut self) {
        self.saturated.clear();
    }

    /// Executor-internal: the inboxes this step saturated (park targets).
    pub(crate) fn take_saturated(&mut self) -> Vec<Arc<Inbox>> {
        std::mem::take(&mut self.saturated)
    }

    /// Executor-internal teardown on task finish: detach from every
    /// downstream inbox so consumers observe end-of-input once drained
    /// (the pooled analog of dropping a channel sender).
    pub(crate) fn release_outputs(&mut self) {
        self.release_outputs_fault(None);
    }

    /// Like [`release_outputs`](Ctx::release_outputs), but first stamps a
    /// fault close-reason on every downstream inbox. Consumers drain
    /// whatever was already queued, then observe end-of-input *with* the
    /// fault attached — partial output is flagged instead of passing for
    /// a clean EOS, and the fault record keeps its origin across hops.
    pub(crate) fn release_outputs_fault(&mut self, fault: Option<&Fault>) {
        for sender in self.outputs.iter().flatten() {
            if let Some(f) = fault {
                sender.inbox().producer_fault(f);
            }
            sender.inbox().producer_done();
        }
        self.outputs.clear();
    }

    /// The fault (if any) recorded on this element's own input inbox by
    /// a dead upstream producer. Checked by the executor when input is
    /// exhausted, before deciding between the clean-EOS flush path and
    /// fault propagation.
    pub(crate) fn input_fault(&self) -> Option<Fault> {
        self.input.as_ref().and_then(|ib| ib.fault())
    }

    /// Consult the fault injector for a fault armed at the *current*
    /// step index, without consuming it (`Drop` faults and retried steps
    /// need the spec to stay armed until the step really happens).
    ///
    /// Step-index contract (what "step N" means, per task kind):
    /// * **sources** — the number of *productive* `generate()` calls so
    ///   far, i.e. calls that returned `Ok(Flow::Continue)`; `Wait`
    ///   retries do not advance the index, so index N is deterministic
    ///   for a given pipeline regardless of scheduling.
    /// * **consumers** — the number of `Item::Buffer` arrivals consumed
    ///   so far (EOS markers and control drains do not count). The index
    ///   advances via [`advance_injected_fault`](Ctx::advance_injected_fault)
    ///   exactly once per buffer, before the element's `handle` runs.
    pub(crate) fn check_injected_fault(&mut self) -> Option<FaultKind> {
        self.injector.as_mut().and_then(|inj| inj.check())
    }

    /// Advance the injector's step index (see
    /// [`check_injected_fault`](Ctx::check_injected_fault) for when the
    /// executor calls this).
    pub(crate) fn advance_injected_fault(&mut self) {
        if let Some(inj) = self.injector.as_mut() {
            inj.advance();
        }
    }

    pub fn n_src_pads(&self) -> usize {
        self.outputs.len()
    }

    /// Has someone requested pipeline stop?
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Request pipeline stop (used by sinks with `num-buffers` style caps).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    pub fn stats(&self) -> &Arc<ElementStats> {
        &self.stats
    }
}

/// A pipeline element. Implementations live in [`crate::elements`].
pub trait Element: Send {
    /// Factory name (e.g. `"tensor_converter"`).
    fn type_name(&self) -> &'static str;

    /// Set a property from its string form. The default implementation of
    /// every built-in element delegates to its typed [`Props`] struct, so
    /// the parser, `Graph::set_property` and runtime control all share
    /// one parsing/validation path.
    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        Err(Error::Property {
            key: key.into(),
            value: value.into(),
            reason: format!("{} has no such property", self.type_name()),
        })
    }

    /// Apply a runtime control message (delivered by the scheduler on the
    /// element's own thread, before the next item it processes).
    /// Default: property changes go through
    /// [`set_property`](Element::set_property); subscription is rejected.
    fn handle_control(&mut self, msg: ControlMsg) -> Result<()> {
        match msg {
            ControlMsg::SetProperty { key, value } => self.set_property(&key, &value),
            ControlMsg::Subscribe(_) => Err(Error::element(
                self.type_name(),
                "does not support buffer subscription",
            )),
        }
    }

    /// Number of sink pads this element expects given `n` attached links
    /// (fixed-pad elements must return their fixed count).
    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(1)
    }

    /// Src pad specification.
    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(1)
    }

    /// Caps negotiation: given fixed caps on each sink pad, return the caps
    /// produced on each src pad. Called once before the pipeline starts,
    /// in topological order. `n_srcs` is the number of attached src links.
    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>>;

    /// Downstream caps hint: when a source is directly followed by a
    /// capsfilter, the graph proposes the filter's caps before the
    /// topological negotiation pass (the limited upstream direction of
    /// GStreamer's bidirectional negotiation — `videotestsrc ! video/x-raw,
    /// width=...` configures the source). Default: ignore.
    fn propose_caps(&mut self, _downstream: &Caps) -> Result<()> {
        Ok(())
    }

    /// For capsfilter-like elements: the restriction they will impose
    /// (drives the [`propose_caps`](Element::propose_caps) pre-pass).
    fn proposed_caps(&self) -> Option<Caps> {
        None
    }

    /// Process one input item arriving on sink pad `pad`.
    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow>;

    /// Re-entry point after [`handle`](Element::handle) returned
    /// [`Flow::Wait`]: the executor calls this — instead of polling new
    /// input — on every wake until it stops returning `Flow::Wait`.
    /// Elements that stash work across the wait (a `tensor_filter` with
    /// an in-flight device job) drain it here and return
    /// `Flow::Continue`; `Flow::Wait` parks again (spurious wake or the
    /// completion has not fired). The default covers elements whose
    /// `Wait` handed the item back via
    /// [`Ctx::push_back_input`](Ctx::push_back_input) (appsink): resume
    /// immediately, and the replayed item reaches `handle` on the next
    /// step.
    fn resume(&mut self, _ctx: &mut Ctx) -> Result<Flow> {
        Ok(Flow::Continue)
    }

    /// Called when every sink pad has seen EOS: flush buffered state.
    fn flush(&mut self, _ctx: &mut Ctx) -> Result<()> {
        Ok(())
    }

    /// Called instead of [`flush`](Element::flush) when the element's
    /// stream was truncated by an upstream fault, or on the faulting
    /// element itself as it is torn down. Elements that hand data to
    /// application-side consumers (appsink, tensor_sink, query server
    /// ports) override this to forward the fault as the close-reason of
    /// their app-facing channel — **never** reporting a clean EOS for a
    /// fault-truncated stream. Buffered partial state must not be
    /// emitted as if the stream completed. Default: do nothing.
    fn on_fault(&mut self, _fault: &Fault) {}

    /// Sources produce data instead of consuming it. Return `Flow::Eos`
    /// when exhausted.
    fn generate(&mut self, _ctx: &mut Ctx) -> Result<Flow> {
        Err(Error::element(self.type_name(), "not a source"))
    }

    fn is_source(&self) -> bool {
        matches!(self.sink_pads(), PadSpec::Fixed(0))
    }

    /// Capacity of this element's input channel (a `queue` raises it).
    fn preferred_input_capacity(&self) -> usize {
        1
    }

    /// Link delivery into this element ([`Delivery::Leaky`] for leaky queues).
    fn input_delivery(&self) -> Delivery {
        Delivery::Blocking
    }

    /// Compute domain for busy-time accounting (NPU-bound filters override).
    fn domain(&self) -> Domain {
        Domain::Cpu
    }

    /// Downcast support for elements with post-run state (sinks that
    /// collected data, sources handing out push handles).
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

/// Pad cardinality specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PadSpec {
    Fixed(usize),
    /// Request pads: 1..=max (mux, tee, demux, ...).
    Variadic { max: usize },
}

impl PadSpec {
    /// Validate an attached-link count against the spec.
    pub fn accepts(&self, n: usize) -> bool {
        match *self {
            PadSpec::Fixed(k) => n == k,
            PadSpec::Variadic { max } => n >= 1 && n <= max,
        }
    }
}

/// Test-only helper: drive a single element directly, collecting outputs.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::metrics::stats::Domain;
    use crate::tensor::Buffer;

    /// Build a ctx with `n_src` outputs and return (ctx, capture inboxes).
    pub fn ctx_with_outputs(n_src: usize) -> (Ctx, Vec<Arc<Inbox>>) {
        let stats = crate::metrics::stats::ElementStats::new("testutil");
        let mut outputs = Vec::new();
        let mut pads = Vec::new();
        for _ in 0..n_src {
            let inbox = Inbox::new(1024, stats.clone());
            inbox.add_producer();
            outputs.push(Some(LinkSender::new(
                inbox.clone(),
                0,
                Delivery::Blocking,
                stats.clone(),
            )));
            pads.push(inbox);
        }
        let ctx = Ctx {
            outputs,
            stats,
            stop: Arc::new(AtomicBool::new(false)),
            epoch: Instant::now(),
            domain: Domain::Cpu,
            idle_ns: 0,
            input: None,
            pending: std::collections::VecDeque::new(),
            control: None,
            waker: None,
            saturated: Vec::new(),
            deadline_ns: 0,
            injector: None,
            timer_deadline: None,
        };
        (ctx, pads)
    }

    /// Feed one buffer into sink pad `pad`; drain buffers from src pad 0.
    pub fn drive(el: &mut dyn Element, pad: usize, buf: Buffer) -> Vec<Buffer> {
        let (mut ctx, pads) = ctx_with_outputs(1);
        el.handle(pad, Item::Buffer(buf), &mut ctx).unwrap();
        drop(ctx);
        drain(&pads[0])
    }

    pub fn drain(inbox: &Arc<Inbox>) -> Vec<Buffer> {
        inbox.drain_buffers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padspec_accepts() {
        assert!(PadSpec::Fixed(2).accepts(2));
        assert!(!PadSpec::Fixed(2).accepts(1));
        assert!(PadSpec::Variadic { max: 16 }.accepts(1));
        assert!(PadSpec::Variadic { max: 16 }.accepts(16));
        assert!(!PadSpec::Variadic { max: 16 }.accepts(17));
        assert!(!PadSpec::Variadic { max: 16 }.accepts(0));
    }
}
