//! The element model: pipe-and-filter nodes with pads, properties, caps.
//!
//! Mirrors GStreamer's model at the granularity the paper relies on:
//! elements expose *sink pads* (inputs) and *src pads* (outputs), declare
//! caps through negotiation, and process timestamped [`Buffer`]s. The
//! scheduler (in [`crate::pipeline`]) runs each element on its own thread
//! and connects pads with bounded channels — GStreamer's "transparent and
//! easy-to-apply parallelism" (§III requirement list).

pub mod props;
pub mod registry;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::stats::{Domain, ElementStats};
use crate::tensor::{Buffer, Caps};

pub use props::{FromProps, Props};
pub use registry::Registry;

/// What flows over a link.
#[derive(Debug, Clone)]
pub enum Item {
    Buffer(Buffer),
    /// End of stream on this pad.
    Eos,
}

/// A buffer observer attached to a sink element at runtime
/// ([`ControlMsg::Subscribe`]).
pub type BufferCallback = Box<dyn FnMut(&Buffer) + Send>;

/// Runtime control message for an element of a *playing* pipeline.
///
/// Delivered through a per-element control channel owned by the scheduler
/// and applied by the element's own thread, strictly **before** the next
/// buffer (or EOS) it processes — so a message sent before a buffer
/// enters the pipeline is guaranteed to be in effect when that buffer
/// reaches the element.
pub enum ControlMsg {
    /// Apply a property change, same string form as the parser. Routed
    /// into the element's typed [`Props`] via
    /// [`Element::set_property`].
    SetProperty { key: String, value: String },
    /// Attach a buffer callback (supported by `tensor_sink`).
    Subscribe(BufferCallback),
}

impl std::fmt::Debug for ControlMsg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControlMsg::SetProperty { key, value } => {
                write!(f, "SetProperty({key}={value})")
            }
            ControlMsg::Subscribe(_) => write!(f, "Subscribe(..)"),
        }
    }
}

/// Element processing verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flow {
    Continue,
    /// The element is done (it will produce nothing more): the scheduler
    /// sends EOS downstream and drains remaining input.
    Eos,
}

/// How a link delivers when the consumer is saturated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// Block the producer (GStreamer's default push semantics).
    Blocking,
    /// Drop the new buffer (a `leaky=downstream` queue).
    Leaky,
}

/// Sending half of a link, as seen from the producer's src pad.
pub struct LinkSender {
    tx: SyncSender<(usize, Item)>,
    dst_pad: usize,
    delivery: Delivery,
    dst_stats: Arc<ElementStats>,
}

impl LinkSender {
    pub fn new(
        tx: SyncSender<(usize, Item)>,
        dst_pad: usize,
        delivery: Delivery,
        dst_stats: Arc<ElementStats>,
    ) -> Self {
        Self {
            tx,
            dst_pad,
            delivery,
            dst_stats,
        }
    }

    /// Deliver an item; returns false if the consumer is gone.
    fn send(&self, item: Item) -> bool {
        match self.delivery {
            Delivery::Blocking => self.tx.send((self.dst_pad, item)).is_ok(),
            Delivery::Leaky => match self.tx.try_send((self.dst_pad, item)) {
                Ok(()) => true,
                Err(TrySendError::Full(_)) => {
                    self.dst_stats.record_drop();
                    true
                }
                Err(TrySendError::Disconnected(_)) => false,
            },
        }
    }
}

/// Execution context handed to an element while it processes.
pub struct Ctx {
    /// One sender per src pad (index = src pad index).
    pub(crate) outputs: Vec<Option<LinkSender>>,
    pub(crate) stats: Arc<ElementStats>,
    pub(crate) stop: Arc<AtomicBool>,
    /// Pipeline epoch: pts 0 corresponds to this instant (live pacing and
    /// end-to-end latency measurement).
    pub epoch: Instant,
    /// Which compute domain this element's busy time is charged to.
    pub domain: Domain,
    /// Time spent waiting (blocked pushes, live pacing) during the current
    /// handle()/generate() call — subtracted from busy-time accounting.
    pub(crate) idle_ns: u64,
    /// The element's input channel (None for sources and test harnesses).
    /// Owned by the ctx so elements can drain additional ready items
    /// mid-`handle` (the batching path of `tensor_filter`).
    pub(crate) input: Option<InputReceiver>,
    /// Items pulled ahead by an element and returned via
    /// [`push_back_input`](Ctx::push_back_input); delivered before the
    /// channel on the next scheduler iteration.
    pub(crate) pending: VecDeque<(usize, Item)>,
    /// Runtime control mailbox (live property changes, subscriptions);
    /// drained by the scheduler before each processing step.
    pub(crate) control: Option<Receiver<ControlMsg>>,
}

impl Ctx {
    /// Push a buffer out of src pad `pad`. Time spent blocked on a
    /// saturated downstream is accounted as idle, not busy.
    pub fn push(&mut self, pad: usize, buf: Buffer) -> Result<()> {
        let bytes = buf.size();
        let Some(sender) = self.outputs.get(pad).and_then(Option::as_ref) else {
            // unlinked src pad: buffer is discarded (like an unlinked tee pad)
            return Ok(());
        };
        let t0 = Instant::now();
        let delivered = sender.send(Item::Buffer(buf));
        self.idle_ns += t0.elapsed().as_nanos() as u64;
        if !delivered {
            // downstream went away: treat as stop request, not an error
            self.stop.store(true, Ordering::Relaxed);
        }
        self.stats.record_out(bytes);
        Ok(())
    }

    /// Sleep until the pipeline-relative deadline `pts_ns`, accounted as
    /// idle time (live-source pacing).
    pub fn sleep_until_pts(&mut self, pts_ns: u64) {
        let t0 = Instant::now();
        crate::pipeline::scheduler::sleep_until(self.epoch, pts_ns);
        self.idle_ns += t0.elapsed().as_nanos() as u64;
    }

    /// Take and reset the idle counter (scheduler-internal).
    pub(crate) fn take_idle(&mut self) -> std::time::Duration {
        std::time::Duration::from_nanos(std::mem::take(&mut self.idle_ns))
    }

    /// Record an arrival pulled from the input channel. Items replayed
    /// from the push-back queue are *not* re-recorded, so every buffer is
    /// counted exactly once however it reaches the element.
    fn record_arrival(&self, item: &(usize, Item)) {
        if matches!(item.1, Item::Buffer(_)) {
            let at = Instant::now().duration_since(self.epoch).as_nanos() as u64;
            self.stats.record_in_at(at);
        }
    }

    /// Blocking pull of the next input item: pushed-back items first, then
    /// the input channel. `None` once the channel is closed and drained.
    /// Scheduler-internal — elements receive items through
    /// [`Element::handle`] and drain extras with
    /// [`try_pull_input`](Ctx::try_pull_input).
    pub(crate) fn next_input(&mut self) -> Option<(usize, Item)> {
        if let Some(item) = self.pending.pop_front() {
            return Some(item);
        }
        let item = self.input.as_ref()?.recv().ok()?;
        self.record_arrival(&item);
        Some(item)
    }

    /// Non-blocking attempt to pull one more queued input item while
    /// processing (the `tensor_filter` batch-aggregation path). Returns
    /// `None` when nothing is ready or the element has no input channel.
    ///
    /// An element that pulls an item it cannot consume — in particular
    /// [`Item::Eos`] — **must** hand it back via
    /// [`push_back_input`](Ctx::push_back_input) so the scheduler's
    /// end-of-stream accounting stays correct.
    pub fn try_pull_input(&mut self) -> Option<(usize, Item)> {
        if let Some(item) = self.pending.pop_front() {
            return Some(item);
        }
        let item = self.input.as_ref()?.try_recv().ok()?;
        self.record_arrival(&item);
        Some(item)
    }

    /// Like [`try_pull_input`](Ctx::try_pull_input), but waits up to
    /// `timeout` for an item. The wait is accounted as idle time, not
    /// element busy time.
    pub fn pull_input_timeout(&mut self, timeout: Duration) -> Option<(usize, Item)> {
        if let Some(item) = self.pending.pop_front() {
            return Some(item);
        }
        let t0 = Instant::now();
        let item = match self.input.as_ref() {
            Some(rx) => rx.recv_timeout(timeout).ok(),
            None => None,
        };
        self.idle_ns += t0.elapsed().as_nanos() as u64;
        if let Some(it) = &item {
            self.record_arrival(it);
        }
        item
    }

    /// Return an item obtained from [`try_pull_input`](Ctx::try_pull_input)
    /// / [`pull_input_timeout`](Ctx::pull_input_timeout) that the element
    /// did not consume. It is redelivered (in pull order) before any new
    /// channel items.
    pub fn push_back_input(&mut self, pad: usize, item: Item) {
        self.pending.push_back((pad, item));
    }

    /// Non-blocking pull of the next pending control message
    /// (scheduler-internal; applied via [`Element::handle_control`]).
    pub(crate) fn try_pull_control(&mut self) -> Option<ControlMsg> {
        self.control.as_ref()?.try_recv().ok()
    }

    /// Send EOS on one src pad.
    pub fn push_eos(&mut self, pad: usize) {
        if let Some(sender) = self.outputs.get(pad).and_then(Option::as_ref) {
            let _ = sender.send(Item::Eos);
        }
    }

    pub fn n_src_pads(&self) -> usize {
        self.outputs.len()
    }

    /// Has someone requested pipeline stop?
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Request pipeline stop (used by sinks with `num-buffers` style caps).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    pub fn stats(&self) -> &Arc<ElementStats> {
        &self.stats
    }
}

/// A pipeline element. Implementations live in [`crate::elements`].
pub trait Element: Send {
    /// Factory name (e.g. `"tensor_converter"`).
    fn type_name(&self) -> &'static str;

    /// Set a property from its string form. The default implementation of
    /// every built-in element delegates to its typed [`Props`] struct, so
    /// the parser, `Graph::set_property` and runtime control all share
    /// one parsing/validation path.
    fn set_property(&mut self, key: &str, value: &str) -> Result<()> {
        Err(Error::Property {
            key: key.into(),
            value: value.into(),
            reason: format!("{} has no such property", self.type_name()),
        })
    }

    /// Apply a runtime control message (delivered by the scheduler on the
    /// element's own thread, before the next item it processes).
    /// Default: property changes go through
    /// [`set_property`](Element::set_property); subscription is rejected.
    fn handle_control(&mut self, msg: ControlMsg) -> Result<()> {
        match msg {
            ControlMsg::SetProperty { key, value } => self.set_property(&key, &value),
            ControlMsg::Subscribe(_) => Err(Error::element(
                self.type_name(),
                "does not support buffer subscription",
            )),
        }
    }

    /// Number of sink pads this element expects given `n` attached links
    /// (fixed-pad elements must return their fixed count).
    fn sink_pads(&self) -> PadSpec {
        PadSpec::Fixed(1)
    }

    /// Src pad specification.
    fn src_pads(&self) -> PadSpec {
        PadSpec::Fixed(1)
    }

    /// Caps negotiation: given fixed caps on each sink pad, return the caps
    /// produced on each src pad. Called once before the pipeline starts,
    /// in topological order. `n_srcs` is the number of attached src links.
    fn negotiate(&mut self, in_caps: &[Caps], n_srcs: usize) -> Result<Vec<Caps>>;

    /// Downstream caps hint: when a source is directly followed by a
    /// capsfilter, the graph proposes the filter's caps before the
    /// topological negotiation pass (the limited upstream direction of
    /// GStreamer's bidirectional negotiation — `videotestsrc ! video/x-raw,
    /// width=...` configures the source). Default: ignore.
    fn propose_caps(&mut self, _downstream: &Caps) -> Result<()> {
        Ok(())
    }

    /// For capsfilter-like elements: the restriction they will impose
    /// (drives the [`propose_caps`](Element::propose_caps) pre-pass).
    fn proposed_caps(&self) -> Option<Caps> {
        None
    }

    /// Process one input item arriving on sink pad `pad`.
    fn handle(&mut self, pad: usize, item: Item, ctx: &mut Ctx) -> Result<Flow>;

    /// Called when every sink pad has seen EOS: flush buffered state.
    fn flush(&mut self, _ctx: &mut Ctx) -> Result<()> {
        Ok(())
    }

    /// Sources produce data instead of consuming it. Return `Flow::Eos`
    /// when exhausted.
    fn generate(&mut self, _ctx: &mut Ctx) -> Result<Flow> {
        Err(Error::element(self.type_name(), "not a source"))
    }

    fn is_source(&self) -> bool {
        matches!(self.sink_pads(), PadSpec::Fixed(0))
    }

    /// Capacity of this element's input channel (a `queue` raises it).
    fn preferred_input_capacity(&self) -> usize {
        1
    }

    /// Link delivery into this element ([`Delivery::Leaky`] for leaky queues).
    fn input_delivery(&self) -> Delivery {
        Delivery::Blocking
    }

    /// Compute domain for busy-time accounting (NPU-bound filters override).
    fn domain(&self) -> Domain {
        Domain::Cpu
    }

    /// Downcast support for elements with post-run state (sinks that
    /// collected data, sources handing out push handles).
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

/// Pad cardinality specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PadSpec {
    Fixed(usize),
    /// Request pads: 1..=max (mux, tee, demux, ...).
    Variadic { max: usize },
}

impl PadSpec {
    /// Validate an attached-link count against the spec.
    pub fn accepts(&self, n: usize) -> bool {
        match *self {
            PadSpec::Fixed(k) => n == k,
            PadSpec::Variadic { max } => n >= 1 && n <= max,
        }
    }
}

/// Receiver side of an element's input (all sink pads share one channel;
/// items are tagged with the pad index).
pub type InputReceiver = Receiver<(usize, Item)>;

/// Test-only helper: drive a single element directly, collecting outputs.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::metrics::stats::Domain;
    use crate::tensor::Buffer;
    use std::sync::mpsc::sync_channel;

    /// Build a ctx with `n_src` outputs and return (ctx, receivers).
    pub fn ctx_with_outputs(n_src: usize) -> (Ctx, Vec<Receiver<(usize, Item)>>) {
        let stats = crate::metrics::stats::ElementStats::new("testutil");
        let mut outputs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n_src {
            let (tx, rx) = sync_channel(1024);
            outputs.push(Some(LinkSender::new(
                tx,
                0,
                Delivery::Blocking,
                stats.clone(),
            )));
            rxs.push(rx);
        }
        let ctx = Ctx {
            outputs,
            stats,
            stop: Arc::new(AtomicBool::new(false)),
            epoch: Instant::now(),
            domain: Domain::Cpu,
            idle_ns: 0,
            input: None,
            pending: std::collections::VecDeque::new(),
            control: None,
        };
        (ctx, rxs)
    }

    /// Feed one buffer into sink pad `pad`; drain buffers from src pad 0.
    pub fn drive(el: &mut dyn Element, pad: usize, buf: Buffer) -> Vec<Buffer> {
        let (mut ctx, rxs) = ctx_with_outputs(1);
        el.handle(pad, Item::Buffer(buf), &mut ctx).unwrap();
        drop(ctx);
        drain(&rxs[0])
    }

    pub fn drain(rx: &Receiver<(usize, Item)>) -> Vec<Buffer> {
        let mut out = Vec::new();
        while let Ok((_, item)) = rx.try_recv() {
            if let Item::Buffer(b) = item {
                out.push(b);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padspec_accepts() {
        assert!(PadSpec::Fixed(2).accepts(2));
        assert!(!PadSpec::Fixed(2).accepts(1));
        assert!(PadSpec::Variadic { max: 16 }.accepts(1));
        assert!(PadSpec::Variadic { max: 16 }.accepts(16));
        assert!(!PadSpec::Variadic { max: 16 }.accepts(17));
        assert!(!PadSpec::Variadic { max: 16 }.accepts(0));
    }
}
