//! NNFW sub-plugins for `tensor_filter` (§III).
//!
//! NNStreamer's Tensor-Filter delegates model execution to interchangeable
//! NNFW sub-plugins (TensorFlow, TFLite, Vivante, ... — 15+ in release
//! 1.6.0). Here the same structure exists with:
//!
//! * [`XlaNnfw`] — AOT-compiled JAX/Pallas artifacts through PJRT, bound to
//!   an accelerator (`cpu` with a modeled envelope, or the simulated NPU).
//!   The `*_opt` / `*_ref` artifact variants stand in for different NNFW
//!   versions (E4's TFLite 1.15 vs 2.1 — see DESIGN.md).
//! * [`CustomNnfw`] — user-registered Rust functions (NNStreamer's
//!   custom-filter sub-plugin, used heavily by E3's NMS/BBR/patch stages).
//! * passthrough — identity (testing).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use once_cell::sync::Lazy;

use crate::devices::{Completion, DeviceClass, NpuSim};
use crate::error::{Error, Result};
use crate::pipeline::executor::SharedWaker;
use crate::runtime::{Model, ModelPool, PoolLease};
use crate::tensor::{Chunk, TensorInfo};

/// Outcome of one non-blocking batched dispatch
/// ([`Nnfw::invoke_batch_async`]).
pub enum AsyncInvoke {
    /// Outputs are ready now — no modeled wait remains (CPU with no
    /// envelope, custom functions, passthrough).
    Ready(Vec<Vec<Chunk>>),
    /// Outputs are computed but the modeled service envelope has not
    /// elapsed: the caller should hold them until `deadline` (parking on
    /// the executor timer wheel rather than sleeping). `pad` is the
    /// remaining envelope — the busy time a blocking dispatch would have
    /// burned sleeping, which the caller charges on completion to keep
    /// utilization accounting identical.
    After {
        deadline: std::time::Instant,
        pad: Duration,
        outputs: Vec<Vec<Chunk>>,
    },
    /// In flight on a device queue: the [`Completion`] fires the waker
    /// passed to `invoke_batch_async` when the device finishes.
    Pending(Completion),
}

/// Which accelerator executes an [`XlaNnfw`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accelerator {
    /// Host CPU with a modeled service envelope (see [`cpu_rate_flops`]).
    Cpu,
    /// The simulated NPU (single shared hardware queue).
    Npu,
}

impl Accelerator {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "cpu" => Accelerator::Cpu,
            "npu" => Accelerator::Npu,
            other => {
                return Err(Error::Parse(format!("unknown accelerator {other:?}")))
            }
        })
    }
}

/// Modeled CPU inference throughput (FLOPs/s). The embedded-CPU envelope of
/// E1's "C/I3" rows: the A311D's Cortex-A73 runs I3 ~23x slower than its
/// NPU. Settable by benches via [`set_cpu_rate_flops`]; 0 disables the
/// envelope (pure real time).
static CPU_RATE_FLOPS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

pub fn set_cpu_rate_flops(rate: u64) {
    CPU_RATE_FLOPS.store(rate, std::sync::atomic::Ordering::Relaxed);
}

/// Serializes tests that reconfigure the process-global CPU envelope
/// (E1 sets an embedded rate, E4 disables it); without this, concurrent
/// test threads would flip the envelope mid-measurement.
#[cfg(test)]
pub(crate) static CPU_ENVELOPE_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Holds [`CPU_ENVELOPE_TEST_LOCK`] and restores the no-envelope default
/// on drop, so a test's rate never leaks into later tests.
#[cfg(test)]
pub(crate) struct CpuEnvelopeTestGuard {
    _lock: std::sync::MutexGuard<'static, ()>,
}

#[cfg(test)]
impl Drop for CpuEnvelopeTestGuard {
    fn drop(&mut self) {
        set_cpu_rate_flops(0);
    }
}

#[cfg(test)]
pub(crate) fn cpu_envelope_test_guard() -> CpuEnvelopeTestGuard {
    CpuEnvelopeTestGuard {
        _lock: CPU_ENVELOPE_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner()),
    }
}

pub fn cpu_rate_flops() -> u64 {
    CPU_RATE_FLOPS.load(std::sync::atomic::Ordering::Relaxed)
}

/// An NNFW sub-plugin instance bound to one model.
pub trait Nnfw: Send {
    /// Input tensor specs (NNStreamer minor-first dim order).
    fn inputs(&self) -> Vec<TensorInfo>;
    /// Output tensor specs (minor-first).
    fn outputs(&self) -> Vec<TensorInfo>;
    /// Run inference on one frame's chunks.
    fn invoke(&self, inputs: &[&Chunk]) -> Result<Vec<Chunk>>;
    /// Run inference on several frames; `frames[i]` holds frame `i`'s
    /// input chunks and the result holds frame `i`'s outputs, in order.
    ///
    /// The default loops over [`invoke`](Nnfw::invoke); backends with a
    /// cheaper batched path ([`XlaNnfw`] stacking frames into a single
    /// dispatch) override it. Implementations must keep per-frame results
    /// identical to per-frame `invoke` calls — `tensor_filter` relies on
    /// that to de-batch transparently.
    fn invoke_batch(&self, frames: &[&[&Chunk]]) -> Result<Vec<Vec<Chunk>>> {
        frames.iter().map(|inputs| self.invoke(inputs)).collect()
    }
    /// Run inference on several frames **without blocking on modeled
    /// device time**. Backends with a real device queue return
    /// [`AsyncInvoke::Pending`] and fire `waker` on completion; backends
    /// with a modeled envelope return [`AsyncInvoke::After`]. The default
    /// wraps the blocking [`invoke_batch`](Nnfw::invoke_batch) — correct
    /// for sub-plugins whose compute is real host CPU work.
    fn invoke_batch_async(
        &self,
        frames: &[&[&Chunk]],
        _waker: Option<Arc<SharedWaker>>,
    ) -> Result<AsyncInvoke> {
        Ok(AsyncInvoke::Ready(self.invoke_batch(frames)?))
    }
    /// Whether invoke() blocks on the NPU queue (busy time charged to NPU).
    fn is_npu(&self) -> bool {
        false
    }
}

/// Convert a manifest (numpy major-first) spec to stream (minor-first) dims.
fn to_stream_info(info: &TensorInfo) -> TensorInfo {
    let mut dims: Vec<usize> = info.dims.as_slice().to_vec();
    dims.reverse();
    TensorInfo::new(info.dtype, crate::tensor::Dims::new(&dims))
}

/// XLA sub-plugin: executes AOT artifacts leased from the shared
/// [`ModelPool`], so pipeline branches referencing the same artifact share
/// one loaded instance.
pub struct XlaNnfw {
    lease: PoolLease,
    accel: Accelerator,
    class: DeviceClass,
}

impl XlaNnfw {
    pub fn load(name: &str, accel: Accelerator, class: DeviceClass) -> Result<Self> {
        let pool = ModelPool::global()?;
        Ok(Self {
            lease: pool.acquire(name)?,
            accel,
            class,
        })
    }

    pub fn model(&self) -> &Arc<Model> {
        self.lease.model()
    }

    /// Remaining pad to stretch a CPU execution that took `real` to the
    /// modeled envelope (embedded-CPU rate x device class) for `n` frames
    /// of work. Zero when the real execution already filled the envelope.
    fn cpu_envelope_pad(&self, real: Duration, n: u64) -> Duration {
        let rate = cpu_rate_flops();
        let target = if rate > 0 {
            Duration::from_secs_f64(
                self.model().spec.flops.saturating_mul(n) as f64 / rate as f64,
            )
        } else {
            real
        };
        target
            .max(real)
            .mul_f64(self.class.throttle_factor())
            .saturating_sub(real)
    }

    /// Pad a CPU execution to the modeled envelope by sleeping in place
    /// (the blocking dispatch path).
    fn cpu_envelope(&self, real: Duration, n: u64) {
        let pad = self.cpu_envelope_pad(real, n);
        if !pad.is_zero() {
            std::thread::sleep(pad);
        }
    }
}

impl Nnfw for XlaNnfw {
    fn inputs(&self) -> Vec<TensorInfo> {
        self.model().spec.inputs.iter().map(to_stream_info).collect()
    }

    fn outputs(&self) -> Vec<TensorInfo> {
        self.model().spec.outputs.iter().map(to_stream_info).collect()
    }

    fn invoke(&self, inputs: &[&Chunk]) -> Result<Vec<Chunk>> {
        match self.accel {
            Accelerator::Npu => {
                let owned: Vec<Chunk> = inputs.iter().map(|&c| c.clone()).collect();
                NpuSim::global().submit(self.model().clone(), owned)
            }
            Accelerator::Cpu => {
                let t0 = Instant::now();
                let out = self.model().execute(inputs)?;
                self.cpu_envelope(t0.elapsed(), 1);
                Ok(out)
            }
        }
    }

    fn invoke_batch(&self, frames: &[&[&Chunk]]) -> Result<Vec<Vec<Chunk>>> {
        match self.accel {
            Accelerator::Npu => {
                let owned: Vec<Vec<Chunk>> = frames
                    .iter()
                    .map(|inputs| inputs.iter().map(|&c| c.clone()).collect())
                    .collect();
                NpuSim::global().submit_batch(self.model().clone(), owned)
            }
            Accelerator::Cpu => {
                let t0 = Instant::now();
                let out = self.model().execute_batch(frames)?;
                self.cpu_envelope(t0.elapsed(), frames.len() as u64);
                Ok(out)
            }
        }
    }

    fn invoke_batch_async(
        &self,
        frames: &[&[&Chunk]],
        waker: Option<Arc<SharedWaker>>,
    ) -> Result<AsyncInvoke> {
        match self.accel {
            Accelerator::Npu => {
                let owned: Vec<Vec<Chunk>> = frames
                    .iter()
                    .map(|inputs| inputs.iter().map(|&c| c.clone()).collect())
                    .collect();
                let completion = NpuSim::global().submit_batch_async(
                    self.model().clone(),
                    owned,
                    waker,
                )?;
                Ok(AsyncInvoke::Pending(completion))
            }
            Accelerator::Cpu => {
                let t0 = Instant::now();
                let outputs = self.model().execute_batch(frames)?;
                let pad = self.cpu_envelope_pad(t0.elapsed(), frames.len() as u64);
                if pad.is_zero() {
                    Ok(AsyncInvoke::Ready(outputs))
                } else {
                    Ok(AsyncInvoke::After {
                        deadline: Instant::now() + pad,
                        pad,
                        outputs,
                    })
                }
            }
        }
    }

    fn is_npu(&self) -> bool {
        self.accel == Accelerator::Npu
    }
}

/// Everything an NNFW factory learns about the `tensor_filter` it is
/// instantiating for: the configured model/artifact name, placement, and
/// the negotiated input tensor layout.
pub struct NnfwRequest<'a> {
    /// `model=` property of the filter (artifact or function name).
    pub model: &'a str,
    /// `accelerator=` property.
    pub accelerator: Accelerator,
    /// `device-class=` property (E3 hardware-class throttle).
    pub device_class: DeviceClass,
    /// Negotiated input tensor specs, stream (minor-first) order.
    pub input_infos: &'a [TensorInfo],
}

type NnfwFactory = Arc<dyn Fn(&NnfwRequest) -> Result<Box<dyn Nnfw>> + Send + Sync>;

static NNFW_REGISTRY: Lazy<Mutex<HashMap<String, NnfwFactory>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Register an NNFW sub-plugin factory under a `framework=` name —
/// the runtime extension point of the paper's sub-plugin API, mirroring
/// [`Registry::register`](crate::element::Registry::register) for
/// elements. `tensor_filter framework=<name>` then routes through the
/// factory instead of the built-in set.
pub fn register_nnfw(
    name: &str,
    factory: impl Fn(&NnfwRequest) -> Result<Box<dyn Nnfw>> + Send + Sync + 'static,
) {
    NNFW_REGISTRY
        .lock()
        .unwrap()
        .insert(name.to_string(), Arc::new(factory));
}

/// Is a sub-plugin factory registered under `name`?
pub fn nnfw_exists(name: &str) -> bool {
    NNFW_REGISTRY.lock().unwrap().contains_key(name)
}

/// Names of every registered sub-plugin factory (sorted).
pub fn nnfw_names() -> Vec<String> {
    let mut v: Vec<String> = NNFW_REGISTRY.lock().unwrap().keys().cloned().collect();
    v.sort();
    v
}

/// Instantiate a registered sub-plugin (the `Framework::Plugin` path of
/// `tensor_filter`).
pub(crate) fn make_nnfw(name: &str, req: &NnfwRequest) -> Result<Box<dyn Nnfw>> {
    let factory = {
        let g = NNFW_REGISTRY.lock().unwrap();
        g.get(name).cloned()
    };
    match factory {
        Some(f) => f(req),
        None => Err(Error::Runtime(format!(
            "NNFW sub-plugin {name:?} is not registered (register_nnfw)"
        ))),
    }
}

/// A registered custom-filter function: chunks in, chunks out.
pub type CustomFn =
    Arc<dyn Fn(&[&Chunk]) -> Result<Vec<Chunk>> + Send + Sync + 'static>;

struct CustomEntry {
    f: CustomFn,
    inputs: Vec<TensorInfo>,
    outputs: Vec<TensorInfo>,
}

static CUSTOM_REGISTRY: Lazy<Mutex<HashMap<String, Arc<CustomEntry>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Register a custom filter function under `name` (the
/// `framework=custom model=<name>` path of tensor_filter).
pub fn register_custom(
    name: &str,
    inputs: Vec<TensorInfo>,
    outputs: Vec<TensorInfo>,
    f: impl Fn(&[&Chunk]) -> Result<Vec<Chunk>> + Send + Sync + 'static,
) {
    CUSTOM_REGISTRY.lock().unwrap().insert(
        name.to_string(),
        Arc::new(CustomEntry {
            f: Arc::new(f),
            inputs,
            outputs,
        }),
    );
}

/// Custom-function sub-plugin.
pub struct CustomNnfw {
    entry: Arc<CustomEntry>,
}

impl CustomNnfw {
    pub fn load(name: &str) -> Result<Self> {
        let entry = CUSTOM_REGISTRY
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("custom filter {name:?} not registered")))?;
        Ok(Self { entry })
    }
}

impl Nnfw for CustomNnfw {
    fn inputs(&self) -> Vec<TensorInfo> {
        self.entry.inputs.clone()
    }

    fn outputs(&self) -> Vec<TensorInfo> {
        self.entry.outputs.clone()
    }

    fn invoke(&self, inputs: &[&Chunk]) -> Result<Vec<Chunk>> {
        (self.entry.f)(inputs)
    }
}

/// Identity sub-plugin (framework=passthrough).
pub struct PassthroughNnfw {
    pub info: Vec<TensorInfo>,
}

impl Nnfw for PassthroughNnfw {
    fn inputs(&self) -> Vec<TensorInfo> {
        self.info.clone()
    }

    fn outputs(&self) -> Vec<TensorInfo> {
        self.info.clone()
    }

    fn invoke(&self, inputs: &[&Chunk]) -> Result<Vec<Chunk>> {
        Ok(inputs.iter().map(|&c| c.clone()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn custom_registration_and_invoke() {
        register_custom(
            "double",
            vec![TensorInfo::new(DType::F32, [2])],
            vec![TensorInfo::new(DType::F32, [2])],
            |ins| {
                let v = ins[0].to_f32_vec()?;
                let out: Vec<f32> = v.iter().map(|x| x * 2.0).collect();
                Ok(vec![Chunk::from_f32(&out)])
            },
        );
        let f = CustomNnfw::load("double").unwrap();
        let c = Chunk::from_f32(&[1.0, 2.5]);
        let out = f.invoke(&[&c]).unwrap();
        assert_eq!(out[0].to_f32_vec().unwrap(), vec![2.0, 5.0]);
    }

    #[test]
    fn unknown_custom_errors() {
        assert!(CustomNnfw::load("nope").is_err());
    }

    #[test]
    fn default_invoke_batch_loops_per_frame() {
        register_custom(
            "triple",
            vec![TensorInfo::new(DType::F32, [2])],
            vec![TensorInfo::new(DType::F32, [2])],
            |ins| {
                let v = ins[0].to_f32_vec()?;
                let out: Vec<f32> = v.iter().map(|x| x * 3.0).collect();
                Ok(vec![Chunk::from_f32(&out)])
            },
        );
        let f = CustomNnfw::load("triple").unwrap();
        let a = Chunk::from_f32(&[1.0, 2.0]);
        let b = Chunk::from_f32(&[3.0, 4.0]);
        let ra: Vec<&Chunk> = vec![&a];
        let rb: Vec<&Chunk> = vec![&b];
        let frames: [&[&Chunk]; 2] = [ra.as_slice(), rb.as_slice()];
        let outs = f.invoke_batch(&frames).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0][0].to_f32_vec().unwrap(), vec![3.0, 6.0]);
        assert_eq!(outs[1][0].to_f32_vec().unwrap(), vec![9.0, 12.0]);
    }

    #[test]
    fn stream_info_reverses_dims() {
        let spec = TensorInfo::new(DType::F32, [1, 64, 48, 3]);
        let s = to_stream_info(&spec);
        assert_eq!(s.dims.as_slice(), &[3, 48, 64, 1]);
    }
}
