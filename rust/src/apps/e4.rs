//! E4: NNStreamer vs the MediaPipe-like framework (Fig 5, Table III).
//!
//! Four cases on the same SSDLite object-detection workload:
//! * (a) NNStreamer with the `ssd_opt` build ("TFLite 1.15.2")
//! * (b) NNStreamer with the `ssd_ref` build ("TFLite 2.1")
//! * (c) the MediaPipe-like calculator graph (pinned to `ssd_ref`)
//! * (d) hybrid: the NNStreamer pipeline embedding graph (c) as a filter
//!
//! Metrics per case: CPU %, throughput, latency, memory accesses (byte
//! traffic — see DESIGN.md), peak memory.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::baselines::mediapipe_like::{CalculatorGraph, Packet};
use crate::elements::converter::TensorConverterProps;
use crate::elements::decoder::{DecoderMode, TensorDecoderProps};
use crate::elements::filter::{Framework, TensorFilterProps};
use crate::elements::sinks::FakeSinkProps;
use crate::elements::sources::VideoTestSrcProps;
use crate::elements::transform::{ArithOp, TensorTransformProps};
use crate::elements::videofilters::{VideoConvertProps, VideoScaleProps};
use crate::error::Result;
use crate::metrics::{traffic, CpuTracker, MemInfo};
use crate::nnfw::register_custom;
use crate::pipeline::{Pipeline, PipelineBuilder};
use crate::tensor::{Chunk, DType, TensorInfo, VideoFormat};
use crate::video::Pattern;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum E4Case {
    NnsOpt,
    NnsRef,
    MediaPipe,
    Hybrid,
}

impl E4Case {
    pub fn label(self) -> &'static str {
        match self {
            E4Case::NnsOpt => "(a) NNStreamer-a",
            E4Case::NnsRef => "(b) NNStreamer-b",
            E4Case::MediaPipe => "(c) MediaPipe",
            E4Case::Hybrid => "(d) Hybrid",
        }
    }

    pub fn all() -> [E4Case; 4] {
        [
            E4Case::NnsOpt,
            E4Case::NnsRef,
            E4Case::MediaPipe,
            E4Case::Hybrid,
        ]
    }
}

#[derive(Debug, Clone)]
pub struct E4Config {
    pub src_w: usize,
    pub src_h: usize,
    /// The paper feeds 1818 frames.
    pub num_frames: u64,
}

impl Default for E4Config {
    fn default() -> Self {
        Self {
            src_w: 320,
            src_h: 240,
            num_frames: 300,
        }
    }
}

/// One row set of Table III.
#[derive(Debug, Clone, Default)]
pub struct E4Row {
    pub label: String,
    pub cpu_percent: f64,
    pub throughput_fps: f64,
    pub latency_ms: f64,
    /// Byte traffic through the streaming layer (the perf "memory access"
    /// substitute), in millions.
    pub mem_access_m: f64,
    pub mem_mib: f64,
}

/// The NNStreamer detection pipeline as a launch description
/// (parser-compat fixture for `tests/api_roundtrip.rs`).
pub fn launch_description(cfg: &E4Config, variant: &str) -> String {
    format!(
        "videotestsrc pattern=ball width={w} height={h} framerate=1000 num-buffers={n} is-live=false ! \
         videoconvert format=RGB ! videoscale width=96 height=96 ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=div:255 ! \
         tensor_filter framework=xla model=ssd_{variant} accelerator=cpu ! \
         tensor_decoder mode=bounding_boxes option1=ssd option2=0.5 ! \
         fakesink name=out",
        w = cfg.src_w,
        h = cfg.src_h,
        n = cfg.num_frames,
    )
}

/// Common pre-processing head: camera -> RGB -> 96x96 -> normalized f32
/// tensors (the builder-typed equivalent of the launch string above).
/// `framerate` matches the historical launch fixtures: 1000 for the
/// detection cases, 100000 for the pre-processor-only comparison.
fn chain_preprocess<'a>(
    b: &'a mut PipelineBuilder,
    cfg: &E4Config,
    framerate: f64,
) -> Result<&'a mut PipelineBuilder> {
    b.chain(VideoTestSrcProps {
        pattern: Pattern::Ball,
        width: cfg.src_w,
        height: cfg.src_h,
        framerate,
        num_buffers: Some(cfg.num_frames),
        ..Default::default()
    })?
    .chain(VideoConvertProps {
        format: VideoFormat::Rgb,
    })?
    .chain(VideoScaleProps {
        width: 96,
        height: 96,
    })?
    .chain(TensorConverterProps)?
    .chain(TensorTransformProps::typecast(DType::F32))?
    .chain(TensorTransformProps::arithmetic(vec![(ArithOp::Div, 255.0)]))
}

/// Build the detection pipeline for one NNFW variant through the typed
/// builder.
pub fn build_pipeline(cfg: &E4Config, variant: &str) -> Result<Pipeline> {
    let mut b = PipelineBuilder::new();
    chain_preprocess(&mut b, cfg, 1000.0)?
        .chain(TensorFilterProps {
            framework: Framework::Xla,
            model: format!("ssd_{variant}"),
            ..Default::default()
        })?
        .chain(TensorDecoderProps {
            mode: DecoderMode::BoundingBoxes,
            head: "ssd".into(),
            threshold: 0.5,
            ..Default::default()
        })?
        .chain_named("out", FakeSinkProps::default())?;
    Ok(b.build())
}

/// Run an NNStreamer case (a or b).
fn run_nns(cfg: &E4Config, variant: &str, label: &str) -> Result<E4Row> {
    let mem_before = MemInfo::read().vm_rss_kib;
    let tr0 = traffic::snapshot();
    let cpu = CpuTracker::start();
    let mut p = build_pipeline(cfg, variant)?;
    let report = p.run()?;
    let tr = traffic::since(tr0);
    let mem_after = MemInfo::read().vm_rss_kib;
    let out = report.element("out").unwrap();
    Ok(E4Row {
        label: label.to_string(),
        cpu_percent: cpu.cpu_percent(),
        throughput_fps: out.buffers_in() as f64 / report.wall.as_secs_f64(),
        // per-frame latency along the processing chain (sum of element
        // means on the path)
        latency_ms: report
            .elements
            .iter()
            .filter(|e| e.buffers_in() > 0)
            .map(|e| e.latency().mean.as_secs_f64() * 1e3)
            .sum(),
        mem_access_m: tr.total() as f64 / 1e6,
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
    })
}

/// Run the MediaPipe-like case (c).
fn run_mediapipe(cfg: &E4Config) -> Result<E4Row> {
    let mem_before = MemInfo::read().vm_rss_kib;
    let tr0 = traffic::snapshot();
    let cpu = CpuTracker::start();
    let mut graph = CalculatorGraph::object_detection(cfg.src_w, cfg.src_h)?;
    let t0 = Instant::now();
    let mut lat_sum = 0.0f64;
    let mut done = 0u64;
    for n in 0..cfg.num_frames {
        let rgb = crate::video::pattern::generate_rgb(
            crate::video::Pattern::Ball,
            cfg.src_w,
            cfg.src_h,
            n,
        );
        let data: Vec<f32> = rgb.iter().map(|&v| v as f32).collect();
        traffic::count_write(data.len() * 4);
        let f0 = Instant::now();
        // FlowLimiter: frames offered while a detection is in flight are
        // dropped; in this synchronous harness we run to idle each frame
        if graph.add_frame(Packet {
            ts_us: n,
            data: Arc::new(data),
        }) {
            graph.run_until_idle()?;
            lat_sum += f0.elapsed().as_secs_f64() * 1e3;
            done += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let tr = traffic::since(tr0);
    let mem_after = MemInfo::read().vm_rss_kib;
    Ok(E4Row {
        label: E4Case::MediaPipe.label().to_string(),
        cpu_percent: cpu.cpu_percent(),
        throughput_fps: done as f64 / wall,
        latency_ms: lat_sum / done.max(1) as f64,
        mem_access_m: tr.total() as f64 / 1e6,
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
    })
}

/// Run the hybrid case (d): NNStreamer pipeline embedding the MediaPipe
/// graph as a tensor_filter (framework=custom).
fn run_hybrid(cfg: &E4Config) -> Result<E4Row> {
    // the embedded graph re-runs its (now lighter) pre-processing on the
    // already pre-processed 96x96 frame, then infers with its pinned NNFW
    let graph = Arc::new(Mutex::new(CalculatorGraph::object_detection(96, 96)?));
    let g2 = graph.clone();
    register_custom(
        "mediapipe_embedded",
        vec![TensorInfo::new(DType::F32, [3, 96, 96, 1])],
        vec![TensorInfo::new(DType::F32, [1])],
        move |ins| {
            let data = ins[0].to_f32_vec()?;
            // MediaPipe expects 0..255 floats; the NNS pipeline normalized
            let scaled: Vec<f32> = data.iter().map(|v| v * 255.0).collect();
            let mut g = g2.lock().unwrap();
            if g.add_frame(Packet {
                ts_us: 0,
                data: Arc::new(scaled),
            }) {
                let outs = g.run_until_idle()?;
                let n = outs.last().map(|p| p.data.len()).unwrap_or(0);
                return Ok(vec![Chunk::from_f32(&[n as f32])]);
            }
            Ok(vec![Chunk::from_f32(&[0.0])])
        },
    );
    let mem_before = MemInfo::read().vm_rss_kib;
    let tr0 = traffic::snapshot();
    let cpu = CpuTracker::start();
    let mut b = PipelineBuilder::new();
    chain_preprocess(&mut b, cfg, 1000.0)?
        .chain(TensorFilterProps {
            framework: Framework::Custom,
            model: "mediapipe_embedded".into(),
            ..Default::default()
        })?
        .chain_named("out", FakeSinkProps::default())?;
    let mut p = b.build();
    let report = p.run()?;
    let tr = traffic::since(tr0);
    let mem_after = MemInfo::read().vm_rss_kib;
    let out = report.element("out").unwrap();
    Ok(E4Row {
        label: E4Case::Hybrid.label().to_string(),
        cpu_percent: cpu.cpu_percent(),
        throughput_fps: out.buffers_in() as f64 / report.wall.as_secs_f64(),
        latency_ms: report
            .elements
            .iter()
            .filter(|e| e.buffers_in() > 0)
            .map(|e| e.latency().mean.as_secs_f64() * 1e3)
            .sum(),
        mem_access_m: tr.total() as f64 / 1e6,
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
    })
}

/// Run one Table III case.
pub fn run_case(cfg: &E4Config, case: E4Case) -> Result<E4Row> {
    crate::nnfw::set_cpu_rate_flops(0); // desktop PC: no CPU envelope
    match case {
        E4Case::NnsOpt => run_nns(cfg, "opt", E4Case::NnsOpt.label()),
        E4Case::NnsRef => run_nns(cfg, "ref", E4Case::NnsRef.label()),
        E4Case::MediaPipe => run_mediapipe(cfg),
        E4Case::Hybrid => run_hybrid(cfg),
    }
}

/// The pre-processor-only comparison (E4's 25% / 40% numbers): returns
/// ((nns_cpu_s, nns_real_s), (mp_cpu_s, mp_real_s)).
pub fn preprocessor_comparison(
    cfg: &E4Config,
    frames: u64,
) -> Result<((f64, f64), (f64, f64))> {
    // NNStreamer path: off-the-shelf videoscale + converter + transform
    let pre_cfg = E4Config {
        num_frames: frames,
        ..cfg.clone()
    };
    let cpu = CpuTracker::start();
    let t0 = Instant::now();
    let mut b = PipelineBuilder::new();
    chain_preprocess(&mut b, &pre_cfg, 100_000.0)?
        .chain_named("out", FakeSinkProps::default())?;
    let mut p = b.build();
    p.run()?;
    let nns_real = t0.elapsed().as_secs_f64();
    let nns_cpu = cpu.cpu_percent() / 100.0 * cpu.elapsed_secs();

    let (mp_cpu, mp_real) =
        CalculatorGraph::preprocess_only(cfg.src_w, cfg.src_h, frames)?;
    Ok(((nns_cpu, nns_real), (mp_cpu, mp_real)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> E4Config {
        E4Config {
            src_w: 160,
            src_h: 120,
            num_frames: 6,
        }
    }

    fn env_lock() -> crate::nnfw::CpuEnvelopeTestGuard {
        crate::nnfw::cpu_envelope_test_guard()
    }

    #[test]
    fn all_cases_run() {
        let _env = env_lock();
        for case in E4Case::all() {
            let row = run_case(&quick(), case).unwrap();
            assert!(row.throughput_fps > 0.0, "{case:?}: {row:?}");
        }
    }

    #[test]
    fn opt_beats_ref() {
        let _env = env_lock();
        let cfg = E4Config {
            num_frames: 10,
            ..quick()
        };
        let a = run_case(&cfg, E4Case::NnsOpt).unwrap();
        let b = run_case(&cfg, E4Case::NnsRef).unwrap();
        assert!(
            a.throughput_fps > b.throughput_fps,
            "opt {} <= ref {}",
            a.throughput_fps,
            b.throughput_fps
        );
    }

    #[test]
    fn preprocessor_gap() {
        let ((_, nns_real), (_, mp_real)) =
            preprocessor_comparison(&quick(), 40).unwrap();
        assert!(
            mp_real > nns_real,
            "MediaPipe-like preprocessing should be slower: {mp_real} vs {nns_real}"
        );
    }
}
