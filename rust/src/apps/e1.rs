//! E1: multi-model pipelines on heterogeneous resources (Fig 2, Table I).
//!
//! Nine configurations a–i: Control vs NNStreamer, Inception-v3 ("I3") and
//! YOLO-v3 ("Y3") on the (simulated) NPU, plus an I3 running on the CPU
//! ("C/I3"), in every combination. Case i is the full pipeline of Fig 2;
//! c–h are its sub-pipelines.

use crate::baselines::control;
use crate::devices::NpuSim;
use crate::elements::decoder::{DecoderMode, TensorDecoderProps};
use crate::elements::filter::{Framework, TensorFilterProps};
use crate::elements::flow::{QueueProps, TeeProps};
use crate::elements::sinks::FakeSinkProps;
use crate::elements::sources::VideoTestSrcProps;
use crate::elements::transform::{ArithOp, TensorTransformProps};
use crate::elements::videofilters::VideoScaleProps;
use crate::error::Result;
use crate::metrics::MemInfo;
use crate::nnfw::{self, Accelerator};
use crate::pipeline::{Graph, Pipeline, PipelineBuilder};
use crate::tensor::DType;
use crate::video::Pattern;

/// Which models a configuration runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum E1Case {
    ControlI3,
    ControlY3,
    NnsI3,
    NnsY3,
    NnsCpuI3,
    NnsI3Y3,
    NnsI3CpuI3,
    NnsY3CpuI3,
    NnsAll3,
}

impl E1Case {
    pub fn label(self) -> &'static str {
        match self {
            E1Case::ControlI3 => "a.Control / I3",
            E1Case::ControlY3 => "b.Control / Y3",
            E1Case::NnsI3 => "c.NNStreamer / I3",
            E1Case::NnsY3 => "d.NNStreamer / Y3",
            E1Case::NnsCpuI3 => "e.NNStreamer / C/I3",
            E1Case::NnsI3Y3 => "f.NNStreamer / I3+Y3",
            E1Case::NnsI3CpuI3 => "g.NNStreamer / I3+C/I3",
            E1Case::NnsY3CpuI3 => "h.NNStreamer / Y3+C/I3",
            E1Case::NnsAll3 => "i.NNS / I3+Y3+C/I3",
        }
    }

    /// Branch descriptors: (model stem, on_npu).
    pub fn branches(self) -> Vec<(&'static str, bool)> {
        match self {
            E1Case::ControlI3 | E1Case::NnsI3 => vec![("i3", true)],
            E1Case::ControlY3 | E1Case::NnsY3 => vec![("y3", true)],
            E1Case::NnsCpuI3 => vec![("i3", false)],
            E1Case::NnsI3Y3 => vec![("i3", true), ("y3", true)],
            E1Case::NnsI3CpuI3 => vec![("i3", true), ("i3", false)],
            E1Case::NnsY3CpuI3 => vec![("y3", true), ("i3", false)],
            E1Case::NnsAll3 => vec![("i3", true), ("y3", true), ("i3", false)],
        }
    }

    pub fn is_control(self) -> bool {
        matches!(self, E1Case::ControlI3 | E1Case::ControlY3)
    }

    pub fn all() -> [E1Case; 9] {
        [
            E1Case::ControlI3,
            E1Case::ControlY3,
            E1Case::NnsI3,
            E1Case::NnsY3,
            E1Case::NnsCpuI3,
            E1Case::NnsI3Y3,
            E1Case::NnsI3CpuI3,
            E1Case::NnsY3CpuI3,
            E1Case::NnsAll3,
        ]
    }
}

#[derive(Debug, Clone)]
pub struct E1Config {
    /// Camera resolution (the A311D products use VGA cameras).
    pub src_w: usize,
    pub src_h: usize,
    pub fps: f64,
    pub num_frames: u64,
    /// Live pacing (the paper feeds 30 fps live input).
    pub live: bool,
    /// Modeled embedded-CPU inference throughput (FLOPs/s): the A311D's
    /// A73 cores run I3 ~23x slower than its NPU.
    pub cpu_rate_flops: u64,
}

impl Default for E1Config {
    fn default() -> Self {
        Self {
            src_w: 640,
            src_h: 480,
            fps: 30.0,
            num_frames: 300,
            live: true,
            cpu_rate_flops: 15_000_000,
        }
    }
}

/// One row of Table I.
#[derive(Debug, Clone, Default)]
pub struct E1Row {
    pub label: String,
    /// Output rate per model branch (frames/s).
    pub fps: Vec<f64>,
    /// Modeled app-CPU usage (%), excluding NPU-domain time.
    pub cpu_percent: f64,
    /// Memory estimate (MiB, RSS growth during the run).
    pub mem_mib: f64,
    pub wall_s: f64,
}

/// Build a model branch: scale -> convert -> normalize -> filter -> decode.
///
/// All typed props: the leaky queue keeps a slow model branch from
/// stalling the tee (exactly how production GStreamer pipelines wire
/// slow consumers). Both branches run the optimized artifact; the
/// accelerator decides the device envelope (the C/I3 slowdown comes from
/// the modeled embedded-CPU rate, not from a different model build).
fn add_branch(b: &mut PipelineBuilder, idx: usize, stem: &str, on_npu: bool) -> Result<()> {
    let (side, decoder) = match stem {
        "i3" => (
            64,
            TensorDecoderProps {
                mode: DecoderMode::ImageLabeling,
                ..Default::default()
            },
        ),
        _ => (
            96,
            TensorDecoderProps {
                mode: DecoderMode::BoundingBoxes,
                head: "yolo".into(),
                ..Default::default()
            },
        ),
    };
    b.from("t")?
        .chain(QueueProps {
            max_size_buffers: 2,
            leaky: true,
        })?
        .chain(VideoScaleProps {
            width: side,
            height: side,
        })?
        .chain(crate::elements::converter::TensorConverterProps)?
        .chain(TensorTransformProps::typecast(DType::F32))?
        .chain(TensorTransformProps::arithmetic(vec![(ArithOp::Div, 255.0)]))?
        .chain_named(
            format!("model_{idx}"),
            TensorFilterProps {
                framework: Framework::Xla,
                model: format!("{stem}_opt"),
                accelerator: if on_npu {
                    Accelerator::Npu
                } else {
                    Accelerator::Cpu
                },
                ..Default::default()
            },
        )?
        .chain(decoder)?
        .chain_named(format!("sink_{idx}"), FakeSinkProps::default())?;
    Ok(())
}

/// Build the NNStreamer pipeline for a case (Fig 2 or a sub-pipeline)
/// through the typed builder.
pub fn build_pipeline(cfg: &E1Config, case: E1Case) -> Result<Graph> {
    assert!(!case.is_control());
    let mut b = PipelineBuilder::new();
    b.chain_named("src", source_props(cfg))?
        .chain_named("t", TeeProps)?;
    for (i, (stem, on_npu)) in case.branches().into_iter().enumerate() {
        add_branch(&mut b, i, stem, on_npu)?;
    }
    Ok(b.into_graph())
}

fn source_props(cfg: &E1Config) -> VideoTestSrcProps {
    VideoTestSrcProps {
        pattern: Pattern::Ball,
        width: cfg.src_w,
        height: cfg.src_h,
        framerate: cfg.fps,
        num_buffers: Some(cfg.num_frames),
        is_live: cfg.live,
        ..Default::default()
    }
}

/// The same pipeline as a launch description — the parser-compat fixture
/// asserted against the builder graph in `tests/api_roundtrip.rs`.
pub fn launch_description(cfg: &E1Config, case: E1Case) -> String {
    assert!(!case.is_control());
    let mut desc = format!(
        "videotestsrc name=src pattern=ball width={w} height={h} framerate={fps} \
         num-buffers={n} is-live={live} ! tee name=t",
        w = cfg.src_w,
        h = cfg.src_h,
        fps = cfg.fps,
        n = cfg.num_frames,
        live = cfg.live,
    );
    for (i, (stem, on_npu)) in case.branches().into_iter().enumerate() {
        let (side, dec) = match stem {
            "i3" => (64, "tensor_decoder mode=image_labeling".to_string()),
            _ => (96, "tensor_decoder mode=bounding_boxes option1=yolo".to_string()),
        };
        desc.push_str(&format!(
            " t. ! queue max-size-buffers=2 leaky=downstream ! \
             videoscale width={side} height={side} ! tensor_converter ! \
             tensor_transform mode=typecast option=float32 ! \
             tensor_transform mode=arithmetic option=div:255 ! \
             tensor_filter name=model_{i} framework=xla model={stem}_opt accelerator={acc} ! \
             {dec} ! fakesink name=sink_{i}",
            acc = if on_npu { "npu" } else { "cpu" },
        ));
    }
    desc
}

/// Run one case (dispatching to Control or NNS) and measure a table row.
pub fn run_case(cfg: &E1Config, case: E1Case) -> Result<E1Row> {
    nnfw::set_cpu_rate_flops(cfg.cpu_rate_flops);
    if case.is_control() {
        return control::run_e1_control(cfg, case);
    }
    let mem_before = MemInfo::read().vm_rss_kib;
    let npu_before = NpuSim::global().stats.total_service();
    let mut pipeline = Pipeline::new(build_pipeline(cfg, case)?);
    let report = pipeline.run()?;
    let mem_after = MemInfo::read().vm_rss_kib;

    let n_branches = case.branches().len();
    let mut fps = Vec::new();
    for i in 0..n_branches {
        fps.push(report.fps(&format!("sink_{i}")));
    }
    // app CPU: element busy time in the CPU domain over wall-clock
    // (NPU-domain time excluded — the paper measures app cores, and the
    // Vivante NPU is not a CPU)
    let _ = npu_before;
    Ok(E1Row {
        label: case.label().to_string(),
        fps,
        cpu_percent: report.element_cpu_percent(),
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
        wall_s: report.wall.as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> E1Config {
        E1Config {
            num_frames: 4,
            live: false,
            src_w: 160,
            src_h: 120,
            ..Default::default()
        }
    }

    fn env_lock() -> crate::nnfw::CpuEnvelopeTestGuard {
        crate::nnfw::cpu_envelope_test_guard()
    }

    #[test]
    fn single_model_pipeline_runs() {
        let _env = env_lock();
        let row = run_case(&quick_cfg(), E1Case::NnsI3).unwrap();
        assert_eq!(row.fps.len(), 1);
        assert!(row.fps[0] > 0.0, "{row:?}");
    }

    #[test]
    fn three_model_pipeline_runs() {
        let _env = env_lock();
        let row = run_case(&quick_cfg(), E1Case::NnsAll3).unwrap();
        assert_eq!(row.fps.len(), 3);
        for f in &row.fps {
            assert!(*f > 0.0, "{row:?}");
        }
    }

    #[test]
    fn control_cases_run() {
        let _env = env_lock();
        let row = run_case(&quick_cfg(), E1Case::ControlI3).unwrap();
        assert!(row.fps[0] > 0.0, "{row:?}");
    }
}
