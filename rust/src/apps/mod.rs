//! Scenario applications: the paper's E1–E4 pipelines and the MTCNN
//! post-processing substrate.

pub mod e1;
pub mod e2_ars;
pub mod e3_mtcnn;
pub mod e4;
pub mod postproc;
