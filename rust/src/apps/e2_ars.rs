//! E2: the Activity Recognition Sensor pipeline (Fig 3).
//!
//! Multi-modal and multi-model: IIO sensors (3-axis accelerometer +
//! pressure) and a microphone feed three NN stages at different aggregated
//! rates:
//!
//! ```text
//! sensorsrc(accel 3ch)    ! tee ta
//!   ta. ! queue ! tensor_filter(ars_a)              -> sink_a   (a)
//!   ta. ! queue ! tensor_transform(stand)           -> merge
//! sensorsrc(pressure 1ch) ! tee tp
//!   tp. ! queue                                     -> merge
//!   tp. ! queue ! tensor_transform(stand)           -> merge
//!   ta. ! queue                                     -> merge
//! tensor_merge(axis 0: 3+1+3+1 = 8ch) ! tensor_aggregator(4x)
//!   ! tensor_filter(ars_b)                          -> sink_b   (b)
//! sensorsrc(mic 16ch) ! tensor_aggregator(2x, flush 2 = decimate)
//!   ! tensor_filter(ars_c)                          -> sink_c   (c)
//! ```
//!
//! The paper's headline: one developer, a dozen lines of pipeline
//! description, −48% memory, −43% CPU, +65.5% batch rate vs the
//! conventional serial implementation ([`crate::baselines::control`]).

use crate::elements::aggregator::TensorAggregatorProps;
use crate::elements::filter::{Framework, TensorFilterProps};
use crate::elements::flow::{QueueProps, TeeProps};
use crate::elements::merge::TensorMergeProps;
use crate::elements::rate::TensorRateProps;
use crate::elements::sinks::FakeSinkProps;
use crate::elements::sources::{SensorKind, SensorSrcProps};
use crate::elements::transform::TensorTransformProps;
use crate::error::Result;
use crate::metrics::MemInfo;
use crate::pipeline::{Graph, Pipeline, PipelineBuilder};

#[derive(Debug, Clone)]
pub struct ArsConfig {
    /// Sensor window rate (windows/s) for live runs; batch runs use a high
    /// rate with no pacing.
    pub rate: f64,
    pub num_windows: u64,
    pub live: bool,
}

impl Default for ArsConfig {
    fn default() -> Self {
        Self {
            rate: 30.0,
            num_windows: 240,
            live: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct ArsReport {
    pub rate_a: f64,
    pub rate_b: f64,
    pub rate_c: f64,
    pub cpu_percent: f64,
    pub mem_mib: f64,
    pub wall_s: f64,
    pub dropped: u64,
    /// The pipeline description length (the paper's "dozen lines" claim).
    pub description_lines: usize,
}

/// The ARS pipeline as a launch description (measured for the paper's
/// developmental-effort claim: this is the entire application).
pub fn launch_description(cfg: &ArsConfig) -> String {
    let live = if cfg.live { "true" } else { "false" };
    let n = cfg.num_windows;
    let rate = cfg.rate;
    format!(
        "sensorsrc kind=accel window=128 channels=3 rate={rate} num-buffers={n} is-live={live} ! tee name=ta\n\
         ta. ! queue ! tensor_filter framework=xla model=ars_a_opt ! fakesink name=sink_a\n\
         sensorsrc kind=pressure window=128 channels=1 rate={rate} num-buffers={n} is-live={live} ! tee name=tp\n\
         ta. ! queue ! tensor_merge mode=linear option=0 sync-mode=slowest name=m\n\
         tp. ! queue ! m.\n\
         ta. ! queue ! tensor_transform mode=stand ! m.\n\
         tp. ! queue ! tensor_transform mode=stand ! m.\n\
         m. ! tensor_aggregator frames-in=4 frames-dim=1 ! tensor_filter framework=xla model=ars_b_opt ! fakesink name=sink_b\n\
         sensorsrc kind=mic window=64 channels=16 rate={rate} num-buffers={n} is-live={live} ! \
           tensor_rate framerate={half} ! tensor_filter framework=xla model=ars_c_opt ! fakesink name=sink_c",
        half = rate / 2.0,
    )
}

/// Build the Fig 3 graph through the typed builder (the launch string
/// above is the paper-facing "dozen lines"; the builder keeps branch
/// wiring explicit and compile-time-checked).
pub fn build_pipeline(cfg: &ArsConfig) -> Result<Graph> {
    let sensor = |kind, window, channels| SensorSrcProps {
        kind,
        window,
        channels,
        rate: cfg.rate,
        num_buffers: Some(cfg.num_windows),
        is_live: cfg.live,
        ..Default::default()
    };
    let xla = |model: &str| TensorFilterProps {
        framework: Framework::Xla,
        model: model.to_string(),
        ..Default::default()
    };

    let mut b = PipelineBuilder::new();

    // accel source + tee, (a) fast path: per-window activity classifier
    b.chain_named("accel", sensor(SensorKind::Accel, 128, 3))?
        .chain_named("ta", TeeProps)?
        .chain(QueueProps::default())?
        .chain(xla("ars_a_opt"))?
        .chain_named("sink_a", FakeSinkProps::default())?;

    // pressure source + tee
    b.chain_named("pressure", sensor(SensorKind::Pressure, 128, 1))?
        .chain_named("tp", TeeProps)?;

    // (b) slow path: 8-channel fusion -> 4x aggregation -> long classifier
    // (merge input order = pad order: accel raw, pressure raw, accel
    // standardized, pressure standardized)
    b.add_named(
        "m",
        TensorMergeProps {
            axis: 0, // channel axis (minor)
            ..Default::default()
        },
    )?;
    b.from("ta")?.chain(QueueProps::default())?.to("m")?;
    b.from("tp")?.chain(QueueProps::default())?.to("m")?;
    b.from("ta")?
        .chain(QueueProps::default())?
        .chain(TensorTransformProps::stand())?
        .to("m")?;
    b.from("tp")?
        .chain(QueueProps::default())?
        .chain(TensorTransformProps::stand())?
        .to("m")?;
    b.from("m")?
        .chain(TensorAggregatorProps {
            frames_in: 4,
            frames_dim: 1, // time axis
            ..Default::default()
        })?
        .chain(xla("ars_b_opt"))?
        .chain_named("sink_b", FakeSinkProps::default())?;

    // (c) mic path: rate-decimated audio event classifier
    b.chain_named("mic", sensor(SensorKind::Mic, 64, 16))?
        .chain(TensorRateProps {
            framerate: cfg.rate / 2.0,
            ..Default::default()
        })?
        .chain(xla("ars_c_opt"))?
        .chain_named("sink_c", FakeSinkProps::default())?;

    Ok(b.into_graph())
}

/// Run the NNStreamer ARS pipeline and collect Fig 3 measurements.
pub fn run_nns(cfg: &ArsConfig) -> Result<ArsReport> {
    let mem_before = MemInfo::read().vm_rss_kib;
    let mut pipeline = Pipeline::new(build_pipeline(cfg)?);
    let report = pipeline.run()?;
    let mem_after = MemInfo::read().vm_rss_kib;
    // tensor_rate drops are intentional decimation, not lost frames
    let dropped = report
        .elements
        .iter()
        .filter(|e| !e.name.starts_with("tensor_rate"))
        .map(|e| e.dropped())
        .sum();
    Ok(ArsReport {
        rate_a: report.fps("sink_a"),
        rate_b: report.fps("sink_b"),
        rate_c: report.fps("sink_c"),
        cpu_percent: report.element_cpu_percent(),
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
        wall_s: report.wall.as_secs_f64(),
        dropped,
        description_lines: launch_description(cfg).lines().count(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ars_pipeline_negotiates() {
        let cfg = ArsConfig {
            num_windows: 4,
            ..Default::default()
        };
        let mut g = build_pipeline(&cfg).unwrap();
        g.negotiate_all().unwrap();
    }

    #[test]
    fn ars_pipeline_stage_rates() {
        let cfg = ArsConfig {
            num_windows: 64,
            live: false,
            ..Default::default()
        };
        // assert on processed *counts* (rates race in batch mode):
        // a sees every window; b every 4th (aggregator); c every 2nd (rate)
        let mut p = Pipeline::new(build_pipeline(&cfg).unwrap());
        let report = p.run().unwrap();
        let count = |n: &str| report.element(n).unwrap().buffers_in();
        assert_eq!(count("sink_a"), 64);
        let b = count("sink_b");
        assert!((12..=16).contains(&b), "b decimated 4x, got {b}");
        let c = count("sink_c");
        assert!((28..=34).contains(&c), "c decimated 2x, got {c}");
    }

    #[test]
    fn description_is_a_dozen_lines() {
        let lines = launch_description(&ArsConfig::default()).lines().count();
        assert!(lines <= 12, "paper: 'only a dozen lines', got {lines}");
    }
}
