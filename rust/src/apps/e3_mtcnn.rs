//! E3: the MTCNN face-detection cascade (Fig 4, Table II).
//!
//! Pipeline shape (as in the paper's figure):
//!
//! ```text
//! videotestsrc(FullHD) ! videoconvert ! tee t
//!   t. ! queue ! videoscale(scale i) ! tensor_converter ! typecast !
//!        normalize ! tensor_filter(pnet_s{i}) ! custom(pnet_post_s{i}) \
//!     -> tensor_mux (5 scales) ! custom(merge+NMS)          [P-Net Stage]
//!   t. ! queue ! videoscale(base) ! tensor_converter ! typecast !
//!        normalize ! tee frame_f32
//!   mux(frame_f32, pnet boxes) ! custom(rnet_stage)          [R-Net Stage]
//!   mux(frame_f32, rnet boxes) ! custom(onet_stage)          [O-Net Stage]
//!   ! tensor_decoder(direct_video) ! fakesink                [Video Sink]
//! ```
//!
//! The N/B/I boxes of Fig 4 (NMS, bounding-box regression, image patch)
//! live in [`super::postproc`] and run as `framework=custom` filter stages,
//! like the paper's 1004 lines of re-implemented post-processing.
//!
//! The R/O stages embed their model execution inside the custom stage
//! (patch extraction and regression need the candidate boxes next to the
//! tensor batch); P-Nets are plain `tensor_filter` elements. Device classes
//! (Table II's A/B/C columns) throttle all model executions.

use std::sync::Arc;
use std::time::Instant;

use crate::devices::DeviceClass;
use crate::elements::decoder::{decode_boxes, encode_boxes, DetBox, MAX_BOXES};
use crate::error::{Error, Result};
use crate::nnfw::register_custom;
use crate::pipeline::Graph;
use crate::runtime::{Model, ModelRegistry};
use crate::tensor::{Chunk, DType, TensorInfo};

use super::postproc::{extract_patches, nms, pnet_candidates, apply_bbr};

/// The pyramid must match `python/compile/models/mtcnn.py`.
pub const PYRAMID: [(usize, usize); 5] = [(108, 192), (76, 136), (54, 96), (38, 68), (27, 48)];
pub const BASE: (usize, usize) = (108, 192); // (H, W)
pub const RNET_BATCH: usize = 16;
pub const ONET_BATCH: usize = 8;

const BOXES_LEN: usize = 1 + MAX_BOXES * 6;

#[derive(Debug, Clone)]
pub struct MtcnnConfig {
    pub class: DeviceClass,
    /// Source resolution (paper: Full-HD).
    pub src_w: usize,
    pub src_h: usize,
    pub thresholds: [f32; 3],
    pub num_frames: u64,
    pub fps: f64,
    pub live: bool,
}

impl Default for MtcnnConfig {
    fn default() -> Self {
        Self {
            class: DeviceClass::Pc,
            src_w: 1920,
            src_h: 1080,
            thresholds: [0.6, 0.6, 0.55],
            num_frames: 30,
            fps: 30.0,
            live: false,
        }
    }
}

fn boxes_info() -> TensorInfo {
    TensorInfo::new(DType::F32, [BOXES_LEN])
}

fn class_suffix(class: DeviceClass) -> &'static str {
    match class {
        DeviceClass::MidEmbedded => "a",
        DeviceClass::HighEmbedded => "b",
        DeviceClass::Pc => "c",
    }
}

/// Throttle a model execution to the device class (sleep-padded envelope;
/// see DESIGN.md substitutions).
fn execute_throttled(
    model: &Arc<Model>,
    inputs: &[&Chunk],
    class: DeviceClass,
) -> Result<Vec<Chunk>> {
    let t0 = Instant::now();
    let out = model.execute(inputs)?;
    class.throttle(t0.elapsed());
    Ok(out)
}

/// Register every custom stage for a device class. Idempotent per class.
pub fn register_stages(class: DeviceClass) -> Result<()> {
    let reg = ModelRegistry::global()?;
    let sfx = class_suffix(class);
    let (bh, bw) = BASE;

    // P-Net post per scale: (prob, reg) maps -> candidate boxes
    for (i, (h, w)) in PYRAMID.iter().enumerate() {
        let spec = reg
            .load(&format!("pnet_s{i}_opt"))?
            .spec
            .clone();
        // output maps (1, mh, mw, 2/4)
        let mh = spec.outputs[0].dims.as_slice()[1];
        let mw = spec.outputs[0].dims.as_slice()[2];
        let scale = *w as f32 / bw as f32;
        let threshold = 0.6f32;
        let _ = (h, bh);
        register_custom(
            &format!("mtcnn_pnet_post_s{i}"),
            vec![
                TensorInfo::new(DType::F32, [2, mw, mh, 1]),
                TensorInfo::new(DType::F32, [4, mw, mh, 1]),
            ],
            vec![boxes_info()],
            move |ins| {
                let prob = ins[0].to_f32_vec()?;
                let rg = ins[1].to_f32_vec()?;
                let cands = pnet_candidates(
                    &prob, &rg, mh, mw, scale, bw as f32, bh as f32, threshold,
                );
                let kept = nms(cands, 0.5);
                Ok(vec![encode_boxes(&kept[..kept.len().min(MAX_BOXES)])])
            },
        );
    }

    // Cross-scale merge + NMS
    register_custom(
        "mtcnn_merge_nms",
        vec![boxes_info(); PYRAMID.len()],
        vec![boxes_info()],
        move |ins| {
            let mut all = Vec::new();
            for c in ins {
                all.extend(decode_boxes(c)?);
            }
            let kept = nms(all, 0.7);
            Ok(vec![encode_boxes(&kept[..kept.len().min(RNET_BATCH)])])
        },
    );

    // R-Net stage: (frame_f32, boxes) -> refined boxes
    let rnet = reg.load("rnet_opt")?;
    let t_r = 0.6f32;
    register_custom(
        &format!("mtcnn_rnet_stage_{sfx}"),
        vec![
            TensorInfo::new(DType::F32, [3, bw, bh, 1]),
            boxes_info(),
        ],
        vec![boxes_info()],
        move |ins| {
            let frame = ins[0].as_f32()?;
            let boxes = decode_boxes(ins[1])?;
            if boxes.is_empty() {
                return Ok(vec![encode_boxes(&[])]);
            }
            let patches =
                extract_patches(frame, bh, bw, 3, &boxes, 24, RNET_BATCH);
            let input = Chunk::from_f32(&patches);
            let outs = execute_throttled(&rnet, &[&input], class)?;
            let probs = outs[0].to_f32_vec()?;
            let regs = outs[1].to_f32_vec()?;
            let mut refined = Vec::new();
            for (i, b) in boxes.iter().take(RNET_BATCH).enumerate() {
                let p = probs[i * 2 + 1];
                if p < t_r {
                    continue;
                }
                let r: [f32; 4] = regs[i * 4..i * 4 + 4].try_into().unwrap();
                let mut nb = apply_bbr(b, &r);
                nb.score = p;
                refined.push(nb);
            }
            let kept = nms(refined, 0.7);
            Ok(vec![encode_boxes(&kept[..kept.len().min(ONET_BATCH)])])
        },
    );

    // O-Net stage: (frame_f32, boxes) -> final boxes
    let onet = reg.load("onet_opt")?;
    let t_o = 0.55f32;
    register_custom(
        &format!("mtcnn_onet_stage_{sfx}"),
        vec![
            TensorInfo::new(DType::F32, [3, bw, bh, 1]),
            boxes_info(),
        ],
        vec![boxes_info()],
        move |ins| {
            let frame = ins[0].as_f32()?;
            let boxes = decode_boxes(ins[1])?;
            if boxes.is_empty() {
                return Ok(vec![encode_boxes(&[])]);
            }
            let patches =
                extract_patches(frame, bh, bw, 3, &boxes, 48, ONET_BATCH);
            let input = Chunk::from_f32(&patches);
            let outs = execute_throttled(&onet, &[&input], class)?;
            let probs = outs[0].to_f32_vec()?;
            let regs = outs[1].to_f32_vec()?;
            let mut refined = Vec::new();
            for (i, b) in boxes.iter().take(ONET_BATCH).enumerate() {
                let p = probs[i * 2 + 1];
                if p < t_o {
                    continue;
                }
                let r: [f32; 4] = regs[i * 4..i * 4 + 4].try_into().unwrap();
                let mut nb = apply_bbr(b, &r);
                nb.score = p;
                refined.push(nb);
            }
            let kept = nms(refined, 0.6);
            Ok(vec![encode_boxes(&kept)])
        },
    );
    Ok(())
}

/// `framework=custom` filter props for a registered post-processing stage.
fn custom_stage(model: String) -> crate::elements::filter::TensorFilterProps {
    crate::elements::filter::TensorFilterProps {
        framework: crate::elements::filter::Framework::Custom,
        model,
        ..Default::default()
    }
}

/// Caps of the normalized base-frame stream (what flows out of the
/// `t_frame` tee) — the explicit announcement of the split pipelines'
/// `<prefix>/frames` topic.
pub fn frame_caps(cfg: &MtcnnConfig) -> crate::tensor::Caps {
    let (bh, bw) = BASE;
    crate::tensor::Caps::Tensor {
        info: TensorInfo::new(DType::F32, [3, bw, bh, 1]),
        fps_millis: (cfg.fps * 1000.0).round() as u64,
    }
}

/// Caps of the encoded candidate-box stream (`pnet_merge` output) — the
/// explicit announcement of the `<prefix>/boxes` topic.
pub fn box_caps(cfg: &MtcnnConfig) -> crate::tensor::Caps {
    crate::tensor::Caps::Tensor {
        info: boxes_info(),
        fps_millis: (cfg.fps * 1000.0).round() as u64,
    }
}

/// Front stage shared by the fused and split builds: source, tee, the
/// 5-scale P-Net pyramid merged into `pnet_merge`, and the normalized
/// base-frame branch ending in the `t_frame` tee.
fn build_front(b: &mut crate::pipeline::PipelineBuilder, cfg: &MtcnnConfig) -> Result<()> {
    use crate::elements::filter::{Framework, TensorFilterProps};
    use crate::elements::flow::{QueueProps, TeeProps};
    use crate::elements::mux::TensorMuxProps;
    use crate::elements::sources::VideoTestSrcProps;
    use crate::elements::transform::{ArithOp, TensorTransformProps};
    use crate::elements::videofilters::VideoScaleProps;
    use crate::video::Pattern;

    let (bh, bw) = BASE;
    // typecast + the MTCNN normalization (x - 127.5) / 128
    let cast = || TensorTransformProps::typecast(DType::F32);
    let norm = || {
        TensorTransformProps::arithmetic(vec![
            (ArithOp::Add, -127.5),
            (ArithOp::Div, 128.0),
        ])
    };

    b.chain_named(
        "src",
        VideoTestSrcProps {
            pattern: Pattern::Ball,
            width: cfg.src_w,
            height: cfg.src_h,
            framerate: cfg.fps,
            num_buffers: Some(cfg.num_frames),
            is_live: cfg.live,
            ..Default::default()
        },
    )?
    .chain_named("t", TeeProps)?;

    // P-Net branches feed the cross-scale mux in pyramid order
    b.add_named("pnet_mux", TensorMuxProps::default())?;
    for (i, (h, w)) in PYRAMID.iter().enumerate() {
        b.from("t")?
            .chain(QueueProps::default())?
            .chain(VideoScaleProps {
                width: *w,
                height: *h,
            })?
            .chain(crate::elements::converter::TensorConverterProps)?
            .chain(cast())?
            .chain(norm())?
            .chain_named(
                format!("pnet_s{i}"),
                TensorFilterProps {
                    framework: Framework::Xla,
                    model: format!("pnet_s{i}_opt"),
                    device_class: cfg.class,
                    ..Default::default()
                },
            )?
            .chain(custom_stage(format!("mtcnn_pnet_post_s{i}")))?
            .chain(QueueProps::default())?
            .to("pnet_mux")?;
    }
    b.from("pnet_mux")?
        .chain_named("pnet_merge", custom_stage("mtcnn_merge_nms".into()))?;

    // base frame branch (f32, normalized)
    b.from("t")?
        .chain(QueueProps::default())?
        .chain(VideoScaleProps {
            width: bw,
            height: bh,
        })?
        .chain(crate::elements::converter::TensorConverterProps)?
        .chain(cast())?
        .chain(norm())?
        .chain_named("t_frame", TeeProps)?;
    Ok(())
}

/// Back stage shared by the fused and split builds: the R-Net and O-Net
/// refinement stages, decoder, and video sink — wired from elements
/// named `t_frame` (the normalized frame stream) and `pnet_merge` (the
/// candidate boxes). The fused pipeline provides those as its tee/merge
/// elements; the split back half provides them as `tensor_query` topic
/// sources. With `collect` the sink is a `tensor_sink` (for bitwise
/// output comparison) instead of a `fakesink`.
fn build_back(
    b: &mut crate::pipeline::PipelineBuilder,
    cfg: &MtcnnConfig,
    collect: bool,
) -> Result<()> {
    use crate::elements::decoder::{DecoderMode, TensorDecoderProps};
    use crate::elements::flow::QueueProps;
    use crate::elements::mux::TensorMuxProps;
    use crate::elements::sinks::{FakeSinkProps, TensorSinkProps};

    let sfx = class_suffix(cfg.class);
    let (bh, bw) = BASE;

    // R-Net stage: (frame, pnet boxes) -> refined boxes
    b.add_named("mux_r", TensorMuxProps::default())?;
    b.from("t_frame")?.chain(QueueProps::default())?.to("mux_r")?;
    b.from("pnet_merge")?.chain(QueueProps::default())?.to("mux_r")?;
    b.from("mux_r")?
        .chain_named("rnet_stage", custom_stage(format!("mtcnn_rnet_stage_{sfx}")))?;

    // O-Net stage: (frame, rnet boxes) -> final boxes
    b.add_named("mux_o", TensorMuxProps::default())?;
    b.from("t_frame")?.chain(QueueProps::default())?.to("mux_o")?;
    b.from("rnet_stage")?.chain(QueueProps::default())?.to("mux_o")?;
    b.from("mux_o")?
        .chain_named("onet_stage", custom_stage(format!("mtcnn_onet_stage_{sfx}")))?;

    // Video sink: draw boxes on a transparent canvas
    b.from("onet_stage")?.chain(TensorDecoderProps {
        mode: DecoderMode::DirectVideo,
        width: bw,
        height: bh,
        ..Default::default()
    })?;
    if collect {
        b.chain_named("video_sink", TensorSinkProps::default())?;
    } else {
        b.chain_named("video_sink", FakeSinkProps::default())?;
    }
    Ok(())
}

/// Build the full MTCNN NNStreamer pipeline graph through the typed
/// builder.
pub fn build_pipeline(cfg: &MtcnnConfig) -> Result<Graph> {
    register_stages(cfg.class)?;
    let mut b = crate::pipeline::PipelineBuilder::new();
    build_front(&mut b, cfg)?;
    build_back(&mut b, cfg, false)?;
    Ok(b.into_graph())
}

/// The fused pipeline with a collecting `tensor_sink` (named
/// `video_sink`) — reference output for the split-vs-fused bit-identity
/// assertion.
pub fn build_collect_pipeline(cfg: &MtcnnConfig) -> Result<Graph> {
    register_stages(cfg.class)?;
    let mut b = crate::pipeline::PipelineBuilder::new();
    build_front(&mut b, cfg)?;
    build_back(&mut b, cfg, true)?;
    Ok(b.into_graph())
}

/// The cascade split into **two hub pipelines joined by stream topics**
/// (the among-device composition: camera + P-Net stage on one "device",
/// R/O-Net refinement on another). The front pipeline publishes
/// `<prefix>/frames` (normalized base frames) and `<prefix>/boxes`
/// (P-Net candidates); the back pipeline subscribes both and runs the
/// refinement stages. Launch the **back** pipeline first so its
/// subscriptions exist before the front produces — then sink output is
/// bit-identical to the fused run (asserted in `tests/query.rs`).
pub fn build_split_pipelines(
    cfg: &MtcnnConfig,
    prefix: &str,
    collect: bool,
) -> Result<(Graph, Graph)> {
    Ok((
        build_split_front(cfg, prefix, "inproc", 0)?,
        build_split_back(cfg, prefix, collect, "inproc")?,
    ))
}

/// The front (camera + P-Net) half of the split cascade alone, publishing
/// its two topics over `transport`. With a network transport and
/// `wait_subscribers = 1` the serversinks park until the remote back half
/// attaches, so no frame is lost to connection racing — the body of the
/// publisher OS process in the two-process cascade.
pub fn build_split_front(
    cfg: &MtcnnConfig,
    prefix: &str,
    transport: &str,
    wait_subscribers: usize,
) -> Result<Graph> {
    use crate::elements::query::QueryServerSinkProps;

    register_stages(cfg.class)?;
    let mut f = crate::pipeline::PipelineBuilder::new();
    build_front(&mut f, cfg)?;
    f.from("pnet_merge")?.chain_named(
        "boxes_out",
        QueryServerSinkProps {
            topic: format!("{prefix}/boxes"),
            transport: transport.to_string(),
            wait_subscribers,
            ..Default::default()
        },
    )?;
    f.from("t_frame")?.chain_named(
        "frames_out",
        QueryServerSinkProps {
            topic: format!("{prefix}/frames"),
            transport: transport.to_string(),
            wait_subscribers,
            ..Default::default()
        },
    )?;
    Ok(f.into_graph())
}

/// The back (R/O-Net refinement) half of the split cascade alone: two
/// topic subscribers standing in for the front's tee/merge elements
/// (same node names `build_back` wires from), resolving over
/// `transport` — the consumer OS process of the two-process cascade.
pub fn build_split_back(
    cfg: &MtcnnConfig,
    prefix: &str,
    collect: bool,
    transport: &str,
) -> Result<Graph> {
    use crate::elements::flow::TeeProps;
    use crate::elements::query::QueryServerSrcProps;

    register_stages(cfg.class)?;
    let mut k = crate::pipeline::PipelineBuilder::new();
    k.chain_named(
        "frames_in",
        QueryServerSrcProps {
            topic: format!("{prefix}/frames"),
            caps: frame_caps(cfg),
            transport: transport.to_string(),
            ..Default::default()
        },
    )?
    .chain_named("t_frame", TeeProps)?;
    k.add_named(
        "pnet_merge",
        QueryServerSrcProps {
            topic: format!("{prefix}/boxes"),
            caps: box_caps(cfg),
            transport: transport.to_string(),
            ..Default::default()
        },
    )?;
    build_back(&mut k, cfg, collect)?;
    Ok(k.into_graph())
}

/// Run only the front half over `transport` (blocking): the publisher OS
/// process of the two-process cascade. Serversinks wait for one remote
/// subscriber each before producing.
pub fn run_split_front(
    cfg: &MtcnnConfig,
    prefix: &str,
    transport: &str,
) -> Result<crate::metrics::stats::PipelineReport> {
    let g = build_split_front(cfg, prefix, transport, 1)?;
    let mut pipeline = crate::pipeline::Pipeline::new(g);
    pipeline.run()
}

/// Run only the back half over `transport` (blocking, collect variant):
/// the consumer OS process of the two-process cascade. Returns the
/// pipeline report and the sink payloads for bit-identity comparison.
pub fn run_split_back(
    cfg: &MtcnnConfig,
    prefix: &str,
    transport: &str,
) -> Result<(crate::metrics::stats::PipelineReport, Vec<(u64, Vec<u8>)>)> {
    let g = build_split_back(cfg, prefix, true, transport)?;
    let mut pipeline = crate::pipeline::Pipeline::new(g);
    let report = pipeline.run()?;
    let sink = collect_sink(&mut pipeline);
    Ok((report, sink))
}

/// Sink payloads of a finished collect-variant pipeline, in arrival
/// order: `(pts, bytes)` per frame.
pub fn collect_sink(pipeline: &mut crate::pipeline::Pipeline) -> Vec<(u64, Vec<u8>)> {
    use crate::elements::sinks::TensorSink;
    let Some(el) = pipeline.finished_element("video_sink") else {
        return Vec::new();
    };
    el.as_any()
        .and_then(|a| a.downcast_mut::<TensorSink>())
        .map(|sink| {
            sink.buffers
                .iter()
                .map(|b| (b.pts_ns, b.chunk().as_bytes_unaccounted().to_vec()))
                .collect()
        })
        .unwrap_or_default()
}

/// Run the fused collect-variant pipeline and return its sink payloads.
pub fn run_collect(cfg: &MtcnnConfig) -> Result<Vec<(u64, Vec<u8>)>> {
    let mut g = build_collect_pipeline(cfg)?;
    let mut pipeline = crate::pipeline::Pipeline::new(g_take(&mut g));
    pipeline.run()?;
    Ok(collect_sink(&mut pipeline))
}

/// Result of one split (two-pipeline) cascade run.
pub struct SplitRun {
    /// Report of the front (camera + P-Net) pipeline.
    pub front: crate::metrics::stats::PipelineReport,
    /// Report of the back (R/O-Net refinement) pipeline.
    pub back: crate::metrics::stats::PipelineReport,
    /// Sink payloads of the back pipeline (collect variant).
    pub sink: Vec<(u64, Vec<u8>)>,
}

/// Run the cascade as two hub pipelines joined by topics (back pipeline
/// launched first so nothing is dropped) on a dedicated `workers`-sized
/// pool, and collect the back sink's payloads.
pub fn run_split(cfg: &MtcnnConfig, prefix: &str, workers: usize) -> Result<SplitRun> {
    let (front, back) = build_split_pipelines(cfg, prefix, true)?;
    let hub = crate::pipeline::PipelineHub::with_workers(workers);
    hub.launch("mtcnn-back", crate::pipeline::Pipeline::new(back))?;
    hub.launch("mtcnn-front", crate::pipeline::Pipeline::new(front))?;
    let mut front_report = None;
    let mut back_report = None;
    let mut sink = Vec::new();
    for j in hub.join_all() {
        let report = j.report?;
        let mut pipeline = j.pipeline;
        if j.name == "mtcnn-back" {
            sink = collect_sink(&mut pipeline);
            back_report = Some(report);
        } else {
            front_report = Some(report);
        }
    }
    Ok(SplitRun {
        front: front_report.ok_or_else(|| Error::Runtime("front pipeline missing".into()))?,
        back: back_report.ok_or_else(|| Error::Runtime("back pipeline missing".into()))?,
        sink,
    })
}

/// The same pipeline as a launch description (parser-compat fixture for
/// `tests/api_roundtrip.rs`). Requires [`register_stages`] to have run
/// for `cfg.class` so the custom filter stages resolve.
pub fn launch_description(cfg: &MtcnnConfig) -> String {
    let sfx = class_suffix(cfg.class);
    let (bh, bw) = BASE;
    let mut desc = format!(
        "videotestsrc name=src pattern=ball width={w} height={h} framerate={fps} \
         num-buffers={n} is-live={live} ! tee name=t",
        w = cfg.src_w,
        h = cfg.src_h,
        fps = cfg.fps,
        n = cfg.num_frames,
        live = cfg.live,
    );
    for (i, (h, w)) in PYRAMID.iter().enumerate() {
        let mux_head = if i == 0 {
            " ! tensor_mux name=pnet_mux sync-mode=slowest".to_string()
        } else {
            " ! pnet_mux.".to_string()
        };
        desc.push_str(&format!(
            " t. ! queue ! videoscale width={w} height={h} ! tensor_converter ! \
             tensor_transform mode=typecast option=float32 ! \
             tensor_transform mode=arithmetic option=add:-127.5,div:128 ! \
             tensor_filter name=pnet_s{i} framework=xla model=pnet_s{i}_opt device-class={sfx} ! \
             tensor_filter framework=custom model=mtcnn_pnet_post_s{i} ! queue{mux_head}",
        ));
    }
    desc.push_str(
        " pnet_mux. ! tensor_filter name=pnet_merge framework=custom model=mtcnn_merge_nms",
    );
    desc.push_str(&format!(
        " t. ! queue ! videoscale width={bw} height={bh} ! tensor_converter ! \
         tensor_transform mode=typecast option=float32 ! \
         tensor_transform mode=arithmetic option=add:-127.5,div:128 ! tee name=t_frame",
    ));
    desc.push_str(&format!(
        " t_frame. ! queue ! tensor_mux name=mux_r sync-mode=slowest \
         pnet_merge. ! queue ! mux_r. \
         mux_r. ! tensor_filter name=rnet_stage framework=custom model=mtcnn_rnet_stage_{sfx} \
         t_frame. ! queue ! tensor_mux name=mux_o sync-mode=slowest \
         rnet_stage. ! queue ! mux_o. \
         mux_o. ! tensor_filter name=onet_stage framework=custom model=mtcnn_onet_stage_{sfx} ! \
         tensor_decoder mode=direct_video width={bw} height={bh} ! fakesink name=video_sink",
    ));
    desc
}

/// Per-run measurements shared by the NNS pipeline and the Control loop.
#[derive(Debug, Default, Clone)]
pub struct MtcnnReport {
    pub frames: u64,
    pub wall_s: f64,
    pub throughput_fps: f64,
    /// Mean end-to-end latency (ms), measured at 1 fps live input.
    pub overall_latency_ms: f64,
    pub pnet_latency_ms: f64,
    pub rnet_latency_ms: f64,
    pub onet_latency_ms: f64,
}

/// Run the NNStreamer MTCNN pipeline and collect Table II measurements.
pub fn run_nns(cfg: &MtcnnConfig) -> Result<MtcnnReport> {
    let mut g = build_pipeline(cfg)?;
    let mut pipeline = crate::pipeline::Pipeline::new(g_take(&mut g));
    let report = pipeline.run()?;
    let sink = report
        .element("video_sink")
        .ok_or_else(|| Error::Runtime("no video_sink stats".into()))?;
    let frames = sink.buffers_in();
    // P-Net stage latency: slowest P-Net branch (filter + post) mean
    let mut pnet_ms: f64 = 0.0;
    for i in 0..PYRAMID.len() {
        if let Some(e) = report.element(&format!("pnet_s{i}")) {
            pnet_ms = pnet_ms.max(e.latency().mean.as_secs_f64() * 1e3);
        }
    }
    let stage_ms = |name: &str| -> f64 {
        report
            .element(name)
            .map(|e| e.latency().mean.as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    };
    // overall latency: mean over sink arrivals vs pts (live runs only)
    Ok(MtcnnReport {
        frames,
        wall_s: report.wall.as_secs_f64(),
        throughput_fps: frames as f64 / report.wall.as_secs_f64(),
        overall_latency_ms: 0.0, // filled by latency runs (run_nns_latency)
        pnet_latency_ms: pnet_ms,
        rnet_latency_ms: stage_ms("rnet_stage"),
        onet_latency_ms: stage_ms("onet_stage"),
    })
}

// Graph is not Clone; move helper keeps run_nns tidy.
fn g_take(g: &mut Graph) -> Graph {
    std::mem::take(g)
}

/// Serial Control implementation (the paper's ROS-based C++ team's code):
/// every stage for every frame, one after another, single thread.
pub fn run_control(cfg: &MtcnnConfig) -> Result<MtcnnReport> {
    let reg = ModelRegistry::global()?;
    let (bh, bw) = BASE;
    let mut pnets = Vec::new();
    for i in 0..PYRAMID.len() {
        pnets.push(reg.load(&format!("pnet_s{i}_opt"))?);
    }
    let rnet = reg.load("rnet_opt")?;
    let onet = reg.load("onet_opt")?;

    let t0 = Instant::now();
    let mut pnet_ms = 0.0f64;
    let mut rnet_ms = 0.0f64;
    let mut onet_ms = 0.0f64;
    let mut total_ms = 0.0f64;
    for n in 0..cfg.num_frames {
        let f0 = Instant::now();
        // fetch + convert (the Control code also caches everything: it
        // keeps full-res copies around, i.e. an extra frame copy per stage)
        let frame = crate::video::pattern::generate_rgb(
            crate::video::Pattern::Ball,
            cfg.src_w,
            cfg.src_h,
            n,
        );
        let _cached = frame.clone(); // "caching everything in memory"
        // P-Net over the pyramid — serial
        let ps = Instant::now();
        let mut cands: Vec<DetBox> = Vec::new();
        for (i, (h, w)) in PYRAMID.iter().enumerate() {
            let scaled = crate::video::scale_bilinear(
                crate::tensor::VideoFormat::Rgb,
                cfg.src_w,
                cfg.src_h,
                *w,
                *h,
                &frame,
            );
            let norm: Vec<f32> = scaled.iter().map(|&v| (v as f32 - 127.5) / 128.0).collect();
            let input = Chunk::from_f32(&norm);
            let outs = execute_throttled(&pnets[i], &[&input], cfg.class)?;
            let prob = outs[0].to_f32_vec()?;
            let rg = outs[1].to_f32_vec()?;
            let spec = &pnets[i].spec;
            let mh = spec.outputs[0].dims.as_slice()[1];
            let mw = spec.outputs[0].dims.as_slice()[2];
            let scale = *w as f32 / bw as f32;
            cands.extend(pnet_candidates(
                &prob,
                &rg,
                mh,
                mw,
                scale,
                bw as f32,
                bh as f32,
                cfg.thresholds[0],
            ));
        }
        let boxes = nms(cands, 0.7);
        let boxes = &boxes[..boxes.len().min(RNET_BATCH)];
        pnet_ms += ps.elapsed().as_secs_f64() * 1e3;

        // base frame for patches
        let base = crate::video::scale_bilinear(
            crate::tensor::VideoFormat::Rgb,
            cfg.src_w,
            cfg.src_h,
            bw,
            bh,
            &frame,
        );
        let base_f: Vec<f32> = base.iter().map(|&v| (v as f32 - 127.5) / 128.0).collect();

        // R-Net — serial
        let rs = Instant::now();
        let mut rboxes = Vec::new();
        if !boxes.is_empty() {
            let patches = extract_patches(&base_f, bh, bw, 3, boxes, 24, RNET_BATCH);
            let input = Chunk::from_f32(&patches);
            let outs = execute_throttled(&rnet, &[&input], cfg.class)?;
            let probs = outs[0].to_f32_vec()?;
            let regs = outs[1].to_f32_vec()?;
            for (i, b) in boxes.iter().take(RNET_BATCH).enumerate() {
                let p = probs[i * 2 + 1];
                if p < cfg.thresholds[1] {
                    continue;
                }
                let r: [f32; 4] = regs[i * 4..i * 4 + 4].try_into().unwrap();
                let mut nb = apply_bbr(b, &r);
                nb.score = p;
                rboxes.push(nb);
            }
            rboxes = nms(rboxes, 0.7);
            rboxes.truncate(ONET_BATCH);
        }
        rnet_ms += rs.elapsed().as_secs_f64() * 1e3;

        // O-Net — serial
        let os = Instant::now();
        let mut fboxes = Vec::new();
        if !rboxes.is_empty() {
            let patches = extract_patches(&base_f, bh, bw, 3, &rboxes, 48, ONET_BATCH);
            let input = Chunk::from_f32(&patches);
            let outs = execute_throttled(&onet, &[&input], cfg.class)?;
            let probs = outs[0].to_f32_vec()?;
            let regs = outs[1].to_f32_vec()?;
            for (i, b) in rboxes.iter().take(ONET_BATCH).enumerate() {
                let p = probs[i * 2 + 1];
                if p < cfg.thresholds[2] {
                    continue;
                }
                let r: [f32; 4] = regs[i * 4..i * 4 + 4].try_into().unwrap();
                let mut nb = apply_bbr(b, &r);
                nb.score = p;
                fboxes.push(nb);
            }
            fboxes = nms(fboxes, 0.6);
        }
        onet_ms += os.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(&fboxes);
        total_ms += f0.elapsed().as_secs_f64() * 1e3;
    }
    let n = cfg.num_frames.max(1) as f64;
    Ok(MtcnnReport {
        frames: cfg.num_frames,
        wall_s: t0.elapsed().as_secs_f64(),
        throughput_fps: cfg.num_frames as f64 / t0.elapsed().as_secs_f64(),
        overall_latency_ms: total_ms / n,
        pnet_latency_ms: pnet_ms / n,
        rnet_latency_ms: rnet_ms / n,
        onet_latency_ms: onet_ms / n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_builds_and_negotiates() {
        let cfg = MtcnnConfig {
            num_frames: 2,
            src_w: 480,
            src_h: 270,
            ..Default::default()
        };
        let mut g = build_pipeline(&cfg).unwrap();
        g.negotiate_all().unwrap();
    }

    #[test]
    fn split_pipelines_build_and_negotiate() {
        let cfg = MtcnnConfig {
            num_frames: 2,
            src_w: 480,
            src_h: 270,
            ..Default::default()
        };
        let (mut front, mut back) =
            build_split_pipelines(&cfg, "unit/e3-negotiate", true).unwrap();
        // back first: its topic subscriptions must exist before the
        // front pipeline starts publishing
        back.negotiate_all().unwrap();
        front.negotiate_all().unwrap();
        assert!(back.by_name("pnet_merge").is_some());
        assert!(front.by_name("frames_out").is_some());
    }

    #[test]
    fn nns_produces_frames() {
        let cfg = MtcnnConfig {
            num_frames: 3,
            src_w: 480,
            src_h: 270,
            fps: 1000.0,
            ..Default::default()
        };
        let report = run_nns(&cfg).unwrap();
        assert_eq!(report.frames, 3);
        assert!(report.pnet_latency_ms > 0.0);
    }

    #[test]
    fn control_runs() {
        let cfg = MtcnnConfig {
            num_frames: 2,
            src_w: 480,
            src_h: 270,
            ..Default::default()
        };
        let report = run_control(&cfg).unwrap();
        assert!(report.overall_latency_ms > 0.0);
        assert!(report.throughput_fps > 0.0);
    }
}
