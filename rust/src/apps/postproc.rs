//! MTCNN post-processing: non-maximum suppression, bounding-box
//! regression, image-patch extraction.
//!
//! The paper notes its E3 NNStreamer implementation re-implements these
//! (1004 of its 1959 lines); they run as `framework=custom` tensor_filter
//! stages between the P/R/O-Net model filters (the N/B/I boxes of Fig 4).

use crate::elements::decoder::DetBox;

/// Intersection-over-union of two center-format boxes.
pub fn iou(a: &DetBox, b: &DetBox) -> f32 {
    let (ax0, ax1) = (a.x - a.w / 2.0, a.x + a.w / 2.0);
    let (ay0, ay1) = (a.y - a.h / 2.0, a.y + a.h / 2.0);
    let (bx0, bx1) = (b.x - b.w / 2.0, b.x + b.w / 2.0);
    let (by0, by1) = (b.y - b.h / 2.0, b.y + b.h / 2.0);
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    let union = a.w * a.h + b.w * b.h - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// Greedy non-maximum suppression (descending score, drop above
/// `iou_threshold`). Returns surviving boxes in score order.
pub fn nms(mut boxes: Vec<DetBox>, iou_threshold: f32) -> Vec<DetBox> {
    boxes.sort_by(|a, b| b.score.total_cmp(&a.score));
    let mut keep: Vec<DetBox> = Vec::new();
    'cand: for b in boxes {
        for k in &keep {
            if iou(k, &b) > iou_threshold {
                continue 'cand;
            }
        }
        keep.push(b);
    }
    keep
}

/// Class-aware NMS: suppression applies only within the same class.
pub fn nms_per_class(boxes: Vec<DetBox>, iou_threshold: f32) -> Vec<DetBox> {
    let mut classes: Vec<usize> = boxes.iter().map(|b| b.class).collect();
    classes.sort_unstable();
    classes.dedup();
    let mut out = Vec::new();
    for c in classes {
        let cls: Vec<DetBox> = boxes.iter().copied().filter(|b| b.class == c).collect();
        out.extend(nms(cls, iou_threshold));
    }
    out.sort_by(|a, b| b.score.total_cmp(&a.score));
    out
}

/// Apply bounding-box regression offsets: `reg = (dx0, dy0, dx1, dy1)`
/// scaled by box size (MTCNN convention, corner format internally).
pub fn apply_bbr(b: &DetBox, reg: &[f32; 4]) -> DetBox {
    let (x0, y0) = (b.x - b.w / 2.0, b.y - b.h / 2.0);
    let (x1, y1) = (b.x + b.w / 2.0, b.y + b.h / 2.0);
    let nx0 = x0 + reg[0] * b.w;
    let ny0 = y0 + reg[1] * b.h;
    let nx1 = x1 + reg[2] * b.w;
    let ny1 = y1 + reg[3] * b.h;
    DetBox {
        x: ((nx0 + nx1) / 2.0).clamp(0.0, 1.0),
        y: ((ny0 + ny1) / 2.0).clamp(0.0, 1.0),
        w: (nx1 - nx0).clamp(0.0, 1.0),
        h: (ny1 - ny0).clamp(0.0, 1.0),
        score: b.score,
        class: b.class,
    }
}

/// Make a box square (MTCNN rerects candidates before patch extraction).
pub fn square(b: &DetBox) -> DetBox {
    let side = b.w.max(b.h);
    DetBox {
        w: side,
        h: side,
        ..*b
    }
}

/// Generate P-Net candidates from its fully-convolutional output maps.
///
/// `prob` is (h, w, 2) NHWC-flattened face probabilities, `reg` (h, w, 4)
/// regressions. The P-Net sliding window has cell size 12 and stride 2 in
/// the *scaled* image; `scale` maps scaled coords back to the base frame.
/// Returned coords are relative ([0,1]) to the base frame.
pub fn pnet_candidates(
    prob: &[f32],
    reg: &[f32],
    map_h: usize,
    map_w: usize,
    scale: f32,
    base_w: f32,
    base_h: f32,
    threshold: f32,
) -> Vec<DetBox> {
    const CELL: f32 = 12.0;
    const STRIDE: f32 = 2.0;
    let mut out = Vec::new();
    for gy in 0..map_h {
        for gx in 0..map_w {
            let p_face = prob[(gy * map_w + gx) * 2 + 1];
            if p_face < threshold {
                continue;
            }
            let r = &reg[(gy * map_w + gx) * 4..(gy * map_w + gx) * 4 + 4];
            // window in scaled-image pixels
            let x0 = gx as f32 * STRIDE / scale;
            let y0 = gy as f32 * STRIDE / scale;
            let side = CELL / scale;
            let b = DetBox {
                x: (x0 + side / 2.0) / base_w,
                y: (y0 + side / 2.0) / base_h,
                w: side / base_w,
                h: side / base_h,
                score: p_face,
                class: 0,
            };
            out.push(apply_bbr(&b, &[r[0], r[1], r[2], r[3]]));
        }
    }
    out
}

/// Extract and bilinearly resize patches from an f32 NHWC frame.
///
/// `frame` is (H, W, C) f32; boxes are relative center-format. The output
/// is a dense (batch, size, size, C) block, zero-padded to `batch` (AOT
/// executables need static batch shapes — see DESIGN.md).
pub fn extract_patches(
    frame: &[f32],
    fh: usize,
    fw: usize,
    ch: usize,
    boxes: &[DetBox],
    size: usize,
    batch: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; batch * size * size * ch];
    for (bi, b) in boxes.iter().take(batch).enumerate() {
        let b = square(b);
        let x0 = ((b.x - b.w / 2.0) * fw as f32).max(0.0);
        let y0 = ((b.y - b.h / 2.0) * fh as f32).max(0.0);
        let pw = (b.w * fw as f32).max(1.0);
        let ph = (b.h * fh as f32).max(1.0);
        for oy in 0..size {
            for ox in 0..size {
                // bilinear sample from the source rect
                let sx = x0 + (ox as f32 + 0.5) / size as f32 * pw - 0.5;
                let sy = y0 + (oy as f32 + 0.5) / size as f32 * ph - 0.5;
                let x_lo = sx.floor().max(0.0) as usize;
                let y_lo = sy.floor().max(0.0) as usize;
                let x_hi = (x_lo + 1).min(fw - 1);
                let y_hi = (y_lo + 1).min(fh - 1);
                let wx = (sx - x_lo as f32).clamp(0.0, 1.0);
                let wy = (sy - y_lo as f32).clamp(0.0, 1.0);
                for c in 0..ch {
                    let s = |y: usize, x: usize| frame[(y * fw + x) * ch + c];
                    let top = s(y_lo.min(fh - 1), x_lo.min(fw - 1)) * (1.0 - wx)
                        + s(y_lo.min(fh - 1), x_hi) * wx;
                    let bot = s(y_hi, x_lo.min(fw - 1)) * (1.0 - wx) + s(y_hi, x_hi) * wx;
                    out[((bi * size + oy) * size + ox) * ch + c] =
                        top * (1.0 - wy) + bot * wy;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bx(x: f32, y: f32, w: f32, h: f32, score: f32) -> DetBox {
        DetBox {
            x,
            y,
            w,
            h,
            score,
            class: 0,
        }
    }

    #[test]
    fn iou_identical_is_one() {
        let a = bx(0.5, 0.5, 0.2, 0.2, 1.0);
        assert!((iou(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        let a = bx(0.2, 0.2, 0.1, 0.1, 1.0);
        let b = bx(0.8, 0.8, 0.1, 0.1, 1.0);
        assert_eq!(iou(&a, &b), 0.0);
    }

    #[test]
    fn nms_keeps_best_of_overlapping() {
        let boxes = vec![
            bx(0.5, 0.5, 0.2, 0.2, 0.9),
            bx(0.51, 0.5, 0.2, 0.2, 0.8), // overlaps the first
            bx(0.1, 0.1, 0.1, 0.1, 0.7),  // separate
        ];
        let keep = nms(boxes, 0.4);
        assert_eq!(keep.len(), 2);
        assert_eq!(keep[0].score, 0.9);
        assert_eq!(keep[1].score, 0.7);
    }

    #[test]
    fn nms_per_class_keeps_cross_class_overlaps() {
        let mut a = bx(0.5, 0.5, 0.2, 0.2, 0.9);
        let mut b = bx(0.5, 0.5, 0.2, 0.2, 0.8);
        a.class = 0;
        b.class = 1;
        let keep = nms_per_class(vec![a, b], 0.4);
        assert_eq!(keep.len(), 2);
    }

    #[test]
    fn bbr_shifts_box() {
        let b = bx(0.5, 0.5, 0.2, 0.2, 1.0);
        let out = apply_bbr(&b, &[0.1, 0.1, 0.1, 0.1]);
        // both corners moved by +0.1*w: center shifts, size constant
        assert!((out.x - 0.52).abs() < 1e-6);
        assert!((out.w - 0.2).abs() < 1e-6);
    }

    #[test]
    fn square_takes_max_side() {
        let b = square(&bx(0.5, 0.5, 0.1, 0.3, 1.0));
        assert_eq!(b.w, 0.3);
        assert_eq!(b.h, 0.3);
    }

    #[test]
    fn pnet_candidates_thresholded() {
        // 2x2 map, only cell (1,0) above threshold
        let prob = vec![
            0.9, 0.1, //
            0.8, 0.2, //
            0.05, 0.95, //
            0.9, 0.1,
        ];
        let reg = vec![0.0; 16];
        let cands = pnet_candidates(&prob, &reg, 2, 2, 1.0, 100.0, 100.0, 0.5);
        assert_eq!(cands.len(), 1);
        let c = cands[0];
        assert!((c.score - 0.95).abs() < 1e-6);
        // cell (gy=1, gx=0): window at (0, 2) size 12
        assert!((c.w - 0.12).abs() < 1e-6);
    }

    #[test]
    fn patches_constant_frame() {
        // constant frame -> constant patches regardless of box
        let frame = vec![0.7f32; 20 * 20 * 3];
        let boxes = vec![bx(0.5, 0.5, 0.4, 0.4, 1.0)];
        let p = extract_patches(&frame, 20, 20, 3, &boxes, 8, 2);
        assert_eq!(p.len(), 2 * 8 * 8 * 3);
        for v in &p[..8 * 8 * 3] {
            assert!((v - 0.7).abs() < 1e-4);
        }
        // padded second slot is zero
        assert!(p[8 * 8 * 3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn patches_preserve_gradient_direction() {
        // horizontal gradient frame: patch should be monotonic in x
        let (h, w) = (16, 16);
        let mut frame = vec![0f32; h * w];
        for y in 0..h {
            for x in 0..w {
                frame[y * w + x] = x as f32 / w as f32;
            }
        }
        let boxes = vec![bx(0.5, 0.5, 0.5, 0.5, 1.0)];
        let p = extract_patches(&frame, h, w, 1, &boxes, 4, 1);
        for row in 0..4 {
            let r = &p[row * 4..(row + 1) * 4];
            assert!(r.windows(2).all(|v| v[0] <= v[1] + 1e-6), "{r:?}");
        }
    }
}
