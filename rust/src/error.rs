//! Crate-wide error type.
//!
//! `Display`/`Error` are implemented by hand: the crate is dependency-free
//! apart from `once_cell`, so there is no `thiserror` to derive them.

use std::time::Duration;

/// A typed stream fault: what broke, where, and how. Faults flow
/// *downstream* — when an element dies, every link, endpoint, and topic
/// it fed carries this record as its close-reason, so consumers (other
/// elements, `AppSink` receivers, topic subscribers in other pipelines)
/// can distinguish a fault-truncated stream from a clean end-of-stream.
///
/// The `element` names the *origin* of the fault, even after the fault
/// crossed several links or a topic boundary: propagation preserves the
/// original record instead of re-wrapping it per hop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fault {
    /// Name of the element where the fault originated.
    pub element: String,
    /// Human-readable cause (panic payload or error message).
    pub message: String,
    /// The origin was a caught panic (vs. a typed `Err` return).
    pub panicked: bool,
}

impl Fault {
    /// Derive the fault record to propagate downstream from the error a
    /// task died with. A fault that merely *arrived* at this element
    /// ([`Error::Fault`]) keeps its original origin; a caught panic
    /// ([`Error::Panicked`]) keeps its payload and panic flag; anything
    /// else becomes a non-panic fault attributed to `element`.
    pub fn from_error(element: &str, err: &Error) -> Fault {
        match err {
            Error::Fault(f) => f.clone(),
            Error::Panicked { element, message } => Fault {
                element: element.clone(),
                message: message.clone(),
                panicked: true,
            },
            other => Fault {
                element: element.to_string(),
                message: other.to_string(),
                panicked: false,
            },
        }
    }
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.panicked {
            write!(f, "element {} panicked: {}", self.element, self.message)
        } else {
            write!(f, "element {} failed: {}", self.element, self.message)
        }
    }
}

/// Errors produced by the streaming framework and its elements.
#[derive(Debug)]
pub enum Error {
    /// Pipeline description could not be parsed.
    Parse(String),

    /// Pipeline description could not be parsed — with the byte span of
    /// the offending token in the original description and, when the
    /// parser knows it, the element being configured.
    ParseAt {
        message: String,
        /// Byte range `[start, end)` into the launch description.
        span: (usize, usize),
        /// Name of the element the error is attributed to.
        element: Option<String>,
    },

    /// Caps negotiation between two linked pads failed.
    Negotiation(String),

    /// An element property was unknown or had an invalid value.
    Property {
        key: String,
        value: String,
        reason: String,
    },

    /// Graph-level error (duplicate names, bad links, cycles, ...).
    Graph(String),

    /// An element failed at runtime while processing a buffer.
    Element { element: String, reason: String },

    /// A control send found the element's mailbox full (the element is
    /// starved of input while the application keeps sending). Sends
    /// never block the application thread; retry after the pipeline
    /// drains, or throttle control traffic.
    ControlBackpressure {
        element: String,
        /// The mailbox capacity that was exhausted.
        capacity: usize,
    },

    /// A tenant request was rejected by the hub's admission control
    /// (quota exhausted). Admission failures are always typed and
    /// immediate — a denied tenant gets this error, never a hang.
    AdmissionDenied {
        /// Tenant whose quota rejected the request.
        tenant: String,
        /// Which quota dimension was exhausted ("live pipelines",
        /// "queued invokes", "topic buffers").
        resource: &'static str,
        /// The configured limit that was reached.
        limit: usize,
    },

    /// An element panicked while processing a step. The panic payload
    /// string is preserved (`&str`/`String` payloads; anything else is
    /// reported as an opaque payload) so the cause survives into logs
    /// and reports instead of being flattened to "element X panicked".
    Panicked { element: String, message: String },

    /// The stream this consumer was reading was truncated by a fault in
    /// an upstream element — possibly in another pipeline, across a
    /// topic. Carries the originating [`Fault`] record.
    Fault(Fault),

    /// A pipeline made no scheduler progress while runnable for longer
    /// than the hub watchdog's configured `stall_timeout`.
    Stalled {
        pipeline: String,
        /// How long the pipeline sat runnable without progress before
        /// the watchdog fired.
        stalled_for: Duration,
    },

    /// A supervised pipeline exhausted its restart budget and was
    /// quarantined by the hub; it will not be restarted again.
    Quarantined {
        pipeline: String,
        /// Restarts consumed before quarantine (== the policy's
        /// `max_restarts`).
        restarts: u32,
        /// Rendered cause of the final fault.
        reason: String,
    },

    /// A network transport could not reach a peer: topic resolution
    /// against the registry failed, or the TCP connect/handshake to the
    /// resolved address failed (after any configured retries).
    Connect {
        /// Topic (or registry endpoint) being reached.
        topic: String,
        /// Address attempted, or the registry address when resolution
        /// itself failed.
        addr: String,
        reason: String,
    },

    /// A wire frame was malformed: bad magic, unsupported version,
    /// unknown frame type, length/checksum mismatch, or a truncated or
    /// internally inconsistent payload. Decoders return this instead of
    /// panicking, whatever the input bytes.
    Frame(String),

    /// The credit-based flow-control protocol was violated on a
    /// connection (e.g. a peer granted credits past the advertised
    /// window, or sent a buffer with no credit outstanding).
    Credit { topic: String, reason: String },

    /// NNFW / model runtime failure (artifact load or execute).
    Runtime(String),

    /// Artifact manifest missing/invalid.
    Manifest(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::ParseAt {
                message,
                span,
                element,
            } => {
                write!(f, "parse error at bytes {}..{}", span.0, span.1)?;
                if let Some(el) = element {
                    write!(f, " (element {el})")?;
                }
                write!(f, ": {message}")
            }
            Error::Negotiation(msg) => write!(f, "negotiation failed: {msg}"),
            Error::Property { key, value, reason } => {
                write!(f, "bad property {key}={value}: {reason}")
            }
            Error::Graph(msg) => write!(f, "graph error: {msg}"),
            Error::Element { element, reason } => write!(f, "element {element}: {reason}"),
            Error::ControlBackpressure { element, capacity } => write!(
                f,
                "control backpressure: mailbox of element {element:?} is full \
                 ({capacity} pending messages); the element is not consuming input"
            ),
            Error::AdmissionDenied {
                tenant,
                resource,
                limit,
            } => write!(
                f,
                "admission denied for tenant {tenant:?}: {resource} quota \
                 exhausted (limit {limit})"
            ),
            Error::Panicked { element, message } => {
                write!(f, "element {element} panicked: {message}")
            }
            Error::Fault(fault) => write!(f, "stream truncated by a fault: {fault}"),
            Error::Stalled {
                pipeline,
                stalled_for,
            } => write!(
                f,
                "pipeline {pipeline:?} stalled: no progress while runnable for \
                 {:.3}s",
                stalled_for.as_secs_f64()
            ),
            Error::Quarantined {
                pipeline,
                restarts,
                reason,
            } => write!(
                f,
                "pipeline {pipeline:?} quarantined after {restarts} restarts: {reason}"
            ),
            Error::Connect { topic, addr, reason } => write!(
                f,
                "connect failed for topic {topic:?} at {addr}: {reason}"
            ),
            Error::Frame(msg) => write!(f, "bad wire frame: {msg}"),
            Error::Credit { topic, reason } => write!(
                f,
                "credit protocol violation on topic {topic:?}: {reason}"
            ),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for element-scoped runtime failures.
    pub fn element(element: impl Into<String>, reason: impl Into<String>) -> Self {
        Error::Element {
            element: element.into(),
            reason: reason.into(),
        }
    }

    /// The message without its variant prefix — used when a lower-level
    /// error is re-wrapped into a span-carrying [`Error::ParseAt`], so the
    /// final rendering does not stutter ("parse error ...: parse error:").
    pub fn bare_message(&self) -> String {
        match self {
            Error::Parse(m)
            | Error::Negotiation(m)
            | Error::Graph(m)
            | Error::Runtime(m)
            | Error::Manifest(m) => m.clone(),
            Error::ParseAt { message, .. } => message.clone(),
            other => other.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_keep_their_prefixes() {
        assert_eq!(
            Error::Parse("x".into()).to_string(),
            "parse error: x"
        );
        assert_eq!(
            Error::Property {
                key: "k".into(),
                value: "v".into(),
                reason: "r".into(),
            }
            .to_string(),
            "bad property k=v: r"
        );
        assert_eq!(
            Error::element("queue", "boom").to_string(),
            "element queue: boom"
        );
        assert_eq!(
            Error::AdmissionDenied {
                tenant: "acme".into(),
                resource: "live pipelines",
                limit: 2,
            }
            .to_string(),
            "admission denied for tenant \"acme\": live pipelines quota \
             exhausted (limit 2)"
        );
    }

    #[test]
    fn fault_variants_render_origin_and_cause() {
        assert_eq!(
            Error::Panicked {
                element: "tensor_filter0".into(),
                message: "index out of bounds".into(),
            }
            .to_string(),
            "element tensor_filter0 panicked: index out of bounds"
        );
        let fault = Fault {
            element: "videoscale0".into(),
            message: "boom".into(),
            panicked: false,
        };
        assert_eq!(
            Error::Fault(fault.clone()).to_string(),
            "stream truncated by a fault: element videoscale0 failed: boom"
        );
        let panicked = Fault {
            panicked: true,
            ..fault
        };
        assert_eq!(
            Error::Fault(panicked).to_string(),
            "stream truncated by a fault: element videoscale0 panicked: boom"
        );
        assert_eq!(
            Error::Stalled {
                pipeline: "cam".into(),
                stalled_for: Duration::from_millis(1500),
            }
            .to_string(),
            "pipeline \"cam\" stalled: no progress while runnable for 1.500s"
        );
        assert_eq!(
            Error::Quarantined {
                pipeline: "cam".into(),
                restarts: 3,
                reason: "element f panicked: boom".into(),
            }
            .to_string(),
            "pipeline \"cam\" quarantined after 3 restarts: element f panicked: boom"
        );
    }

    #[test]
    fn fault_from_error_preserves_origin_across_hops() {
        // a panic becomes a panicked fault at its own element
        let panic_err = Error::Panicked {
            element: "filter0".into(),
            message: "overflow".into(),
        };
        let f = Fault::from_error("filter0", &panic_err);
        assert!(f.panicked);
        assert_eq!(f.element, "filter0");
        assert_eq!(f.message, "overflow");
        // a fault arriving at a downstream element keeps the origin
        let downstream = Fault::from_error("sink0", &Error::Fault(f.clone()));
        assert_eq!(downstream, f, "propagation must not re-attribute");
        // a typed element error becomes a non-panic fault
        let e = Fault::from_error("decoder0", &Error::element("decoder0", "bad header"));
        assert!(!e.panicked);
        assert_eq!(e.element, "decoder0");
        assert!(e.message.contains("bad header"));
    }

    #[test]
    fn parse_at_renders_span_and_element() {
        let e = Error::ParseAt {
            message: "bad property num-buffers=nope: expected integer".into(),
            span: (13, 29),
            element: Some("videotestsrc0".into()),
        };
        assert_eq!(
            e.to_string(),
            "parse error at bytes 13..29 (element videotestsrc0): \
             bad property num-buffers=nope: expected integer"
        );
        let anon = Error::ParseAt {
            message: "dangling '!'".into(),
            span: (0, 1),
            element: None,
        };
        assert_eq!(anon.to_string(), "parse error at bytes 0..1: dangling '!'");
        assert_eq!(anon.bare_message(), "dangling '!'");
    }

    #[test]
    fn net_variants_render_topic_and_cause() {
        assert_eq!(
            Error::Connect {
                topic: "ns/frames".into(),
                addr: "127.0.0.1:9000".into(),
                reason: "connection refused".into(),
            }
            .to_string(),
            "connect failed for topic \"ns/frames\" at 127.0.0.1:9000: \
             connection refused"
        );
        assert_eq!(
            Error::Frame("checksum mismatch".into()).to_string(),
            "bad wire frame: checksum mismatch"
        );
        assert_eq!(
            Error::Credit {
                topic: "ns/frames".into(),
                reason: "grant of 5 exceeds window 4".into(),
            }
            .to_string(),
            "credit protocol violation on topic \"ns/frames\": \
             grant of 5 exceeds window 4"
        );
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
