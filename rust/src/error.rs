//! Crate-wide error type.

/// Errors produced by the streaming framework and its elements.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Pipeline description could not be parsed.
    #[error("parse error: {0}")]
    Parse(String),

    /// Caps negotiation between two linked pads failed.
    #[error("negotiation failed: {0}")]
    Negotiation(String),

    /// An element property was unknown or had an invalid value.
    #[error("bad property {key}={value}: {reason}")]
    Property {
        key: String,
        value: String,
        reason: String,
    },

    /// Graph-level error (duplicate names, bad links, cycles, ...).
    #[error("graph error: {0}")]
    Graph(String),

    /// An element failed at runtime while processing a buffer.
    #[error("element {element}: {reason}")]
    Element { element: String, reason: String },

    /// NNFW / model runtime failure (PJRT compile or execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact manifest missing/invalid.
    #[error("manifest error: {0}")]
    Manifest(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for element-scoped runtime failures.
    pub fn element(element: impl Into<String>, reason: impl Into<String>) -> Self {
        Error::Element {
            element: element.into(),
            reason: reason.into(),
        }
    }
}
