//! Crate-wide error type.
//!
//! `Display`/`Error` are implemented by hand: the crate is dependency-free
//! apart from `once_cell`, so there is no `thiserror` to derive them.

/// Errors produced by the streaming framework and its elements.
#[derive(Debug)]
pub enum Error {
    /// Pipeline description could not be parsed.
    Parse(String),

    /// Pipeline description could not be parsed — with the byte span of
    /// the offending token in the original description and, when the
    /// parser knows it, the element being configured.
    ParseAt {
        message: String,
        /// Byte range `[start, end)` into the launch description.
        span: (usize, usize),
        /// Name of the element the error is attributed to.
        element: Option<String>,
    },

    /// Caps negotiation between two linked pads failed.
    Negotiation(String),

    /// An element property was unknown or had an invalid value.
    Property {
        key: String,
        value: String,
        reason: String,
    },

    /// Graph-level error (duplicate names, bad links, cycles, ...).
    Graph(String),

    /// An element failed at runtime while processing a buffer.
    Element { element: String, reason: String },

    /// A control send found the element's mailbox full (the element is
    /// starved of input while the application keeps sending). Sends
    /// never block the application thread; retry after the pipeline
    /// drains, or throttle control traffic.
    ControlBackpressure {
        element: String,
        /// The mailbox capacity that was exhausted.
        capacity: usize,
    },

    /// A tenant request was rejected by the hub's admission control
    /// (quota exhausted). Admission failures are always typed and
    /// immediate — a denied tenant gets this error, never a hang.
    AdmissionDenied {
        /// Tenant whose quota rejected the request.
        tenant: String,
        /// Which quota dimension was exhausted ("live pipelines",
        /// "queued invokes", "topic buffers").
        resource: &'static str,
        /// The configured limit that was reached.
        limit: usize,
    },

    /// NNFW / model runtime failure (artifact load or execute).
    Runtime(String),

    /// Artifact manifest missing/invalid.
    Manifest(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::ParseAt {
                message,
                span,
                element,
            } => {
                write!(f, "parse error at bytes {}..{}", span.0, span.1)?;
                if let Some(el) = element {
                    write!(f, " (element {el})")?;
                }
                write!(f, ": {message}")
            }
            Error::Negotiation(msg) => write!(f, "negotiation failed: {msg}"),
            Error::Property { key, value, reason } => {
                write!(f, "bad property {key}={value}: {reason}")
            }
            Error::Graph(msg) => write!(f, "graph error: {msg}"),
            Error::Element { element, reason } => write!(f, "element {element}: {reason}"),
            Error::ControlBackpressure { element, capacity } => write!(
                f,
                "control backpressure: mailbox of element {element:?} is full \
                 ({capacity} pending messages); the element is not consuming input"
            ),
            Error::AdmissionDenied {
                tenant,
                resource,
                limit,
            } => write!(
                f,
                "admission denied for tenant {tenant:?}: {resource} quota \
                 exhausted (limit {limit})"
            ),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience constructor for element-scoped runtime failures.
    pub fn element(element: impl Into<String>, reason: impl Into<String>) -> Self {
        Error::Element {
            element: element.into(),
            reason: reason.into(),
        }
    }

    /// The message without its variant prefix — used when a lower-level
    /// error is re-wrapped into a span-carrying [`Error::ParseAt`], so the
    /// final rendering does not stutter ("parse error ...: parse error:").
    pub fn bare_message(&self) -> String {
        match self {
            Error::Parse(m)
            | Error::Negotiation(m)
            | Error::Graph(m)
            | Error::Runtime(m)
            | Error::Manifest(m) => m.clone(),
            Error::ParseAt { message, .. } => message.clone(),
            other => other.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_keep_their_prefixes() {
        assert_eq!(
            Error::Parse("x".into()).to_string(),
            "parse error: x"
        );
        assert_eq!(
            Error::Property {
                key: "k".into(),
                value: "v".into(),
                reason: "r".into(),
            }
            .to_string(),
            "bad property k=v: r"
        );
        assert_eq!(
            Error::element("queue", "boom").to_string(),
            "element queue: boom"
        );
        assert_eq!(
            Error::AdmissionDenied {
                tenant: "acme".into(),
                resource: "live pipelines",
                limit: 2,
            }
            .to_string(),
            "admission denied for tenant \"acme\": live pipelines quota \
             exhausted (limit 2)"
        );
    }

    #[test]
    fn parse_at_renders_span_and_element() {
        let e = Error::ParseAt {
            message: "bad property num-buffers=nope: expected integer".into(),
            span: (13, 29),
            element: Some("videotestsrc0".into()),
        };
        assert_eq!(
            e.to_string(),
            "parse error at bytes 13..29 (element videotestsrc0): \
             bad property num-buffers=nope: expected integer"
        );
        let anon = Error::ParseAt {
            message: "dangling '!'".into(),
            span: (0, 1),
            element: None,
        };
        assert_eq!(anon.to_string(), "parse error at bytes 0..1: dangling '!'");
        assert_eq!(anon.bare_message(), "dangling '!'");
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
