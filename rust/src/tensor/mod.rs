//! Tensor stream data model: dtypes, dimensions, caps, buffers.
//!
//! This is the `other/tensor` / `other/tensors` layer of the paper (§III):
//! tensors are first-class stream citizens with an element type, dimensions
//! and a frame rate, and an `other/tensors` frame bundles up to
//! [`MAX_TENSORS`] tensors as *separate memory chunks* so that mux/demux
//! never copy payloads.

mod buffer;
mod caps;
mod dims;
mod dtype;
pub mod pool;

pub use buffer::{Buffer, Chunk, MAX_TENSORS};
pub use caps::{AudioInfo, Caps, VideoFormat, VideoInfo};
pub use dims::{Dims, MAX_RANK};
pub use dtype::DType;
pub use pool::{ChunkPool, PoolStats};

/// Element type + dimensions of one tensor (no frame rate; rate lives in
/// [`Caps`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorInfo {
    pub dtype: DType,
    pub dims: Dims,
}

impl TensorInfo {
    pub fn new(dtype: DType, dims: impl Into<Dims>) -> Self {
        Self {
            dtype,
            dims: dims.into(),
        }
    }

    /// Payload size of one frame of this tensor.
    pub fn size_bytes(&self) -> usize {
        self.dtype.size_bytes() * self.dims.num_elements()
    }

    /// Rank-agnostic compatibility (see [`Dims::equivalent`]).
    pub fn equivalent(&self, other: &TensorInfo) -> bool {
        self.dtype == other.dtype && self.dims.equivalent(&other.dims)
    }
}

impl std::fmt::Display for TensorInfo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.dtype, self.dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_info_size() {
        let ti = TensorInfo::new(DType::F32, [3, 64, 64]);
        assert_eq!(ti.size_bytes(), 4 * 3 * 64 * 64);
        assert_eq!(ti.to_string(), "float32:3:64:64");
    }

    #[test]
    fn tensor_info_rank_agnostic_equivalence() {
        let a = TensorInfo::new(DType::U8, [640, 480]);
        let b = TensorInfo::new(DType::U8, [640, 480, 1, 1]);
        assert!(a.equivalent(&b));
        let c = TensorInfo::new(DType::U8, [640, 480, 3]);
        assert!(!a.equivalent(&c));
        let d = TensorInfo::new(DType::I8, [640, 480]);
        assert!(!a.equivalent(&d));
    }
}
