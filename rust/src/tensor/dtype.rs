//! Tensor element types (mirrors NNStreamer's `other/tensor` type set).

use crate::error::{Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    U8,
    I8,
    U16,
    I16,
    U32,
    I32,
    U64,
    I64,
    F32,
    F64,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::U8 | DType::I8 => 1,
            DType::U16 | DType::I16 => 2,
            DType::U32 | DType::I32 | DType::F32 => 4,
            DType::U64 | DType::I64 | DType::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::U8 => "uint8",
            DType::I8 => "int8",
            DType::U16 => "uint16",
            DType::I16 => "int16",
            DType::U32 => "uint32",
            DType::I32 => "int32",
            DType::U64 => "uint64",
            DType::I64 => "int64",
            DType::F32 => "float32",
            DType::F64 => "float64",
        }
    }

    /// Parse both NNStreamer spellings (`uint8`) and numpy spellings the
    /// AOT manifest uses (`float32`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "uint8" | "u8" => DType::U8,
            "int8" | "i8" => DType::I8,
            "uint16" | "u16" => DType::U16,
            "int16" | "i16" => DType::I16,
            "uint32" | "u32" => DType::U32,
            "int32" | "i32" => DType::I32,
            "uint64" | "u64" => DType::U64,
            "int64" | "i64" => DType::I64,
            "float32" | "f32" => DType::F32,
            "float64" | "f64" => DType::F64,
            other => {
                return Err(Error::Parse(format!("unknown tensor dtype {other:?}")))
            }
        })
    }

    /// Is this a floating-point type?
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F64)
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}
