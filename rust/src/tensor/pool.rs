//! Size-classed chunk recycling pool — the hot-path memory subsystem.
//!
//! Steady-state streaming allocates the same handful of buffer sizes once
//! per frame per element. Instead of hitting the system allocator every
//! time, [`ChunkPool`] keeps dropped chunk storage in power-of-two size
//! classes and hands it back out on the next [`take`](ChunkPool::take):
//!
//! ```text
//! take(len) ──▶ Chunk (via Chunk::from_pooled) ──▶ shared via Arc ──▶
//!   last ref drops ──▶ storage recycled into its size class ──▶ take(len)
//! ```
//!
//! The recycle hook lives in the chunk storage's `Drop` impl
//! (`tensor/buffer.rs`), so *every* chunk in the system returns its bytes
//! here automatically; only `take` decides whether a request is served
//! from recycled storage. Allocation vs. reuse is accounted through
//! [`crate::metrics::traffic`], which is how `benches/e6_memory.rs`
//! measures bytes-allocated-per-frame with pooling on vs. off.
//!
//! The pool is deliberately simple: per-class `Mutex<Vec<Vec<u8>>>` free
//! lists (uncontended in steady state — each element thread takes and a
//! downstream thread recycles, touching one class each), a per-class
//! retention budget so an occasional large frame cannot pin memory
//! forever, and a global enable switch for A/B measurement.

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::Mutex;

use once_cell::sync::Lazy;

use crate::metrics::traffic;

/// Smallest size class: 64 bytes (2^6). Requests below it round up.
const MIN_CLASS_SHIFT: usize = 6;
/// Power-of-two classes from 64 B up to 2 GiB.
const NUM_CLASSES: usize = 26;
/// Per-class retention budget in bytes (caps pool-held memory).
const CLASS_BUDGET_BYTES: usize = 8 << 20;
/// Hard cap on buffers retained per class regardless of size.
const CLASS_MAX_ENTRIES: usize = 64;

#[inline]
fn class_size(i: usize) -> usize {
    1usize << (MIN_CLASS_SHIFT + i)
}

/// Smallest class whose buffers can serve a request of `len` bytes.
#[inline]
fn class_for_request(len: usize) -> Option<usize> {
    if len == 0 {
        return None;
    }
    let needed = len.next_power_of_two().max(1 << MIN_CLASS_SHIFT);
    let i = needed.trailing_zeros() as usize - MIN_CLASS_SHIFT;
    if i < NUM_CLASSES {
        Some(i)
    } else {
        None
    }
}

/// Largest class a buffer of capacity `cap` can serve (floor), i.e. every
/// buffer stored in class `i` has capacity >= `class_size(i)`.
#[inline]
fn class_for_storage(cap: usize) -> Option<usize> {
    if cap < (1 << MIN_CLASS_SHIFT) {
        return None;
    }
    let i = (usize::BITS - 1 - cap.leading_zeros()) as usize - MIN_CLASS_SHIFT;
    Some(i.min(NUM_CLASSES - 1))
}

/// How many buffers class `i` may retain. Classes larger than the whole
/// budget keep at most one buffer — a recurring jumbo frame still reuses
/// it, but a transient burst cannot pin multiples for the process
/// lifetime.
#[inline]
fn class_capacity(i: usize) -> usize {
    let size = class_size(i);
    if size > CLASS_BUDGET_BYTES {
        1
    } else {
        (CLASS_BUDGET_BYTES / size).clamp(2, CLASS_MAX_ENTRIES)
    }
}

/// Monotonic pool counters (all cumulative since process start).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served by a fresh heap allocation.
    pub fresh_allocs: u64,
    /// Bytes requested through fresh allocations.
    pub fresh_bytes: u64,
    /// `take` calls served from recycled storage.
    pub reuses: u64,
    /// Bytes requested that were served from recycled storage.
    pub reuse_bytes: u64,
    /// Buffers accepted back into a size class.
    pub recycles: u64,
    /// Bytes of capacity accepted back into size classes.
    pub recycle_bytes: u64,
    /// Buffers dropped instead of retained (budget full / pool disabled /
    /// too small to classify).
    pub discards: u64,
}

/// A size-classed recycling allocator for chunk payload storage.
///
/// Two families of free lists: byte buffers (`Vec<u8>`, the chunk
/// storage of every media/tensor kernel) and f32 buffers (`Vec<f32>`,
/// the model-execution layer's output scratch — kept separate because a
/// `Vec`'s allocation cannot change element type soundly).
pub struct ChunkPool {
    classes: Vec<Mutex<Vec<Vec<u8>>>>,
    f32_classes: Vec<Mutex<Vec<Vec<f32>>>>,
    enabled: AtomicBool,
    fresh_allocs: AtomicU64,
    fresh_bytes: AtomicU64,
    reuses: AtomicU64,
    reuse_bytes: AtomicU64,
    recycles: AtomicU64,
    recycle_bytes: AtomicU64,
    discards: AtomicU64,
}

static GLOBAL: Lazy<ChunkPool> = Lazy::new(ChunkPool::new);

impl ChunkPool {
    /// A fresh, enabled pool (tests use private instances; production code
    /// goes through [`ChunkPool::global`]).
    pub fn new() -> Self {
        Self {
            classes: (0..NUM_CLASSES).map(|_| Mutex::new(Vec::new())).collect(),
            f32_classes: (0..NUM_CLASSES).map(|_| Mutex::new(Vec::new())).collect(),
            enabled: AtomicBool::new(true),
            fresh_allocs: AtomicU64::new(0),
            fresh_bytes: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
            reuse_bytes: AtomicU64::new(0),
            recycles: AtomicU64::new(0),
            recycle_bytes: AtomicU64::new(0),
            discards: AtomicU64::new(0),
        }
    }

    /// The process-wide pool every [`crate::tensor::Chunk`] recycles into.
    pub fn global() -> &'static ChunkPool {
        &GLOBAL
    }

    /// Turn recycling on/off (off: `take` always allocates fresh and
    /// `recycle` drops). Used by `benches/e6_memory.rs` for A/B runs.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Hand out a zero-filled buffer of exactly `len` bytes, reusing a
    /// recycled allocation from the matching size class when available.
    /// Wrap the filled buffer with `Chunk::from_pooled` so it returns
    /// here when dropped.
    ///
    /// Reused buffers are deliberately re-zeroed: kernels with
    /// subsampled planes (e.g. NV12 chroma at odd frame widths) may
    /// leave a few bytes untouched, and stale contents there would make
    /// pooled output diverge from the freshly-allocated (OS-zeroed)
    /// path. One memset is far cheaper than the allocation it replaces.
    pub fn take(&self, len: usize) -> Vec<u8> {
        if len == 0 {
            return Vec::new();
        }
        if self.enabled() {
            if let Some(i) = class_for_request(len) {
                let recycled = self.classes[i]
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .pop();
                if let Some(mut v) = recycled {
                    self.reuses.fetch_add(1, Ordering::Relaxed);
                    self.reuse_bytes.fetch_add(len as u64, Ordering::Relaxed);
                    traffic::count_pool_reuse(len);
                    v.clear();
                    v.resize(len, 0);
                    return v;
                }
            }
        }
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        if self.enabled() {
            // allocate the full class size so the buffer can serve any
            // request of its class once recycled; account the rounded
            // capacity, not the request, so pooled-vs-unpooled alloc
            // comparisons stay honest
            let cap = class_for_request(len)
                .map(class_size)
                .unwrap_or(len)
                .max(len);
            self.fresh_bytes.fetch_add(cap as u64, Ordering::Relaxed);
            traffic::count_alloc(cap);
            let mut v = Vec::with_capacity(cap);
            v.resize(len, 0);
            v
        } else {
            self.fresh_bytes.fetch_add(len as u64, Ordering::Relaxed);
            traffic::count_alloc(len);
            vec![0u8; len]
        }
    }

    /// f32 variant of [`take`](ChunkPool::take): a zero-filled
    /// `Vec<f32>` of `len` elements. The model-execution layer draws its
    /// per-output scratch here; wrap results with `Chunk::from_pooled_f32`
    /// so the storage recycles when downstream drops the chunk.
    ///
    /// (Kept in lockstep with [`take`](ChunkPool::take) — the families
    /// differ only in element type, because a `Vec`'s allocation cannot
    /// change element type soundly.)
    pub fn take_f32(&self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        let bytes = len * 4;
        if self.enabled() {
            if let Some(i) = class_for_request(bytes) {
                let recycled = self.f32_classes[i]
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .pop();
                if let Some(mut v) = recycled {
                    self.reuses.fetch_add(1, Ordering::Relaxed);
                    self.reuse_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                    traffic::count_pool_reuse(bytes);
                    v.clear();
                    v.resize(len, 0.0);
                    return v;
                }
            }
        }
        self.fresh_allocs.fetch_add(1, Ordering::Relaxed);
        if self.enabled() {
            let cap_bytes = class_for_request(bytes)
                .map(class_size)
                .unwrap_or(bytes)
                .max(bytes);
            self.fresh_bytes.fetch_add(cap_bytes as u64, Ordering::Relaxed);
            traffic::count_alloc(cap_bytes);
            let mut v = Vec::with_capacity(cap_bytes / 4);
            v.resize(len, 0.0);
            v
        } else {
            self.fresh_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            traffic::count_alloc(bytes);
            vec![0.0; len]
        }
    }

    /// Return uniquely-owned storage to its size class. Called from the
    /// chunk storage `Drop` hook; also usable directly for scratch buffers
    /// obtained via [`take`](ChunkPool::take).
    pub fn recycle(&self, v: Vec<u8>) {
        let cap = v.capacity();
        if !self.enabled() {
            self.discards.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let Some(i) = class_for_storage(cap) else {
            self.discards.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let mut class = self.classes[i].lock().unwrap_or_else(|e| e.into_inner());
        if class.len() >= class_capacity(i) {
            self.discards.fetch_add(1, Ordering::Relaxed);
            return;
        }
        class.push(v);
        self.recycles.fetch_add(1, Ordering::Relaxed);
        self.recycle_bytes.fetch_add(cap as u64, Ordering::Relaxed);
        traffic::count_pool_recycle(cap);
    }

    /// f32 variant of [`recycle`](ChunkPool::recycle); called by the
    /// chunk storage drop hook for `Vec<f32>`-backed chunks.
    pub fn recycle_f32(&self, v: Vec<f32>) {
        let cap_bytes = v.capacity() * 4;
        if !self.enabled() {
            self.discards.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let Some(i) = class_for_storage(cap_bytes) else {
            self.discards.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let mut class = self.f32_classes[i].lock().unwrap_or_else(|e| e.into_inner());
        if class.len() >= class_capacity(i) {
            self.discards.fetch_add(1, Ordering::Relaxed);
            return;
        }
        class.push(v);
        self.recycles.fetch_add(1, Ordering::Relaxed);
        self.recycle_bytes.fetch_add(cap_bytes as u64, Ordering::Relaxed);
        traffic::count_pool_recycle(cap_bytes);
    }

    /// Drop all retained storage (benches call this between A/B cases so
    /// RSS comparisons start from the same baseline).
    pub fn clear(&self) {
        for class in &self.classes {
            class.lock().unwrap_or_else(|e| e.into_inner()).clear();
        }
        for class in &self.f32_classes {
            class.lock().unwrap_or_else(|e| e.into_inner()).clear();
        }
    }

    /// Bytes of capacity currently retained across all classes.
    pub fn retained_bytes(&self) -> usize {
        let bytes: usize = self
            .classes
            .iter()
            .map(|c| {
                c.lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .iter()
                    .map(Vec::capacity)
                    .sum::<usize>()
            })
            .sum();
        let f32s: usize = self
            .f32_classes
            .iter()
            .map(|c| {
                c.lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .iter()
                    .map(|v| v.capacity() * 4)
                    .sum::<usize>()
            })
            .sum();
        bytes + f32s
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            fresh_allocs: self.fresh_allocs.load(Ordering::Relaxed),
            fresh_bytes: self.fresh_bytes.load(Ordering::Relaxed),
            reuses: self.reuses.load(Ordering::Relaxed),
            reuse_bytes: self.reuse_bytes.load(Ordering::Relaxed),
            recycles: self.recycles.load(Ordering::Relaxed),
            recycle_bytes: self.recycle_bytes.load(Ordering::Relaxed),
            discards: self.discards.load(Ordering::Relaxed),
        }
    }
}

impl Default for ChunkPool {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_math() {
        assert_eq!(class_for_request(0), None);
        assert_eq!(class_for_request(1), Some(0));
        assert_eq!(class_for_request(64), Some(0));
        assert_eq!(class_for_request(65), Some(1));
        assert_eq!(class_for_request(49152), Some(10)); // -> 64 KiB
        assert_eq!(class_size(10), 65536);
        assert_eq!(class_for_storage(63), None);
        assert_eq!(class_for_storage(64), Some(0));
        assert_eq!(class_for_storage(100), Some(0));
        assert_eq!(class_for_storage(65536), Some(10));
        // stored class always serves its own requests
        for len in [1usize, 64, 100, 4096, 49152] {
            let i = class_for_request(len).unwrap();
            assert!(class_size(i) >= len);
            assert_eq!(class_for_storage(class_size(i)), Some(i));
        }
    }

    #[test]
    fn reuse_returns_the_same_allocation() {
        let pool = ChunkPool::new();
        let v = pool.take(1000);
        assert_eq!(v.len(), 1000);
        assert!(v.iter().all(|&b| b == 0));
        let p = v.as_ptr() as usize;
        pool.recycle(v);
        // 900 rounds up to the same 1024-byte class
        let v2 = pool.take(900);
        assert_eq!(v2.as_ptr() as usize, p, "pool must reuse the allocation");
        assert_eq!(v2.len(), 900);
        let s = pool.stats();
        assert_eq!(s.fresh_allocs, 1);
        assert_eq!(s.reuses, 1);
        assert_eq!(s.recycles, 1);
    }

    #[test]
    fn reused_buffers_come_back_zeroed() {
        let pool = ChunkPool::new();
        let mut v = pool.take(256);
        v.iter_mut().for_each(|b| *b = 0xAB);
        pool.recycle(v);
        let v2 = pool.take(256);
        assert!(v2.iter().all(|&b| b == 0), "stale bytes must be cleared");
    }

    #[test]
    fn f32_reuse_returns_the_same_allocation() {
        let pool = ChunkPool::new();
        let mut v = pool.take_f32(100);
        assert_eq!(v.len(), 100);
        v.iter_mut().for_each(|x| *x = 7.0);
        let p = v.as_ptr() as usize;
        pool.recycle_f32(v);
        // 90 * 4 = 360 bytes rounds up to the same 512-byte class
        let v2 = pool.take_f32(90);
        assert_eq!(v2.as_ptr() as usize, p, "f32 pool must reuse the allocation");
        assert_eq!(v2.len(), 90);
        assert!(v2.iter().all(|&x| x == 0.0), "reused f32s come back zeroed");
    }

    #[test]
    fn disabled_pool_always_allocates_fresh() {
        let pool = ChunkPool::new();
        pool.set_enabled(false);
        let v = pool.take(512);
        pool.recycle(v);
        let s = pool.stats();
        assert_eq!(s.recycles, 0);
        assert_eq!(s.discards, 1);
        let _v2 = pool.take(512);
        assert_eq!(pool.stats().fresh_allocs, 2);
        assert_eq!(pool.stats().reuses, 0);
    }

    #[test]
    fn budget_bounds_retention() {
        let pool = ChunkPool::new();
        let i = class_for_request(1 << 20).unwrap(); // 1 MiB class
        let cap = class_capacity(i);
        assert!(cap >= 2);
        for _ in 0..cap + 3 {
            pool.recycle(Vec::with_capacity(1 << 20));
        }
        let s = pool.stats();
        assert_eq!(s.recycles as usize, cap);
        assert_eq!(s.discards as usize, 3);
        assert!(pool.retained_bytes() >= cap * (1 << 20));
        pool.clear();
        assert_eq!(pool.retained_bytes(), 0);
    }

    #[test]
    fn tiny_and_zero_requests() {
        let pool = ChunkPool::new();
        assert!(pool.take(0).is_empty());
        let v = pool.take(3);
        assert_eq!(v.len(), 3);
        // capacity was rounded up to the 64-byte minimum class
        assert!(v.capacity() >= 64);
        // sub-minimum storage is discarded, not classified
        pool.recycle(Vec::with_capacity(8));
        assert_eq!(pool.stats().discards, 1);
    }
}
