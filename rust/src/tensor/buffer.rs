//! Stream buffers: timestamped frames of up to [`MAX_TENSORS`] memory chunks.
//!
//! Each tensor of an `other/tensors` frame lives in its own refcounted
//! chunk, so `tensor_mux` / `tensor_demux` move `Arc`s around instead of
//! copying payloads (§III: "We store each tensor in an individual memory
//! chunk so that mux and de-mux do not incur memory copies").
//!
//! Chunk storage is recycled: when the last reference to a chunk drops,
//! its byte buffer returns to the global [`ChunkPool`] and the next
//! per-frame kernel gets it back without touching the system allocator.
//! [`Chunk::make_mut`] adds copy-on-write in-place mutation: a uniquely
//! owned chunk is mutated in place, a shared one is first copied into a
//! pooled buffer. All allocations, copies and reuses are accounted to the
//! global traffic counters in [`crate::metrics::traffic`] — the substrate
//! for the paper's perf-based "memory access" row in Table III and for
//! `benches/e6_memory.rs`.

// One of the two audited exceptions to the crate-root
// `#![deny(unsafe_code)]`: byte-level views over f32 storage (raw-slice
// casts and `align_to`). Every site carries a `// SAFETY:` comment.
#![allow(unsafe_code)]

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::metrics::traffic;
use crate::tensor::pool::ChunkPool;

/// Default memory-chunk limit per frame (GStreamer's default, §III).
pub const MAX_TENSORS: usize = 16;

/// Chunk payload storage. Most chunks hold plain bytes; `F32` lets
/// [`Chunk::from_f32_vec`] adopt a `Vec<f32>` allocation without copying
/// it into a byte vector first.
#[derive(Debug)]
enum Storage {
    Bytes(Vec<u8>),
    F32(Vec<f32>),
}

impl Storage {
    fn as_bytes(&self) -> &[u8] {
        match self {
            Storage::Bytes(v) => v,
            // SAFETY: any 4-byte f32 is 4 valid u8s; shrinking alignment
            // from 4 to 1 is always sound, and the borrow pins the Vec.
            Storage::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    fn as_bytes_mut(&mut self) -> &mut [u8] {
        match self {
            Storage::Bytes(v) => v,
            // SAFETY: as above; u8 has no invalid bit patterns, so writes
            // through the view always leave the f32s initialized.
            Storage::F32(v) => unsafe {
                std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, v.len() * 4)
            },
        }
    }

    fn len(&self) -> usize {
        match self {
            Storage::Bytes(v) => v.len(),
            Storage::F32(v) => v.len() * 4,
        }
    }
}

/// Uniquely-owned chunk storage; hands byte buffers back to the global
/// [`ChunkPool`] when the last [`Chunk`] reference drops.
#[derive(Debug)]
struct PooledStorage(Storage);

impl Drop for PooledStorage {
    fn drop(&mut self) {
        match &mut self.0 {
            Storage::Bytes(v) => ChunkPool::global().recycle(std::mem::take(v)),
            Storage::F32(v) => ChunkPool::global().recycle_f32(std::mem::take(v)),
        }
    }
}

/// One refcounted payload chunk (immutable unless uniquely owned — see
/// [`Chunk::make_mut`]).
#[derive(Debug, Clone)]
pub struct Chunk(Arc<PooledStorage>);

/// Byte-wise equality: pooling provenance and storage representation
/// (bytes vs f32) are not identity — two chunks are equal iff their
/// payload bytes are. Comparison reads are not traffic-accounted.
impl PartialEq for Chunk {
    fn eq(&self, other: &Chunk) -> bool {
        self.as_bytes_unaccounted() == other.as_bytes_unaccounted()
    }
}

impl Eq for Chunk {}

impl Chunk {
    /// Allocate a chunk from a caller-allocated byte vector (counted as
    /// written + freshly allocated traffic). Prefer [`ChunkPool::take`] +
    /// [`Chunk::from_pooled`] on per-frame paths.
    pub fn from_vec(data: Vec<u8>) -> Self {
        traffic::count_write(data.len());
        traffic::count_alloc(data.len());
        Chunk(Arc::new(PooledStorage(Storage::Bytes(data))))
    }

    /// Wrap a buffer obtained from [`ChunkPool::take`] (the pool already
    /// accounted the allocation or reuse; only the write is counted here).
    ///
    /// Note: chunk storage always recycles into the *global* pool on
    /// drop, whatever pool instance it was taken from — private
    /// [`ChunkPool`] instances are for tests and explicit scratch, not
    /// for backing chunks.
    pub fn from_pooled(data: Vec<u8>) -> Self {
        traffic::count_write(data.len());
        Chunk(Arc::new(PooledStorage(Storage::Bytes(data))))
    }

    /// Wrap an f32 buffer obtained from [`ChunkPool::take_f32`] (the
    /// model-output path; allocation already accounted by the pool).
    pub fn from_pooled_f32(data: Vec<f32>) -> Self {
        traffic::count_write(data.len() * 4);
        Chunk(Arc::new(PooledStorage(Storage::F32(data))))
    }

    /// Allocate a chunk from an f32 slice via one bulk byte copy into a
    /// pooled buffer (no per-element `to_le_bytes` loop).
    pub fn from_f32(data: &[f32]) -> Self {
        let n = data.len() * 4;
        let mut bytes = ChunkPool::global().take(n);
        if cfg!(target_endian = "little") {
            // SAFETY: an f32 slice is always a valid byte slice of 4x the
            // length (alignment only shrinks, no padding, no invalid u8s).
            let src = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, n)
            };
            bytes.copy_from_slice(src);
        } else {
            for (dst, v) in bytes.chunks_exact_mut(4).zip(data) {
                dst.copy_from_slice(&v.to_le_bytes());
            }
        }
        Chunk::from_pooled(bytes)
    }

    /// Adopt a caller-allocated `Vec<f32>` as chunk storage without
    /// copying — the symmetric zero-copy counterpart of
    /// [`Chunk::from_f32`]. The vector's allocation is counted as fresh;
    /// storage still recycles into the pool's f32 classes on drop. Hot
    /// paths should prefer [`ChunkPool::take_f32`] +
    /// [`Chunk::from_pooled_f32`].
    pub fn from_f32_vec(data: Vec<f32>) -> Self {
        let n = data.len() * 4;
        traffic::count_write(n);
        traffic::count_alloc(n);
        Chunk(Arc::new(PooledStorage(Storage::F32(data))))
    }

    /// Build an f32 chunk by streaming exactly `len` values into a pooled
    /// buffer (one allocation-or-reuse, no intermediate `Vec<f32>`).
    pub fn from_f32_iter(len: usize, values: impl Iterator<Item = f32>) -> Self {
        let mut bytes = ChunkPool::global().take(len * 4);
        let mut written = 0usize;
        for (dst, v) in bytes.chunks_exact_mut(4).zip(values) {
            dst.copy_from_slice(&v.to_le_bytes());
            written += 1;
        }
        debug_assert_eq!(
            written, len,
            "from_f32_iter: iterator yielded {written} of {len} values"
        );
        Chunk::from_pooled(bytes)
    }

    pub fn len(&self) -> usize {
        self.0 .0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_bytes(&self) -> &[u8] {
        traffic::count_read(self.len());
        self.0 .0.as_bytes()
    }

    /// Bytes without traffic accounting (for metrics/tests themselves).
    pub fn as_bytes_unaccounted(&self) -> &[u8] {
        self.0 .0.as_bytes()
    }

    /// View as f32 slice. Vec allocations are 8/16-byte aligned in
    /// practice; we verify instead of assuming.
    pub fn as_f32(&self) -> Result<&[f32]> {
        traffic::count_read(self.len());
        // SAFETY: `align_to` itself is safe to call for any target type
        // without invalid bit patterns (f32 accepts all); the unaligned
        // pre/post remainders are rejected below rather than assumed empty.
        let (pre, body, post) = unsafe { self.0 .0.as_bytes().align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(Error::Runtime("chunk not f32-aligned/sized".into()));
        }
        Ok(body)
    }

    /// Copy out as f32 vector.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f32()?.to_vec())
    }

    /// Copy-on-write mutable access: reuses the allocation in place when
    /// this is the only reference, otherwise replaces it with a pooled
    /// copy first (so a tee'd sibling never observes the mutation).
    ///
    /// Either way the caller is assumed to read and rewrite the payload
    /// once, so a read+write of `len` bytes is charged to the traffic
    /// counters — in-place mutation only avoids *allocator* traffic, not
    /// CPU memory access, keeping `Snapshot::total()` (the Table III
    /// "memory access" substitute) comparable with the pre-pool code.
    pub fn make_mut(&mut self) -> &mut [u8] {
        let len = self.len();
        traffic::count_read(len);
        traffic::count_write(len);
        if Arc::get_mut(&mut self.0).is_some() {
            traffic::count_inplace(len);
        } else {
            let mut fresh = ChunkPool::global().take(len);
            fresh.copy_from_slice(self.0 .0.as_bytes());
            traffic::count_cow(len);
            self.0 = Arc::new(PooledStorage(Storage::Bytes(fresh)));
        }
        Arc::get_mut(&mut self.0)
            .expect("chunk is uniquely owned after CoW")
            .0
            .as_bytes_mut()
    }

    /// [`make_mut`](Chunk::make_mut) viewed as f32 (same alignment
    /// verification as [`as_f32`](Chunk::as_f32)).
    pub fn make_mut_f32(&mut self) -> Result<&mut [f32]> {
        let bytes = self.make_mut();
        // SAFETY: as in `as_f32` — f32 has no invalid bit patterns and the
        // pre/post remainders are rejected, not assumed empty.
        let (pre, body, post) = unsafe { bytes.align_to_mut::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(Error::Runtime("chunk not f32-aligned/sized".into()));
        }
        Ok(body)
    }

    /// Number of strong references (used by zero-copy tests).
    pub fn refcount(&self) -> usize {
        Arc::strong_count(&self.0)
    }

    /// Pointer identity (used by zero-copy tests).
    pub fn ptr(&self) -> *const u8 {
        self.0 .0.as_bytes().as_ptr()
    }
}

/// A timestamped stream frame.
#[derive(Debug, Clone, Default)]
pub struct Buffer {
    /// Presentation timestamp in nanoseconds.
    pub pts_ns: u64,
    /// Frame duration in nanoseconds (0 = unknown).
    pub duration_ns: u64,
    /// Monotonic sequence number assigned by the producing source.
    pub seq: u64,
    /// Payload chunks (1 for `other/tensor`/media, N for `other/tensors`).
    pub chunks: Vec<Chunk>,
}

/// Metadata + payload-byte equality (the wire codec's roundtrip
/// contract: a decoded frame equals the encoded one bit for bit).
impl PartialEq for Buffer {
    fn eq(&self, other: &Buffer) -> bool {
        self.pts_ns == other.pts_ns
            && self.duration_ns == other.duration_ns
            && self.seq == other.seq
            && self.chunks == other.chunks
    }
}

impl Eq for Buffer {}

impl Buffer {
    pub fn new(pts_ns: u64, chunks: Vec<Chunk>) -> Self {
        assert!(
            chunks.len() <= MAX_TENSORS,
            "buffer exceeds MAX_TENSORS chunks"
        );
        Self {
            pts_ns,
            duration_ns: 0,
            seq: 0,
            chunks,
        }
    }

    pub fn single(pts_ns: u64, chunk: Chunk) -> Self {
        Self::new(pts_ns, vec![chunk])
    }

    pub fn from_f32(pts_ns: u64, data: &[f32]) -> Self {
        Self::single(pts_ns, Chunk::from_f32(data))
    }

    /// Total payload bytes across chunks.
    pub fn size(&self) -> usize {
        self.chunks.iter().map(Chunk::len).sum()
    }

    /// First chunk (the common single-tensor case).
    pub fn chunk(&self) -> &Chunk {
        &self.chunks[0]
    }

    /// Bundle several buffers into one `other/tensors` frame without
    /// copying payloads. Timestamp policy: latest of the inputs (§III:
    /// "All merging filters choose the latest timestamp").
    pub fn bundle(parts: Vec<Buffer>) -> Result<Buffer> {
        let mut chunks = Vec::new();
        let mut pts = 0u64;
        let mut seq = 0u64;
        for b in parts {
            pts = pts.max(b.pts_ns);
            seq = seq.max(b.seq);
            chunks.extend(b.chunks);
        }
        if chunks.len() > MAX_TENSORS {
            return Err(Error::Runtime(format!(
                "bundle of {} chunks exceeds MAX_TENSORS={MAX_TENSORS}",
                chunks.len()
            )));
        }
        let mut out = Buffer::new(pts, chunks);
        out.seq = seq;
        Ok(out)
    }

    /// Split an `other/tensors` frame into per-tensor buffers (zero-copy).
    pub fn unbundle(self) -> Vec<Buffer> {
        let (pts, seq, dur) = (self.pts_ns, self.seq, self.duration_ns);
        self.chunks
            .into_iter()
            .map(|c| {
                let mut b = Buffer::single(pts, c);
                b.seq = seq;
                b.duration_ns = dur;
                b
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25];
        let c = Chunk::from_f32(&data);
        assert_eq!(c.as_f32().unwrap(), &data[..]);
        assert_eq!(c.len(), 12);
    }

    #[test]
    fn from_f32_bulk_matches_per_element_le() {
        let data = vec![0.0f32, 1.5, -3.75, f32::MAX, f32::MIN_POSITIVE];
        let c = Chunk::from_f32(&data);
        let mut expect = vec![0u8; data.len() * 4];
        for (i, v) in data.iter().enumerate() {
            expect[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        assert_eq!(c.as_bytes_unaccounted(), &expect[..]);
    }

    #[test]
    fn from_f32_vec_adopts_the_allocation() {
        let v = vec![1.0f32, 2.0, 3.0];
        let p = v.as_ptr() as *const u8;
        let c = Chunk::from_f32_vec(v);
        assert_eq!(c.ptr(), p, "no copy: chunk views the original Vec<f32>");
        assert_eq!(c.as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(c.len(), 12);
    }

    #[test]
    fn from_f32_iter_matches_from_f32() {
        let data = vec![0.25f32, -1.0, 9.5];
        let a = Chunk::from_f32(&data);
        let b = Chunk::from_f32_iter(data.len(), data.iter().copied());
        assert_eq!(a.as_bytes_unaccounted(), b.as_bytes_unaccounted());
    }

    #[test]
    fn make_mut_is_in_place_iff_unshared() {
        let mut c = Chunk::from_vec(vec![1u8, 2, 3, 4]);
        let p0 = c.ptr();
        c.make_mut()[0] = 9;
        assert_eq!(c.ptr(), p0, "unique chunk mutates in place");
        assert_eq!(c.as_bytes_unaccounted()[0], 9);

        let sibling = c.clone();
        assert_eq!(c.refcount(), 2);
        c.make_mut()[1] = 7;
        assert_ne!(c.ptr(), sibling.ptr(), "shared chunk copies on write");
        assert_eq!(c.refcount(), 1);
        assert_eq!(sibling.as_bytes_unaccounted(), &[9, 2, 3, 4]);
        assert_eq!(c.as_bytes_unaccounted(), &[9, 7, 3, 4]);
    }

    #[test]
    fn make_mut_f32_roundtrip() {
        let mut c = Chunk::from_f32(&[1.0, 2.0]);
        {
            let vals = c.make_mut_f32().unwrap();
            vals[0] = 5.0;
        }
        assert_eq!(c.as_f32().unwrap(), &[5.0, 2.0]);
    }

    #[test]
    fn dropped_chunk_storage_is_recycled() {
        let pool = ChunkPool::global();
        let before = pool.stats();
        drop(Chunk::from_vec(vec![0u8; 777]));
        let after = pool.stats();
        // parallel tests may race the class to its retention cap, in which
        // case the storage is discarded — either way the hook must run
        assert!(
            after.recycles + after.discards > before.recycles + before.discards,
            "drop hook must offer storage back to the pool"
        );
    }

    #[test]
    fn bundle_is_zero_copy_and_picks_latest_pts() {
        let a = Buffer::from_f32(100, &[1.0]);
        let b = Buffer::from_f32(250, &[2.0]);
        let pa = a.chunk().ptr();
        let pb = b.chunk().ptr();
        let bundled = Buffer::bundle(vec![a, b]).unwrap();
        assert_eq!(bundled.pts_ns, 250);
        assert_eq!(bundled.chunks.len(), 2);
        // same allocations, no copy
        assert_eq!(bundled.chunks[0].ptr(), pa);
        assert_eq!(bundled.chunks[1].ptr(), pb);

        let parts = bundled.unbundle();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].chunk().ptr(), pa);
        assert_eq!(parts[1].chunk().ptr(), pb);
        // unbundled buffers inherit the bundle pts
        assert_eq!(parts[0].pts_ns, 250);
    }

    #[test]
    fn bundle_rejects_overflow() {
        let parts: Vec<Buffer> = (0..MAX_TENSORS + 1)
            .map(|i| Buffer::from_f32(i as u64, &[0.0]))
            .collect();
        assert!(Buffer::bundle(parts).is_err());
    }

    #[test]
    fn clone_shares_chunks() {
        let b = Buffer::from_f32(0, &[1.0, 2.0]);
        let b2 = b.clone();
        assert_eq!(b.chunk().ptr(), b2.chunk().ptr());
        assert_eq!(b.chunk().refcount(), 2);
    }
}
