//! Stream buffers: timestamped frames of up to [`MAX_TENSORS`] memory chunks.
//!
//! Each tensor of an `other/tensors` frame lives in its own refcounted
//! chunk, so `tensor_mux` / `tensor_demux` move `Arc`s around instead of
//! copying payloads (§III: "We store each tensor in an individual memory
//! chunk so that mux and de-mux do not incur memory copies").
//!
//! All chunk allocations and copies are accounted to the global traffic
//! counters in [`crate::metrics::traffic`] — this is the substrate for the
//! paper's perf-based "memory access" row in Table III.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::metrics::traffic;

/// Default memory-chunk limit per frame (GStreamer's default, §III).
pub const MAX_TENSORS: usize = 16;

/// One immutable, refcounted payload chunk.
#[derive(Debug, Clone)]
pub struct Chunk(Arc<Vec<u8>>);

impl Chunk {
    /// Allocate a chunk from a byte vector (counted as written traffic).
    pub fn from_vec(data: Vec<u8>) -> Self {
        traffic::count_write(data.len());
        Chunk(Arc::new(data))
    }

    /// Allocate a chunk from an f32 slice.
    pub fn from_f32(data: &[f32]) -> Self {
        let mut bytes = vec![0u8; data.len() * 4];
        for (i, v) in data.iter().enumerate() {
            bytes[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        Chunk::from_vec(bytes)
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_bytes(&self) -> &[u8] {
        traffic::count_read(self.0.len());
        &self.0
    }

    /// Bytes without traffic accounting (for metrics/tests themselves).
    pub fn as_bytes_unaccounted(&self) -> &[u8] {
        &self.0
    }

    /// View as f32 slice. Vec allocations are 8/16-byte aligned in
    /// practice; we verify instead of assuming.
    pub fn as_f32(&self) -> Result<&[f32]> {
        traffic::count_read(self.0.len());
        let (pre, body, post) = unsafe { self.0.align_to::<f32>() };
        if !pre.is_empty() || !post.is_empty() {
            return Err(Error::Runtime("chunk not f32-aligned/sized".into()));
        }
        Ok(body)
    }

    /// Copy out as f32 vector.
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f32()?.to_vec())
    }

    /// Number of strong references (used by zero-copy tests).
    pub fn refcount(&self) -> usize {
        Arc::strong_count(&self.0)
    }

    /// Pointer identity (used by zero-copy tests).
    pub fn ptr(&self) -> *const u8 {
        self.0.as_ptr()
    }
}

/// A timestamped stream frame.
#[derive(Debug, Clone, Default)]
pub struct Buffer {
    /// Presentation timestamp in nanoseconds.
    pub pts_ns: u64,
    /// Frame duration in nanoseconds (0 = unknown).
    pub duration_ns: u64,
    /// Monotonic sequence number assigned by the producing source.
    pub seq: u64,
    /// Payload chunks (1 for `other/tensor`/media, N for `other/tensors`).
    pub chunks: Vec<Chunk>,
}

impl Buffer {
    pub fn new(pts_ns: u64, chunks: Vec<Chunk>) -> Self {
        assert!(
            chunks.len() <= MAX_TENSORS,
            "buffer exceeds MAX_TENSORS chunks"
        );
        Self {
            pts_ns,
            duration_ns: 0,
            seq: 0,
            chunks,
        }
    }

    pub fn single(pts_ns: u64, chunk: Chunk) -> Self {
        Self::new(pts_ns, vec![chunk])
    }

    pub fn from_f32(pts_ns: u64, data: &[f32]) -> Self {
        Self::single(pts_ns, Chunk::from_f32(data))
    }

    /// Total payload bytes across chunks.
    pub fn size(&self) -> usize {
        self.chunks.iter().map(Chunk::len).sum()
    }

    /// First chunk (the common single-tensor case).
    pub fn chunk(&self) -> &Chunk {
        &self.chunks[0]
    }

    /// Bundle several buffers into one `other/tensors` frame without
    /// copying payloads. Timestamp policy: latest of the inputs (§III:
    /// "All merging filters choose the latest timestamp").
    pub fn bundle(parts: Vec<Buffer>) -> Result<Buffer> {
        let mut chunks = Vec::new();
        let mut pts = 0u64;
        let mut seq = 0u64;
        for b in parts {
            pts = pts.max(b.pts_ns);
            seq = seq.max(b.seq);
            chunks.extend(b.chunks);
        }
        if chunks.len() > MAX_TENSORS {
            return Err(Error::Runtime(format!(
                "bundle of {} chunks exceeds MAX_TENSORS={MAX_TENSORS}",
                chunks.len()
            )));
        }
        let mut out = Buffer::new(pts, chunks);
        out.seq = seq;
        Ok(out)
    }

    /// Split an `other/tensors` frame into per-tensor buffers (zero-copy).
    pub fn unbundle(self) -> Vec<Buffer> {
        let (pts, seq, dur) = (self.pts_ns, self.seq, self.duration_ns);
        self.chunks
            .into_iter()
            .map(|c| {
                let mut b = Buffer::single(pts, c);
                b.seq = seq;
                b.duration_ns = dur;
                b
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25];
        let c = Chunk::from_f32(&data);
        assert_eq!(c.as_f32().unwrap(), &data[..]);
        assert_eq!(c.len(), 12);
    }

    #[test]
    fn bundle_is_zero_copy_and_picks_latest_pts() {
        let a = Buffer::from_f32(100, &[1.0]);
        let b = Buffer::from_f32(250, &[2.0]);
        let pa = a.chunk().ptr();
        let pb = b.chunk().ptr();
        let bundled = Buffer::bundle(vec![a, b]).unwrap();
        assert_eq!(bundled.pts_ns, 250);
        assert_eq!(bundled.chunks.len(), 2);
        // same allocations, no copy
        assert_eq!(bundled.chunks[0].ptr(), pa);
        assert_eq!(bundled.chunks[1].ptr(), pb);

        let parts = bundled.unbundle();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].chunk().ptr(), pa);
        assert_eq!(parts[1].chunk().ptr(), pb);
        // unbundled buffers inherit the bundle pts
        assert_eq!(parts[0].pts_ns, 250);
    }

    #[test]
    fn bundle_rejects_overflow() {
        let parts: Vec<Buffer> = (0..MAX_TENSORS + 1)
            .map(|i| Buffer::from_f32(i as u64, &[0.0]))
            .collect();
        assert!(Buffer::bundle(parts).is_err());
    }

    #[test]
    fn clone_shares_chunks() {
        let b = Buffer::from_f32(0, &[1.0, 2.0]);
        let b2 = b.clone();
        assert_eq!(b.chunk().ptr(), b2.chunk().ptr());
        assert_eq!(b.chunk().refcount(), 2);
    }
}
