//! Stream capabilities ("caps") and negotiation.
//!
//! Caps describe what flows on a link: conventional media (`video/x-raw`,
//! `audio/x-raw`, `text/x-raw`), the paper's tensor types (`other/tensor`,
//! `other/tensors`), or framed binaries (`other/flatbuf`). Negotiation is
//! intersection-based like GStreamer's: a pad offers caps, the peer
//! restricts them; [`Caps::intersect`] computes the common subset with
//! rank-agnostic tensor dimension matching.

use super::{DType, TensorInfo};
use crate::error::{Error, Result};

/// Raw video pixel formats supported by the built-in media filters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VideoFormat {
    Rgb,
    Bgr,
    Gray8,
    /// 4:2:0 planar, the typical camera output; converters handle it.
    Nv12,
}

impl VideoFormat {
    pub fn name(self) -> &'static str {
        match self {
            VideoFormat::Rgb => "RGB",
            VideoFormat::Bgr => "BGR",
            VideoFormat::Gray8 => "GRAY8",
            VideoFormat::Nv12 => "NV12",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_uppercase().as_str() {
            "RGB" => VideoFormat::Rgb,
            "BGR" => VideoFormat::Bgr,
            "GRAY8" | "GRAY" => VideoFormat::Gray8,
            "NV12" => VideoFormat::Nv12,
            other => return Err(Error::Parse(format!("unknown video format {other:?}"))),
        })
    }

    /// Bytes per frame for WxH.
    pub fn frame_size(self, width: usize, height: usize) -> usize {
        match self {
            VideoFormat::Rgb | VideoFormat::Bgr => width * height * 3,
            VideoFormat::Gray8 => width * height,
            VideoFormat::Nv12 => width * height + width * height / 2,
        }
    }

    /// Channel count as seen by tensor_converter (NV12 converts to RGB first).
    pub fn channels(self) -> usize {
        match self {
            VideoFormat::Rgb | VideoFormat::Bgr => 3,
            VideoFormat::Gray8 => 1,
            VideoFormat::Nv12 => 3,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VideoInfo {
    pub format: VideoFormat,
    pub width: usize,
    pub height: usize,
    /// Frames per second, in 1/1000 units (30000 = 30 fps). 0 = variable.
    pub fps_millis: u64,
}

impl VideoInfo {
    pub fn new(format: VideoFormat, width: usize, height: usize, fps: f64) -> Self {
        Self {
            format,
            width,
            height,
            fps_millis: (fps * 1000.0).round() as u64,
        }
    }

    pub fn fps(&self) -> f64 {
        self.fps_millis as f64 / 1000.0
    }

    pub fn frame_size(&self) -> usize {
        self.format.frame_size(self.width, self.height)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AudioInfo {
    pub rate: usize,
    pub channels: usize,
    /// S16LE assumed; samples per buffer.
    pub samples_per_buffer: usize,
}

/// Stream capabilities.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Caps {
    /// Anything — the starting offer of pads with no constraints.
    Any,
    Video(VideoInfo),
    Audio(AudioInfo),
    Text,
    /// `other/tensor`: one tensor per frame. fps_millis as in [`VideoInfo`].
    Tensor { info: TensorInfo, fps_millis: u64 },
    /// `other/tensors`: up to [`super::MAX_TENSORS`] tensors per frame,
    /// synchronized to a single rate.
    Tensors {
        infos: Vec<TensorInfo>,
        fps_millis: u64,
    },
    /// Framed serialized tensors (flatbuf/protobuf analog).
    FlatBuf,
}

impl Caps {
    pub fn tensor(dtype: DType, dims: impl Into<super::Dims>, fps: f64) -> Self {
        Caps::Tensor {
            info: TensorInfo::new(dtype, dims),
            fps_millis: (fps * 1000.0).round() as u64,
        }
    }

    pub fn media_name(&self) -> &'static str {
        match self {
            Caps::Any => "ANY",
            Caps::Video(_) => "video/x-raw",
            Caps::Audio(_) => "audio/x-raw",
            Caps::Text => "text/x-raw",
            Caps::Tensor { .. } => "other/tensor",
            Caps::Tensors { .. } => "other/tensors",
            Caps::FlatBuf => "other/flatbuf",
        }
    }

    pub fn fps(&self) -> Option<f64> {
        match self {
            Caps::Video(v) => Some(v.fps()),
            Caps::Tensor { fps_millis, .. } | Caps::Tensors { fps_millis, .. } => {
                Some(*fps_millis as f64 / 1000.0)
            }
            _ => None,
        }
    }

    /// Per-frame payload size if statically known.
    pub fn frame_size(&self) -> Option<usize> {
        match self {
            Caps::Video(v) => Some(v.frame_size()),
            Caps::Audio(a) => Some(a.samples_per_buffer * a.channels * 2),
            Caps::Tensor { info, .. } => Some(info.size_bytes()),
            Caps::Tensors { infos, .. } => Some(infos.iter().map(|i| i.size_bytes()).sum()),
            _ => None,
        }
    }

    /// Tensor infos carried by this caps (empty for media).
    pub fn tensor_infos(&self) -> Vec<TensorInfo> {
        match self {
            Caps::Tensor { info, .. } => vec![info.clone()],
            Caps::Tensors { infos, .. } => infos.clone(),
            _ => vec![],
        }
    }

    /// Intersection-based compatibility: can a producer offering `self`
    /// feed a consumer requiring `other`? Tensor dims compare
    /// rank-agnostically; fps 0 (variable) matches any rate.
    pub fn compatible(&self, other: &Caps) -> bool {
        match (self, other) {
            (Caps::Any, _) | (_, Caps::Any) => true,
            (Caps::Video(a), Caps::Video(b)) => {
                a.format == b.format
                    && a.width == b.width
                    && a.height == b.height
                    && (a.fps_millis == b.fps_millis || a.fps_millis == 0 || b.fps_millis == 0)
            }
            (Caps::Audio(a), Caps::Audio(b)) => a.rate == b.rate && a.channels == b.channels,
            (Caps::Text, Caps::Text) | (Caps::FlatBuf, Caps::FlatBuf) => true,
            (
                Caps::Tensor {
                    info: a,
                    fps_millis: fa,
                },
                Caps::Tensor {
                    info: b,
                    fps_millis: fb,
                },
            ) => a.equivalent(b) && (fa == fb || *fa == 0 || *fb == 0),
            (
                Caps::Tensors {
                    infos: a,
                    fps_millis: fa,
                },
                Caps::Tensors {
                    infos: b,
                    fps_millis: fb,
                },
            ) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.equivalent(y))
                    && (fa == fb || *fa == 0 || *fb == 0)
            }
            // A single-tensor `other/tensors` is interchangeable with
            // `other/tensor` (NNStreamer auto-converts at link time).
            (Caps::Tensor { info, fps_millis }, Caps::Tensors { infos, fps_millis: fb })
            | (Caps::Tensors { infos, fps_millis: fb }, Caps::Tensor { info, fps_millis }) => {
                infos.len() == 1
                    && infos[0].equivalent(info)
                    && (fps_millis == fb || *fps_millis == 0 || *fb == 0)
            }
            _ => false,
        }
    }

    /// Intersect producer caps with a consumer restriction, producing the
    /// fixed caps that flow on the link.
    pub fn intersect(&self, other: &Caps) -> Result<Caps> {
        if !self.compatible(other) {
            return Err(Error::Negotiation(format!(
                "{self} not compatible with {other}"
            )));
        }
        Ok(match (self, other) {
            (Caps::Any, o) => o.clone(),
            (s, Caps::Any) => s.clone(),
            // prefer the side with a fixed rate
            (Caps::Tensor { fps_millis: 0, .. }, o @ Caps::Tensor { .. }) => o.clone(),
            (Caps::Video(a), Caps::Video(b)) if a.fps_millis == 0 => Caps::Video(b.clone()),
            (s, _) => s.clone(),
        })
    }

    /// Parse a caps-filter string, e.g.
    /// `other/tensor,dimension=3:64:64,type=float32,framerate=30`
    /// `video/x-raw,format=RGB,width=640,height=480,framerate=30`
    pub fn parse(s: &str) -> Result<Caps> {
        let mut parts = s.split(',').map(str::trim);
        let media = parts
            .next()
            .ok_or_else(|| Error::Parse(format!("empty caps {s:?}")))?;
        let mut fields = std::collections::HashMap::new();
        for p in parts {
            let (k, v) = p
                .split_once('=')
                .ok_or_else(|| Error::Parse(format!("bad caps field {p:?}")))?;
            fields.insert(k.trim().to_string(), v.trim().to_string());
        }
        let fps = fields
            .get("framerate")
            .map(|v| {
                // accept "30", "30.0" or GStreamer "30/1"
                let v = v.split('/').next().unwrap_or(v);
                v.parse::<f64>()
                    .map_err(|_| Error::Parse(format!("bad framerate {v:?}")))
            })
            .transpose()?
            .unwrap_or(0.0);
        match media {
            "video/x-raw" => {
                let format = VideoFormat::parse(fields.get("format").map(String::as_str).unwrap_or("RGB"))?;
                let width = parse_field(&fields, "width")?.unwrap_or(640);
                let height = parse_field(&fields, "height")?.unwrap_or(480);
                Ok(Caps::Video(VideoInfo::new(format, width, height, fps)))
            }
            "other/tensor" => {
                let dims = fields
                    .get("dimension")
                    .map(|d| super::Dims::parse(d))
                    .transpose()?
                    .ok_or_else(|| Error::Parse(format!("other/tensor needs dimension= in {s:?}")))?;
                let dtype = DType::parse(fields.get("type").map(String::as_str).unwrap_or("float32"))?;
                Ok(Caps::Tensor {
                    info: TensorInfo::new(dtype, dims),
                    fps_millis: (fps * 1000.0).round() as u64,
                })
            }
            "other/tensors" => {
                // dimensions=d0. d1. d2,types=t0.t1.t2 (dot-separated lists)
                let dims_list = fields
                    .get("dimensions")
                    .ok_or_else(|| Error::Parse("other/tensors needs dimensions=".into()))?;
                let types_list = fields
                    .get("types")
                    .ok_or_else(|| Error::Parse("other/tensors needs types=".into()))?;
                let dims: Vec<_> = dims_list.split('.').collect();
                let types: Vec<_> = types_list.split('.').collect();
                if dims.len() != types.len() {
                    return Err(Error::Parse("dimensions/types count mismatch".into()));
                }
                let infos = dims
                    .iter()
                    .zip(&types)
                    .map(|(d, t)| {
                        Ok(TensorInfo::new(DType::parse(t)?, super::Dims::parse(d)?))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Caps::Tensors {
                    infos,
                    fps_millis: (fps * 1000.0).round() as u64,
                })
            }
            "text/x-raw" => Ok(Caps::Text),
            "other/flatbuf" => Ok(Caps::FlatBuf),
            "audio/x-raw" => Ok(Caps::Audio(AudioInfo {
                rate: parse_field(&fields, "rate")?.unwrap_or(16000),
                channels: parse_field(&fields, "channels")?.unwrap_or(1),
                samples_per_buffer: parse_field(&fields, "samples")?.unwrap_or(1600),
            })),
            other => Err(Error::Parse(format!("unknown media type {other:?}"))),
        }
    }
}

fn parse_field(
    fields: &std::collections::HashMap<String, String>,
    key: &str,
) -> Result<Option<usize>> {
    fields
        .get(key)
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| Error::Parse(format!("bad {key}={v:?}")))
        })
        .transpose()
}

impl std::fmt::Display for Caps {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Caps::Any => write!(f, "ANY"),
            Caps::Video(v) => write!(
                f,
                "video/x-raw,format={},width={},height={},framerate={}",
                v.format.name(),
                v.width,
                v.height,
                v.fps()
            ),
            Caps::Audio(a) => write!(f, "audio/x-raw,rate={},channels={}", a.rate, a.channels),
            Caps::Text => write!(f, "text/x-raw"),
            Caps::FlatBuf => write!(f, "other/flatbuf"),
            Caps::Tensor { info, fps_millis } => write!(
                f,
                "other/tensor,dimension={},type={},framerate={}",
                info.dims,
                info.dtype,
                *fps_millis as f64 / 1000.0
            ),
            Caps::Tensors { infos, fps_millis } => {
                let dims: Vec<String> = infos.iter().map(|i| i.dims.to_string()).collect();
                let types: Vec<String> = infos.iter().map(|i| i.dtype.to_string()).collect();
                write!(
                    f,
                    "other/tensors,dimensions={},types={},framerate={}",
                    dims.join("."),
                    types.join("."),
                    *fps_millis as f64 / 1000.0
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_video_caps() {
        let c = Caps::parse("video/x-raw,format=RGB,width=640,height=480,framerate=30").unwrap();
        match &c {
            Caps::Video(v) => {
                assert_eq!(v.format, VideoFormat::Rgb);
                assert_eq!((v.width, v.height), (640, 480));
                assert_eq!(v.fps(), 30.0);
                assert_eq!(v.frame_size(), 640 * 480 * 3);
            }
            _ => panic!("wrong caps {c:?}"),
        }
    }

    #[test]
    fn parse_tensor_caps_roundtrip() {
        let c = Caps::parse("other/tensor,dimension=3:64:64,type=float32,framerate=30").unwrap();
        let c2 = Caps::parse(&c.to_string()).unwrap();
        assert!(c.compatible(&c2));
    }

    #[test]
    fn tensor_rank_agnostic_compat() {
        let a = Caps::parse("other/tensor,dimension=640:480,type=uint8").unwrap();
        let b = Caps::parse("other/tensor,dimension=640:480:1:1,type=uint8").unwrap();
        assert!(a.compatible(&b));
    }

    #[test]
    fn single_tensors_matches_tensor() {
        let a = Caps::parse("other/tensor,dimension=4:2,type=float32").unwrap();
        let b = Caps::parse("other/tensors,dimensions=4:2,types=float32").unwrap();
        assert!(a.compatible(&b));
    }

    #[test]
    fn incompatible_formats() {
        let a = Caps::parse("video/x-raw,format=RGB,width=4,height=4").unwrap();
        let b = Caps::parse("video/x-raw,format=BGR,width=4,height=4").unwrap();
        assert!(!a.compatible(&b));
    }

    #[test]
    fn variable_rate_matches_fixed() {
        let a = Caps::tensor(DType::F32, [4], 0.0);
        let b = Caps::tensor(DType::F32, [4], 30.0);
        assert!(a.compatible(&b));
        // intersection picks the fixed rate
        match a.intersect(&b).unwrap() {
            Caps::Tensor { fps_millis, .. } => assert_eq!(fps_millis, 30000),
            _ => panic!(),
        }
    }

    #[test]
    fn any_intersects_to_other_side() {
        let b = Caps::tensor(DType::F32, [4], 30.0);
        assert_eq!(Caps::Any.intersect(&b).unwrap(), b);
        assert_eq!(b.intersect(&Caps::Any).unwrap(), b);
    }
}
