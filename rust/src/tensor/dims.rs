//! Tensor dimensions with rank-agnostic equivalence.
//!
//! NNStreamer does not express rank in tensor stream types: `640:480`
//! (rank 2) and `640:480:1:1` (rank 4) are *equivalent* during caps
//! negotiation (§III). We keep the declared rank (a few NNFWs such as
//! TensorRT need it) but compare modulo trailing 1s.

use crate::error::{Error, Result};

/// Maximum supported rank (NNStreamer supports up to 8 in recent versions).
pub const MAX_RANK: usize = 8;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Dims {
    d: [usize; MAX_RANK],
    rank: usize,
}

impl Dims {
    pub fn new(dims: &[usize]) -> Self {
        assert!(
            dims.len() <= MAX_RANK,
            "rank {} exceeds MAX_RANK {}",
            dims.len(),
            MAX_RANK
        );
        assert!(!dims.is_empty(), "Dims must have at least one dimension");
        let mut d = [1usize; MAX_RANK];
        d[..dims.len()].copy_from_slice(dims);
        Self {
            d,
            rank: dims.len(),
        }
    }

    /// Scalar (rank-1, size-1) dims.
    pub fn scalar() -> Self {
        Self::new(&[1])
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Dimensions as declared (length == rank).
    pub fn as_slice(&self) -> &[usize] {
        &self.d[..self.rank]
    }

    /// Dimension at `idx`, treating out-of-rank indices as 1 (rank-agnostic
    /// accessor, used by dimension-surgery elements).
    pub fn dim_or_1(&self, idx: usize) -> usize {
        if idx < MAX_RANK {
            self.d[idx]
        } else {
            1
        }
    }

    pub fn num_elements(&self) -> usize {
        self.as_slice().iter().product()
    }

    /// Effective rank: declared rank with trailing 1s stripped (min 1).
    pub fn effective_rank(&self) -> usize {
        let mut r = self.rank;
        while r > 1 && self.d[r - 1] == 1 {
            r -= 1;
        }
        r
    }

    /// Rank-agnostic equivalence: `640:480` == `640:480:1:1`.
    pub fn equivalent(&self, other: &Dims) -> bool {
        let r = self.effective_rank().max(other.effective_rank());
        (0..r).all(|i| self.dim_or_1(i) == other.dim_or_1(i))
    }

    /// Parse NNStreamer dimension syntax `"3:224:224"`.
    pub fn parse(s: &str) -> Result<Self> {
        let parts: Vec<usize> = s
            .split(':')
            .map(|p| {
                p.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::Parse(format!("bad dimension {p:?} in {s:?}")))
            })
            .collect::<Result<_>>()?;
        if parts.is_empty() || parts.len() > MAX_RANK {
            return Err(Error::Parse(format!("bad dimension count in {s:?}")));
        }
        if parts.iter().any(|&d| d == 0) {
            return Err(Error::Parse(format!("zero dimension in {s:?}")));
        }
        Ok(Self::new(&parts))
    }

    /// A copy with the dimension at `axis` replaced.
    pub fn with_dim(&self, axis: usize, value: usize) -> Self {
        let mut out = self.clone();
        assert!(axis < MAX_RANK);
        out.d[axis] = value;
        if axis >= out.rank {
            out.rank = axis + 1;
        }
        out
    }
}

impl From<&[usize]> for Dims {
    fn from(s: &[usize]) -> Self {
        Dims::new(s)
    }
}

impl<const N: usize> From<[usize; N]> for Dims {
    fn from(s: [usize; N]) -> Self {
        Dims::new(&s)
    }
}

impl From<Vec<usize>> for Dims {
    fn from(s: Vec<usize>) -> Self {
        Dims::new(&s)
    }
}

impl std::fmt::Display for Dims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self.as_slice().iter().map(|d| d.to_string()).collect();
        f.write_str(&parts.join(":"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let d = Dims::parse("3:224:224").unwrap();
        assert_eq!(d.rank(), 3);
        assert_eq!(d.as_slice(), &[3, 224, 224]);
        assert_eq!(d.to_string(), "3:224:224");
        assert_eq!(d.num_elements(), 3 * 224 * 224);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Dims::parse("").is_err());
        assert!(Dims::parse("3:x").is_err());
        assert!(Dims::parse("3:0:2").is_err());
        assert!(Dims::parse("1:2:3:4:5:6:7:8:9").is_err());
    }

    #[test]
    fn equivalence_ignores_trailing_ones() {
        let a = Dims::parse("640:480").unwrap();
        let b = Dims::parse("640:480:1:1").unwrap();
        assert!(a.equivalent(&b));
        assert_eq!(a.effective_rank(), 2);
        assert_eq!(b.effective_rank(), 2);
        // but declared rank is preserved for rank-sensitive NNFWs
        assert_eq!(b.rank(), 4);
    }

    #[test]
    fn equivalence_respects_interior_ones() {
        let a = Dims::parse("640:1:480").unwrap();
        let b = Dims::parse("640:480").unwrap();
        assert!(!a.equivalent(&b));
    }

    #[test]
    fn with_dim_extends_rank() {
        let d = Dims::parse("4:8").unwrap().with_dim(2, 7);
        assert_eq!(d.as_slice(), &[4, 8, 7]);
    }
}
