//! Baseline implementations the paper compares against.
//!
//! * [`control`] — the "conventional implementation": a straight-line
//!   serial per-frame loop (E1/E2/E3's Control columns).
//! * [`mediapipe_like`] — a re-implemented calculator-graph framework with
//!   its own (naive) pre-processors and a FlowLimiter back-edge, pinned to
//!   the `*_ref` NNFW build (E4's MediaPipe column).

pub mod control;
pub mod mediapipe_like;
