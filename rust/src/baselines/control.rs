//! "Control": the conventional serial implementations the paper compares
//! against in E1 and E2 (E3's ROS-style control lives with the MTCNN app).
//!
//! Per the paper, Control "processes every required operation serially for
//! each input frame" and is "too inefficient, caching everything in
//! memory". We reproduce both properties: a single-threaded
//! fetch→convert→infer→decode loop, an extra cached copy per stage, and
//! (live mode) busy-polling for the next frame — the style of one-off
//! product code the paper describes replacing.

use std::time::Instant;

use crate::apps::e1::{E1Case, E1Config, E1Row};
use crate::devices::NpuSim;
use crate::error::Result;
use crate::metrics::MemInfo;
use crate::runtime::ModelRegistry;
use crate::tensor::Chunk;
use crate::video::{pattern, Pattern};

/// E1 Control: serial per-frame loop over the case's models.
pub fn run_e1_control(cfg: &E1Config, case: E1Case) -> Result<E1Row> {
    let reg = ModelRegistry::global()?;
    let branches = case.branches();
    let models: Vec<_> = branches
        .iter()
        .map(|(stem, _)| reg.load(&format!("{stem}_opt")))
        .collect::<Result<_>>()?;

    let mem_before = MemInfo::read().vm_rss_kib;
    let t0 = Instant::now();
    let frame_dur = 1.0 / cfg.fps.max(0.001);
    let mut cache: Vec<Vec<u8>> = Vec::new();
    let mut busy = std::time::Duration::ZERO;
    let mut done = 0u64;
    for n in 0..cfg.num_frames {
        if cfg.live {
            // conventional code busy-polls the camera for the next frame
            let deadline = n as f64 * frame_dur;
            while t0.elapsed().as_secs_f64() < deadline {
                std::hint::spin_loop();
            }
        }
        let b0 = Instant::now();
        let frame = pattern::generate_rgb(Pattern::Ball, cfg.src_w, cfg.src_h, n);
        // "caching everything in memory": full-res copies pile up
        cache.push(frame.clone());
        if cache.len() > 128 {
            cache.remove(0);
        }
        for (model, (stem, on_npu)) in models.iter().zip(&branches) {
            let side = if *stem == "i3" { 64 } else { 96 };
            // the conventional code pre-processes the way the paper
            // describes it: full-resolution float conversion + separate
            // passes, re-done per model (cached, never shared)
            let norm = naive_preprocess(&frame, cfg.src_w, cfg.src_h, side);
            let input = Chunk::from_f32(&norm);
            let outs = if *on_npu {
                NpuSim::global().submit(model.clone(), vec![input])?
            } else {
                // CPU path with the same modeled envelope tensor_filter uses
                let t = Instant::now();
                let o = model.execute(&[&input])?;
                let rate = crate::nnfw::cpu_rate_flops();
                if rate > 0 {
                    let target = std::time::Duration::from_secs_f64(
                        model.spec.flops as f64 / rate as f64,
                    );
                    if target > t.elapsed() {
                        std::thread::sleep(target - t.elapsed());
                    }
                }
                o
            };
            // trivial decode (argmax / thresholding)
            let v = outs[0].to_f32_vec()?;
            std::hint::black_box(v.iter().cloned().fold(f32::MIN, f32::max));
        }
        busy += b0.elapsed();
        done += 1;
    }
    let wall = t0.elapsed();
    let mem_after = MemInfo::read().vm_rss_kib;
    let fps = done as f64 / wall.as_secs_f64();
    Ok(E1Row {
        label: case.label().to_string(),
        fps: branches.iter().map(|_| fps).collect(),
        // serial loop occupies its core for busy + polling time; polling
        // is CPU-burning by construction
        cpu_percent: if cfg.live {
            100.0 * (wall.as_secs_f64() - idle_estimate(&branches, done, wall))
                / wall.as_secs_f64()
        } else {
            100.0 * busy.as_secs_f64() / wall.as_secs_f64()
        },
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
        wall_s: wall.as_secs_f64(),
    })
}

/// The conventional pre-processing path (the style of the product code the
/// paper replaced): full-resolution f64 conversion, a separate color pass,
/// a separate normalize pass, then a naive per-pixel scale — one fresh
/// allocation per pass, re-run for every model.
fn naive_preprocess(frame: &[u8], src_w: usize, src_h: usize, side: usize) -> Vec<f32> {
    // pass 1: u8 -> f64 full frame
    let float_frame: Vec<f64> = frame.iter().map(|&v| v as f64).collect();
    // pass 2: "color calibration" full frame
    let calibrated: Vec<f64> = float_frame.iter().map(|v| (v * 1.0003).min(255.0)).collect();
    // pass 3: normalize full frame
    let normalized: Vec<f64> = calibrated.iter().map(|v| v / 255.0).collect();
    // pass 4: naive bilinear scale with per-sample bounds checks
    let mut out = vec![0f32; side * side * 3];
    let texel = |x: usize, y: usize, c: usize| -> f64 {
        normalized[(y.min(src_h - 1) * src_w + x.min(src_w - 1)) * 3 + c]
    };
    for y in 0..side {
        for x in 0..side {
            let sx = x as f64 * (src_w - 1) as f64 / (side - 1) as f64;
            let sy = y as f64 * (src_h - 1) as f64 / (side - 1) as f64;
            let (x0, y0) = (sx as usize, sy as usize);
            let (wx, wy) = (sx - x0 as f64, sy - y0 as f64);
            for c in 0..3 {
                let top = texel(x0, y0, c) * (1.0 - wx) + texel(x0 + 1, y0, c) * wx;
                let bot = texel(x0, y0 + 1, c) * (1.0 - wx) + texel(x0 + 1, y0 + 1, c) * wx;
                out[(y * side + x) * 3 + c] = (top * (1.0 - wy) + bot * wy) as f32;
            }
        }
    }
    out
}

/// The only time Control's thread is *not* occupying its core is while
/// blocked on the NPU ioctl; estimate that from NPU service times.
fn idle_estimate(
    branches: &[(&'static str, bool)],
    frames: u64,
    wall: std::time::Duration,
) -> f64 {
    let npu_jobs = branches.iter().filter(|(_, npu)| *npu).count() as u64;
    if npu_jobs == 0 {
        return 0.0;
    }
    // normalize by frames, not jobs: batched submissions make a "job"
    // cover several frames, while Control always submits one frame per job
    let stats = &NpuSim::global().stats;
    let per_frame =
        stats.total_service().as_secs_f64() / stats.frames().max(1) as f64;
    (per_frame * (npu_jobs * frames) as f64).min(wall.as_secs_f64())
}

/// E2 Control: the pre-NNStreamer ARS implementation — serial multi-sensor
/// loop with redundant conversions and copies (see module docs).
pub struct ArsControlReport {
    pub windows_a: u64,
    pub windows_b: u64,
    pub windows_c: u64,
    pub wall_s: f64,
    pub rate_a: f64,
    pub rate_b: f64,
    pub rate_c: f64,
    pub cpu_percent: f64,
    pub mem_mib: f64,
}

pub fn run_ars_control(num_windows: u64, live_rate: Option<f64>) -> Result<ArsControlReport> {
    let reg = ModelRegistry::global()?;
    let ars_a = reg.load("ars_a_opt")?;
    let ars_b = reg.load("ars_b_opt")?;
    let ars_c = reg.load("ars_c_opt")?;

    let mem_before = MemInfo::read().vm_rss_kib;
    let t0 = Instant::now();
    let mut busy = std::time::Duration::ZERO;
    // conventional code keeps a growing history of raw sensor readings
    let mut history: Vec<Vec<f32>> = Vec::new();
    let (mut na, mut nb, mut nc) = (0u64, 0u64, 0u64);
    let mut agg: Vec<f32> = Vec::new();
    for n in 0..num_windows {
        if let Some(rate) = live_rate {
            let deadline = n as f64 / rate;
            while t0.elapsed().as_secs_f64() < deadline {
                std::hint::spin_loop(); // busy-poll the sensor FIFO
            }
        }
        let b0 = Instant::now();
        // fetch sensor windows (synthesized like sensorsrc's waveforms)
        let accel = synth_window(n, 128, 3, 0);
        let pressure = synth_window(n, 128, 1, 1);
        let mic = synth_window(n, 64, 16, 2);
        // "caching everything in memory": raw history grows unboundedly
        // (the paper's control caches full-rate sensor history)
        history.push(accel.clone());
        if history.len() > 4096 {
            history.remove(0);
        }

        // stage (a): per-window activity — with a redundant normalize pass
        // and an extra copy, the way the original product code worked
        let mut a_in = accel.clone();
        let mean: f32 = a_in.iter().sum::<f32>() / a_in.len() as f32;
        for v in &mut a_in {
            *v -= mean;
        }
        let a_copy = a_in.clone();
        let out_a = ars_a.execute(&[&Chunk::from_f32(&a_copy)])?;
        std::hint::black_box(out_a[0].to_f32_vec()?);
        na += 1;

        // stage (b): fused long window — rebuilt from raw history EVERY
        // window (no streaming aggregation), with standardization
        // recomputed from scratch in f64 each time; the model only runs
        // every 4th window, but the conversion work is repeated always
        agg.clear();
        let from = history.len().saturating_sub(4);
        let hist = &history[from..];
        // full-recompute standardization over the whole fused window
        let flat: Vec<f64> = hist.iter().flat_map(|w| w.iter().map(|&v| v as f64)).collect();
        let fmean = flat.iter().sum::<f64>() / flat.len().max(1) as f64;
        let fvar = flat.iter().map(|v| (v - fmean).powi(2)).sum::<f64>()
            / flat.len().max(1) as f64;
        let fsd = fvar.sqrt().max(1e-10);
        for w in hist {
            // interleave 8 channels: accel(3) + pressure(1) + stand(3) + pad
            for s in 0..128 {
                for c in 0..3 {
                    agg.push(w[s * 3 + c]);
                }
                agg.push(pressure[s.min(pressure.len() - 1)]);
                for c in 0..3 {
                    agg.push(((w[s * 3 + c] as f64 - fmean) / fsd) as f32);
                }
                agg.push(0.0);
            }
        }
        if n % 4 == 3 && agg.len() >= 512 * 8 {
            let out_b = ars_b.execute(&[&Chunk::from_f32(&agg[..512 * 8])])?;
            std::hint::black_box(out_b[0].to_f32_vec()?);
            nb += 1;
        }

        // stage (c): mic events every 2 windows
        if n % 2 == 1 {
            let out_c = ars_c.execute(&[&Chunk::from_f32(&mic)])?;
            std::hint::black_box(out_c[0].to_f32_vec()?);
            nc += 1;
        }
        busy += b0.elapsed();
    }
    let wall = t0.elapsed();
    let mem_after = MemInfo::read().vm_rss_kib;
    Ok(ArsControlReport {
        windows_a: na,
        windows_b: nb,
        windows_c: nc,
        wall_s: wall.as_secs_f64(),
        rate_a: na as f64 / wall.as_secs_f64(),
        rate_b: nb as f64 / wall.as_secs_f64(),
        rate_c: nc as f64 / wall.as_secs_f64(),
        cpu_percent: if live_rate.is_some() {
            100.0 // busy-polls whenever idle: the core never rests
        } else {
            100.0 * busy.as_secs_f64() / wall.as_secs_f64()
        },
        mem_mib: ((mem_after.saturating_sub(mem_before)) as f64 / 1024.0).max(0.0),
    })
}

fn synth_window(n: u64, window: usize, channels: usize, seed: u64) -> Vec<f32> {
    let mut out = vec![0f32; window * channels];
    for s in 0..window {
        for c in 0..channels {
            let x = pattern::splitmix64(n * window as u64 + s as u64 + seed * 7919);
            out[s * channels + c] =
                ((x % 2000) as f32 / 1000.0 - 1.0) * 0.5 + ((n + c as u64) as f32 * 0.1).sin();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ars_control_counts_stages() {
        let r = run_ars_control(8, None).unwrap();
        assert_eq!(r.windows_a, 8);
        assert_eq!(r.windows_b, 2);
        assert_eq!(r.windows_c, 4);
        assert!(r.rate_a > 0.0);
    }
}
