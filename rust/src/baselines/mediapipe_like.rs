//! MediaPipe-like baseline for E4: a re-implemented "calculator graph"
//! perception framework.
//!
//! Reproduces the two measured handicaps of MediaPipe the paper exploits:
//!
//! 1. **Re-implemented pre-processing** (P4 forfeited): its own scalar,
//!    float-per-pixel image ops instead of the optimized off-the-shelf
//!    media filters — E4 measures these 25% slower with 40% more overhead.
//! 2. **NNFW pinning** (P6 forfeited): the build system locks one NNFW
//!    version, here the `ssd_ref` artifact (the "TFLite 2.1" analog),
//!    while NNStreamer is free to run `ssd_opt` ("TFLite 1.15").
//!
//! Like MediaPipe's object-detection example, the graph has a FlowLimiter
//! back-edge: new frames are admitted only after the in-flight detection
//! finishes (the paper notes NNStreamer needs no such cycle because
//! GStreamer's QoS events flow upstream inside the stream channel).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::{Model, ModelRegistry};
use crate::tensor::Chunk;

/// A packet flowing through the calculator graph.
#[derive(Clone)]
pub struct Packet {
    pub ts_us: u64,
    pub data: Arc<Vec<f32>>,
}

/// One calculator node: packets in, packets out.
pub trait Calculator: Send {
    fn name(&self) -> &str;
    fn process(&mut self, input: Packet) -> Result<Option<Packet>>;
}

/// The naive pre-processors (the framework's own re-implementations).
pub mod calculators {
    use super::*;

    /// RGB u8 frame (as f32 0..255 packet) -> scaled, normalized tensor.
    /// Deliberately naive: per-pixel closure calls, f64 arithmetic,
    /// separate passes for scale / convert / normalize with a fresh
    /// allocation each (how a quick re-implementation actually looks).
    pub struct ImageTransformCalculator {
        pub src_w: usize,
        pub src_h: usize,
        pub dst_w: usize,
        pub dst_h: usize,
    }

    impl ImageTransformCalculator {
        fn texel(&self, data: &[f32], x: usize, y: usize, c: usize) -> f64 {
            // bounds-checked per call (the naive style)
            let xi = x.min(self.src_w - 1);
            let yi = y.min(self.src_h - 1);
            data[(yi * self.src_w + xi) * 3 + c] as f64
        }

        fn sample(&self, data: &[f32], x: f64, y: f64, c: usize) -> f64 {
            // bilinear with 4 bounds-checked texel fetches in f64 — the
            // same visual quality as videoscale, re-implemented naively
            let x0 = x.floor().max(0.0) as usize;
            let y0 = y.floor().max(0.0) as usize;
            let wx = x - x0 as f64;
            let wy = y - y0 as f64;
            let p00 = self.texel(data, x0, y0, c);
            let p01 = self.texel(data, x0 + 1, y0, c);
            let p10 = self.texel(data, x0, y0 + 1, c);
            let p11 = self.texel(data, x0 + 1, y0 + 1, c);
            (p00 * (1.0 - wx) + p01 * wx) * (1.0 - wy) + (p10 * (1.0 - wx) + p11 * wx) * wy
        }
    }

    impl Calculator for ImageTransformCalculator {
        fn name(&self) -> &str {
            "ImageTransformCalculator"
        }

        fn process(&mut self, input: Packet) -> Result<Option<Packet>> {
            // pass 1: scale (fresh allocation)
            let mut scaled = vec![0f64; self.dst_w * self.dst_h * 3];
            for y in 0..self.dst_h {
                for x in 0..self.dst_w {
                    for c in 0..3 {
                        let sx = x as f64 * self.src_w as f64 / self.dst_w as f64;
                        let sy = y as f64 * self.src_h as f64 / self.dst_h as f64;
                        scaled[(y * self.dst_w + x) * 3 + c] =
                            self.sample(&input.data, sx, sy, c);
                    }
                }
            }
            // pass 2: RGB -> float tensor (another allocation)
            let mut tensor = vec![0f64; scaled.len()];
            for (i, v) in scaled.iter().enumerate() {
                tensor[i] = *v;
            }
            // pass 3: normalize
            let out: Vec<f32> = tensor.iter().map(|v| (v / 255.0) as f32).collect();
            crate::metrics::traffic::count_write(out.len() * 4);
            crate::metrics::traffic::count_read(input.data.len() * 4 + scaled.len() * 8);
            Ok(Some(Packet {
                ts_us: input.ts_us,
                data: Arc::new(out),
            }))
        }
    }

    /// Runs the pinned-NNFW detection model.
    pub struct InferenceCalculator {
        pub model: Arc<Model>,
    }

    impl Calculator for InferenceCalculator {
        fn name(&self) -> &str {
            "InferenceCalculator"
        }

        fn process(&mut self, input: Packet) -> Result<Option<Packet>> {
            let chunk = Chunk::from_f32(&input.data);
            let outs = self.model.execute(&[&chunk])?;
            // concat (locs, scores) into one packet
            let mut data = outs[0].to_f32_vec()?;
            data.extend(outs[1].to_f32_vec()?);
            Ok(Some(Packet {
                ts_us: input.ts_us,
                data: Arc::new(data),
            }))
        }
    }

    /// Decodes detections (threshold + box assembly), naive scalar code.
    pub struct DetectionCalculator {
        pub n_anchors: usize,
        pub classes: usize,
        pub threshold: f32,
    }

    impl Calculator for DetectionCalculator {
        fn name(&self) -> &str {
            "TensorsToDetectionsCalculator"
        }

        fn process(&mut self, input: Packet) -> Result<Option<Packet>> {
            let locs = &input.data[..self.n_anchors * 4];
            let confs = &input.data[self.n_anchors * 4..];
            let mut dets = Vec::new();
            for i in 0..self.n_anchors {
                let c = &confs[i * self.classes..(i + 1) * self.classes];
                // naive softmax per anchor in f64
                let m = c.iter().fold(f32::MIN, |a, &b| a.max(b)) as f64;
                let exps: Vec<f64> = c.iter().map(|&v| ((v as f64) - m).exp()).collect();
                let z: f64 = exps.iter().sum();
                for (ci, e) in exps.iter().enumerate().skip(1) {
                    let p = (e / z) as f32;
                    if p >= self.threshold {
                        dets.extend_from_slice(&[
                            locs[i * 4],
                            locs[i * 4 + 1],
                            locs[i * 4 + 2],
                            locs[i * 4 + 3],
                            p,
                            ci as f32,
                        ]);
                    }
                }
            }
            Ok(Some(Packet {
                ts_us: input.ts_us,
                data: Arc::new(dets),
            }))
        }
    }
}

/// The object-detection graph with a FlowLimiter back-edge.
pub struct CalculatorGraph {
    limiter_in_flight: usize,
    max_in_flight: usize,
    queue: VecDeque<Packet>,
    nodes: Vec<Box<dyn Calculator>>,
    pub frames_out: u64,
    pub latency_sum_us: u64,
}

impl CalculatorGraph {
    /// Build the E4 detection graph, pinned to the `ssd_ref` NNFW build.
    pub fn object_detection(src_w: usize, src_h: usize) -> Result<Self> {
        let reg = ModelRegistry::global()?;
        let model = reg.load("ssd_ref")?;
        let spec = &model.spec;
        let n_anchors = spec.outputs[0].dims.as_slice()[1];
        let classes = spec.outputs[1].dims.as_slice()[2];
        let side = spec.inputs[0].dims.as_slice()[1];
        Ok(Self {
            limiter_in_flight: 0,
            max_in_flight: 1,
            queue: VecDeque::new(),
            nodes: vec![
                Box::new(calculators::ImageTransformCalculator {
                    src_w,
                    src_h,
                    dst_w: side,
                    dst_h: side,
                }),
                Box::new(calculators::InferenceCalculator { model }),
                Box::new(calculators::DetectionCalculator {
                    n_anchors,
                    classes,
                    threshold: 0.5,
                }),
            ],
            frames_out: 0,
            latency_sum_us: 0,
        })
    }

    /// Variant without the image pre-processor (the hybrid case d: the
    /// outer NNStreamer pipeline already pre-processed the frame).
    pub fn object_detection_preprocessed() -> Result<Self> {
        let mut g = Self::object_detection(1, 1)?;
        g.nodes.remove(0);
        Ok(g)
    }

    /// Offer a frame; the FlowLimiter may reject it (returns false).
    pub fn add_frame(&mut self, packet: Packet) -> bool {
        if self.limiter_in_flight >= self.max_in_flight {
            return false;
        }
        self.limiter_in_flight += 1;
        self.queue.push_back(packet);
        true
    }

    /// Run until idle; returns the detection packets.
    pub fn run_until_idle(&mut self) -> Result<Vec<Packet>> {
        let mut outputs = Vec::new();
        while let Some(mut packet) = self.queue.pop_front() {
            let admitted_us = packet.ts_us;
            let mut alive = true;
            for node in &mut self.nodes {
                match node.process(packet.clone())? {
                    Some(p) => packet = p,
                    None => {
                        alive = false;
                        break;
                    }
                }
            }
            // detection done: FlowLimiter admits the next frame
            self.limiter_in_flight = self.limiter_in_flight.saturating_sub(1);
            if alive {
                self.frames_out += 1;
                let _ = admitted_us;
                outputs.push(packet);
            }
        }
        Ok(outputs)
    }

    /// Pre-processing only (the paper's pre-processor comparison): run the
    /// image calculator over `frames` synthetic frames, returning
    /// (cpu_time_s, real_time_s).
    pub fn preprocess_only(src_w: usize, src_h: usize, frames: u64) -> Result<(f64, f64)> {
        let mut node = calculators::ImageTransformCalculator {
            src_w,
            src_h,
            dst_w: 96,
            dst_h: 96,
        };
        let cpu = crate::metrics::CpuTracker::start();
        let t0 = Instant::now();
        for n in 0..frames {
            let rgb = crate::video::pattern::generate_rgb(
                crate::video::Pattern::Ball,
                src_w,
                src_h,
                n,
            );
            let data: Vec<f32> = rgb.iter().map(|&v| v as f32).collect();
            let packet = Packet {
                ts_us: n,
                data: Arc::new(data),
            };
            node.process(packet)?.ok_or_else(|| {
                Error::Runtime("preprocessor dropped a frame".into())
            })?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let cpu_s = cpu.cpu_percent() / 100.0 * cpu.elapsed_secs();
        Ok((cpu_s, wall))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_detects_something_eventually() {
        let mut g = CalculatorGraph::object_detection(64, 64).unwrap();
        let rgb =
            crate::video::pattern::generate_rgb(crate::video::Pattern::Ball, 64, 64, 3);
        let data: Vec<f32> = rgb.iter().map(|&v| v as f32).collect();
        assert!(g.add_frame(Packet {
            ts_us: 0,
            data: Arc::new(data),
        }));
        let outs = g.run_until_idle().unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(g.frames_out, 1);
    }

    #[test]
    fn flow_limiter_rejects_while_in_flight() {
        let mut g = CalculatorGraph::object_detection(32, 32).unwrap();
        let p = Packet {
            ts_us: 0,
            data: Arc::new(vec![0f32; 32 * 32 * 3]),
        };
        assert!(g.add_frame(p.clone()));
        // second frame rejected until the first completes
        assert!(!g.add_frame(p.clone()));
        g.run_until_idle().unwrap();
        assert!(g.add_frame(p));
    }

    #[test]
    fn preprocess_only_measures() {
        let (cpu_s, wall_s) = CalculatorGraph::preprocess_only(160, 120, 3).unwrap();
        assert!(wall_s > 0.0);
        assert!(cpu_s >= 0.0);
    }
}
