//! Shared model-instance pool.
//!
//! `tensor_filter` elements do not load models directly: they *lease* them
//! from the process-wide [`ModelPool`]. Two pipeline branches (or two
//! pipelines) that reference the same artifact share one loaded
//! [`Model`] instance — the paper's observation that NNStreamer can run
//! "multiple instances of a single neural network model without
//! duplicated overheads" (§V, E1) — and the pool makes that sharing
//! observable and manageable:
//!
//! * per-artifact counters: how often it was loaded (compiled) vs merely
//!   re-acquired, and how many leases are currently live;
//! * idle eviction: [`ModelPool::evict_idle`] drops executables no filter
//!   is using (long-running daemons swap model sets without restarting).
//!
//! Loading delegates to [`ModelRegistry`], so pool users and direct
//! registry users (the Control baselines, the E3 custom stages) still end
//! up sharing the same `Arc<Model>`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Mutex;

use once_cell::sync::Lazy;

use crate::error::Result;
use crate::runtime::{Model, ModelRegistry};

struct Entry {
    /// `None` after idle eviction; re-loaded on the next acquire.
    model: Option<Arc<Model>>,
    live: Arc<AtomicUsize>,
    acquires: u64,
    loads: u64,
}

/// A leased model handle. Dropping the lease releases the pool slot (the
/// executable itself stays cached until [`ModelPool::evict_idle`]).
pub struct PoolLease {
    model: Arc<Model>,
    live: Arc<AtomicUsize>,
}

impl PoolLease {
    /// The shared model instance backing this lease.
    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }
}

impl std::ops::Deref for PoolLease {
    type Target = Model;

    fn deref(&self) -> &Model {
        &self.model
    }
}

impl Drop for PoolLease {
    fn drop(&mut self) {
        self.live.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Aggregate pool counters (see [`ModelPool::snapshot`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStatsSnapshot {
    /// Artifacts currently resident (not evicted).
    pub resident_models: usize,
    /// Total acquires across all artifacts.
    pub total_acquires: u64,
    /// Total loads (compiles) across all artifacts.
    pub total_loads: u64,
    /// Currently live leases across all artifacts.
    pub live_leases: usize,
}

/// The shared model-instance pool.
pub struct ModelPool {
    registry: Arc<ModelRegistry>,
    entries: Mutex<HashMap<String, Entry>>,
}

static GLOBAL: Lazy<Mutex<Option<Arc<ModelPool>>>> = Lazy::new(|| Mutex::new(None));

impl ModelPool {
    /// A pool over an explicit registry (tests, multi-directory setups).
    pub fn new(registry: Arc<ModelRegistry>) -> Self {
        Self {
            registry,
            entries: Mutex::new(HashMap::new()),
        }
    }

    /// The process-wide pool over [`ModelRegistry::global`].
    pub fn global() -> Result<Arc<Self>> {
        let mut g = GLOBAL.lock().unwrap();
        if let Some(p) = g.as_ref() {
            return Ok(p.clone());
        }
        let pool = Arc::new(Self::new(ModelRegistry::global()?));
        *g = Some(pool.clone());
        Ok(pool)
    }

    /// Lease a model by artifact name, loading it on first use.
    pub fn acquire(&self, name: &str) -> Result<PoolLease> {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(name.to_string()).or_insert_with(|| Entry {
            model: None,
            live: Arc::new(AtomicUsize::new(0)),
            acquires: 0,
            loads: 0,
        });
        if entry.model.is_none() {
            entry.model = Some(self.registry.load(name)?);
            entry.loads += 1;
        }
        entry.acquires += 1;
        entry.live.fetch_add(1, Ordering::Relaxed);
        Ok(PoolLease {
            model: entry.model.as_ref().expect("just loaded").clone(),
            live: entry.live.clone(),
        })
    }

    /// Times `name` was loaded (compiled). Stays at 1 however many
    /// branches lease the artifact — the sharing proof.
    pub fn loads(&self, name: &str) -> u64 {
        self.entries
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, |e| e.loads)
    }

    /// Times `name` was leased.
    pub fn acquires(&self, name: &str) -> u64 {
        self.entries
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, |e| e.acquires)
    }

    /// Currently live leases on `name`.
    pub fn live_leases(&self, name: &str) -> usize {
        self.entries
            .lock()
            .unwrap()
            .get(name)
            .map_or(0, |e| e.live.load(Ordering::Relaxed))
    }

    /// Aggregate counters over every artifact the pool has seen.
    pub fn snapshot(&self) -> PoolStatsSnapshot {
        let entries = self.entries.lock().unwrap();
        let mut s = PoolStatsSnapshot::default();
        for e in entries.values() {
            if e.model.is_some() {
                s.resident_models += 1;
            }
            s.total_acquires += e.acquires;
            s.total_loads += e.loads;
            s.live_leases += e.live.load(Ordering::Relaxed);
        }
        s
    }

    /// Evict every resident executable with zero live leases; returns how
    /// many were dropped. Counters survive eviction, so `loads` reflects
    /// genuine recompiles.
    pub fn evict_idle(&self) -> usize {
        let mut entries = self.entries.lock().unwrap();
        let mut evicted = 0;
        for (name, e) in entries.iter_mut() {
            if e.model.is_some() && e.live.load(Ordering::Relaxed) == 0 {
                e.model = None;
                self.registry.evict(name);
                evicted += 1;
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn private_pool() -> ModelPool {
        ModelPool::new(ModelRegistry::global().expect("artifacts present"))
    }

    #[test]
    fn leases_share_one_instance() {
        let pool = private_pool();
        let a = pool.acquire("onet_opt").unwrap();
        let b = pool.acquire("onet_opt").unwrap();
        assert!(
            Arc::ptr_eq(a.model(), b.model()),
            "two leases must share one Model instance"
        );
        assert_eq!(pool.loads("onet_opt"), 1);
        assert_eq!(pool.acquires("onet_opt"), 2);
        assert_eq!(pool.live_leases("onet_opt"), 2);
        drop(a);
        assert_eq!(pool.live_leases("onet_opt"), 1);
        drop(b);
        assert_eq!(pool.live_leases("onet_opt"), 0);
        let s = pool.snapshot();
        assert_eq!(s.resident_models, 1);
        assert_eq!(s.total_acquires, 2);
    }

    #[test]
    fn idle_eviction_reloads_on_next_acquire() {
        let pool = private_pool();
        let lease = pool.acquire("onet_opt").unwrap();
        assert_eq!(pool.evict_idle(), 0, "live lease must not be evicted");
        drop(lease);
        assert_eq!(pool.evict_idle(), 1);
        assert_eq!(pool.snapshot().resident_models, 0);
        let again = pool.acquire("onet_opt").unwrap();
        assert_eq!(pool.loads("onet_opt"), 2, "eviction forces a reload");
        assert_eq!(again.spec.name, "onet_opt");
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = ModelPool::global().unwrap();
        let b = ModelPool::global().unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
