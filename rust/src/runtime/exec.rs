//! Execution backend for AOT artifacts.
//!
//! The runtime is backend-agnostic: a [`Executable`] turns per-frame input
//! tensors into output tensors and charges a per-dispatch cost, and
//! everything above it (registry, pool, NNFW sub-plugins, NPU simulator)
//! only sees that contract. The offline build ships one backend, the
//! *deterministic surrogate* below; a PJRT/XLA backend slots in behind the
//! same `run_batch` seam (see DESIGN.md "Execution backends").
//!
//! ## Surrogate semantics
//!
//! The surrogate is a pure function of the model's *stem* (artifact name
//! minus the `_opt`/`_ref` variant suffix) and the frame's input values:
//!
//! * every output element mixes a fixed pseudo-random sample of the input
//!   (so outputs are input-dependent and spatially varied);
//! * heads marked `act=softmax` in the manifest are normalized into
//!   probability distributions over their last axis;
//! * `_opt` and `_ref` variants of one stem produce *identical values* —
//!   they model the same network built by two NNFW versions — but `_ref`
//!   pays a larger per-dispatch cost (E4's pinned old-NNFW build);
//! * the per-dispatch cost is real, deterministic CPU work sized from the
//!   manifest `flops=` field, modeling executable launch + weight
//!   residency. It is paid **once per dispatch**, not once per frame,
//!   which is precisely what makes batched invocation profitable.

use std::hint::black_box;

use crate::runtime::manifest::{Act, ModelSpec};
use crate::tensor::ChunkPool;
use crate::video::pattern::splitmix64;

/// Input samples mixed into each output element.
const SAMPLES: usize = 16;
/// Lower/upper bounds on modeled dispatch work (mixer iterations).
const DISPATCH_MIN: u64 = 200_000;
const DISPATCH_MAX: u64 = 20_000_000;
/// Dispatch-cost multiplier for `_ref` artifacts (the slower NNFW build).
const REF_DISPATCH_FACTOR: u64 = 3;

/// Artifact name minus the `_opt` / `_ref` variant suffix.
pub(crate) fn stem(name: &str) -> &str {
    name.strip_suffix("_opt")
        .or_else(|| name.strip_suffix("_ref"))
        .unwrap_or(name)
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A loaded, executable model (surrogate backend).
pub(crate) struct Executable {
    seed: u64,
    dispatch_iters: u64,
}

impl Executable {
    pub(crate) fn new(spec: &ModelSpec) -> Self {
        let mut iters = (spec.flops / 10).clamp(DISPATCH_MIN, DISPATCH_MAX);
        if spec.name.ends_with("_ref") {
            iters = iters.saturating_mul(REF_DISPATCH_FACTOR);
        }
        Self {
            seed: fnv1a(stem(&spec.name)),
            dispatch_iters: iters,
        }
    }

    /// Deterministic busy work standing in for executable launch + weight
    /// traffic. Paid once per dispatch regardless of batch size.
    fn dispatch_pad(&self) {
        let mut h = self.seed;
        for _ in 0..self.dispatch_iters {
            h = splitmix64(h);
        }
        black_box(h);
    }

    /// Execute a batch of frames in one dispatch. `frames[i]` holds frame
    /// `i`'s input tensors (borrowed views); the result holds frame `i`'s
    /// output tensors. Per-frame values are independent of the batch they
    /// ran in, so batched and unbatched execution are bit-identical.
    pub(crate) fn run_batch(
        &self,
        spec: &ModelSpec,
        frames: &[Vec<&[f32]>],
    ) -> Vec<Vec<Vec<f32>>> {
        self.dispatch_pad();
        frames.iter().map(|f| self.run_frame(spec, f)).collect()
    }

    fn run_frame(&self, spec: &ModelSpec, inputs: &[&[f32]]) -> Vec<Vec<f32>> {
        // single-input models (the common case) sample the input slice
        // directly; only multi-input models pay for a concat scratch copy
        let owned: Vec<f32>;
        let concat: &[f32] = if inputs.len() == 1 {
            inputs[0]
        } else {
            owned = inputs.iter().flat_map(|v| v.iter().copied()).collect();
            &owned
        };
        let n_in = concat.len().max(1);
        spec.outputs
            .iter()
            .enumerate()
            .map(|(j, info)| {
                let n = info.dims.num_elements();
                // per-output scratch from the pool: steady-state dispatch
                // reuses the previous frames' output allocations
                let mut out = ChunkPool::global().take_f32(n);
                for (k, slot) in out.iter_mut().enumerate() {
                    let mut h = self.seed
                        ^ (j as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                        ^ (k as u64).wrapping_mul(0xd1b5_4a32_d192_ed03);
                    let mut acc = 0f32;
                    for _ in 0..SAMPLES {
                        h = splitmix64(h);
                        let idx = (h as usize) % n_in;
                        let w = ((h >> 32) & 0xFFFF) as f32 / 65535.0 - 0.5;
                        acc += w * concat.get(idx).copied().unwrap_or(0.0);
                    }
                    *slot = (acc * (8.0 / SAMPLES as f32)).tanh();
                }
                if spec.acts.get(j) == Some(&Act::Softmax) {
                    let row = info
                        .dims
                        .as_slice()
                        .last()
                        .copied()
                        .unwrap_or(n)
                        .max(1);
                    softmax_rows(&mut out, row);
                }
                out
            })
            .collect()
    }
}

/// In-place softmax over consecutive rows of length `row`.
fn softmax_rows(v: &mut [f32], row: usize) {
    for chunk in v.chunks_mut(row) {
        let m = chunk.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0f32;
        for x in chunk.iter_mut() {
            *x = (*x - m).exp();
            z += *x;
        }
        if z > 0.0 {
            for x in chunk.iter_mut() {
                *x /= z;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DType, TensorInfo};

    fn spec(name: &str, out_dims: &[usize], act: Act) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            inputs: vec![TensorInfo::new(DType::F32, [1, 8, 4])],
            outputs: vec![TensorInfo::new(DType::F32, out_dims)],
            flops: 1,
            acts: vec![act],
        }
    }

    #[test]
    fn stem_strips_variant_suffix() {
        assert_eq!(stem("i3_opt"), "i3");
        assert_eq!(stem("i3_ref"), "i3");
        assert_eq!(stem("plain"), "plain");
    }

    #[test]
    fn softmax_head_sums_to_one_and_depends_on_input() {
        let s = spec("toy_opt", &[1, 8], Act::Softmax);
        let exe = Executable::new(&s);
        let a: Vec<f32> = (0..32).map(|i| i as f32 / 32.0).collect();
        let b: Vec<f32> = (0..32).map(|i| 1.0 - i as f32 / 32.0).collect();
        let oa = &exe.run_batch(&s, &[vec![a.as_slice()]])[0][0];
        let ob = &exe.run_batch(&s, &[vec![b.as_slice()]])[0][0];
        assert!((oa.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        let diff = oa
            .iter()
            .zip(ob)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(diff > 1e-4, "outputs must depend on inputs");
    }

    #[test]
    fn opt_and_ref_values_agree_but_ref_dispatch_is_heavier() {
        let so = spec("toy_opt", &[1, 8], Act::None);
        let sr = spec("toy_ref", &[1, 8], Act::None);
        let eo = Executable::new(&so);
        let er = Executable::new(&sr);
        let input: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        let oo = &eo.run_batch(&so, &[vec![input.as_slice()]])[0][0];
        let or = &er.run_batch(&sr, &[vec![input.as_slice()]])[0][0];
        assert_eq!(oo, or, "variants model the same network");
        assert!(er.dispatch_iters > eo.dispatch_iters);
    }

    #[test]
    fn batched_run_is_bit_identical_to_single() {
        let s = spec("toy_opt", &[1, 6], Act::Softmax);
        let exe = Executable::new(&s);
        let data: Vec<Vec<f32>> = (0..5)
            .map(|i| (0..32).map(|k| ((i * 32 + k) as f32).cos()).collect())
            .collect();
        let frames: Vec<Vec<&[f32]>> = data.iter().map(|d| vec![d.as_slice()]).collect();
        let batched = exe.run_batch(&s, &frames);
        for (i, frame) in frames.iter().enumerate() {
            let single = exe.run_batch(&s, std::slice::from_ref(frame));
            assert_eq!(batched[i], single[0]);
        }
    }
}
