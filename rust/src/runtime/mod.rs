//! Model runtime: loads AOT artifacts (HLO text) and executes them through
//! the XLA PJRT CPU client.
//!
//! This is the "NNFW delegation" layer of the paper: the pipeline never
//! computes tensors itself, it hands frames to a compiled model executable
//! — here one produced by `python/compile/aot.py` (JAX + Pallas, lowered
//! once at build time; Python is never on this path).

pub mod manifest;
pub mod single;

pub use manifest::{Manifest, ModelSpec};
pub use single::SingleShot;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::error::{Error, Result};
use crate::tensor::{Buffer, Chunk};

/// A compiled model executable plus its IO spec.
pub struct Model {
    pub spec: ModelSpec,
    exe: xla::PjRtLoadedExecutable,
}

// xla's loaded executable wraps a thread-safe PJRT client.
unsafe impl Send for Model {}
unsafe impl Sync for Model {}

impl Model {
    /// Execute on f32 input buffers; returns one output buffer per output
    /// tensor. Inputs are validated against the manifest spec.
    pub fn execute(&self, inputs: &[&Chunk]) -> Result<Vec<Chunk>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (chunk, info) in inputs.iter().zip(&self.spec.inputs) {
            if chunk.len() != info.size_bytes() {
                return Err(Error::Runtime(format!(
                    "{}: input payload {}B does not match {} ({}B)",
                    self.spec.name,
                    chunk.len(),
                    info,
                    info.size_bytes()
                )));
            }
            let vals = chunk.as_f32()?;
            let dims: Vec<i64> = info.dims.as_slice().iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(vals).reshape(&dims)?;
            literals.push(lit);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let outs = result.decompose_tuple()?;
        let mut chunks = Vec::with_capacity(outs.len());
        for (lit, info) in outs.iter().zip(&self.spec.outputs) {
            let vals: Vec<f32> = lit.to_vec()?;
            if vals.len() != info.dims.num_elements() {
                return Err(Error::Runtime(format!(
                    "{}: output has {} elements, manifest says {}",
                    self.spec.name,
                    vals.len(),
                    info.dims.num_elements()
                )));
            }
            chunks.push(Chunk::from_f32(&vals));
        }
        Ok(chunks)
    }

    /// Execute on a buffer's chunks (1 chunk per model input).
    pub fn execute_buffer(&self, buf: &Buffer) -> Result<Vec<Chunk>> {
        let refs: Vec<&Chunk> = buf.chunks.iter().collect();
        self.execute(&refs)
    }
}

/// Process-wide model registry: compiles each artifact once, shares the
/// executable across all filters (like NNStreamer sharing a model between
/// pipelines).
pub struct ModelRegistry {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Model>>>,
}

unsafe impl Send for ModelRegistry {}
unsafe impl Sync for ModelRegistry {}

static GLOBAL: Lazy<Mutex<Option<Arc<ModelRegistry>>>> = Lazy::new(|| Mutex::new(None));

impl ModelRegistry {
    /// Open an artifacts directory (reads `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Arc::new(Self {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    /// Process-wide shared registry rooted at `$NNS_ARTIFACTS` or
    /// `./artifacts`.
    pub fn global() -> Result<Arc<Self>> {
        let mut g = GLOBAL.lock().unwrap();
        if let Some(r) = g.as_ref() {
            return Ok(r.clone());
        }
        let dir = std::env::var("NNS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        let reg = Self::open(dir)?;
        *g = Some(reg.clone());
        Ok(reg)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load (compile-once, cached) a model by artifact name.
    pub fn load(&self, name: &str) -> Result<Arc<Model>> {
        if let Some(m) = self.cache.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("model {name:?} not in manifest")))?
            .clone();
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Manifest("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let model = Arc::new(Model { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), model.clone());
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::global().expect("artifacts/ must be built (make artifacts)")
    }

    #[test]
    fn loads_manifest_and_runs_i3() {
        let reg = registry();
        let model = reg.load("i3_opt").unwrap();
        assert_eq!(model.spec.inputs.len(), 1);
        let n = model.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.5f32; n]);
        let out = model.execute(&[&input]).unwrap();
        assert_eq!(out.len(), 1);
        let probs = out[0].to_f32_vec().unwrap();
        assert_eq!(probs.len(), 100);
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "softmax sums to 1, got {sum}");
    }

    #[test]
    fn opt_and_ref_variants_agree() {
        let reg = registry();
        let opt = reg.load("i3_opt").unwrap();
        let rf = reg.load("i3_ref").unwrap();
        let n = opt.spec.inputs[0].dims.num_elements();
        let data: Vec<f32> = (0..n).map(|i| ((i % 255) as f32) / 255.0).collect();
        let input = Chunk::from_f32(&data);
        let a = opt.execute(&[&input]).unwrap()[0].to_f32_vec().unwrap();
        let b = rf.execute(&[&input]).unwrap()[0].to_f32_vec().unwrap();
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "variants disagree: {max_err}");
    }

    #[test]
    fn outputs_depend_on_inputs() {
        // Regression: if artifact weights were elided in the text
        // round-trip (zeroed), outputs collapse to input-independent
        // constants. Two different inputs must produce different outputs.
        let reg = registry();
        let model = reg.load("pnet_s4_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let a: Vec<f32> = (0..n).map(|i| ((i * 37 % 251) as f32) / 251.0 - 0.5).collect();
        let b: Vec<f32> = (0..n).map(|i| ((i * 11 % 113) as f32) / 113.0 - 0.5).collect();
        let oa = model.execute(&[&Chunk::from_f32(&a)]).unwrap()[0]
            .to_f32_vec()
            .unwrap();
        let ob = model.execute(&[&Chunk::from_f32(&b)]).unwrap()[0]
            .to_f32_vec()
            .unwrap();
        let diff = oa
            .iter()
            .zip(&ob)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(diff > 1e-4, "outputs are input-independent (weights lost?)");
        // and the probability map must have spatial variation
        let spread = oa.iter().cloned().fold(f32::MIN, f32::max)
            - oa.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 1e-3, "flat output map");
    }

    #[test]
    fn rejects_wrong_input_count() {
        let reg = registry();
        let model = reg.load("i3_opt").unwrap();
        assert!(model.execute(&[]).is_err());
    }

    #[test]
    fn multi_output_model() {
        let reg = registry();
        let ssd = reg.load("ssd_opt").unwrap();
        assert_eq!(ssd.spec.outputs.len(), 2);
        let n = ssd.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        let out = ssd.execute(&[&input]).unwrap();
        assert_eq!(out.len(), 2);
    }
}
