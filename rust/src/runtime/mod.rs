//! Model runtime: loads AOT artifacts and executes them deterministically.
//!
//! This is the "NNFW delegation" layer of the paper: the pipeline never
//! computes tensors itself, it hands frames to a loaded model executable.
//! Artifacts are produced by `python/compile/aot.py` (JAX + Pallas,
//! lowered once at build time; Python is never on this path): a
//! `manifest.txt` describing every model's IO spec plus one `.hlo.txt`
//! program per model. The offline build executes models through the
//! in-crate surrogate backend (see [`exec`](self) internals and DESIGN.md
//! "Execution backends"), which needs only the manifest; the `.hlo.txt`
//! programs are carried for provenance and for PJRT-capable builds.
//!
//! Three layers share loaded models:
//!
//! * [`ModelRegistry`] — compile-once cache keyed by artifact name;
//! * [`ModelPool`] — lease-tracked sharing across pipeline branches with
//!   observable statistics (the batching/pooling subsystem's bookkeeping);
//! * [`SingleShot`] — the pipeline-less "Single API set" of the paper.

mod exec;
pub mod manifest;
pub mod pool;
pub mod single;

pub use manifest::{Act, Manifest, ModelSpec};
pub use pool::{ModelPool, PoolLease, PoolStatsSnapshot};
pub use single::{QueryService, SingleShot};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::error::{Error, Result};
use crate::tensor::{Buffer, Chunk};

/// A loaded model executable plus its IO spec.
pub struct Model {
    pub spec: ModelSpec,
    exe: exec::Executable,
}

impl Model {
    /// Execute on f32 input buffers; returns one output buffer per output
    /// tensor. Inputs are validated against the manifest spec.
    pub fn execute(&self, inputs: &[&Chunk]) -> Result<Vec<Chunk>> {
        let mut outs = self.execute_batch(&[inputs])?;
        Ok(outs.pop().expect("one frame in, one frame out"))
    }

    /// Execute several frames in **one dispatch**. `frames[i]` carries
    /// frame `i`'s input chunks; the result carries frame `i`'s outputs.
    ///
    /// The per-dispatch cost (executable launch, weight residency) is paid
    /// once for the whole batch, so batched execution of N frames is
    /// cheaper than N single dispatches, while the de-batched outputs are
    /// bit-identical to unbatched execution.
    pub fn execute_batch(&self, frames: &[&[&Chunk]]) -> Result<Vec<Vec<Chunk>>> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        // borrow, don't copy: inputs stay in their chunks on the hot path
        let mut decoded: Vec<Vec<&[f32]>> = Vec::with_capacity(frames.len());
        for inputs in frames {
            if inputs.len() != self.spec.inputs.len() {
                return Err(Error::Runtime(format!(
                    "{}: expected {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                )));
            }
            let mut vals = Vec::with_capacity(inputs.len());
            for (chunk, info) in inputs.iter().zip(&self.spec.inputs) {
                if chunk.len() != info.size_bytes() {
                    return Err(Error::Runtime(format!(
                        "{}: input payload {}B does not match {} ({}B)",
                        self.spec.name,
                        chunk.len(),
                        info,
                        info.size_bytes()
                    )));
                }
                vals.push(chunk.as_f32()?);
            }
            decoded.push(vals);
        }
        let raw = self.exe.run_batch(&self.spec, &decoded);
        // adopt each pooled output Vec<f32> as chunk storage (no copy);
        // the storage recycles into the pool's f32 classes on drop
        Ok(raw
            .into_iter()
            .map(|frame| frame.into_iter().map(Chunk::from_pooled_f32).collect())
            .collect())
    }

    /// Execute on a buffer's chunks (1 chunk per model input).
    pub fn execute_buffer(&self, buf: &Buffer) -> Result<Vec<Chunk>> {
        let refs: Vec<&Chunk> = buf.chunks.iter().collect();
        self.execute(&refs)
    }
}

/// Process-wide model registry: loads each artifact once, shares the
/// executable across all filters (like NNStreamer sharing a model between
/// pipelines).
pub struct ModelRegistry {
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Model>>>,
}

static GLOBAL: Lazy<Mutex<Option<Arc<ModelRegistry>>>> = Lazy::new(|| Mutex::new(None));

impl ModelRegistry {
    /// Open an artifacts directory (reads `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        Ok(Arc::new(Self {
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    /// Process-wide shared registry rooted at `$NNS_ARTIFACTS`, falling
    /// back to `./artifacts` then `../artifacts` (tests run with the
    /// package directory `rust/` as their working directory while the
    /// artifacts live at the repository root).
    pub fn global() -> Result<Arc<Self>> {
        let mut g = GLOBAL.lock().unwrap();
        if let Some(r) = g.as_ref() {
            return Ok(r.clone());
        }
        let dir = match std::env::var("NNS_ARTIFACTS") {
            Ok(d) => d,
            Err(_) => {
                if Path::new("../artifacts/manifest.txt").exists()
                    && !Path::new("artifacts/manifest.txt").exists()
                {
                    "../artifacts".to_string()
                } else {
                    "artifacts".to_string()
                }
            }
        };
        let reg = Self::open(dir)?;
        *g = Some(reg.clone());
        Ok(reg)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load (once, cached) a model by artifact name.
    pub fn load(&self, name: &str) -> Result<Arc<Model>> {
        if let Some(m) = self.cache.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("model {name:?} not in manifest")))?
            .clone();
        // The compiled program is carried next to the manifest; the
        // surrogate backend synthesizes the executable from the spec
        // alone, so a missing .hlo.txt is not an error here.
        let _artifact = self.dir.join(format!("{name}.hlo.txt"));
        let exe = exec::Executable::new(&spec);
        let model = Arc::new(Model { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), model.clone());
        Ok(model)
    }

    /// Drop a cached executable (the pool's idle eviction calls this; any
    /// live `Arc<Model>` handles keep working until dropped).
    pub fn evict(&self, name: &str) -> bool {
        self.cache.lock().unwrap().remove(name).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Arc<ModelRegistry> {
        ModelRegistry::global().expect("artifacts/manifest.txt must exist")
    }

    #[test]
    fn loads_manifest_and_runs_i3() {
        let reg = registry();
        let model = reg.load("i3_opt").unwrap();
        assert_eq!(model.spec.inputs.len(), 1);
        let n = model.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.5f32; n]);
        let out = model.execute(&[&input]).unwrap();
        assert_eq!(out.len(), 1);
        let probs = out[0].to_f32_vec().unwrap();
        assert_eq!(probs.len(), 100);
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "softmax sums to 1, got {sum}");
    }

    #[test]
    fn opt_and_ref_variants_agree() {
        let reg = registry();
        let opt = reg.load("i3_opt").unwrap();
        let rf = reg.load("i3_ref").unwrap();
        let n = opt.spec.inputs[0].dims.num_elements();
        let data: Vec<f32> = (0..n).map(|i| ((i % 255) as f32) / 255.0).collect();
        let input = Chunk::from_f32(&data);
        let a = opt.execute(&[&input]).unwrap()[0].to_f32_vec().unwrap();
        let b = rf.execute(&[&input]).unwrap()[0].to_f32_vec().unwrap();
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "variants disagree: {max_err}");
    }

    #[test]
    fn outputs_depend_on_inputs() {
        // Regression: if execution ignored input payloads, outputs would
        // collapse to input-independent constants. Two different inputs
        // must produce different outputs.
        let reg = registry();
        let model = reg.load("pnet_s4_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let a: Vec<f32> = (0..n).map(|i| ((i * 37 % 251) as f32) / 251.0 - 0.5).collect();
        let b: Vec<f32> = (0..n).map(|i| ((i * 11 % 113) as f32) / 113.0 - 0.5).collect();
        let oa = model.execute(&[&Chunk::from_f32(&a)]).unwrap()[0]
            .to_f32_vec()
            .unwrap();
        let ob = model.execute(&[&Chunk::from_f32(&b)]).unwrap()[0]
            .to_f32_vec()
            .unwrap();
        let diff = oa
            .iter()
            .zip(&ob)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(diff > 1e-4, "outputs are input-independent (weights lost?)");
        // and the probability map must have spatial variation
        let spread = oa.iter().cloned().fold(f32::MIN, f32::max)
            - oa.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 1e-3, "flat output map");
    }

    #[test]
    fn rejects_wrong_input_count() {
        let reg = registry();
        let model = reg.load("i3_opt").unwrap();
        assert!(model.execute(&[]).is_err());
    }

    #[test]
    fn multi_output_model() {
        let reg = registry();
        let ssd = reg.load("ssd_opt").unwrap();
        assert_eq!(ssd.spec.outputs.len(), 2);
        let n = ssd.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        let out = ssd.execute(&[&input]).unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn batched_execute_matches_single_bitwise() {
        let reg = registry();
        let model = reg.load("ars_a_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let frames: Vec<Chunk> = (0..4)
            .map(|f| {
                Chunk::from_f32(
                    &(0..n)
                        .map(|i| ((i + f * 131) % 97) as f32 / 97.0)
                        .collect::<Vec<f32>>(),
                )
            })
            .collect();
        let frame_refs: Vec<Vec<&Chunk>> = frames.iter().map(|c| vec![c]).collect();
        let slices: Vec<&[&Chunk]> = frame_refs.iter().map(|v| v.as_slice()).collect();
        let batched = model.execute_batch(&slices).unwrap();
        assert_eq!(batched.len(), 4);
        for (i, frame) in frames.iter().enumerate() {
            let single = model.execute(&[frame]).unwrap();
            let a = batched[i][0].to_f32_vec().unwrap();
            let b = single[0].to_f32_vec().unwrap();
            assert_eq!(a, b, "frame {i} differs between batched and single");
        }
    }
}
