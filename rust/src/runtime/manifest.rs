//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes one line per model:
//! ```text
//! name\tin=float32:1x64x64x3[;...]\tout=float32:1x100[;...]\tflops=N\tact=softmax[;none...]
//! ```
//! (Line-based on purpose: the offline vendor set has no JSON crate, and a
//! TSV manifest diffs nicely in review.)
//!
//! The optional `act=` field records the final activation of each output
//! head (`none` when absent). Compiled HLO artifacts embed the activation
//! in the program itself; the surrogate execution backend (see
//! `runtime::exec`) uses the hint to reproduce head semantics — e.g. that
//! a classifier output is a probability distribution.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::{DType, Dims, TensorInfo};

/// Final activation of one model output head (manifest `act=` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Raw values (regression heads, logit maps, ...).
    None,
    /// Probability distribution over the output's last (minor) axis.
    Softmax,
}

impl Act {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim() {
            "none" | "" => Act::None,
            "softmax" => Act::Softmax,
            other => {
                return Err(Error::Manifest(format!("unknown activation {other:?}")))
            }
        })
    }
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub inputs: Vec<TensorInfo>,
    pub outputs: Vec<TensorInfo>,
    pub flops: u64,
    /// Per-output head activation, aligned with `outputs` (padded with
    /// [`Act::None`] when the manifest has no `act=` field).
    pub acts: Vec<Act>,
}

#[derive(Debug, Default)]
pub struct Manifest {
    models: HashMap<String, ModelSpec>,
}

fn parse_tensor_list(s: &str) -> Result<Vec<TensorInfo>> {
    s.split(';')
        .map(|spec| {
            let (dtype, dims) = spec
                .split_once(':')
                .ok_or_else(|| Error::Manifest(format!("bad tensor spec {spec:?}")))?;
            let dims: Vec<usize> = dims
                .split('x')
                .map(|d| {
                    d.parse()
                        .map_err(|_| Error::Manifest(format!("bad dim {d:?} in {spec:?}")))
                })
                .collect::<Result<_>>()?;
            Ok(TensorInfo::new(DType::parse(dtype)?, Dims::new(&dims)))
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut models = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut inputs = None;
            let mut outputs = None;
            let mut flops = 0u64;
            let mut acts: Vec<Act> = Vec::new();
            for (i, field) in line.split('\t').enumerate() {
                if i == 0 {
                    name = Some(field.to_string());
                } else if let Some(v) = field.strip_prefix("in=") {
                    inputs = Some(parse_tensor_list(v)?);
                } else if let Some(v) = field.strip_prefix("out=") {
                    outputs = Some(parse_tensor_list(v)?);
                } else if let Some(v) = field.strip_prefix("flops=") {
                    flops = v.parse().unwrap_or(0);
                } else if let Some(v) = field.strip_prefix("act=") {
                    acts = v.split(';').map(Act::parse).collect::<Result<_>>()?;
                }
            }
            let outputs: Vec<TensorInfo> = outputs
                .ok_or_else(|| Error::Manifest(format!("line {}: no out=", lineno + 1)))?;
            // act= absent means all heads default to None; when present it
            // must name every head (partial lists would silently shift
            // semantics between heads)
            if !acts.is_empty() && acts.len() != outputs.len() {
                return Err(Error::Manifest(format!(
                    "line {}: {} act entries for {} outputs",
                    lineno + 1,
                    acts.len(),
                    outputs.len()
                )));
            }
            acts.resize(outputs.len(), Act::None);
            let spec = ModelSpec {
                name: name
                    .ok_or_else(|| Error::Manifest(format!("line {}: no name", lineno + 1)))?,
                inputs: inputs
                    .ok_or_else(|| Error::Manifest(format!("line {}: no in=", lineno + 1)))?,
                outputs,
                flops,
                acts,
            };
            models.insert(spec.name.clone(), spec);
        }
        Ok(Self { models })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.as_ref().display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ModelSpec> {
        self.models.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let m = Manifest::parse(
            "i3_opt\tin=float32:1x64x64x3\tout=float32:1x100\tflops=12345\n\
             ssd_opt\tin=float32:1x96x96x3\tout=float32:1x360x4;float32:1x360x11\tflops=0\n\
             # comment\n",
        )
        .unwrap();
        assert_eq!(m.len(), 2);
        let i3 = m.get("i3_opt").unwrap();
        assert_eq!(i3.inputs[0].dims.as_slice(), &[1, 64, 64, 3]);
        assert_eq!(i3.flops, 12345);
        let ssd = m.get("ssd_opt").unwrap();
        assert_eq!(ssd.outputs.len(), 2);
        assert_eq!(ssd.outputs[1].dims.as_slice(), &[1, 360, 11]);
        // no act= field: every head defaults to Act::None
        assert_eq!(ssd.acts, vec![Act::None, Act::None]);
    }

    #[test]
    fn parses_act_field() {
        let m = Manifest::parse(
            "rnet\tin=float32:16x24x24x3\tout=float32:16x2;float32:16x4\tflops=1\tact=softmax;none\n",
        )
        .unwrap();
        let r = m.get("rnet").unwrap();
        assert_eq!(r.acts, vec![Act::Softmax, Act::None]);
    }

    #[test]
    fn rejects_unknown_act() {
        assert!(
            Manifest::parse("x\tin=float32:1\tout=float32:1\tact=relu6\n").is_err()
        );
    }

    #[test]
    fn rejects_act_output_count_mismatch() {
        assert!(
            Manifest::parse("x\tin=float32:1\tout=float32:1\tact=none;softmax\n")
                .is_err()
        );
        // too few entries is just as wrong as too many
        assert!(Manifest::parse(
            "x\tin=float32:1\tout=float32:1;float32:2\tact=softmax\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name_only\n").is_err());
        assert!(Manifest::parse("x\tin=float32:ZxZ\tout=float32:1\n").is_err());
    }
}
