//! "Single API set" analog (§III): run one model without building a
//! pipeline — the unified Tensor-Filter interface NNStreamer exposes to
//! Tizen (C/.NET) and Android (Java) applications.

use std::sync::Arc;

use crate::error::Result;
use crate::runtime::{Model, ModelRegistry};
use crate::tensor::{Chunk, TensorInfo};

/// One-shot model invocation handle.
pub struct SingleShot {
    model: Arc<Model>,
}

impl SingleShot {
    /// Open a model by artifact name from the global registry.
    pub fn open(name: &str) -> Result<Self> {
        let reg = ModelRegistry::global()?;
        Ok(Self {
            model: reg.load(name)?,
        })
    }

    /// Open from a specific registry (tests, multi-directory setups).
    pub fn open_in(reg: &ModelRegistry, name: &str) -> Result<Self> {
        Ok(Self {
            model: reg.load(name)?,
        })
    }

    pub fn input_info(&self) -> &[TensorInfo] {
        &self.model.spec.inputs
    }

    pub fn output_info(&self) -> &[TensorInfo] {
        &self.model.spec.outputs
    }

    /// Invoke the model on raw f32 tensors (one slice per model input).
    ///
    /// ```no_run
    /// use nnstreamer::runtime::SingleShot;
    ///
    /// # fn main() -> nnstreamer::Result<()> {
    /// let s = SingleShot::open("ars_a_opt")?;
    /// let window = vec![0.25f32; 128 * 3]; // one accelerometer window
    /// let out = s.invoke(&[&window])?;
    /// println!("activity probabilities: {:?}", out[0]);
    /// # Ok(())
    /// # }
    /// ```
    pub fn invoke(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let chunks: Vec<Chunk> = inputs.iter().map(|d| Chunk::from_f32(d)).collect();
        let refs: Vec<&Chunk> = chunks.iter().collect();
        let outs = self.model.execute(&refs)?;
        outs.iter().map(|c| c.to_f32_vec()).collect()
    }

    /// Invoke a **single-input** model on several frames in one dispatch
    /// (see [`Model::execute_batch`]); returns per-frame output lists.
    /// De-batched results are bit-identical to per-frame [`invoke`] calls.
    ///
    /// [`invoke`]: SingleShot::invoke
    pub fn invoke_batch(&self, frames: &[&[f32]]) -> Result<Vec<Vec<Vec<f32>>>> {
        let chunks: Vec<Chunk> = frames.iter().map(|d| Chunk::from_f32(d)).collect();
        let frame_refs: Vec<Vec<&Chunk>> = chunks.iter().map(|c| vec![c]).collect();
        let slices: Vec<&[&Chunk]> = frame_refs.iter().map(|v| v.as_slice()).collect();
        let outs = self.model.execute_batch(&slices)?;
        outs.into_iter()
            .map(|frame| frame.iter().map(|c| c.to_f32_vec()).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shot_runs_ars_model() {
        let s = SingleShot::open("ars_a_opt").expect("artifacts present");
        assert_eq!(s.input_info()[0].dims.as_slice(), &[1, 128, 3]);
        let input = vec![0.25f32; 128 * 3];
        let out = s.invoke(&[&input]).unwrap();
        assert_eq!(out[0].len(), 8);
        let sum: f32 = out[0].iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
    }

    #[test]
    fn invoke_batch_matches_invoke() {
        let s = SingleShot::open("ars_a_opt").expect("artifacts present");
        let frames: Vec<Vec<f32>> = (0..3)
            .map(|f| (0..128 * 3).map(|i| ((i + f * 7) % 13) as f32 / 13.0).collect())
            .collect();
        let refs: Vec<&[f32]> = frames.iter().map(|v| v.as_slice()).collect();
        let batched = s.invoke_batch(&refs).unwrap();
        for (i, frame) in refs.iter().enumerate() {
            let single = s.invoke(&[frame]).unwrap();
            assert_eq!(batched[i], single);
        }
    }
}
