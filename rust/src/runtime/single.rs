//! "Single API set" analog (§III): run one model without writing a
//! pipeline — the unified Tensor-Filter interface NNStreamer exposes to
//! Tizen (C/.NET) and Android (Java) applications.
//!
//! Since the typed-API redesign, [`SingleShot::open`] is itself expressed
//! over the [`PipelineBuilder`]: it assembles a three-element
//! `appsrc ! tensor_filter ! appsink` pipeline (typed props, no strings),
//! keeps it playing, and [`invoke`](SingleShot::invoke) becomes a
//! push/recv round trip. On the pooled executor an idle handle costs no
//! thread at all — all three element tasks park between invocations, so
//! applications can hold hundreds of open handles. The model executes through the same pooled
//! `tensor_filter` path as any other pipeline, so branches, SingleShot
//! handles, and benches all share one loaded instance per artifact.
//! The filter is configured with `batch=MAX_BATCH latency-budget=0`, so
//! back-to-back [`invoke_batch`](SingleShot::invoke_batch) frames that
//! queue up are executed as stacked single dispatches — outputs stay
//! bit-identical to per-frame invocation.

use std::sync::{Arc, Mutex};

use crate::elements::filter::{Framework, TensorFilterProps, MAX_BATCH};
use crate::elements::query::{QueryServerSinkProps, QueryServerSrcProps};
use crate::elements::sinks::{AppSinkProps, AppSinkReceiver};
use crate::elements::sources::{AppSrcHandle, AppSrcProps};
use crate::error::{Error, Result};
use crate::pipeline::{PipelineBuilder, Running};
use crate::runtime::{Model, ModelRegistry};
use crate::tensor::{Buffer, Caps, Chunk, TensorInfo};

/// Caps matching a model's input spec (single tensor or tensor list).
fn input_caps(inputs: &[TensorInfo]) -> Caps {
    if inputs.len() == 1 {
        Caps::Tensor {
            info: inputs[0].clone(),
            fps_millis: 0,
        }
    } else {
        Caps::Tensors {
            infos: inputs.to_vec(),
            fps_millis: 0,
        }
    }
}

enum Engine {
    /// A playing `appsrc ! tensor_filter ! appsink` pipeline.
    Pipeline {
        push: AppSrcHandle,
        frames: AppSinkReceiver,
        running: Mutex<Option<Running>>,
    },
    /// Direct execution against a caller-supplied registry
    /// ([`SingleShot::open_in`] — multi-directory setups bypass the
    /// global pool).
    Direct { model: Arc<Model> },
}

/// One-shot model invocation handle.
pub struct SingleShot {
    name: String,
    engine: Engine,
    inputs: Vec<TensorInfo>,
    outputs: Vec<TensorInfo>,
}

impl SingleShot {
    /// Open a model by artifact name from the global registry, backed by
    /// a playing builder pipeline.
    pub fn open(name: &str) -> Result<Self> {
        let reg = ModelRegistry::global()?;
        let spec = reg.load(name)?.spec.clone();
        let caps = input_caps(&spec.inputs);

        let mut b = PipelineBuilder::new();
        b.chain_named("in", AppSrcProps { caps })?
            .chain_named(
                "model",
                TensorFilterProps {
                    framework: Framework::Xla,
                    model: name.to_string(),
                    batch: MAX_BATCH,
                    ..Default::default()
                },
            )?
            .chain_named("out", AppSinkProps::default())?;
        let mut pipeline = b.build();
        let push = pipeline.appsrc("in")?;
        let frames = pipeline.appsink("out")?;
        let running = pipeline.play()?;

        Ok(Self {
            name: name.to_string(),
            engine: Engine::Pipeline {
                push,
                frames,
                running: Mutex::new(Some(running)),
            },
            inputs: spec.inputs,
            outputs: spec.outputs,
        })
    }

    /// Open from a specific registry (tests, multi-directory setups);
    /// executes the model directly, outside the pipeline/pool path.
    pub fn open_in(reg: &ModelRegistry, name: &str) -> Result<Self> {
        let model = reg.load(name)?;
        Ok(Self {
            name: name.to_string(),
            inputs: model.spec.inputs.clone(),
            outputs: model.spec.outputs.clone(),
            engine: Engine::Direct { model },
        })
    }

    pub fn input_info(&self) -> &[TensorInfo] {
        &self.inputs
    }

    pub fn output_info(&self) -> &[TensorInfo] {
        &self.outputs
    }

    /// The real failure behind a dead pipeline, if it can still be
    /// collected.
    fn pipeline_failure(&self) -> Error {
        if let Engine::Pipeline { running, .. } = &self.engine {
            let taken = running.lock().unwrap_or_else(|e| e.into_inner()).take();
            if let Some(r) = taken {
                if let Err(e) = r.wait() {
                    return e;
                }
            }
        }
        Error::Runtime(format!("single-shot pipeline for {:?} terminated", self.name))
    }

    /// Invoke the model on raw f32 tensors (one slice per model input).
    ///
    /// ```no_run
    /// use nnstreamer::runtime::SingleShot;
    ///
    /// # fn main() -> nnstreamer::Result<()> {
    /// let s = SingleShot::open("ars_a_opt")?;
    /// let window = vec![0.25f32; 128 * 3]; // one accelerometer window
    /// let out = s.invoke(&[&window])?;
    /// println!("activity probabilities: {:?}", out[0]);
    /// # Ok(())
    /// # }
    /// ```
    pub fn invoke(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let chunks: Vec<Chunk> = inputs.iter().map(|d| Chunk::from_f32(d)).collect();
        match &self.engine {
            Engine::Pipeline { push, frames, .. } => {
                push.push(Buffer::new(0, chunks))
                    .map_err(|_| self.pipeline_failure())?;
                let out = frames.recv().map_err(|_| self.pipeline_failure())?;
                out.chunks.iter().map(|c| c.to_f32_vec()).collect()
            }
            Engine::Direct { model } => {
                let refs: Vec<&Chunk> = chunks.iter().collect();
                let outs = model.execute(&refs)?;
                outs.iter().map(|c| c.to_f32_vec()).collect()
            }
        }
    }

    /// Invoke a **single-input** model on several frames; queued frames
    /// are stacked into single dispatches by the underlying batching
    /// filter. Returns per-frame output lists, bit-identical to per-frame
    /// [`invoke`] calls. Pushes and result reads are interleaved with a
    /// bounded in-flight window, so any frame count stays within the
    /// pipeline's buffering.
    ///
    /// [`invoke`]: SingleShot::invoke
    pub fn invoke_batch(&self, frames: &[&[f32]]) -> Result<Vec<Vec<Vec<f32>>>> {
        match &self.engine {
            Engine::Pipeline {
                push,
                frames: out_rx,
                ..
            } => {
                // keep at most one filter-batch of frames in flight —
                // well inside the pipeline's channel buffering, large
                // enough that the filter can stack full batches
                const IN_FLIGHT: usize = MAX_BATCH;
                let mut outs = Vec::with_capacity(frames.len());
                let mut pushed = 0usize;
                while outs.len() < frames.len() {
                    while pushed < frames.len() && pushed - outs.len() < IN_FLIGHT {
                        let buf = Buffer::new(
                            pushed as u64,
                            vec![Chunk::from_f32(frames[pushed])],
                        );
                        push.push(buf).map_err(|_| self.pipeline_failure())?;
                        pushed += 1;
                    }
                    let out = out_rx.recv().map_err(|_| self.pipeline_failure())?;
                    outs.push(
                        out.chunks
                            .iter()
                            .map(|c| c.to_f32_vec())
                            .collect::<Result<Vec<_>>>()?,
                    );
                }
                Ok(outs)
            }
            Engine::Direct { model } => {
                let chunks: Vec<Chunk> =
                    frames.iter().map(|d| Chunk::from_f32(d)).collect();
                let frame_refs: Vec<Vec<&Chunk>> =
                    chunks.iter().map(|c| vec![c]).collect();
                let slices: Vec<&[&Chunk]> =
                    frame_refs.iter().map(|v| v.as_slice()).collect();
                let outs = model.execute_batch(&slices)?;
                outs.into_iter()
                    .map(|frame| frame.iter().map(|c| c.to_f32_vec()).collect())
                    .collect()
            }
        }
    }
}

impl Drop for SingleShot {
    fn drop(&mut self) {
        if let Engine::Pipeline { push, running, .. } = &self.engine {
            push.end();
            let taken = running.lock().unwrap_or_else(|e| e.into_inner()).take();
            if let Some(r) = taken {
                let _ = r.wait();
            }
        }
    }
}

/// A model served as a stream-query service — the "SingleShot over a
/// remote pipeline" side of the among-device API. [`QueryService::serve`]
/// keeps a `tensor_query_serversrc ! tensor_filter ! tensor_query_serversink`
/// pipeline playing on topics `<topic>/in` → `<topic>/out`; any number
/// of *other* pipelines (via the `tensor_query_client` element) or
/// applications (via
/// [`QueryClient::connect`](crate::pipeline::QueryClient::connect)) can
/// then invoke the model
/// without loading it themselves — on another "device", they only need
/// the topic name. Like an idle [`SingleShot`], an idle service costs no
/// thread: all three element tasks park between requests.
///
/// ```no_run
/// use nnstreamer::pipeline::QueryClient;
/// use nnstreamer::runtime::QueryService;
///
/// # fn main() -> nnstreamer::Result<()> {
/// let service = QueryService::serve("ars_a_opt", "svc/ars")?;
/// let client = QueryClient::connect("svc/ars");
/// let window = vec![0.25f32; 128 * 3];
/// let out = client.invoke_f32(&[&window])?;
/// println!("activity probabilities: {:?}", out[0]);
/// service.stop()?;
/// # Ok(())
/// # }
/// ```
pub struct QueryService {
    topic: String,
    running: Mutex<Option<Running>>,
}

impl QueryService {
    /// Build and play the serving pipeline for `model` on topics
    /// `<topic>/in` → `<topic>/out`. The filter is configured exactly
    /// like [`SingleShot::open`]'s (`batch=MAX_BATCH latency-budget=0`),
    /// so queued concurrent requests stack into single dispatches with
    /// bit-identical per-frame results.
    pub fn serve(model: &str, topic: &str) -> Result<QueryService> {
        let reg = ModelRegistry::global()?;
        let spec = reg.load(model)?.spec.clone();
        let mut b = PipelineBuilder::new();
        b.chain_named(
            "in",
            QueryServerSrcProps {
                topic: format!("{topic}/in"),
                caps: input_caps(&spec.inputs),
                ..Default::default()
            },
        )?
        .chain_named(
            "model",
            TensorFilterProps {
                framework: Framework::Xla,
                model: model.to_string(),
                batch: MAX_BATCH,
                ..Default::default()
            },
        )?
        .chain_named(
            "out",
            QueryServerSinkProps {
                topic: format!("{topic}/out"),
                ..Default::default()
            },
        )?;
        let mut pipeline = b.build();
        let running = pipeline.play()?;
        Ok(QueryService {
            topic: topic.to_string(),
            running: Mutex::new(Some(running)),
        })
    }

    /// The topic prefix this service answers on.
    pub fn topic(&self) -> &str {
        &self.topic
    }

    /// Is the serving pipeline still running?
    pub fn is_running(&self) -> bool {
        self.running
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .is_some_and(|r| !r.is_done())
    }

    /// Stop the service and join its pipeline (outstanding requests on
    /// the reply topic observe end-of-stream).
    pub fn stop(self) -> Result<()> {
        self.shutdown()
    }

    fn shutdown(&self) -> Result<()> {
        let taken = self
            .running
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(r) = taken {
            r.request_stop();
            r.wait()?;
        }
        Ok(())
    }
}

impl Drop for QueryService {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shot_runs_ars_model() {
        let s = SingleShot::open("ars_a_opt").expect("artifacts present");
        assert_eq!(s.input_info()[0].dims.as_slice(), &[1, 128, 3]);
        let input = vec![0.25f32; 128 * 3];
        let out = s.invoke(&[&input]).unwrap();
        assert_eq!(out[0].len(), 8);
        let sum: f32 = out[0].iter().sum();
        assert!((sum - 1.0).abs() < 1e-3);
    }

    #[test]
    fn invoke_batch_matches_invoke() {
        let s = SingleShot::open("ars_a_opt").expect("artifacts present");
        let frames: Vec<Vec<f32>> = (0..3)
            .map(|f| (0..128 * 3).map(|i| ((i + f * 7) % 13) as f32 / 13.0).collect())
            .collect();
        let refs: Vec<&[f32]> = frames.iter().map(|v| v.as_slice()).collect();
        let batched = s.invoke_batch(&refs).unwrap();
        for (i, frame) in refs.iter().enumerate() {
            let single = s.invoke(&[frame]).unwrap();
            assert_eq!(batched[i], single);
        }
    }

    #[test]
    fn open_in_uses_the_given_registry() {
        let reg = ModelRegistry::global().expect("artifacts present");
        let s = SingleShot::open_in(&reg, "ars_a_opt").unwrap();
        let input = vec![0.1f32; 128 * 3];
        let out = s.invoke(&[&input]).unwrap();
        assert_eq!(out[0].len(), 8);
    }

    #[test]
    fn query_service_agrees_with_single_shot_bitwise() {
        use crate::pipeline::QueryClient;

        let service =
            QueryService::serve("ars_a_opt", "unit/single-qs").expect("artifacts present");
        assert!(service.is_running());
        let client = QueryClient::connect("unit/single-qs");
        let local = SingleShot::open("ars_a_opt").unwrap();
        let input: Vec<f32> = (0..128 * 3).map(|i| (i % 31) as f32 / 31.0).collect();
        let remote_out = client.invoke_f32(&[&input]).unwrap();
        let local_out = local.invoke(&[&input]).unwrap();
        assert_eq!(remote_out, local_out, "remote pipeline path is bit-identical");
        service.stop().unwrap();
    }

    #[test]
    fn query_client_without_service_fails_fast() {
        use crate::pipeline::QueryClient;

        let client = QueryClient::connect("unit/single-no-service");
        let err = client.invoke_f32(&[&[0.0f32; 4]]).unwrap_err().to_string();
        assert!(err.contains("no pipeline is serving"), "{err}");
    }

    #[test]
    fn pipeline_and_direct_paths_agree_bitwise() {
        let reg = ModelRegistry::global().expect("artifacts present");
        let piped = SingleShot::open("ars_a_opt").unwrap();
        let direct = SingleShot::open_in(&reg, "ars_a_opt").unwrap();
        let input: Vec<f32> = (0..128 * 3).map(|i| (i % 97) as f32 / 97.0).collect();
        assert_eq!(
            piped.invoke(&[&input]).unwrap(),
            direct.invoke(&[&input]).unwrap()
        );
    }
}
