//! Simulated NPU: one hardware queue, one service thread, modeled timing.
//!
//! E1's headline is that NNStreamer runs multiple models on one NPU "with
//! virtually no overheads": the NPU is a serial device, so two models
//! sharing it time-slice its queue. This simulator reproduces exactly that
//! contention structure:
//!
//! * all submissions funnel through a single FIFO queue;
//! * one dedicated service thread executes them in order;
//! * callers block on a completion signal (like a driver ioctl);
//! * **service time is modeled**: the real PJRT execution produces the
//!   output values, and the service thread then pads the job to
//!   `max(real_time, flops / npu_rate)`. The pad is a *sleep*, so host CPU
//!   stays free — which is exactly the property that makes an NPU an NPU
//!   (and what lets pipeline parallelism show up even on a 1-core host:
//!   while the simulated NPU "computes", CPU elements keep streaming).
//!
//! Queue time vs service time are tracked separately; service time is
//! charged to the NPU domain, not the submitting element's CPU.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use once_cell::sync::Lazy;

use crate::error::{Error, Result};
use crate::runtime::Model;
use crate::tensor::Chunk;

type Job = (
    Arc<Model>,
    Vec<Chunk>,
    Sender<Result<Vec<Chunk>>>,
    Instant,
);

/// Aggregate NPU counters.
#[derive(Debug, Default)]
pub struct NpuStats {
    jobs: AtomicU64,
    queue_ns: AtomicU64,
    service_ns: AtomicU64,
    real_compute_ns: AtomicU64,
}

impl NpuStats {
    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    pub fn mean_queue(&self) -> Duration {
        let n = self.jobs().max(1);
        Duration::from_nanos(self.queue_ns.load(Ordering::Relaxed) / n)
    }

    pub fn mean_service(&self) -> Duration {
        let n = self.jobs().max(1);
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed) / n)
    }

    pub fn total_service(&self) -> Duration {
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed))
    }

    /// Host-CPU time actually burned by the service thread (the real PJRT
    /// execution inside the modeled envelope).
    pub fn total_real_compute(&self) -> Duration {
        Duration::from_nanos(self.real_compute_ns.load(Ordering::Relaxed))
    }

    /// NPU utilization over a wall-clock window.
    pub fn utilization(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.total_service().as_secs_f64() / wall.as_secs_f64()
    }

    /// Snapshot for before/after deltas in benches.
    pub fn snapshot(&self) -> (u64, Duration, Duration) {
        (
            self.jobs(),
            self.total_service(),
            self.total_real_compute(),
        )
    }
}

/// The simulated NPU device.
pub struct NpuSim {
    tx: Mutex<Sender<Job>>,
    pub stats: Arc<NpuStats>,
    shared: Arc<SharedTiming>,
}

/// Timing model shared with the service thread.
#[derive(Default)]
struct SharedTiming {
    /// Modeled throughput in FLOPs/s (service time = flops / rate).
    rate_flops: AtomicU64,
    /// Per-model service-time overrides (ns), keyed by artifact name.
    overrides: Mutex<HashMap<String, u64>>,
}

static GLOBAL_NPU: Lazy<NpuSim> = Lazy::new(NpuSim::spawn);

/// Default modeled NPU throughput (FLOPs/s). Calibrated so the small-model
/// zoo lands in the paper's fps regime (I3 ≈ 30 fps on the NPU).
pub const DEFAULT_NPU_FLOPS: u64 = 400_000_000;

impl NpuSim {
    /// The process-wide NPU instance (one accelerator per device, as on
    /// the A311D).
    pub fn global() -> &'static NpuSim {
        &GLOBAL_NPU
    }

    fn spawn() -> NpuSim {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = std::sync::mpsc::channel();
        let stats = Arc::new(NpuStats::default());
        let shared = Arc::new(SharedTiming::default());
        shared
            .rate_flops
            .store(DEFAULT_NPU_FLOPS, Ordering::Relaxed);
        let thread_stats = stats.clone();
        let thread_shared = shared.clone();
        std::thread::Builder::new()
            .name("npu-sim".into())
            .spawn(move || {
                while let Ok((model, inputs, done, submitted)) = rx.recv() {
                    let start = Instant::now();
                    thread_stats.queue_ns.fetch_add(
                        start.duration_since(submitted).as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    let refs: Vec<&Chunk> = inputs.iter().collect();
                    let result = model.execute(&refs);
                    let real = start.elapsed();
                    thread_stats
                        .real_compute_ns
                        .fetch_add(real.as_nanos() as u64, Ordering::Relaxed);
                    // modeled service envelope
                    let target = thread_shared.service_time(&model);
                    if target > real {
                        std::thread::sleep(target - real);
                    }
                    thread_stats
                        .service_ns
                        .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    thread_stats.jobs.fetch_add(1, Ordering::Relaxed);
                    let _ = done.send(result);
                }
            })
            .expect("spawn npu-sim");
        NpuSim {
            tx: Mutex::new(tx),
            stats,
            shared,
        }
    }

    /// Set the modeled NPU throughput (FLOPs/s).
    pub fn set_rate_flops(&self, rate: u64) {
        self.shared.rate_flops.store(rate, Ordering::Relaxed);
    }

    /// Override the modeled service time for one artifact.
    pub fn set_service_override(&self, model: &str, service: Duration) {
        self.shared
            .overrides
            .lock()
            .unwrap()
            .insert(model.to_string(), service.as_nanos() as u64);
    }

    /// Clear all overrides (benches reset between tables).
    pub fn clear_service_overrides(&self) {
        self.shared.overrides.lock().unwrap().clear();
    }

    /// Submit a job and block until the NPU completes it.
    pub fn submit(&self, model: Arc<Model>, inputs: Vec<Chunk>) -> Result<Vec<Chunk>> {
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send((model, inputs, done_tx, Instant::now()))
            .map_err(|_| Error::Runtime("NPU service thread gone".into()))?;
        done_rx
            .recv()
            .map_err(|_| Error::Runtime("NPU dropped job".into()))?
    }
}

impl SharedTiming {
    fn service_time(&self, model: &Model) -> Duration {
        if let Some(&ns) = self.overrides.lock().unwrap().get(&model.spec.name) {
            return Duration::from_nanos(ns);
        }
        let rate = self.rate_flops.load(Ordering::Relaxed).max(1);
        Duration::from_secs_f64(model.spec.flops as f64 / rate as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelRegistry;

    #[test]
    fn npu_computes_and_counts() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let npu = NpuSim::global();
        let before = npu.stats.jobs();
        let n = model.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        let out = npu.submit(model.clone(), vec![input]).unwrap();
        assert_eq!(out[0].to_f32_vec().unwrap().len(), 8);
        assert_eq!(npu.stats.jobs(), before + 1);
        assert!(npu.stats.mean_service() > Duration::ZERO);
    }

    #[test]
    fn service_override_paces_jobs() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_c_opt").unwrap();
        let npu = NpuSim::global();
        npu.set_service_override("ars_c_opt", Duration::from_millis(30));
        let n = model.spec.inputs[0].dims.num_elements();
        let t0 = Instant::now();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        npu.submit(model.clone(), vec![input]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(29));
        npu.clear_service_overrides();
    }

    #[test]
    fn npu_handles_concurrent_submitters() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = model.clone();
                std::thread::spawn(move || {
                    let input = Chunk::from_f32(&vec![0.2f32; n]);
                    NpuSim::global().submit(m, vec![input]).unwrap()
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.len(), 1);
        }
    }
}
