//! Simulated NPU: one hardware queue, one service thread, modeled timing.
//!
//! E1's headline is that NNStreamer runs multiple models on one NPU "with
//! virtually no overheads": the NPU is a serial device, so two models
//! sharing it time-slice its queue. This simulator reproduces exactly that
//! contention structure:
//!
//! * all submissions funnel through a single FIFO queue;
//! * one dedicated service thread executes them in order;
//! * callers block on a completion signal (like a driver ioctl);
//! * **service time is modeled**: the real PJRT execution produces the
//!   output values, and the service thread then pads the job to
//!   `max(real_time, flops / npu_rate)`. The pad is a *sleep*, so host CPU
//!   stays free — which is exactly the property that makes an NPU an NPU
//!   (and what lets pipeline parallelism show up even on a 1-core host:
//!   while the simulated NPU "computes", CPU elements keep streaming).
//!
//! Queue time vs service time are tracked separately; service time is
//! charged to the NPU domain, not the submitting element's CPU.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use once_cell::sync::Lazy;

use crate::error::{Error, Result};
use crate::runtime::Model;
use crate::tensor::Chunk;

/// One queued submission: a batch of frames for one model. A single-frame
/// invocation is a batch of one.
type Job = (
    Arc<Model>,
    Vec<Vec<Chunk>>,
    Sender<Result<Vec<Vec<Chunk>>>>,
    Instant,
);

/// Aggregate NPU counters.
#[derive(Debug, Default)]
pub struct NpuStats {
    jobs: AtomicU64,
    frames: AtomicU64,
    queue_ns: AtomicU64,
    service_ns: AtomicU64,
    real_compute_ns: AtomicU64,
}

impl NpuStats {
    /// Completed submissions (a batch counts once).
    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Completed frames across all submissions.
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    pub fn mean_queue(&self) -> Duration {
        let n = self.jobs().max(1);
        Duration::from_nanos(self.queue_ns.load(Ordering::Relaxed) / n)
    }

    pub fn mean_service(&self) -> Duration {
        let n = self.jobs().max(1);
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed) / n)
    }

    pub fn total_service(&self) -> Duration {
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed))
    }

    /// Host-CPU time actually burned by the service thread (the real PJRT
    /// execution inside the modeled envelope).
    pub fn total_real_compute(&self) -> Duration {
        Duration::from_nanos(self.real_compute_ns.load(Ordering::Relaxed))
    }

    /// NPU utilization over a wall-clock window.
    pub fn utilization(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.total_service().as_secs_f64() / wall.as_secs_f64()
    }

    /// Snapshot for before/after deltas in benches.
    pub fn snapshot(&self) -> (u64, Duration, Duration) {
        (
            self.jobs(),
            self.total_service(),
            self.total_real_compute(),
        )
    }
}

/// The simulated NPU device.
pub struct NpuSim {
    tx: Mutex<Sender<Job>>,
    pub stats: Arc<NpuStats>,
    shared: Arc<SharedTiming>,
}

/// Timing model shared with the service thread.
#[derive(Default)]
struct SharedTiming {
    /// Modeled throughput in FLOPs/s (per-frame service = flops / rate).
    rate_flops: AtomicU64,
    /// Fixed per-submission dispatch cost in ns (driver ioctl + DMA
    /// setup). Paid once per job, so batched submissions amortize it.
    dispatch_ns: AtomicU64,
    /// Per-model service-time overrides (ns per frame), keyed by artifact
    /// name.
    overrides: Mutex<HashMap<String, u64>>,
}

static GLOBAL_NPU: Lazy<NpuSim> = Lazy::new(NpuSim::spawn);

/// Default modeled NPU throughput (FLOPs/s). Calibrated so the small-model
/// zoo lands in the paper's fps regime (I3 ≈ 30 fps on the NPU).
pub const DEFAULT_NPU_FLOPS: u64 = 400_000_000;

/// Default per-submission dispatch cost (driver round-trip).
pub const DEFAULT_NPU_DISPATCH: Duration = Duration::from_micros(500);

impl NpuSim {
    /// The process-wide NPU instance (one accelerator per device, as on
    /// the A311D).
    pub fn global() -> &'static NpuSim {
        &GLOBAL_NPU
    }

    fn spawn() -> NpuSim {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = std::sync::mpsc::channel();
        let stats = Arc::new(NpuStats::default());
        let shared = Arc::new(SharedTiming::default());
        shared
            .rate_flops
            .store(DEFAULT_NPU_FLOPS, Ordering::Relaxed);
        shared
            .dispatch_ns
            .store(DEFAULT_NPU_DISPATCH.as_nanos() as u64, Ordering::Relaxed);
        let thread_stats = stats.clone();
        let thread_shared = shared.clone();
        std::thread::Builder::new()
            .name("npu-sim".into())
            .spawn(move || {
                while let Ok((model, frames, done, submitted)) = rx.recv() {
                    let start = Instant::now();
                    thread_stats.queue_ns.fetch_add(
                        start.duration_since(submitted).as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    let n = frames.len() as u64;
                    let refs: Vec<Vec<&Chunk>> =
                        frames.iter().map(|f| f.iter().collect()).collect();
                    let slices: Vec<&[&Chunk]> =
                        refs.iter().map(|v| v.as_slice()).collect();
                    let result = model.execute_batch(&slices);
                    let real = start.elapsed();
                    thread_stats
                        .real_compute_ns
                        .fetch_add(real.as_nanos() as u64, Ordering::Relaxed);
                    // modeled service envelope: one dispatch + n frames
                    let target = thread_shared.service_time(&model, n);
                    if target > real {
                        std::thread::sleep(target - real);
                    }
                    thread_stats
                        .service_ns
                        .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    thread_stats.jobs.fetch_add(1, Ordering::Relaxed);
                    thread_stats.frames.fetch_add(n, Ordering::Relaxed);
                    let _ = done.send(result);
                }
            })
            .expect("spawn npu-sim");
        NpuSim {
            tx: Mutex::new(tx),
            stats,
            shared,
        }
    }

    /// Set the modeled NPU throughput (FLOPs/s).
    pub fn set_rate_flops(&self, rate: u64) {
        self.shared.rate_flops.store(rate, Ordering::Relaxed);
    }

    /// Set the modeled per-submission dispatch cost.
    pub fn set_dispatch(&self, dispatch: Duration) {
        self.shared
            .dispatch_ns
            .store(dispatch.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Override the modeled service time for one artifact.
    pub fn set_service_override(&self, model: &str, service: Duration) {
        self.shared
            .overrides
            .lock()
            .unwrap()
            .insert(model.to_string(), service.as_nanos() as u64);
    }

    /// Clear all overrides (benches reset between tables).
    pub fn clear_service_overrides(&self) {
        self.shared.overrides.lock().unwrap().clear();
    }

    /// Submit one frame and block until the NPU completes it.
    pub fn submit(&self, model: Arc<Model>, inputs: Vec<Chunk>) -> Result<Vec<Chunk>> {
        let mut frames = self.submit_batch(model, vec![inputs])?;
        Ok(frames.pop().expect("one frame in, one frame out"))
    }

    /// Submit a batch of frames as **one hardware job** and block until it
    /// completes. The driver dispatch cost is paid once for the whole
    /// batch, per-frame compute is serialized on the device as usual.
    pub fn submit_batch(
        &self,
        model: Arc<Model>,
        frames: Vec<Vec<Chunk>>,
    ) -> Result<Vec<Vec<Chunk>>> {
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send((model, frames, done_tx, Instant::now()))
            .map_err(|_| Error::Runtime("NPU service thread gone".into()))?;
        done_rx
            .recv()
            .map_err(|_| Error::Runtime("NPU dropped job".into()))?
    }
}

impl SharedTiming {
    /// Modeled service envelope for one job of `n` frames. A per-artifact
    /// override is a *calibrated measured total* (it already includes the
    /// driver round-trip), so it is used verbatim per frame; the modeled
    /// dispatch cost applies only to the rate-based path.
    fn service_time(&self, model: &Model, n: u64) -> Duration {
        if let Some(&ns) = self.overrides.lock().unwrap().get(&model.spec.name) {
            return Duration::from_nanos(ns.saturating_mul(n));
        }
        let dispatch =
            Duration::from_nanos(self.dispatch_ns.load(Ordering::Relaxed));
        let rate = self.rate_flops.load(Ordering::Relaxed).max(1);
        dispatch
            + Duration::from_secs_f64(
                (model.spec.flops.saturating_mul(n)) as f64 / rate as f64,
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelRegistry;

    #[test]
    fn npu_computes_and_counts() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let npu = NpuSim::global();
        let before = npu.stats.jobs();
        let n = model.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        let out = npu.submit(model.clone(), vec![input]).unwrap();
        assert_eq!(out[0].to_f32_vec().unwrap().len(), 8);
        assert_eq!(npu.stats.jobs(), before + 1);
        assert!(npu.stats.mean_service() > Duration::ZERO);
    }

    #[test]
    fn service_override_paces_jobs() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_c_opt").unwrap();
        let npu = NpuSim::global();
        npu.set_service_override("ars_c_opt", Duration::from_millis(30));
        let n = model.spec.inputs[0].dims.num_elements();
        let t0 = Instant::now();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        npu.submit(model.clone(), vec![input]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(29));
        npu.clear_service_overrides();
    }

    #[test]
    fn batched_submit_returns_per_frame_outputs() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let frames: Vec<Vec<Chunk>> = (0..3)
            .map(|i| vec![Chunk::from_f32(&vec![0.1f32 * (i as f32 + 1.0); n])])
            .collect();
        let npu = NpuSim::global();
        let jobs_before = npu.stats.jobs();
        let frames_before = npu.stats.frames();
        let out = npu.submit_batch(model, frames).unwrap();
        assert_eq!(out.len(), 3);
        for frame in &out {
            assert_eq!(frame[0].to_f32_vec().unwrap().len(), 8);
        }
        assert!(npu.stats.jobs() >= jobs_before + 1);
        assert!(npu.stats.frames() >= frames_before + 3);
    }

    #[test]
    fn npu_handles_concurrent_submitters() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = model.clone();
                std::thread::spawn(move || {
                    let input = Chunk::from_f32(&vec![0.2f32; n]);
                    NpuSim::global().submit(m, vec![input]).unwrap()
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.len(), 1);
        }
    }
}
