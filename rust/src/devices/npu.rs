//! Simulated NPU: one hardware queue, one service thread, modeled timing.
//!
//! E1's headline is that NNStreamer runs multiple models on one NPU "with
//! virtually no overheads": the NPU is a serial device, so two models
//! sharing it time-slice its queue. This simulator reproduces exactly that
//! contention structure:
//!
//! * all submissions funnel through a single FIFO queue;
//! * one dedicated service thread accepts them in order;
//! * **service time is modeled**: the real PJRT execution produces the
//!   output values immediately, and the job's *completion* is delayed to
//!   the end of its modeled service window
//!   `max(real_time, dispatch + flops·n / npu_rate)` on a virtual device
//!   clock. The window occupies no host CPU — which is exactly the
//!   property that makes an NPU an NPU (while the simulated NPU
//!   "computes", CPU elements keep streaming);
//! * completion is **push-based**: [`NpuSim::submit_batch_async`] returns
//!   a [`Completion`] handle and fires a
//!   [`SharedWaker`](crate::pipeline::executor::SharedWaker) when the
//!   window elapses, so an executor task parks at zero worker cost while
//!   its job is in flight. The blocking [`NpuSim::submit`] /
//!   [`NpuSim::submit_batch`] wrappers are the same path plus a wait.
//!
//! Device parallelism is modeled as virtual lanes
//! ([`NpuSim::set_parallelism`], default 1 = the serial A311D queue):
//! each accepted job occupies the earliest-free lane for its service
//! window, so with `k` lanes up to `k` windows overlap — the knob the
//! e12 bench turns to show throughput scaling with device parallelism
//! instead of worker count.
//!
//! Queue time vs service time are tracked separately; service time is
//! charged to the NPU domain, not the submitting element's CPU.

use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;

use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::{thread, Condvar, Mutex};
use std::time::{Duration, Instant};

use once_cell::sync::Lazy;

use crate::error::{Error, Result};
use crate::pipeline::executor::SharedWaker;
use crate::runtime::Model;
use crate::tensor::Chunk;

/// One queued submission: a batch of frames for one model. A single-frame
/// invocation is a batch of one.
struct Job {
    model: Arc<Model>,
    frames: Vec<Vec<Chunk>>,
    state: Arc<CompletionState>,
    waker: Option<Arc<SharedWaker>>,
    submitted: Instant,
}

/// The drained outcome of one completed job.
pub struct Completed {
    pub result: Result<Vec<Vec<Chunk>>>,
    /// Modeled submit-to-completion occupancy (queue wait + service
    /// window): what the blocking path would have charged as busy time.
    pub occupancy: Duration,
}

struct CompletionState {
    slot: Mutex<Option<Completed>>,
    ready: Condvar,
}

/// Handle to an in-flight NPU job. The service thread stores the result
/// and fires the registered waker when the modeled service window
/// elapses; the submitter drains it with [`try_take`](Completion::try_take)
/// (executor tasks, after their wake) or blocks in
/// [`wait`](Completion::wait) (the classic dispatch path).
pub struct Completion {
    state: Arc<CompletionState>,
}

impl Completion {
    /// Non-blocking drain. `None` while the job is still in flight
    /// (spurious wake); each completed job yields its result exactly once.
    pub fn try_take(&self) -> Option<Completed> {
        self.state.slot.lock().unwrap().take()
    }

    /// Block until the job completes (the classic driver-ioctl shape).
    pub fn wait(self) -> Result<Vec<Vec<Chunk>>> {
        let mut g = self.state.slot.lock().unwrap();
        loop {
            if let Some(c) = g.take() {
                return c.result;
            }
            g = self.state.ready.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Heap entry for a job whose service window is running: fires (stores
/// the result, wakes the submitter) at `due`. Min-ordered by
/// `(due, seq)` — `seq` keeps FIFO order among jobs sharing a deadline.
struct Firing {
    due: Instant,
    seq: u64,
    n_frames: u64,
    service_ns: u64,
    completed: Completed,
    state: Arc<CompletionState>,
    waker: Option<Arc<SharedWaker>>,
}

impl PartialEq for Firing {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Firing {}
impl PartialOrd for Firing {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Firing {
    // reversed: BinaryHeap is a max-heap, we want the soonest due first
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .due
            .cmp(&self.due)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Aggregate NPU counters.
#[derive(Debug, Default)]
pub struct NpuStats {
    jobs: AtomicU64,
    frames: AtomicU64,
    queue_ns: AtomicU64,
    service_ns: AtomicU64,
    real_compute_ns: AtomicU64,
    /// Jobs submitted but not yet completed (device queue depth).
    in_flight: AtomicU64,
    in_flight_hwm: AtomicU64,
}

impl NpuStats {
    /// Completed submissions (a batch counts once).
    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Completed frames across all submissions.
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    /// Jobs currently in flight (submitted, not yet completed).
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// High-water mark of the in-flight job count — how deep the device
    /// queue got. Under the async lane this can exceed the executor's
    /// worker count by design; under blocking dispatch it cannot.
    pub fn in_flight_high_water(&self) -> u64 {
        self.in_flight_hwm.load(Ordering::Relaxed)
    }

    pub fn mean_queue(&self) -> Duration {
        let n = self.jobs().max(1);
        Duration::from_nanos(self.queue_ns.load(Ordering::Relaxed) / n)
    }

    pub fn mean_service(&self) -> Duration {
        let n = self.jobs().max(1);
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed) / n)
    }

    pub fn total_service(&self) -> Duration {
        Duration::from_nanos(self.service_ns.load(Ordering::Relaxed))
    }

    /// Host-CPU time actually burned by the service thread (the real PJRT
    /// execution inside the modeled envelope).
    pub fn total_real_compute(&self) -> Duration {
        Duration::from_nanos(self.real_compute_ns.load(Ordering::Relaxed))
    }

    /// NPU utilization over a wall-clock window.
    pub fn utilization(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.total_service().as_secs_f64() / wall.as_secs_f64()
    }

    /// Snapshot for before/after deltas in benches.
    pub fn snapshot(&self) -> (u64, Duration, Duration) {
        (
            self.jobs(),
            self.total_service(),
            self.total_real_compute(),
        )
    }

    fn record_submit(&self) {
        let now = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.in_flight_hwm.fetch_max(now, Ordering::Relaxed);
    }
}

/// The simulated NPU device.
pub struct NpuSim {
    tx: Mutex<Sender<Job>>,
    pub stats: Arc<NpuStats>,
    shared: Arc<SharedTiming>,
}

/// Timing model shared with the service thread.
#[derive(Default)]
struct SharedTiming {
    /// Modeled throughput in FLOPs/s (per-frame service = flops / rate).
    rate_flops: AtomicU64,
    /// Fixed per-submission dispatch cost in ns (driver ioctl + DMA
    /// setup). Paid once per job, so batched submissions amortize it.
    dispatch_ns: AtomicU64,
    /// Virtual device lanes: how many service windows may overlap
    /// (1 = the serial hardware queue).
    parallelism: AtomicUsize,
    /// Per-model service-time overrides (ns per frame), keyed by artifact
    /// name.
    overrides: Mutex<HashMap<String, u64>>,
}

static GLOBAL_NPU: Lazy<NpuSim> = Lazy::new(NpuSim::spawn);

/// Default modeled NPU throughput (FLOPs/s). Calibrated so the small-model
/// zoo lands in the paper's fps regime (I3 ≈ 30 fps on the NPU).
pub const DEFAULT_NPU_FLOPS: u64 = 400_000_000;

/// Default per-submission dispatch cost (driver round-trip).
pub const DEFAULT_NPU_DISPATCH: Duration = Duration::from_micros(500);

/// Store the result and wake the submitter (the completion interrupt).
fn fire(f: Firing, stats: &NpuStats) {
    stats.service_ns.fetch_add(f.service_ns, Ordering::Relaxed);
    stats.jobs.fetch_add(1, Ordering::Relaxed);
    stats.frames.fetch_add(f.n_frames, Ordering::Relaxed);
    stats.in_flight.fetch_sub(1, Ordering::Relaxed);
    {
        let mut slot = f.state.slot.lock().unwrap();
        *slot = Some(f.completed);
    }
    f.state.ready.notify_all();
    if let Some(w) = f.waker {
        w.wake();
    }
}

impl NpuSim {
    /// The process-wide NPU instance (one accelerator per device, as on
    /// the A311D).
    pub fn global() -> &'static NpuSim {
        &GLOBAL_NPU
    }

    fn spawn() -> NpuSim {
        let (tx, rx): (Sender<Job>, Receiver<Job>) = std::sync::mpsc::channel();
        let stats = Arc::new(NpuStats::default());
        let shared = Arc::new(SharedTiming::default());
        shared
            .rate_flops
            .store(DEFAULT_NPU_FLOPS, Ordering::Relaxed);
        shared
            .dispatch_ns
            .store(DEFAULT_NPU_DISPATCH.as_nanos() as u64, Ordering::Relaxed);
        shared.parallelism.store(1, Ordering::Relaxed);
        let thread_stats = stats.clone();
        let thread_shared = shared.clone();
        thread::Builder::new()
            .name("npu-sim".into())
            .spawn(move || service_loop(rx, thread_stats, thread_shared))
            .expect("spawn npu-sim");
        NpuSim {
            tx: Mutex::new(tx),
            stats,
            shared,
        }
    }

    /// Set the modeled NPU throughput (FLOPs/s).
    pub fn set_rate_flops(&self, rate: u64) {
        self.shared.rate_flops.store(rate, Ordering::Relaxed);
    }

    /// Set the modeled per-submission dispatch cost.
    pub fn set_dispatch(&self, dispatch: Duration) {
        self.shared
            .dispatch_ns
            .store(dispatch.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Set the modeled device parallelism: how many service windows may
    /// run concurrently (virtual lanes). 1 models the serial hardware
    /// queue; benches raise it to show pipeline throughput scaling with
    /// device parallelism rather than worker count.
    pub fn set_parallelism(&self, lanes: usize) {
        self.shared.parallelism.store(lanes.max(1), Ordering::Relaxed);
    }

    /// Override the modeled service time for one artifact.
    pub fn set_service_override(&self, model: &str, service: Duration) {
        self.shared
            .overrides
            .lock()
            .unwrap()
            .insert(model.to_string(), service.as_nanos() as u64);
    }

    /// Clear all overrides (benches reset between tables).
    pub fn clear_service_overrides(&self) {
        self.shared.overrides.lock().unwrap().clear();
    }

    /// Submit one frame and block until the NPU completes it.
    pub fn submit(&self, model: Arc<Model>, inputs: Vec<Chunk>) -> Result<Vec<Chunk>> {
        let mut frames = self.submit_batch(model, vec![inputs])?;
        Ok(frames.pop().expect("one frame in, one frame out"))
    }

    /// Submit a batch of frames as **one hardware job** and block until it
    /// completes. The driver dispatch cost is paid once for the whole
    /// batch, per-frame compute is serialized on the device as usual.
    pub fn submit_batch(
        &self,
        model: Arc<Model>,
        frames: Vec<Vec<Chunk>>,
    ) -> Result<Vec<Vec<Chunk>>> {
        self.submit_batch_async(model, frames, None)?.wait()
    }

    /// Submit one frame without blocking; see
    /// [`submit_batch_async`](NpuSim::submit_batch_async).
    pub fn submit_async(
        &self,
        model: Arc<Model>,
        inputs: Vec<Chunk>,
        waker: Option<Arc<SharedWaker>>,
    ) -> Result<Completion> {
        self.submit_batch_async(model, vec![inputs], waker)
    }

    /// Submit a batch as one hardware job **without blocking**: returns a
    /// [`Completion`] handle immediately. When the modeled service window
    /// elapses, the service thread stores the result and fires `waker` —
    /// the executor's device lane parks the submitting task until then,
    /// so an in-flight job costs zero pool workers.
    pub fn submit_batch_async(
        &self,
        model: Arc<Model>,
        frames: Vec<Vec<Chunk>>,
        waker: Option<Arc<SharedWaker>>,
    ) -> Result<Completion> {
        let state = Arc::new(CompletionState {
            slot: Mutex::new(None),
            ready: Condvar::new(),
        });
        self.stats.record_submit();
        let sent = self.tx.lock().unwrap().send(Job {
            model,
            frames,
            state: state.clone(),
            waker,
            submitted: Instant::now(),
        });
        if sent.is_err() {
            self.stats.in_flight.fetch_sub(1, Ordering::Relaxed);
            return Err(Error::Runtime("NPU service thread gone".into()));
        }
        Ok(Completion { state })
    }
}

/// The device service loop: accept jobs in FIFO order, execute the real
/// compute immediately, assign each job a service window on the earliest
/// free virtual lane, and fire its completion when the window ends. The
/// `recv_timeout` bound by the soonest pending firing replaces the old
/// in-line sleep — the thread stays responsive to new submissions while
/// windows run, which is what lets windows overlap across lanes.
fn service_loop(rx: Receiver<Job>, stats: Arc<NpuStats>, shared: Arc<SharedTiming>) {
    let mut heap: BinaryHeap<Firing> = BinaryHeap::new();
    let mut free_at: Vec<Instant> = Vec::new();
    let mut seq: u64 = 0;
    loop {
        let now = Instant::now();
        while heap.peek().map_or(false, |f| f.due <= now) {
            fire(heap.pop().unwrap(), &stats);
        }
        let job = match heap.peek() {
            Some(f) => match rx.recv_timeout(f.due.saturating_duration_since(Instant::now())) {
                Ok(j) => Some(j),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(j) => Some(j),
                Err(_) => break,
            },
        };
        let Some(job) = job else { continue };
        let now = Instant::now();
        let lanes = shared.parallelism.load(Ordering::Relaxed).max(1);
        if free_at.len() != lanes {
            free_at.resize(lanes, now);
        }
        let lane = free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, t)| **t)
            .map(|(i, _)| i)
            .expect("at least one lane");
        let window_start = free_at[lane].max(now);
        stats.queue_ns.fetch_add(
            window_start.duration_since(job.submitted).as_nanos() as u64,
            Ordering::Relaxed,
        );
        let n = job.frames.len() as u64;
        let refs: Vec<Vec<&Chunk>> = job.frames.iter().map(|f| f.iter().collect()).collect();
        let slices: Vec<&[&Chunk]> = refs.iter().map(|v| v.as_slice()).collect();
        let t0 = Instant::now();
        let result = job.model.execute_batch(&slices);
        let real = t0.elapsed();
        stats
            .real_compute_ns
            .fetch_add(real.as_nanos() as u64, Ordering::Relaxed);
        // modeled service envelope: one dispatch + n frames, floored by
        // the real compute the window must contain
        let target = shared.service_time(&job.model, n).max(real);
        let window_end = window_start + target;
        free_at[lane] = window_end;
        // errors surface immediately; results honor the window
        let due = if result.is_err() { Instant::now() } else { window_end };
        seq += 1;
        heap.push(Firing {
            due,
            seq,
            n_frames: n,
            service_ns: target.as_nanos() as u64,
            completed: Completed {
                result,
                occupancy: window_end.duration_since(job.submitted),
            },
            state: job.state,
            waker: job.waker,
        });
    }
    // channel gone: honor the remaining windows, then exit
    while let Some(f) = heap.pop() {
        let now = Instant::now();
        if f.due > now {
            thread::sleep(f.due - now);
        }
        fire(f, &stats);
    }
}

impl SharedTiming {
    /// Modeled service envelope for one job of `n` frames. A per-artifact
    /// override is a *calibrated measured total* (it already includes the
    /// driver round-trip), so it is used verbatim per frame; the modeled
    /// dispatch cost applies only to the rate-based path.
    fn service_time(&self, model: &Model, n: u64) -> Duration {
        if let Some(&ns) = self.overrides.lock().unwrap().get(&model.spec.name) {
            return Duration::from_nanos(ns.saturating_mul(n));
        }
        let dispatch =
            Duration::from_nanos(self.dispatch_ns.load(Ordering::Relaxed));
        let rate = self.rate_flops.load(Ordering::Relaxed).max(1);
        dispatch
            + Duration::from_secs_f64(
                (model.spec.flops.saturating_mul(n)) as f64 / rate as f64,
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelRegistry;

    /// Service-time overrides and parallelism are global device state, so
    /// the tests that mutate them take this gate to avoid clobbering each
    /// other's timing model under the parallel test runner.
    static TIMING_GATE: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

    #[test]
    fn npu_computes_and_counts() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let npu = NpuSim::global();
        let before = npu.stats.jobs();
        let n = model.spec.inputs[0].dims.num_elements();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        let out = npu.submit(model.clone(), vec![input]).unwrap();
        assert_eq!(out[0].to_f32_vec().unwrap().len(), 8);
        assert_eq!(npu.stats.jobs(), before + 1);
        assert!(npu.stats.mean_service() > Duration::ZERO);
    }

    #[test]
    fn service_override_paces_jobs() {
        let _gate = TIMING_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_c_opt").unwrap();
        let npu = NpuSim::global();
        npu.set_service_override("ars_c_opt", Duration::from_millis(30));
        let n = model.spec.inputs[0].dims.num_elements();
        let t0 = Instant::now();
        let input = Chunk::from_f32(&vec![0.1f32; n]);
        npu.submit(model.clone(), vec![input]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(29));
        npu.clear_service_overrides();
    }

    #[test]
    fn async_submit_completes_without_blocking() {
        let _gate = TIMING_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_b_opt").unwrap();
        let npu = NpuSim::global();
        npu.set_service_override("ars_b_opt", Duration::from_millis(20));
        let n = model.spec.inputs[0].dims.num_elements();
        let waker = SharedWaker::new();
        let t0 = Instant::now();
        let c = npu
            .submit_async(
                model.clone(),
                vec![Chunk::from_f32(&vec![0.1f32; n])],
                Some(waker),
            )
            .unwrap();
        // submit itself returns immediately, well inside the window
        assert!(t0.elapsed() < Duration::from_millis(15), "submit blocked");
        // the completion honors the modeled window
        let out = c.wait().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(19));
        assert_eq!(out.len(), 1);
        npu.clear_service_overrides();
    }

    #[test]
    fn parallel_lanes_overlap_service_windows() {
        let _gate = TIMING_GATE.lock().unwrap_or_else(|e| e.into_inner());
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_b_opt").unwrap();
        let npu = NpuSim::global();
        npu.set_service_override("ars_b_opt", Duration::from_millis(25));
        npu.set_parallelism(4);
        let n = model.spec.inputs[0].dims.num_elements();
        let t0 = Instant::now();
        let completions: Vec<Completion> = (0..4)
            .map(|_| {
                npu.submit_async(
                    model.clone(),
                    vec![Chunk::from_f32(&vec![0.1f32; n])],
                    None,
                )
                .unwrap()
            })
            .collect();
        assert!(npu.stats.in_flight_high_water() >= 4);
        for c in completions {
            c.wait().unwrap();
        }
        // 4 jobs of 25 ms on 4 lanes: ~1 window, not 4 serialized ones
        assert!(
            t0.elapsed() < Duration::from_millis(80),
            "windows did not overlap: {:?}",
            t0.elapsed()
        );
        npu.set_parallelism(1);
        npu.clear_service_overrides();
    }

    #[test]
    fn batched_submit_returns_per_frame_outputs() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let frames: Vec<Vec<Chunk>> = (0..3)
            .map(|i| vec![Chunk::from_f32(&vec![0.1f32 * (i as f32 + 1.0); n])])
            .collect();
        let npu = NpuSim::global();
        let jobs_before = npu.stats.jobs();
        let frames_before = npu.stats.frames();
        let out = npu.submit_batch(model, frames).unwrap();
        assert_eq!(out.len(), 3);
        for frame in &out {
            assert_eq!(frame[0].to_f32_vec().unwrap().len(), 8);
        }
        assert!(npu.stats.jobs() >= jobs_before + 1);
        assert!(npu.stats.frames() >= frames_before + 3);
    }

    #[test]
    fn npu_handles_concurrent_submitters() {
        let reg = ModelRegistry::global().expect("artifacts built");
        let model = reg.load("ars_a_opt").unwrap();
        let n = model.spec.inputs[0].dims.num_elements();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = model.clone();
                thread::spawn(move || {
                    let input = Chunk::from_f32(&vec![0.2f32; n]);
                    NpuSim::global().submit(m, vec![input]).unwrap()
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.len(), 1);
        }
    }
}
