//! Simulated compute devices (see DESIGN.md "Substitutions").
//!
//! * [`NpuSim`] — the Vivante-NPU stand-in of E1: a single hardware queue
//!   serviced by one dedicated thread. Models sharing the NPU serialize on
//!   the queue; work done there is charged to the NPU domain, not app CPU.
//! * [`DeviceClass`] — E3's device classes (mid-end embedded / high-end
//!   embedded / PC) as deterministic compute-throttle factors.

pub mod npu;

pub use npu::{Completed, Completion, NpuSim, NpuStats};

use crate::error::{Error, Result};

/// E3 device classes: a slowdown factor applied to model execution,
/// reproducing the A (Exynos 5422) / B (Exynos 8890) / C (i7 PC) spread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    /// Device A — mid-end embedded (largest slowdown).
    MidEmbedded,
    /// Device B — high-end automotive embedded.
    HighEmbedded,
    /// Device C — PC (no slowdown; the measurement baseline).
    Pc,
}

impl DeviceClass {
    /// Multiplier on compute time relative to this machine.
    /// Calibrated from the paper's Control rows (Table II): PC≈10.4 fps,
    /// B≈1.48 fps (~7x slower), A≈1.01 fps (~10.3x slower).
    pub fn throttle_factor(self) -> f64 {
        match self {
            DeviceClass::MidEmbedded => 10.3,
            DeviceClass::HighEmbedded => 7.0,
            DeviceClass::Pc => 1.0,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "a" | "mid" | "mid-embedded" => DeviceClass::MidEmbedded,
            "b" | "high" | "high-embedded" => DeviceClass::HighEmbedded,
            "c" | "pc" => DeviceClass::Pc,
            other => return Err(Error::Parse(format!("unknown device class {other:?}"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DeviceClass::MidEmbedded => "A/mid-embedded",
            DeviceClass::HighEmbedded => "B/high-embedded",
            DeviceClass::Pc => "C/PC",
        }
    }

    /// Sleep for `(factor - 1) * busy` to emulate the slower device: the
    /// computation itself already took `busy` on this machine.
    pub fn throttle(self, busy: std::time::Duration) -> std::time::Duration {
        let extra = busy.mul_f64(self.throttle_factor() - 1.0);
        if !extra.is_zero() {
            std::thread::sleep(extra);
        }
        busy.mul_f64(self.throttle_factor())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_classes() {
        assert_eq!(DeviceClass::parse("a").unwrap(), DeviceClass::MidEmbedded);
        assert_eq!(DeviceClass::parse("PC").unwrap(), DeviceClass::Pc);
        assert!(DeviceClass::parse("q").is_err());
    }

    #[test]
    fn pc_has_no_throttle() {
        let t0 = std::time::Instant::now();
        let total = DeviceClass::Pc.throttle(Duration::from_millis(50));
        assert!(t0.elapsed() < Duration::from_millis(20));
        assert_eq!(total, Duration::from_millis(50));
    }

    #[test]
    fn mid_embedded_stretches_time() {
        let t0 = std::time::Instant::now();
        let total = DeviceClass::MidEmbedded.throttle(Duration::from_millis(5));
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(40), "waited {waited:?}");
        assert!(total >= Duration::from_millis(51));
    }
}
