//! Pipeline graph: elements + links, validation, caps negotiation.

use std::collections::HashMap;

use crate::element::{Element, PadSpec, Props, Registry};
use crate::error::{Error, Result};
use crate::tensor::Caps;

/// Node identifier within a [`Graph`].
pub type NodeId = usize;

pub struct Node {
    pub name: String,
    pub element: Box<dyn Element>,
    /// Resolved output caps per src pad (filled by [`Graph::negotiate_all`]).
    pub out_caps: Vec<Caps>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Link {
    pub src_node: NodeId,
    pub src_pad: usize,
    pub dst_node: NodeId,
    pub dst_pad: usize,
}

/// A directed acyclic element graph.
#[derive(Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// Deadline budget for load shedding, in ns since the pipeline
    /// epoch relative to each buffer's pts (0 = disabled). When set, a
    /// buffer older than `pts + deadline_ns` is shed at the next link
    /// crossing or step gate and charged to the shedding element's
    /// `shed` counter — late frames stop consuming compute instead of
    /// growing queues. See `Pipeline::set_deadline`.
    pub deadline_ns: u64,
    /// Deterministic fault-injection plan for chaos testing (None in
    /// production). See `Pipeline::set_fault_plan` and
    /// [`crate::pipeline::fault`].
    pub fault_plan: Option<crate::pipeline::fault::FaultPlan>,
    names: HashMap<String, NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an element instance under a unique name.
    pub fn add_element(
        &mut self,
        name: impl Into<String>,
        element: Box<dyn Element>,
    ) -> Result<NodeId> {
        let name = name.into();
        if self.names.contains_key(&name) {
            return Err(Error::Graph(format!("duplicate element name {name:?}")));
        }
        let id = self.nodes.len();
        self.names.insert(name.clone(), id);
        self.nodes.push(Node {
            name,
            element,
            out_caps: Vec::new(),
        });
        Ok(id)
    }

    /// Add an element by factory name with an auto-generated unique name.
    pub fn add(&mut self, factory: &str) -> Result<NodeId> {
        let element = Registry::make(factory)?;
        self.add_boxed(factory, element)
    }

    /// Add an already-constructed element under an auto-generated unique
    /// name derived from its factory name (`factory{N}`).
    pub fn add_boxed(&mut self, factory: &str, element: Box<dyn Element>) -> Result<NodeId> {
        let mut i = self.nodes.len();
        loop {
            let name = format!("{factory}{i}");
            if !self.names.contains_key(&name) {
                return self.add_element(name, element);
            }
            i += 1;
        }
    }

    /// Add an element built from typed props (auto-named).
    pub fn add_props<P: Props>(&mut self, props: P) -> Result<NodeId> {
        let element = props.into_element()?;
        self.add_boxed(P::FACTORY, element)
    }

    /// Rename a node (used by the parser when it sees `name=`).
    pub fn rename(&mut self, id: NodeId, new_name: impl Into<String>) -> Result<()> {
        let new_name = new_name.into();
        if self.names.contains_key(&new_name) {
            return Err(Error::Graph(format!("duplicate element name {new_name:?}")));
        }
        let old = std::mem::replace(&mut self.nodes[id].name, new_name.clone());
        self.names.remove(&old);
        self.names.insert(new_name, id);
        Ok(())
    }

    pub fn by_name(&self, name: &str) -> Option<NodeId> {
        self.names.get(name).copied()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id]
    }

    pub fn set_property(&mut self, id: NodeId, key: &str, value: &str) -> Result<()> {
        self.nodes[id].element.set_property(key, value)
    }

    /// Number of links already attached to `id`'s src side.
    pub fn n_src_links(&self, id: NodeId) -> usize {
        self.links.iter().filter(|l| l.src_node == id).count()
    }

    /// Number of links already attached to `id`'s sink side.
    pub fn n_sink_links(&self, id: NodeId) -> usize {
        self.links.iter().filter(|l| l.dst_node == id).count()
    }

    /// Link with automatic pad assignment (next free pad on both sides).
    pub fn link(&mut self, src: NodeId, dst: NodeId) -> Result<()> {
        let src_pad = self.n_src_links(src);
        let dst_pad = self.n_sink_links(dst);
        self.link_pads(src, src_pad, dst, dst_pad)
    }

    pub fn link_pads(
        &mut self,
        src_node: NodeId,
        src_pad: usize,
        dst_node: NodeId,
        dst_pad: usize,
    ) -> Result<()> {
        if src_node >= self.nodes.len() || dst_node >= self.nodes.len() {
            return Err(Error::Graph("link references unknown node".into()));
        }
        for l in &self.links {
            if l.src_node == src_node && l.src_pad == src_pad {
                return Err(Error::Graph(format!(
                    "src pad {}:{src_pad} already linked",
                    self.nodes[src_node].name
                )));
            }
            if l.dst_node == dst_node && l.dst_pad == dst_pad {
                return Err(Error::Graph(format!(
                    "sink pad {}:{dst_pad} already linked",
                    self.nodes[dst_node].name
                )));
            }
        }
        self.links.push(Link {
            src_node,
            src_pad,
            dst_node,
            dst_pad,
        });
        Ok(())
    }

    /// Validate pad cardinality and acyclicity; returns a topological order.
    pub fn validate(&self) -> Result<Vec<NodeId>> {
        for (id, node) in self.nodes.iter().enumerate() {
            let n_sinks = self.n_sink_links(id);
            let n_srcs = self.n_src_links(id);
            let spec_sink = node.element.sink_pads();
            let spec_src = node.element.src_pads();
            // sources have Fixed(0) sink specs; sinks have Fixed(0) src specs
            let sink_ok = match spec_sink {
                PadSpec::Fixed(0) => n_sinks == 0,
                spec => spec.accepts(n_sinks),
            };
            if !sink_ok {
                return Err(Error::Graph(format!(
                    "element {} ({}) has {} sink links, wants {:?}",
                    node.name,
                    node.element.type_name(),
                    n_sinks,
                    spec_sink
                )));
            }
            let src_ok = match spec_src {
                PadSpec::Fixed(0) => n_srcs == 0,
                spec => spec.accepts(n_srcs) || n_srcs == 0, // unlinked src ok for some
            };
            if !src_ok {
                return Err(Error::Graph(format!(
                    "element {} ({}) has {} src links, wants {:?}",
                    node.name,
                    node.element.type_name(),
                    n_srcs,
                    spec_src
                )));
            }
            // dense pad indices
            for pad in 0..n_sinks {
                if !self
                    .links
                    .iter()
                    .any(|l| l.dst_node == id && l.dst_pad == pad)
                {
                    return Err(Error::Graph(format!(
                        "element {} sink pads not dense (missing pad {pad})",
                        node.name
                    )));
                }
            }
        }
        self.topo_order()
    }

    /// Kahn topological sort; errors on cycles (§III: GStreamer prohibits
    /// stream cycles — recurrence goes through tensor_repo instead).
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for l in &self.links {
            indeg[l.dst_node] += 1;
        }
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(id) = queue.pop() {
            order.push(id);
            for l in self.links.iter().filter(|l| l.src_node == id) {
                indeg[l.dst_node] -= 1;
                if indeg[l.dst_node] == 0 {
                    queue.push(l.dst_node);
                }
            }
        }
        if order.len() != n {
            return Err(Error::Graph(
                "pipeline contains a cycle (use tensor_repo_src/sink for recurrences)".into(),
            ));
        }
        Ok(order)
    }

    /// Run caps negotiation over the whole graph in topological order.
    /// After this, every node's `out_caps[pad]` is fixed.
    pub fn negotiate_all(&mut self) -> Result<()> {
        let order = self.validate()?;
        // Pre-pass: propagate capsfilter restrictions onto direct upstream
        // neighbors (the `src ! caps ! ...` idiom of gst-launch).
        let proposals: Vec<(NodeId, Caps)> = self
            .links
            .iter()
            .filter_map(|l| {
                let dst = &self.nodes[l.dst_node];
                if dst.element.type_name() == "capsfilter" {
                    dst.element
                        .proposed_caps()
                        .map(|caps| (l.src_node, caps))
                } else {
                    None
                }
            })
            .collect();
        for (node, caps) in proposals {
            self.nodes[node].element.propose_caps(&caps)?;
        }
        for id in order {
            let n_sinks = self.n_sink_links(id);
            let n_srcs = self.n_src_links(id);
            let mut in_caps = vec![Caps::Any; n_sinks];
            for l in self.links.iter().filter(|l| l.dst_node == id) {
                let up = &self.nodes[l.src_node];
                let caps = up.out_caps.get(l.src_pad).cloned().ok_or_else(|| {
                    Error::Negotiation(format!(
                        "upstream {} pad {} has no negotiated caps",
                        up.name, l.src_pad
                    ))
                })?;
                in_caps[l.dst_pad] = caps;
            }
            let node = &mut self.nodes[id];
            let out = node
                .element
                .negotiate(&in_caps, n_srcs)
                .map_err(|e| Error::Negotiation(format!("{}: {e}", node.name)))?;
            if out.len() < n_srcs {
                return Err(Error::Negotiation(format!(
                    "{} produced {} caps for {} src links",
                    node.name,
                    out.len(),
                    n_srcs
                )));
            }
            node.out_caps = out;
        }
        Ok(())
    }

    /// Links out of a node, ordered by src pad.
    pub fn links_from(&self, id: NodeId) -> Vec<Link> {
        let mut v: Vec<Link> = self
            .links
            .iter()
            .copied()
            .filter(|l| l.src_node == id)
            .collect();
        v.sort_by_key(|l| l.src_pad);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_validate_linear() {
        let mut g = Graph::new();
        let src = g.add("videotestsrc").unwrap();
        g.set_property(src, "num-buffers", "4").unwrap();
        let conv = g.add("tensor_converter").unwrap();
        let sink = g.add("fakesink").unwrap();
        g.link(src, conv).unwrap();
        g.link(conv, sink).unwrap();
        let order = g.validate().unwrap();
        assert_eq!(order.len(), 3);
        g.negotiate_all().unwrap();
        assert!(matches!(g.node(conv).out_caps[0], Caps::Tensor { .. }));
    }

    #[test]
    fn cycle_is_rejected() {
        let mut g = Graph::new();
        let a = g.add("tensor_transform").unwrap();
        let b = g.add("tensor_transform").unwrap();
        g.link(a, b).unwrap();
        g.link(b, a).unwrap();
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = Graph::new();
        g.add_element("x", Registry::make("queue").unwrap()).unwrap();
        assert!(g
            .add_element("x", Registry::make("queue").unwrap())
            .is_err());
    }

    #[test]
    fn double_link_same_pad_rejected() {
        let mut g = Graph::new();
        let a = g.add("videotestsrc").unwrap();
        let b = g.add("fakesink").unwrap();
        let c = g.add("fakesink").unwrap();
        g.link_pads(a, 0, b, 0).unwrap();
        assert!(g.link_pads(a, 0, c, 0).is_err());
    }
}
