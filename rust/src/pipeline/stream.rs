//! Named stream endpoints: tensor-query pub/sub over the hub registry.
//!
//! The among-device-AI follow-up paper (arXiv:2201.06026) composes AI
//! services *across* pipelines and devices through `tensor_query`
//! client/server elements. This module is the in-process core of that
//! surface: a [`StreamRegistry`] of named **topics**, each fanning one
//! ordered buffer stream out to any number of bounded per-subscriber
//! queues. Pipelines attach through the `tensor_query_serversrc` /
//! `tensor_query_serversink` / `tensor_query_client` elements
//! ([`crate::elements::query`]); applications attach through
//! [`PipelineHub::publish`]/[`PipelineHub::subscribe`] handles — both
//! sides speak the **same** publish/subscribe contract, and since the
//! endpoint redesign `appsrc`/`appsink` are thin wrappers over the same
//! `Endpoint` primitive (anonymous, single-consumer local topics).
//!
//! ## The endpoint contract
//!
//! An `Endpoint` is one bounded buffer queue with wake hooks on both
//! sides:
//!
//! * **element tasks** never block a pool worker — a producer that finds
//!   the queue full returns [`Flow::Wait`](crate::element::Flow::Wait)
//!   and parks; a consumer that finds it empty does the same. Every pop
//!   (and every push) unconditionally wakes the registered
//!   [`SharedWaker`]s of the other side, the exact protocol `appsrc` /
//!   `appsink` proved under the worker-pool executor (spurious wakes are
//!   cheap re-checks, lost wakes are impossible because the waker is
//!   published before the queue is probed);
//! * **application threads** block on condvars (`recv`, blocking
//!   `push`), never inside the executor.
//!
//! EOS propagates across a topic exactly like an in-pipeline link: the
//! topic counts attached publishers; when the last one finishes, every
//! subscriber queue is marked end-of-stream and drains to a terminal
//! `End`, which a `tensor_query_serversrc` forwards downstream as
//! pipeline EOS and an application handle surfaces as a closed channel.
//!
//! ## Transports
//!
//! Delivery is abstracted behind the [`Transport`] trait (publisher and
//! subscriber **ports**). Only the in-process transport exists today;
//! socket/network backends can be registered with
//! [`register_transport`] later without changing the element or
//! application API — `tensor_query_serversrc topic=faces
//! transport=tcp` is a property change, not a new element.
//!
//! [`PipelineHub::publish`]: crate::pipeline::PipelineHub::publish
//! [`PipelineHub::subscribe`]: crate::pipeline::PipelineHub::subscribe

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{RecvError, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use once_cell::sync::Lazy;

use crate::sync::{Condvar, Mutex, MutexGuard};

use crate::error::{Error, Fault, Result};
use crate::metrics::stats::{
    latency_bucket, merge_latency, summarize_latency, TopicDrops, TopicSnapshot,
    LATENCY_BUCKETS,
};
use crate::pipeline::executor::{lock, SharedWaker};
use crate::tensor::{Buffer, Caps};

/// Default bound of one subscriber queue (matches the `appsrc`/`appsink`
/// channel capacity the endpoint layer replaced).
pub const DEFAULT_ENDPOINT_CAPACITY: usize = 64;

/// Per-subscriber delivery mode of a topic queue — the serving-layer
/// QoS knob. The mode decides what happens when the subscriber's
/// bounded queue is full at delivery time:
///
/// * `Blocking` — the publisher parks (elements) or blocks (app
///   threads) until the queue drains: lossless, correctness-mode
///   pipelines; the default everywhere.
/// * `Leaky` — the **arriving** buffer is discarded and counted
///   (`drops.qos_leaky`): a flooded tenant loses its own newest frames
///   and never backpressures the publisher.
/// * `LatestOnly` — the **oldest** queued buffer is evicted
///   (`drops.qos_latest`) and the newest enqueued: consumers that only
///   care about the freshest frame (monitoring, UI previews).
///
/// Every drop is typed and counted, so
/// `pushed == delivered + dropped + in_flight` holds exactly for every
/// subscriber queue (see [`SubscriberCounters`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum Qos {
    #[default]
    Blocking,
    Leaky,
    LatestOnly,
}

impl Qos {
    /// Parse the element-property spelling (`qos=` on
    /// `tensor_query_serversink`/`serversrc`).
    pub fn parse(s: &str) -> Result<Qos> {
        match s {
            "blocking" => Ok(Qos::Blocking),
            "leaky" => Ok(Qos::Leaky),
            "latest-only" | "latest_only" | "latest" => Ok(Qos::LatestOnly),
            other => Err(Error::Property {
                key: "qos".into(),
                value: other.into(),
                reason: "expected blocking | leaky | latest-only".into(),
            }),
        }
    }
}

impl std::fmt::Display for Qos {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Qos::Blocking => "blocking",
            Qos::Leaky => "leaky",
            Qos::LatestOnly => "latest-only",
        })
    }
}

/// Why a stream endpoint stopped delivering — the close-reason every
/// consumer can ask for once `recv` reports the end. This is what makes
/// a fault-truncated stream distinguishable from a clean end-of-stream
/// *at every consumer*: element links carry it on their inboxes, app
/// channels ([`AppSinkReceiver`]) and topic subscriptions
/// ([`TopicSubscriber::close_reason`]) carry it here.
///
/// Precedence: a recorded fault outranks everything (a consumer that
/// cancelled *after* a fault arrived still reports the fault), `Closed`
/// outranks plain EOS.
///
/// [`AppSinkReceiver`]: crate::elements::sinks::AppSinkReceiver
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEnd {
    /// Clean end-of-stream: every producer finished normally.
    Eos,
    /// The stream was truncated by an upstream fault — possibly in
    /// another pipeline, across a topic. Carries the originating record.
    Fault(Fault),
    /// The consumer side cancelled (receiver dropped, hub stop).
    Closed,
}

impl std::fmt::Display for StreamEnd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamEnd::Eos => f.write_str("end of stream"),
            StreamEnd::Fault(fault) => write!(f, "stream truncated by a fault: {fault}"),
            StreamEnd::Closed => f.write_str("stream closed by the consumer"),
        }
    }
}

impl std::error::Error for StreamEnd {}

/// Exact counter snapshot of one subscriber queue, taken under the
/// endpoint lock. Invariant (checked by the property suite):
/// `pushed == delivered + dropped.subscriber_total() + in_flight`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SubscriberCounters {
    /// Buffers the topic pushed toward this queue (accepted, dropped by
    /// QoS, or evicting an older one).
    pub pushed: u64,
    /// Buffers the consumer popped.
    pub delivered: u64,
    /// Typed drops (`no_subscriber` is always zero here — that reason
    /// is accounted at the topic, not per subscriber).
    pub dropped: TopicDrops,
    /// Buffers currently queued.
    pub in_flight: u64,
}

impl SubscriberCounters {
    fn fold(&mut self, other: &SubscriberCounters) {
        self.pushed += other.pushed;
        self.delivered += other.delivered;
        self.dropped.qos_leaky += other.dropped.qos_leaky;
        self.dropped.qos_latest += other.dropped.qos_latest;
        self.dropped.closed += other.dropped.closed;
        self.in_flight += other.in_flight;
    }
}

// ---------------------------------------------------------------------------
// Endpoint: one bounded queue with wake hooks on both sides
// ---------------------------------------------------------------------------

/// Outcome of a non-blocking endpoint push (element producers).
pub(crate) enum EpPush {
    /// Enqueued; the consumer side has been woken.
    Ok,
    /// At capacity — the buffer comes back so the element can
    /// `push_back_input` it and park ([`Flow::Wait`](crate::element::Flow::Wait)).
    Full(Buffer),
    /// The consumer is gone (or the stream ended): nothing can be
    /// delivered anymore.
    Closed(Buffer),
}

/// Outcome of a non-blocking endpoint pop (element consumers).
pub(crate) enum EpPop {
    Item(Buffer),
    /// Nothing queued yet but the stream is still open — park.
    Empty,
    /// Stream over: every producer finished (queue drained) or the
    /// endpoint was closed.
    End,
}

struct EpState {
    /// Queued buffers with their enqueue instant (feeds the queue-wait
    /// latency histogram at pop time).
    queue: VecDeque<(Buffer, Instant)>,
    /// No more data will ever be pushed; queued buffers still drain.
    eos: bool,
    /// First fault recorded by a producer side: the stream is truncated,
    /// not cleanly ended. Implies `eos` (set together by `fail`). Sticky
    /// — later faults and later clean EOS never overwrite it.
    fault: Option<Fault>,
    /// Consumer cancelled (receiver dropped, hub stop): pushes are
    /// rejected and pops end immediately, queued buffers discarded.
    closed: bool,
    /// Wakers of element tasks producing into this endpoint.
    producer_wakers: Vec<Arc<SharedWaker>>,
    /// Wakers of the element task consuming this endpoint.
    consumer_wakers: Vec<Arc<SharedWaker>>,
    /// Plain counters, exact under this mutex: conservation
    /// (`pushed == delivered + drops + queue.len()`) holds at every
    /// instant a lock holder can observe.
    counters: SubscriberCounters,
    /// Queue-wait latency histogram (enqueue → pop), fixed buckets.
    hist: [u64; LATENCY_BUCKETS],
}

impl EpState {
    fn record_pop(&mut self, at: Instant) {
        self.counters.delivered += 1;
        let ns = at.elapsed().as_nanos() as u64;
        self.hist[latency_bucket(ns)] += 1;
    }

    /// The conservation identity of one subscriber queue, exact under
    /// the endpoint mutex: every accepted buffer is still queued, was
    /// delivered, or became a typed drop. Checked after every locked
    /// mutation in debug builds — and therefore at every explored
    /// instant of the `--features check` model suite.
    fn assert_conserved(&self) {
        debug_assert_eq!(
            self.counters.pushed,
            self.counters.delivered
                + self.counters.dropped.subscriber_total()
                + self.queue.len() as u64,
            "endpoint conservation violated: pushed != delivered + drops + in_flight"
        );
    }
}

/// One bounded buffer queue shared by a producer side and a consumer
/// side, either of which may be an element task (woken through
/// [`SharedWaker`]s) or an application thread (blocking on condvars).
/// The common primitive under `appsrc`, `appsink` and every topic
/// subscription.
pub(crate) struct Endpoint {
    cap: usize,
    /// Delivery mode when this queue is full (see [`Qos`]).
    qos: Qos,
    inner: Mutex<EpState>,
    /// Consumer-side blocking waits.
    not_empty: Condvar,
    /// Producer-side blocking waits.
    not_full: Condvar,
    /// Owning topic (None for anonymous appsrc/appsink endpoints):
    /// pops additionally release publishers parked at topic level.
    owner: Option<Weak<TopicInner>>,
}

impl Endpoint {
    pub(crate) fn new(cap: usize, qos: Qos, owner: Option<Weak<TopicInner>>) -> Arc<Endpoint> {
        Arc::new(Endpoint {
            cap: cap.max(1),
            qos,
            inner: Mutex::new(EpState {
                queue: VecDeque::new(),
                eos: false,
                fault: None,
                closed: false,
                producer_wakers: Vec::new(),
                consumer_wakers: Vec::new(),
                counters: SubscriberCounters::default(),
                hist: [0; LATENCY_BUCKETS],
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            owner,
        })
    }

    /// Anonymous single-consumer endpoint (the appsrc/appsink channel).
    pub(crate) fn standalone(cap: usize) -> Arc<Endpoint> {
        Endpoint::new(cap, Qos::Blocking, None)
    }

    pub(crate) fn qos(&self) -> Qos {
        self.qos
    }

    pub(crate) fn capacity(&self) -> usize {
        self.cap
    }

    /// Exact counter snapshot plus latency buckets, under the endpoint
    /// lock.
    pub(crate) fn counters_and_hist(&self) -> (SubscriberCounters, [u64; LATENCY_BUCKETS]) {
        let g = lock(&self.inner);
        let mut c = g.counters;
        c.in_flight = g.queue.len() as u64;
        (c, g.hist)
    }

    /// Register the waker of an element task producing into this
    /// endpoint (woken on every pop/close — spurious wakes are cheap).
    pub(crate) fn add_producer_waker(&self, w: &Arc<SharedWaker>) {
        let mut g = lock(&self.inner);
        if !g.producer_wakers.iter().any(|x| Arc::ptr_eq(x, w)) {
            g.producer_wakers.push(w.clone());
        }
    }

    /// Register the waker of the element task consuming this endpoint.
    pub(crate) fn add_consumer_waker(&self, w: &Arc<SharedWaker>) {
        let mut g = lock(&self.inner);
        if !g.consumer_wakers.iter().any(|x| Arc::ptr_eq(x, w)) {
            g.consumer_wakers.push(w.clone());
        }
    }

    /// Queue length at/over capacity? (publisher-side space probe; only
    /// meaningful under the owning topic's lock for fan-out atomicity.)
    pub(crate) fn is_full(&self) -> bool {
        let g = lock(&self.inner);
        !g.closed && g.queue.len() >= self.cap
    }

    /// Does this subscriber hold publishers back right now? Only
    /// `Blocking`-mode queues ever gate a publisher: leaky and
    /// latest-only queues absorb overload by dropping.
    pub(crate) fn gates_publisher(&self) -> bool {
        self.qos == Qos::Blocking && self.is_full()
    }

    fn wake_consumers(&self, wakers: Vec<Arc<SharedWaker>>) {
        self.not_empty.notify_all();
        for w in &wakers {
            w.wake();
        }
    }

    fn wake_producers(&self, wakers: Vec<Arc<SharedWaker>>) {
        self.not_full.notify_all();
        for w in &wakers {
            w.wake();
        }
        if let Some(t) = self.owner.as_ref().and_then(Weak::upgrade) {
            t.notify_space();
        }
    }

    /// Non-blocking push (element producers — never holds a worker).
    pub(crate) fn try_push(&self, buf: Buffer) -> EpPush {
        let wakers = {
            let mut g = lock(&self.inner);
            if g.closed || g.eos {
                return EpPush::Closed(buf);
            }
            if g.queue.len() >= self.cap {
                return EpPush::Full(buf);
            }
            g.counters.pushed += 1;
            g.queue.push_back((buf, Instant::now()));
            g.assert_conserved();
            g.consumer_wakers.clone()
        };
        self.wake_consumers(wakers);
        EpPush::Ok
    }

    /// QoS-aware delivery from the owning topic (called under the topic
    /// lock; see [`TopicInner::deliver_locked`]). `qos` is the effective
    /// mode for this delivery — the subscriber's own unless a
    /// non-blocking publisher overrode it. Blocking queues are gated
    /// non-full by the publisher before delivery, so `Blocking` never
    /// observes a full queue here; a full leaky queue discards the
    /// arriving buffer, a full latest-only queue evicts its oldest.
    pub(crate) fn offer(&self, buf: Buffer, qos: Qos) {
        let wakers = {
            let mut g = lock(&self.inner);
            if g.closed || g.eos {
                // nothing can ever be delivered: not part of this
                // subscriber's accounting (the queue is already retired)
                return;
            }
            g.counters.pushed += 1;
            if g.queue.len() >= self.cap {
                match qos {
                    Qos::Blocking | Qos::Leaky => {
                        // Blocking is gated by the publisher under the
                        // topic lock and cannot be full here; counting a
                        // defensive overflow as leaky keeps conservation.
                        g.counters.dropped.qos_leaky += 1;
                        g.assert_conserved();
                        return;
                    }
                    Qos::LatestOnly => {
                        g.queue.pop_front();
                        g.counters.dropped.qos_latest += 1;
                    }
                }
            }
            g.queue.push_back((buf, Instant::now()));
            g.assert_conserved();
            g.consumer_wakers.clone()
        };
        self.wake_consumers(wakers);
    }

    /// Blocking push (application producers — `AppSrcHandle::push`).
    /// Errors once the stream ended or the consumer is gone.
    pub(crate) fn push_blocking(&self, buf: Buffer) -> std::result::Result<(), Buffer> {
        let mut g = lock(&self.inner);
        loop {
            if g.closed || g.eos {
                return Err(buf);
            }
            if g.queue.len() < self.cap {
                g.counters.pushed += 1;
                g.queue.push_back((buf, Instant::now()));
                g.assert_conserved();
                let wakers = g.consumer_wakers.clone();
                drop(g);
                self.wake_consumers(wakers);
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking pop (element consumers).
    pub(crate) fn try_pop(&self) -> EpPop {
        let (buf, wakers) = {
            let mut g = lock(&self.inner);
            if g.closed {
                return EpPop::End;
            }
            match g.queue.pop_front() {
                Some((b, at)) => {
                    g.record_pop(at);
                    g.assert_conserved();
                    (b, g.producer_wakers.clone())
                }
                None => {
                    return if g.eos { EpPop::End } else { EpPop::Empty };
                }
            }
        };
        self.wake_producers(wakers);
        EpPop::Item(buf)
    }

    /// Blocking pop (application consumers). `None` = stream over.
    pub(crate) fn pop_blocking(&self) -> Option<Buffer> {
        let mut g = lock(&self.inner);
        loop {
            if g.closed {
                return None;
            }
            if let Some((b, at)) = g.queue.pop_front() {
                g.record_pop(at);
                g.assert_conserved();
                let wakers = g.producer_wakers.clone();
                drop(g);
                self.wake_producers(wakers);
                return Some(b);
            }
            if g.eos {
                return None;
            }
            g = self.not_empty.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Timed pop (application consumers). `Empty` = timed out.
    pub(crate) fn pop_timeout(&self, timeout: Duration) -> EpPop {
        let deadline = Instant::now() + timeout;
        let mut g = lock(&self.inner);
        loop {
            if g.closed {
                return EpPop::End;
            }
            if let Some((b, at)) = g.queue.pop_front() {
                g.record_pop(at);
                g.assert_conserved();
                let wakers = g.producer_wakers.clone();
                drop(g);
                self.wake_producers(wakers);
                return EpPop::Item(b);
            }
            if g.eos {
                return EpPop::End;
            }
            let now = Instant::now();
            if now >= deadline {
                return EpPop::Empty;
            }
            let (ng, _) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            g = ng;
        }
    }

    /// No more data will arrive; queued buffers still drain, then the
    /// consumer observes `End`. Both sides are woken.
    pub(crate) fn set_eos(&self) {
        let (producers, consumers) = {
            let mut g = lock(&self.inner);
            g.eos = true;
            (g.producer_wakers.clone(), g.consumer_wakers.clone())
        };
        self.wake_consumers(consumers);
        self.wake_producers(producers);
    }

    /// The producer side died on a fault: ends the stream like
    /// [`set_eos`](Endpoint::set_eos) (queued buffers still drain) but
    /// records the fault so the consumer's close-reason reads
    /// [`StreamEnd::Fault`] instead of a clean EOS. First fault wins.
    pub(crate) fn fail(&self, fault: &Fault) {
        let (producers, consumers) = {
            let mut g = lock(&self.inner);
            if g.fault.is_none() {
                g.fault = Some(fault.clone());
            }
            g.eos = true;
            (g.producer_wakers.clone(), g.consumer_wakers.clone())
        };
        self.wake_consumers(consumers);
        self.wake_producers(producers);
    }

    /// Why this stream ended — `None` while it is still open. Precedence
    /// fault > closed > eos: a consumer that cancelled after a fault
    /// arrived still learns about the fault.
    pub(crate) fn close_reason(&self) -> Option<StreamEnd> {
        let g = lock(&self.inner);
        if let Some(f) = &g.fault {
            return Some(StreamEnd::Fault(f.clone()));
        }
        if g.closed {
            return Some(StreamEnd::Closed);
        }
        if g.eos {
            return Some(StreamEnd::Eos);
        }
        None
    }

    /// Consumer cancelled: discard queued buffers (counted as `closed`
    /// drops), reject future pushes, wake everything (parked producers
    /// observe `Closed` and unwind).
    pub(crate) fn close(&self) {
        let (producers, consumers) = self.close_quiet().1;
        self.wake_consumers(consumers);
        self.wake_producers(producers);
    }

    /// Close without firing any wakes: marks closed, charges queued
    /// buffers to `dropped.closed`, and returns the final counters plus
    /// the waker lists for the caller to fire **after** releasing
    /// whatever lock it holds. Used by [`TopicInner::unsubscribe`],
    /// which folds the counters into the topic's retired totals under
    /// the topic lock — waking from there would re-enter the topic
    /// mutex through `notify_space`.
    #[allow(clippy::type_complexity)]
    pub(crate) fn close_quiet(
        &self,
    ) -> (
        (SubscriberCounters, [u64; LATENCY_BUCKETS]),
        (Vec<Arc<SharedWaker>>, Vec<Arc<SharedWaker>>),
    ) {
        let mut g = lock(&self.inner);
        if !g.closed {
            g.closed = true;
            g.counters.dropped.closed += g.queue.len() as u64;
            g.queue.clear();
            g.assert_conserved();
        }
        let counters = g.counters;
        let hist = g.hist;
        let wakers = (g.producer_wakers.clone(), g.consumer_wakers.clone());
        drop(g);
        ((counters, hist), wakers)
    }

    /// Fire producer/consumer wakes collected by
    /// [`close_quiet`](Endpoint::close_quiet) once the caller's locks
    /// are released.
    pub(crate) fn wake_both(
        &self,
        (producers, consumers): (Vec<Arc<SharedWaker>>, Vec<Arc<SharedWaker>>),
    ) {
        self.wake_consumers(consumers);
        self.wake_producers(producers);
    }
}

// ---------------------------------------------------------------------------
// Topic: named fan-out over per-subscriber endpoints
// ---------------------------------------------------------------------------

/// Outcome of a non-blocking topic publish.
pub(crate) enum TopicPush {
    /// Delivered to every subscriber queue.
    Ok,
    /// Nobody is listening — the caller decides between dropping
    /// (pub/sub default) and parking (`wait-subscribers=`).
    NoSubscribers(Buffer),
    /// Some subscriber queue is at capacity — park until it drains.
    Full(Buffer),
    /// The stream already ended on this topic.
    Closed(Buffer),
}

struct TopicState {
    subs: Vec<Arc<Endpoint>>,
    open_publishers: usize,
    /// The last publisher finished: new subscribers observe `End`
    /// immediately; a new publisher attachment reopens the topic.
    eos: bool,
    /// First fault reported by a publisher this stream generation. When
    /// the last publisher detaches with a fault on record, subscriber
    /// queues end with [`StreamEnd::Fault`] instead of clean EOS.
    /// Cleared when a new publisher reopens an ended topic.
    fault: Option<Fault>,
    /// Caps advertised by the first publisher (subscriber elements
    /// announce these downstream when no explicit caps were configured).
    caps: Option<Caps>,
    /// Wakers of element publishers parked on a saturated (or
    /// subscriber-less, with `wait-subscribers=`) topic.
    publisher_wakers: Vec<Arc<SharedWaker>>,
    /// Buffers accepted from publishers (fanned out to ≥1 subscriber).
    published: u64,
    /// Publisher-side discards: published while nobody subscribed.
    no_sub_drops: u64,
    /// Counters folded in from already-detached subscriber queues, so a
    /// subscriber leaving never loses its share of the accounting.
    retired: SubscriberCounters,
    retired_hist: [u64; LATENCY_BUCKETS],
}

/// One named stream shared by any number of publishers and subscribers.
/// All counters are plain integers inside `state` — every read and
/// write happens under the topic (or a subscriber endpoint's) mutex, so
/// a [`snapshot`](TopicInner::snapshot) taken mid-stream is a consistent
/// cut, never a racy read of independently updated atomics.
pub(crate) struct TopicInner {
    name: String,
    /// Default capacity of newly created subscriber queues.
    default_cap: usize,
    state: Mutex<TopicState>,
    /// Application publishers blocking for space / topic events.
    space: Condvar,
}

impl TopicInner {
    fn new(name: &str, default_cap: usize) -> Arc<TopicInner> {
        Arc::new(TopicInner {
            name: name.to_string(),
            default_cap,
            state: Mutex::new(TopicState {
                subs: Vec::new(),
                open_publishers: 0,
                eos: false,
                fault: None,
                caps: None,
                publisher_wakers: Vec::new(),
                published: 0,
                no_sub_drops: 0,
                retired: SubscriberCounters::default(),
                retired_hist: [0; LATENCY_BUCKETS],
            }),
            space: Condvar::new(),
        })
    }

    pub(crate) fn name(&self) -> &str {
        &self.name
    }

    /// Register one publisher. Re-attaching to an ended topic reopens it
    /// for future subscribers (already-ended subscriptions stay ended).
    pub(crate) fn attach_publisher(&self) {
        let mut g = lock(&self.state);
        if g.eos {
            // new stream generation: the previous generation's fault (if
            // any) already reached its subscribers and must not taint
            // this one
            g.fault = None;
        }
        g.open_publishers += 1;
        g.eos = false;
    }

    /// A publisher is detaching because its pipeline faulted: record the
    /// fault (first wins) so that when the *last* publisher detaches the
    /// subscribers end with [`StreamEnd::Fault`]. Callers pair this with
    /// [`publisher_done`](TopicInner::publisher_done).
    pub(crate) fn publisher_fault(&self, fault: &Fault) {
        let mut g = lock(&self.state);
        if g.fault.is_none() {
            g.fault = Some(fault.clone());
        }
    }

    /// Record the caps flowing on this topic (first publisher wins).
    pub(crate) fn advertise_caps(&self, caps: &Caps) {
        let mut g = lock(&self.state);
        if g.caps.is_none() && !matches!(caps, Caps::Any) {
            g.caps = Some(caps.clone());
        }
    }

    pub(crate) fn caps(&self) -> Option<Caps> {
        lock(&self.state).caps.clone()
    }

    pub(crate) fn subscriber_count(&self) -> usize {
        lock(&self.state).subs.len()
    }

    /// Register the waker of an element publisher (woken when space or a
    /// subscriber appears, or the topic ends).
    pub(crate) fn add_publisher_waker(&self, w: &Arc<SharedWaker>) {
        let mut g = lock(&self.state);
        if !g.publisher_wakers.iter().any(|x| Arc::ptr_eq(x, w)) {
            g.publisher_wakers.push(w.clone());
        }
    }

    /// One publisher finished; the last one ends the stream for every
    /// subscriber (their queues drain, then report `End`).
    pub(crate) fn publisher_done(&self) {
        let (ended, wakers, fault) = {
            let mut g = lock(&self.state);
            g.open_publishers = g.open_publishers.saturating_sub(1);
            if g.open_publishers == 0 {
                g.eos = true;
                (g.subs.clone(), g.publisher_wakers.clone(), g.fault.clone())
            } else {
                (Vec::new(), Vec::new(), None)
            }
        };
        for ep in &ended {
            // outside the topic lock: waking re-enters it via notify_space
            match &fault {
                Some(f) => ep.fail(f),
                None => ep.set_eos(),
            }
        }
        self.space.notify_all();
        for w in &wakers {
            w.wake();
        }
    }

    /// Wake every publisher-side waiter (called by subscriber queues
    /// after a pop frees space, and on subscribe/unsubscribe).
    pub(crate) fn notify_space(&self) {
        let wakers = lock(&self.state).publisher_wakers.clone();
        self.space.notify_all();
        for w in &wakers {
            w.wake();
        }
    }

    /// Deliver one buffer to every subscriber queue, atomically with
    /// respect to other publishers and (un)subscriptions. With a
    /// `Blocking` publisher, either every blocking-mode queue takes it,
    /// or none does and the caller parks/drops; space is re-checked
    /// under the topic lock, so a replayed buffer is never
    /// double-delivered to the subscribers that had room the first
    /// time. A non-blocking publisher QoS (`leaky`/`latest-only` on
    /// `tensor_query_serversink`) never observes `Full`: full queues
    /// shed per the publisher's mode instead of gating it.
    pub(crate) fn try_publish(self: &Arc<Self>, buf: Buffer, qos: Qos) -> TopicPush {
        let mut g = lock(&self.state);
        if g.eos {
            return TopicPush::Closed(buf);
        }
        if g.subs.is_empty() {
            // not counted as dropped here: the caller may park and replay
            // this frame (wait-subscribers, a query client waiting for its
            // service) — it records the drop only when it truly discards
            return TopicPush::NoSubscribers(buf);
        }
        if qos == Qos::Blocking && g.subs.iter().any(|s| s.gates_publisher()) {
            return TopicPush::Full(buf);
        }
        Self::deliver_locked(&mut g, buf, qos);
        TopicPush::Ok
    }

    /// Fan the buffer out while the topic lock is held. Each queue is
    /// offered the buffer under its **effective** QoS: the publisher's
    /// mode when the publisher is non-blocking (it refuses to be gated,
    /// so full queues shed), the subscriber's own mode otherwise. The
    /// last subscriber takes the original buffer, the others clones —
    /// chunks are Arc-backed, so clones share payload storage.
    fn deliver_locked(g: &mut MutexGuard<'_, TopicState>, buf: Buffer, qos: Qos) {
        let n = g.subs.len();
        let mut buf = Some(buf);
        for (i, ep) in g.subs.iter().enumerate() {
            let item = if i + 1 == n {
                buf.take().expect("buffer consumed once")
            } else {
                buf.as_ref().expect("buffer present").clone()
            };
            let effective = if qos == Qos::Blocking { ep.qos() } else { qos };
            ep.offer(item, effective);
        }
        g.published += 1;
    }

    /// Blocking publish (application publishers): waits for space;
    /// drops (returning `Ok(false)`) when nobody subscribes, errors once
    /// the stream ended. Only blocking-mode subscriber queues gate the
    /// wait — leaky/latest-only subscribers shed instead.
    pub(crate) fn publish_blocking(self: &Arc<Self>, buf: Buffer) -> Result<bool> {
        let mut g = lock(&self.state);
        loop {
            if g.eos {
                return Err(Error::Runtime(format!(
                    "topic {:?}: stream already ended",
                    self.name
                )));
            }
            if g.subs.is_empty() {
                g.no_sub_drops += 1;
                return Ok(false);
            }
            if !g.subs.iter().any(|s| s.gates_publisher()) {
                Self::deliver_locked(&mut g, buf, Qos::Blocking);
                return Ok(true);
            }
            g = self.space.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Record one publisher-side discard (a frame published while nobody
    /// subscribed and not replayed).
    pub(crate) fn count_dropped(&self) {
        lock(&self.state).no_sub_drops += 1;
    }

    /// Attach a bounded subscriber queue with a delivery mode.
    /// Subscribing to an ended topic yields an immediately-ended queue.
    pub(crate) fn subscribe(self: &Arc<Self>, cap: Option<usize>, qos: Qos) -> Arc<Endpoint> {
        let ep = Endpoint::new(
            cap.unwrap_or(self.default_cap),
            qos,
            Some(Arc::downgrade(self)),
        );
        let (ended, fault) = {
            let mut g = lock(&self.state);
            g.subs.push(ep.clone());
            (g.eos, g.fault.clone())
        };
        if ended {
            // outside the topic lock: ending wakes through notify_space
            match &fault {
                Some(f) => ep.fail(f),
                None => ep.set_eos(),
            }
        }
        // publishers parked on wait-subscribers= (or full siblings that
        // no longer matter) re-check
        self.notify_space();
        ep
    }

    /// Detach (and close) one subscriber queue; parked publishers are
    /// released — a leaving subscriber must not wedge the stream. The
    /// queue's counters are folded into the topic's retired totals
    /// under the topic lock, so the detach is atomic with respect to
    /// [`snapshot`](TopicInner::snapshot) and no accounting is lost.
    pub(crate) fn unsubscribe(&self, ep: &Arc<Endpoint>) {
        let wakers = {
            let mut g = lock(&self.state);
            let attached = g.subs.iter().any(|s| Arc::ptr_eq(s, ep));
            g.subs.retain(|s| !Arc::ptr_eq(s, ep));
            // Close quietly: waking from under the topic lock would
            // re-enter this mutex through `notify_space`.
            let ((counters, hist), wakers) = ep.close_quiet();
            if attached {
                g.retired.fold(&counters);
                merge_latency(&mut g.retired_hist, &hist);
            }
            wakers
        };
        ep.wake_both(wakers);
        self.notify_space();
    }

    /// Consistent counter cut of this topic: taken entirely under the
    /// topic lock (and each live queue's own lock), so mid-stream
    /// reports obey the conservation and ordering invariants — e.g.
    /// `delivered` never exceeds `pushed`, and
    /// `pushed == delivered + dropped + in_flight` exactly.
    pub(crate) fn snapshot(&self) -> TopicSnapshot {
        let g = lock(&self.state);
        let mut agg = g.retired;
        let mut hist = g.retired_hist;
        for ep in &g.subs {
            let (c, h) = ep.counters_and_hist();
            agg.fold(&c);
            merge_latency(&mut hist, &h);
        }
        let drops = TopicDrops {
            no_subscriber: g.no_sub_drops,
            ..agg.dropped
        };
        // Aggregate conservation: summing the per-queue identity over
        // live and retired queues (each exact under its own lock) and
        // adding publisher-side no-subscriber discards to both sides.
        debug_assert_eq!(
            agg.pushed + g.no_sub_drops,
            agg.delivered + drops.total() + agg.in_flight,
            "topic {:?}: aggregate conservation violated",
            self.name
        );
        TopicSnapshot {
            name: self.name.clone(),
            publishers: g.open_publishers,
            subscribers: g.subs.len(),
            eos: g.eos,
            published: g.published,
            pushed: agg.pushed + g.no_sub_drops,
            delivered: agg.delivered,
            dropped: drops.total(),
            drops,
            in_flight: agg.in_flight,
            latency: summarize_latency(&hist),
        }
    }
}

// ---------------------------------------------------------------------------
// StreamRegistry
// ---------------------------------------------------------------------------

#[derive(Default)]
struct RegistryInner {
    topics: Mutex<HashMap<String, Arc<TopicInner>>>,
}

/// Registry of named stream topics — the hub-owned name service of the
/// among-device composition surface. Cheap to clone (shared handle);
/// [`StreamRegistry::global`] is the process-wide instance every
/// `tensor_query_*` element and [`PipelineHub`] resolves topics in.
///
/// [`PipelineHub`]: crate::pipeline::PipelineHub
#[derive(Clone, Default)]
pub struct StreamRegistry {
    inner: Arc<RegistryInner>,
}

impl StreamRegistry {
    /// An isolated registry (tests; multi-tenant setups that must not
    /// share topic names).
    pub fn new() -> StreamRegistry {
        StreamRegistry::default()
    }

    /// The process-wide registry (like the model pool: pipelines compose
    /// across hubs and executors through one namespace).
    pub fn global() -> &'static StreamRegistry {
        static GLOBAL: Lazy<StreamRegistry> = Lazy::new(StreamRegistry::new);
        &GLOBAL
    }

    /// Get-or-create a topic.
    pub(crate) fn topic(&self, name: &str) -> Arc<TopicInner> {
        let mut g = lock(&self.inner.topics);
        g.entry(name.to_string())
            .or_insert_with(|| TopicInner::new(name, DEFAULT_ENDPOINT_CAPACITY))
            .clone()
    }

    /// Names of every topic ever referenced, sorted.
    pub fn topic_names(&self) -> Vec<String> {
        let mut v: Vec<String> = lock(&self.inner.topics).keys().cloned().collect();
        v.sort();
        v
    }

    /// Per-topic counters (sorted by topic name).
    pub fn snapshot(&self) -> Vec<TopicSnapshot> {
        let topics: Vec<Arc<TopicInner>> =
            lock(&self.inner.topics).values().cloned().collect();
        let mut v: Vec<TopicSnapshot> = topics.iter().map(|t| t.snapshot()).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// A publisher handle on `topic`: [`TopicPublisher::push`] blocks
    /// while any subscriber queue is saturated and drops (reporting it)
    /// while nobody subscribes.
    pub fn publish(&self, topic: &str) -> TopicPublisher {
        let t = self.topic(topic);
        t.attach_publisher();
        TopicPublisher {
            topic: t,
            done: false,
        }
    }

    /// A subscriber handle on `topic` with the default queue bound and
    /// lossless (`blocking`) delivery.
    pub fn subscribe(&self, topic: &str) -> TopicSubscriber {
        self.subscribe_with(topic, DEFAULT_ENDPOINT_CAPACITY, Qos::Blocking)
    }

    /// A subscriber handle with an explicit queue bound (small bounds
    /// make a slow consumer exert backpressure sooner).
    pub fn subscribe_with_capacity(&self, topic: &str, capacity: usize) -> TopicSubscriber {
        self.subscribe_with(topic, capacity, Qos::Blocking)
    }

    /// A subscriber handle with an explicit delivery mode: `leaky` and
    /// `latest-only` subscribers absorb overload by dropping (typed and
    /// counted) instead of backpressuring the publisher — one flooded
    /// tenant cannot stall the stream for everyone else.
    pub fn subscribe_with_qos(&self, topic: &str, qos: Qos) -> TopicSubscriber {
        self.subscribe_with(topic, DEFAULT_ENDPOINT_CAPACITY, qos)
    }

    /// The general subscription form: explicit queue bound and QoS.
    pub fn subscribe_with(&self, topic: &str, capacity: usize, qos: Qos) -> TopicSubscriber {
        let t = self.topic(topic);
        let ep = t.subscribe(Some(capacity), qos);
        TopicSubscriber { topic: t, ep }
    }

    /// A request/response handle over a pair of topics: requests go out
    /// on `request`, responses come back on `reply` (see
    /// [`QueryClient`]). The reply subscription attaches first, so no
    /// response can be lost to ordering.
    pub fn query_client(&self, request: &str, reply: &str) -> QueryClient {
        let rep = self.subscribe(reply);
        let req = self.publish(request);
        QueryClient {
            inner: Mutex::new(QueryClientInner { req, rep }),
        }
    }
}

// ---------------------------------------------------------------------------
// Application-side handles
// ---------------------------------------------------------------------------

/// Application-side publisher on a named topic (from
/// [`PipelineHub::publish`](crate::pipeline::PipelineHub::publish) or
/// [`StreamRegistry::publish`]). The producing counterpart of an
/// `appsrc` handle, minus the pipeline: anything subscribed to the topic
/// — `tensor_query_serversrc` elements, application
/// [`TopicSubscriber`]s — receives every pushed buffer, in order.
pub struct TopicPublisher {
    topic: Arc<TopicInner>,
    done: bool,
}

impl TopicPublisher {
    /// Publish one buffer. Blocks while any subscriber queue is
    /// saturated (backpressure); returns `Ok(false)` when nobody is
    /// subscribed (the buffer is dropped and counted, pub/sub style).
    pub fn push(&self, buf: Buffer) -> Result<bool> {
        if self.done {
            return Err(Error::Runtime(format!(
                "topic {:?}: publisher already ended",
                self.topic.name()
            )));
        }
        self.topic.publish_blocking(buf)
    }

    /// Non-blocking publish: reports what happened instead of waiting
    /// for space. Useful for load generators and the QoS property
    /// suite; pipelines use the element ports, applications normally
    /// the blocking [`push`](TopicPublisher::push).
    pub fn try_push(&self, buf: Buffer) -> PushOutcome {
        if self.done {
            return PushOutcome::Closed;
        }
        match self.topic.try_publish(buf, Qos::Blocking) {
            TopicPush::Ok => PushOutcome::Delivered,
            TopicPush::NoSubscribers(_) => {
                self.topic.count_dropped();
                PushOutcome::NoSubscribers
            }
            TopicPush::Full(_) => PushOutcome::Full,
            TopicPush::Closed(_) => PushOutcome::Closed,
        }
    }

    /// Subscribers currently attached.
    pub fn subscriber_count(&self) -> usize {
        self.topic.subscriber_count()
    }

    /// Announce caps for late subscriber elements with no explicit
    /// `caps=` configuration.
    pub fn advertise(&self, caps: &Caps) {
        self.topic.advertise_caps(caps);
    }

    /// End this publisher's stream; the topic reaches end-of-stream once
    /// every publisher ended (also implied by dropping the handle).
    pub fn end(&mut self) {
        if !self.done {
            self.done = true;
            self.topic.publisher_done();
        }
    }
}

impl Drop for TopicPublisher {
    fn drop(&mut self) {
        self.end();
    }
}

/// Outcome of a non-blocking [`TopicPublisher::try_push`]. Unlike the
/// crate-internal [`TopicPush`], the undelivered buffer is discarded
/// (and a `NoSubscribers` outcome counted as a drop) — callers that
/// need replay semantics use the element ports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// Offered to every subscriber queue (each per its effective QoS).
    Delivered,
    /// Nobody subscribed; counted as a `no_subscriber` drop.
    NoSubscribers,
    /// A blocking-mode subscriber queue is at capacity.
    Full,
    /// The stream (or this publisher) already ended.
    Closed,
}

/// Application-side subscriber on a named topic (from
/// [`PipelineHub::subscribe`](crate::pipeline::PipelineHub::subscribe)).
/// Mirrors the `AppSinkReceiver` surface: `recv` blocks until the next
/// buffer and errors once the topic reached end-of-stream (or the hub
/// closed the handle via `request_stop_all`), so drain loops terminate.
pub struct TopicSubscriber {
    topic: Arc<TopicInner>,
    ep: Arc<Endpoint>,
}

impl TopicSubscriber {
    /// Block until the next buffer; errors once the stream ended and the
    /// queue drained.
    pub fn recv(&self) -> std::result::Result<Buffer, RecvError> {
        self.ep.pop_blocking().ok_or(RecvError)
    }

    pub fn try_recv(&self) -> std::result::Result<Buffer, TryRecvError> {
        match self.ep.try_pop() {
            EpPop::Item(b) => Ok(b),
            EpPop::Empty => Err(TryRecvError::Empty),
            EpPop::End => Err(TryRecvError::Disconnected),
        }
    }

    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<Buffer, RecvTimeoutError> {
        match self.ep.pop_timeout(timeout) {
            EpPop::Item(b) => Ok(b),
            EpPop::Empty => Err(RecvTimeoutError::Timeout),
            EpPop::End => Err(RecvTimeoutError::Disconnected),
        }
    }

    /// Drain iterator; terminates at topic end-of-stream.
    pub fn iter(&self) -> impl Iterator<Item = Buffer> + '_ {
        std::iter::from_fn(move || self.recv().ok())
    }

    /// Why this subscription's stream ended — `None` while it is still
    /// open. After [`recv`](TopicSubscriber::recv) errors, this
    /// distinguishes a clean end of stream ([`StreamEnd::Eos`]) from a
    /// publisher pipeline dying mid-stream ([`StreamEnd::Fault`],
    /// carrying the originating element and cause across the topic) and
    /// from a hub-initiated cancellation ([`StreamEnd::Closed`]).
    pub fn close_reason(&self) -> Option<StreamEnd> {
        self.ep.close_reason()
    }

    /// Name of the subscribed topic.
    pub fn topic(&self) -> &str {
        self.topic.name()
    }

    /// This subscription's delivery mode.
    pub fn qos(&self) -> Qos {
        self.ep.qos()
    }

    /// Exact counter snapshot of this subscription's queue (taken under
    /// the queue lock): `pushed`, `delivered`, typed drops, `in_flight`.
    pub fn counters(&self) -> SubscriberCounters {
        self.ep.counters_and_hist().0
    }

    /// A weak closer the hub keeps so `request_stop_all` can terminate
    /// application drain loops over this handle.
    pub(crate) fn close_handle(&self) -> SubscriberClose {
        SubscriberClose {
            topic: self.topic.clone(),
            ep: Arc::downgrade(&self.ep),
        }
    }
}

impl Drop for TopicSubscriber {
    fn drop(&mut self) {
        self.topic.unsubscribe(&self.ep);
    }
}

/// Weak handle that closes one hub-issued topic subscription (kept by
/// [`PipelineHub`](crate::pipeline::PipelineHub) for `request_stop_all`).
pub(crate) struct SubscriberClose {
    topic: Arc<TopicInner>,
    ep: Weak<Endpoint>,
}

impl SubscriberClose {
    pub(crate) fn close(&self) {
        if let Some(ep) = self.ep.upgrade() {
            self.topic.unsubscribe(&ep);
        }
    }

    /// The subscriber handle this closer targets was already dropped.
    pub(crate) fn is_dead(&self) -> bool {
        self.ep.upgrade().is_none()
    }
}

struct QueryClientInner {
    req: TopicPublisher,
    rep: TopicSubscriber,
}

/// Request/response handle over a pair of topics — SingleShot over a
/// *remote* pipeline: push one buffer to the service's request topic,
/// block for the next buffer on its reply topic. One request is in
/// flight at a time (requests from multiple threads serialize), and
/// responses correlate by order, so run exactly one `QueryClient` per
/// reply topic.
///
/// Obtain one from
/// [`PipelineHub::query_client`](crate::pipeline::PipelineHub::query_client),
/// [`StreamRegistry::query_client`], or — paired with a
/// [`QueryService`](crate::runtime::QueryService) — via
/// [`QueryClient::connect`].
pub struct QueryClient {
    inner: Mutex<QueryClientInner>,
}

impl QueryClient {
    /// Connect to a [`QueryService`](crate::runtime::QueryService)-style
    /// topic pair `<topic>/in` → `<topic>/out` in the global registry.
    pub fn connect(service_topic: &str) -> QueryClient {
        StreamRegistry::global().query_client(
            &format!("{service_topic}/in"),
            &format!("{service_topic}/out"),
        )
    }

    /// One request/response round trip. Fails fast when no pipeline is
    /// serving the request topic, and errors if the service ends before
    /// replying.
    pub fn invoke(&self, request: Buffer) -> Result<Buffer> {
        let g = lock(&self.inner);
        if g.req.subscriber_count() == 0 {
            return Err(Error::Runtime(format!(
                "query: no pipeline is serving topic {:?}",
                g.req.topic.name()
            )));
        }
        if !g.req.push(request)? {
            return Err(Error::Runtime(format!(
                "query: service left topic {:?} before the request was taken",
                g.req.topic.name()
            )));
        }
        g.rep.recv().map_err(|_| match g.rep.close_reason() {
            Some(StreamEnd::Fault(f)) => Error::Fault(f),
            _ => Error::Runtime(format!(
                "query: service on topic {:?} ended before replying",
                g.req.topic.name()
            )),
        })
    }

    /// [`invoke`](QueryClient::invoke) on raw f32 tensors, mirroring
    /// [`SingleShot::invoke`](crate::runtime::SingleShot::invoke).
    pub fn invoke_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let chunks: Vec<crate::tensor::Chunk> = inputs
            .iter()
            .map(|d| crate::tensor::Chunk::from_f32(d))
            .collect();
        let out = self.invoke(Buffer::new(0, chunks))?;
        out.chunks.iter().map(|c| c.to_f32_vec()).collect()
    }
}

// ---------------------------------------------------------------------------
// Transport: pluggable delivery behind the endpoint contract
// ---------------------------------------------------------------------------

/// Outcome of a publisher-port send.
pub enum PortSend {
    Sent,
    /// Nobody subscribed — caller drops (default) or parks
    /// (`wait-subscribers=`).
    NoSubscribers(Buffer),
    /// A subscriber queue is saturated — park until space.
    Full(Buffer),
    /// The stream ended.
    Closed(Buffer),
}

/// Outcome of a subscriber-port receive.
pub enum PortRecv {
    Item(Buffer),
    Empty,
    End,
}

/// Producing side of one topic attachment, as used by
/// `tensor_query_serversink` and `tensor_query_client`. Dropping the
/// port without [`finish`](PublisherPort::finish) still detaches
/// (error-path safety).
pub trait PublisherPort: Send {
    /// Announce the caps flowing on the topic.
    fn advertise(&mut self, caps: &Caps);
    /// Non-blocking delivery; see [`PortSend`].
    fn try_send(&mut self, buf: Buffer) -> PortSend;
    fn subscriber_count(&self) -> usize;
    /// Register the element task's waker (woken on space/subscribe/EOS).
    fn add_waker(&mut self, w: &Arc<SharedWaker>);
    /// Record that the caller discarded a frame
    /// [`try_send`](PublisherPort::try_send) could not deliver (surfaces
    /// in the topic's `dropped` counter).
    fn count_dropped(&mut self);
    /// This publisher reached end-of-stream (idempotent).
    fn finish(&mut self);
    /// This publisher's pipeline died on `fault`: end the stream like
    /// [`finish`](PublisherPort::finish), but deliver the fault as the
    /// subscribers' close-reason so remote consumers see a truncated
    /// stream, never a clean EOS. Transports without fault support fall
    /// back to a plain finish.
    fn fail(&mut self, _fault: &Fault) {
        self.finish();
    }
}

/// Consuming side of one topic attachment, as used by
/// `tensor_query_serversrc` and `tensor_query_client`. Dropping the
/// port detaches the subscription.
pub trait SubscriberPort: Send {
    /// Caps advertised by the topic's publisher, if any yet.
    fn topic_caps(&self) -> Option<Caps>;
    /// Non-blocking receive; see [`PortRecv`].
    fn try_recv(&mut self) -> PortRecv;
    /// Register the element task's waker (woken on data/EOS).
    fn add_waker(&mut self, w: &Arc<SharedWaker>);
    /// Detach the subscription (idempotent; implied by drop).
    fn detach(&mut self);
    /// Why the stream ended (`None` while open). Lets the consuming
    /// element turn [`PortRecv::End`] into a typed fault instead of a
    /// clean EOS when the publisher pipeline died. Transports without
    /// fault support report `None` and the consumer treats `End` as EOS.
    fn close_reason(&self) -> Option<StreamEnd> {
        None
    }
}

/// A tensor-query delivery backend. The in-process transport is the
/// only one today; socket/network backends register under a new name
/// ([`register_transport`]) and the element API — `transport=` — stays
/// unchanged.
pub trait Transport: Send + Sync {
    fn name(&self) -> &'static str;
    /// Attach a publisher to `topic`. A non-blocking `qos`
    /// (`leaky`/`latest-only`) makes the publisher shed on full
    /// subscriber queues instead of observing `Full` and parking.
    fn advertise(&self, topic: &str, qos: Qos) -> Result<Box<dyn PublisherPort>>;
    /// Attach a bounded subscriber to `topic` with a delivery mode.
    fn attach(&self, topic: &str, capacity: usize, qos: Qos) -> Result<Box<dyn SubscriberPort>>;
}

/// The in-process transport: topics resolve in a [`StreamRegistry`].
pub struct InProcTransport {
    registry: StreamRegistry,
}

impl InProcTransport {
    pub fn new(registry: StreamRegistry) -> InProcTransport {
        InProcTransport { registry }
    }
}

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn advertise(&self, topic: &str, qos: Qos) -> Result<Box<dyn PublisherPort>> {
        Ok(topic_publisher_port(self.registry.topic(topic), qos))
    }

    fn attach(&self, topic: &str, capacity: usize, qos: Qos) -> Result<Box<dyn SubscriberPort>> {
        let t = self.registry.topic(topic);
        let ep = t.subscribe(Some(capacity), qos);
        Ok(Box::new(InProcSubscriberPort {
            topic: t,
            ep,
            detached: false,
        }))
    }
}

/// Build a publisher port bound directly to `topic`, registering one
/// publisher on it. Shared by the in-process transport and the serve
/// side of network transports — a served topic's per-subscriber queues
/// are remote connections, but the publisher-facing mechanics (QoS
/// fan-out, wait-subscribers parking, fault-vs-EOS close) are
/// identical, so both speak through the same port.
pub(crate) fn topic_publisher_port(topic: Arc<TopicInner>, qos: Qos) -> Box<dyn PublisherPort> {
    topic.attach_publisher();
    Box::new(InProcPublisherPort {
        topic,
        qos,
        finished: false,
    })
}

struct InProcPublisherPort {
    topic: Arc<TopicInner>,
    qos: Qos,
    finished: bool,
}

impl PublisherPort for InProcPublisherPort {
    fn advertise(&mut self, caps: &Caps) {
        self.topic.advertise_caps(caps);
    }

    fn try_send(&mut self, buf: Buffer) -> PortSend {
        if self.finished {
            return PortSend::Closed(buf);
        }
        match self.topic.try_publish(buf, self.qos) {
            TopicPush::Ok => PortSend::Sent,
            TopicPush::NoSubscribers(b) => PortSend::NoSubscribers(b),
            TopicPush::Full(b) => PortSend::Full(b),
            TopicPush::Closed(b) => PortSend::Closed(b),
        }
    }

    fn subscriber_count(&self) -> usize {
        self.topic.subscriber_count()
    }

    fn add_waker(&mut self, w: &Arc<SharedWaker>) {
        self.topic.add_publisher_waker(w);
    }

    fn count_dropped(&mut self) {
        self.topic.count_dropped();
    }

    fn finish(&mut self) {
        if !self.finished {
            self.finished = true;
            self.topic.publisher_done();
        }
    }

    fn fail(&mut self, fault: &Fault) {
        if !self.finished {
            self.finished = true;
            self.topic.publisher_fault(fault);
            self.topic.publisher_done();
        }
    }
}

impl Drop for InProcPublisherPort {
    fn drop(&mut self) {
        self.finish();
    }
}

struct InProcSubscriberPort {
    topic: Arc<TopicInner>,
    ep: Arc<Endpoint>,
    detached: bool,
}

impl SubscriberPort for InProcSubscriberPort {
    fn topic_caps(&self) -> Option<Caps> {
        self.topic.caps()
    }

    fn try_recv(&mut self) -> PortRecv {
        if self.detached {
            return PortRecv::End;
        }
        match self.ep.try_pop() {
            EpPop::Item(b) => PortRecv::Item(b),
            EpPop::Empty => PortRecv::Empty,
            EpPop::End => PortRecv::End,
        }
    }

    fn add_waker(&mut self, w: &Arc<SharedWaker>) {
        self.ep.add_consumer_waker(w);
    }

    fn detach(&mut self) {
        if !self.detached {
            self.detached = true;
            self.topic.unsubscribe(&self.ep);
        }
    }

    fn close_reason(&self) -> Option<StreamEnd> {
        self.ep.close_reason()
    }
}

impl Drop for InProcSubscriberPort {
    fn drop(&mut self) {
        self.detach();
    }
}

static TRANSPORTS: Lazy<Mutex<HashMap<String, Arc<dyn Transport>>>> = Lazy::new(|| {
    let mut m: HashMap<String, Arc<dyn Transport>> = HashMap::new();
    m.insert(
        "inproc".to_string(),
        Arc::new(InProcTransport::new(StreamRegistry::global().clone())),
    );
    Mutex::new(m)
});

/// Register a tensor-query transport backend (plug-in style, mirroring
/// [`Registry::register`](crate::element::Registry::register)).
pub fn register_transport(name: &str, transport: Arc<dyn Transport>) {
    lock(&TRANSPORTS).insert(name.to_string(), transport);
}

/// Resolve a transport by name; unknown names suggest the nearest
/// registered one.
pub fn transport(name: &str) -> Result<Arc<dyn Transport>> {
    let g = lock(&TRANSPORTS);
    g.get(name).cloned().ok_or_else(|| {
        let names = g.keys().map(String::as_str);
        Error::Runtime(format!(
            "no such tensor-query transport {name:?}{}",
            crate::element::registry::did_you_mean(name, names)
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf(v: f32) -> Buffer {
        Buffer::from_f32(0, &[v])
    }

    #[test]
    fn endpoint_fifo_and_eos() {
        let ep = Endpoint::standalone(4);
        assert!(matches!(ep.try_push(buf(1.0)), EpPush::Ok));
        assert!(matches!(ep.try_push(buf(2.0)), EpPush::Ok));
        ep.set_eos();
        // queued items drain before End
        match ep.try_pop() {
            EpPop::Item(b) => assert_eq!(b.chunk().as_f32().unwrap(), &[1.0]),
            _ => panic!("expected item"),
        }
        assert!(matches!(ep.try_pop(), EpPop::Item(_)));
        assert!(matches!(ep.try_pop(), EpPop::End));
        // pushes after eos are rejected
        assert!(matches!(ep.try_push(buf(3.0)), EpPush::Closed(_)));
    }

    #[test]
    fn endpoint_full_and_close() {
        let ep = Endpoint::standalone(1);
        assert!(matches!(ep.try_push(buf(1.0)), EpPush::Ok));
        assert!(matches!(ep.try_push(buf(2.0)), EpPush::Full(_)));
        ep.close();
        assert!(matches!(ep.try_pop(), EpPop::End));
        assert!(matches!(ep.try_push(buf(3.0)), EpPush::Closed(_)));
    }

    #[test]
    fn topic_fans_out_to_every_subscriber() {
        let reg = StreamRegistry::new();
        let s1 = reg.subscribe("t");
        let s2 = reg.subscribe("t");
        let mut p = reg.publish("t");
        assert!(p.push(buf(5.0)).unwrap());
        assert_eq!(s1.recv().unwrap().chunk().as_f32().unwrap(), &[5.0]);
        assert_eq!(s2.recv().unwrap().chunk().as_f32().unwrap(), &[5.0]);
        p.end();
        assert!(s1.recv().is_err(), "eos closes subscriber 1");
        assert!(s2.recv().is_err(), "eos closes subscriber 2");
    }

    #[test]
    fn publish_without_subscribers_drops() {
        let reg = StreamRegistry::new();
        let p = reg.publish("lonely");
        assert!(!p.push(buf(1.0)).unwrap(), "no subscriber: dropped");
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].dropped, 1);
        assert_eq!(snap[0].published, 0);
    }

    #[test]
    fn late_subscriber_to_ended_topic_sees_end() {
        let reg = StreamRegistry::new();
        let mut p = reg.publish("t");
        p.end();
        let s = reg.subscribe("t");
        assert!(s.try_recv().is_err());
    }

    #[test]
    fn subscriber_drop_releases_publisher() {
        let reg = StreamRegistry::new();
        let s = reg.subscribe_with_capacity("t", 1);
        let p = reg.publish("t");
        assert!(p.push(buf(1.0)).unwrap());
        // queue full now; dropping the subscriber must unblock pushes
        drop(s);
        // with no subscribers remaining, pushes drop instead of blocking
        assert!(!p.push(buf(2.0)).unwrap());
    }

    #[test]
    fn app_push_blocks_until_consumer_drains() {
        let reg = StreamRegistry::new();
        let s = reg.subscribe_with_capacity("t", 2);
        let p = reg.publish("t");
        let producer = std::thread::spawn(move || {
            for i in 0..8 {
                assert!(p.push(buf(i as f32)).unwrap());
            }
        });
        let mut got = Vec::new();
        for b in s.iter().take(8) {
            got.push(b.chunk().as_f32().unwrap()[0]);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..8).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn transport_lookup_suggests_nearest() {
        assert!(transport("inproc").is_ok());
        let err = transport("inprc").unwrap_err().to_string();
        assert!(err.contains("did you mean \"inproc\"?"), "{err}");
    }

    #[test]
    fn registry_snapshot_counts() {
        let reg = StreamRegistry::new();
        let s = reg.subscribe("a");
        let p = reg.publish("a");
        assert!(p.push(buf(1.0)).unwrap());
        assert!(p.push(buf(2.0)).unwrap());
        let mid = reg.snapshot();
        assert_eq!(mid[0].published, 2);
        assert_eq!(mid[0].pushed, 2);
        assert_eq!(mid[0].delivered, 0, "delivered counts consumer pops");
        assert_eq!(mid[0].in_flight, 2);
        // dropping the subscriber retires its queue: the two undelivered
        // buffers become typed `closed` drops, conservation holds
        drop(s);
        let snap = reg.snapshot();
        assert_eq!(snap[0].published, 2);
        assert_eq!(snap[0].pushed, 2);
        assert_eq!(snap[0].delivered, 0);
        assert_eq!(snap[0].drops.closed, 2);
        assert_eq!(snap[0].in_flight, 0);
        assert_eq!(
            snap[0].pushed,
            snap[0].delivered + snap[0].dropped + snap[0].in_flight
        );
        assert_eq!(snap[0].subscribers, 0);
        assert_eq!(snap[0].publishers, 1);
    }

    #[test]
    fn snapshot_never_shows_delivered_over_pushed_or_published() {
        // single subscriber: every popped buffer was pushed and every
        // pushed buffer was published first, so any consistent cut obeys
        // delivered <= pushed <= published
        let reg = StreamRegistry::new();
        let s = reg.subscribe("a");
        let p = reg.publish("a");
        for i in 0..5 {
            assert!(p.push(buf(i as f32)).unwrap());
        }
        for _ in 0..3 {
            s.recv().unwrap();
        }
        let snap = reg.snapshot();
        assert!(snap[0].delivered <= snap[0].pushed);
        assert!(snap[0].delivered <= snap[0].published);
        assert_eq!(snap[0].delivered, 3);
        assert_eq!(snap[0].in_flight, 2);
        assert!(snap[0].latency.count == 3, "3 pops recorded latency");
    }

    #[test]
    fn leaky_subscriber_sheds_newest_without_gating_publisher() {
        let reg = StreamRegistry::new();
        let s = reg.subscribe_with("t", 2, Qos::Leaky);
        let p = reg.publish("t");
        // capacity 2: pushes 3.. are shed, but none of them blocks
        for i in 0..5 {
            assert!(p.push(buf(i as f32)).unwrap());
        }
        let c = s.counters();
        assert_eq!(c.pushed, 5);
        assert_eq!(c.in_flight, 2);
        assert_eq!(c.dropped.qos_leaky, 3);
        assert_eq!(c.pushed, c.delivered + c.dropped.subscriber_total() + c.in_flight);
        // the two oldest survive (leaky drops the arriving frame)
        assert_eq!(s.recv().unwrap().chunk().as_f32().unwrap(), &[0.0]);
        assert_eq!(s.recv().unwrap().chunk().as_f32().unwrap(), &[1.0]);
    }

    #[test]
    fn latest_only_subscriber_keeps_freshest() {
        let reg = StreamRegistry::new();
        let s = reg.subscribe_with("t", 2, Qos::LatestOnly);
        let p = reg.publish("t");
        for i in 0..5 {
            assert!(p.push(buf(i as f32)).unwrap());
        }
        let c = s.counters();
        assert_eq!(c.pushed, 5);
        assert_eq!(c.dropped.qos_latest, 3);
        assert_eq!(c.in_flight, 2);
        // the two newest survive (oldest evicted on overflow)
        assert_eq!(s.recv().unwrap().chunk().as_f32().unwrap(), &[3.0]);
        assert_eq!(s.recv().unwrap().chunk().as_f32().unwrap(), &[4.0]);
    }

    #[test]
    fn mixed_qos_fanout_gates_only_on_blocking() {
        let reg = StreamRegistry::new();
        let fast = reg.subscribe_with("t", 8, Qos::Blocking);
        let slow = reg.subscribe_with("t", 1, Qos::Leaky);
        let p = reg.publish("t");
        // the leaky queue fills after 1 buffer but must not block pushes
        for i in 0..4 {
            assert!(p.try_push(buf(i as f32)) == PushOutcome::Delivered);
        }
        assert_eq!(fast.counters().in_flight, 4);
        let sc = slow.counters();
        assert_eq!(sc.in_flight, 1);
        assert_eq!(sc.dropped.qos_leaky, 3);
        // a full *blocking* queue does gate
        for i in 4..8 {
            assert!(p.try_push(buf(i as f32)) == PushOutcome::Delivered);
        }
        assert_eq!(p.try_push(buf(9.0)), PushOutcome::Full);
    }

    #[test]
    fn leaky_publisher_qos_overrides_blocking_subscriber() {
        // tensor_query_serversink qos=leaky: a saturated blocking
        // subscriber no longer parks the pipeline — the frame sheds
        let reg = StreamRegistry::new();
        let s = reg.subscribe_with_capacity("t", 1);
        let tr = InProcTransport::new(reg.clone());
        let mut port = tr.advertise("t", Qos::Leaky).unwrap();
        assert!(matches!(port.try_send(buf(1.0)), PortSend::Sent));
        // queue full; a leaky publisher sheds instead of Full
        assert!(matches!(port.try_send(buf(2.0)), PortSend::Sent));
        let c = s.counters();
        assert_eq!(c.pushed, 2);
        assert_eq!(c.dropped.qos_leaky, 1);
        assert_eq!(s.recv().unwrap().chunk().as_f32().unwrap(), &[1.0]);
    }

    #[test]
    fn qos_parse_and_display_roundtrip() {
        for q in [Qos::Blocking, Qos::Leaky, Qos::LatestOnly] {
            assert_eq!(Qos::parse(&q.to_string()).unwrap(), q);
        }
        assert_eq!(Qos::parse("latest").unwrap(), Qos::LatestOnly);
        assert!(Qos::parse("lossy").is_err());
    }

    fn fault(msg: &str) -> Fault {
        Fault {
            element: "boom".into(),
            message: msg.into(),
            panicked: true,
        }
    }

    #[test]
    fn endpoint_fail_drains_then_reports_fault() {
        let ep = Endpoint::standalone(4);
        assert!(ep.close_reason().is_none());
        assert!(matches!(ep.try_push(buf(1.0)), EpPush::Ok));
        let f = fault("index out of range");
        ep.fail(&f);
        // queued data still drains, like EOS...
        assert!(matches!(ep.try_pop(), EpPop::Item(_)));
        assert!(matches!(ep.try_pop(), EpPop::End));
        // ...but the close-reason is the fault, never a clean EOS, and
        // a first fault is sticky against later ones
        ep.fail(&fault("second"));
        match ep.close_reason() {
            Some(StreamEnd::Fault(got)) => assert_eq!(got, f),
            other => panic!("expected fault close-reason, got {other:?}"),
        }
    }

    #[test]
    fn endpoint_close_reason_precedence() {
        // clean EOS
        let ep = Endpoint::standalone(2);
        ep.set_eos();
        assert_eq!(ep.close_reason(), Some(StreamEnd::Eos));
        // consumer cancel outranks EOS
        ep.close();
        assert_eq!(ep.close_reason(), Some(StreamEnd::Closed));
        // fault outranks a close that happened after it
        let ep = Endpoint::standalone(2);
        ep.fail(&fault("died"));
        ep.close();
        assert!(matches!(ep.close_reason(), Some(StreamEnd::Fault(_))));
    }

    #[test]
    fn topic_fault_reaches_subscribers_and_late_joiners() {
        let reg = StreamRegistry::new();
        let s = reg.subscribe("t");
        let tr = InProcTransport::new(reg.clone());
        let mut port = tr.advertise("t", Qos::Blocking).unwrap();
        assert!(matches!(port.try_send(buf(1.0)), PortSend::Sent));
        port.fail(&fault("publisher pipeline died"));
        // queued frame drains, then the subscription ends with the fault
        assert!(s.recv().is_ok());
        assert!(s.recv().is_err());
        match s.close_reason() {
            Some(StreamEnd::Fault(f)) => assert_eq!(f.element, "boom"),
            other => panic!("expected fault close-reason, got {other:?}"),
        }
        // a subscriber joining after the fault sees it too
        let late = reg.subscribe("t");
        assert!(late.recv().is_err());
        assert!(matches!(late.close_reason(), Some(StreamEnd::Fault(_))));
    }

    #[test]
    fn topic_reopen_clears_previous_generation_fault() {
        let reg = StreamRegistry::new();
        let tr = InProcTransport::new(reg.clone());
        let mut port = tr.advertise("t", Qos::Blocking).unwrap();
        port.fail(&fault("gen-1 died"));
        // a new publisher generation reopens the topic cleanly
        let mut port2 = tr.advertise("t", Qos::Blocking).unwrap();
        let s = reg.subscribe("t");
        assert!(matches!(port2.try_send(buf(2.0)), PortSend::Sent));
        port2.finish();
        assert!(s.recv().is_ok());
        assert!(s.recv().is_err());
        assert_eq!(s.close_reason(), Some(StreamEnd::Eos));
    }

    #[test]
    fn stream_end_display() {
        assert_eq!(StreamEnd::Eos.to_string(), "end of stream");
        assert_eq!(
            StreamEnd::Closed.to_string(),
            "stream closed by the consumer"
        );
        let msg = StreamEnd::Fault(fault("oops")).to_string();
        assert!(msg.contains("truncated"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }
}
