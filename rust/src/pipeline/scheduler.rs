//! Pipeline wiring over the pooled executor, plus the live-control
//! surface ([`Running`] / [`Controller`]).
//!
//! The seed scheduler ran every element on its own OS thread; since the
//! worker-pool refactor this module only *wires* a negotiated graph —
//! per-element [`Inbox`]es, output [`LinkSender`] tables, control
//! mailboxes — and hands the resulting element tasks to an
//! [`Executor`](crate::pipeline::executor::Executor) (the process-global
//! one for [`start`], any executor for [`start_on`]). Links stay bounded
//! MPSC queues with blocking or leaky delivery, and `queue` elements
//! still raise capacity to decouple producer from consumer — exactly the
//! role queues play in the paper's pipelines.
//!
//! ## Runtime control
//!
//! Each element owns a bounded **control mailbox**. The application
//! steers a playing pipeline through [`Running`] (or a cloneable
//! [`Controller`]): property changes, valve open/close, selector
//! switching and sink subscriptions are enqueued as [`ControlMsg`]s and
//! applied at the element's next step, always *before* the next item it
//! processes. That ordering makes control deterministic with respect to
//! the data stream: a message sent before a buffer enters the pipeline
//! is in effect when that buffer reaches the element. Control sends
//! never block the application thread — a full mailbox (an element
//! starved of input while the application keeps sending) surfaces as
//! [`Error::ControlBackpressure`] instead.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use crate::element::{ControlMsg, Ctx, Element, LinkSender};
use crate::error::{Error, Result};
use crate::metrics::stats::{ElementStats, PipelineReport, SchedSnapshot};
use crate::metrics::CpuTracker;
use crate::pipeline::executor::{Executor, Inbox, PipelineRun, Priority, TaskSpec, Waker};
use crate::pipeline::graph::Graph;
use crate::tensor::Buffer;

/// Capacity of each element's control mailbox. Control messages are tiny
/// and drained at every element step; the bound only matters if an
/// element is starved of input while the application keeps sending — in
/// which case [`Controller::send`] reports
/// [`Error::ControlBackpressure`] instead of blocking.
const CONTROL_CAPACITY: usize = 64;

/// Cloneable, thread-safe handle for steering a playing pipeline.
///
/// Obtained from [`Running::controller`]; all [`Running`] control methods
/// delegate here. Sending to an element that already finished (post-EOS)
/// fails with a runtime error; a full mailbox fails fast with
/// [`Error::ControlBackpressure`] instead of blocking the application.
#[derive(Clone)]
pub struct Controller {
    channels: Arc<HashMap<String, SyncSender<ControlMsg>>>,
}

impl Controller {
    /// Enqueue a raw control message for a named element.
    pub fn send(&self, element: &str, msg: ControlMsg) -> Result<()> {
        let tx = self.channels.get(element).ok_or_else(|| {
            let names = self.channels.keys().map(String::as_str);
            Error::Runtime(format!(
                "no element named {element:?} in this pipeline{}",
                crate::element::registry::did_you_mean(element, names)
            ))
        })?;
        match tx.try_send(msg) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(Error::ControlBackpressure {
                element: element.to_string(),
                capacity: CONTROL_CAPACITY,
            }),
            Err(TrySendError::Disconnected(_)) => Err(Error::Runtime(format!(
                "element {element:?} is no longer running"
            ))),
        }
    }

    /// Change a property of a playing element (applied at the element's
    /// next step, before its next buffer). Invalid keys/values surface as
    /// the element's failure when the pipeline is joined.
    pub fn set_property(&self, element: &str, key: &str, value: &str) -> Result<()> {
        self.send(
            element,
            ControlMsg::SetProperty {
                key: key.to_string(),
                value: value.to_string(),
            },
        )
    }

    /// Open (`true`) or close (`false`) a named `valve`.
    pub fn set_valve(&self, element: &str, open: bool) -> Result<()> {
        self.set_property(element, "drop", if open { "false" } else { "true" })
    }

    /// Switch the active sink pad of a named `input-selector`.
    pub fn select_input(&self, element: &str, pad: usize) -> Result<()> {
        self.set_property(element, "active-pad", &pad.to_string())
    }

    /// Switch the active src pad of a named `output-selector`.
    pub fn select_output(&self, element: &str, pad: usize) -> Result<()> {
        self.set_property(element, "active-pad", &pad.to_string())
    }

    /// Attach a per-buffer callback to a named `tensor_sink`. The
    /// callback runs on the pool worker stepping the sink and observes
    /// every buffer the sink processes (the pull-based collection
    /// additionally caps retention at `max-kept`).
    pub fn subscribe<F>(&self, element: &str, callback: F) -> Result<()>
    where
        F: FnMut(&Buffer) + Send + 'static,
    {
        self.send(element, ControlMsg::Subscribe(Box::new(callback)))
    }
}

/// A running pipeline: join to completion via [`Running::wait`], steer it
/// live through the control methods (see [`Controller`]). The pipeline's
/// elements execute as tasks on a shared worker pool; `wait` blocks the
/// *application* thread only.
pub struct Running {
    run: Arc<PipelineRun>,
    exec: Executor,
    node_names: Vec<String>,
    /// One (weak) waker per element task — `request_stop` nudges parked
    /// tasks so sources re-check the stop flag.
    wakers: Vec<Waker>,
    pub stats: Vec<Arc<ElementStats>>,
    pub stop: Arc<AtomicBool>,
    pub epoch: Instant,
    cpu: CpuTracker,
    traffic0: crate::metrics::traffic::Snapshot,
    controller: Controller,
}

impl Running {
    /// Request a stop: live sources exit at the next frame boundary, and
    /// parked sources (an idle `appsrc` waiting for application data)
    /// are woken so they observe the flag instead of sleeping through it.
    pub fn request_stop(&self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        for w in &self.wakers {
            w.wake();
        }
    }

    /// A cloneable control handle usable from any thread, and after this
    /// `Running` has been consumed by [`wait`](Running::wait).
    pub fn controller(&self) -> Controller {
        self.controller.clone()
    }

    /// See [`Controller::set_property`].
    pub fn set_property(&self, element: &str, key: &str, value: &str) -> Result<()> {
        self.controller.set_property(element, key, value)
    }

    /// See [`Controller::set_valve`].
    pub fn set_valve(&self, element: &str, open: bool) -> Result<()> {
        self.controller.set_valve(element, open)
    }

    /// See [`Controller::select_input`].
    pub fn select_input(&self, element: &str, pad: usize) -> Result<()> {
        self.controller.select_input(element, pad)
    }

    /// See [`Controller::select_output`].
    pub fn select_output(&self, element: &str, pad: usize) -> Result<()> {
        self.controller.select_output(element, pad)
    }

    /// See [`Controller::subscribe`].
    pub fn subscribe<F>(&self, element: &str, callback: F) -> Result<()>
    where
        F: FnMut(&Buffer) + Send + 'static,
    {
        self.controller.subscribe(element, callback)
    }

    /// Per-element stats of the live pipeline, by element name.
    pub fn element_stats(&self, name: &str) -> Option<&Arc<ElementStats>> {
        self.node_names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.stats[i])
    }

    /// Has every element of this pipeline finished (EOS or error)?
    pub fn is_done(&self) -> bool {
        self.run.is_done()
    }

    /// A detached health probe for the hub's stall watchdog: samples
    /// scheduler progress without keeping the pipeline alive (weak run
    /// reference) and can kill a stalled pipeline with a typed error.
    pub(crate) fn watchdog_probe(&self, name: impl Into<String>) -> WatchdogProbe {
        WatchdogProbe {
            name: name.into(),
            run: Arc::downgrade(&self.run),
            wakers: self.wakers.clone(),
            stats: self.stats.clone(),
            stop: self.stop.clone(),
        }
    }

    /// Join the pipeline (block until every element task finished) and
    /// assemble the run report. Elements are returned (in node order)
    /// for post-run inspection.
    pub fn wait(self) -> Result<(PipelineReport, Vec<(String, Box<dyn Element>)>)> {
        let Running {
            run,
            exec,
            node_names,
            stats,
            epoch,
            cpu,
            traffic0,
            ..
        } = self;
        run.wait_done();
        if let Some(e) = run.take_error() {
            return Err(e);
        }
        let mut elements = Vec::new();
        for (name, slot) in node_names.into_iter().zip(run.take_elements()) {
            if let Some(el) = slot {
                elements.push((name, el));
            }
        }
        let mem = crate::metrics::MemInfo::read();
        // End-to-end frame latency: terminal elements record
        // (arrival − pts) per buffer; merge their histograms into one
        // per-pipeline percentile summary.
        let mut e2e = [0u64; crate::metrics::stats::LATENCY_BUCKETS];
        for e in &stats {
            crate::metrics::stats::merge_latency(&mut e2e, &e.e2e_latency_counts());
        }
        let report = PipelineReport {
            wall: epoch.elapsed(),
            cpu_percent: cpu.cpu_percent(),
            peak_rss_mib: mem.peak_mib(),
            traffic: crate::metrics::traffic::since(traffic0),
            sched: snapshot_sched(&stats, &exec),
            latency: crate::metrics::stats::summarize_latency(&e2e),
            // per-topic endpoint counters (process-global, like
            // traffic), with network-transport topics folded in as
            // `tcp-pub:`/`tcp-sub:` entries
            topics: {
                let mut t = crate::pipeline::stream::StreamRegistry::global().snapshot();
                t.extend(crate::net::topics_snapshot());
                t
            },
            elements: stats,
            // supervision counters are stamped by the hub supervisor
            restarts: 0,
            faults: 0,
        };
        Ok((report, elements))
    }
}

/// Health probe over one running pipeline, held by the hub's stall
/// watchdog (see `PipelineHub::set_watchdog`). The probe observes
/// without owning: a weak run reference (a joined pipeline reads as
/// done), the per-element counters, and the task wakers.
///
/// The stall signature is *runnable but not progressing*: some task is
/// queued or mid-step ([`is_runnable`](WatchdogProbe::is_runnable))
/// while the progress sum ([`progress`](WatchdogProbe::progress)) stays
/// frozen — e.g. an element wedged inside its step. A fully parked
/// pipeline (idle appsrc) is *not* runnable and never flags.
pub(crate) struct WatchdogProbe {
    pub(crate) name: String,
    run: Weak<PipelineRun>,
    wakers: Vec<Waker>,
    stats: Vec<Arc<ElementStats>>,
    stop: Arc<AtomicBool>,
}

impl WatchdogProbe {
    /// Finished (or already joined and dropped)?
    pub(crate) fn is_done(&self) -> bool {
        self.run.upgrade().map_or(true, |r| r.is_done())
    }

    /// Monotone progress sum: element steps + wakeups. Any scheduling
    /// activity moves it; a frozen value means no task stepped and no
    /// park/wake transition happened since the last sample.
    pub(crate) fn progress(&self) -> u64 {
        self.stats.iter().map(|e| e.steps() + e.wakeups()).sum()
    }

    /// Is any task of this pipeline queued or mid-step right now?
    pub(crate) fn is_runnable(&self) -> bool {
        self.wakers.iter().any(|w| w.is_runnable())
    }

    /// Kill the pipeline with a typed error: records `err` as the run's
    /// failure (first error wins), raises the stop flag and wakes every
    /// parked task so the pipeline unwinds. Best-effort against a truly
    /// wedged step — a worker stuck *inside* an element cannot be
    /// reclaimed; it delivers the error as soon as that step returns.
    pub(crate) fn kill(&self, err: Error) {
        if let Some(run) = self.run.upgrade() {
            run.fail(err);
        }
        self.stop.store(true, Ordering::Relaxed);
        for w in &self.wakers {
            w.wake();
        }
    }
}

/// Aggregate the executor counters of one pipeline's elements into the
/// report's scheduling section (Table-III-style accounting stays
/// comparable across executors and worker counts).
fn snapshot_sched(stats: &[Arc<ElementStats>], exec: &Executor) -> SchedSnapshot {
    let mut s = SchedSnapshot {
        workers: exec.worker_count(),
        run_queue_high_water: exec.run_queue_high_water(),
        ..Default::default()
    };
    for e in stats {
        s.steps += e.steps();
        s.parks_input += e.parks_input();
        s.parks_output += e.parks_output();
        s.wakeups += e.wakeups();
        s.shed += e.shed();
        s.parks_timer += e.parks_timer();
        s.timer_fires += e.timer_fires();
        s.device_submits += e.device_submits();
        s.device_completions += e.device_completions();
        s.link_high_water = s.link_high_water.max(e.queue_high_water());
    }
    s
}

/// Start every element of a negotiated graph on the process-global
/// executor. Consumes the graph's elements; they come back from
/// [`Running::wait`].
pub fn start(graph: &mut Graph) -> Result<Running> {
    start_on(Executor::global(), graph, Priority::Normal)
}

/// Start a negotiated graph's elements as tasks on a specific executor
/// with a pipeline-wide scheduling priority (the
/// [`PipelineHub`](crate::pipeline::PipelineHub) entry point).
pub fn start_on(exec: &Executor, graph: &mut Graph, pri: Priority) -> Result<Running> {
    graph.negotiate_all()?;

    let n = graph.nodes.len();
    let stop = Arc::new(AtomicBool::new(false));
    let epoch = Instant::now();

    let stats: Vec<Arc<ElementStats>> = graph
        .nodes
        .iter()
        .map(|node| ElementStats::new(&node.name))
        .collect();

    // Per-consumer bounded inboxes (all sink pads of an element share
    // one inbox; items carry their pad index).
    let mut inboxes: Vec<Option<Arc<Inbox>>> = (0..n).map(|_| None).collect();
    for id in 0..n {
        if graph.n_sink_links(id) > 0 {
            let cap = graph.nodes[id]
                .element
                .preferred_input_capacity()
                .max(1);
            inboxes[id] = Some(Inbox::new(cap, stats[id].clone()));
        }
    }

    // Per-node control mailboxes (live property changes, subscriptions).
    let mut control_txs: HashMap<String, SyncSender<ControlMsg>> =
        HashMap::with_capacity(n);
    let mut control_rxs: Vec<Option<Receiver<ControlMsg>>> =
        (0..n).map(|_| None).collect();
    for id in 0..n {
        let (tx, rx) = sync_channel(CONTROL_CAPACITY);
        control_txs.insert(graph.nodes[id].name.clone(), tx);
        control_rxs[id] = Some(rx);
    }

    // Build per-node output sender tables into the consumers' inboxes.
    let mut outputs: Vec<Vec<Option<LinkSender>>> = (0..n).map(|_| Vec::new()).collect();
    for id in 0..n {
        let links = graph.links_from(id);
        let n_pads = links.iter().map(|l| l.src_pad + 1).max().unwrap_or(0);
        let mut table: Vec<Option<LinkSender>> = (0..n_pads).map(|_| None).collect();
        for l in links {
            let inbox = inboxes[l.dst_node]
                .as_ref()
                .expect("linked dst must have an inbox")
                .clone();
            inbox.add_producer();
            let delivery = graph.nodes[l.dst_node].element.input_delivery();
            table[l.src_pad] = Some(LinkSender::new(
                inbox,
                l.dst_pad,
                delivery,
                stats[l.dst_node].clone(),
            ));
        }
        outputs[id] = table;
    }

    let run = PipelineRun::new(n);
    let mut node_names = Vec::with_capacity(n);
    let mut specs = Vec::with_capacity(n);
    // Move elements out of the graph into their tasks.
    let nodes = std::mem::take(&mut graph.nodes);
    for (id, node) in nodes.into_iter().enumerate() {
        let n_sink_links = graph.links.iter().filter(|l| l.dst_node == id).count();
        let ctx = Ctx {
            outputs: std::mem::take(&mut outputs[id]),
            stats: stats[id].clone(),
            stop: stop.clone(),
            epoch,
            domain: node.element.domain(),
            idle_ns: 0,
            // consumers own their inbox through the ctx so they can
            // drain ready items mid-handle (tensor_filter batching)
            input: inboxes[id].clone(),
            pending: std::collections::VecDeque::new(),
            control: control_rxs[id].take(),
            waker: None,
            saturated: Vec::new(),
            deadline_ns: graph.deadline_ns,
            timer_deadline: None,
            // chaos testing: arm this element's injector if the
            // pipeline carries a fault plan naming it (None otherwise —
            // production pipelines pay one Option check per step)
            injector: graph
                .fault_plan
                .as_ref()
                .and_then(|p| p.injector_for(&node.name)),
        };
        let is_source = node.element.is_source();
        node_names.push(node.name.clone());
        specs.push(TaskSpec {
            name: node.name,
            index: id,
            pri,
            stats: stats[id].clone(),
            inbox: inboxes[id].clone(),
            element: node.element,
            ctx,
            is_source,
            n_sink_links,
        });
    }
    let wakers = exec.spawn_pipeline(specs, &run);

    Ok(Running {
        run,
        exec: exec.clone(),
        node_names,
        wakers,
        stats,
        stop,
        epoch,
        cpu: CpuTracker::start(),
        traffic0: crate::metrics::traffic::snapshot(),
        controller: Controller {
            channels: Arc::new(control_txs),
        },
    })
}

/// Convenience: sleep until the pipeline-relative deadline `pts_ns`.
/// This is the *blocking* fallback used by contexts without an executor
/// waker (bare threads, testutil); scheduled tasks pace through
/// `Ctx::park_until_pts`, which parks on the executor timer wheel and
/// holds no worker while waiting.
pub fn sleep_until(epoch: Instant, pts_ns: u64) {
    let deadline = epoch + Duration::from_nanos(pts_ns);
    let now = Instant::now();
    if deadline > now {
        std::thread::sleep(deadline - now);
    }
}
