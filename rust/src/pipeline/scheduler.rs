//! Thread-per-element scheduler with bounded-channel links.
//!
//! Every element runs on its own OS thread; links are bounded MPSC
//! channels, so push blocks when a consumer is saturated (GStreamer's
//! synchronous push + implicit backpressure). `queue` elements raise the
//! channel capacity and thereby decouple producer from consumer — exactly
//! the role queues play in the paper's pipelines.
//!
//! ## Runtime control
//!
//! Each element additionally owns a bounded **control channel**. The
//! application steers a playing pipeline through [`Running`] (or a
//! cloneable [`Controller`]): property changes, valve open/close,
//! selector switching and sink subscriptions are enqueued as
//! [`ControlMsg`]s and applied *by the element's own thread*, always
//! before the next item it processes. That ordering makes control
//! deterministic with respect to the data stream: a message sent before
//! a buffer enters the pipeline is in effect when that buffer reaches
//! the element.

use std::collections::HashMap;
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::element::{ControlMsg, Ctx, Element, Flow, Item, LinkSender};
use crate::error::{Error, Result};
use crate::metrics::stats::{ElementStats, PipelineReport};
use crate::metrics::CpuTracker;
use crate::pipeline::graph::Graph;
use crate::tensor::Buffer;

/// Capacity of each element's control mailbox. Control messages are tiny
/// and drained before every processed item; the bound only matters if an
/// element is starved of input while the application keeps sending.
const CONTROL_CAPACITY: usize = 64;

/// Cloneable, thread-safe handle for steering a playing pipeline.
///
/// Obtained from [`Running::controller`]; all [`Running`] control methods
/// delegate here. Sending to an element that already finished (post-EOS)
/// fails with a runtime error.
#[derive(Clone)]
pub struct Controller {
    channels: Arc<HashMap<String, SyncSender<ControlMsg>>>,
}

impl Controller {
    /// Enqueue a raw control message for a named element.
    pub fn send(&self, element: &str, msg: ControlMsg) -> Result<()> {
        let tx = self.channels.get(element).ok_or_else(|| {
            let names = self.channels.keys().map(String::as_str);
            Error::Runtime(format!(
                "no element named {element:?} in this pipeline{}",
                crate::element::registry::did_you_mean(element, names)
            ))
        })?;
        tx.send(msg).map_err(|_| {
            Error::Runtime(format!("element {element:?} is no longer running"))
        })
    }

    /// Change a property of a playing element (applied by the element's
    /// thread before its next buffer). Invalid keys/values surface as the
    /// element's failure when the pipeline is joined.
    pub fn set_property(&self, element: &str, key: &str, value: &str) -> Result<()> {
        self.send(
            element,
            ControlMsg::SetProperty {
                key: key.to_string(),
                value: value.to_string(),
            },
        )
    }

    /// Open (`true`) or close (`false`) a named `valve`.
    pub fn set_valve(&self, element: &str, open: bool) -> Result<()> {
        self.set_property(element, "drop", if open { "false" } else { "true" })
    }

    /// Switch the active sink pad of a named `input-selector`.
    pub fn select_input(&self, element: &str, pad: usize) -> Result<()> {
        self.set_property(element, "active-pad", &pad.to_string())
    }

    /// Switch the active src pad of a named `output-selector`.
    pub fn select_output(&self, element: &str, pad: usize) -> Result<()> {
        self.set_property(element, "active-pad", &pad.to_string())
    }

    /// Attach a per-buffer callback to a named `tensor_sink`. The
    /// callback runs on the sink's thread and observes every buffer the
    /// sink processes (the pull-based collection additionally caps
    /// retention at `max-kept`).
    pub fn subscribe<F>(&self, element: &str, callback: F) -> Result<()>
    where
        F: FnMut(&Buffer) + Send + 'static,
    {
        self.send(element, ControlMsg::Subscribe(Box::new(callback)))
    }
}

/// A running pipeline: join to completion via [`Running::wait`], steer it
/// live through the control methods (see [`Controller`]).
pub struct Running {
    threads: Vec<std::thread::JoinHandle<Result<Box<dyn Element>>>>,
    node_names: Vec<String>,
    pub stats: Vec<Arc<ElementStats>>,
    pub stop: Arc<AtomicBool>,
    pub epoch: Instant,
    cpu: CpuTracker,
    traffic0: crate::metrics::traffic::Snapshot,
    controller: Controller,
}

impl Running {
    /// Request a stop (live sources exit at the next frame boundary).
    pub fn request_stop(&self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
    }

    /// A cloneable control handle usable from any thread, and after this
    /// `Running` has been consumed by [`wait`](Running::wait).
    pub fn controller(&self) -> Controller {
        self.controller.clone()
    }

    /// See [`Controller::set_property`].
    pub fn set_property(&self, element: &str, key: &str, value: &str) -> Result<()> {
        self.controller.set_property(element, key, value)
    }

    /// See [`Controller::set_valve`].
    pub fn set_valve(&self, element: &str, open: bool) -> Result<()> {
        self.controller.set_valve(element, open)
    }

    /// See [`Controller::select_input`].
    pub fn select_input(&self, element: &str, pad: usize) -> Result<()> {
        self.controller.select_input(element, pad)
    }

    /// See [`Controller::select_output`].
    pub fn select_output(&self, element: &str, pad: usize) -> Result<()> {
        self.controller.select_output(element, pad)
    }

    /// See [`Controller::subscribe`].
    pub fn subscribe<F>(&self, element: &str, callback: F) -> Result<()>
    where
        F: FnMut(&Buffer) + Send + 'static,
    {
        self.controller.subscribe(element, callback)
    }

    /// Per-element stats of the live pipeline, by element name.
    pub fn element_stats(&self, name: &str) -> Option<&Arc<ElementStats>> {
        self.node_names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.stats[i])
    }

    /// Join all element threads and assemble the run report.
    /// Elements are returned (in node order) for post-run inspection.
    pub fn wait(self) -> Result<(PipelineReport, Vec<(String, Box<dyn Element>)>)> {
        let mut elements = Vec::new();
        let mut first_err: Option<Error> = None;
        for (th, name) in self.threads.into_iter().zip(self.node_names) {
            match th.join() {
                Ok(Ok(el)) => elements.push((name, el)),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(Error::Runtime(format!("element {name} panicked")));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let mem = crate::metrics::MemInfo::read();
        let report = PipelineReport {
            wall: self.epoch.elapsed(),
            elements: self.stats,
            cpu_percent: self.cpu.cpu_percent(),
            peak_rss_mib: mem.peak_mib(),
            traffic: crate::metrics::traffic::since(self.traffic0),
        };
        Ok((report, elements))
    }
}

/// Start every element of a negotiated graph. Consumes the graph's
/// elements; they come back from [`Running::wait`].
pub fn start(graph: &mut Graph) -> Result<Running> {
    graph.negotiate_all()?;

    let n = graph.nodes.len();
    let stop = Arc::new(AtomicBool::new(false));
    let epoch = Instant::now();

    // Per-node stats + input channels.
    let stats: Vec<Arc<ElementStats>> = graph
        .nodes
        .iter()
        .map(|node| ElementStats::new(&node.name))
        .collect();

    let mut senders: Vec<Option<SyncSender<(usize, Item)>>> = vec![None; n];
    let mut receivers: Vec<Option<std::sync::mpsc::Receiver<(usize, Item)>>> =
        (0..n).map(|_| None).collect();
    for id in 0..n {
        let n_sinks = graph.n_sink_links(id);
        if n_sinks > 0 {
            let cap = graph.nodes[id]
                .element
                .preferred_input_capacity()
                .max(1);
            let (tx, rx) = sync_channel(cap);
            senders[id] = Some(tx);
            receivers[id] = Some(rx);
        }
    }

    // Per-node control channels (live property changes, subscriptions).
    let mut control_txs: HashMap<String, SyncSender<ControlMsg>> =
        HashMap::with_capacity(n);
    let mut control_rxs: Vec<Option<std::sync::mpsc::Receiver<ControlMsg>>> =
        (0..n).map(|_| None).collect();
    for id in 0..n {
        let (tx, rx) = sync_channel(CONTROL_CAPACITY);
        control_txs.insert(graph.nodes[id].name.clone(), tx);
        control_rxs[id] = Some(rx);
    }

    // Build per-node output sender tables.
    let mut outputs: Vec<Vec<Option<LinkSender>>> = (0..n).map(|_| Vec::new()).collect();
    for id in 0..n {
        let links = graph.links_from(id);
        let n_pads = links.iter().map(|l| l.src_pad + 1).max().unwrap_or(0);
        let mut table: Vec<Option<LinkSender>> = (0..n_pads).map(|_| None).collect();
        for l in links {
            let tx = senders[l.dst_node]
                .as_ref()
                .expect("linked dst must have a channel")
                .clone();
            let delivery = graph.nodes[l.dst_node].element.input_delivery();
            table[l.src_pad] = Some(LinkSender::new(
                tx,
                l.dst_pad,
                delivery,
                stats[l.dst_node].clone(),
            ));
        }
        outputs[id] = table;
    }
    // Drop the original senders so channels close when all producers exit.
    drop(senders);

    let mut threads = Vec::with_capacity(n);
    let mut node_names = Vec::with_capacity(n);
    // Move elements out of the graph into their threads.
    let nodes = std::mem::take(&mut graph.nodes);
    for (id, node) in nodes.into_iter().enumerate() {
        let n_sink_links = graph
            .links
            .iter()
            .filter(|l| l.dst_node == id)
            .count();
        let mut ctx = Ctx {
            outputs: std::mem::take(&mut outputs[id]),
            stats: stats[id].clone(),
            stop: stop.clone(),
            epoch,
            domain: node.element.domain(),
            idle_ns: 0,
            // consumers own their input channel through the ctx so they
            // can drain ready items mid-handle (tensor_filter batching)
            input: receivers[id].take(),
            pending: std::collections::VecDeque::new(),
            control: control_rxs[id].take(),
        };
        let name = node.name.clone();
        node_names.push(name.clone());
        let mut element = node.element;
        let th = std::thread::Builder::new()
            .name(name.clone())
            .spawn(move || -> Result<Box<dyn Element>> {
                if element.is_source() {
                    run_source(&mut *element, &mut ctx)?;
                } else {
                    run_consumer(&mut *element, n_sink_links, &mut ctx)?;
                }
                Ok(element)
            })
            .map_err(|e| Error::Runtime(format!("spawn {name}: {e}")))?;
        threads.push(th);
    }

    Ok(Running {
        threads,
        node_names,
        stats,
        stop,
        epoch,
        cpu: CpuTracker::start(),
        traffic0: crate::metrics::traffic::snapshot(),
        controller: Controller {
            channels: Arc::new(control_txs),
        },
    })
}

/// Drain and apply every pending control message — called by element
/// threads before each processed item, so control is ordered with
/// respect to the data stream.
fn apply_control(element: &mut dyn Element, ctx: &mut Ctx) -> Result<()> {
    while let Some(msg) = ctx.try_pull_control() {
        element.handle_control(msg)?;
    }
    Ok(())
}

fn run_source(element: &mut dyn Element, ctx: &mut Ctx) -> Result<()> {
    loop {
        if ctx.stopped() {
            break;
        }
        let t0 = Instant::now();
        apply_control(element, ctx)?;
        let flow = element.generate(ctx)?;
        let busy = t0.elapsed().saturating_sub(ctx.take_idle());
        ctx.stats.record_busy(ctx.domain, busy);
        if flow == Flow::Eos {
            break;
        }
    }
    for pad in 0..ctx.n_src_pads() {
        ctx.push_eos(pad);
    }
    Ok(())
}

fn run_consumer(
    element: &mut dyn Element,
    n_sink_links: usize,
    ctx: &mut Ctx,
) -> Result<()> {
    let mut eos_seen = 0usize;
    let mut early_eos = false;
    // Arrival accounting happens inside Ctx::next_input (shared with the
    // mid-handle drain paths), pushed-back items replay first.
    while let Some((pad, item)) = ctx.next_input() {
        let is_eos = matches!(item, Item::Eos);
        if is_eos {
            eos_seen += 1;
        }
        if early_eos {
            // the element is done but still draining input: keep the
            // control mailbox drained too, so application Controller
            // sends never back up against a finished element
            apply_control(element, ctx)?;
        } else {
            let t0 = Instant::now();
            // control first: a message enqueued before this item entered
            // the pipeline is guaranteed to be in effect for it
            let flow =
                apply_control(element, ctx).and_then(|_| element.handle(pad, item, ctx));
            let busy = t0.elapsed().saturating_sub(ctx.take_idle());
            ctx.stats.record_busy(ctx.domain, busy);
            match flow {
                Ok(Flow::Continue) => {}
                Ok(Flow::Eos) => {
                    // Element declared end-of-stream: flush, notify
                    // downstream, then keep draining input (discarding) so
                    // upstream never blocks on a dead consumer.
                    element.flush(ctx)?;
                    for p in 0..ctx.n_src_pads() {
                        ctx.push_eos(p);
                    }
                    early_eos = true;
                }
                Err(e) => {
                    // Propagate EOS downstream so the pipeline unwinds,
                    // then surface the error.
                    for p in 0..ctx.n_src_pads() {
                        ctx.push_eos(p);
                    }
                    return Err(e);
                }
            }
        }
        if eos_seen >= n_sink_links {
            break;
        }
    }
    if !early_eos {
        element.flush(ctx)?;
        for p in 0..ctx.n_src_pads() {
            ctx.push_eos(p);
        }
    }
    Ok(())
}

/// Convenience: sleep until the pipeline-relative deadline `pts_ns`
/// (live-source pacing helper).
pub fn sleep_until(epoch: Instant, pts_ns: u64) {
    let deadline = epoch + Duration::from_nanos(pts_ns);
    let now = Instant::now();
    if deadline > now {
        std::thread::sleep(deadline - now);
    }
}
