//! Step-driven cooperative executor: a fixed-size worker pool running
//! every element of every pipeline as a small state machine.
//!
//! The seed scheduler gave each element its own OS thread, so a device
//! hosting N pipelines of E elements burned N×E threads — unusable at the
//! "many pipelines per device" scale the among-device-AI follow-up paper
//! targets. This module replaces the blocking loops with an **element
//! task contract**: each element is a [`Task`] that a pool worker *steps*
//! (one `generate()` or one `handle()` call per step), after which the
//! task is either
//!
//! * **ready** — requeued on the global run queue,
//! * **parked on input** — its inbox was empty; the next producer push
//!   wakes it,
//! * **parked on output** — a downstream inbox it filled past capacity;
//!   the consumer draining below capacity wakes it,
//! * **parked externally** — a source with nothing to produce
//!   ([`Flow::Wait`]); an application-held [`Waker`] unparks it, or
//! * **finished** — EOS/error; its element is handed back to the
//!   pipeline's completion slots.
//!
//! Links stay bounded and keep the seed semantics: a *blocking* link
//! applies backpressure by parking the producer until the consumer drains
//! (instead of blocking a thread), and a *leaky* link drops at capacity
//! exactly as before. Control mailboxes are drained at step entry, so a
//! control message sent before a buffer enters the pipeline is still
//! guaranteed to be in effect when that buffer reaches the element —
//! the determinism contract of the seed scheduler is preserved, and sink
//! output is bit-identical for any worker count (asserted in
//! `tests/determinism.rs`).
//!
//! Fairness: one item per step, FIFO within a priority lane, and a
//! weighted 4:2:1 rotation across the [`Priority`] lanes so low-priority
//! pipelines never starve.

use std::collections::VecDeque;
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use once_cell::sync::Lazy;

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{thread, Condvar, Mutex, MutexGuard};

use crate::element::{Ctx, Element, Flow, Item};
use crate::error::{Error, Fault};
use crate::metrics::stats::ElementStats;
use crate::pipeline::fault::FaultKind;

/// Hard ceiling on the worker count of any executor — the "bounded
/// thread" guarantee of the hub holds even against misconfiguration
/// (`NNS_WORKERS=100000`).
pub const MAX_WORKERS: usize = 64;

/// Lock helper that survives poisoning: a panicking element must not
/// wedge the whole pool (the seed scheduler isolated panics per thread;
/// we isolate them per step).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Scheduling priority of a pipeline on a shared executor. Lanes are
/// drained in a weighted 4:2:1 rotation (strict priority would starve
/// background pipelines under sustained high-priority load).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Outcome of delivering one item into an [`Inbox`].
pub(crate) enum PushResult {
    /// Enqueued; `saturated` is true when the inbox is now at/over
    /// capacity, i.e. the producer must park before producing more.
    Delivered { saturated: bool },
    /// Leaky link at capacity: the item was discarded.
    Dropped,
    /// The consumer finished; nothing can be delivered anymore.
    Closed,
}

/// Outcome of a consumer-side pop.
pub(crate) enum PopResult {
    Item((usize, Item)),
    /// Nothing queued but producers are still attached — park on input.
    Pending,
    /// Nothing queued and no producer remains (the pooled equivalent of
    /// a disconnected channel): the element will never see input again.
    Exhausted,
}

struct InboxInner {
    queue: VecDeque<(usize, Item)>,
    /// Attached link count; decremented as producers finish. 0 with an
    /// empty queue reads as end-of-input (channel-disconnect analog).
    open_producers: usize,
    /// Set when the consumer finishes; producers observe [`PushResult::Closed`].
    closed: bool,
    /// A producer died: the stream feeding this inbox is truncated, not
    /// complete. Queued items still drain first (in-order truncation);
    /// once the consumer observes [`PopResult::Exhausted`] it checks
    /// this record to distinguish fault from clean EOS. First fault
    /// wins and the record is sticky.
    fault: Option<Fault>,
    /// Producer tasks parked until this inbox drains below capacity.
    waiters: Vec<Arc<Task>>,
}

/// Bounded, multi-producer input queue of one element. All sink pads of
/// an element share one inbox; items carry their pad index (exactly the
/// seed's shared input channel). Unlike a `SyncSender`, pushes never
/// block: a blocking-delivery push past capacity instead tells the
/// producer to park, which keeps pool workers deadlock-free while
/// preserving backpressure (queues exceed capacity by at most one step's
/// output).
pub struct Inbox {
    cap: usize,
    /// Consumer's stats handle: link high-water marks are recorded here.
    stats: Arc<ElementStats>,
    inner: Mutex<InboxInner>,
    /// Signals item arrival/closure to an in-step timed wait
    /// ([`Ctx::pull_input_timeout`], the tensor_filter latency budget).
    avail: Condvar,
    /// The task that drains this inbox (set at wiring time).
    consumer: Mutex<Option<Weak<Task>>>,
}

impl Inbox {
    pub(crate) fn new(cap: usize, stats: Arc<ElementStats>) -> Arc<Inbox> {
        Arc::new(Inbox {
            cap: cap.max(1),
            stats,
            inner: Mutex::new(InboxInner {
                queue: VecDeque::new(),
                open_producers: 0,
                closed: false,
                fault: None,
                waiters: Vec::new(),
            }),
            avail: Condvar::new(),
            consumer: Mutex::new(None),
        })
    }

    pub(crate) fn set_consumer(&self, task: &Arc<Task>) {
        *lock(&self.consumer) = Some(Arc::downgrade(task));
    }

    /// Register one producing link (called once per link at wiring).
    pub(crate) fn add_producer(&self) {
        lock(&self.inner).open_producers += 1;
    }

    fn consumer_task(&self) -> Option<Arc<Task>> {
        lock(&self.consumer).as_ref().and_then(Weak::upgrade)
    }

    /// Blocking-delivery push: always enqueues (capacity overshoot is
    /// bounded by one step's output); reports saturation so the caller's
    /// task parks instead of producing more.
    pub(crate) fn push(&self, pad: usize, item: Item) -> PushResult {
        let (result, wake) = {
            let mut g = lock(&self.inner);
            if g.closed {
                return PushResult::Closed;
            }
            let was_empty = g.queue.is_empty();
            g.queue.push_back((pad, item));
            let len = g.queue.len();
            self.stats.record_queue_depth(len as u64);
            (
                PushResult::Delivered {
                    saturated: len >= self.cap,
                },
                // empty -> nonempty is the only transition that can have
                // a consumer parked on input
                if was_empty { self.consumer_task() } else { None },
            )
        };
        self.avail.notify_all();
        if let Some(t) = wake {
            wake_task(&t);
        }
        result
    }

    /// Leaky-delivery push: drops at capacity (a `leaky=downstream`
    /// queue), never saturates the producer.
    pub(crate) fn push_leaky(&self, pad: usize, item: Item) -> PushResult {
        let wake = {
            let mut g = lock(&self.inner);
            if g.closed {
                return PushResult::Closed;
            }
            if g.queue.len() >= self.cap {
                return PushResult::Dropped;
            }
            let was_empty = g.queue.is_empty();
            g.queue.push_back((pad, item));
            let len = g.queue.len();
            self.stats.record_queue_depth(len as u64);
            if was_empty {
                self.consumer_task()
            } else {
                None
            }
        };
        self.avail.notify_all();
        if let Some(t) = wake {
            wake_task(&t);
        }
        PushResult::Delivered { saturated: false }
    }

    /// Locked pop: dequeue one item and collect the producers to wake if
    /// this drain crossed below capacity. The single home of the
    /// capacity-wake rule, shared by [`try_pop`](Inbox::try_pop) and
    /// [`pop_timeout`](Inbox::pop_timeout).
    fn pop_locked(&self, g: &mut InboxInner) -> Option<((usize, Item), Vec<Arc<Task>>)> {
        let it = g.queue.pop_front()?;
        let wakes = if g.queue.len() < self.cap && !g.waiters.is_empty() {
            std::mem::take(&mut g.waiters)
        } else {
            Vec::new()
        };
        Some((it, wakes))
    }

    /// Consumer-side non-blocking pop; draining below capacity wakes
    /// producers parked on this inbox.
    pub(crate) fn try_pop(&self) -> PopResult {
        let (res, wakes) = {
            let mut g = lock(&self.inner);
            match self.pop_locked(&mut g) {
                Some((it, wakes)) => (PopResult::Item(it), wakes),
                None => {
                    let res = if g.closed || g.open_producers == 0 {
                        PopResult::Exhausted
                    } else {
                        PopResult::Pending
                    };
                    (res, Vec::new())
                }
            }
        };
        for t in &wakes {
            wake_task(t);
        }
        res
    }

    /// Consumer-side timed pop: waits (accounted as idle by the caller)
    /// up to `timeout` for an item. Used by the `tensor_filter` batching
    /// latency budget; the wait blocks one pool worker for at most the
    /// budget, never indefinitely.
    pub(crate) fn pop_timeout(&self, timeout: Duration) -> Option<(usize, Item)> {
        let deadline = Instant::now() + timeout;
        let mut g = lock(&self.inner);
        loop {
            if let Some((it, wakes)) = self.pop_locked(&mut g) {
                drop(g);
                for t in &wakes {
                    wake_task(t);
                }
                return Some(it);
            }
            if g.closed || g.open_producers == 0 {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self
                .avail
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            g = ng;
        }
    }

    /// Park-on-output registration. Returns false when the inbox already
    /// drained below capacity (or closed) — the caller must not park.
    /// Registration and the re-check are atomic under the inbox lock, so
    /// a wake can never be lost between a push and the park decision.
    /// Idempotent per task (re-parking on a still-full inbox does not
    /// grow the waiter list).
    pub(crate) fn register_waiter(&self, task: &Arc<Task>) -> bool {
        let mut g = lock(&self.inner);
        if g.closed || g.queue.len() < self.cap {
            return false;
        }
        if !g.waiters.iter().any(|t| Arc::ptr_eq(t, task)) {
            g.waiters.push(task.clone());
        }
        true
    }

    /// Is the inbox still at/over capacity (the producer step gate)?
    pub(crate) fn at_capacity(&self) -> bool {
        let g = lock(&self.inner);
        !g.closed && g.queue.len() >= self.cap
    }

    /// Park-on-input re-check: anything a parked consumer would need to
    /// see (item queued, closed, all producers gone)?
    pub(crate) fn has_ready(&self) -> bool {
        let g = lock(&self.inner);
        !g.queue.is_empty() || g.closed || g.open_producers == 0
    }

    /// A producing link died with a fault: record it (first fault wins,
    /// sticky) so the consumer can tell truncation from clean EOS when
    /// it reaches end-of-input. Always paired with
    /// [`producer_done`](Inbox::producer_done), which does the
    /// accounting and the wake.
    pub(crate) fn producer_fault(&self, fault: &Fault) {
        let mut g = lock(&self.inner);
        if g.fault.is_none() {
            g.fault = Some(fault.clone());
        }
    }

    /// The fault a dead producer left on this inbox, if any.
    pub(crate) fn fault(&self) -> Option<Fault> {
        lock(&self.inner).fault.clone()
    }

    /// One producing link finished; at zero the consumer observes
    /// end-of-input once drained (channel-disconnect analog).
    pub(crate) fn producer_done(&self) {
        let last = {
            let mut g = lock(&self.inner);
            g.open_producers = g.open_producers.saturating_sub(1);
            g.open_producers == 0
        };
        if last {
            self.avail.notify_all();
            if let Some(t) = self.consumer_task() {
                wake_task(&t);
            }
        }
    }

    /// Consumer finished: refuse further deliveries and release parked
    /// producers (they observe [`PushResult::Closed`], the equivalent of
    /// a send to a dropped receiver, and request pipeline stop).
    pub(crate) fn close(&self) {
        let waiters = {
            let mut g = lock(&self.inner);
            g.closed = true;
            std::mem::take(&mut g.waiters)
        };
        self.avail.notify_all();
        for t in &waiters {
            wake_task(t);
        }
    }

    /// Test support: drain every queued buffer (EOS markers skipped).
    #[cfg(test)]
    pub(crate) fn drain_buffers(&self) -> Vec<crate::tensor::Buffer> {
        let mut g = lock(&self.inner);
        g.queue
            .drain(..)
            .filter_map(|(_, item)| match item {
                Item::Buffer(b) => Some(b),
                Item::Eos => None,
            })
            .collect()
    }
}

/// Handle that unparks one task from outside the pool — the mechanism
/// behind `appsrc`: the application's push handle wakes the source task
/// that returned [`Flow::Wait`]. Holding a waker never keeps a finished
/// pipeline alive (weak reference), and waking a running, queued or
/// finished task is a cheap no-op. Also used by
/// [`Running::request_stop`](crate::pipeline::Running::request_stop) to
/// nudge every parked task of a pipeline so sources re-check the stop
/// flag instead of sleeping through it.
#[derive(Clone, Default)]
pub struct Waker {
    task: Weak<Task>,
}

impl Waker {
    pub(crate) fn for_task(task: &Arc<Task>) -> Waker {
        Waker {
            task: Arc::downgrade(task),
        }
    }

    /// Unpark the task (no-op once it finished).
    pub fn wake(&self) {
        if let Some(t) = self.task.upgrade() {
            wake_task(&t);
        }
    }

    /// True while the task is queued or mid-step — i.e. the scheduler
    /// considers it *runnable* rather than parked or finished. The hub
    /// watchdog uses this: a pipeline is only "stalled" when some task
    /// is runnable yet the progress counters stop moving; a fully
    /// parked pipeline is merely idle, not stalled.
    pub(crate) fn is_runnable(&self) -> bool {
        match self.task.upgrade() {
            Some(t) => matches!(
                lock(&t.sched).state(),
                SchedState::Queued | SchedState::Running
            ),
            None => false,
        }
    }
}

/// A late-bound [`Waker`] slot shared between an element and its
/// application-side handles: the element publishes its waker at the
/// first step, handles wake through it from any thread.
#[derive(Default)]
pub struct SharedWaker {
    slot: Mutex<Option<Waker>>,
}

impl SharedWaker {
    pub fn new() -> Arc<SharedWaker> {
        Arc::new(SharedWaker::default())
    }

    pub fn set(&self, w: Waker) {
        *lock(&self.slot) = Some(w);
    }

    pub fn wake(&self) {
        if let Some(w) = lock(&self.slot).as_ref() {
            w.wake();
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum SchedState {
    /// On the run queue (or being handed to a worker).
    #[default]
    Queued,
    /// A worker is inside this task's step.
    Running,
    ParkedInput,
    ParkedOutput,
    ParkedExternal,
    Finished,
}

/// What [`SchedCell::on_wake`] decided; the caller owns the side effect.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum WakeVerdict {
    /// The task was parked and is now `Queued`: the caller must put it
    /// on the run queue.
    Enqueue,
    /// The task is mid-step: the wake was recorded in `wake_pending`
    /// and step exit will requeue instead of parking.
    Deferred,
    /// Queued or finished: the wake is a no-op.
    Ignored,
}

/// The park/wake state machine of one task — the protocol kernel behind
/// [`wake_task`]/`park`. Extracted as a plain (lock-free, caller-locked)
/// struct so `tests/check.rs` can model-check the exact production code:
/// the model wraps a `Mutex<SchedCell>` and explores every interleaving
/// of a parking consumer against a waking producer.
///
/// The load-bearing piece is `wake_pending`: a wake that lands while the
/// task is `Running` cannot enqueue (the task is not parked yet) and
/// must not be dropped (the park decision was made on state the wake
/// just invalidated). Deferring it to the park transition is the
/// lost-wakeup guard; `cargo test --features check,mutate-wake-pending`
/// compiles the guard out and must produce a counterexample seed.
#[derive(Debug, Default)]
pub struct SchedCell {
    state: SchedState,
    /// A wake arrived while the task was mid-step: requeue instead of
    /// parking (the lost-wakeup guard of the state machine).
    wake_pending: bool,
}

impl SchedCell {
    pub fn new() -> SchedCell {
        SchedCell::default()
    }

    pub fn state(&self) -> SchedState {
        self.state
    }

    /// A worker dequeued the task and is entering its step.
    pub fn set_running(&mut self) {
        self.state = SchedState::Running;
    }

    /// A wake from any thread (producer push, inbox drain, external
    /// waker, timer fire). Returns what the caller must do.
    pub fn on_wake(&mut self) -> WakeVerdict {
        match self.state {
            SchedState::Running => {
                #[cfg(not(feature = "mutate-wake-pending"))]
                {
                    self.wake_pending = true;
                }
                WakeVerdict::Deferred
            }
            SchedState::Queued | SchedState::Finished => WakeVerdict::Ignored,
            SchedState::ParkedInput | SchedState::ParkedOutput | SchedState::ParkedExternal => {
                self.state = SchedState::Queued;
                WakeVerdict::Enqueue
            }
        }
    }

    /// Transition `Running -> target` park state. Returns `false` when a
    /// wake arrived mid-step: the cell went back to `Queued` instead and
    /// the caller must enqueue the task rather than leave it parked.
    pub fn try_park(&mut self, target: SchedState) -> bool {
        if self.wake_pending {
            self.wake_pending = false;
            self.state = SchedState::Queued;
            return false;
        }
        self.state = target;
        true
    }

    /// The step verdict requeues the task directly (also clears a
    /// pending wake — the requeue satisfies it).
    pub fn requeued(&mut self) {
        self.wake_pending = false;
        self.state = SchedState::Queued;
    }

    /// Terminal: finished tasks ignore all wakes.
    pub fn finish(&mut self) {
        self.state = SchedState::Finished;
        self.wake_pending = false;
    }
}

#[derive(Clone, Copy)]
enum TaskKind {
    Source,
    Consumer { n_sink_links: usize },
}

/// Everything a step needs exclusive access to. Only the worker that
/// dequeued the task locks it (the scheduling discipline guarantees a
/// task is never queued twice).
struct StepCore {
    element: Option<Box<dyn Element>>,
    ctx: Option<Ctx>,
    kind: TaskKind,
    /// EOS markers seen so far (one per sink link ends the element).
    eos_seen: usize,
    /// The element declared EOS early: drain-and-discard mode.
    early_eos: bool,
    /// A consumer's `handle()` returned [`Flow::Wait`] (in-flight device
    /// job, timed output pad): the next step re-enters through
    /// [`Element::resume`] instead of polling input, so a stashed job
    /// drains before any new input is consumed.
    waiting_external: bool,
}

/// One schedulable element of one pipeline.
pub struct Task {
    name: String,
    /// Node index within its pipeline (completion slot).
    index: usize,
    pri: Priority,
    stats: Arc<ElementStats>,
    core: Arc<ExecutorCore>,
    run: Arc<PipelineRun>,
    /// This element's own input queue (None for sources).
    inbox: Option<Arc<Inbox>>,
    /// Saturated downstream inboxes this task parked on. A wake (any of
    /// them draining, or an external waker) only leads to a step once
    /// *all* of them are below capacity again — otherwise a fast branch
    /// draining repeatedly would let the producer grow a slow sibling
    /// branch's inbox without bound.
    blocked_on: Mutex<Vec<Arc<Inbox>>>,
    step: Mutex<StepCore>,
    sched: Mutex<SchedCell>,
}

/// Wiring description of one task, assembled by the scheduler.
pub(crate) struct TaskSpec {
    pub name: String,
    pub index: usize,
    pub pri: Priority,
    pub stats: Arc<ElementStats>,
    pub inbox: Option<Arc<Inbox>>,
    pub element: Box<dyn Element>,
    pub ctx: Ctx,
    pub is_source: bool,
    pub n_sink_links: usize,
}

/// Completion state of one launched pipeline: elements come back through
/// per-node slots, the first error wins, and `wait_done` blocks the
/// application (never a pool worker) until every task finished.
pub(crate) struct PipelineRun {
    remaining: Mutex<usize>,
    done: Condvar,
    slots: Mutex<Vec<Option<Box<dyn Element>>>>,
    first_err: Mutex<Option<Error>>,
}

impl PipelineRun {
    pub(crate) fn new(n: usize) -> Arc<PipelineRun> {
        Arc::new(PipelineRun {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            slots: Mutex::new((0..n).map(|_| None).collect()),
            first_err: Mutex::new(None),
        })
    }

    /// Block until every task of this pipeline finished.
    pub(crate) fn wait_done(&self) {
        let mut rem = lock(&self.remaining);
        while *rem > 0 {
            rem = self.done.wait(rem).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub(crate) fn is_done(&self) -> bool {
        *lock(&self.remaining) == 0
    }

    pub(crate) fn take_error(&self) -> Option<Error> {
        lock(&self.first_err).take()
    }

    /// Record a pipeline-level error from outside the task path (the hub
    /// watchdog killing a stalled pipeline). First error wins, same as
    /// task errors, so a watchdog kill never masks the element fault
    /// that caused the stall.
    pub(crate) fn fail(&self, err: Error) {
        let mut g = lock(&self.first_err);
        if g.is_none() {
            *g = Some(err);
        }
    }

    pub(crate) fn take_elements(&self) -> Vec<Option<Box<dyn Element>>> {
        std::mem::take(&mut *lock(&self.slots))
    }

    fn task_finished(
        &self,
        index: usize,
        element: Option<Box<dyn Element>>,
        err: Option<Error>,
    ) {
        if let Some(el) = element {
            lock(&self.slots)[index] = Some(el);
        }
        if let Some(e) = err {
            let mut g = lock(&self.first_err);
            if g.is_none() {
                *g = Some(e);
            }
        }
        let mut rem = lock(&self.remaining);
        *rem = rem.saturating_sub(1);
        if *rem == 0 {
            self.done.notify_all();
        }
    }
}

/// Priority-laned global run queue (guarded by `ExecutorCore::rq`).
struct RunQueue {
    lanes: [VecDeque<Arc<Task>>; 3],
    len: usize,
    /// Rotation cursor for the weighted 4:2:1 lane pick.
    seq: u64,
}

/// Weighted lane rotation: 4 high, 2 normal, 1 low per 7 picks.
const LANE_PICKS: [usize; 7] = [0, 1, 0, 1, 0, 2, 0];

impl RunQueue {
    fn new() -> RunQueue {
        RunQueue {
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            len: 0,
            seq: 0,
        }
    }

    fn push(&mut self, pri: Priority, task: Arc<Task>) {
        self.lanes[pri.lane()].push_back(task);
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Arc<Task>> {
        if self.len == 0 {
            return None;
        }
        let preferred = LANE_PICKS[(self.seq % LANE_PICKS.len() as u64) as usize];
        self.seq += 1;
        for lane in [preferred, 0, 1, 2] {
            if let Some(t) = self.lanes[lane].pop_front() {
                self.len -= 1;
                return Some(t);
            }
        }
        None
    }
}

/// Slot count of the hashed timer wheel. Entries hash to
/// `deadline_tick % WHEEL_SLOTS`; a slot may hold deadlines from later
/// wheel rounds, so each entry's own deadline is re-checked at fire time
/// — timers never fire early, only (bounded by scheduling latency) late.
const WHEEL_SLOTS: usize = 256;
/// Wheel tick granularity. Pacing and device envelopes are multi-hundred
/// µs to multi-ms; 1 ms buckets keep slots short without a timer thread.
const WHEEL_TICK_NS: u64 = 1_000_000;

/// Hashed timer wheel behind [`Ctx::park_until`]: deadline-parked tasks
/// cost zero workers. There is no dedicated timer thread — idle workers
/// bound their run-queue condvar wait by the soonest armed deadline and
/// fire due entries themselves (see [`worker_loop`]).
///
/// Generic over the entry payload (the executor arms `Weak<Task>`) so
/// the never-fires-early contract is model-checkable with plain values
/// and virtual `now` probes in `tests/check.rs`.
pub struct TimerWheel<T> {
    origin: Instant,
    slots: Vec<Vec<(Instant, T)>>,
    len: usize,
    /// Cached soonest armed deadline (the condvar wait bound).
    soonest: Option<Instant>,
}

impl<T> Default for TimerWheel<T> {
    fn default() -> TimerWheel<T> {
        TimerWheel::new()
    }
}

impl<T> TimerWheel<T> {
    pub fn new() -> TimerWheel<T> {
        TimerWheel {
            origin: Instant::now(),
            slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            len: 0,
            soonest: None,
        }
    }

    fn slot_of(&self, t: Instant) -> usize {
        let tick = t.saturating_duration_since(self.origin).as_nanos() as u64 / WHEEL_TICK_NS;
        (tick % WHEEL_SLOTS as u64) as usize
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Soonest armed deadline, if any entry is armed.
    pub fn soonest(&self) -> Option<Instant> {
        self.soonest
    }

    pub fn arm(&mut self, deadline: Instant, entry: T) {
        let slot = self.slot_of(deadline);
        self.slots[slot].push((deadline, entry));
        self.len += 1;
        if self.soonest.map_or(true, |s| deadline < s) {
            self.soonest = Some(deadline);
        }
    }

    /// Remove and return every entry due at `now`. Nothing due is a cheap
    /// cached-`soonest` check; firing scans the (mostly empty) slots so
    /// entries armed in the past or left behind by coarse ticks are never
    /// missed.
    pub fn take_due(&mut self, now: Instant) -> Vec<T> {
        match self.soonest {
            Some(s) if s <= now => {}
            _ => return Vec::new(),
        }
        let mut due = Vec::new();
        let mut soonest = None;
        for slot in &mut self.slots {
            if slot.is_empty() {
                continue;
            }
            for (deadline, entry) in std::mem::take(slot) {
                if deadline <= now {
                    due.push(entry);
                } else {
                    if soonest.map_or(true, |s| deadline < s) {
                        soonest = Some(deadline);
                    }
                    slot.push((deadline, entry));
                }
            }
        }
        self.len -= due.len();
        self.soonest = soonest;
        due
    }
}

pub(crate) struct ExecutorCore {
    rq: Mutex<RunQueue>,
    available: Condvar,
    shutdown: AtomicBool,
    workers: usize,
    /// Strong registry of unfinished tasks (parked tasks are not
    /// necessarily referenced by the run queue or any inbox).
    live: Mutex<Vec<Arc<Task>>>,
    timers: Mutex<TimerWheel<Weak<Task>>>,
    steps_total: AtomicU64,
    wakeups_total: AtomicU64,
    timer_parks_total: AtomicU64,
    timer_fires_total: AtomicU64,
    runq_hwm: AtomicU64,
}

impl ExecutorCore {
    fn enqueue(&self, task: Arc<Task>) {
        let pri = task.pri;
        {
            let mut rq = lock(&self.rq);
            rq.push(pri, task);
            self.runq_hwm.fetch_max(rq.len as u64, Ordering::Relaxed);
        }
        self.available.notify_one();
    }

    fn remove_live(&self, task: &Arc<Task>) {
        lock(&self.live).retain(|t| !Arc::ptr_eq(t, task));
    }

    /// Arm a wheel entry for a deadline-parked task. The notify is
    /// essential: an idle worker may be in an unbounded condvar wait (no
    /// timers armed) or one bounded by a *later* deadline — it must wake
    /// and re-read the soonest deadline.
    fn arm_timer(&self, deadline: Instant, task: &Arc<Task>) {
        lock(&self.timers).arm(deadline, Arc::downgrade(task));
        self.timer_parks_total.fetch_add(1, Ordering::Relaxed);
        self.available.notify_all();
    }

    fn next_timer_due(&self) -> Option<Instant> {
        lock(&self.timers).soonest()
    }

    /// Fire every due timer entry (idle-worker timer service). Wakes run
    /// through the ordinary [`wake_task`] path, so a task that was woken
    /// early for another reason absorbs the late fire as a no-op.
    fn fire_due_timers(&self) {
        let due = lock(&self.timers).take_due(Instant::now());
        if due.is_empty() {
            return;
        }
        self.timer_fires_total
            .fetch_add(due.len() as u64, Ordering::Relaxed);
        for weak in due {
            if let Some(t) = weak.upgrade() {
                t.stats.record_timer_fire();
                wake_task(&t);
            }
        }
    }
}

/// Requeue a task that a wake or a ready verdict made runnable.
fn requeue(task: &Arc<Task>) {
    lock(&task.sched).requeued();
    task.core.enqueue(task.clone());
}

/// Transition `Running -> parked` unless a wake arrived mid-step, in
/// which case the task is requeued and `false` returned.
fn park(task: &Arc<Task>, state: SchedState) -> bool {
    let parked = lock(&task.sched).try_park(state);
    if !parked {
        task.core.enqueue(task.clone());
    }
    parked
}

/// Make a task runnable from any thread. Safe against every state:
/// running tasks defer the wake to step exit, queued/finished tasks
/// ignore it, parked tasks are enqueued. Spurious wakes are harmless (a
/// step with nothing to do re-parks).
pub(crate) fn wake_task(task: &Arc<Task>) {
    let verdict = lock(&task.sched).on_wake();
    if verdict == WakeVerdict::Enqueue {
        task.stats.record_wakeup();
        task.core.wakeups_total.fetch_add(1, Ordering::Relaxed);
        task.core.enqueue(task.clone());
    }
}

/// What a step decided about the task's future.
enum Verdict {
    Ready,
    ParkInput,
    ParkOutput(Vec<Arc<Inbox>>),
    /// Park until an external [`Waker`] fires. Carries any outputs the
    /// step saturated: the worker-loop gate re-checks them on wake, so
    /// an element that pushes and then waits cannot bypass backpressure.
    ParkExternal(Vec<Arc<Inbox>>),
    /// Park until `deadline` on the executor timer wheel (live-source
    /// pacing, CPU-envelope pads, injected delays). The park itself is
    /// an external park; the wheel entry is the wake source.
    ParkTimer {
        deadline: Instant,
        saturated: Vec<Arc<Inbox>>,
    },
}

/// Build the park verdict for a step that returned [`Flow::Wait`]: a
/// deadline the element set via [`Ctx::park_until`] rides the timer
/// wheel; otherwise the wake must come from an external [`Waker`].
fn wait_verdict(cx: &mut Ctx) -> Verdict {
    let saturated = cx.take_saturated();
    match cx.take_timer_deadline() {
        Some(deadline) => Verdict::ParkTimer {
            deadline,
            saturated,
        },
        None => Verdict::ParkExternal(saturated),
    }
}

enum Outcome {
    Park(Verdict),
    Finish(Option<Error>),
}

fn drain_control(el: &mut Box<dyn Element>, cx: &mut Ctx) -> crate::error::Result<()> {
    while let Some(msg) = cx.try_pull_control() {
        el.handle_control(msg)?;
    }
    Ok(())
}

fn push_all_eos(cx: &mut Ctx) {
    for pad in 0..cx.n_src_pads() {
        cx.push_eos(pad);
    }
}

/// Execute one step of an element: drain the control mailbox, then one
/// `generate()` (sources) or one input item through `handle()`
/// (consumers) — the exact per-iteration body of the seed scheduler's
/// thread loops, minus the blocking.
fn drive(core: &mut StepCore, stats: &ElementStats) -> Outcome {
    let StepCore {
        element,
        ctx,
        kind,
        eos_seen,
        early_eos,
        waiting_external,
    } = core;
    let el = element.as_mut().expect("task stepped after finish");
    let cx = ctx.as_mut().expect("task stepped after finish");
    cx.begin_step();

    match *kind {
        TaskKind::Source => {
            if cx.stopped() {
                push_all_eos(cx);
                return Outcome::Finish(None);
            }
            // Deterministic fault injection: the source's step index is
            // the number of *productive* generate() calls so far (Wait
            // retries don't count), so an injected fault lands at the
            // same produced-buffer boundary for any worker count.
            if let Some(kind) = cx.check_injected_fault() {
                match kind {
                    FaultKind::Panic => panic!("injected fault: panic before source step"),
                    FaultKind::Error => {
                        return Outcome::Finish(Some(Error::element(
                            el.type_name(),
                            "injected fault",
                        )));
                    }
                    FaultKind::DelayMs(ms) => {
                        // the injected delay rides the timer wheel like
                        // any timed wait; the sticky fired flag means the
                        // post-wake re-entry proceeds into generate()
                        if cx.park_until(Instant::now() + Duration::from_millis(ms)) {
                            return Outcome::Park(wait_verdict(cx));
                        }
                    }
                    // an in-step stall (the watchdog's runnable-but-
                    // frozen signature) must actually wedge the worker
                    FaultKind::StallMs(ms) => thread::sleep(Duration::from_millis(ms)),
                    FaultKind::Drop => return Outcome::Park(Verdict::Ready),
                }
            }
            let t0 = Instant::now();
            let flow = drain_control(el, cx).and_then(|_| el.generate(cx));
            let busy = t0.elapsed().saturating_sub(cx.take_idle());
            stats.record_busy(cx.domain, busy);
            if matches!(flow, Ok(Flow::Continue)) {
                cx.advance_injected_fault();
            }
            match flow {
                // No EOS downstream on error: the stream is truncated,
                // not complete — finish_task forwards the typed fault to
                // every downstream inbox instead.
                Err(e) => Outcome::Finish(Some(e)),
                Ok(Flow::Eos) => {
                    push_all_eos(cx);
                    Outcome::Finish(None)
                }
                Ok(Flow::Wait) => Outcome::Park(wait_verdict(cx)),
                Ok(Flow::Continue) => {
                    let sat = cx.take_saturated();
                    if sat.is_empty() {
                        Outcome::Park(Verdict::Ready)
                    } else {
                        Outcome::Park(Verdict::ParkOutput(sat))
                    }
                }
            }
        }
        TaskKind::Consumer { n_sink_links } => {
            // Re-entry after a Flow::Wait from handle(): the element has
            // a stashed job (an in-flight device submit, a timed
            // envelope pad). resume() — not poll_input — so the pending
            // work drains, in order, before any new input is consumed.
            if *waiting_external {
                let t0 = Instant::now();
                let flow = drain_control(el, cx).and_then(|_| el.resume(cx));
                let busy = t0.elapsed().saturating_sub(cx.take_idle());
                stats.record_busy(cx.domain, busy);
                match flow {
                    Err(e) => return Outcome::Finish(Some(e)),
                    // still pending (spurious wake, or the completion
                    // has not fired yet): park again
                    Ok(Flow::Wait) => return Outcome::Park(wait_verdict(cx)),
                    Ok(Flow::Eos) => {
                        *waiting_external = false;
                        if let Err(e) = el.flush(cx) {
                            return Outcome::Finish(Some(e));
                        }
                        push_all_eos(cx);
                        *early_eos = true;
                    }
                    Ok(Flow::Continue) => {
                        *waiting_external = false;
                    }
                }
                // outputs emitted by the resumed work go through the
                // ordinary saturation gate; input polling restarts on
                // the next step
                let sat = cx.take_saturated();
                return Outcome::Park(if sat.is_empty() {
                    Verdict::Ready
                } else {
                    Verdict::ParkOutput(sat)
                });
            }
            match cx.poll_input() {
            PopResult::Pending => Outcome::Park(Verdict::ParkInput),
            PopResult::Exhausted => {
                if !*early_eos {
                    if let Some(fault) = cx.input_fault() {
                        // A producer died: the stream is truncated, not
                        // complete. No flush (partial state must not be
                        // emitted as if the stream finished) and no EOS
                        // downstream — the element is told via on_fault
                        // (sinks forward it to application endpoints)
                        // and finish_task propagates the typed fault.
                        let _ = drain_control(el, cx);
                        el.on_fault(&fault);
                        return Outcome::Finish(None);
                    }
                    // All producers gone with full EOS accounting still
                    // pending but no fault recorded (e.g. an upstream
                    // element finished early on request): flush and
                    // unwind, exactly like the seed's
                    // disconnected-channel path.
                    let t0 = Instant::now();
                    let r = drain_control(el, cx).and_then(|_| el.flush(cx));
                    let busy = t0.elapsed().saturating_sub(cx.take_idle());
                    stats.record_busy(cx.domain, busy);
                    push_all_eos(cx);
                    if let Err(e) = r {
                        return Outcome::Finish(Some(e));
                    }
                }
                Outcome::Finish(None)
            }
            PopResult::Item((pad, item)) => {
                if matches!(item, Item::Eos) {
                    *eos_seen += 1;
                }
                // Deterministic fault injection: a consumer's step index
                // counts the buffers that arrived at the element, so an
                // injected fault lands before the same input frame for
                // any worker count. Drop discards the frame (it still
                // advances the index).
                if !*early_eos && matches!(item, Item::Buffer(_)) {
                    if let Some(kind) = cx.check_injected_fault() {
                        match kind {
                            FaultKind::Panic => {
                                panic!("injected fault: panic before consuming buffer")
                            }
                            FaultKind::Error => {
                                return Outcome::Finish(Some(Error::element(
                                    el.type_name(),
                                    "injected fault",
                                )));
                            }
                            FaultKind::DelayMs(ms) => {
                                // timer-wheel park: hand the item back
                                // first; the sticky fired flag makes the
                                // replayed check a no-op, so the index
                                // still advances exactly once
                                if cx.park_until(Instant::now() + Duration::from_millis(ms))
                                {
                                    cx.replay_input(pad, item);
                                    return Outcome::Park(wait_verdict(cx));
                                }
                            }
                            FaultKind::StallMs(ms) => {
                                thread::sleep(Duration::from_millis(ms));
                            }
                            FaultKind::Drop => {
                                cx.advance_injected_fault();
                                if let Err(e) = drain_control(el, cx) {
                                    return Outcome::Finish(Some(e));
                                }
                                return Outcome::Park(Verdict::Ready);
                            }
                        }
                    }
                    cx.advance_injected_fault();
                }
                // Deadline step gate: a buffer that is already past the
                // pipeline's deadline budget is shed here, before the
                // element spends compute on it. EOS and control traffic
                // are exempt so teardown and steering stay exact.
                if !*early_eos {
                    if let Item::Buffer(b) = &item {
                        if cx.past_deadline(b) {
                            stats.record_shed();
                            if let Err(e) = drain_control(el, cx) {
                                return Outcome::Finish(Some(e));
                            }
                            return Outcome::Park(Verdict::Ready);
                        }
                    }
                }
                if *early_eos {
                    // done but still draining input: keep the control
                    // mailbox drained so application sends don't back up
                    // against a finished element
                    if let Err(e) = drain_control(el, cx) {
                        return Outcome::Finish(Some(e));
                    }
                } else {
                    let t0 = Instant::now();
                    // control first: a message enqueued before this item
                    // entered the pipeline is in effect for it
                    let flow =
                        drain_control(el, cx).and_then(|_| el.handle(pad, item, cx));
                    let busy = t0.elapsed().saturating_sub(cx.take_idle());
                    stats.record_busy(cx.domain, busy);
                    match flow {
                        Ok(Flow::Continue) => {}
                        Ok(Flow::Wait) => {
                            // the element either handed the item back via
                            // push_back_input (appsink waiting for the
                            // application to drain) or stashed a pending
                            // job (tensor_filter with a device submit in
                            // flight): park, carrying any saturated
                            // outputs into the wake gate; the next step
                            // re-enters through resume()
                            *waiting_external = true;
                            return Outcome::Park(wait_verdict(cx));
                        }
                        Ok(Flow::Eos) => {
                            // element declared end-of-stream: flush,
                            // notify downstream, keep draining input so
                            // upstream never parks on a dead consumer
                            if let Err(e) = el.flush(cx) {
                                return Outcome::Finish(Some(e));
                            }
                            push_all_eos(cx);
                            *early_eos = true;
                        }
                        Err(e) => {
                            // no EOS downstream: finish_task forwards
                            // the typed fault instead
                            return Outcome::Finish(Some(e));
                        }
                    }
                }
                if *eos_seen >= n_sink_links {
                    if !*early_eos {
                        let r = el.flush(cx);
                        push_all_eos(cx);
                        if let Err(e) = r {
                            return Outcome::Finish(Some(e));
                        }
                    }
                    return Outcome::Finish(None);
                }
                let sat = cx.take_saturated();
                if sat.is_empty() {
                    Outcome::Park(Verdict::Ready)
                } else {
                    Outcome::Park(Verdict::ParkOutput(sat))
                }
            }
            }
        }
    }
}

/// Tear a finished task down so neighbors observe termination exactly
/// like a thread exit under the seed scheduler: downstream inboxes lose
/// a producer (end-of-input once drained), the own inbox closes (pushes
/// fail, parked producers release — upstream unwinds instead of
/// leaking), and the element lands in its pipeline completion slot.
///
/// Fault flow: a task that dies with an error — or whose own input
/// carried a fault from further upstream — stamps that fault on every
/// downstream inbox before detaching, so the truncation reason travels
/// the whole chain (and across topics, via the element `on_fault`
/// hooks) instead of decaying into a clean-looking EOS.
fn finish_task(task: &Arc<Task>, err: Option<Error>) {
    let (mut element, ctx) = {
        let mut core = lock(&task.step);
        (core.element.take(), core.ctx.take())
    };
    let fault = match &err {
        Some(e) => Some(Fault::from_error(&task.name, e)),
        None => task.inbox.as_ref().and_then(|ib| ib.fault()),
    };
    if let (Some(e), Some(el)) = (&err, element.as_mut()) {
        // The dying element gets the fault too (an appsink that
        // panicked must still fail its application endpoint, or the
        // receiver would mistake the truncation for clean EOS). The
        // element may be mid-panic-unwind state, so a second panic in
        // the hook is contained here.
        let f = Fault::from_error(&task.name, e);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| el.on_fault(&f)));
    }
    if let Some(mut cx) = ctx {
        cx.release_outputs_fault(fault.as_ref());
    }
    if let Some(ib) = &task.inbox {
        ib.close();
    }
    lock(&task.sched).finish();
    task.core.remove_live(task);
    task.run.task_finished(task.index, element, err);
}

/// Park a task on a set of saturated downstream inboxes. Publishes the
/// gate set first (so any wake landing after the park re-checks it at
/// dequeue), then registers as a waiter on each inbox with an atomic
/// register-and-recheck under the inbox lock — if any inbox already
/// drained (or closed), the task self-wakes instead of risking a lost
/// wakeup. Shared by the step verdict path and the worker-loop gate
/// re-park.
fn park_on_output(task: &Arc<Task>, saturated: Vec<Arc<Inbox>>) {
    task.stats.record_park_output();
    *lock(&task.blocked_on) = saturated.clone();
    if park(task, SchedState::ParkedOutput) {
        let mut already_drained = false;
        for ib in &saturated {
            if !ib.register_waiter(task) {
                already_drained = true;
            }
        }
        if already_drained {
            wake_task(task);
        }
    }
}

fn apply_verdict(task: &Arc<Task>, verdict: Verdict) {
    match verdict {
        Verdict::Ready => requeue(task),
        Verdict::ParkInput => {
            task.stats.record_park_input();
            if park(task, SchedState::ParkedInput) {
                // lost-wakeup guard: an item may have arrived between the
                // step's empty poll and the park transition
                let ready = match task.inbox.as_ref() {
                    Some(ib) => ib.has_ready(),
                    None => true,
                };
                if ready {
                    wake_task(task);
                }
            }
        }
        Verdict::ParkOutput(saturated) => park_on_output(task, saturated),
        Verdict::ParkExternal(saturated) => {
            // an external park is an input park (waiting on the
            // application) for accounting purposes, keeping
            // wakeups <= parks
            task.stats.record_park_input();
            // saturated outputs go into the dequeue gate (not the
            // waiter lists): the external Waker is the unpark path,
            // but the task must not step past full links when it fires
            *lock(&task.blocked_on) = saturated;
            // the wake_pending check inside park() covers an external
            // wake that raced the park decision
            park(task, SchedState::ParkedExternal);
        }
        Verdict::ParkTimer {
            deadline,
            saturated,
        } => {
            // a timer park is an external park whose waker is the wheel
            task.stats.record_park_input();
            task.stats.record_timer_park();
            *lock(&task.blocked_on) = saturated;
            if park(task, SchedState::ParkedExternal) {
                // arm *after* the park transition so the fire cannot
                // precede it; a fire racing a concurrent external wake
                // is absorbed by wake_task as a no-op
                task.core.arm_timer(deadline, task);
            }
        }
    }
}

fn worker_loop(core: Arc<ExecutorCore>) {
    'outer: loop {
        // Timer service: no dedicated thread — whichever worker passes
        // here fires the due wheel entries (outside the run-queue lock).
        core.fire_due_timers();
        let task = {
            let mut rq = lock(&core.rq);
            loop {
                if core.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(t) = rq.pop() {
                    break t;
                }
                // idle: bound the wait by the soonest armed deadline so
                // a fully parked pool still fires its timers on time
                match core.next_timer_due() {
                    Some(due) => {
                        let now = Instant::now();
                        if due <= now {
                            drop(rq);
                            continue 'outer;
                        }
                        let (g, _) = core
                            .available
                            .wait_timeout(rq, due - now)
                            .unwrap_or_else(|e| e.into_inner());
                        rq = g;
                        if core.next_timer_due().map_or(false, |d| d <= Instant::now()) {
                            drop(rq);
                            continue 'outer;
                        }
                    }
                    None => {
                        rq = core.available.wait(rq).unwrap_or_else(|e| e.into_inner());
                    }
                }
            }
        };
        lock(&task.sched).set_running();
        // Output gate: a task woken out of park-on-output only steps
        // once every link it parked on drained below capacity; partial
        // wakes re-park on the still-full remainder. This keeps bounded
        // links bounded when one downstream branch is fast and another
        // slow.
        let gate = std::mem::take(&mut *lock(&task.blocked_on));
        if !gate.is_empty() {
            let still_full: Vec<Arc<Inbox>> =
                gate.into_iter().filter(|ib| ib.at_capacity()).collect();
            if !still_full.is_empty() {
                park_on_output(&task, still_full);
                continue;
            }
        }
        task.stats.record_step();
        core.steps_total.fetch_add(1, Ordering::Relaxed);
        // isolate element panics to the step, like the seed isolated
        // them to the element's thread
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut step = lock(&task.step);
            drive(&mut step, &task.stats)
        }));
        match outcome {
            Ok(Outcome::Park(v)) => apply_verdict(&task, v),
            Ok(Outcome::Finish(err)) => finish_task(&task, err),
            Err(payload) => {
                // preserve the panic payload: `panic!("...")` carries a
                // &str or String; anything else stays opaque
                let message = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "panic payload of unknown type".to_string());
                finish_task(
                    &task,
                    Some(Error::Panicked {
                        element: task.name.clone(),
                        message,
                    }),
                );
            }
        }
    }
}

/// Lower bound of the *auto-detected* worker count (explicit requests
/// may go below it — see [`clamp_explicit_workers`]).
pub const AUTO_WORKERS_MIN: usize = 2;
/// Upper bound of the *auto-detected* worker count.
pub const AUTO_WORKERS_MAX: usize = 8;

/// The single home of the worker-count envelope. Two regimes exist on
/// purpose and must not be conflated:
///
/// * **explicit** — `Executor::new(n)` or `NNS_WORKERS=n`: the caller
///   decides; we only enforce `1..=`[`MAX_WORKERS`]. One worker is
///   valid and fully supported (every pipeline still completes, just
///   serialized — the CI matrix runs the whole suite under
///   `NNS_WORKERS=1` and `NNS_WORKERS=8`).
/// * **auto-detected** — no configuration: the core count clamped to
///   [`AUTO_WORKERS_MIN`]`..=`[`AUTO_WORKERS_MAX`], so the default
///   neither grabs a big machine's every core uninvited nor drops to a
///   single worker on a 1-core box.
fn clamp_explicit_workers(n: usize) -> usize {
    n.clamp(1, MAX_WORKERS)
}

fn default_workers() -> usize {
    if let Ok(v) = std::env::var("NNS_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return clamp_explicit_workers(n);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(AUTO_WORKERS_MIN, AUTO_WORKERS_MAX)
}

/// A fixed-size worker pool executing element tasks. Cheap to clone
/// (shared handle). The process-wide [`Executor::global`] instance sizes
/// itself from `NNS_WORKERS` (default: the core count, clamped to
/// [`AUTO_WORKERS_MIN`]..=[`AUTO_WORKERS_MAX`]) and backs
/// `Pipeline::play`/`run` and `SingleShot`; dedicated executors serve
/// tests and [`PipelineHub`](crate::pipeline::PipelineHub)s that need
/// their own bounded pool.
#[derive(Clone)]
pub struct Executor {
    core: Arc<ExecutorCore>,
}

impl Executor {
    /// Spawn a pool of `workers` threads (clamped to 1..=[`MAX_WORKERS`];
    /// see [`clamp_explicit_workers`] for the full envelope).
    pub fn new(workers: usize) -> Executor {
        let workers = clamp_explicit_workers(workers);
        let core = Arc::new(ExecutorCore {
            rq: Mutex::new(RunQueue::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            workers,
            live: Mutex::new(Vec::new()),
            timers: Mutex::new(TimerWheel::new()),
            steps_total: AtomicU64::new(0),
            wakeups_total: AtomicU64::new(0),
            timer_parks_total: AtomicU64::new(0),
            timer_fires_total: AtomicU64::new(0),
            runq_hwm: AtomicU64::new(0),
        });
        for i in 0..workers {
            let c = core.clone();
            thread::Builder::new()
                .name(format!("nns-worker-{i}"))
                .spawn(move || worker_loop(c))
                .expect("spawn pool worker");
        }
        Executor { core }
    }

    /// The process-wide default executor (all `Pipeline::play` traffic).
    pub fn global() -> &'static Executor {
        static GLOBAL: Lazy<Executor> = Lazy::new(|| Executor::new(default_workers()));
        &GLOBAL
    }

    pub fn worker_count(&self) -> usize {
        self.core.workers
    }

    /// Total element steps executed across all pipelines.
    pub fn steps_executed(&self) -> u64 {
        self.core.steps_total.load(Ordering::Relaxed)
    }

    /// Total parked-task wakeups across all pipelines.
    pub fn wakeups(&self) -> u64 {
        self.core.wakeups_total.load(Ordering::Relaxed)
    }

    /// Total deadline parks armed on the timer wheel (live-source
    /// pacing, envelope pads, injected delays).
    pub fn timer_parks(&self) -> u64 {
        self.core.timer_parks_total.load(Ordering::Relaxed)
    }

    /// Total timer-wheel entries fired by idle workers.
    pub fn timer_fires(&self) -> u64 {
        self.core.timer_fires_total.load(Ordering::Relaxed)
    }

    /// High-water mark of the global run queue (scheduling-pressure
    /// indicator: how many tasks were runnable but waiting for a worker).
    pub fn run_queue_high_water(&self) -> u64 {
        self.core.runq_hwm.load(Ordering::Relaxed)
    }

    /// Number of unfinished element tasks currently owned by the pool.
    pub fn live_tasks(&self) -> usize {
        lock(&self.core.live).len()
    }

    /// Stop the worker threads once idle. Parked pipelines are stranded —
    /// only call on dedicated executors after everything joined (the
    /// dedicated-`PipelineHub` drop path).
    pub fn shutdown(&self) {
        self.core.shutdown.store(true, Ordering::Relaxed);
        self.core.available.notify_all();
    }

    /// Wire and enqueue every task of one pipeline. The returned wakers
    /// (one per task, weak) let the pipeline handle nudge parked tasks —
    /// `request_stop` uses them so a parked source observes the flag.
    pub(crate) fn spawn_pipeline(
        &self,
        specs: Vec<TaskSpec>,
        run: &Arc<PipelineRun>,
    ) -> Vec<Waker> {
        let mut tasks = Vec::with_capacity(specs.len());
        for spec in specs {
            let kind = if spec.is_source {
                TaskKind::Source
            } else {
                TaskKind::Consumer {
                    n_sink_links: spec.n_sink_links,
                }
            };
            let task = Arc::new(Task {
                name: spec.name,
                index: spec.index,
                pri: spec.pri,
                stats: spec.stats,
                core: self.core.clone(),
                run: run.clone(),
                inbox: spec.inbox,
                blocked_on: Mutex::new(Vec::new()),
                step: Mutex::new(StepCore {
                    element: Some(spec.element),
                    ctx: Some(spec.ctx),
                    kind,
                    eos_seen: 0,
                    early_eos: false,
                    waiting_external: false,
                }),
                sched: Mutex::new(SchedCell::new()),
            });
            // hand the element a waker for external (appsrc-style) wakes
            if let Some(cx) = lock(&task.step).ctx.as_mut() {
                cx.set_waker(Waker::for_task(&task));
            }
            if let Some(ib) = &task.inbox {
                ib.set_consumer(&task);
            }
            tasks.push(task);
        }
        lock(&self.core.live).extend(tasks.iter().cloned());
        let wakers: Vec<Waker> = tasks.iter().map(Waker::for_task).collect();
        for t in tasks {
            self.core.enqueue(t);
        }
        wakers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Buffer;

    fn stats() -> Arc<ElementStats> {
        ElementStats::new("test")
    }

    #[test]
    fn run_queue_rotation_never_starves_low() {
        let mut rq = RunQueue::new();
        // no tasks needed: empty lanes fall through to priority order
        assert!(rq.pop().is_none());
        assert_eq!(LANE_PICKS.iter().filter(|&&l| l == 0).count(), 4);
        assert_eq!(LANE_PICKS.iter().filter(|&&l| l == 1).count(), 2);
        assert_eq!(LANE_PICKS.iter().filter(|&&l| l == 2).count(), 1);
    }

    #[test]
    fn inbox_blocking_push_saturates_at_capacity() {
        let ib = Inbox::new(2, stats());
        ib.add_producer();
        let b = || Item::Buffer(Buffer::from_f32(0, &[1.0]));
        assert!(matches!(
            ib.push(0, b()),
            PushResult::Delivered { saturated: false }
        ));
        assert!(matches!(
            ib.push(0, b()),
            PushResult::Delivered { saturated: true }
        ));
        // over-capacity pushes still deliver (bounded by one step)
        assert!(matches!(
            ib.push(0, b()),
            PushResult::Delivered { saturated: true }
        ));
        assert!(matches!(ib.try_pop(), PopResult::Item(_)));
    }

    #[test]
    fn inbox_leaky_push_drops_at_capacity() {
        let ib = Inbox::new(1, stats());
        ib.add_producer();
        let b = || Item::Buffer(Buffer::from_f32(0, &[1.0]));
        assert!(matches!(ib.push_leaky(0, b()), PushResult::Delivered { .. }));
        assert!(matches!(ib.push_leaky(0, b()), PushResult::Dropped));
    }

    #[test]
    fn inbox_exhausts_when_producers_finish() {
        let ib = Inbox::new(4, stats());
        ib.add_producer();
        assert!(matches!(ib.try_pop(), PopResult::Pending));
        ib.push(0, Item::Buffer(Buffer::from_f32(0, &[1.0])));
        ib.producer_done();
        // queued item still delivered, then end-of-input
        assert!(matches!(ib.try_pop(), PopResult::Item(_)));
        assert!(matches!(ib.try_pop(), PopResult::Exhausted));
    }

    #[test]
    fn inbox_close_rejects_pushes() {
        let ib = Inbox::new(4, stats());
        ib.add_producer();
        ib.close();
        assert!(matches!(
            ib.push(0, Item::Buffer(Buffer::from_f32(0, &[1.0]))),
            PushResult::Closed
        ));
    }

    #[test]
    fn executor_clamps_workers() {
        let e = Executor::new(0);
        assert_eq!(e.worker_count(), 1);
        e.shutdown();
        let e = Executor::new(MAX_WORKERS + 100);
        assert_eq!(e.worker_count(), MAX_WORKERS);
        e.shutdown();
    }

    #[test]
    fn worker_clamp_envelope() {
        // Explicit requests honor the full 1..=MAX_WORKERS envelope; the
        // auto-detected default never leaves AUTO_WORKERS_MIN..=MAX.
        assert_eq!(clamp_explicit_workers(0), 1);
        assert_eq!(clamp_explicit_workers(1), 1);
        assert_eq!(clamp_explicit_workers(8), 8);
        assert_eq!(clamp_explicit_workers(MAX_WORKERS), MAX_WORKERS);
        assert_eq!(clamp_explicit_workers(MAX_WORKERS + 1), MAX_WORKERS);
        assert!(AUTO_WORKERS_MIN >= 1);
        assert!(AUTO_WORKERS_MAX <= MAX_WORKERS);
        // a single explicit worker still runs a pipeline to completion
        let e = Executor::new(1);
        assert_eq!(e.worker_count(), 1);
        e.shutdown();
    }

    #[test]
    fn timer_wheel_fires_only_due_entries() {
        let mut w: TimerWheel<u32> = TimerWheel::new();
        let now = Instant::now();
        // entries on both sides of `now`, including one already past and
        // one a full wheel round away (same slot, later deadline)
        w.arm(now - Duration::from_millis(5), 0);
        w.arm(now + Duration::from_millis(2), 1);
        w.arm(
            now + Duration::from_millis(2)
                + Duration::from_nanos(WHEEL_SLOTS as u64 * WHEEL_TICK_NS),
            2,
        );
        assert_eq!(w.len, 3);
        assert_eq!(w.take_due(now).len(), 1, "only the past entry fires");
        assert_eq!(w.len, 2);
        let soon = w.soonest.expect("future entries keep a soonest");
        assert!(soon > now);
        assert_eq!(w.take_due(now).len(), 0, "nothing due fires nothing");
        assert_eq!(
            w.take_due(now + Duration::from_millis(3)).len(),
            1,
            "hashed collision from a later round must not fire early"
        );
        assert_eq!(w.len, 1);
        assert_eq!(w.take_due(now + Duration::from_secs(2)).len(), 1);
        assert_eq!(w.len, 0);
        assert!(w.soonest.is_none());
    }

    #[test]
    fn inbox_records_first_fault_only() {
        let ib = Inbox::new(4, stats());
        ib.add_producer();
        assert!(ib.fault().is_none());
        let f1 = Fault {
            element: "a".into(),
            message: "first".into(),
            panicked: true,
        };
        let f2 = Fault {
            element: "b".into(),
            message: "second".into(),
            panicked: false,
        };
        ib.producer_fault(&f1);
        ib.producer_fault(&f2);
        assert_eq!(ib.fault(), Some(f1));
    }
}
