//! Multi-tenant pipeline registry over one bounded worker pool.
//!
//! The among-device-AI follow-up paper (arXiv:2201.06026) has devices
//! hosting *many* pipelines at once. A [`PipelineHub`] launches,
//! enumerates, steers and joins any number of concurrent pipelines over
//! a single [`Executor`] — so 64 pipelines of 10 elements run on, say, 4
//! worker threads instead of the 640 the seed scheduler would have
//! spawned. Per-pipeline [`Priority`] lanes let latency-sensitive
//! pipelines (a camera feed) outrank background ones (a model warmup)
//! without starving either, and the worker count is hard-capped at
//! [`MAX_WORKERS`](crate::pipeline::executor::MAX_WORKERS) regardless of
//! configuration.
//!
//! ```no_run
//! use nnstreamer::pipeline::{Pipeline, PipelineHub};
//!
//! # fn main() -> nnstreamer::Result<()> {
//! let hub = PipelineHub::with_workers(4);
//! for i in 0..64 {
//!     let p = Pipeline::parse(
//!         "videotestsrc num-buffers=32 ! tensor_converter ! fakesink",
//!     )?;
//!     hub.launch(format!("cam-{i}"), p)?;
//! }
//! for joined in hub.join_all() {
//!     let report = joined.report?;
//!     println!("{}: {:.1} s", joined.name, report.wall.as_secs_f64());
//! }
//! # Ok(())
//! # }
//! ```

use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::metrics::stats::PipelineReport;
use crate::pipeline::executor::{lock, Executor, Priority};
use crate::pipeline::scheduler::{self, Controller, Running};
use crate::pipeline::stream::{
    QueryClient, StreamRegistry, SubscriberClose, TopicPublisher, TopicSubscriber,
};
use crate::pipeline::Pipeline;

struct HubEntry {
    name: String,
    pri: Priority,
    pipeline: Pipeline,
    running: Option<Running>,
}

/// Result of joining one hub pipeline: its report (or failure) plus the
/// [`Pipeline`] itself, whose finished elements (collecting sinks, app
/// handles) remain inspectable via
/// [`Pipeline::finished_element`].
pub struct HubJoin {
    pub name: String,
    pub priority: Priority,
    pub report: Result<PipelineReport>,
    pub pipeline: Pipeline,
}

/// Registry of concurrently running pipelines sharing one bounded
/// executor (see the module docs for an example).
pub struct PipelineHub {
    exec: Executor,
    /// True when this hub spawned its own pool (shut down on drop once
    /// no launched pipeline is still executing); false when it shares
    /// [`Executor::global`].
    dedicated: bool,
    entries: Mutex<Vec<HubEntry>>,
    /// Stream-endpoint registry this hub resolves topics in (the
    /// process-global one, so pipelines compose across hubs).
    streams: StreamRegistry,
    /// Weak closers of every subscriber handle this hub issued:
    /// [`request_stop_all`](PipelineHub::request_stop_all) closes them so
    /// application drain loops over [`subscribe`](PipelineHub::subscribe)
    /// terminate.
    subs: Mutex<Vec<SubscriberClose>>,
}

impl PipelineHub {
    fn over(exec: Executor, dedicated: bool) -> PipelineHub {
        PipelineHub {
            exec,
            dedicated,
            entries: Mutex::new(Vec::new()),
            streams: StreamRegistry::global().clone(),
            subs: Mutex::new(Vec::new()),
        }
    }

    /// A hub over the process-global executor (shared with
    /// `Pipeline::play` traffic).
    pub fn new() -> PipelineHub {
        PipelineHub::over(Executor::global().clone(), false)
    }

    /// A hub with its own dedicated pool of `workers` threads (clamped
    /// to the hard cap). The pool is shut down when the hub is dropped
    /// and no launched pipeline is still executing (joined or not).
    pub fn with_workers(workers: usize) -> PipelineHub {
        PipelineHub::over(Executor::new(workers), true)
    }

    /// A hub over a caller-managed executor.
    pub fn on(exec: &Executor) -> PipelineHub {
        PipelineHub::over(exec.clone(), false)
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// The stream-endpoint registry this hub resolves topics in.
    pub fn streams(&self) -> &StreamRegistry {
        &self.streams
    }

    /// Publish a named topic from application code: the returned handle
    /// pushes buffers to every subscriber — `tensor_query_serversrc`
    /// elements of launched pipelines, or other application
    /// [`subscribe`](PipelineHub::subscribe) handles. The app-side
    /// counterpart of ending a pipeline in `tensor_query_serversink`.
    pub fn publish(&self, topic: &str) -> TopicPublisher {
        self.streams.publish(topic)
    }

    /// Subscribe a named topic from application code. The handle's
    /// `recv` loop terminates at topic end-of-stream **and** when
    /// [`request_stop_all`](PipelineHub::request_stop_all) runs — the
    /// hub closes every subscriber handle it issued.
    pub fn subscribe(&self, topic: &str) -> TopicSubscriber {
        let s = self.streams.subscribe(topic);
        self.track_subscription(s.close_handle());
        s
    }

    /// [`subscribe`](PipelineHub::subscribe) with an explicit queue
    /// bound (small bounds apply backpressure to publishers sooner).
    pub fn subscribe_with_capacity(&self, topic: &str, capacity: usize) -> TopicSubscriber {
        let s = self.streams.subscribe_with_capacity(topic, capacity);
        self.track_subscription(s.close_handle());
        s
    }

    /// Remember a closer for `request_stop_all`, pruning closers whose
    /// handles were already dropped so long-lived hubs serving many
    /// short-lived subscriptions don't accumulate dead entries.
    fn track_subscription(&self, closer: SubscriberClose) {
        let mut subs = lock(&self.subs);
        subs.retain(|s| !s.is_dead());
        subs.push(closer);
    }

    /// A request/response handle over a serving pipeline's topic pair
    /// (see [`QueryClient`]).
    pub fn query_client(&self, request: &str, reply: &str) -> QueryClient {
        self.streams.query_client(request, reply)
    }

    pub fn worker_count(&self) -> usize {
        self.exec.worker_count()
    }

    /// Launch a pipeline at [`Priority::Normal`]; returns its control
    /// handle. Pipeline names must be unique within the hub.
    pub fn launch(&self, name: impl Into<String>, pipeline: Pipeline) -> Result<Controller> {
        self.launch_with_priority(name, pipeline, Priority::Normal)
    }

    /// Launch a pipeline with an explicit scheduling priority.
    pub fn launch_with_priority(
        &self,
        name: impl Into<String>,
        mut pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        let name = name.into();
        let mut entries = lock(&self.entries);
        if entries.iter().any(|e| e.name == name) {
            return Err(Error::Runtime(format!(
                "hub already runs a pipeline named {name:?}"
            )));
        }
        let running = scheduler::start_on(&self.exec, &mut pipeline.graph, pri)?;
        let controller = running.controller();
        entries.push(HubEntry {
            name,
            pri,
            pipeline,
            running: Some(running),
        });
        Ok(controller)
    }

    /// Number of launched (not yet joined) pipelines.
    pub fn len(&self) -> usize {
        lock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Names of the launched pipelines, in launch order.
    pub fn names(&self) -> Vec<String> {
        lock(&self.entries).iter().map(|e| e.name.clone()).collect()
    }

    /// How many launched pipelines are still executing.
    pub fn running_count(&self) -> usize {
        lock(&self.entries)
            .iter()
            .filter(|e| e.running.as_ref().is_some_and(|r| !r.is_done()))
            .count()
    }

    /// Control handle of a launched pipeline, by its hub name.
    pub fn controller(&self, pipeline: &str) -> Option<Controller> {
        lock(&self.entries)
            .iter()
            .find(|e| e.name == pipeline)
            .and_then(|e| e.running.as_ref().map(Running::controller))
    }

    /// Request a stop on every launched pipeline (live sources exit at
    /// their next frame boundary), and close every topic subscriber
    /// handle this hub issued — application drain loops over
    /// [`subscribe`](PipelineHub::subscribe) terminate even if the
    /// topic's publisher never reaches end-of-stream on its own.
    pub fn request_stop_all(&self) {
        for e in lock(&self.entries).iter() {
            if let Some(r) = &e.running {
                r.request_stop();
            }
        }
        for s in lock(&self.subs).drain(..) {
            s.close();
        }
    }

    /// Join every launched pipeline (in launch order) and drain the
    /// registry. Blocks the calling thread only — pool workers keep
    /// stepping the remaining pipelines while earlier ones are joined.
    pub fn join_all(&self) -> Vec<HubJoin> {
        let entries: Vec<HubEntry> = {
            let mut g = lock(&self.entries);
            g.drain(..).collect()
        };
        entries
            .into_iter()
            .map(|mut e| {
                let report = match e.running.take() {
                    Some(running) => running.wait().map(|(report, elements)| {
                        e.pipeline.finished = elements;
                        report
                    }),
                    None => Err(Error::Runtime(format!(
                        "pipeline {:?} was never started",
                        e.name
                    ))),
                };
                HubJoin {
                    name: e.name,
                    priority: e.pri,
                    report,
                    pipeline: e.pipeline,
                }
            })
            .collect()
    }
}

impl Default for PipelineHub {
    fn default() -> Self {
        PipelineHub::new()
    }
}

impl Drop for PipelineHub {
    fn drop(&mut self) {
        // A dedicated pool is stopped as soon as nothing can still be
        // scheduled on it: every launched pipeline finished (joined or
        // not). Pipelines still executing keep their workers alive —
        // shutting down under them would strand parked tasks forever,
        // so that (discouraged) path intentionally leaks the pool.
        if self.dedicated && self.running_count() == 0 {
            self.exec.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_runs_many_pipelines_on_few_workers() {
        let hub = PipelineHub::with_workers(2);
        assert_eq!(hub.worker_count(), 2);
        for i in 0..8 {
            let p = Pipeline::parse(
                "videotestsrc num-buffers=4 pattern=gradient ! \
                 video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
                 tensor_converter ! fakesink name=out",
            )
            .unwrap();
            hub.launch(format!("p{i}"), p).unwrap();
        }
        assert_eq!(hub.len(), 8);
        assert_eq!(hub.names().len(), 8);
        let joined = hub.join_all();
        assert_eq!(joined.len(), 8);
        for j in joined {
            let report = j.report.expect("pipeline succeeded");
            assert_eq!(report.element("out").unwrap().buffers_in(), 4);
            assert_eq!(report.sched.workers, 2);
            assert!(report.sched.steps > 0, "scheduler counted steps");
        }
    }

    #[test]
    fn hub_rejects_duplicate_names() {
        let hub = PipelineHub::with_workers(1);
        let mk = || {
            Pipeline::parse("videotestsrc num-buffers=1 ! fakesink").unwrap()
        };
        hub.launch("same", mk()).unwrap();
        let err = hub.launch("same", mk()).unwrap_err().to_string();
        assert!(err.contains("already runs"), "{err}");
        hub.join_all();
    }

    #[test]
    fn hub_priorities_all_complete() {
        let hub = PipelineHub::with_workers(1);
        for (i, pri) in [Priority::High, Priority::Normal, Priority::Low]
            .into_iter()
            .enumerate()
        {
            let p = Pipeline::parse(
                "videotestsrc num-buffers=3 ! \
                 video/x-raw,format=RGB,width=8,height=8,framerate=240 ! \
                 tensor_converter ! fakesink name=out",
            )
            .unwrap();
            hub.launch_with_priority(format!("p{i}"), p, pri).unwrap();
        }
        for j in hub.join_all() {
            assert_eq!(
                j.report.unwrap().element("out").unwrap().buffers_in(),
                3,
                "pipeline {} at {:?} completed",
                j.name,
                j.priority
            );
        }
    }
}
