//! Multi-tenant pipeline registry over one bounded worker pool.
//!
//! The among-device-AI follow-up paper (arXiv:2201.06026) has devices
//! hosting *many* pipelines at once. A [`PipelineHub`] launches,
//! enumerates, steers and joins any number of concurrent pipelines over
//! a single [`Executor`] — so 64 pipelines of 10 elements run on, say, 4
//! worker threads instead of the 640 the seed scheduler would have
//! spawned. Per-pipeline [`Priority`] lanes let latency-sensitive
//! pipelines (a camera feed) outrank background ones (a model warmup)
//! without starving either, and the worker count is hard-capped at
//! [`MAX_WORKERS`](crate::pipeline::executor::MAX_WORKERS) regardless of
//! configuration.
//!
//! ```no_run
//! use nnstreamer::pipeline::{Pipeline, PipelineHub};
//!
//! # fn main() -> nnstreamer::Result<()> {
//! let hub = PipelineHub::with_workers(4);
//! for i in 0..64 {
//!     let p = Pipeline::parse(
//!         "videotestsrc num-buffers=32 ! tensor_converter ! fakesink",
//!     )?;
//!     hub.launch(format!("cam-{i}"), p)?;
//! }
//! for joined in hub.join_all() {
//!     let report = joined.report?;
//!     println!("{}: {:.1} s", joined.name, report.wall.as_secs_f64());
//! }
//! # Ok(())
//! # }
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{thread, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::stats::PipelineReport;
use crate::pipeline::executor::{lock, Executor, Priority};
use crate::pipeline::scheduler::{self, Controller, Running, WatchdogProbe};
use crate::pipeline::stream::{
    Qos, QueryClient, StreamRegistry, SubscriberClose, TopicPublisher, TopicSubscriber,
};
use crate::pipeline::Pipeline;

struct HubEntry {
    name: String,
    /// Tenant this pipeline was admitted under (None: unquota'd
    /// [`launch`](PipelineHub::launch)).
    tenant: Option<String>,
    pri: Priority,
    pipeline: Pipeline,
    running: Option<Running>,
}

/// Per-tenant admission quotas (each dimension: 0 = unlimited).
///
/// Set with [`PipelineHub::set_quota`]; enforced by
/// [`launch_as`](PipelineHub::launch_as),
/// [`try_admit_invoke`](PipelineHub::try_admit_invoke) and
/// [`subscribe_as`](PipelineHub::subscribe_as). A denied tenant always
/// gets a typed [`Error::AdmissionDenied`] immediately — admission never
/// blocks or hangs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantQuota {
    /// Max pipelines of this tenant live (launched and still executing)
    /// on the hub at once.
    pub max_live_pipelines: usize,
    /// Max concurrently outstanding [`InvokeTicket`]s (one per queued
    /// or in-flight SingleShot-style invoke).
    pub max_queued_invokes: usize,
    /// Max summed queue capacity of this tenant's live topic
    /// subscriptions (its topic-buffer budget).
    pub max_topic_buffers: usize,
}

struct TenantState {
    quota: TenantQuota,
    /// Outstanding invoke tickets (shared with [`InvokeTicket`] drops,
    /// which decrement without taking the hub lock).
    invokes: Arc<AtomicUsize>,
    /// (queue capacity, weak closer) of every subscription admitted for
    /// this tenant; dead closers are pruned at the next admission check.
    topic_caps: Vec<(usize, SubscriberClose)>,
}

impl TenantState {
    fn new(quota: TenantQuota) -> Self {
        TenantState {
            quota,
            invokes: Arc::new(AtomicUsize::new(0)),
            topic_caps: Vec::new(),
        }
    }
}

/// RAII admission slot for one queued invoke. Hold it for the lifetime
/// of the request (queue wait + execution); dropping it releases the
/// slot. Obtained from [`PipelineHub::try_admit_invoke`].
pub struct InvokeTicket {
    slots: Arc<AtomicUsize>,
}

impl Drop for InvokeTicket {
    fn drop(&mut self) {
        self.slots.fetch_sub(1, Ordering::AcqRel);
    }
}

/// What the hub does when a supervised pipeline dies on a fault
/// (element panic, typed element error, watchdog kill). Set per pipeline
/// at [`PipelineHub::launch_supervised`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestartPolicy {
    /// Faults are terminal: the first failure is the pipeline's final
    /// result (same behavior as an unsupervised launch).
    Never,
    /// Rebuild and relaunch the pipeline after each fault, up to
    /// `max_restarts` times, with deterministic exponential backoff:
    /// restart *k* (1-indexed) is delayed `backoff * 2^(k-1)`. A fault
    /// arriving with the budget exhausted quarantines the pipeline —
    /// its final result is a typed [`Error::Quarantined`].
    OnFault {
        max_restarts: u32,
        backoff: Duration,
    },
}

/// Supervisor poll cadence: how often restarts-due, finished runs and
/// watchdog progress are re-examined. Backoff delays and stall timeouts
/// are quantized to this.
const SUPERVISOR_TICK: Duration = Duration::from_millis(1);

/// One pipeline under supervision: a factory that can rebuild it from
/// scratch, its restart budget, and the state of the current run.
struct SupEntry {
    name: String,
    factory: Box<dyn Fn() -> Result<Pipeline> + Send>,
    policy: RestartPolicy,
    pri: Priority,
    /// The current run (None between a fault and the backoff-delayed
    /// restart, and after the terminal result is in).
    running: Option<Running>,
    /// The Pipeline object of the current run (its finished elements are
    /// restored at terminal join, like unsupervised entries).
    pipeline: Option<Pipeline>,
    /// Completed restarts so far.
    restarts: u32,
    /// Faults observed so far (each fault either consumes a restart or
    /// terminates the pipeline).
    faults: u32,
    /// Deadline of the pending backoff-delayed restart.
    restart_at: Option<Instant>,
    /// Terminal result; set exactly once, then `join_supervised` returns.
    done: Option<Result<PipelineReport>>,
}

/// Tracks one pipeline's progress counter for the stall watchdog.
struct StallTrack {
    progress: u64,
    since: Instant,
}

/// State shared between the hub and its supervisor thread (spawned
/// lazily by the first `launch_supervised` / `set_watchdog`). Leaf lock:
/// nothing is called with it held that locks hub entries/tenants/subs,
/// and the scheduler never calls back into it.
struct SupState {
    /// Stall timeout; None disables the watchdog.
    watchdog: Option<Duration>,
    /// Progress probes of *unsupervised* hub launches (pruned once
    /// done); supervised probes are regenerated from `entries` per tick.
    probes: Vec<WatchdogProbe>,
    stall: HashMap<String, StallTrack>,
    entries: Vec<SupEntry>,
    /// `request_stop_all` ran: stop current runs and suppress restarts.
    stopping: bool,
    /// Hub dropped: the thread exits once every supervised entry is
    /// terminal.
    shutdown: bool,
    thread_running: bool,
}

struct Supervisor {
    exec: Executor,
    state: Mutex<SupState>,
    cv: Condvar,
}

impl Supervisor {
    /// Deterministic exponential backoff: restart `k` (1-indexed) waits
    /// `backoff * 2^(k-1)`. The shift is capped so pathological restart
    /// budgets cannot overflow the multiplier.
    fn backoff_delay(backoff: Duration, restart_index: u32) -> Duration {
        let exp = restart_index.saturating_sub(1).min(20);
        backoff.saturating_mul(1u32 << exp)
    }

    /// Supervisor thread body: collect finished supervised runs, decide
    /// restart / quarantine, perform due restarts, and run the stall
    /// watchdog — every [`SUPERVISOR_TICK`], until the hub shuts down
    /// and every supervised entry is terminal.
    fn run(&self) {
        let mut g = lock(&self.state);
        loop {
            let now = Instant::now();
            {
                let SupState {
                    entries,
                    stall,
                    stopping,
                    ..
                } = &mut *g;
                let stopping = *stopping;
                for e in entries.iter_mut() {
                    if e.done.is_some() {
                        continue;
                    }
                    // collect a finished run and decide its fate
                    if e.running.as_ref().is_some_and(|r| r.is_done()) {
                        let running = e.running.take().expect("checked is_some above");
                        match running.wait() {
                            Ok((mut report, elements)) => {
                                report.restarts = e.restarts;
                                report.faults = e.faults;
                                if let Some(p) = e.pipeline.as_mut() {
                                    p.finished = elements;
                                }
                                e.done = Some(Ok(report));
                                self.cv.notify_all();
                            }
                            Err(err) => {
                                e.faults += 1;
                                stall.remove(&e.name);
                                match e.policy {
                                    RestartPolicy::Never => {
                                        e.done = Some(Err(err));
                                        self.cv.notify_all();
                                    }
                                    RestartPolicy::OnFault {
                                        max_restarts,
                                        backoff,
                                    } => {
                                        if stopping {
                                            // stop requested: the fault is final
                                            e.done = Some(Err(err));
                                            self.cv.notify_all();
                                        } else if e.restarts >= max_restarts {
                                            e.done = Some(Err(Error::Quarantined {
                                                pipeline: e.name.clone(),
                                                restarts: e.restarts,
                                                reason: err.to_string(),
                                            }));
                                            self.cv.notify_all();
                                        } else {
                                            e.restarts += 1;
                                            e.restart_at = Some(
                                                now + Self::backoff_delay(backoff, e.restarts),
                                            );
                                        }
                                    }
                                }
                            }
                        }
                    }
                    // a restart is pending: abandon it on stop, perform
                    // it once its backoff deadline passes
                    if e.running.is_none() && e.done.is_none() {
                        if stopping {
                            e.restart_at = None;
                            e.done = Some(Err(Error::Runtime(format!(
                                "pipeline {:?}: stopped before its supervised restart",
                                e.name
                            ))));
                            self.cv.notify_all();
                        } else if e.restart_at.is_some_and(|at| at <= now) {
                            e.restart_at = None;
                            let started = (e.factory)().and_then(|mut p| {
                                scheduler::start_on(&self.exec, &mut p.graph, e.pri)
                                    .map(|r| (p, r))
                            });
                            match started {
                                Ok((p, r)) => {
                                    e.pipeline = Some(p);
                                    e.running = Some(r);
                                }
                                Err(err) => {
                                    // the rebuild itself failed: terminal
                                    e.done = Some(Err(err));
                                    self.cv.notify_all();
                                }
                            }
                        }
                    }
                }
            }
            // stall watchdog: flag a pipeline that is runnable (some task
            // queued or mid-step) yet whose progress counters froze
            if let Some(timeout) = g.watchdog {
                let SupState {
                    entries,
                    probes,
                    stall,
                    ..
                } = &mut *g;
                probes.retain(|p| !p.is_done());
                let sup_probes: Vec<WatchdogProbe> = entries
                    .iter()
                    .filter_map(|e| e.running.as_ref().map(|r| r.watchdog_probe(&e.name)))
                    .collect();
                for probe in probes.iter().chain(sup_probes.iter()) {
                    let progress = probe.progress();
                    let runnable = probe.is_runnable();
                    let track = stall.entry(probe.name.clone()).or_insert(StallTrack {
                        progress,
                        since: now,
                    });
                    if !runnable || progress != track.progress {
                        // moving, or fully parked (an idle appsrc feed is
                        // not a stall): reset the clock
                        track.progress = progress;
                        track.since = now;
                    } else if now.duration_since(track.since) >= timeout {
                        probe.kill(Error::Stalled {
                            pipeline: probe.name.clone(),
                            stalled_for: now.duration_since(track.since),
                        });
                        stall.remove(&probe.name);
                    }
                }
                // drop tracks of pipelines that finished or were killed
                stall.retain(|name, _| {
                    probes
                        .iter()
                        .chain(sup_probes.iter())
                        .any(|p| p.name == *name)
                });
            }
            if g.shutdown && g.entries.iter().all(|e| e.done.is_some()) {
                g.thread_running = false;
                return;
            }
            let (ng, _) = self
                .cv
                .wait_timeout(g, SUPERVISOR_TICK)
                .unwrap_or_else(|e| e.into_inner());
            g = ng;
        }
    }
}

/// Result of joining one hub pipeline: its report (or failure) plus the
/// [`Pipeline`] itself, whose finished elements (collecting sinks, app
/// handles) remain inspectable via
/// [`Pipeline::finished_element`].
pub struct HubJoin {
    pub name: String,
    /// Tenant the pipeline was admitted under (None for unquota'd
    /// launches) — lets multi-tenant callers route each report back to
    /// its owner.
    pub tenant: Option<String>,
    pub priority: Priority,
    pub report: Result<PipelineReport>,
    pub pipeline: Pipeline,
}

/// Registry of concurrently running pipelines sharing one bounded
/// executor (see the module docs for an example).
pub struct PipelineHub {
    exec: Executor,
    /// True when this hub spawned its own pool (shut down on drop once
    /// no launched pipeline is still executing); false when it shares
    /// [`Executor::global`].
    dedicated: bool,
    entries: Mutex<Vec<HubEntry>>,
    /// Stream-endpoint registry this hub resolves topics in (the
    /// process-global one, so pipelines compose across hubs).
    streams: StreamRegistry,
    /// Weak closers of every subscriber handle this hub issued:
    /// [`request_stop_all`](PipelineHub::request_stop_all) closes them so
    /// application drain loops over [`subscribe`](PipelineHub::subscribe)
    /// terminate.
    subs: Mutex<Vec<SubscriberClose>>,
    /// Admission state per tenant (quota + live usage). Tenants without
    /// an entry are unlimited; plain [`launch`](PipelineHub::launch) /
    /// [`subscribe`](PipelineHub::subscribe) bypass admission entirely.
    tenants: Mutex<HashMap<String, TenantState>>,
    /// Supervision + watchdog state, shared with the lazily spawned
    /// supervisor thread.
    sup: Arc<Supervisor>,
    /// The supervisor thread handle (joined on hub drop).
    sup_thread: Mutex<Option<thread::JoinHandle<()>>>,
    /// Discovery registry served by [`serve_registry`]
    /// (PipelineHub::serve_registry); held so it lives (and its port
    /// stays bound) as long as the hub.
    net_registry: Mutex<Option<crate::net::RegistryServer>>,
}

impl PipelineHub {
    fn over(exec: Executor, dedicated: bool) -> PipelineHub {
        PipelineHub {
            sup: Arc::new(Supervisor {
                exec: exec.clone(),
                state: Mutex::new(SupState {
                    watchdog: None,
                    probes: Vec::new(),
                    stall: HashMap::new(),
                    entries: Vec::new(),
                    stopping: false,
                    shutdown: false,
                    thread_running: false,
                }),
                cv: Condvar::new(),
            }),
            sup_thread: Mutex::new(None),
            exec,
            dedicated,
            entries: Mutex::new(Vec::new()),
            streams: StreamRegistry::global().clone(),
            subs: Mutex::new(Vec::new()),
            tenants: Mutex::new(HashMap::new()),
            net_registry: Mutex::new(None),
        }
    }

    /// A hub over the process-global executor (shared with
    /// `Pipeline::play` traffic).
    pub fn new() -> PipelineHub {
        PipelineHub::over(Executor::global().clone(), false)
    }

    /// A hub with its own dedicated pool of `workers` threads (clamped
    /// to the hard cap). The pool is shut down when the hub is dropped
    /// and no launched pipeline is still executing (joined or not).
    pub fn with_workers(workers: usize) -> PipelineHub {
        PipelineHub::over(Executor::new(workers), true)
    }

    /// A hub over a caller-managed executor.
    pub fn on(exec: &Executor) -> PipelineHub {
        PipelineHub::over(exec.clone(), false)
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// The stream-endpoint registry this hub resolves topics in.
    pub fn streams(&self) -> &StreamRegistry {
        &self.streams
    }

    /// Publish a named topic from application code: the returned handle
    /// pushes buffers to every subscriber — `tensor_query_serversrc`
    /// elements of launched pipelines, or other application
    /// [`subscribe`](PipelineHub::subscribe) handles. The app-side
    /// counterpart of ending a pipeline in `tensor_query_serversink`.
    pub fn publish(&self, topic: &str) -> TopicPublisher {
        self.streams.publish(topic)
    }

    /// Subscribe a named topic from application code. The handle's
    /// `recv` loop terminates at topic end-of-stream **and** when
    /// [`request_stop_all`](PipelineHub::request_stop_all) runs — the
    /// hub closes every subscriber handle it issued.
    pub fn subscribe(&self, topic: &str) -> TopicSubscriber {
        let s = self.streams.subscribe(topic);
        self.track_subscription(s.close_handle());
        s
    }

    /// [`subscribe`](PipelineHub::subscribe) with an explicit queue
    /// bound (small bounds apply backpressure to publishers sooner).
    pub fn subscribe_with_capacity(&self, topic: &str, capacity: usize) -> TopicSubscriber {
        let s = self.streams.subscribe_with_capacity(topic, capacity);
        self.track_subscription(s.close_handle());
        s
    }

    /// [`subscribe`](PipelineHub::subscribe) with an explicit delivery
    /// [`Qos`]: a `Leaky` or `LatestOnly` subscriber never gates
    /// publishers — when its queue is full the arriving (leaky) or
    /// oldest (latest-only) buffer is dropped and counted in the
    /// topic's drop breakdown instead.
    pub fn subscribe_with_qos(&self, topic: &str, qos: Qos) -> TopicSubscriber {
        let s = self.streams.subscribe_with_qos(topic, qos);
        self.track_subscription(s.close_handle());
        s
    }

    /// Remember a closer for `request_stop_all`, pruning closers whose
    /// handles were already dropped so long-lived hubs serving many
    /// short-lived subscriptions don't accumulate dead entries.
    fn track_subscription(&self, closer: SubscriberClose) {
        let mut subs = lock(&self.subs);
        subs.retain(|s| !s.is_dead());
        subs.push(closer);
    }

    /// A request/response handle over a serving pipeline's topic pair
    /// (see [`QueryClient`]).
    pub fn query_client(&self, request: &str, reply: &str) -> QueryClient {
        self.streams.query_client(request, reply)
    }

    /// Host the cross-process discovery registry on `addr`
    /// (`"127.0.0.1:0"` picks a free port) and register a TCP transport
    /// resolving through it under `transport=tcp`. Returns the bound
    /// address — hand it to consumer processes, whose hubs call
    /// [`connect_registry`](PipelineHub::connect_registry) with it.
    /// After this, `tensor_query_serversink topic=x transport=tcp` in
    /// this process serves topic `x` to any process on the network.
    ///
    /// The registry server lives as long as the hub; serving twice
    /// replaces the previous instance.
    pub fn serve_registry(&self, addr: &str) -> Result<String> {
        let server = crate::net::NetRegistry::serve(addr)?;
        let bound = server.addr().to_string();
        crate::net::register_tcp(crate::net::TcpConfig::new(&bound));
        *lock(&self.net_registry) = Some(server);
        Ok(bound)
    }

    /// Join a discovery registry served elsewhere (the address returned
    /// by another process's
    /// [`serve_registry`](PipelineHub::serve_registry)): registers a TCP
    /// transport under `transport=tcp` resolving topics through it, so
    /// `tensor_query_serversrc topic=x transport=tcp` pipelines on this
    /// hub consume streams served by that process. Returns the transport
    /// (e.g. to [`quiesce`](crate::net::TcpTransport::quiesce) before a
    /// publisher process exits).
    pub fn connect_registry(&self, addr: &str) -> Arc<crate::net::TcpTransport> {
        crate::net::register_tcp(crate::net::TcpConfig::new(addr))
    }

    pub fn worker_count(&self) -> usize {
        self.exec.worker_count()
    }

    /// Launch a pipeline at [`Priority::Normal`]; returns its control
    /// handle. Pipeline names must be unique within the hub.
    pub fn launch(&self, name: impl Into<String>, pipeline: Pipeline) -> Result<Controller> {
        self.launch_with_priority(name, pipeline, Priority::Normal)
    }

    /// Launch a pipeline with an explicit scheduling priority.
    pub fn launch_with_priority(
        &self,
        name: impl Into<String>,
        pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        self.launch_inner(None, name.into(), pipeline, pri)
    }

    /// Install (or replace) a tenant's admission quota. Existing usage
    /// is kept — a lowered quota only affects future admissions.
    pub fn set_quota(&self, tenant: impl Into<String>, quota: TenantQuota) {
        let mut tenants = lock(&self.tenants);
        tenants
            .entry(tenant.into())
            .and_modify(|t| t.quota = quota)
            .or_insert_with(|| TenantState::new(quota));
    }

    /// A tenant's installed quota, if any.
    pub fn quota(&self, tenant: &str) -> Option<TenantQuota> {
        lock(&self.tenants).get(tenant).map(|t| t.quota)
    }

    /// Launch a pipeline on behalf of `tenant` at [`Priority::Normal`],
    /// subject to its `max_live_pipelines` quota. Denial is immediate
    /// and typed ([`Error::AdmissionDenied`]); tenants without a quota
    /// are unlimited.
    pub fn launch_as(
        &self,
        tenant: impl Into<String>,
        name: impl Into<String>,
        pipeline: Pipeline,
    ) -> Result<Controller> {
        self.launch_as_with_priority(tenant, name, pipeline, Priority::Normal)
    }

    /// [`launch_as`](PipelineHub::launch_as) with an explicit priority.
    pub fn launch_as_with_priority(
        &self,
        tenant: impl Into<String>,
        name: impl Into<String>,
        pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        self.launch_inner(Some(tenant.into()), name.into(), pipeline, pri)
    }

    fn launch_inner(
        &self,
        tenant: Option<String>,
        name: String,
        mut pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        // Quota lookup before the entries lock (tenants and entries are
        // never held together; each is a leaf lock).
        let live_limit = tenant
            .as_deref()
            .and_then(|t| lock(&self.tenants).get(t).map(|s| s.quota.max_live_pipelines))
            .unwrap_or(0);
        let mut entries = lock(&self.entries);
        if entries.iter().any(|e| e.name == name) {
            return Err(Error::Runtime(format!(
                "hub already runs a pipeline named {name:?}"
            )));
        }
        // Admission: count this tenant's *live* pipelines (launched and
        // still executing) under the entries lock, so concurrent
        // launches can't both slip under the limit.
        if live_limit > 0 {
            let t = tenant.as_deref().unwrap();
            let live = entries
                .iter()
                .filter(|e| e.tenant.as_deref() == Some(t))
                .filter(|e| e.running.as_ref().is_some_and(|r| !r.is_done()))
                .count();
            if live >= live_limit {
                return Err(Error::AdmissionDenied {
                    tenant: t.to_string(),
                    resource: "live pipelines",
                    limit: live_limit,
                });
            }
        }
        let running = scheduler::start_on(&self.exec, &mut pipeline.graph, pri)?;
        let controller = running.controller();
        // register a watchdog probe when stall detection is on (sup.state
        // is a leaf lock: taking it under the entries lock is safe)
        {
            let mut sg = lock(&self.sup.state);
            if sg.watchdog.is_some() {
                sg.probes.retain(|p| !p.is_done());
                sg.probes.push(running.watchdog_probe(&name));
            }
        }
        entries.push(HubEntry {
            name,
            tenant,
            pri,
            pipeline,
            running: Some(running),
        });
        Ok(controller)
    }

    /// Launch a pipeline under supervision: when a run dies on a fault
    /// (element panic, typed element error, watchdog kill), `policy`
    /// decides whether the hub rebuilds it from `factory` and relaunches
    /// — with deterministic exponential backoff — or lets the fault
    /// stand. After `max_restarts` are consumed, the next fault
    /// quarantines the pipeline: its terminal result (from
    /// [`join_supervised`](PipelineHub::join_supervised)) is a typed
    /// [`Error::Quarantined`]. A run that ends cleanly is terminal too,
    /// with its restart/fault history stamped into the
    /// [`PipelineReport`] (`restarts` / `faults`).
    ///
    /// Supervised pipelines live in their own namespace, joined by
    /// [`join_supervised`](PipelineHub::join_supervised) — not by
    /// [`join_all`](PipelineHub::join_all).
    pub fn launch_supervised<F>(
        &self,
        name: impl Into<String>,
        factory: F,
        policy: RestartPolicy,
    ) -> Result<()>
    where
        F: Fn() -> Result<Pipeline> + Send + 'static,
    {
        self.launch_supervised_with_priority(name, factory, policy, Priority::Normal)
    }

    /// [`launch_supervised`](PipelineHub::launch_supervised) with an
    /// explicit scheduling priority (applied to every restart too).
    pub fn launch_supervised_with_priority<F>(
        &self,
        name: impl Into<String>,
        factory: F,
        policy: RestartPolicy,
        pri: Priority,
    ) -> Result<()>
    where
        F: Fn() -> Result<Pipeline> + Send + 'static,
    {
        let name = name.into();
        let mut pipeline = factory()?;
        {
            let mut g = lock(&self.sup.state);
            if g.entries.iter().any(|e| e.name == name) {
                return Err(Error::Runtime(format!(
                    "hub already supervises a pipeline named {name:?}"
                )));
            }
            let running = scheduler::start_on(&self.exec, &mut pipeline.graph, pri)?;
            g.entries.push(SupEntry {
                name,
                factory: Box::new(factory),
                policy,
                pri,
                running: Some(running),
                pipeline: Some(pipeline),
                restarts: 0,
                faults: 0,
                restart_at: None,
                done: None,
            });
        }
        self.ensure_supervisor();
        self.sup.cv.notify_all();
        Ok(())
    }

    /// Block until the named supervised pipeline reaches its terminal
    /// result — a clean completion (report carries `restarts`/`faults`),
    /// a terminal fault, or quarantine — and return it, removing the
    /// entry from the hub.
    pub fn join_supervised(&self, name: &str) -> Result<HubJoin> {
        let mut g = lock(&self.sup.state);
        loop {
            let Some(idx) = g.entries.iter().position(|e| e.name == name) else {
                return Err(Error::Runtime(format!(
                    "hub supervises no pipeline named {name:?}"
                )));
            };
            if g.entries[idx].done.is_some() {
                let e = g.entries.remove(idx);
                return Ok(HubJoin {
                    name: e.name,
                    tenant: None,
                    priority: e.pri,
                    report: e.done.expect("checked above"),
                    pipeline: e
                        .pipeline
                        .expect("supervised entry always holds a pipeline"),
                });
            }
            g = self.sup.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Live restart/fault counters of a supervised pipeline (restarts
    /// performed, faults observed), or `None` if the hub does not
    /// supervise `name` (or it was already joined).
    pub fn supervised_counters(&self, name: &str) -> Option<(u32, u32)> {
        lock(&self.sup.state)
            .entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| (e.restarts, e.faults))
    }

    /// Enable the stall watchdog: a pipeline that is *runnable* (some
    /// task queued or executing — a fully parked pipeline waiting on an
    /// idle `appsrc` is not a stall) yet makes no scheduler progress
    /// (steps + wakeups frozen) for `stall_timeout` is killed with a
    /// typed [`Error::Stalled`]. Supervised pipelines are then subject
    /// to their [`RestartPolicy`]; unsupervised pipelines launched
    /// *after* this call are watched too and report the error at join.
    /// Best-effort by construction: a worker thread wedged *inside* an
    /// element step cannot be reclaimed — the error is delivered as soon
    /// as that step returns.
    pub fn set_watchdog(&self, stall_timeout: Duration) {
        {
            let mut g = lock(&self.sup.state);
            g.watchdog = Some(stall_timeout.max(SUPERVISOR_TICK));
        }
        self.ensure_supervisor();
        self.sup.cv.notify_all();
    }

    /// Spawn the supervisor thread if it is not already running.
    fn ensure_supervisor(&self) {
        {
            let mut g = lock(&self.sup.state);
            if g.thread_running {
                return;
            }
            g.thread_running = true;
        }
        let sup = self.sup.clone();
        let handle = thread::Builder::new()
            .name("nns-supervisor".into())
            .spawn(move || sup.run())
            .expect("spawn supervisor thread");
        *lock(&self.sup_thread) = Some(handle);
    }

    /// Reserve an invoke slot for `tenant` (SingleShot-style request
    /// admission). Returns an RAII [`InvokeTicket`] holding the slot
    /// until dropped, or a typed [`Error::AdmissionDenied`] when the
    /// tenant's `max_queued_invokes` slots are all outstanding — never a
    /// hang. Tenants without a quota are unlimited.
    pub fn try_admit_invoke(&self, tenant: &str) -> Result<InvokeTicket> {
        let (limit, slots) = {
            let mut tenants = lock(&self.tenants);
            // No quota installed: unlimited, but still slot-accounted so
            // usage is visible if a quota is installed later.
            let state = tenants
                .entry(tenant.to_string())
                .or_insert_with(|| TenantState::new(TenantQuota::default()));
            (state.quota.max_queued_invokes, state.invokes.clone())
        };
        // Reserve-then-check: tickets release via fetch_sub without the
        // hub lock, so admission must be a single atomic reservation.
        if slots.fetch_add(1, Ordering::AcqRel) >= limit && limit > 0 {
            slots.fetch_sub(1, Ordering::AcqRel);
            return Err(Error::AdmissionDenied {
                tenant: tenant.to_string(),
                resource: "queued invokes",
                limit,
            });
        }
        Ok(InvokeTicket { slots })
    }

    /// Subscribe to a topic on behalf of `tenant`, charging `capacity`
    /// buffers against its `max_topic_buffers` budget. The budget counts
    /// summed queue capacity of the tenant's *live* subscriptions
    /// (dropped handles are pruned at the next admission check). Denial
    /// is immediate and typed; tenants without a quota are unlimited.
    pub fn subscribe_as(
        &self,
        tenant: &str,
        topic: &str,
        capacity: usize,
        qos: Qos,
    ) -> Result<TopicSubscriber> {
        // Check and charge under one tenants-lock hold so concurrent
        // subscriptions can't both slip under the budget. The stream
        // registry's locks nest inside (leaf locks, never lock tenants).
        let s = {
            let mut tenants = lock(&self.tenants);
            let state = tenants
                .entry(tenant.to_string())
                .or_insert_with(|| TenantState::new(TenantQuota::default()));
            let limit = state.quota.max_topic_buffers;
            if limit > 0 {
                state.topic_caps.retain(|(_, c)| !c.is_dead());
                let used: usize = state.topic_caps.iter().map(|(cap, _)| cap).sum();
                if used + capacity > limit {
                    return Err(Error::AdmissionDenied {
                        tenant: tenant.to_string(),
                        resource: "topic buffers",
                        limit,
                    });
                }
            }
            let s = self.streams.subscribe_with(topic, capacity, qos);
            state.topic_caps.push((capacity, s.close_handle()));
            s
        };
        self.track_subscription(s.close_handle());
        Ok(s)
    }

    /// Number of launched (not yet joined) pipelines.
    pub fn len(&self) -> usize {
        lock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Names of the launched pipelines, in launch order.
    pub fn names(&self) -> Vec<String> {
        lock(&self.entries).iter().map(|e| e.name.clone()).collect()
    }

    /// How many launched pipelines are still executing.
    pub fn running_count(&self) -> usize {
        lock(&self.entries)
            .iter()
            .filter(|e| e.running.as_ref().is_some_and(|r| !r.is_done()))
            .count()
    }

    /// Control handle of a launched pipeline, by its hub name.
    pub fn controller(&self, pipeline: &str) -> Option<Controller> {
        lock(&self.entries)
            .iter()
            .find(|e| e.name == pipeline)
            .and_then(|e| e.running.as_ref().map(Running::controller))
    }

    /// Request a stop on every launched pipeline (live sources exit at
    /// their next frame boundary), and close every topic subscriber
    /// handle this hub issued — application drain loops over
    /// [`subscribe`](PipelineHub::subscribe) terminate even if the
    /// topic's publisher never reaches end-of-stream on its own.
    pub fn request_stop_all(&self) {
        for e in lock(&self.entries).iter() {
            if let Some(r) = &e.running {
                r.request_stop();
            }
        }
        // supervised pipelines: stop current runs and suppress further
        // restarts — a pending backoff restart is abandoned with a
        // terminal error instead of resurrecting a stopped pipeline
        {
            let mut g = lock(&self.sup.state);
            g.stopping = true;
            for e in g.entries.iter() {
                if let Some(r) = &e.running {
                    r.request_stop();
                }
            }
        }
        self.sup.cv.notify_all();
        for s in lock(&self.subs).drain(..) {
            s.close();
        }
    }

    /// Join every launched pipeline (in launch order) and drain the
    /// registry. Blocks the calling thread only — pool workers keep
    /// stepping the remaining pipelines while earlier ones are joined.
    pub fn join_all(&self) -> Vec<HubJoin> {
        let entries: Vec<HubEntry> = {
            let mut g = lock(&self.entries);
            g.drain(..).collect()
        };
        entries
            .into_iter()
            .map(|mut e| {
                let report = match e.running.take() {
                    Some(running) => running.wait().map(|(report, elements)| {
                        e.pipeline.finished = elements;
                        report
                    }),
                    None => Err(Error::Runtime(format!(
                        "pipeline {:?} was never started",
                        e.name
                    ))),
                };
                HubJoin {
                    name: e.name,
                    tenant: e.tenant,
                    priority: e.pri,
                    report,
                    pipeline: e.pipeline,
                }
            })
            .collect()
    }
}

impl Default for PipelineHub {
    fn default() -> Self {
        PipelineHub::new()
    }
}

impl Drop for PipelineHub {
    fn drop(&mut self) {
        // Wind down supervision first: stop supervised runs, suppress
        // restarts, and join the supervisor thread (it exits once every
        // supervised entry is terminal). Only then is it safe to decide
        // whether the dedicated pool still hosts live tasks.
        {
            let mut g = lock(&self.sup.state);
            g.stopping = true;
            g.shutdown = true;
            for e in g.entries.iter() {
                if let Some(r) = &e.running {
                    r.request_stop();
                }
            }
        }
        self.sup.cv.notify_all();
        if let Some(h) = lock(&self.sup_thread).take() {
            let _ = h.join();
        }
        // A dedicated pool is stopped as soon as nothing can still be
        // scheduled on it: every launched pipeline finished (joined or
        // not). Pipelines still executing keep their workers alive —
        // shutting down under them would strand parked tasks forever,
        // so that (discouraged) path intentionally leaks the pool.
        if self.dedicated && self.running_count() == 0 {
            self.exec.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_runs_many_pipelines_on_few_workers() {
        let hub = PipelineHub::with_workers(2);
        assert_eq!(hub.worker_count(), 2);
        for i in 0..8 {
            let p = Pipeline::parse(
                "videotestsrc num-buffers=4 pattern=gradient ! \
                 video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
                 tensor_converter ! fakesink name=out",
            )
            .unwrap();
            hub.launch(format!("p{i}"), p).unwrap();
        }
        assert_eq!(hub.len(), 8);
        assert_eq!(hub.names().len(), 8);
        let joined = hub.join_all();
        assert_eq!(joined.len(), 8);
        for j in joined {
            let report = j.report.expect("pipeline succeeded");
            assert_eq!(report.element("out").unwrap().buffers_in(), 4);
            assert_eq!(report.sched.workers, 2);
            assert!(report.sched.steps > 0, "scheduler counted steps");
        }
    }

    #[test]
    fn hub_rejects_duplicate_names() {
        let hub = PipelineHub::with_workers(1);
        let mk = || {
            Pipeline::parse("videotestsrc num-buffers=1 ! fakesink").unwrap()
        };
        hub.launch("same", mk()).unwrap();
        let err = hub.launch("same", mk()).unwrap_err().to_string();
        assert!(err.contains("already runs"), "{err}");
        hub.join_all();
    }

    #[test]
    fn admission_denies_over_quota_launch_then_recovers() {
        let hub = PipelineHub::with_workers(1);
        hub.set_quota(
            "acme",
            TenantQuota {
                max_live_pipelines: 1,
                ..Default::default()
            },
        );
        // appsrc with no producer: stays live (parked) until stopped
        let mk = || Pipeline::parse("appsrc name=in ! appsink name=out").unwrap();
        hub.launch_as("acme", "a1", mk()).unwrap();
        let err = hub.launch_as("acme", "a2", mk()).unwrap_err();
        match err {
            Error::AdmissionDenied {
                tenant,
                resource,
                limit,
            } => {
                assert_eq!(tenant, "acme");
                assert_eq!(resource, "live pipelines");
                assert_eq!(limit, 1);
            }
            other => panic!("expected AdmissionDenied, got {other}"),
        }
        // other tenants (and unquota'd launches) are unaffected
        hub.launch_as("beta", "b1", mk()).unwrap();
        hub.launch("plain", mk()).unwrap();
        hub.request_stop_all();
        for j in hub.join_all() {
            j.report.unwrap();
        }
    }

    #[test]
    fn invoke_tickets_enforce_and_release_slots() {
        let hub = PipelineHub::new();
        hub.set_quota(
            "t",
            TenantQuota {
                max_queued_invokes: 2,
                ..Default::default()
            },
        );
        let t1 = hub.try_admit_invoke("t").unwrap();
        let _t2 = hub.try_admit_invoke("t").unwrap();
        assert!(matches!(
            hub.try_admit_invoke("t"),
            Err(Error::AdmissionDenied {
                resource: "queued invokes",
                limit: 2,
                ..
            })
        ));
        drop(t1); // RAII release frees a slot
        hub.try_admit_invoke("t").unwrap();
        // unknown tenants are unlimited
        hub.try_admit_invoke("unmetered").unwrap();
    }

    #[test]
    fn topic_buffer_budget_counts_live_subscriptions() {
        use crate::pipeline::stream::Qos;
        let hub = PipelineHub::new();
        hub.set_quota(
            "t",
            TenantQuota {
                max_topic_buffers: 8,
                ..Default::default()
            },
        );
        let s1 = hub.subscribe_as("t", "adm/a", 6, Qos::Blocking).unwrap();
        assert!(matches!(
            hub.subscribe_as("t", "adm/b", 4, Qos::Leaky),
            Err(Error::AdmissionDenied {
                resource: "topic buffers",
                ..
            })
        ));
        let s2 = hub.subscribe_as("t", "adm/b", 2, Qos::Leaky).unwrap();
        drop(s1);
        drop(s2);
        // dropped handles are pruned: the full budget is available again
        hub.subscribe_as("t", "adm/c", 8, Qos::LatestOnly).unwrap();
    }

    #[test]
    fn supervised_restart_recovers_after_fault() {
        use crate::pipeline::fault::{FaultKind, FaultPlan};
        let hub = PipelineHub::with_workers(2);
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        hub.launch_supervised(
            "flaky",
            move || {
                let mut p = Pipeline::parse(
                    "videotestsrc num-buffers=4 ! \
                     video/x-raw,format=RGB,width=8,height=8,framerate=240 ! \
                     tensor_converter ! fakesink name=out",
                )?;
                if a.fetch_add(1, Ordering::SeqCst) == 0 {
                    // first attempt panics mid-stream; restarts run clean
                    p.set_fault_plan(FaultPlan::new().at(
                        "videotestsrc0",
                        1,
                        FaultKind::Panic,
                    ));
                }
                Ok(p)
            },
            RestartPolicy::OnFault {
                max_restarts: 3,
                backoff: Duration::from_millis(1),
            },
        )
        .unwrap();
        let j = hub.join_supervised("flaky").unwrap();
        let report = j.report.expect("restarted attempt completes");
        assert_eq!(report.restarts, 1, "one restart consumed");
        assert_eq!(report.faults, 1, "one fault observed");
        assert_eq!(report.element("out").unwrap().buffers_in(), 4);
        assert_eq!(attempts.load(Ordering::SeqCst), 2, "factory ran twice");
        // the terminal entry is gone from the hub
        assert!(hub.supervised_counters("flaky").is_none());
        assert!(hub.join_supervised("flaky").is_err());
    }

    #[test]
    fn supervision_quarantines_after_budget_exhausted() {
        use crate::pipeline::fault::{FaultKind, FaultPlan};
        let hub = PipelineHub::with_workers(1);
        hub.launch_supervised(
            "doomed",
            || {
                let mut p = Pipeline::parse("videotestsrc num-buffers=2 ! fakesink")?;
                p.set_fault_plan(FaultPlan::new().at(
                    "videotestsrc0",
                    0,
                    FaultKind::Error,
                ));
                Ok(p)
            },
            RestartPolicy::OnFault {
                max_restarts: 2,
                backoff: Duration::from_millis(1),
            },
        )
        .unwrap();
        let j = hub.join_supervised("doomed").unwrap();
        match j.report {
            Err(Error::Quarantined {
                pipeline,
                restarts,
                reason,
            }) => {
                assert_eq!(pipeline, "doomed");
                assert_eq!(restarts, 2, "budget fully consumed before quarantine");
                assert!(reason.contains("injected"), "{reason}");
            }
            Ok(_) => panic!("expected quarantine, pipeline completed"),
            Err(other) => panic!("expected Quarantined, got {other}"),
        }
    }

    #[test]
    fn supervision_never_policy_fault_is_terminal() {
        use crate::pipeline::fault::{FaultKind, FaultPlan};
        let hub = PipelineHub::with_workers(1);
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        hub.launch_supervised(
            "fragile",
            move || {
                a.fetch_add(1, Ordering::SeqCst);
                let mut p = Pipeline::parse("videotestsrc num-buffers=2 ! fakesink")?;
                p.set_fault_plan(FaultPlan::new().at(
                    "videotestsrc0",
                    0,
                    FaultKind::Panic,
                ));
                Ok(p)
            },
            RestartPolicy::Never,
        )
        .unwrap();
        let j = hub.join_supervised("fragile").unwrap();
        match j.report {
            Err(Error::Panicked { message, .. }) => {
                assert!(message.contains("injected"), "{message}")
            }
            Ok(_) => panic!("expected terminal fault, pipeline completed"),
            Err(other) => panic!("expected Panicked, got {other}"),
        }
        assert_eq!(attempts.load(Ordering::SeqCst), 1, "never restarted");
    }

    #[test]
    fn watchdog_kills_stalled_pipeline() {
        use crate::pipeline::fault::{FaultKind, FaultPlan};
        let hub = PipelineHub::with_workers(2);
        hub.set_watchdog(Duration::from_millis(40));
        let mut p = Pipeline::parse("videotestsrc num-buffers=64 ! fakesink").unwrap();
        // the source wedges inside one step for far longer than the
        // stall timeout — runnable, yet no progress
        p.set_fault_plan(FaultPlan::new().at(
            "videotestsrc0",
            2,
            FaultKind::StallMs(400),
        ));
        hub.launch("wedged", p).unwrap();
        let mut joined = hub.join_all();
        assert_eq!(joined.len(), 1);
        match joined.remove(0).report {
            Err(Error::Stalled {
                pipeline,
                stalled_for,
            }) => {
                assert_eq!(pipeline, "wedged");
                assert!(stalled_for >= Duration::from_millis(40));
            }
            Ok(_) => panic!("expected stall kill, pipeline completed"),
            Err(other) => panic!("expected Stalled, got {other}"),
        }
    }

    #[test]
    fn watchdog_ignores_fully_parked_pipeline() {
        let hub = PipelineHub::with_workers(1);
        hub.set_watchdog(Duration::from_millis(20));
        // an appsrc nobody pushes into: every task parks — idle, not
        // stalled — so the watchdog must not fire
        let p = Pipeline::parse("appsrc name=in ! appsink name=out").unwrap();
        hub.launch("idle", p).unwrap();
        thread::sleep(Duration::from_millis(120));
        assert_eq!(hub.running_count(), 1, "idle pipeline still alive");
        hub.request_stop_all();
        for j in hub.join_all() {
            j.report.unwrap();
        }
    }

    #[test]
    fn serve_registry_binds_and_registers_tcp_transport() {
        let hub = PipelineHub::new();
        let addr = hub.serve_registry("127.0.0.1:0").unwrap();
        let port: u16 = addr.rsplit(':').next().unwrap().parse().unwrap();
        assert_ne!(port, 0, "a real port was bound");
        // `transport=tcp` now resolves for query elements on this hub
        assert!(crate::pipeline::stream::transport("tcp").is_ok());
        // a consumer-side hub joins by address
        let t = hub.connect_registry(&addr);
        assert_eq!(t.config().registry, addr);
    }

    #[test]
    fn hub_priorities_all_complete() {
        let hub = PipelineHub::with_workers(1);
        for (i, pri) in [Priority::High, Priority::Normal, Priority::Low]
            .into_iter()
            .enumerate()
        {
            let p = Pipeline::parse(
                "videotestsrc num-buffers=3 ! \
                 video/x-raw,format=RGB,width=8,height=8,framerate=240 ! \
                 tensor_converter ! fakesink name=out",
            )
            .unwrap();
            hub.launch_with_priority(format!("p{i}"), p, pri).unwrap();
        }
        for j in hub.join_all() {
            assert_eq!(
                j.report.unwrap().element("out").unwrap().buffers_in(),
                3,
                "pipeline {} at {:?} completed",
                j.name,
                j.priority
            );
        }
    }
}
