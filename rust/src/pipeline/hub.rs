//! Multi-tenant pipeline registry over one bounded worker pool.
//!
//! The among-device-AI follow-up paper (arXiv:2201.06026) has devices
//! hosting *many* pipelines at once. A [`PipelineHub`] launches,
//! enumerates, steers and joins any number of concurrent pipelines over
//! a single [`Executor`] — so 64 pipelines of 10 elements run on, say, 4
//! worker threads instead of the 640 the seed scheduler would have
//! spawned. Per-pipeline [`Priority`] lanes let latency-sensitive
//! pipelines (a camera feed) outrank background ones (a model warmup)
//! without starving either, and the worker count is hard-capped at
//! [`MAX_WORKERS`](crate::pipeline::executor::MAX_WORKERS) regardless of
//! configuration.
//!
//! ```no_run
//! use nnstreamer::pipeline::{Pipeline, PipelineHub};
//!
//! # fn main() -> nnstreamer::Result<()> {
//! let hub = PipelineHub::with_workers(4);
//! for i in 0..64 {
//!     let p = Pipeline::parse(
//!         "videotestsrc num-buffers=32 ! tensor_converter ! fakesink",
//!     )?;
//!     hub.launch(format!("cam-{i}"), p)?;
//! }
//! for joined in hub.join_all() {
//!     let report = joined.report?;
//!     println!("{}: {:.1} s", joined.name, report.wall.as_secs_f64());
//! }
//! # Ok(())
//! # }
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::metrics::stats::PipelineReport;
use crate::pipeline::executor::{lock, Executor, Priority};
use crate::pipeline::scheduler::{self, Controller, Running};
use crate::pipeline::stream::{
    Qos, QueryClient, StreamRegistry, SubscriberClose, TopicPublisher, TopicSubscriber,
};
use crate::pipeline::Pipeline;

struct HubEntry {
    name: String,
    /// Tenant this pipeline was admitted under (None: unquota'd
    /// [`launch`](PipelineHub::launch)).
    tenant: Option<String>,
    pri: Priority,
    pipeline: Pipeline,
    running: Option<Running>,
}

/// Per-tenant admission quotas (each dimension: 0 = unlimited).
///
/// Set with [`PipelineHub::set_quota`]; enforced by
/// [`launch_as`](PipelineHub::launch_as),
/// [`try_admit_invoke`](PipelineHub::try_admit_invoke) and
/// [`subscribe_as`](PipelineHub::subscribe_as). A denied tenant always
/// gets a typed [`Error::AdmissionDenied`] immediately — admission never
/// blocks or hangs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantQuota {
    /// Max pipelines of this tenant live (launched and still executing)
    /// on the hub at once.
    pub max_live_pipelines: usize,
    /// Max concurrently outstanding [`InvokeTicket`]s (one per queued
    /// or in-flight SingleShot-style invoke).
    pub max_queued_invokes: usize,
    /// Max summed queue capacity of this tenant's live topic
    /// subscriptions (its topic-buffer budget).
    pub max_topic_buffers: usize,
}

struct TenantState {
    quota: TenantQuota,
    /// Outstanding invoke tickets (shared with [`InvokeTicket`] drops,
    /// which decrement without taking the hub lock).
    invokes: Arc<AtomicUsize>,
    /// (queue capacity, weak closer) of every subscription admitted for
    /// this tenant; dead closers are pruned at the next admission check.
    topic_caps: Vec<(usize, SubscriberClose)>,
}

impl TenantState {
    fn new(quota: TenantQuota) -> Self {
        TenantState {
            quota,
            invokes: Arc::new(AtomicUsize::new(0)),
            topic_caps: Vec::new(),
        }
    }
}

/// RAII admission slot for one queued invoke. Hold it for the lifetime
/// of the request (queue wait + execution); dropping it releases the
/// slot. Obtained from [`PipelineHub::try_admit_invoke`].
pub struct InvokeTicket {
    slots: Arc<AtomicUsize>,
}

impl Drop for InvokeTicket {
    fn drop(&mut self) {
        self.slots.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Result of joining one hub pipeline: its report (or failure) plus the
/// [`Pipeline`] itself, whose finished elements (collecting sinks, app
/// handles) remain inspectable via
/// [`Pipeline::finished_element`].
pub struct HubJoin {
    pub name: String,
    /// Tenant the pipeline was admitted under (None for unquota'd
    /// launches) — lets multi-tenant callers route each report back to
    /// its owner.
    pub tenant: Option<String>,
    pub priority: Priority,
    pub report: Result<PipelineReport>,
    pub pipeline: Pipeline,
}

/// Registry of concurrently running pipelines sharing one bounded
/// executor (see the module docs for an example).
pub struct PipelineHub {
    exec: Executor,
    /// True when this hub spawned its own pool (shut down on drop once
    /// no launched pipeline is still executing); false when it shares
    /// [`Executor::global`].
    dedicated: bool,
    entries: Mutex<Vec<HubEntry>>,
    /// Stream-endpoint registry this hub resolves topics in (the
    /// process-global one, so pipelines compose across hubs).
    streams: StreamRegistry,
    /// Weak closers of every subscriber handle this hub issued:
    /// [`request_stop_all`](PipelineHub::request_stop_all) closes them so
    /// application drain loops over [`subscribe`](PipelineHub::subscribe)
    /// terminate.
    subs: Mutex<Vec<SubscriberClose>>,
    /// Admission state per tenant (quota + live usage). Tenants without
    /// an entry are unlimited; plain [`launch`](PipelineHub::launch) /
    /// [`subscribe`](PipelineHub::subscribe) bypass admission entirely.
    tenants: Mutex<HashMap<String, TenantState>>,
}

impl PipelineHub {
    fn over(exec: Executor, dedicated: bool) -> PipelineHub {
        PipelineHub {
            exec,
            dedicated,
            entries: Mutex::new(Vec::new()),
            streams: StreamRegistry::global().clone(),
            subs: Mutex::new(Vec::new()),
            tenants: Mutex::new(HashMap::new()),
        }
    }

    /// A hub over the process-global executor (shared with
    /// `Pipeline::play` traffic).
    pub fn new() -> PipelineHub {
        PipelineHub::over(Executor::global().clone(), false)
    }

    /// A hub with its own dedicated pool of `workers` threads (clamped
    /// to the hard cap). The pool is shut down when the hub is dropped
    /// and no launched pipeline is still executing (joined or not).
    pub fn with_workers(workers: usize) -> PipelineHub {
        PipelineHub::over(Executor::new(workers), true)
    }

    /// A hub over a caller-managed executor.
    pub fn on(exec: &Executor) -> PipelineHub {
        PipelineHub::over(exec.clone(), false)
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    /// The stream-endpoint registry this hub resolves topics in.
    pub fn streams(&self) -> &StreamRegistry {
        &self.streams
    }

    /// Publish a named topic from application code: the returned handle
    /// pushes buffers to every subscriber — `tensor_query_serversrc`
    /// elements of launched pipelines, or other application
    /// [`subscribe`](PipelineHub::subscribe) handles. The app-side
    /// counterpart of ending a pipeline in `tensor_query_serversink`.
    pub fn publish(&self, topic: &str) -> TopicPublisher {
        self.streams.publish(topic)
    }

    /// Subscribe a named topic from application code. The handle's
    /// `recv` loop terminates at topic end-of-stream **and** when
    /// [`request_stop_all`](PipelineHub::request_stop_all) runs — the
    /// hub closes every subscriber handle it issued.
    pub fn subscribe(&self, topic: &str) -> TopicSubscriber {
        let s = self.streams.subscribe(topic);
        self.track_subscription(s.close_handle());
        s
    }

    /// [`subscribe`](PipelineHub::subscribe) with an explicit queue
    /// bound (small bounds apply backpressure to publishers sooner).
    pub fn subscribe_with_capacity(&self, topic: &str, capacity: usize) -> TopicSubscriber {
        let s = self.streams.subscribe_with_capacity(topic, capacity);
        self.track_subscription(s.close_handle());
        s
    }

    /// [`subscribe`](PipelineHub::subscribe) with an explicit delivery
    /// [`Qos`]: a `Leaky` or `LatestOnly` subscriber never gates
    /// publishers — when its queue is full the arriving (leaky) or
    /// oldest (latest-only) buffer is dropped and counted in the
    /// topic's drop breakdown instead.
    pub fn subscribe_with_qos(&self, topic: &str, qos: Qos) -> TopicSubscriber {
        let s = self.streams.subscribe_with_qos(topic, qos);
        self.track_subscription(s.close_handle());
        s
    }

    /// Remember a closer for `request_stop_all`, pruning closers whose
    /// handles were already dropped so long-lived hubs serving many
    /// short-lived subscriptions don't accumulate dead entries.
    fn track_subscription(&self, closer: SubscriberClose) {
        let mut subs = lock(&self.subs);
        subs.retain(|s| !s.is_dead());
        subs.push(closer);
    }

    /// A request/response handle over a serving pipeline's topic pair
    /// (see [`QueryClient`]).
    pub fn query_client(&self, request: &str, reply: &str) -> QueryClient {
        self.streams.query_client(request, reply)
    }

    pub fn worker_count(&self) -> usize {
        self.exec.worker_count()
    }

    /// Launch a pipeline at [`Priority::Normal`]; returns its control
    /// handle. Pipeline names must be unique within the hub.
    pub fn launch(&self, name: impl Into<String>, pipeline: Pipeline) -> Result<Controller> {
        self.launch_with_priority(name, pipeline, Priority::Normal)
    }

    /// Launch a pipeline with an explicit scheduling priority.
    pub fn launch_with_priority(
        &self,
        name: impl Into<String>,
        pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        self.launch_inner(None, name.into(), pipeline, pri)
    }

    /// Install (or replace) a tenant's admission quota. Existing usage
    /// is kept — a lowered quota only affects future admissions.
    pub fn set_quota(&self, tenant: impl Into<String>, quota: TenantQuota) {
        let mut tenants = lock(&self.tenants);
        tenants
            .entry(tenant.into())
            .and_modify(|t| t.quota = quota)
            .or_insert_with(|| TenantState::new(quota));
    }

    /// A tenant's installed quota, if any.
    pub fn quota(&self, tenant: &str) -> Option<TenantQuota> {
        lock(&self.tenants).get(tenant).map(|t| t.quota)
    }

    /// Launch a pipeline on behalf of `tenant` at [`Priority::Normal`],
    /// subject to its `max_live_pipelines` quota. Denial is immediate
    /// and typed ([`Error::AdmissionDenied`]); tenants without a quota
    /// are unlimited.
    pub fn launch_as(
        &self,
        tenant: impl Into<String>,
        name: impl Into<String>,
        pipeline: Pipeline,
    ) -> Result<Controller> {
        self.launch_as_with_priority(tenant, name, pipeline, Priority::Normal)
    }

    /// [`launch_as`](PipelineHub::launch_as) with an explicit priority.
    pub fn launch_as_with_priority(
        &self,
        tenant: impl Into<String>,
        name: impl Into<String>,
        pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        self.launch_inner(Some(tenant.into()), name.into(), pipeline, pri)
    }

    fn launch_inner(
        &self,
        tenant: Option<String>,
        name: String,
        mut pipeline: Pipeline,
        pri: Priority,
    ) -> Result<Controller> {
        // Quota lookup before the entries lock (tenants and entries are
        // never held together; each is a leaf lock).
        let live_limit = tenant
            .as_deref()
            .and_then(|t| lock(&self.tenants).get(t).map(|s| s.quota.max_live_pipelines))
            .unwrap_or(0);
        let mut entries = lock(&self.entries);
        if entries.iter().any(|e| e.name == name) {
            return Err(Error::Runtime(format!(
                "hub already runs a pipeline named {name:?}"
            )));
        }
        // Admission: count this tenant's *live* pipelines (launched and
        // still executing) under the entries lock, so concurrent
        // launches can't both slip under the limit.
        if live_limit > 0 {
            let t = tenant.as_deref().unwrap();
            let live = entries
                .iter()
                .filter(|e| e.tenant.as_deref() == Some(t))
                .filter(|e| e.running.as_ref().is_some_and(|r| !r.is_done()))
                .count();
            if live >= live_limit {
                return Err(Error::AdmissionDenied {
                    tenant: t.to_string(),
                    resource: "live pipelines",
                    limit: live_limit,
                });
            }
        }
        let running = scheduler::start_on(&self.exec, &mut pipeline.graph, pri)?;
        let controller = running.controller();
        entries.push(HubEntry {
            name,
            tenant,
            pri,
            pipeline,
            running: Some(running),
        });
        Ok(controller)
    }

    /// Reserve an invoke slot for `tenant` (SingleShot-style request
    /// admission). Returns an RAII [`InvokeTicket`] holding the slot
    /// until dropped, or a typed [`Error::AdmissionDenied`] when the
    /// tenant's `max_queued_invokes` slots are all outstanding — never a
    /// hang. Tenants without a quota are unlimited.
    pub fn try_admit_invoke(&self, tenant: &str) -> Result<InvokeTicket> {
        let (limit, slots) = {
            let mut tenants = lock(&self.tenants);
            // No quota installed: unlimited, but still slot-accounted so
            // usage is visible if a quota is installed later.
            let state = tenants
                .entry(tenant.to_string())
                .or_insert_with(|| TenantState::new(TenantQuota::default()));
            (state.quota.max_queued_invokes, state.invokes.clone())
        };
        // Reserve-then-check: tickets release via fetch_sub without the
        // hub lock, so admission must be a single atomic reservation.
        if slots.fetch_add(1, Ordering::AcqRel) >= limit && limit > 0 {
            slots.fetch_sub(1, Ordering::AcqRel);
            return Err(Error::AdmissionDenied {
                tenant: tenant.to_string(),
                resource: "queued invokes",
                limit,
            });
        }
        Ok(InvokeTicket { slots })
    }

    /// Subscribe to a topic on behalf of `tenant`, charging `capacity`
    /// buffers against its `max_topic_buffers` budget. The budget counts
    /// summed queue capacity of the tenant's *live* subscriptions
    /// (dropped handles are pruned at the next admission check). Denial
    /// is immediate and typed; tenants without a quota are unlimited.
    pub fn subscribe_as(
        &self,
        tenant: &str,
        topic: &str,
        capacity: usize,
        qos: Qos,
    ) -> Result<TopicSubscriber> {
        // Check and charge under one tenants-lock hold so concurrent
        // subscriptions can't both slip under the budget. The stream
        // registry's locks nest inside (leaf locks, never lock tenants).
        let s = {
            let mut tenants = lock(&self.tenants);
            let state = tenants
                .entry(tenant.to_string())
                .or_insert_with(|| TenantState::new(TenantQuota::default()));
            let limit = state.quota.max_topic_buffers;
            if limit > 0 {
                state.topic_caps.retain(|(_, c)| !c.is_dead());
                let used: usize = state.topic_caps.iter().map(|(cap, _)| cap).sum();
                if used + capacity > limit {
                    return Err(Error::AdmissionDenied {
                        tenant: tenant.to_string(),
                        resource: "topic buffers",
                        limit,
                    });
                }
            }
            let s = self.streams.subscribe_with(topic, capacity, qos);
            state.topic_caps.push((capacity, s.close_handle()));
            s
        };
        self.track_subscription(s.close_handle());
        Ok(s)
    }

    /// Number of launched (not yet joined) pipelines.
    pub fn len(&self) -> usize {
        lock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Names of the launched pipelines, in launch order.
    pub fn names(&self) -> Vec<String> {
        lock(&self.entries).iter().map(|e| e.name.clone()).collect()
    }

    /// How many launched pipelines are still executing.
    pub fn running_count(&self) -> usize {
        lock(&self.entries)
            .iter()
            .filter(|e| e.running.as_ref().is_some_and(|r| !r.is_done()))
            .count()
    }

    /// Control handle of a launched pipeline, by its hub name.
    pub fn controller(&self, pipeline: &str) -> Option<Controller> {
        lock(&self.entries)
            .iter()
            .find(|e| e.name == pipeline)
            .and_then(|e| e.running.as_ref().map(Running::controller))
    }

    /// Request a stop on every launched pipeline (live sources exit at
    /// their next frame boundary), and close every topic subscriber
    /// handle this hub issued — application drain loops over
    /// [`subscribe`](PipelineHub::subscribe) terminate even if the
    /// topic's publisher never reaches end-of-stream on its own.
    pub fn request_stop_all(&self) {
        for e in lock(&self.entries).iter() {
            if let Some(r) = &e.running {
                r.request_stop();
            }
        }
        for s in lock(&self.subs).drain(..) {
            s.close();
        }
    }

    /// Join every launched pipeline (in launch order) and drain the
    /// registry. Blocks the calling thread only — pool workers keep
    /// stepping the remaining pipelines while earlier ones are joined.
    pub fn join_all(&self) -> Vec<HubJoin> {
        let entries: Vec<HubEntry> = {
            let mut g = lock(&self.entries);
            g.drain(..).collect()
        };
        entries
            .into_iter()
            .map(|mut e| {
                let report = match e.running.take() {
                    Some(running) => running.wait().map(|(report, elements)| {
                        e.pipeline.finished = elements;
                        report
                    }),
                    None => Err(Error::Runtime(format!(
                        "pipeline {:?} was never started",
                        e.name
                    ))),
                };
                HubJoin {
                    name: e.name,
                    tenant: e.tenant,
                    priority: e.pri,
                    report,
                    pipeline: e.pipeline,
                }
            })
            .collect()
    }
}

impl Default for PipelineHub {
    fn default() -> Self {
        PipelineHub::new()
    }
}

impl Drop for PipelineHub {
    fn drop(&mut self) {
        // A dedicated pool is stopped as soon as nothing can still be
        // scheduled on it: every launched pipeline finished (joined or
        // not). Pipelines still executing keep their workers alive —
        // shutting down under them would strand parked tasks forever,
        // so that (discouraged) path intentionally leaks the pool.
        if self.dedicated && self.running_count() == 0 {
            self.exec.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_runs_many_pipelines_on_few_workers() {
        let hub = PipelineHub::with_workers(2);
        assert_eq!(hub.worker_count(), 2);
        for i in 0..8 {
            let p = Pipeline::parse(
                "videotestsrc num-buffers=4 pattern=gradient ! \
                 video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
                 tensor_converter ! fakesink name=out",
            )
            .unwrap();
            hub.launch(format!("p{i}"), p).unwrap();
        }
        assert_eq!(hub.len(), 8);
        assert_eq!(hub.names().len(), 8);
        let joined = hub.join_all();
        assert_eq!(joined.len(), 8);
        for j in joined {
            let report = j.report.expect("pipeline succeeded");
            assert_eq!(report.element("out").unwrap().buffers_in(), 4);
            assert_eq!(report.sched.workers, 2);
            assert!(report.sched.steps > 0, "scheduler counted steps");
        }
    }

    #[test]
    fn hub_rejects_duplicate_names() {
        let hub = PipelineHub::with_workers(1);
        let mk = || {
            Pipeline::parse("videotestsrc num-buffers=1 ! fakesink").unwrap()
        };
        hub.launch("same", mk()).unwrap();
        let err = hub.launch("same", mk()).unwrap_err().to_string();
        assert!(err.contains("already runs"), "{err}");
        hub.join_all();
    }

    #[test]
    fn admission_denies_over_quota_launch_then_recovers() {
        let hub = PipelineHub::with_workers(1);
        hub.set_quota(
            "acme",
            TenantQuota {
                max_live_pipelines: 1,
                ..Default::default()
            },
        );
        // appsrc with no producer: stays live (parked) until stopped
        let mk = || Pipeline::parse("appsrc name=in ! appsink name=out").unwrap();
        hub.launch_as("acme", "a1", mk()).unwrap();
        let err = hub.launch_as("acme", "a2", mk()).unwrap_err();
        match err {
            Error::AdmissionDenied {
                tenant,
                resource,
                limit,
            } => {
                assert_eq!(tenant, "acme");
                assert_eq!(resource, "live pipelines");
                assert_eq!(limit, 1);
            }
            other => panic!("expected AdmissionDenied, got {other}"),
        }
        // other tenants (and unquota'd launches) are unaffected
        hub.launch_as("beta", "b1", mk()).unwrap();
        hub.launch("plain", mk()).unwrap();
        hub.request_stop_all();
        for j in hub.join_all() {
            j.report.unwrap();
        }
    }

    #[test]
    fn invoke_tickets_enforce_and_release_slots() {
        let hub = PipelineHub::new();
        hub.set_quota(
            "t",
            TenantQuota {
                max_queued_invokes: 2,
                ..Default::default()
            },
        );
        let t1 = hub.try_admit_invoke("t").unwrap();
        let _t2 = hub.try_admit_invoke("t").unwrap();
        assert!(matches!(
            hub.try_admit_invoke("t"),
            Err(Error::AdmissionDenied {
                resource: "queued invokes",
                limit: 2,
                ..
            })
        ));
        drop(t1); // RAII release frees a slot
        hub.try_admit_invoke("t").unwrap();
        // unknown tenants are unlimited
        hub.try_admit_invoke("unmetered").unwrap();
    }

    #[test]
    fn topic_buffer_budget_counts_live_subscriptions() {
        use crate::pipeline::stream::Qos;
        let hub = PipelineHub::new();
        hub.set_quota(
            "t",
            TenantQuota {
                max_topic_buffers: 8,
                ..Default::default()
            },
        );
        let s1 = hub.subscribe_as("t", "adm/a", 6, Qos::Blocking).unwrap();
        assert!(matches!(
            hub.subscribe_as("t", "adm/b", 4, Qos::Leaky),
            Err(Error::AdmissionDenied {
                resource: "topic buffers",
                ..
            })
        ));
        let s2 = hub.subscribe_as("t", "adm/b", 2, Qos::Leaky).unwrap();
        drop(s1);
        drop(s2);
        // dropped handles are pruned: the full budget is available again
        hub.subscribe_as("t", "adm/c", 8, Qos::LatestOnly).unwrap();
    }

    #[test]
    fn hub_priorities_all_complete() {
        let hub = PipelineHub::with_workers(1);
        for (i, pri) in [Priority::High, Priority::Normal, Priority::Low]
            .into_iter()
            .enumerate()
        {
            let p = Pipeline::parse(
                "videotestsrc num-buffers=3 ! \
                 video/x-raw,format=RGB,width=8,height=8,framerate=240 ! \
                 tensor_converter ! fakesink name=out",
            )
            .unwrap();
            hub.launch_with_priority(format!("p{i}"), p, pri).unwrap();
        }
        for j in hub.join_all() {
            assert_eq!(
                j.report.unwrap().element("out").unwrap().buffers_in(),
                3,
                "pipeline {} at {:?} completed",
                j.name,
                j.priority
            );
        }
    }
}
