//! Fluent, typed pipeline construction — the programmatic counterpart of
//! the launch-string front door.
//!
//! [`PipelineBuilder`] keeps a *cursor* on the element most recently
//! chained, mirroring how a gst-launch line reads: [`chain`] adds an
//! element (typed props, no strings) and links it after the cursor,
//! [`from`] moves the cursor to a named element (the `name. !` branch
//! idiom), and [`to`] terminates a chain into an existing element (the
//! `! name.` idiom, used to wire mux/merge inputs). Chaining a sink
//! clears the cursor, exactly like the end of a gst-launch chain.
//!
//! [`chain`]: PipelineBuilder::chain
//! [`from`]: PipelineBuilder::from
//! [`to`]: PipelineBuilder::to

use crate::element::{PadSpec, Props};
use crate::error::{Error, Result};
use crate::pipeline::graph::{Graph, NodeId};
use crate::pipeline::Pipeline;
use crate::tensor::Caps;

/// Builds a [`Pipeline`] from typed element props.
///
/// ```
/// use nnstreamer::elements::converter::TensorConverterProps;
/// use nnstreamer::elements::sinks::TensorSinkProps;
/// use nnstreamer::elements::sources::VideoTestSrcProps;
/// use nnstreamer::elements::transform::TensorTransformProps;
/// use nnstreamer::pipeline::PipelineBuilder;
///
/// # fn main() -> nnstreamer::Result<()> {
/// let mut b = PipelineBuilder::new();
/// b.chain(VideoTestSrcProps {
///     num_buffers: Some(4),
///     width: 16,
///     height: 16,
///     framerate: 600.0,
///     ..Default::default()
/// })?
///     .chain(TensorConverterProps)?
///     .chain(TensorTransformProps::normalize())?
///     .chain_named("out", TensorSinkProps::default())?;
///
/// let mut pipeline = b.build();
/// let report = pipeline.run()?;
/// assert_eq!(report.element("out").unwrap().buffers_in(), 4);
/// # Ok(())
/// # }
/// ```
#[derive(Default)]
pub struct PipelineBuilder {
    graph: Graph,
    cursor: Option<NodeId>,
}

impl PipelineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Node id of a named element (for mixed typed/Graph-level work).
    pub fn node(&self, name: &str) -> Option<NodeId> {
        self.graph.by_name(name)
    }

    fn attach(&mut self, id: NodeId) -> Result<&mut Self> {
        if let Some(cur) = self.cursor {
            self.graph.link(cur, id)?;
        }
        let is_sink = matches!(self.graph.node(id).element.src_pads(), PadSpec::Fixed(0));
        self.cursor = if is_sink { None } else { Some(id) };
        Ok(self)
    }

    /// Add an element from typed props (auto-named `factory{N}`), linked
    /// after the cursor; the new element becomes the cursor.
    pub fn chain<P: Props>(&mut self, props: P) -> Result<&mut Self> {
        let id = self.graph.add_props(props)?;
        self.attach(id)
    }

    /// Like [`chain`](PipelineBuilder::chain) with an explicit element
    /// name (referenced later by [`from`](PipelineBuilder::from) /
    /// [`to`](PipelineBuilder::to), by live control on [`Running`], and
    /// in reports).
    ///
    /// [`Running`]: crate::pipeline::Running
    pub fn chain_named<P: Props>(
        &mut self,
        name: impl Into<String>,
        props: P,
    ) -> Result<&mut Self> {
        let element = props.into_element()?;
        let id = self.graph.add_element(name, element)?;
        self.attach(id)
    }

    /// Insert a capsfilter restricting the current link
    /// (`! video/x-raw,... !` in launch syntax).
    pub fn caps(&mut self, caps: Caps) -> Result<&mut Self> {
        self.chain(crate::elements::flow::CapsFilterProps { caps })
    }

    /// Add a named element **without** linking it (cursor unchanged) —
    /// for merge/mux-style elements whose inputs are wired afterwards
    /// with [`to`](PipelineBuilder::to) in pad order.
    pub fn add_named<P: Props>(
        &mut self,
        name: impl Into<String>,
        props: P,
    ) -> Result<&mut Self> {
        let element = props.into_element()?;
        self.graph.add_element(name, element)?;
        Ok(self)
    }

    /// Move the cursor to a named element — start a branch from it
    /// (`name. ! ...`).
    pub fn from(&mut self, name: &str) -> Result<&mut Self> {
        let id = self
            .graph
            .by_name(name)
            .ok_or_else(|| Error::Graph(format!("no element named {name:?} to branch from")))?;
        self.cursor = Some(id);
        Ok(self)
    }

    /// Link the cursor into a named element and end the chain
    /// (`... ! name.`) — how additional mux/merge inputs are wired.
    pub fn to(&mut self, name: &str) -> Result<&mut Self> {
        let src = self
            .cursor
            .ok_or_else(|| Error::Graph("to() without a current chain".into()))?;
        let dst = self
            .graph
            .by_name(name)
            .ok_or_else(|| Error::Graph(format!("no element named {name:?} to link into")))?;
        self.graph.link(src, dst)?;
        self.cursor = None;
        Ok(self)
    }

    /// Explicit link between two named elements (next free pads).
    pub fn link(&mut self, src: &str, dst: &str) -> Result<&mut Self> {
        let s = self
            .graph
            .by_name(src)
            .ok_or_else(|| Error::Graph(format!("no element named {src:?}")))?;
        let d = self
            .graph
            .by_name(dst)
            .ok_or_else(|| Error::Graph(format!("no element named {dst:?}")))?;
        self.graph.link(s, d)?;
        Ok(self)
    }

    /// Finish, returning the raw [`Graph`] (apps that post-process the
    /// graph before running).
    pub fn into_graph(self) -> Graph {
        self.graph
    }

    /// Finish into a [`Pipeline`].
    pub fn build(self) -> Pipeline {
        Pipeline::new(self.graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elements::flow::{QueueProps, TeeProps};
    use crate::elements::sinks::FakeSinkProps;
    use crate::elements::sources::VideoTestSrcProps;

    #[test]
    fn linear_chain_links_in_order() {
        let mut b = PipelineBuilder::new();
        b.chain(VideoTestSrcProps {
            num_buffers: Some(2),
            ..Default::default()
        })
        .unwrap()
        .chain(QueueProps::default())
        .unwrap()
        .chain_named("out", FakeSinkProps::default())
        .unwrap();
        let g = b.into_graph();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.links.len(), 2);
        assert!(g.by_name("out").is_some());
    }

    #[test]
    fn sink_clears_cursor_and_branching_works() {
        let mut b = PipelineBuilder::new();
        b.chain(VideoTestSrcProps {
            num_buffers: Some(2),
            width: 8,
            height: 8,
            framerate: 600.0,
            ..Default::default()
        })
        .unwrap()
        .chain_named("t", TeeProps)
        .unwrap()
        .chain_named("s1", FakeSinkProps::default())
        .unwrap();
        // cursor cleared by the sink: chaining again without from() is an
        // orphan chain, so branch explicitly
        b.from("t")
            .unwrap()
            .chain_named("s2", FakeSinkProps::default())
            .unwrap();
        let mut p = b.build();
        let report = p.run().unwrap();
        assert_eq!(report.element("s1").unwrap().buffers_in(), 2);
        assert_eq!(report.element("s2").unwrap().buffers_in(), 2);
    }

    #[test]
    fn from_unknown_name_errors() {
        let mut b = PipelineBuilder::new();
        assert!(b.from("nope").is_err());
        assert!(b.to("nope").is_err());
    }
}
