//! Pipeline construction and execution.
//!
//! [`Pipeline`] is the user-facing entry point: build from a launch string
//! ([`Pipeline::parse`]) or programmatically via [`Graph`], then [`run`]
//! to completion or [`play`] for live interaction.
//!
//! [`run`]: Pipeline::run
//! [`play`]: Pipeline::play

pub mod graph;
pub mod parser;
pub mod scheduler;

pub use graph::{Graph, Link, Node, NodeId};
pub use scheduler::Running;

use crate::element::Element;
use crate::error::Result;
use crate::metrics::stats::PipelineReport;

pub struct Pipeline {
    pub graph: Graph,
    /// Elements recovered after a completed run, keyed by node name.
    finished: Vec<(String, Box<dyn Element>)>,
}

impl Pipeline {
    pub fn new(graph: Graph) -> Self {
        Self {
            graph,
            finished: Vec::new(),
        }
    }

    /// Parse a gst-launch-style description (see [`parser`]).
    ///
    /// ```
    /// use nnstreamer::pipeline::Pipeline;
    ///
    /// # fn main() -> nnstreamer::Result<()> {
    /// let p = Pipeline::parse(
    ///     "videotestsrc num-buffers=4 ! tensor_converter ! fakesink",
    /// )?;
    /// assert_eq!(p.graph.nodes.len(), 3);
    /// # Ok(())
    /// # }
    /// ```
    pub fn parse(desc: &str) -> Result<Self> {
        Ok(Self::new(parser::parse(desc)?))
    }

    /// Start all element threads; returns a handle for live control.
    pub fn play(&mut self) -> Result<Running> {
        scheduler::start(&mut self.graph)
    }

    /// Run to completion (EOS on all sinks) and return the report.
    pub fn run(&mut self) -> Result<PipelineReport> {
        let running = self.play()?;
        let (report, elements) = running.wait()?;
        self.finished = elements;
        Ok(report)
    }

    /// Access an element after [`run`] completed (for sinks that collected
    /// results). Returns `None` while the pipeline has not finished.
    ///
    /// [`run`]: Pipeline::run
    pub fn finished_element(&mut self, name: &str) -> Option<&mut Box<dyn Element>> {
        self.finished
            .iter_mut()
            .find(|(n, _)| n == name)
            .map(|(_, e)| e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_smoke() {
        let mut p = Pipeline::parse(
            "videotestsrc num-buffers=6 pattern=gradient ! \
             video/x-raw,format=RGB,width=32,height=32,framerate=120 ! \
             tensor_converter ! tensor_transform mode=typecast option=float32 ! \
             fakesink",
        )
        .unwrap();
        let report = p.run().unwrap();
        // all 6 frames reached the sink
        let sink = report.elements.iter().find(|e| e.name.starts_with("fakesink")).unwrap();
        assert_eq!(sink.buffers_in(), 6);
        // the run report carries traffic/allocator counters
        assert!(report.traffic.writes > 0);
        assert!(report.traffic.alloc + report.traffic.pool_reuse > 0);
    }

    #[test]
    fn tee_duplicates_frames() {
        let mut p = Pipeline::parse(
            "videotestsrc num-buffers=5 ! video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
             tee name=t t. ! queue ! fakesink name=s1 t. ! queue ! fakesink name=s2",
        )
        .unwrap();
        let report = p.run().unwrap();
        assert_eq!(report.element("s1").unwrap().buffers_in(), 5);
        assert_eq!(report.element("s2").unwrap().buffers_in(), 5);
    }
}
