//! Pipeline construction and execution.
//!
//! [`Pipeline`] is the user-facing entry point. Three layers of the
//! public API meet here (see DESIGN.md "Public API"):
//!
//! * **launch strings** — [`Pipeline::parse`] accepts gst-launch syntax
//!   and deserializes properties into the same typed structs the builder
//!   uses;
//! * **typed builder** — [`PipelineBuilder`] constructs graphs
//!   programmatically from compile-time-checked props;
//! * **live control** — [`play`] returns a [`Running`] whose control
//!   channel steers a playing pipeline (`set_property`, valves,
//!   selectors, `tensor_sink` subscriptions), and `appsrc` handles
//!   ([`Pipeline::appsrc`]) push application data in.
//!
//! Execution happens on a **bounded worker pool** ([`executor`]): every
//! element is a step-driven task, so a device can host many pipelines at
//! O(workers) threads. [`PipelineHub`] is the multi-tenant entry point —
//! launch/enumerate/join fleets of pipelines with per-pipeline
//! [`Priority`] over one executor — and its [`stream`] registry names
//! the **stream endpoints** (tensor-query pub/sub topics) through which
//! pipelines compose as services: publish with
//! `tensor_query_serversink topic=x` (or `hub.publish`), subscribe with
//! `tensor_query_serversrc topic=x` (or `hub.subscribe`).
//!
//! [`run`]: Pipeline::run
//! [`play`]: Pipeline::play

pub mod builder;
pub mod executor;
pub mod fault;
pub mod graph;
pub mod hub;
pub mod parser;
pub mod scheduler;
pub mod stream;

pub use builder::PipelineBuilder;
pub use executor::{Executor, Priority, Waker};
pub use fault::{FaultKind, FaultPlan, FaultSpec};
pub use graph::{Graph, Link, Node, NodeId};
pub use hub::{HubJoin, InvokeTicket, PipelineHub, RestartPolicy, TenantQuota};
pub use scheduler::{Controller, Running};
pub use stream::{
    PushOutcome, Qos, QueryClient, StreamEnd, StreamRegistry, SubscriberCounters, TopicPublisher,
    TopicSubscriber, Transport,
};

use crate::element::Element;
use crate::elements::sinks::AppSink;
use crate::elements::sources::{AppSrc, AppSrcHandle};
use crate::error::{Error, Result};
use crate::metrics::stats::PipelineReport;
use crate::tensor::Buffer;

pub struct Pipeline {
    pub graph: Graph,
    /// Elements recovered after a completed run, keyed by node name.
    finished: Vec<(String, Box<dyn Element>)>,
}

impl Pipeline {
    pub fn new(graph: Graph) -> Self {
        Self {
            graph,
            finished: Vec::new(),
        }
    }

    /// Parse a gst-launch-style description (see [`parser`]).
    ///
    /// ```
    /// use nnstreamer::pipeline::Pipeline;
    ///
    /// # fn main() -> nnstreamer::Result<()> {
    /// let p = Pipeline::parse(
    ///     "videotestsrc num-buffers=4 ! tensor_converter ! fakesink",
    /// )?;
    /// assert_eq!(p.graph.nodes.len(), 3);
    /// # Ok(())
    /// # }
    /// ```
    pub fn parse(desc: &str) -> Result<Self> {
        Ok(Self::new(parser::parse(desc)?))
    }

    /// Start a typed, fluent [`PipelineBuilder`].
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder::new()
    }

    /// Set a deadline budget for load shedding (deadline-aware QoS). A
    /// buffer whose pts lies more than `budget` in the past — measured
    /// against the pipeline's epoch clock — is *shed* at its next link
    /// crossing or executor step gate instead of consuming further
    /// compute, and charged to the shedding element's `shed` counter
    /// (surfaced in `PipelineReport.sched.shed`). `Duration::ZERO`
    /// disables shedding (the default): correctness-mode pipelines
    /// deliver every buffer exactly as before.
    pub fn set_deadline(&mut self, budget: std::time::Duration) -> &mut Self {
        self.graph.deadline_ns = budget.as_nanos() as u64;
        self
    }

    /// Install a deterministic [`FaultPlan`] for chaos testing: armed
    /// faults fire at exact stream positions of named elements (see
    /// [`fault`] for the step-index contract). Without a plan — the
    /// default — the step path carries no injector.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) -> &mut Self {
        self.graph.fault_plan = if plan.is_empty() { None } else { Some(plan) };
        self
    }

    /// Push handle of a named [`AppSrc`] — call before [`play`], push
    /// from any thread afterwards.
    ///
    /// [`play`]: Pipeline::play
    pub fn appsrc(&mut self, name: &str) -> Result<AppSrcHandle> {
        let id = self
            .graph
            .by_name(name)
            .ok_or_else(|| Error::Graph(format!("no element named {name:?}")))?;
        self.graph
            .node_mut(id)
            .element
            .as_any()
            .and_then(|a| a.downcast_mut::<AppSrc>())
            .map(|src| src.handle())
            .ok_or_else(|| Error::Graph(format!("element {name:?} is not an appsrc")))
    }

    /// Receiving end of a named [`AppSink`] — call before [`play`]; the
    /// channel closes when the sink reaches end-of-stream, and each
    /// receive unparks the sink task if the bounded channel had filled.
    ///
    /// [`play`]: Pipeline::play
    pub fn appsink(
        &mut self,
        name: &str,
    ) -> Result<crate::elements::sinks::AppSinkReceiver> {
        let id = self
            .graph
            .by_name(name)
            .ok_or_else(|| Error::Graph(format!("no element named {name:?}")))?;
        self.graph
            .node_mut(id)
            .element
            .as_any()
            .and_then(|a| a.downcast_mut::<AppSink>())
            .and_then(|sink| sink.take_receiver())
            .ok_or_else(|| {
                Error::Graph(format!(
                    "element {name:?} is not an appsink (or its receiver was already taken)"
                ))
            })
    }

    /// Start the pipeline's elements as tasks on the process-global
    /// worker pool; returns a handle for live control.
    pub fn play(&mut self) -> Result<Running> {
        scheduler::start(&mut self.graph)
    }

    /// Like [`play`](Pipeline::play), but on a specific executor with a
    /// scheduling priority (tests pin worker counts this way; apps
    /// hosting many pipelines usually go through [`PipelineHub`]).
    pub fn play_on(
        &mut self,
        exec: &executor::Executor,
        pri: executor::Priority,
    ) -> Result<Running> {
        scheduler::start_on(exec, &mut self.graph, pri)
    }

    /// Run to completion (EOS on all sinks) and return the report.
    pub fn run(&mut self) -> Result<PipelineReport> {
        let running = self.play()?;
        let (report, elements) = running.wait()?;
        self.finished = elements;
        Ok(report)
    }

    /// Run to completion on a specific executor.
    pub fn run_on(
        &mut self,
        exec: &executor::Executor,
        pri: executor::Priority,
    ) -> Result<PipelineReport> {
        let running = self.play_on(exec, pri)?;
        let (report, elements) = running.wait()?;
        self.finished = elements;
        Ok(report)
    }

    /// Access an element after [`run`] completed (for sinks that collected
    /// results). Returns `None` while the pipeline has not finished.
    ///
    /// [`run`]: Pipeline::run
    pub fn finished_element(&mut self, name: &str) -> Option<&mut Box<dyn Element>> {
        self.finished
            .iter_mut()
            .find(|(n, _)| n == name)
            .map(|(_, e)| e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_smoke() {
        let mut p = Pipeline::parse(
            "videotestsrc num-buffers=6 pattern=gradient ! \
             video/x-raw,format=RGB,width=32,height=32,framerate=120 ! \
             tensor_converter ! tensor_transform mode=typecast option=float32 ! \
             fakesink",
        )
        .unwrap();
        let report = p.run().unwrap();
        // all 6 frames reached the sink
        let sink = report.elements.iter().find(|e| e.name.starts_with("fakesink")).unwrap();
        assert_eq!(sink.buffers_in(), 6);
        // the run report carries traffic/allocator counters
        assert!(report.traffic.writes > 0);
        assert!(report.traffic.alloc + report.traffic.pool_reuse > 0);
        // the terminal sink recorded one e2e latency sample per frame
        assert_eq!(report.latency.count, 6);
        // no deadline configured: nothing shed
        assert_eq!(report.sched.shed, 0);
    }

    #[test]
    fn deadline_sheds_late_buffers() {
        let mut p = Pipeline::parse("appsrc name=in ! fakesink name=out").unwrap();
        // 1 ns budget: a pts-0 buffer is always late by the time any
        // element sees it, so every push sheds at the first link crossing
        p.set_deadline(std::time::Duration::from_nanos(1));
        let h = p.appsrc("in").unwrap();
        let feeder = std::thread::spawn(move || {
            for i in 0..4 {
                h.push(Buffer::from_f32(0, &[i as f32])).unwrap();
            }
            h.end();
        });
        let report = p.run().unwrap();
        feeder.join().unwrap();
        assert_eq!(report.element("out").unwrap().buffers_in(), 0);
        assert_eq!(report.sched.shed, 4);
        assert_eq!(report.latency.count, 0);
    }

    #[test]
    fn tee_duplicates_frames() {
        let mut p = Pipeline::parse(
            "videotestsrc num-buffers=5 ! video/x-raw,format=RGB,width=16,height=16,framerate=240 ! \
             tee name=t t. ! queue ! fakesink name=s1 t. ! queue ! fakesink name=s2",
        )
        .unwrap();
        let report = p.run().unwrap();
        assert_eq!(report.element("s1").unwrap().buffers_in(), 5);
        assert_eq!(report.element("s2").unwrap().buffers_in(), 5);
    }
}
