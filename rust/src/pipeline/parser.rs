//! gst-launch-style pipeline description parser.
//!
//! Supported grammar (the subset the paper's pipelines use):
//!
//! ```text
//! pipeline   := chain { chain }
//! chain      := endpoint { "!" endpoint }
//! endpoint   := element | capsfilter | branchref
//! element    := FACTORY { prop }
//! prop       := KEY "=" VALUE        (quotes allowed around VALUE)
//! capsfilter := MEDIA "," FIELDS     (e.g. video/x-raw,format=RGB,...)
//! branchref  := NAME "."             (continue from a named element)
//! ```
//!
//! `name=foo` renames an element so later chains can branch from `foo.`,
//! exactly like gst-launch:
//!
//! ```text
//! videotestsrc ! tee name=t   t. ! queue ! fakesink   t. ! queue ! fakesink
//! ```
//!
//! The parser is a thin front-end over the typed property structs: every
//! `key=value` token is deserialized into the owning element's
//! [`Props`](crate::element::Props) through `Graph::set_property`, so the
//! launch string and the [`PipelineBuilder`](super::PipelineBuilder)
//! configure elements through one validation path. Errors carry the byte
//! span of the offending token and the element being configured
//! ([`Error::ParseAt`]).

use crate::element::Registry;
use crate::error::{Error, Result};
use crate::pipeline::graph::{Graph, NodeId};
use crate::tensor::Caps;

/// A lexed token with its byte span in the description.
struct Token {
    text: String,
    start: usize,
    end: usize,
}

impl Token {
    fn error(&self, element: Option<&str>, message: impl Into<String>) -> Error {
        Error::ParseAt {
            message: message.into(),
            span: (self.start, self.end),
            element: element.map(str::to_string),
        }
    }
}

/// Parse a launch description into a [`Graph`].
pub fn parse(desc: &str) -> Result<Graph> {
    let tokens = tokenize(desc)?;
    if tokens.is_empty() {
        return Err(Error::Parse("empty pipeline description".into()));
    }
    let mut g = Graph::new();
    // current chain head: the node new links attach from
    let mut current: Option<NodeId> = None;
    // whether a "!" is pending between current and the next endpoint
    let mut pending_link = false;

    for tok in &tokens {
        match tok.text.as_str() {
            "!" => {
                if current.is_none() || pending_link {
                    return Err(tok.error(None, "dangling '!'"));
                }
                pending_link = true;
            }
            t if t.ends_with('.') && !t.contains('=') && !t.contains('/') => {
                // branch reference: `name. ! ...` continues from a named
                // element; `... ! name.` links into it (gst-launch both ways)
                let name = &t[..t.len() - 1];
                let id = g.by_name(name).ok_or_else(|| {
                    tok.error(None, format!("unknown branch reference {name:?}"))
                })?;
                if pending_link {
                    let src = current
                        .ok_or_else(|| tok.error(None, "link without source"))?;
                    g.link(src, id)
                        .map_err(|e| tok.error(Some(name), e.bare_message()))?;
                    pending_link = false;
                    // the chain terminates at the reference
                    current = None;
                } else {
                    current = Some(id);
                }
            }
            t if is_property_token(t) && current.is_some() && !pending_link => {
                // property on the current element
                let (k, v) = t.split_once('=').unwrap();
                let id = current.unwrap();
                let result = if k == "name" {
                    g.rename(id, v)
                } else {
                    g.set_property(id, k, unquote(v))
                };
                result.map_err(|e| {
                    let element = g.node(id).name.clone();
                    tok.error(Some(&element), e.bare_message())
                })?;
            }
            t if t.contains('/') => {
                // caps filter
                let caps = Caps::parse(t).map_err(|e| tok.error(None, e.bare_message()))?;
                let id = g
                    .add("capsfilter")
                    .map_err(|e| tok.error(None, e.bare_message()))?;
                g.set_property(id, "caps", &caps.to_string())
                    .map_err(|e| tok.error(Some("capsfilter"), e.bare_message()))?;
                attach(&mut g, &mut current, &mut pending_link, id, tok)?;
            }
            t => {
                // element factory: Registry::make reports unknown names
                // with a nearest-factory suggestion
                let id = g.add(t).map_err(|e| tok.error(None, e.bare_message()))?;
                attach(&mut g, &mut current, &mut pending_link, id, tok)?;
            }
        }
    }
    if pending_link {
        let last = tokens.last().expect("non-empty");
        return Err(last.error(None, "pipeline ends with '!'"));
    }
    Ok(g)
}

fn attach(
    g: &mut Graph,
    current: &mut Option<NodeId>,
    pending_link: &mut bool,
    id: NodeId,
    tok: &Token,
) -> Result<()> {
    if *pending_link {
        let src = current.ok_or_else(|| tok.error(None, "link without source"))?;
        let dst_name = g.node(id).name.clone();
        g.link(src, id)
            .map_err(|e| tok.error(Some(&dst_name), e.bare_message()))?;
        *pending_link = false;
    }
    *current = Some(id);
    Ok(())
}

/// A `key=value` token is a property when its first `=` comes before any
/// `/` — so `topic=ns/stream` and `location=/tmp/frames.bin` configure
/// the current element, while caps like `video/x-raw,format=RGB` keep
/// their media-type prefix and stay caps filters.
fn is_property_token(t: &str) -> bool {
    match t.find('=') {
        Some(eq) => t.find('/').is_none_or(|slash| eq < slash),
        None => false,
    }
}

fn unquote(v: &str) -> &str {
    let v = v.trim();
    if (v.starts_with('"') && v.ends_with('"') && v.len() >= 2)
        || (v.starts_with('\'') && v.ends_with('\'') && v.len() >= 2)
    {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

/// Split on whitespace, honoring quotes inside property values. Each
/// token records its byte span in the original description.
fn tokenize(desc: &str) -> Result<Vec<Token>> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    let mut cur_start = 0usize;
    let mut quote: Option<char> = None;
    let mut quote_start = 0usize;
    for (pos, c) in desc.char_indices() {
        match quote {
            Some(q) => {
                cur.push(c);
                if c == q {
                    quote = None;
                }
            }
            None => match c {
                '"' | '\'' => {
                    if cur.is_empty() {
                        cur_start = pos;
                    }
                    cur.push(c);
                    quote = Some(c);
                    quote_start = pos;
                }
                c if c.is_whitespace() => {
                    if !cur.is_empty() {
                        tokens.push(Token {
                            text: std::mem::take(&mut cur),
                            start: cur_start,
                            end: pos,
                        });
                    }
                }
                c => {
                    if cur.is_empty() {
                        cur_start = pos;
                    }
                    cur.push(c);
                }
            },
        }
    }
    if quote.is_some() {
        return Err(Error::ParseAt {
            message: "unterminated quote".into(),
            span: (quote_start, desc.len()),
            element: None,
        });
    }
    if !cur.is_empty() {
        tokens.push(Token {
            text: cur,
            start: cur_start,
            end: desc.len(),
        });
    }
    Ok(tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_linear_pipeline() {
        let g = parse(
            "videotestsrc num-buffers=8 ! videoconvert format=RGB ! \
             tensor_converter ! fakesink",
        )
        .unwrap();
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.links.len(), 3);
    }

    #[test]
    fn parses_named_branches() {
        let g = parse(
            "videotestsrc num-buffers=4 ! tee name=t \
             t. ! queue ! fakesink \
             t. ! queue ! fakesink",
        )
        .unwrap();
        assert_eq!(g.links.len(), 5);
        let t = g.by_name("t").unwrap();
        assert_eq!(g.n_src_links(t), 2);
    }

    #[test]
    fn parses_caps_filter() {
        let g = parse(
            "videotestsrc ! video/x-raw,format=RGB,width=64,height=64,framerate=30 ! fakesink",
        )
        .unwrap();
        assert_eq!(g.nodes.len(), 3);
        let cf = g.by_name("capsfilter1").unwrap();
        assert_eq!(g.node(cf).element.type_name(), "capsfilter");
    }

    #[test]
    fn rejects_unknown_element() {
        assert!(parse("nosuchelement ! fakesink").is_err());
    }

    #[test]
    fn rejects_dangling_link() {
        assert!(parse("videotestsrc !").is_err());
        assert!(parse("! fakesink").is_err());
    }

    #[test]
    fn rejects_unknown_branch() {
        assert!(parse("videotestsrc ! fakesink nope. ! fakesink").is_err());
    }

    #[test]
    fn quoted_property_values() {
        let g = parse("videotestsrc pattern=\"smpte\" ! fakesink").unwrap();
        assert_eq!(g.nodes.len(), 2);
    }

    #[test]
    fn tensor_filter_batch_properties_parse() {
        let g = parse(
            "videotestsrc num-buffers=4 ! tensor_converter ! \
             tensor_filter framework=passthrough batch=4 latency-budget=2 name=f ! \
             fakesink",
        )
        .unwrap();
        assert!(g.by_name("f").is_some());
    }

    #[test]
    fn tensor_filter_rejects_bad_batch_values() {
        assert!(parse("videotestsrc ! tensor_filter batch=0 ! fakesink").is_err());
        assert!(parse("videotestsrc ! tensor_filter batch=nope ! fakesink").is_err());
        assert!(
            parse("videotestsrc ! tensor_filter latency-budget=-3 ! fakesink").is_err()
        );
    }

    #[test]
    fn property_values_may_contain_slashes() {
        // topic namespaces (`ns/stream`) and filesystem paths are
        // properties, not caps filters
        let g = parse(
            "videotestsrc num-buffers=2 ! tensor_converter ! \
             tensor_query_serversink name=q topic=ns/stream",
        )
        .unwrap();
        assert_eq!(
            g.node(g.by_name("q").unwrap()).element.type_name(),
            "tensor_query_serversink"
        );
        let g = parse("filesrc location=/tmp/frames.bin ! fakesink").unwrap();
        assert_eq!(g.nodes.len(), 2);
    }

    #[test]
    fn query_elements_parse_with_trailing_capsfilter() {
        let g = parse(
            "tensor_query_serversrc topic=q/parse max-buffers=8 ! \
             other/tensor,dimension=3:16:16,type=uint8,framerate=240 ! \
             tensor_converter name=conv ! fakesink",
        );
        // tensor_converter rejects tensor input, so the *graph* may not
        // negotiate — but the description must parse into 4 nodes
        let g = g.unwrap();
        assert_eq!(g.nodes.len(), 4);
    }

    // -- span-carrying error reporting (satellite) ----------------------

    fn parse_at(desc: &str) -> (String, (usize, usize), Option<String>) {
        match parse(desc).unwrap_err() {
            Error::ParseAt {
                message,
                span,
                element,
            } => (message, span, element),
            other => panic!("expected ParseAt, got {other}"),
        }
    }

    #[test]
    fn bad_property_value_reports_span_and_element() {
        let desc = "videotestsrc num-buffers=nope ! fakesink";
        let (msg, span, element) = parse_at(desc);
        assert_eq!(&desc[span.0..span.1], "num-buffers=nope");
        assert_eq!(element.as_deref(), Some("videotestsrc0"));
        assert!(msg.contains("expected integer"), "{msg}");
    }

    #[test]
    fn unknown_property_reports_renamed_element() {
        let desc = "videotestsrc name=cam frobnicate=1 ! fakesink";
        let (msg, span, element) = parse_at(desc);
        assert_eq!(&desc[span.0..span.1], "frobnicate=1");
        assert_eq!(element.as_deref(), Some("cam"));
        assert!(msg.contains("unknown property"), "{msg}");
    }

    #[test]
    fn unknown_factory_reports_span_and_suggestion() {
        let desc = "videotestsrc ! qeueu ! fakesink";
        let (msg, span, element) = parse_at(desc);
        assert_eq!(&desc[span.0..span.1], "qeueu");
        assert_eq!(element, None);
        assert!(msg.contains("did you mean \"queue\"?"), "{msg}");
    }

    #[test]
    fn dangling_link_reports_span() {
        let desc = "! fakesink";
        let (msg, span, _) = parse_at(desc);
        assert_eq!(&desc[span.0..span.1], "!");
        assert!(msg.contains("dangling"), "{msg}");
    }

    #[test]
    fn trailing_link_reports_span() {
        let desc = "videotestsrc !";
        let (msg, span, _) = parse_at(desc);
        assert_eq!(&desc[span.0..span.1], "!");
        assert!(msg.contains("ends with"), "{msg}");
    }

    #[test]
    fn unknown_branch_reports_span() {
        let desc = "videotestsrc ! fakesink nope. ! fakesink";
        let (msg, span, _) = parse_at(desc);
        assert_eq!(&desc[span.0..span.1], "nope.");
        assert!(msg.contains("unknown branch reference"), "{msg}");
    }

    #[test]
    fn unterminated_quote_reports_span_to_end() {
        let desc = "videotestsrc pattern=\"smpte ! fakesink";
        let (msg, span, _) = parse_at(desc);
        assert_eq!(span.1, desc.len());
        assert!(msg.contains("unterminated quote"), "{msg}");
    }
}
