//! gst-launch-style pipeline description parser.
//!
//! Supported grammar (the subset the paper's pipelines use):
//!
//! ```text
//! pipeline   := chain { chain }
//! chain      := endpoint { "!" endpoint }
//! endpoint   := element | capsfilter | branchref
//! element    := FACTORY { prop }
//! prop       := KEY "=" VALUE        (quotes allowed around VALUE)
//! capsfilter := MEDIA "," FIELDS     (e.g. video/x-raw,format=RGB,...)
//! branchref  := NAME "."             (continue from a named element)
//! ```
//!
//! `name=foo` renames an element so later chains can branch from `foo.`,
//! exactly like gst-launch:
//!
//! ```text
//! videotestsrc ! tee name=t   t. ! queue ! fakesink   t. ! queue ! fakesink
//! ```

use crate::element::Registry;
use crate::error::{Error, Result};
use crate::pipeline::graph::{Graph, NodeId};
use crate::tensor::Caps;

/// Parse a launch description into a [`Graph`].
pub fn parse(desc: &str) -> Result<Graph> {
    let tokens = tokenize(desc)?;
    if tokens.is_empty() {
        return Err(Error::Parse("empty pipeline description".into()));
    }
    let mut g = Graph::new();
    // current chain head: the node new links attach from
    let mut current: Option<NodeId> = None;
    // whether a "!" is pending between current and the next endpoint
    let mut pending_link = false;

    for tok in tokens {
        match tok.as_str() {
            "!" => {
                if current.is_none() || pending_link {
                    return Err(Error::Parse("dangling '!'".into()));
                }
                pending_link = true;
            }
            t if t.ends_with('.') && !t.contains('=') && !t.contains('/') => {
                // branch reference: `name. ! ...` continues from a named
                // element; `... ! name.` links into it (gst-launch both ways)
                let name = &t[..t.len() - 1];
                let id = g
                    .by_name(name)
                    .ok_or_else(|| Error::Parse(format!("unknown branch reference {name:?}")))?;
                if pending_link {
                    let src = current
                        .ok_or_else(|| Error::Parse("link without source".into()))?;
                    g.link(src, id)?;
                    pending_link = false;
                    // the chain terminates at the reference
                    current = None;
                } else {
                    current = Some(id);
                }
            }
            t if t.contains('=') && !t.contains('/') && current.is_some() && !pending_link => {
                // property on the current element
                let (k, v) = t.split_once('=').unwrap();
                let id = current.unwrap();
                if k == "name" {
                    g.rename(id, v)?;
                } else {
                    g.set_property(id, k, unquote(v))?;
                }
            }
            t if t.contains('/') => {
                // caps filter
                let caps = Caps::parse(t)?;
                let id = g.add("capsfilter")?;
                g.set_property(id, "caps", &caps.to_string())?;
                attach(&mut g, &mut current, &mut pending_link, id)?;
            }
            t => {
                if !Registry::exists(t) {
                    return Err(Error::Parse(format!("no such element {t:?}")));
                }
                let id = g.add(t)?;
                attach(&mut g, &mut current, &mut pending_link, id)?;
            }
        }
    }
    if pending_link {
        return Err(Error::Parse("pipeline ends with '!'".into()));
    }
    Ok(g)
}

fn attach(
    g: &mut Graph,
    current: &mut Option<NodeId>,
    pending_link: &mut bool,
    id: NodeId,
) -> Result<()> {
    if *pending_link {
        let src = current.ok_or_else(|| Error::Parse("link without source".into()))?;
        g.link(src, id)?;
        *pending_link = false;
    }
    *current = Some(id);
    Ok(())
}

fn unquote(v: &str) -> &str {
    let v = v.trim();
    if (v.starts_with('"') && v.ends_with('"') && v.len() >= 2)
        || (v.starts_with('\'') && v.ends_with('\'') && v.len() >= 2)
    {
        &v[1..v.len() - 1]
    } else {
        v
    }
}

/// Split on whitespace, honoring quotes inside property values.
fn tokenize(desc: &str) -> Result<Vec<String>> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    let mut quote: Option<char> = None;
    for c in desc.chars() {
        match quote {
            Some(q) => {
                cur.push(c);
                if c == q {
                    quote = None;
                }
            }
            None => match c {
                '"' | '\'' => {
                    cur.push(c);
                    quote = Some(c);
                }
                c if c.is_whitespace() => {
                    if !cur.is_empty() {
                        tokens.push(std::mem::take(&mut cur));
                    }
                }
                c => cur.push(c),
            },
        }
    }
    if quote.is_some() {
        return Err(Error::Parse("unterminated quote".into()));
    }
    if !cur.is_empty() {
        tokens.push(cur);
    }
    Ok(tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_linear_pipeline() {
        let g = parse(
            "videotestsrc num-buffers=8 ! videoconvert format=RGB ! \
             tensor_converter ! fakesink",
        )
        .unwrap();
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.links.len(), 3);
    }

    #[test]
    fn parses_named_branches() {
        let g = parse(
            "videotestsrc num-buffers=4 ! tee name=t \
             t. ! queue ! fakesink \
             t. ! queue ! fakesink",
        )
        .unwrap();
        assert_eq!(g.links.len(), 5);
        let t = g.by_name("t").unwrap();
        assert_eq!(g.n_src_links(t), 2);
    }

    #[test]
    fn parses_caps_filter() {
        let g = parse(
            "videotestsrc ! video/x-raw,format=RGB,width=64,height=64,framerate=30 ! fakesink",
        )
        .unwrap();
        assert_eq!(g.nodes.len(), 3);
        let cf = g.by_name("capsfilter1").unwrap();
        assert_eq!(g.node(cf).element.type_name(), "capsfilter");
    }

    #[test]
    fn rejects_unknown_element() {
        assert!(parse("nosuchelement ! fakesink").is_err());
    }

    #[test]
    fn rejects_dangling_link() {
        assert!(parse("videotestsrc !").is_err());
        assert!(parse("! fakesink").is_err());
    }

    #[test]
    fn rejects_unknown_branch() {
        assert!(parse("videotestsrc ! fakesink nope. ! fakesink").is_err());
    }

    #[test]
    fn quoted_property_values() {
        let g = parse("videotestsrc pattern=\"smpte\" ! fakesink").unwrap();
        assert_eq!(g.nodes.len(), 2);
    }

    #[test]
    fn tensor_filter_batch_properties_parse() {
        let g = parse(
            "videotestsrc num-buffers=4 ! tensor_converter ! \
             tensor_filter framework=passthrough batch=4 latency-budget=2 name=f ! \
             fakesink",
        )
        .unwrap();
        assert!(g.by_name("f").is_some());
    }

    #[test]
    fn tensor_filter_rejects_bad_batch_values() {
        assert!(parse("videotestsrc ! tensor_filter batch=0 ! fakesink").is_err());
        assert!(parse("videotestsrc ! tensor_filter batch=nope ! fakesink").is_err());
        assert!(
            parse("videotestsrc ! tensor_filter latency-budget=-3 ! fakesink").is_err()
        );
    }
}
