//! Deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] arms faults at exact *step indices* of named
//! elements; [`Pipeline::set_fault_plan`](crate::Pipeline::set_fault_plan)
//! threads it into the executor, which consults a per-element
//! [`FaultInjector`] in the step path. Because the step index is defined
//! in stream terms — the Nth produced buffer for a source, the Nth
//! arriving buffer for a consumer (see
//! `Ctx::check_injected_fault`) — an armed fault fires at the same
//! point in the data stream for any worker count or schedule, which is
//! what makes chaos runs reproducible and their assertions exact.
//!
//! This is test/bench infrastructure compiled into the crate (it's the
//! foundation of `tests/chaos.rs` and the `e10_faults` bench), but it's
//! inert unless a plan is installed: production pipelines carry no
//! injector and pay only an `Option` check that is `None`.

use crate::error::{Error, Result};

/// What an armed fault does when its step arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside the element's step (exercises the catch-unwind +
    /// typed [`Error::Panicked`](crate::Error::Panicked) path).
    Panic,
    /// Return a typed element error from the step.
    Error,
    /// Delay the element's step by this many milliseconds. Under the
    /// pooled executor the task parks on the timer wheel (no worker
    /// held, invisible to the stall watchdog — like a slow device, not a
    /// wedged one); the step's buffer is replayed after the deadline.
    DelayMs(u64),
    /// Sleep this many milliseconds *inside* the step while runnable —
    /// the signature a stall watchdog must detect (progress counters
    /// frozen, task not parked, worker held).
    StallMs(u64),
    /// Discard one buffer. On a consumer the arriving buffer is
    /// consumed and dropped (the step index still advances); on a
    /// source there is no buffer to discard yet, so it degrades to a
    /// skipped scheduling step.
    Drop,
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind> {
        match s {
            "panic" => Ok(FaultKind::Panic),
            "error" => Ok(FaultKind::Error),
            "drop" => Ok(FaultKind::Drop),
            _ => {
                if let Some(ms) = s.strip_prefix("delay:") {
                    let ms = ms.parse::<u64>().map_err(|_| {
                        Error::Parse(format!("bad fault delay {ms:?}: expected milliseconds"))
                    })?;
                    Ok(FaultKind::DelayMs(ms))
                } else if let Some(ms) = s.strip_prefix("stall:") {
                    let ms = ms.parse::<u64>().map_err(|_| {
                        Error::Parse(format!("bad fault stall {ms:?}: expected milliseconds"))
                    })?;
                    Ok(FaultKind::StallMs(ms))
                } else {
                    Err(Error::Parse(format!(
                        "unknown fault kind {s:?}: expected panic|error|delay:MS|stall:MS|drop"
                    )))
                }
            }
        }
    }
}

/// One armed fault: element name + step index + kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    /// Name of the element (graph node name, e.g. `"tensor_filter0"`).
    pub element: String,
    /// Step index at which to fire (0 = before the first buffer).
    pub step: u64,
    pub kind: FaultKind,
}

/// A set of armed faults for one pipeline run. Build programmatically
/// ([`at`](FaultPlan::at)) or parse the compact string form
/// `"element:step:kind"` (comma-separated; kinds:
/// `panic | error | delay:MS | stall:MS | drop`):
///
/// ```
/// use nnstreamer::pipeline::fault::{FaultKind, FaultPlan};
/// let plan = FaultPlan::parse("filter0:3:panic,sink0:10:delay:250").unwrap();
/// assert_eq!(plan.specs().len(), 2);
/// assert_eq!(plan.specs()[1].kind, FaultKind::DelayMs(250));
/// let same = FaultPlan::new()
///     .at("filter0", 3, FaultKind::Panic)
///     .at("sink0", 10, FaultKind::DelayMs(250));
/// assert_eq!(plan, same);
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Arm `kind` at step `step` of element `element` (builder-style).
    pub fn at(mut self, element: impl Into<String>, step: u64, kind: FaultKind) -> FaultPlan {
        self.specs.push(FaultSpec {
            element: element.into(),
            step,
            kind,
        });
        self
    }

    /// Parse the compact `"element:step:kind,..."` form.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            // kind may itself contain ':' (delay:MS) — split off the
            // first two fields only.
            let mut it = part.splitn(3, ':');
            let (el, step, kind) = (it.next(), it.next(), it.next());
            let (Some(el), Some(step), Some(kind)) = (el, step, kind) else {
                return Err(Error::Parse(format!(
                    "bad fault spec {part:?}: expected element:step:kind"
                )));
            };
            let step = step.parse::<u64>().map_err(|_| {
                Error::Parse(format!("bad fault step {step:?}: expected integer"))
            })?;
            plan.specs.push(FaultSpec {
                element: el.to_string(),
                step,
                kind: FaultKind::parse(kind)?,
            });
        }
        Ok(plan)
    }

    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// The injector for one element, or `None` when the plan arms
    /// nothing there (the executor then skips injection entirely).
    pub(crate) fn injector_for(&self, element: &str) -> Option<FaultInjector> {
        let specs: Vec<InjSpec> = self
            .specs
            .iter()
            .filter(|s| s.element == element)
            .map(|s| InjSpec {
                step: s.step,
                kind: s.kind,
                fired: false,
            })
            .collect();
        if specs.is_empty() {
            None
        } else {
            Some(FaultInjector { specs, seen: 0 })
        }
    }
}

#[derive(Debug, Clone)]
struct InjSpec {
    step: u64,
    kind: FaultKind,
    fired: bool,
}

/// Per-element runtime state of a [`FaultPlan`]: a step counter plus
/// the armed specs. `check()` fires a spec at most once (sticky `fired`
/// flag), so a `DelayMs` consulted again on a retried step does not
/// sleep twice; `advance()` moves the stream-position counter per the
/// contract documented on `Ctx::check_injected_fault`.
#[derive(Debug, Clone)]
pub(crate) struct FaultInjector {
    specs: Vec<InjSpec>,
    /// Current step index (buffers produced / arrived so far).
    seen: u64,
}

impl FaultInjector {
    /// Fault armed at the current step index, if any (fires once).
    pub(crate) fn check(&mut self) -> Option<FaultKind> {
        let seen = self.seen;
        for spec in self.specs.iter_mut() {
            if !spec.fired && spec.step == seen {
                spec.fired = true;
                return Some(spec.kind);
            }
        }
        None
    }

    /// Advance the step index by one.
    pub(crate) fn advance(&mut self) {
        self.seen += 1;
    }
}

/// Tiny deterministic PRNG (splitmix64) for seeded chaos schedules —
/// shared by `tests/chaos.rs` and the `e10_faults` bench so "randomized"
/// step indices are reproducible from a printed seed.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_all_kinds() {
        let plan =
            FaultPlan::parse("a:0:panic, b:7:error, c:3:delay:40, d:2:drop, e:5:stall:15")
                .unwrap();
        assert_eq!(
            plan.specs(),
            &[
                FaultSpec {
                    element: "a".into(),
                    step: 0,
                    kind: FaultKind::Panic
                },
                FaultSpec {
                    element: "b".into(),
                    step: 7,
                    kind: FaultKind::Error
                },
                FaultSpec {
                    element: "c".into(),
                    step: 3,
                    kind: FaultKind::DelayMs(40)
                },
                FaultSpec {
                    element: "d".into(),
                    step: 2,
                    kind: FaultKind::Drop
                },
                FaultSpec {
                    element: "e".into(),
                    step: 5,
                    kind: FaultKind::StallMs(15)
                },
            ]
        );
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("a:1").is_err());
        assert!(FaultPlan::parse("a:x:panic").is_err());
        assert!(FaultPlan::parse("a:1:explode").is_err());
        assert!(FaultPlan::parse("a:1:delay:soon").is_err());
        assert!(FaultPlan::parse("a:1:stall:soon").is_err());
    }

    #[test]
    fn injector_fires_at_exact_step_once() {
        let plan = FaultPlan::new()
            .at("f", 2, FaultKind::Panic)
            .at("f", 4, FaultKind::Drop)
            .at("other", 0, FaultKind::Error);
        assert!(plan.injector_for("missing").is_none());
        let mut inj = plan.injector_for("f").unwrap();
        // step 0, 1: nothing armed
        assert_eq!(inj.check(), None);
        inj.advance();
        assert_eq!(inj.check(), None);
        inj.advance();
        // step 2: fires exactly once even if the step retries
        assert_eq!(inj.check(), Some(FaultKind::Panic));
        assert_eq!(inj.check(), None);
        inj.advance();
        inj.advance(); // skip to step 4 — 3 was never checked; harmless
        assert_eq!(inj.check(), Some(FaultKind::Drop));
        inj.advance();
        assert_eq!(inj.check(), None);
    }

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = 42u64;
        let mut b = 42u64;
        let xs: Vec<u64> = (0..4).map(|_| splitmix64(&mut a)).collect();
        let ys: Vec<u64> = (0..4).map(|_| splitmix64(&mut b)).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs[0], xs[1]);
    }
}
