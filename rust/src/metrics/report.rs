//! Plain-text table rendering shared by the benches so they can print the
//! paper's tables row-for-row.

/// A simple fixed-width table printer.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals (bench table cells).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a    bbbb"));
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_width() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
