//! Global byte-traffic accounting.
//!
//! The paper measures "memory access (billions)" with `perf` (Table III
//! row 4). Hardware counters are not portable to this substrate, so we
//! count bytes moved through the streaming layer instead: every chunk
//! allocation/copy counts as a write, every payload access as a read.
//! The *ordering* between frameworks (NNStreamer vs MediaPipe-like) is what
//! the table compares, and byte traffic preserves it.

use std::sync::atomic::{AtomicU64, Ordering};

static READS: AtomicU64 = AtomicU64::new(0);
static WRITES: AtomicU64 = AtomicU64::new(0);

#[inline]
pub fn count_read(bytes: usize) {
    READS.fetch_add(bytes as u64, Ordering::Relaxed);
}

#[inline]
pub fn count_write(bytes: usize) {
    WRITES.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Snapshot of (read, write) byte counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Snapshot {
    pub reads: u64,
    pub writes: u64,
}

impl Snapshot {
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }
}

pub fn snapshot() -> Snapshot {
    Snapshot {
        reads: READS.load(Ordering::Relaxed),
        writes: WRITES.load(Ordering::Relaxed),
    }
}

/// Traffic accumulated since an earlier snapshot.
pub fn since(start: Snapshot) -> Snapshot {
    let now = snapshot();
    Snapshot {
        reads: now.reads - start.reads,
        writes: now.writes - start.writes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Chunk;

    #[test]
    fn chunk_alloc_counts_write() {
        let start = snapshot();
        let _c = Chunk::from_vec(vec![0u8; 1000]);
        let d = since(start);
        assert!(d.writes >= 1000);
    }

    #[test]
    fn chunk_read_counts_read() {
        let c = Chunk::from_vec(vec![0u8; 512]);
        let start = snapshot();
        let _ = c.as_bytes();
        let d = since(start);
        assert!(d.reads >= 512);
    }
}
