//! Global byte-traffic and allocation accounting.
//!
//! The paper measures "memory access (billions)" with `perf` (Table III
//! row 4). Hardware counters are not portable to this substrate, so we
//! count bytes moved through the streaming layer instead: every chunk
//! allocation/copy counts as a write, every payload access as a read.
//! The *ordering* between frameworks (NNStreamer vs MediaPipe-like) is what
//! the table compares, and byte traffic preserves it.
//!
//! On top of reads/writes, the chunk-pool memory subsystem adds five
//! allocator-level counters:
//!
//! * `alloc` — bytes served by fresh heap allocations (chunk storage and
//!   pool misses);
//! * `pool_reuse` — bytes served from recycled pool storage instead of
//!   the allocator;
//! * `pool_recycle` — bytes of capacity returned to the pool by chunk
//!   drop hooks;
//! * `inplace` — bytes mutated in place by [`Chunk::make_mut`] on a
//!   uniquely owned chunk (a copy that did *not* happen);
//! * `cow` — bytes copied because `make_mut` hit a shared chunk.
//!
//! `benches/e6_memory.rs` compares `alloc` per frame with pooling on vs
//! off; [`crate::metrics::PipelineReport`] carries a per-run delta.
//!
//! [`Chunk::make_mut`]: crate::tensor::Chunk::make_mut

use std::sync::atomic::{AtomicU64, Ordering};

static READS: AtomicU64 = AtomicU64::new(0);
static WRITES: AtomicU64 = AtomicU64::new(0);
static ALLOC: AtomicU64 = AtomicU64::new(0);
static POOL_REUSE: AtomicU64 = AtomicU64::new(0);
static POOL_RECYCLE: AtomicU64 = AtomicU64::new(0);
static INPLACE: AtomicU64 = AtomicU64::new(0);
static COW: AtomicU64 = AtomicU64::new(0);

#[inline]
pub fn count_read(bytes: usize) {
    READS.fetch_add(bytes as u64, Ordering::Relaxed);
}

#[inline]
pub fn count_write(bytes: usize) {
    WRITES.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Bytes served by a fresh heap allocation.
#[inline]
pub fn count_alloc(bytes: usize) {
    ALLOC.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Bytes served from recycled pool storage.
#[inline]
pub fn count_pool_reuse(bytes: usize) {
    POOL_REUSE.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Bytes of capacity returned to the pool.
#[inline]
pub fn count_pool_recycle(bytes: usize) {
    POOL_RECYCLE.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Bytes mutated in place by copy-on-write on a uniquely owned chunk.
#[inline]
pub fn count_inplace(bytes: usize) {
    INPLACE.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Bytes copied by copy-on-write on a shared chunk.
#[inline]
pub fn count_cow(bytes: usize) {
    COW.fetch_add(bytes as u64, Ordering::Relaxed);
}

/// Snapshot of the traffic and allocation counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub reads: u64,
    pub writes: u64,
    /// Bytes served by fresh heap allocations.
    pub alloc: u64,
    /// Bytes served from recycled pool storage.
    pub pool_reuse: u64,
    /// Bytes of capacity returned to the pool.
    pub pool_recycle: u64,
    /// Bytes mutated in place instead of copied (CoW fast path).
    pub inplace: u64,
    /// Bytes copied by CoW on shared chunks.
    pub cow: u64,
}

impl Snapshot {
    /// Total byte traffic (the Table III "memory access" substitute).
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }

    /// Fraction of chunk-storage demand served without a fresh heap
    /// allocation (0.0 when nothing was requested).
    pub fn reuse_ratio(&self) -> f64 {
        let demand = self.alloc + self.pool_reuse;
        if demand == 0 {
            0.0
        } else {
            self.pool_reuse as f64 / demand as f64
        }
    }
}

pub fn snapshot() -> Snapshot {
    Snapshot {
        reads: READS.load(Ordering::Relaxed),
        writes: WRITES.load(Ordering::Relaxed),
        alloc: ALLOC.load(Ordering::Relaxed),
        pool_reuse: POOL_REUSE.load(Ordering::Relaxed),
        pool_recycle: POOL_RECYCLE.load(Ordering::Relaxed),
        inplace: INPLACE.load(Ordering::Relaxed),
        cow: COW.load(Ordering::Relaxed),
    }
}

/// Traffic accumulated since an earlier snapshot.
pub fn since(start: Snapshot) -> Snapshot {
    let now = snapshot();
    Snapshot {
        reads: now.reads - start.reads,
        writes: now.writes - start.writes,
        alloc: now.alloc - start.alloc,
        pool_reuse: now.pool_reuse - start.pool_reuse,
        pool_recycle: now.pool_recycle - start.pool_recycle,
        inplace: now.inplace - start.inplace,
        cow: now.cow - start.cow,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Chunk;

    #[test]
    fn chunk_alloc_counts_write() {
        let start = snapshot();
        let _c = Chunk::from_vec(vec![0u8; 1000]);
        let d = since(start);
        assert!(d.writes >= 1000);
        assert!(d.alloc >= 1000);
    }

    #[test]
    fn chunk_read_counts_read() {
        let c = Chunk::from_vec(vec![0u8; 512]);
        let start = snapshot();
        let _ = c.as_bytes();
        let d = since(start);
        assert!(d.reads >= 512);
    }

    #[test]
    fn make_mut_counts_inplace_then_cow() {
        let start = snapshot();
        let mut c = Chunk::from_vec(vec![0u8; 256]);
        c.make_mut()[0] = 1;
        let d = since(start);
        assert!(d.inplace >= 256);
        let keep = c.clone();
        c.make_mut()[1] = 2;
        let d = since(start);
        assert!(d.cow >= 256);
        drop(keep);
    }

    #[test]
    fn reuse_ratio_bounds() {
        let s = Snapshot {
            alloc: 100,
            pool_reuse: 300,
            ..Default::default()
        };
        assert!((s.reuse_ratio() - 0.75).abs() < 1e-9);
        assert_eq!(Snapshot::default().reuse_ratio(), 0.0);
    }
}
