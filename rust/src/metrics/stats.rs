//! Per-element and per-pipeline statistics probes.
//!
//! Every scheduled element owns an [`ElementStats`] handle; the scheduler
//! records buffers, bytes and busy time as items flow. Work executed on the
//! simulated NPU is recorded in the `npu` domain so that "app CPU" numbers
//! reproduce the paper's offload accounting (see DESIGN.md substitutions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Which compute domain an element's busy time belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Cpu,
    Npu,
}

/// Number of buckets in the fixed-bucket latency histograms.
///
/// Bucket `i` counts samples with `ns < 1 << (10 + i)`: bucket 0 is
/// everything under ~1 µs, bucket 21 under ~2.1 s, and the last bucket
/// absorbs the tail. Power-of-two bounds keep recording a couple of
/// integer ops — cheap enough for the per-buffer hot path.
pub const LATENCY_BUCKETS: usize = 32;

/// Bucket index for a latency sample of `ns` nanoseconds.
#[inline]
pub fn latency_bucket(ns: u64) -> usize {
    let bits = 64 - ns.leading_zeros() as usize;
    bits.saturating_sub(10).min(LATENCY_BUCKETS - 1)
}

/// Upper bound (exclusive) of bucket `i`, in nanoseconds — the value a
/// percentile query reports for samples that landed in the bucket.
#[inline]
fn bucket_bound_ns(i: usize) -> u64 {
    1u64 << (10 + i as u32)
}

/// Summarize plain bucket counts (as produced by [`LatencyHistogram`] or
/// kept under a lock) into `p50/p90/p99` percentiles. Percentiles are
/// conservative upper estimates: each reports the bound of the bucket
/// holding the requested rank.
pub fn summarize_latency(counts: &[u64; LATENCY_BUCKETS]) -> LatencySummary {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return LatencySummary::default();
    }
    let pick = |q: f64| -> Duration {
        let rank = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Duration::from_nanos(bucket_bound_ns(i));
            }
        }
        Duration::from_nanos(bucket_bound_ns(LATENCY_BUCKETS - 1))
    };
    LatencySummary {
        count: total,
        p50: pick(0.50),
        p90: pick(0.90),
        p99: pick(0.99),
    }
}

/// Merge `src` bucket counts into `dst` (for aggregating per-element or
/// per-endpoint histograms into a pipeline/topic summary).
pub fn merge_latency(dst: &mut [u64; LATENCY_BUCKETS], src: &[u64; LATENCY_BUCKETS]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Lock-free fixed-bucket latency histogram (see [`LATENCY_BUCKETS`]).
/// Recording is a single relaxed `fetch_add`; reads are approximate
/// under concurrency, exact once the writers have quiesced.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        LatencyHistogram {
            buckets: [ZERO; LATENCY_BUCKETS],
        }
    }
}

impl LatencyHistogram {
    pub fn record_ns(&self, ns: u64) {
        self.buckets[latency_bucket(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Plain snapshot of the bucket counts.
    pub fn counts(&self) -> [u64; LATENCY_BUCKETS] {
        let mut out = [0u64; LATENCY_BUCKETS];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    pub fn summary(&self) -> LatencySummary {
        summarize_latency(&self.counts())
    }
}

/// Percentile summary of a fixed-bucket latency histogram. `count` is
/// the number of samples; with zero samples the percentiles are zero.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencySummary {
    pub count: u64,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
}

#[derive(Debug, Default)]
pub struct ElementStats {
    pub name: String,
    buffers_in: AtomicU64,
    buffers_out: AtomicU64,
    bytes_out: AtomicU64,
    busy_ns_cpu: AtomicU64,
    busy_ns_npu: AtomicU64,
    dropped: AtomicU64,
    /// wall-clock offsets (ns since pipeline epoch) of first/last arrivals
    first_in_ns: AtomicU64,
    last_in_ns: AtomicU64,
    /// min/max/sum of per-buffer processing latency (ns)
    lat_sum_ns: AtomicU64,
    lat_max_ns: AtomicU64,
    lat_count: AtomicU64,
    /// Pooled-executor accounting: steps this element's task executed,
    /// how often it parked (empty input / saturated output), how often a
    /// wake made it runnable again, and the high-water mark of its
    /// bounded input inbox.
    steps: AtomicU64,
    parks_input: AtomicU64,
    parks_output: AtomicU64,
    wakeups: AtomicU64,
    queue_hwm: AtomicU64,
    /// Device-lane accounting: timer-wheel parks/fires (live pacing,
    /// envelope holds, injected delays) and async device dispatches
    /// (submit → completion wake) this element's task performed.
    parks_timer: AtomicU64,
    timer_fires: AtomicU64,
    device_submits: AtomicU64,
    device_completions: AtomicU64,
    /// Buffers discarded by deadline-aware load shedding (stamped past
    /// their pipeline's deadline budget when crossing a link or arriving
    /// at the step gate). Kept separate from `dropped` so Table-III
    /// accounting stays comparable: `dropped` is element policy (leaky
    /// queues, no subscribers), `shed` is the serving layer.
    shed: AtomicU64,
    /// End-to-end frame latency (arrival at a terminal element minus the
    /// buffer's pts), recorded only by sink-side tasks.
    e2e: LatencyHistogram,
}

impl ElementStats {
    pub fn new(name: &str) -> Arc<Self> {
        Arc::new(ElementStats {
            name: name.to_string(),
            ..Default::default()
        })
    }

    pub fn record_in(&self) {
        self.buffers_in.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an arrival with its wall-clock offset from the pipeline
    /// epoch (lets throughput be computed over the element's own active
    /// span instead of the global wall, which may include the draining of
    /// unrelated slow branches after EOS).
    pub fn record_in_at(&self, ns_since_epoch: u64) {
        if self.buffers_in.fetch_add(1, Ordering::Relaxed) == 0 {
            self.first_in_ns.store(ns_since_epoch, Ordering::Relaxed);
        }
        self.last_in_ns.fetch_max(ns_since_epoch, Ordering::Relaxed);
    }

    /// (first, last) arrival offsets, if any buffers arrived.
    pub fn arrival_span(&self) -> Option<(Duration, Duration)> {
        if self.buffers_in.load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some((
            Duration::from_nanos(self.first_in_ns.load(Ordering::Relaxed)),
            Duration::from_nanos(self.last_in_ns.load(Ordering::Relaxed)),
        ))
    }

    pub fn record_out(&self, bytes: usize) {
        self.buffers_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_drop(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one end-to-end frame latency sample (ns) into the
    /// fixed-bucket histogram.
    pub fn record_e2e_latency_ns(&self, ns: u64) {
        self.e2e.record_ns(ns);
    }

    /// Bucket counts of the end-to-end latency histogram.
    pub fn e2e_latency_counts(&self) -> [u64; LATENCY_BUCKETS] {
        self.e2e.counts()
    }

    pub fn record_busy(&self, domain: Domain, dur: Duration) {
        let ns = dur.as_nanos() as u64;
        match domain {
            Domain::Cpu => self.busy_ns_cpu.fetch_add(ns, Ordering::Relaxed),
            Domain::Npu => self.busy_ns_npu.fetch_add(ns, Ordering::Relaxed),
        };
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.lat_max_ns.fetch_max(ns, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_step(&self) {
        self.steps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_park_input(&self) {
        self.parks_input.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_park_output(&self) {
        self.parks_output.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_wakeup(&self) {
        self.wakeups.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_timer_park(&self) {
        self.parks_timer.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_timer_fire(&self) {
        self.timer_fires.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_device_submit(&self) {
        self.device_submits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_device_completion(&self) {
        self.device_completions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the queue depth of this element's inbox after a push
    /// (keeps the link high-water mark).
    pub fn record_queue_depth(&self, len: u64) {
        self.queue_hwm.fetch_max(len, Ordering::Relaxed);
    }

    /// Executor steps this element's task ran.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Times the task parked waiting for input (empty inbox, or a source
    /// waiting for externally pushed application data).
    pub fn parks_input(&self) -> u64 {
        self.parks_input.load(Ordering::Relaxed)
    }

    /// Times the task parked on a saturated downstream inbox.
    pub fn parks_output(&self) -> u64 {
        self.parks_output.load(Ordering::Relaxed)
    }

    /// Times a wake made the parked task runnable again.
    pub fn wakeups(&self) -> u64 {
        self.wakeups.load(Ordering::Relaxed)
    }

    /// High-water mark of this element's bounded input inbox.
    pub fn queue_high_water(&self) -> u64 {
        self.queue_hwm.load(Ordering::Relaxed)
    }

    /// Times the task parked on the executor timer wheel (live pacing,
    /// envelope holds, injected delays) — waits that held no worker.
    pub fn parks_timer(&self) -> u64 {
        self.parks_timer.load(Ordering::Relaxed)
    }

    /// Times a timer-wheel deadline fired and re-queued the task.
    pub fn timer_fires(&self) -> u64 {
        self.timer_fires.load(Ordering::Relaxed)
    }

    /// Async device-lane submissions (jobs dispatched without blocking).
    pub fn device_submits(&self) -> u64 {
        self.device_submits.load(Ordering::Relaxed)
    }

    /// Device-lane completions drained after a wake.
    pub fn device_completions(&self) -> u64 {
        self.device_completions.load(Ordering::Relaxed)
    }

    pub fn buffers_in(&self) -> u64 {
        self.buffers_in.load(Ordering::Relaxed)
    }

    pub fn buffers_out(&self) -> u64 {
        self.buffers_out.load(Ordering::Relaxed)
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Buffers discarded by deadline-aware load shedding.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn busy_cpu(&self) -> Duration {
        Duration::from_nanos(self.busy_ns_cpu.load(Ordering::Relaxed))
    }

    pub fn busy_npu(&self) -> Duration {
        Duration::from_nanos(self.busy_ns_npu.load(Ordering::Relaxed))
    }

    pub fn latency(&self) -> LatencyStats {
        let count = self.lat_count.load(Ordering::Relaxed);
        LatencyStats {
            count,
            mean: if count == 0 {
                Duration::ZERO
            } else {
                Duration::from_nanos(self.lat_sum_ns.load(Ordering::Relaxed) / count)
            },
            max: Duration::from_nanos(self.lat_max_ns.load(Ordering::Relaxed)),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStats {
    pub count: u64,
    pub mean: Duration,
    pub max: Duration,
}

/// Scheduling counters of one pipeline run on the pooled executor —
/// the Table-III-style accounting extension for the worker-pool core.
/// Per-element sums except `workers` and `run_queue_high_water`, which
/// describe the (possibly shared) executor the pipeline ran on.
#[derive(Debug, Default, Clone, Copy)]
pub struct SchedSnapshot {
    /// Worker threads of the executor this pipeline ran on.
    pub workers: usize,
    /// Element steps executed (one `generate()`/`handle()` per step).
    pub steps: u64,
    /// Parks waiting for input: an empty inbox, or a source waiting for
    /// externally pushed application data (`appsrc`).
    pub parks_input: u64,
    /// Parks on a saturated downstream inbox (backpressure events).
    pub parks_output: u64,
    /// Wakes that made a parked task runnable again.
    pub wakeups: u64,
    /// Executor run-queue high-water mark (tasks runnable but waiting
    /// for a worker; shared across concurrent pipelines).
    pub run_queue_high_water: u64,
    /// Largest bounded-link (inbox) depth any of this pipeline's
    /// elements reached.
    pub link_high_water: u64,
    /// Buffers shed by the deadline gate across this pipeline's elements
    /// (zero unless the pipeline set a deadline budget).
    pub shed: u64,
    /// Timer-wheel parks across this pipeline's elements: timed waits
    /// (live pacing, envelope holds, injected delays) that held no
    /// worker thread while pending.
    pub parks_timer: u64,
    /// Timer-wheel deadlines that fired and re-queued one of this
    /// pipeline's tasks.
    pub timer_fires: u64,
    /// Async device-lane submissions (filter jobs dispatched without
    /// blocking a worker).
    pub device_submits: u64,
    /// Device-lane completions drained after their wake.
    pub device_completions: u64,
}

/// Typed drop accounting of one stream topic. Conservation invariant
/// (per subscriber and in aggregate):
/// `pushed == delivered + qos_leaky + qos_latest + closed + in_flight`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TopicDrops {
    /// Publisher-side: buffer published while no subscriber was attached.
    pub no_subscriber: u64,
    /// Leaky QoS: the arriving buffer was discarded because the
    /// subscriber's queue was full.
    pub qos_leaky: u64,
    /// Latest-only QoS: the oldest queued buffer was evicted to make
    /// room for the newest.
    pub qos_latest: u64,
    /// Buffers still queued when the subscriber detached (or the topic
    /// closed) — delivered to nobody.
    pub closed: u64,
}

impl TopicDrops {
    /// Sum over all drop reasons (excluding `no_subscriber`, which never
    /// entered any subscriber queue and is accounted at the topic, not
    /// per subscriber).
    pub fn subscriber_total(&self) -> u64 {
        self.qos_leaky + self.qos_latest + self.closed
    }

    /// Sum over every drop reason.
    pub fn total(&self) -> u64 {
        self.no_subscriber + self.subscriber_total()
    }
}

/// Counters of one named stream topic (the tensor-query pub/sub layer;
/// see `pipeline/stream.rs`). Cumulative since topic creation and
/// process-global, like the traffic counters.
#[derive(Debug, Default, Clone)]
pub struct TopicSnapshot {
    pub name: String,
    /// Publishers currently attached.
    pub publishers: usize,
    /// Subscriber queues currently attached.
    pub subscribers: usize,
    /// Every publisher finished: the stream ended.
    pub eos: bool,
    /// Buffers accepted from publishers.
    pub published: u64,
    /// Buffer copies pushed into subscriber queues (`published` ×
    /// fan-out at delivery time), plus publisher-side no-subscriber
    /// drops so that `pushed == delivered + dropped + in_flight` holds.
    pub pushed: u64,
    /// Buffers consumers actually popped from subscriber queues.
    pub delivered: u64,
    /// Buffers discarded, summed over every reason (see `drops`).
    pub dropped: u64,
    /// Per-reason drop breakdown; `dropped == drops.total()`.
    pub drops: TopicDrops,
    /// Buffers currently sitting in subscriber queues.
    pub in_flight: u64,
    /// Queue-wait latency percentiles (push into a subscriber queue →
    /// pop by its consumer), aggregated over this topic's subscribers
    /// including already-detached ones.
    pub latency: LatencySummary,
}

/// Summary of one pipeline run, assembled by the scheduler.
#[derive(Debug, Default)]
pub struct PipelineReport {
    pub wall: Duration,
    pub elements: Vec<Arc<ElementStats>>,
    pub cpu_percent: f64,
    pub peak_rss_mib: f64,
    /// Byte-traffic and allocator counters accumulated during the run
    /// (process-global deltas: concurrent pipelines share the counters).
    pub traffic: crate::metrics::traffic::Snapshot,
    /// Worker-pool scheduling counters for this run.
    pub sched: SchedSnapshot,
    /// Per-topic stream-endpoint counters at join time (cumulative and
    /// process-global, like `traffic`: concurrent pipelines publishing
    /// to the same registry share them).
    pub topics: Vec<TopicSnapshot>,
    /// End-to-end frame latency percentiles (sink arrival − pts),
    /// aggregated over this pipeline's terminal elements.
    pub latency: LatencySummary,
    /// Supervised restarts consumed before this (successful) run —
    /// stamped by the hub supervisor; zero for unsupervised pipelines.
    pub restarts: u32,
    /// Faults absorbed across the supervised incarnations that preceded
    /// this run (== `restarts` for a pipeline that eventually
    /// succeeded); zero for unsupervised pipelines.
    pub faults: u32,
}

impl PipelineReport {
    pub fn element(&self, name: &str) -> Option<&Arc<ElementStats>> {
        self.elements.iter().find(|e| e.name == name)
    }

    /// Counters of one stream topic, by name.
    pub fn topic(&self, name: &str) -> Option<&TopicSnapshot> {
        self.topics.iter().find(|t| t.name == name)
    }

    /// Frame rate at element `name`, measured over the element's own
    /// arrival span (a pipeline's slow branch draining after EOS must not
    /// dilute a fast branch's throughput).
    pub fn fps(&self, name: &str) -> f64 {
        let Some(e) = self.element(name) else {
            return 0.0;
        };
        let count = e.buffers_in();
        if count >= 8 {
            if let Some((first, last)) = e.arrival_span() {
                let span = last.saturating_sub(first);
                if !span.is_zero() {
                    return (count - 1) as f64 / span.as_secs_f64();
                }
            }
        }
        if self.wall.is_zero() {
            return 0.0;
        }
        count as f64 / self.wall.as_secs_f64()
    }

    /// Sum of CPU-domain busy time across elements.
    pub fn total_cpu_busy(&self) -> Duration {
        self.elements.iter().map(|e| e.busy_cpu()).sum()
    }

    /// Sum of NPU-domain busy time across elements.
    pub fn total_npu_busy(&self) -> Duration {
        self.elements.iter().map(|e| e.busy_npu()).sum()
    }

    /// Element busy CPU over wallclock, percent-of-one-core (the
    /// framework-attributed CPU load, excluding NPU-domain work).
    pub fn element_cpu_percent(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        100.0 * self.total_cpu_busy().as_secs_f64() / self.wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let s = ElementStats::new("x");
        s.record_in();
        s.record_out(100);
        s.record_busy(Domain::Cpu, Duration::from_millis(5));
        s.record_busy(Domain::Npu, Duration::from_millis(7));
        assert_eq!(s.buffers_in(), 1);
        assert_eq!(s.buffers_out(), 1);
        assert_eq!(s.bytes_out(), 100);
        assert_eq!(s.busy_cpu(), Duration::from_millis(5));
        assert_eq!(s.busy_npu(), Duration::from_millis(7));
        let l = s.latency();
        assert_eq!(l.count, 2);
        assert_eq!(l.max, Duration::from_millis(7));
    }

    #[test]
    fn latency_buckets_are_monotone_and_bounded() {
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1023), 0);
        assert_eq!(latency_bucket(1024), 1);
        assert_eq!(latency_bucket(u64::MAX), LATENCY_BUCKETS - 1);
        let mut prev = 0;
        for ns in [1u64, 1_000, 1_000_000, 1_000_000_000, u64::MAX] {
            let b = latency_bucket(ns);
            assert!(b >= prev && b < LATENCY_BUCKETS);
            prev = b;
        }
    }

    #[test]
    fn histogram_percentiles_rank_correctly() {
        let h = LatencyHistogram::default();
        // 98 fast samples (~2 µs), 1 medium (~2 ms), 1 slow (~2 s).
        for _ in 0..98 {
            h.record_ns(2_000);
        }
        h.record_ns(2_000_000);
        h.record_ns(2_000_000_000);
        let s = h.summary();
        assert_eq!(s.count, 100);
        // p50/p90 land in the fast bucket, p99 in the medium one, and
        // every percentile is a bucket upper bound ≥ the sample.
        assert!(s.p50 >= Duration::from_nanos(2_000));
        assert!(s.p50 < Duration::from_micros(10));
        assert_eq!(s.p50, s.p90);
        assert!(s.p99 >= Duration::from_millis(2));
        assert!(s.p99 < Duration::from_millis(10));
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99);
    }

    #[test]
    fn empty_histogram_summarizes_to_zero() {
        let s = summarize_latency(&[0u64; LATENCY_BUCKETS]);
        assert_eq!(s, LatencySummary::default());
    }

    #[test]
    fn merge_adds_bucket_counts() {
        let a = LatencyHistogram::default();
        a.record_ns(500);
        let b = LatencyHistogram::default();
        b.record_ns(500);
        b.record_ns(5_000_000);
        let mut m = a.counts();
        merge_latency(&mut m, &b.counts());
        assert_eq!(m.iter().sum::<u64>(), 3);
        assert_eq!(summarize_latency(&m).count, 3);
    }

    #[test]
    fn topic_drops_totals() {
        let d = TopicDrops {
            no_subscriber: 1,
            qos_leaky: 2,
            qos_latest: 3,
            closed: 4,
        };
        assert_eq!(d.subscriber_total(), 9);
        assert_eq!(d.total(), 10);
    }
}
