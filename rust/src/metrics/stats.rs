//! Per-element and per-pipeline statistics probes.
//!
//! Every scheduled element owns an [`ElementStats`] handle; the scheduler
//! records buffers, bytes and busy time as items flow. Work executed on the
//! simulated NPU is recorded in the `npu` domain so that "app CPU" numbers
//! reproduce the paper's offload accounting (see DESIGN.md substitutions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Which compute domain an element's busy time belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Cpu,
    Npu,
}

#[derive(Debug, Default)]
pub struct ElementStats {
    pub name: String,
    buffers_in: AtomicU64,
    buffers_out: AtomicU64,
    bytes_out: AtomicU64,
    busy_ns_cpu: AtomicU64,
    busy_ns_npu: AtomicU64,
    dropped: AtomicU64,
    /// wall-clock offsets (ns since pipeline epoch) of first/last arrivals
    first_in_ns: AtomicU64,
    last_in_ns: AtomicU64,
    /// min/max/sum of per-buffer processing latency (ns)
    lat_sum_ns: AtomicU64,
    lat_max_ns: AtomicU64,
    lat_count: AtomicU64,
    /// Pooled-executor accounting: steps this element's task executed,
    /// how often it parked (empty input / saturated output), how often a
    /// wake made it runnable again, and the high-water mark of its
    /// bounded input inbox.
    steps: AtomicU64,
    parks_input: AtomicU64,
    parks_output: AtomicU64,
    wakeups: AtomicU64,
    queue_hwm: AtomicU64,
}

impl ElementStats {
    pub fn new(name: &str) -> Arc<Self> {
        Arc::new(ElementStats {
            name: name.to_string(),
            ..Default::default()
        })
    }

    pub fn record_in(&self) {
        self.buffers_in.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an arrival with its wall-clock offset from the pipeline
    /// epoch (lets throughput be computed over the element's own active
    /// span instead of the global wall, which may include the draining of
    /// unrelated slow branches after EOS).
    pub fn record_in_at(&self, ns_since_epoch: u64) {
        if self.buffers_in.fetch_add(1, Ordering::Relaxed) == 0 {
            self.first_in_ns.store(ns_since_epoch, Ordering::Relaxed);
        }
        self.last_in_ns.fetch_max(ns_since_epoch, Ordering::Relaxed);
    }

    /// (first, last) arrival offsets, if any buffers arrived.
    pub fn arrival_span(&self) -> Option<(Duration, Duration)> {
        if self.buffers_in.load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some((
            Duration::from_nanos(self.first_in_ns.load(Ordering::Relaxed)),
            Duration::from_nanos(self.last_in_ns.load(Ordering::Relaxed)),
        ))
    }

    pub fn record_out(&self, bytes: usize) {
        self.buffers_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_drop(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_busy(&self, domain: Domain, dur: Duration) {
        let ns = dur.as_nanos() as u64;
        match domain {
            Domain::Cpu => self.busy_ns_cpu.fetch_add(ns, Ordering::Relaxed),
            Domain::Npu => self.busy_ns_npu.fetch_add(ns, Ordering::Relaxed),
        };
        self.lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.lat_max_ns.fetch_max(ns, Ordering::Relaxed);
        self.lat_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_step(&self) {
        self.steps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_park_input(&self) {
        self.parks_input.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_park_output(&self) {
        self.parks_output.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_wakeup(&self) {
        self.wakeups.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the queue depth of this element's inbox after a push
    /// (keeps the link high-water mark).
    pub fn record_queue_depth(&self, len: u64) {
        self.queue_hwm.fetch_max(len, Ordering::Relaxed);
    }

    /// Executor steps this element's task ran.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Times the task parked waiting for input (empty inbox, or a source
    /// waiting for externally pushed application data).
    pub fn parks_input(&self) -> u64 {
        self.parks_input.load(Ordering::Relaxed)
    }

    /// Times the task parked on a saturated downstream inbox.
    pub fn parks_output(&self) -> u64 {
        self.parks_output.load(Ordering::Relaxed)
    }

    /// Times a wake made the parked task runnable again.
    pub fn wakeups(&self) -> u64 {
        self.wakeups.load(Ordering::Relaxed)
    }

    /// High-water mark of this element's bounded input inbox.
    pub fn queue_high_water(&self) -> u64 {
        self.queue_hwm.load(Ordering::Relaxed)
    }

    pub fn buffers_in(&self) -> u64 {
        self.buffers_in.load(Ordering::Relaxed)
    }

    pub fn buffers_out(&self) -> u64 {
        self.buffers_out.load(Ordering::Relaxed)
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn busy_cpu(&self) -> Duration {
        Duration::from_nanos(self.busy_ns_cpu.load(Ordering::Relaxed))
    }

    pub fn busy_npu(&self) -> Duration {
        Duration::from_nanos(self.busy_ns_npu.load(Ordering::Relaxed))
    }

    pub fn latency(&self) -> LatencyStats {
        let count = self.lat_count.load(Ordering::Relaxed);
        LatencyStats {
            count,
            mean: if count == 0 {
                Duration::ZERO
            } else {
                Duration::from_nanos(self.lat_sum_ns.load(Ordering::Relaxed) / count)
            },
            max: Duration::from_nanos(self.lat_max_ns.load(Ordering::Relaxed)),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStats {
    pub count: u64,
    pub mean: Duration,
    pub max: Duration,
}

/// Scheduling counters of one pipeline run on the pooled executor —
/// the Table-III-style accounting extension for the worker-pool core.
/// Per-element sums except `workers` and `run_queue_high_water`, which
/// describe the (possibly shared) executor the pipeline ran on.
#[derive(Debug, Default, Clone, Copy)]
pub struct SchedSnapshot {
    /// Worker threads of the executor this pipeline ran on.
    pub workers: usize,
    /// Element steps executed (one `generate()`/`handle()` per step).
    pub steps: u64,
    /// Parks waiting for input: an empty inbox, or a source waiting for
    /// externally pushed application data (`appsrc`).
    pub parks_input: u64,
    /// Parks on a saturated downstream inbox (backpressure events).
    pub parks_output: u64,
    /// Wakes that made a parked task runnable again.
    pub wakeups: u64,
    /// Executor run-queue high-water mark (tasks runnable but waiting
    /// for a worker; shared across concurrent pipelines).
    pub run_queue_high_water: u64,
    /// Largest bounded-link (inbox) depth any of this pipeline's
    /// elements reached.
    pub link_high_water: u64,
}

/// Counters of one named stream topic (the tensor-query pub/sub layer;
/// see `pipeline/stream.rs`). Cumulative since topic creation and
/// process-global, like the traffic counters.
#[derive(Debug, Default, Clone)]
pub struct TopicSnapshot {
    pub name: String,
    /// Publishers currently attached.
    pub publishers: usize,
    /// Subscriber queues currently attached.
    pub subscribers: usize,
    /// Every publisher finished: the stream ended.
    pub eos: bool,
    /// Buffers accepted from publishers.
    pub published: u64,
    /// Buffer deliveries into subscriber queues (`published` × fan-out).
    pub delivered: u64,
    /// Buffers discarded because no subscriber was attached.
    pub dropped: u64,
}

/// Summary of one pipeline run, assembled by the scheduler.
#[derive(Debug, Default)]
pub struct PipelineReport {
    pub wall: Duration,
    pub elements: Vec<Arc<ElementStats>>,
    pub cpu_percent: f64,
    pub peak_rss_mib: f64,
    /// Byte-traffic and allocator counters accumulated during the run
    /// (process-global deltas: concurrent pipelines share the counters).
    pub traffic: crate::metrics::traffic::Snapshot,
    /// Worker-pool scheduling counters for this run.
    pub sched: SchedSnapshot,
    /// Per-topic stream-endpoint counters at join time (cumulative and
    /// process-global, like `traffic`: concurrent pipelines publishing
    /// to the same registry share them).
    pub topics: Vec<TopicSnapshot>,
}

impl PipelineReport {
    pub fn element(&self, name: &str) -> Option<&Arc<ElementStats>> {
        self.elements.iter().find(|e| e.name == name)
    }

    /// Counters of one stream topic, by name.
    pub fn topic(&self, name: &str) -> Option<&TopicSnapshot> {
        self.topics.iter().find(|t| t.name == name)
    }

    /// Frame rate at element `name`, measured over the element's own
    /// arrival span (a pipeline's slow branch draining after EOS must not
    /// dilute a fast branch's throughput).
    pub fn fps(&self, name: &str) -> f64 {
        let Some(e) = self.element(name) else {
            return 0.0;
        };
        let count = e.buffers_in();
        if count >= 8 {
            if let Some((first, last)) = e.arrival_span() {
                let span = last.saturating_sub(first);
                if !span.is_zero() {
                    return (count - 1) as f64 / span.as_secs_f64();
                }
            }
        }
        if self.wall.is_zero() {
            return 0.0;
        }
        count as f64 / self.wall.as_secs_f64()
    }

    /// Sum of CPU-domain busy time across elements.
    pub fn total_cpu_busy(&self) -> Duration {
        self.elements.iter().map(|e| e.busy_cpu()).sum()
    }

    /// Sum of NPU-domain busy time across elements.
    pub fn total_npu_busy(&self) -> Duration {
        self.elements.iter().map(|e| e.busy_npu()).sum()
    }

    /// Element busy CPU over wallclock, percent-of-one-core (the
    /// framework-attributed CPU load, excluding NPU-domain work).
    pub fn element_cpu_percent(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        100.0 * self.total_cpu_busy().as_secs_f64() / self.wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let s = ElementStats::new("x");
        s.record_in();
        s.record_out(100);
        s.record_busy(Domain::Cpu, Duration::from_millis(5));
        s.record_busy(Domain::Npu, Duration::from_millis(7));
        assert_eq!(s.buffers_in(), 1);
        assert_eq!(s.buffers_out(), 1);
        assert_eq!(s.bytes_out(), 100);
        assert_eq!(s.busy_cpu(), Duration::from_millis(5));
        assert_eq!(s.busy_npu(), Duration::from_millis(7));
        let l = s.latency();
        assert_eq!(l.count, 2);
        assert_eq!(l.max, Duration::from_millis(7));
    }
}
