//! Process-level CPU and memory measurement via procfs.
//!
//! CPU% is computed the way the paper reports it (`top`-style: utime+stime
//! delta over wall-clock, so 4 saturated cores read as 400%). Memory is
//! peak RSS (`VmHWM`), matching the paper's "peak VmRSS" (Table III row 5).

// One of the two audited exceptions to the crate-root
// `#![deny(unsafe_code)]`: a single libc `sysconf` call (declared here
// directly — the crate has no libc dependency). The site carries a
// `// SAFETY:` comment.
#![allow(unsafe_code)]

use std::time::Instant;

// `sysconf(3)` from the platform libc every Rust binary already links.
// `_SC_CLK_TCK` is 2 on Linux (bits/confname.h), the only platform the
// procfs reads above work on anyway.
extern "C" {
    fn sysconf(name: i32) -> i64;
}
const SC_CLK_TCK: i32 = 2;

fn read_proc_stat_jiffies() -> Option<u64> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // fields 14/15 (1-based) are utime/stime; field 2 (comm) may contain
    // spaces but is parenthesized — split after the closing paren.
    let rest = stat.rsplit_once(')')?.1;
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: u64 = fields.get(11)?.parse().ok()?;
    let stime: u64 = fields.get(12)?.parse().ok()?;
    Some(utime + stime)
}

fn jiffies_per_second() -> f64 {
    // SAFETY: sysconf takes no pointers, touches no shared state we own,
    // and is callable at any time; an invalid name returns -1, handled
    // by the fallback below.
    let hz = unsafe { sysconf(SC_CLK_TCK) };
    if hz > 0 {
        hz as f64
    } else {
        100.0
    }
}

/// Tracks process CPU usage between `start()` and `stop()`.
pub struct CpuTracker {
    start_jiffies: u64,
    start_wall: Instant,
}

impl CpuTracker {
    pub fn start() -> Self {
        Self {
            start_jiffies: read_proc_stat_jiffies().unwrap_or(0),
            start_wall: Instant::now(),
        }
    }

    /// CPU usage in percent-of-one-core units (may exceed 100).
    pub fn cpu_percent(&self) -> f64 {
        let jiffies = read_proc_stat_jiffies().unwrap_or(self.start_jiffies) - self.start_jiffies;
        let cpu_secs = jiffies as f64 / jiffies_per_second();
        let wall = self.start_wall.elapsed().as_secs_f64();
        if wall <= 0.0 {
            0.0
        } else {
            100.0 * cpu_secs / wall
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start_wall.elapsed().as_secs_f64()
    }
}

/// Memory info snapshot from /proc/self/status.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemInfo {
    /// Current resident set size, KiB.
    pub vm_rss_kib: u64,
    /// Peak resident set size, KiB.
    pub vm_hwm_kib: u64,
}

impl MemInfo {
    pub fn read() -> MemInfo {
        let mut out = MemInfo::default();
        if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
            for line in status.lines() {
                if let Some(v) = line.strip_prefix("VmRSS:") {
                    out.vm_rss_kib = parse_kib(v);
                } else if let Some(v) = line.strip_prefix("VmHWM:") {
                    out.vm_hwm_kib = parse_kib(v);
                }
            }
        }
        out
    }

    pub fn rss_mib(&self) -> f64 {
        self.vm_rss_kib as f64 / 1024.0
    }

    pub fn peak_mib(&self) -> f64 {
        self.vm_hwm_kib as f64 / 1024.0
    }
}

fn parse_kib(v: &str) -> u64 {
    v.trim()
        .trim_end_matches("kB")
        .trim()
        .parse()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_tracker_measures_busy_loop() {
        let t = CpuTracker::start();
        let mut acc = 0u64;
        let start = Instant::now();
        while start.elapsed().as_millis() < 60 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let pct = t.cpu_percent();
        assert!(pct > 25.0, "busy loop should register CPU, got {pct}");
    }

    #[test]
    fn meminfo_reads_something() {
        let m = MemInfo::read();
        assert!(m.vm_rss_kib > 0);
        assert!(m.vm_hwm_kib >= m.vm_rss_kib);
    }
}
