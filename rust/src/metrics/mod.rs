//! Measurement infrastructure for the paper's evaluation metrics.
//!
//! * per-element probes: buffer count, bytes, busy time, per-buffer latency
//! * process-level CPU% (from `/proc/self/stat`) and peak RSS (`VmHWM`)
//! * global byte-traffic counters — the substitute for the paper's
//!   perf-measured "memory access" row (Table III row 4, see DESIGN.md)
//! * simple reporting tables shared by the benches

pub mod process;
pub mod report;
pub mod stats;
pub mod traffic;

pub use process::{CpuTracker, MemInfo};
pub use stats::{ElementStats, LatencyStats, PipelineReport, SchedSnapshot, TopicSnapshot};
