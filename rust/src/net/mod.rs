//! Network transport subsystem: tensor-query over real sockets.
//!
//! The among-device follow-on paper (arXiv:2201.06026) composes one
//! logical AI pipeline across devices; PR 5's stream-endpoint layer
//! built the topic pub/sub surface but stopped at the process
//! boundary. This module crosses it:
//!
//! * [`wire`] — the versioned framed codec (magic + version + type +
//!   length + checksum) carrying caps, tensor buffers, EOS, typed
//!   faults, credit grants, and registry operations;
//! * [`transport`] — [`TcpTransport`], a [`Transport`] backend with
//!   per-subscriber **credit-based flow control** (a full remote queue
//!   parks the publisher like an in-pipeline link; non-blocking QoS
//!   sheds with typed drops) and reconnects that preserve
//!   EOS-vs-fault close reasons;
//! * [`registry`] — the [`NetRegistry`] discovery service resolving
//!   `topic → host:port` across OS processes.
//!
//! Register with [`register_tcp`] and the element API is unchanged:
//! `tensor_query_serversink topic=ns/frames transport=tcp` serves a
//! topic; a `tensor_query_serversrc` with the same properties in
//! another process consumes it.
//!
//! [`Transport`]: crate::pipeline::stream::Transport

pub mod registry;
pub mod transport;
pub mod wire;

use std::sync::{Arc, Weak};

use crate::sync::Mutex;

use once_cell::sync::Lazy;

use crate::metrics::stats::TopicSnapshot;
use crate::pipeline::executor::lock;

pub use registry::{NetRegistry, RegistryClient, RegistryServer};
pub use transport::{TcpConfig, TcpTransport};

/// Every live [`TcpTransport`] created through [`register_tcp`] /
/// [`register_tcp_as`], so pipeline reports can fold network topic
/// counters in next to in-process ones.
static INSTANCES: Lazy<Mutex<Vec<Weak<TcpTransport>>>> = Lazy::new(Mutex::default);

/// Create a [`TcpTransport`] and register it under the standard
/// `transport=tcp` name.
pub fn register_tcp(cfg: TcpConfig) -> Arc<TcpTransport> {
    register_tcp_as("tcp", cfg)
}

/// Create a [`TcpTransport`] under a caller-chosen transport name
/// (parallel tests register isolated instances as `tcp-<case>`).
pub fn register_tcp_as(name: &str, cfg: TcpConfig) -> Arc<TcpTransport> {
    let t = Arc::new(TcpTransport::new(cfg));
    lock(&INSTANCES).push(Arc::downgrade(&t));
    crate::pipeline::stream::register_transport(name, t.clone());
    t
}

/// Counter snapshots of every live TCP transport (served topics as
/// `tcp-pub:<topic>`, subscriptions as `tcp-sub:<topic>`); appended to
/// [`PipelineReport::topics`](crate::metrics::stats::PipelineReport)
/// so the conservation identity is reportable on both sides of a wire.
pub fn topics_snapshot() -> Vec<TopicSnapshot> {
    let mut g = lock(&INSTANCES);
    g.retain(|w| w.strong_count() > 0);
    g.iter()
        .filter_map(Weak::upgrade)
        .flat_map(|t| t.snapshot())
        .collect()
}
